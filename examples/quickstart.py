"""Quickstart: RF-TCA (paper Algorithm 1) on a synthetic domain-shift task.

    PYTHONPATH=src python examples/quickstart.py

Fits the RFF-based transfer components between a source and a target domain,
trains a classifier on aligned source features, and compares target accuracy
against no adaptation — reproducing the paper's core single-machine claim.
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.baselines import rf_tca_baseline, source_only, tca_baseline
from repro.core.rf_tca import rf_tca
from repro.data import make_domains, normalize_unit


def main() -> None:
    doms = make_domains(2, 400, shift=1.2, seed=7)
    source, target = doms

    print("== RF-TCA quickstart ==")
    print(f"source: X{source.x.shape}, target: X{target.x.shape}\n")

    # 1) low-level API: fit + transform (out-of-sample capable)
    f_s, f_t, state = rf_tca(
        normalize_unit(source.x), normalize_unit(target.x),
        n_features=512, m=16, gamma=1e-3, sigma=1.0, seed=0,
    )
    print(f"aligned features: F_S {f_s.shape}, F_T {f_t.shape}")
    print(f"top eigenvalues: {np.round(np.asarray(state.eigvals[:4]), 4)}")
    print(f"client message size (2N): {2 * state.omega.shape[0]} floats\n")

    # 2) end-to-end accuracy comparison
    acc_none = source_only([source], target, seed=0)
    acc_tca = tca_baseline([source], target, gamma=1e-3, m=16)
    acc_rf = rf_tca_baseline([source], target, n_features=512, gamma=1e-3, m=16)
    print(f"target accuracy, no adaptation : {acc_none:.3f}")
    print(f"target accuracy, vanilla TCA   : {acc_tca:.3f}")
    print(f"target accuracy, RF-TCA        : {acc_rf:.3f}")
    assert acc_rf > acc_none, "RF-TCA should beat source-only under shift"
    print("\nOK: RF-TCA recovers accuracy lost to domain shift.")


if __name__ == "__main__":
    main()
