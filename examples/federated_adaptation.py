"""End-to-end FedRF-TCA driver (paper Algorithm 5) — the paper's kind of
end-to-end run: multi-source federated domain adaptation over an unreliable
network, with communication accounting.

    PYTHONPATH=src python examples/federated_adaptation.py [--rounds 300]

Four source clients + one unlabeled target client, shared-seed RFF compressor,
FedAvg of W_RF every round and classifiers every T_C rounds, under message-drop
setting (III) — the harshest of Table III.

``--async`` swaps the lockstep round loop for the event-driven fedsim runtime:
clients churn on a seeded Markov on/off trace, their uplinks land after
link-model latencies, and the server aggregates a FedBuff-style buffer with
polynomial staleness weighting — the same adaptation problem, advanced on a
virtual clock instead of a round counter.
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp

from repro.data import make_domains
from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig
from repro.federated.model import accuracy


def run_async(tr, args) -> None:
    """Churny event-driven run: report accuracy against virtual time."""
    from repro.comm.netsim import LinkModel, LinkScenario
    from repro.fedsim import AsyncConfig, AsyncScheduler, markov_trace

    k = len(tr.sources)
    links = LinkScenario(
        links=[LinkModel(latency_s=0.2 * (i + 1), bandwidth_bps=1e5) for i in range(k)]
    )
    avail = markov_trace(
        k, horizon=500.0 * args.rounds, mean_on=20.0,
        mean_off=20.0 * args.churn / max(1.0 - args.churn, 1e-6), seed=1,
    )
    sched = AsyncScheduler(
        tr,
        AsyncConfig(buffer_size=max(k // 2, 1), staleness="polynomial"),
        availability=avail if args.churn > 0 else None,
        links=links,
    )
    uplink_bytes = sum(sched.payload_bytes.get(k, 0) for k in ("moments", "w_rf"))
    print(
        f"async runtime: buffer={sched.cfg.buffer_size}, churn fraction ~{args.churn:.0%}, "
        f"uplink bytes={uplink_bytes}"
    )
    hist = sched.run(args.rounds, eval_every=max(args.rounds // 8, 1))
    for h in hist:
        if "acc" in h:
            stale = max(h["staleness"])
            print(
                f"virtual t={h['t']:8.1f}s  flush {h['flush']:4d}  "
                f"target acc = {h['acc']:.3f}  (buffer staleness max {stale})"
            )
    final = tr.evaluate()
    print(f"\nfinal target accuracy: {final:.3f} after {sched.flushes} buffered flushes")
    print(f"virtual wall-clock: {sched.clock.now:.1f}s; churned clients resumed with "
          f"stale aligners and their updates were staleness-discounted at the merge.")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--warmup", type=int, default=150)
    ap.add_argument("--setting", default="III", choices=["I", "II", "III"])
    ap.add_argument("--async", dest="use_async", action="store_true",
                    help="event-driven fedsim runtime: churn + buffered aggregation")
    ap.add_argument("--churn", type=float, default=0.3,
                    help="offline fraction of the Markov churn trace (with --async)")
    args = ap.parse_args()

    doms = make_domains(5, 400, shift=1.2, seed=3)
    sources, target = doms[:4], doms[4]
    cfg = ClientConfig(input_dim=16, n_classes=5, n_rff=128, m=16, lambda_mmd=2.0)
    proto = ProtocolConfig(
        n_rounds=args.rounds, t_c=25, warmup_rounds=args.warmup, lr=5e-3,
        drop_setting=args.setting, seed=0,
    )
    print(f"== FedRF-TCA: {len(sources)} sources -> 1 target, drop setting ({args.setting}) ==")
    tr = FedRFTCATrainer(sources, target, cfg, proto)
    if args.use_async:
        run_async(tr, args)
        return
    xt, yt = jnp.asarray(target.x), jnp.asarray(target.y)
    warm = float(accuracy(tr.tgt_params, tr.omega, xt, yt))
    print(f"after FedAvg warm-up ({args.warmup} rounds): target acc = {warm:.3f}")

    for block in range(4):
        n = args.rounds // 4
        for t in range(1, n + 1):
            tr.round(block * n + t)
        acc = tr.evaluate()
        per_round = tr.comm.total / tr.comm.rounds
        print(
            f"round {(block+1)*n:4d}: target acc = {acc:.3f} "
            f"(uplink {per_round:,.0f} floats/round, "
            f"{tr.comm.data_messages/tr.comm.rounds:,.0f} of which are Sigma-ell messages)"
        )
    final = tr.evaluate()
    print(f"\nfinal target accuracy: {final:.3f} (warm-up was {warm:.3f})")
    print("message size is 2N =", 2 * cfg.n_rff, "floats — independent of client data size.")
    assert final > warm, "adaptation should improve on the warm-up transfer"


if __name__ == "__main__":
    main()
