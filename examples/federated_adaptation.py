"""End-to-end FedRF-TCA driver (paper Algorithm 5) — the paper's kind of
end-to-end run: multi-source federated domain adaptation over an unreliable
network, with communication accounting.

    PYTHONPATH=src python examples/federated_adaptation.py [--rounds 300]

Four source clients + one unlabeled target client, shared-seed RFF compressor,
FedAvg of W_RF every round and classifiers every T_C rounds, under message-drop
setting (III) — the harshest of Table III.
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp

from repro.data import make_domains
from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig
from repro.federated.model import accuracy


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--warmup", type=int, default=150)
    ap.add_argument("--setting", default="III", choices=["I", "II", "III"])
    args = ap.parse_args()

    doms = make_domains(5, 400, shift=1.2, seed=3)
    sources, target = doms[:4], doms[4]
    cfg = ClientConfig(input_dim=16, n_classes=5, n_rff=128, m=16, lambda_mmd=2.0)
    proto = ProtocolConfig(
        n_rounds=args.rounds, t_c=25, warmup_rounds=args.warmup, lr=5e-3,
        drop_setting=args.setting, seed=0,
    )
    print(f"== FedRF-TCA: {len(sources)} sources -> 1 target, drop setting ({args.setting}) ==")
    tr = FedRFTCATrainer(sources, target, cfg, proto)
    xt, yt = jnp.asarray(target.x), jnp.asarray(target.y)
    warm = float(accuracy(tr.tgt_params, tr.omega, xt, yt))
    print(f"after FedAvg warm-up ({args.warmup} rounds): target acc = {warm:.3f}")

    for block in range(4):
        n = args.rounds // 4
        for t in range(1, n + 1):
            tr.round(block * n + t)
        acc = tr.evaluate()
        per_round = tr.comm.total / tr.comm.rounds
        print(
            f"round {(block+1)*n:4d}: target acc = {acc:.3f} "
            f"(uplink {per_round:,.0f} floats/round, "
            f"{tr.comm.data_messages/tr.comm.rounds:,.0f} of which are Sigma-ell messages)"
        )
    final = tr.evaluate()
    print(f"\nfinal target accuracy: {final:.3f} (warm-up was {warm:.3f})")
    print("message size is 2N =", 2 * cfg.n_rff, "floats — independent of client data size.")
    assert final > warm, "adaptation should improve on the warm-up transfer"


if __name__ == "__main__":
    main()
