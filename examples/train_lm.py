"""Train an assigned-architecture LM with the FDA head active (eq. 12 on the
client=data-shard axis), asserting the loss decreases.

    PYTHONPATH=src python examples/train_lm.py                 # reduced (CPU)
    PYTHONPATH=src python examples/train_lm.py --arch smollm-135m --full

The reduced default finishes in ~2 min on CPU; --full runs the real config
(use the production mesh + dryrun-verified shardings for that).
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import train as train_mod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()

    argv = ["--arch", args.arch, "--steps", str(args.steps), "--batch", "8",
            "--seq", "128", "--clients", "2", "--log-every", "25"]
    if not args.full:
        argv.append("--reduced")
    out = train_mod.main(argv)
    assert out["last"] < out["first"], "loss must decrease"
    print("OK: loss decreased", f"{out['first']:.3f} -> {out['last']:.3f}")


if __name__ == "__main__":
    main()
