"""Serve a small model with batched requests: prefill + greedy decode.

    PYTHONPATH=src python examples/serve_batch.py --arch mamba2-2.7b
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import serve as serve_mod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()
    out = serve_mod.main(
        ["--arch", args.arch, "--reduced", "--batch", str(args.batch),
         "--prompt-len", "32", "--gen", "16"]
    )
    print("OK: served", out["tokens"].shape, "tokens")


if __name__ == "__main__":
    main()
