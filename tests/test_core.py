"""Core paper math: TCA variants, RF-TCA, MMD, Sherman-Morrison identities."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    centering_matrix,
    ell_vector,
    gaussian_kernel,
    laplace_kernel,
    message,
    mmd_projected,
    mmd_rff,
    mmd_rkhs,
    r_tca,
    rf_tca_fit,
    rf_tca_transform,
    solve_w_rf,
    vanilla_tca,
)
from repro.core.rff import draw_omega, rff_features
from repro.core.tca import r_tca_matrix


@pytest.fixture(scope="module")
def data(rng):
    p, ns, nt = 8, 60, 40
    xs = jnp.asarray(rng.normal(size=(p, ns)), jnp.float32)
    xt = jnp.asarray(rng.normal(size=(p, nt)) + 1.0, jnp.float32)
    x = jnp.concatenate([xs, xt], axis=1)
    return xs, xt, x, ell_vector(ns, nt)


def test_ell_vector_properties(data):
    *_, ell = data
    ns, nt = 60, 40
    assert np.isclose(float(jnp.sum(ell)), 0.0, atol=1e-6)  # H l = l
    assert np.isclose(float(ell @ ell), (ns + nt) / (ns * nt), rtol=1e-5)  # paper eq. (2)


def test_centering_matrix_idempotent():
    h = centering_matrix(10)
    assert np.allclose(h @ h, h, atol=1e-6)


def test_vanilla_tca_eigvals_descending(data):
    _, _, x, ell = data
    k = gaussian_kernel(x, 2.0)
    res = vanilla_tca(k, ell, 1e-2, 6)
    v = np.asarray(res.eigvals)
    assert (np.diff(v) <= 1e-5).all()
    assert res.features.shape == (6, 100)


def test_sherman_morrison_form_matches_direct_inverse(data):
    """Lemma 1: the rank-one corrected matrix equals the explicit inverse form."""
    _, _, x, ell = data
    k = np.asarray(gaussian_kernel(x, 2.0), np.float64)
    ell = np.asarray(ell, np.float64)
    gamma = 0.05
    # direct: (gamma I + K ll^T K)^{-1} K H K -> top eigvecs of symmetric form
    n = k.shape[0]
    direct = np.linalg.inv(gamma * np.eye(n) + k @ np.outer(ell, ell) @ k)
    u = k @ k @ ell
    sm = (np.eye(n) - (k @ np.outer(ell, ell) @ k) / (gamma + ell @ k @ k @ ell)) / gamma
    assert np.allclose(direct, sm, atol=1e-8)


def test_r_tca_equals_generalized_eig(data):
    """Eq. (22): A_R's top eigenspace == R-TCA solution."""
    _, _, x, ell = data
    k = gaussian_kernel(x, 2.0)
    res = r_tca(k, ell, 1e-2, 4)
    a_r = r_tca_matrix(k, ell, 1e-2)
    vals = np.linalg.eigvalsh(np.asarray(a_r, np.float64))[::-1][:4]
    assert np.allclose(np.asarray(res.eigvals), vals, rtol=1e-3)


def test_rf_tca_reduces_projected_mmd(data):
    xs, xt, x, ell = data
    st = rf_tca_fit(xs, xt, n_features=256, m=8, gamma=1e-2, sigma=2.0, seed=0)
    sig = rff_features(x, st.omega)
    m_s = message(rff_features(xs, st.omega), +1.0)
    m_t = message(rff_features(xt, st.omega), -1.0)
    raw = mmd_rff(sig, ell)
    proj = mmd_projected(st.w_rf, m_s, m_t)
    assert float(proj) < 0.1 * float(raw)


def test_rf_tca_out_of_sample(data):
    xs, xt, *_ = data
    st = rf_tca_fit(xs, xt, n_features=128, m=8, gamma=1e-2, sigma=2.0, seed=0)
    f_new = rf_tca_transform(st, xs[:, :5])
    assert f_new.shape == (8, 5)
    assert np.isfinite(np.asarray(f_new)).all()


def test_mmd_rkhs_vs_rff_agree(data):
    _, _, x, ell = data
    k = gaussian_kernel(x, 2.0)
    omega = draw_omega(0, 4096, x.shape[0], sigma=2.0)
    sig = rff_features(x, omega)
    exact = float(mmd_rkhs(k, ell))
    approx = float(mmd_rff(sig, ell))
    assert abs(exact - approx) < 0.1 * abs(exact) + 1e-3


def test_mmd_decomposability(data):
    """Eq. (11): pair loss only needs the two 2N-float messages."""
    xs, xt, x, ell = data
    omega = draw_omega(1, 64, x.shape[0])
    sig = rff_features(x, omega)
    m_s = message(rff_features(xs, omega), +1.0)
    m_t = message(rff_features(xt, omega), -1.0)
    w = jnp.eye(128)
    assert np.isclose(float(mmd_projected(w, m_s, m_t)), float(mmd_rff(sig, ell)), rtol=1e-4)


def test_message_size_independent_of_n(data):
    xs, xt, *_ = data
    omega = draw_omega(0, 32, xs.shape[0])
    m1 = message(rff_features(xs, omega), +1.0)
    m2 = message(rff_features(xs[:, :7], omega), +1.0)
    assert m1.shape == m2.shape == (64,)


def test_solve_w_rf_constraint(data):
    """W^T (Sigma H Sigma^T) W should be ~orthonormal on the top eigenspace."""
    xs, xt, x, ell = data
    omega = draw_omega(0, 64, x.shape[0], sigma=2.0)
    sig = rff_features(x, omega)
    w, vals = solve_w_rf(sig, ell, 1e-2, 4)
    assert w.shape == (128, 4)
    assert (np.diff(np.asarray(vals)) <= 1e-5).all()


def test_laplace_kernel_psd(data):
    _, _, x, _ = data
    k = laplace_kernel(x, 2.0)
    vals = np.linalg.eigvalsh(np.asarray(k, np.float64))
    assert vals.min() > -1e-6
