"""Per-assigned-architecture smoke tests (deliverable f).

Each of the 10 archs is instantiated as its REDUCED variant (2 layers,
d_model <= 256, <= 4 experts) and runs one forward + one train step on CPU,
asserting output shapes and no NaNs. Full configs are exercised only via the
AOT dry-run (launch/dryrun.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import LM, ShardRules
from repro.optim import adamw, apply_updates


def _batch(cfg, key, b=2, s=32):
    batch = {}
    if cfg.embeddings_in:
        batch["embeddings"] = jax.random.normal(key, (b, s, cfg.d_model)) * 0.1
    else:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["images"] = jax.random.normal(key, (b, cfg.n_image_tokens, cfg.d_image)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_reduced_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = LM(cfg, ShardRules(model_size=1))
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, key)

    hidden, aux = model.forward(params, batch)
    assert hidden.shape == (2, 32, cfg.d_model)
    assert np.isfinite(np.asarray(hidden, np.float32)).all()

    logits = model.logits(params, hidden)
    assert logits.shape == (2, 32, cfg.vocab_padded)

    opt = adamw(1e-3)
    opt_state = opt.init(params)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: model.loss(p, batch, 2), has_aux=True
    )(params)
    assert np.isfinite(float(loss))
    upd, opt_state = opt.update(grads, opt_state, params)
    new_params = apply_updates(params, upd)
    # params actually changed
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(new_params))
    )
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_reduced_decode_step(arch):
    cfg = get_config(arch).reduced()
    model = LM(cfg, ShardRules(model_size=1))
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    cache = model.init_cache(2, 16)
    db = {}
    if cfg.embeddings_in:
        db["embeddings"] = jax.random.normal(key, (2, 1, cfg.d_model)) * 0.1
    else:
        db["tokens"] = jax.random.randint(key, (2, 1), 0, cfg.vocab_size)
    logits, new_cache = model.decode_step(params, cache, db, jnp.int32(0))
    assert logits.shape == (2, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_all_archs_have_exact_specs():
    """Config fields match the assignment table."""
    expected = {
        "mamba2-2.7b": dict(n_layers=64, d_model=2560, vocab_size=50280, ssm_state=128),
        "deepseek-v2-lite-16b": dict(
            n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
            vocab_size=102400, kv_lora_rank=512, top_k=6,
        ),
        "internlm2-1.8b": dict(
            n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=8192, vocab_size=92544
        ),
        "zamba2-7b": dict(
            n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
            vocab_size=32000, ssm_state=64,
        ),
        "smollm-360m": dict(
            n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_ff=2560, vocab_size=49152
        ),
        "qwen3-moe-235b-a22b": dict(
            n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_ff=1536,
            vocab_size=151936, n_experts=128, top_k=8,
        ),
        "smollm-135m": dict(
            n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, d_ff=1536, vocab_size=49152
        ),
        "llama-3.2-vision-90b": dict(
            n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672, vocab_size=128256
        ),
        "musicgen-large": dict(
            n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192, vocab_size=2048
        ),
        "command-r-plus-104b": dict(
            n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, d_ff=33792, vocab_size=256000
        ),
    }
    for arch, fields in expected.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
        assert cfg.source  # provenance citation present


def test_param_counts_roughly_match_names():
    """Sanity: '104b' config really is ~104B params etc."""
    approx = {
        "command-r-plus-104b": 104e9,
        "mamba2-2.7b": 2.7e9,
        "smollm-135m": 135e6,
        "smollm-360m": 360e6,
        "internlm2-1.8b": 1.8e9,
    }
    for arch, target in approx.items():
        n = LM(get_config(arch)).param_count()
        assert 0.5 * target < n < 1.8 * target, (arch, n, target)
