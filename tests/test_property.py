"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this container")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.kernels_math import centering_matrix, ell_vector, gaussian_kernel
from repro.core.mmd import message, mmd_projected
from repro.core.rff import draw_omega, rff_features
from repro.federated.aggregation import hard_vote
from repro.models.layers import cross_entropy
from repro.utils.tree import tree_mean, tree_weighted_mean

SETTINGS = dict(max_examples=25, deadline=None)


@given(ns=st.integers(1, 50), nt=st.integers(1, 50))
@settings(**SETTINGS)
def test_ell_vector_invariants(ns, nt):
    ell = np.asarray(ell_vector(ns, nt))
    assert np.isclose(ell.sum(), 0.0, atol=1e-5)
    assert np.isclose(ell @ ell, (ns + nt) / (ns * nt), rtol=1e-4)
    # H l = l (centering leaves ell invariant)
    h = np.asarray(centering_matrix(ns + nt))
    assert np.allclose(h @ ell, ell, atol=1e-6)


@given(
    p=st.integers(2, 10), n=st.integers(2, 30), nf=st.integers(4, 64),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_rff_gram_is_psd_and_diag_one(p, n, nf, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(p, n)), jnp.float32)
    om = draw_omega(seed, nf, p)
    s = rff_features(x, om)
    g = np.asarray(s.T @ s, np.float64)
    vals = np.linalg.eigvalsh(0.5 * (g + g.T))
    assert vals.min() > -1e-5  # PSD
    # diag of Sigma^T Sigma == ||phi(x)||^2 == (cos^2+sin^2 summed)/N == 1
    assert np.allclose(np.diag(g), 1.0, atol=1e-5)


@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 20))
@settings(**SETTINGS)
def test_gaussian_kernel_range(seed, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(4, n)), jnp.float32)
    k = np.asarray(gaussian_kernel(x, 1.5))
    assert (k <= 1.0 + 1e-6).all() and (k >= 0.0).all()
    assert np.allclose(np.diag(k), 1.0, atol=1e-5)


@given(seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_message_scale_invariance_in_n(seed):
    """Duplicating every sample leaves the message unchanged (it's a mean)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(4, 10)), jnp.float32)
    om = draw_omega(0, 8, 4)
    m1 = message(rff_features(x, om), 1.0)
    x2 = jnp.concatenate([x, x], axis=1)
    m2 = message(rff_features(x2, om), 1.0)
    assert np.allclose(m1, m2, atol=1e-5)


@given(seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_mmd_projected_nonnegative(seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    assert float(mmd_projected(w, a, b)) >= 0.0


@given(k=st.integers(1, 5), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_fedavg_idempotent_on_identical_models(k, seed):
    rng = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(rng.normal(size=(3, 2)), jnp.float32)}
    avg = tree_mean([tree] * k)
    assert np.allclose(avg["a"], tree["a"], atol=1e-6)


@given(seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_weighted_mean_convexity(seed):
    rng = np.random.default_rng(seed)
    a = {"x": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}
    b = {"x": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}
    out = tree_weighted_mean([a, b], [3.0, 1.0])["x"]
    lo = np.minimum(a["x"], b["x"]) - 1e-6
    hi = np.maximum(a["x"], b["x"]) + 1e-6
    assert ((out >= lo) & (out <= hi)).all()


@given(
    k=st.integers(1, 7), n=st.integers(1, 10), c=st.integers(2, 6),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_hard_vote_unanimous(k, n, c, seed):
    rng = np.random.default_rng(seed)
    cls = rng.integers(0, c, size=n)
    logits = rng.normal(size=(k, n, c)) * 0.01
    logits[:, np.arange(n), cls] += 10.0  # every client agrees
    assert (hard_vote(logits) == cls).all()


@given(seed=st.integers(0, 2**31 - 1), v=st.integers(5, 50), pad=st.integers(0, 16))
@settings(**SETTINGS)
def test_cross_entropy_padding_invariant(seed, v, pad):
    """Padded vocab entries must not change the loss."""
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(2, 3, v)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, size=(2, 3)))
    base = float(cross_entropy(logits, labels, v))
    padded = jnp.concatenate(
        [logits, jnp.asarray(rng.normal(size=(2, 3, pad)), jnp.float32)], axis=-1
    )
    withpad = float(cross_entropy(padded, labels, v))
    assert np.isclose(base, withpad, atol=1e-3)
    assert base >= 0.0


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_flash_attention_row_stochastic(seed):
    """Attention output of constant-V must be constant (softmax sums to 1)."""
    from repro.models.attention import flash_attention

    key = jax.random.PRNGKey(seed % (2**31))
    q = jax.random.normal(key, (1, 16, 2, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 16, 2, 8))
    v = jnp.ones((1, 16, 2, 8))
    out = flash_attention(q, k, v, causal=True)
    assert np.allclose(np.asarray(out), 1.0, atol=1e-5)
