"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this container")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.kernels_math import centering_matrix, ell_vector, gaussian_kernel
from repro.core.mmd import message, mmd_projected
from repro.core.rff import draw_omega, rff_features
from repro.federated.aggregation import hard_vote, staleness_weights
from repro.fedsim.availability import AvailabilityTrace
from repro.models.layers import cross_entropy
from repro.utils.tree import tree_mean, tree_weighted_mean

SETTINGS = dict(max_examples=25, deadline=None)


@given(ns=st.integers(1, 50), nt=st.integers(1, 50))
@settings(**SETTINGS)
def test_ell_vector_invariants(ns, nt):
    ell = np.asarray(ell_vector(ns, nt))
    assert np.isclose(ell.sum(), 0.0, atol=1e-5)
    assert np.isclose(ell @ ell, (ns + nt) / (ns * nt), rtol=1e-4)
    # H l = l (centering leaves ell invariant)
    h = np.asarray(centering_matrix(ns + nt))
    assert np.allclose(h @ ell, ell, atol=1e-6)


@given(
    p=st.integers(2, 10), n=st.integers(2, 30), nf=st.integers(4, 64),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_rff_gram_is_psd_and_diag_one(p, n, nf, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(p, n)), jnp.float32)
    om = draw_omega(seed, nf, p)
    s = rff_features(x, om)
    g = np.asarray(s.T @ s, np.float64)
    vals = np.linalg.eigvalsh(0.5 * (g + g.T))
    assert vals.min() > -1e-5  # PSD
    # diag of Sigma^T Sigma == ||phi(x)||^2 == (cos^2+sin^2 summed)/N == 1
    assert np.allclose(np.diag(g), 1.0, atol=1e-5)


@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 20))
@settings(**SETTINGS)
def test_gaussian_kernel_range(seed, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(4, n)), jnp.float32)
    k = np.asarray(gaussian_kernel(x, 1.5))
    assert (k <= 1.0 + 1e-6).all() and (k >= 0.0).all()
    assert np.allclose(np.diag(k), 1.0, atol=1e-5)


@given(seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_message_scale_invariance_in_n(seed):
    """Duplicating every sample leaves the message unchanged (it's a mean)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(4, 10)), jnp.float32)
    om = draw_omega(0, 8, 4)
    m1 = message(rff_features(x, om), 1.0)
    x2 = jnp.concatenate([x, x], axis=1)
    m2 = message(rff_features(x2, om), 1.0)
    assert np.allclose(m1, m2, atol=1e-5)


@given(seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_mmd_projected_nonnegative(seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    assert float(mmd_projected(w, a, b)) >= 0.0


@given(k=st.integers(1, 5), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_fedavg_idempotent_on_identical_models(k, seed):
    rng = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(rng.normal(size=(3, 2)), jnp.float32)}
    avg = tree_mean([tree] * k)
    assert np.allclose(avg["a"], tree["a"], atol=1e-6)


@given(seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_weighted_mean_convexity(seed):
    rng = np.random.default_rng(seed)
    a = {"x": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}
    b = {"x": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}
    out = tree_weighted_mean([a, b], [3.0, 1.0])["x"]
    lo = np.minimum(a["x"], b["x"]) - 1e-6
    hi = np.maximum(a["x"], b["x"]) + 1e-6
    assert ((out >= lo) & (out <= hi)).all()


@given(
    k=st.integers(1, 7), n=st.integers(1, 10), c=st.integers(2, 6),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_hard_vote_unanimous(k, n, c, seed):
    rng = np.random.default_rng(seed)
    cls = rng.integers(0, c, size=n)
    logits = rng.normal(size=(k, n, c)) * 0.01
    logits[:, np.arange(n), cls] += 10.0  # every client agrees
    assert (hard_vote(logits) == cls).all()


@given(
    s=st.lists(st.integers(0, 60), min_size=1, max_size=8),
    alpha=st.floats(0.05, 3.0, allow_nan=False),
)
@settings(**SETTINGS)
def test_staleness_polynomial_freshness_monotone(s, alpha):
    """Fresher updates never weigh less; staleness 0 is exactly unit weight;
    every weight sits in (0, 1]."""
    w = staleness_weights(np.array(s), f"polynomial:{alpha}")
    assert ((w > 0.0) & (w <= 1.0)).all()
    for i, si in enumerate(s):
        if si == 0:
            assert w[i] == 1.0
        for j, sj in enumerate(s):
            if si <= sj:
                assert w[i] >= w[j] - 1e-7


@given(s=st.lists(st.integers(0, 60), min_size=1, max_size=8), seed=st.integers(0, 999))
@settings(**SETTINGS)
def test_staleness_constant_mode_invariance(s, seed):
    """Constant mode ignores the staleness tags entirely (FedBuff's mean):
    all-ones under any tags and any permutation of them."""
    arr = np.array(s)
    assert (staleness_weights(arr, "constant") == 1.0).all()
    perm = np.random.default_rng(seed).permutation(len(arr))
    assert (staleness_weights(arr[perm], "constant") == 1.0).all()


@given(
    s=st.lists(st.integers(0, 20), min_size=2, max_size=6),
    scale=st.floats(0.1, 100.0, allow_nan=False),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_staleness_auto_normalization(s, scale, seed):
    """Auto mode's importance factor is n / mean(n): uniform sample counts
    reduce it to the polynomial weights, and rescaling every count by the
    same constant leaves the weights unchanged (only relative sizes count)."""
    arr = np.array(s)
    n = np.random.default_rng(seed).integers(1, 1000, size=len(arr)).astype(float)
    uniform = staleness_weights(arr, "auto", n_samples=np.full(len(arr), 7.0))
    assert np.allclose(uniform, staleness_weights(arr, "polynomial"), rtol=1e-5)
    a = staleness_weights(arr, "auto", n_samples=n)
    b = staleness_weights(arr, "auto", n_samples=n * scale)
    assert np.allclose(a, b, rtol=1e-4)


def _interval_traces():
    """Sorted, disjoint, possibly *touching* interval lists (gap 0 touches —
    the coalescing case) built from non-negative gap/length pairs."""
    seg = st.tuples(st.integers(0, 3), st.integers(1, 4))  # (gap, length)
    return st.lists(st.lists(seg, min_size=0, max_size=6), min_size=1, max_size=3)


@given(data=_interval_traces())
@settings(**SETTINGS)
def test_availability_coalescing_invariants(data):
    """Whatever valid (possibly touching, possibly empty) interval lists go
    in: uptime is preserved, stored intervals are sorted/disjoint with no
    touching pair left (no phantom churn edges), and the edge stream strictly
    alternates join/depart starting with a join."""
    intervals, horizon = [], 1.0
    for segs in data:
        ivs, t = [], 0.0
        for gap, length in segs:
            s = t + gap
            e = s + length
            ivs.append((s, e))
            t = e
        horizon = max(horizon, t + 1.0)
        intervals.append(ivs)
    raw_uptime = [sum(e - s for s, e in ivs) for ivs in intervals]
    tr = AvailabilityTrace(float(horizon), intervals)
    for i, ivs in enumerate(tr.intervals):
        assert tr.uptime(i) == raw_uptime[i]  # coalescing never loses time
        for (s1, e1), (s2, e2) in zip(ivs, ivs[1:]):
            assert e1 < s2  # strictly disjoint AND non-touching after merge
        edges = tr.edges(i)
        kinds = [is_join for _, is_join in edges]
        assert kinds == [j % 2 == 0 for j in range(len(kinds))]  # alternate
        assert len(edges) == 0 or kinds[0] is True
        times = [t for t, _ in edges]
        assert times == sorted(times)
        if not ivs:  # the empty-trace client: never available, no edges
            assert edges == [] and not tr.available(i, 0.0)


@given(
    lo=st.integers(0, 5), mid=st.integers(1, 5), hi=st.integers(1, 5),
)
@settings(**SETTINGS)
def test_availability_nested_intervals_rejected(lo, mid, hi):
    """A nested (or otherwise overlapping) second interval must raise."""
    outer = (float(lo), float(lo + mid + hi + 1))
    inner = (float(lo + 1), float(lo + 1 + mid))
    with pytest.raises(ValueError, match="overlapping|bad interval"):
        AvailabilityTrace(outer[1] + 1.0, [[outer, inner]])


@given(seed=st.integers(0, 2**31 - 1), v=st.integers(5, 50), pad=st.integers(0, 16))
@settings(**SETTINGS)
def test_cross_entropy_padding_invariant(seed, v, pad):
    """Padded vocab entries must not change the loss."""
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(2, 3, v)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, size=(2, 3)))
    base = float(cross_entropy(logits, labels, v))
    padded = jnp.concatenate(
        [logits, jnp.asarray(rng.normal(size=(2, 3, pad)), jnp.float32)], axis=-1
    )
    withpad = float(cross_entropy(padded, labels, v))
    assert np.isclose(base, withpad, atol=1e-3)
    assert base >= 0.0


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_flash_attention_row_stochastic(seed):
    """Attention output of constant-V must be constant (softmax sums to 1)."""
    from repro.models.attention import flash_attention

    key = jax.random.PRNGKey(seed % (2**31))
    q = jax.random.normal(key, (1, 16, 2, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 16, 2, 8))
    v = jnp.ones((1, 16, 2, 8))
    out = flash_attention(q, k, v, causal=True)
    assert np.allclose(np.asarray(out), 1.0, atol=1e-5)


# ---- robust aggregation rules (repro.robust.rules) -------------------------

from repro.robust.rules import (  # noqa: E402
    FiniteMeanRule,
    GeoMedianRule,
    MeanRule,
    NormClipRule,
    TrimmedMeanRule,
    finite_guard,
)

_ROBUST_RULES = (
    FiniteMeanRule(),
    NormClipRule(),
    TrimmedMeanRule(0.2),
    GeoMedianRule(16),
)


@given(seed=st.integers(0, 2**31 - 1), k=st.integers(2, 10), d=st.integers(1, 8))
@settings(**SETTINGS)
def test_robust_rules_permutation_invariant(seed, k, d):
    """Client order is protocol noise: permuting (values, weights) jointly
    must not move any rule's estimate."""
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.1, 2.0, size=(k,)).astype(np.float32))
    perm = rng.permutation(k)
    for rule in (MeanRule(),) + _ROBUST_RULES:
        a = np.asarray(rule.estimate(v, w))
        b = np.asarray(rule.estimate(v[perm], w[perm]))
        assert np.allclose(a, b, atol=1e-4), rule.name


@given(seed=st.integers(0, 2**31 - 1), k=st.integers(2, 10), d=st.integers(1, 6))
@settings(**SETTINGS)
def test_robust_rules_degenerate_to_weighted_mean_without_outliers(seed, k, d):
    """With identical rows every estimator must return that row; with finite
    well-conditioned rows, beta=0 trimming and the finite-guard mean must
    equal the plain weighted mean."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.uniform(0.1, 2.0, size=(k,)).astype(np.float32))
    row = rng.normal(size=(d,)).astype(np.float32)
    same = jnp.asarray(np.tile(row, (k, 1)))
    for rule in (MeanRule(),) + _ROBUST_RULES:
        assert np.allclose(np.asarray(rule.estimate(same, w)), row, atol=1e-3), rule.name
    v = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    ref = np.einsum("k,kd->d", np.asarray(w), np.asarray(v)) / np.asarray(w).sum()
    assert np.allclose(np.asarray(TrimmedMeanRule(0.0).estimate(v, w)), ref, atol=1e-4)
    assert np.allclose(np.asarray(FiniteMeanRule().estimate(v, w)), ref, atol=1e-4)
    assert np.allclose(np.asarray(MeanRule().estimate(v, w)), ref, atol=1e-4)


@given(
    seed=st.integers(0, 2**31 - 1),
    k=st.integers(5, 12),
    d=st.integers(1, 6),
    magnitude=st.floats(10.0, 1e6),
)
@settings(**SETTINGS)
def test_trimmed_mean_breakdown_below_beta_fraction(seed, k, d, magnitude):
    """f adversarial rows of total weight < beta * W cannot push any
    coordinate of the trimmed mean outside the honest value range."""
    rng = np.random.default_rng(seed)
    beta = 0.4
    f = max(int(beta * k) - 1, 1)  # strictly below the trim mass
    honest = rng.uniform(-1.0, 1.0, size=(k - f, d)).astype(np.float32)
    attack = np.full((f, d), magnitude, np.float32) * rng.choice([-1.0, 1.0])
    v = jnp.asarray(np.concatenate([honest, attack]))
    w = jnp.ones((k,), jnp.float32)
    est = np.asarray(TrimmedMeanRule(beta).estimate(v, w))
    lo, hi = honest.min(axis=0), honest.max(axis=0)
    assert (est >= lo - 1e-4).all() and (est <= hi + 1e-4).all()


@given(seed=st.integers(0, 2**31 - 1), k=st.integers(5, 11), d=st.integers(1, 6))
@settings(**SETTINGS)
def test_geomedian_breakdown_below_half(seed, k, d):
    """f < K/2 arbitrarily-placed rows leave the geometric median within a
    bounded neighbourhood of the honest points (breakdown point 1/2)."""
    rng = np.random.default_rng(seed)
    f = (k - 1) // 2
    honest = rng.uniform(-1.0, 1.0, size=(k - f, d)).astype(np.float32)
    attack = np.full((f, d), 1e4, np.float32)
    v = jnp.asarray(np.concatenate([honest, attack]))
    est = np.asarray(GeoMedianRule(64).estimate(v, jnp.ones((k,), jnp.float32)))
    # within the honest bounding box inflated by its own diameter
    diam = float(np.linalg.norm(honest.max(axis=0) - honest.min(axis=0))) + 1.0
    assert np.linalg.norm(est - honest.mean(axis=0)) <= 2.0 * diam


@given(
    seed=st.integers(0, 2**31 - 1),
    k=st.integers(1, 8),
    d=st.integers(1, 6),
    data=st.data(),
)
@settings(**SETTINGS)
def test_finite_guard_rules_always_finite(seed, k, d, data):
    """Whatever mix of NaN/Inf rows arrives, every guarded rule's output is
    finite — even when every row is poisoned (zero mass -> zero estimate)."""
    rng = np.random.default_rng(seed)
    v = rng.normal(size=(k, d)).astype(np.float32)
    poison = data.draw(st.lists(st.booleans(), min_size=k, max_size=k))
    for i, bad in enumerate(poison):
        if bad:
            v[i, rng.integers(d)] = rng.choice([np.nan, np.inf, -np.inf])
    w = jnp.ones((k,), jnp.float32)
    gv, gw = finite_guard(jnp.asarray(v), w)
    assert np.isfinite(np.asarray(gv)).all()
    assert float(gw.sum()) == float(k - sum(poison))
    for rule in _ROBUST_RULES:
        s, m = rule.weighted_sum(jnp.asarray(v), w)
        assert np.isfinite(np.asarray(s)).all(), rule.name
        assert np.isfinite(float(m))


# ---- seed-fused counter-based PRNG -----------------------------------------


@given(seed=st.integers(0, 2**31 - 1), r0=st.integers(0, 37), c0=st.integers(0, 37))
@settings(max_examples=10, deadline=None)
def test_fused_draw_tile_index_independence(seed, r0, c0):
    """Counter-based draws are a pure function of (seed, row, col): any tile
    at offset (r0, c0) equals that region of the full matrix, so which tiles
    get computed — and in what order — cannot change a single entry."""
    from repro.kernels.prng import fused_omega, fused_omega_block

    full = np.asarray(fused_omega(seed, 64, 48))
    blk = np.asarray(fused_omega_block(seed, 16, 8, row0=r0, col0=c0))
    assert np.array_equal(blk, full[r0:r0 + 16, c0:c0 + 8])


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_fused_draw_keys_reproducible_and_distinct(seed):
    """Same (seed, ensemble_index) reproduces bitwise; a different ensemble
    index or seed is a statistically independent stream (never identical)."""
    from repro.kernels.prng import fused_omega

    a = np.asarray(fused_omega(seed, 32, 16))
    assert np.array_equal(a, np.asarray(fused_omega(seed, 32, 16)))
    assert not np.array_equal(a, np.asarray(fused_omega(seed, 32, 16, ensemble_index=1)))
    assert not np.array_equal(a, np.asarray(fused_omega((seed + 1) % 2**32, 32, 16)))
    assert np.isfinite(a).all()


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=5, deadline=None)
def test_fused_gram_layout_invariance(seed):
    """Cross-layout bitwise equality: the (t, t)-tiled and untiled fused
    programs visit identical (row, col) counters and accumulate in the same
    sample-block order, so tiled == untiled bit for bit at any seed."""
    import importlib

    rf = importlib.import_module("repro.core.rf_tca")
    p, n, nf = 5, 96, 64
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(p, n)), jnp.float32)
    ell = ell_vector(n // 2, n - n // 2)
    g_u, u_u = rf.fused_streaming_gram(x, ell, n_features=nf, seed=seed, tile=0)
    g_t, u_t = rf.fused_streaming_gram(x, ell, n_features=nf, seed=seed, tile=128)
    assert bool(jnp.array_equal(g_u, g_t)), float(jnp.abs(g_u - g_t).max())
    assert bool(jnp.array_equal(u_u, u_t)), float(jnp.abs(u_u - u_t).max())
