"""Substrate: optimizers, checkpointing, data pipelines, baselines plumbing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.data import TokenStream, make_domains, make_implicit_domains, train_test_split
from repro.optim import (
    adam,
    adamw,
    apply_updates,
    clip_by_global_norm,
    cosine_schedule,
    linear_schedule,
    sgd,
)


@pytest.mark.parametrize("make_opt", [lambda: sgd(0.05, momentum=0.9), lambda: adam(0.05),
                                      lambda: adamw(0.05, weight_decay=1e-4)],
                         ids=["sgd", "adam", "adamw"])
def test_optimizers_converge_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.ones(8) * 5.0}
    state = opt.init(params)
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum((p["w"] - 2.0) ** 2))(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert np.allclose(params["w"], 2.0, atol=0.05)


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 10.0}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert np.isclose(float(gn), 20.0)
    total = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert np.isclose(total, 1.0, rtol=1e-4)


def test_schedules():
    cos = cosine_schedule(1.0, warmup=10, total=100)
    assert float(cos(jnp.int32(5))) < 1.0  # warming up
    assert np.isclose(float(cos(jnp.int32(10))), 1.0, atol=0.05)
    assert float(cos(jnp.int32(100))) < 0.2
    lin = linear_schedule(1.0, total=100)
    assert np.isclose(float(lin(jnp.int32(50))), 0.5, atol=0.02)


def test_checkpoint_roundtrip_and_gc():
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32), "b": {"c": jnp.ones(())}}
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            save(d, tree, step=s, keep=2)
        assert latest_step(d) == 5
        files = [f for f in os.listdir(d) if f.endswith(".npz")]
        assert len(files) == 2  # gc kept 2
        out = restore(d, tree)
        assert np.allclose(out["a"], tree["a"]) and np.allclose(out["b"]["c"], 1.0)


def test_checkpoint_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        save(d, {"a": jnp.ones((2, 3))}, step=1)
        with pytest.raises(ValueError):
            restore(d, {"a": jnp.ones((3, 2))})


def test_token_stream_deterministic_and_sharded():
    a = next(TokenStream(50, 4, 16, seed=3))
    b = next(TokenStream(50, 4, 16, seed=3))
    assert (a["tokens"] == b["tokens"]).all()
    assert (a["tokens"][:, 1:] == a["labels"][:, :-1]).all()  # labels = shifted
    h0 = next(TokenStream(50, 4, 16, seed=3, shard=(0, 2)))
    h1 = next(TokenStream(50, 4, 16, seed=3, shard=(1, 2)))
    assert not (h0["tokens"] == h1["tokens"]).all()  # disjoint host shards


def test_domains_shapes_and_split():
    doms = make_domains(3, 100, dim=8, n_classes=4, seed=0)
    assert len(doms) == 3
    for d in doms:
        assert d.x.shape == (8, 100) and d.y.shape == (100,)
        assert set(np.unique(d.y)) <= set(range(4))
    tr, te = train_test_split(doms[0], 0.25, seed=0)
    assert tr.x.shape[1] == 75 and te.x.shape[1] == 25


def test_implicit_domains_are_similar():
    """Implicit heterogeneity splits one distribution: domain means are close
    compared to explicit heterogeneity."""
    imp = make_implicit_domains(3, 200, dim=8, seed=0)
    exp = make_domains(3, 200, dim=8, shift=1.0, seed=0)
    d_imp = np.linalg.norm(imp[0].x.mean(1) - imp[1].x.mean(1))
    d_exp = np.linalg.norm(exp[0].x.mean(1) - exp[1].x.mean(1))
    assert d_imp < d_exp
