"""Dry-run machinery: roofline parsing units + one real (small) AOT combo in a
subprocess with 512 forced host devices."""
import json
import os
import subprocess
import sys

import pytest

from repro.launch.roofline import Roofline, collective_bytes, model_flops


def test_collective_bytes_parsing():
    hlo = """
  %x = bf16[2048]{0} all-reduce(bf16[2048]{0} %p), replica_groups={}
  %y = f32[16,128]{1,0} all-gather(f32[16,8]{1,0} %q), dimensions={1}
  %z.1 = bf16[4,4]{1,0} reduce-scatter(bf16[16,4]{1,0} %r)
  %w = (f32[8]{0}, f32[8]{0}) all-to-all(f32[8]{0} %a, f32[8]{0} %b)
  %n = f32[9]{0} add(f32[9]{0} %c, f32[9]{0} %d)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 2048 * 2
    assert out["all-gather"] == 16 * 128 * 4
    assert out["reduce-scatter"] == 4 * 4 * 2
    assert out["all-to-all"] == 8 * 4 * 2


def test_roofline_terms():
    r = Roofline(197e12, 819e9, 50e9, {})
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 1.0) < 1e-9
    assert abs(r.collective_s - 1.0) < 1e-9
    assert r.dominant in ("compute", "memory", "collective")


def test_model_flops():
    assert model_flops(100, 10, "train") == 6000
    assert model_flops(100, 10, "decode") == 2000


@pytest.mark.slow
def test_dryrun_one_combo_subprocess(tmp_path):
    """Smallest real combo: proves mesh + AOT machinery works end to end."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", "smollm-135m", "--shape", "decode_32k",
            "--mesh", "single", "--out", str(tmp_path),
        ],
        capture_output=True, text=True, env=env, timeout=570,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.load(open(tmp_path / "smollm-135m_decode_32k_16x16.json"))
    assert rec["kind"] == "decode"
    assert rec["roofline"]["flops_per_chip"] > 0
    assert rec["roofline"]["coll_bytes_per_chip"] > 0  # sharded => collectives exist
