"""Vertical-FL RFF (paper §VI extension): block decomposition == centralized."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.rff import draw_omega, rff_features
from repro.core.rf_tca import solve_w_rf
from repro.core.kernels_math import ell_vector
from repro.federated.vertical import split_omega, vertical_rff


def test_vertical_rff_matches_centralized(rng):
    x = jnp.asarray(rng.normal(size=(20, 50)), jnp.float32)
    blocks = [x[:7], x[7:12], x[12:]]
    sig_v = vertical_rff(blocks, seed=3, n_features=64, sigma=1.5)
    omega = draw_omega(3, 64, 20, sigma=1.5)
    sig_c = rff_features(x, omega)
    np.testing.assert_allclose(np.asarray(sig_v), np.asarray(sig_c), atol=1e-5)


def test_split_omega_validates():
    om = jnp.ones((4, 10))
    with pytest.raises(ValueError):
        split_omega(om, [3, 3])
    parts = split_omega(om, [4, 6])
    assert parts[0].shape == (4, 4) and parts[1].shape == (4, 6)


def test_vertical_rf_tca_end_to_end(rng):
    """Full vertical pipeline: parties hold feature blocks, RF-TCA still runs."""
    xs = jnp.asarray(rng.normal(size=(16, 60)), jnp.float32)
    xt = jnp.asarray(rng.normal(size=(16, 40)) + 1.0, jnp.float32)
    x = jnp.concatenate([xs, xt], axis=1)
    sig = vertical_rff([x[:5], x[5:11], x[11:]], seed=0, n_features=64)
    w, vals = solve_w_rf(sig, ell_vector(60, 40), 1e-2, 4)
    assert w.shape == (128, 4)
    assert np.isfinite(np.asarray(vals)).all()
