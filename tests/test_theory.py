"""Empirical validation of Theorem 2 / Corollary 1 / Theorem 1 trends."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ell_vector
from repro.core.theory import (
    corollary1_error,
    kernel_approx_error,
    required_features,
    theorem1_feature_error,
)


@pytest.fixture(scope="module")
def x(rng):
    return jnp.asarray(rng.normal(size=(8, 80)), jnp.float32)


def test_theorem2_error_decays_with_n(x):
    errs = [np.mean([kernel_approx_error(x, n, 2.0, s) for s in range(3)]) for n in (32, 256, 2048)]
    assert errs[0] > errs[1] > errs[2]
    # Theorem 2 rate: eps ~ 1/sqrt(N) -> 8x N => ~2.8x error drop (allow slack)
    assert errs[0] / errs[2] > 3.0


def test_corollary1_error_decays(x):
    ell = ell_vector(50, 30)
    errs = [corollary1_error(x, ell, 1e-2, n, 2.0, 0) for n in (32, 512)]
    assert errs[1] < errs[0]


def test_theorem1_feature_error_decays(x):
    ell = ell_vector(50, 30)
    errs = [
        np.mean([theorem1_feature_error(x, ell, 1e-2, 2, n, 2.0, s) for s in range(3)])
        for n in (64, 4096)
    ]
    assert errs[1] < errs[0]


def test_required_features_scaling(x):
    n1 = required_features(x, 2.0, 0.5)
    n2 = required_features(x, 2.0, 0.25)
    assert np.isclose(n2 / n1, 4.0, rtol=1e-3)  # 1/eps^2 scaling
