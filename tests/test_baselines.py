"""DA baselines produce sane accuracies and expected orderings."""
import pytest

from repro.baselines import (
    coral_baseline,
    dann_mmd_baseline,
    jda_baseline,
    rf_tca_baseline,
    source_only,
    tca_baseline,
)
from repro.data import make_domains


@pytest.fixture(scope="module")
def suite():
    doms = make_domains(3, 250, shift=1.0, seed=5)
    return doms[:2], doms[2]


def test_source_only_runs(suite):
    s, t = suite
    acc = source_only(s, t, seed=0)
    assert 0.0 <= acc <= 1.0


def test_tca_variants_run(suite):
    s, t = suite
    for variant in ("vanilla", "r"):
        acc = tca_baseline(s, t, gamma=1e-3, variant=variant, m=16)
        assert 0.05 <= acc <= 1.0


def test_rf_tca_close_to_r_tca(suite):
    """Theorem 1 downstream: RF-TCA accuracy ~ R-TCA accuracy (same gamma)."""
    s, t = suite
    a_r = tca_baseline(s, t, gamma=1e-3, variant="r", m=16)
    a_rf = rf_tca_baseline(s, t, gamma=1e-3, n_features=1024, m=16)
    assert abs(a_r - a_rf) < 0.2, (a_r, a_rf)


def test_coral_jda_dann_run(suite):
    s, t = suite
    assert 0.0 <= coral_baseline(s, t) <= 1.0
    assert 0.0 <= jda_baseline(s, t, gamma=1e-3, iters=2) <= 1.0
    assert 0.0 <= dann_mmd_baseline(s, t, steps=150) <= 1.0


def test_adaptation_beats_chance(suite):
    s, t = suite
    acc = tca_baseline(s, t, gamma=1e-3, m=16)
    assert acc > 1.0 / 5 + 0.05  # better than 5-class chance
