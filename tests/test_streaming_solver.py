"""Streaming RF-TCA solver: scan/Pallas gram paths, SM whitening, eigh vs LOBPCG."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ell_vector,
    rf_tca_fit,
    solve_w_rf,
    solve_w_rf_cholesky,
    solve_w_rf_gram,
    streaming_gram,
)
from repro.core.rff import draw_omega, rff_features


@pytest.fixture(scope="module")
def data(rng):
    p, ns, nt = 8, 90, 70
    xs = jnp.asarray(rng.normal(size=(p, ns)), jnp.float32)
    xt = jnp.asarray(rng.normal(size=(p, nt)) + 1.0, jnp.float32)
    return xs, xt


def test_streaming_gram_matches_dense(data):
    """G_H and u from the blocked scan equal the materializing reference."""
    xs, xt = data
    x = jnp.concatenate([xs, xt], axis=1)
    ell = ell_vector(xs.shape[1], xt.shape[1])
    omega = draw_omega(0, 48, x.shape[0])
    g_h, u = streaming_gram(x, ell, omega, block=37)  # non-divisor block
    sig = rff_features(x, omega)
    mu = jnp.mean(sig, axis=1, keepdims=True)
    sc = sig - mu
    g_ref = sc @ sc.T
    np.testing.assert_allclose(np.asarray(g_h), np.asarray(g_ref), atol=3e-5)
    np.testing.assert_allclose(np.asarray(u), np.asarray(sig @ ell), atol=3e-5)


def test_sherman_morrison_solver_matches_cholesky(data):
    """SM-whitened eigh reproduces the Cholesky reference eigenpairs."""
    xs, xt = data
    x = jnp.concatenate([xs, xt], axis=1)
    ell = ell_vector(xs.shape[1], xt.shape[1])
    omega = draw_omega(0, 64, x.shape[0])
    sig = rff_features(x, omega)
    w_ref, v_ref = solve_w_rf_cholesky(sig, ell, 1e-2, 6)
    w_sm, v_sm = solve_w_rf(sig, ell, 1e-2, 6, solver="eigh")
    np.testing.assert_allclose(np.asarray(v_sm), np.asarray(v_ref), rtol=1e-4)
    # both W are B-orthonormal bases of the same eigenspace: compare subspaces
    qa = np.linalg.qr(np.asarray(w_ref))[0]
    qb = np.linalg.qr(np.asarray(w_sm))[0]
    cosines = np.linalg.svd(qa.T @ qb, compute_uv=False)
    assert cosines.min() > 1 - 1e-4


def test_lobpcg_matches_eigh(data):
    """Acceptance: LOBPCG top-m agrees with eigh within 1e-4 rel tolerance."""
    xs, xt = data
    x = jnp.concatenate([xs, xt], axis=1)
    ell = ell_vector(xs.shape[1], xt.shape[1])
    omega = draw_omega(0, 64, x.shape[0])  # 2N = 128
    g_h, u = streaming_gram(x, ell, omega)
    w_e, v_e = solve_w_rf_gram(g_h, u, 1e-2, 8, solver="eigh")
    w_l, v_l = solve_w_rf_gram(g_h, u, 1e-2, 8, solver="lobpcg")
    np.testing.assert_allclose(np.asarray(v_l), np.asarray(v_e), rtol=1e-4)
    qa = np.linalg.qr(np.asarray(w_e))[0]
    qb = np.linalg.qr(np.asarray(w_l))[0]
    cosines = np.linalg.svd(qa.T @ qb, compute_uv=False)
    assert cosines.min() > 1 - 1e-3


@pytest.mark.parametrize("m", [7, 8, 12])  # 5m >= 2N=32 for all of these
def test_lobpcg_small_problem_falls_back(data, m):
    """5m >= 2N degenerates LOBPCG (jax rejects it); must fall back to eigh."""
    xs, xt = data
    x = jnp.concatenate([xs, xt], axis=1)
    ell = ell_vector(xs.shape[1], xt.shape[1])
    omega = draw_omega(0, 16, x.shape[0])  # 2N = 32
    g_h, u = streaming_gram(x, ell, omega)
    w, v = solve_w_rf_gram(g_h, u, 1e-2, m, solver="lobpcg")
    w_e, v_e = solve_w_rf_gram(g_h, u, 1e-2, m, solver="eigh")
    np.testing.assert_allclose(np.asarray(v), np.asarray(v_e), rtol=1e-5)


def test_stream_cholesky_rejected_early(data):
    """cholesky needs the explicit Sigma — stream mode must refuse up front."""
    xs, xt = data
    with pytest.raises(ValueError, match="cholesky"):
        rf_tca_fit(xs, xt, n_features=32, m=4, mode="stream", solver="cholesky")


def test_fit_modes_agree(data):
    """rf_tca_fit stream (xla + pallas) and dense (cholesky) eigenvalues agree."""
    xs, xt = data
    kw = dict(n_features=64, m=8, gamma=1e-2, sigma=2.0, seed=0)
    v_dense = rf_tca_fit(xs, xt, mode="dense", solver="cholesky", **kw).eigvals
    v_stream = rf_tca_fit(xs, xt, mode="stream", **kw).eigvals
    v_pallas = rf_tca_fit(xs, xt, mode="stream", use_pallas=True, **kw).eigvals
    np.testing.assert_allclose(np.asarray(v_stream), np.asarray(v_dense), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(v_pallas), np.asarray(v_dense), rtol=1e-3)


def test_streaming_never_materializes_sigma(data):
    """The streamed stats pass must not allocate a (2N, n) buffer.

    Checked structurally: every intermediate in the jaxpr of the scan body is
    bounded by max(block * 2N_block_rows, (2N)^2) — a (2N, n) Sigma would
    exceed it.
    """
    from repro.core.rf_tca import _gram_stream_xla

    xs, xt = data
    x = jnp.concatenate([xs, xt], axis=1)
    n = x.shape[1]
    ell = ell_vector(xs.shape[1], xt.shape[1])
    omega = draw_omega(0, 64, x.shape[0])
    two_n, block = 128, 32
    jaxpr = jax.make_jaxpr(lambda a, e, o: _gram_stream_xla(a, e, o, block=block))(
        x, ell, omega
    )
    limit = max(two_n * two_n, two_n * block, x.size)  # stats, slab, input copies

    def walk(jx):
        for eqn in jx.eqns:
            for v in eqn.outvars:
                size = int(np.prod(v.aval.shape)) if v.aval.shape else 1
                assert size <= limit, f"intermediate {v.aval.shape} exceeds streaming bound"
        for sub in jax.core.subjaxprs(jx):
            walk(sub)

    walk(jaxpr.jaxpr)
    assert two_n * n > limit  # the bound would catch a materialized Sigma
