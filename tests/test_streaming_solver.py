"""Streaming RF-TCA solver: scan/Pallas gram paths, SM whitening, eigh vs LOBPCG."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ell_vector,
    rf_tca_fit,
    rf_tca_transform,
    solve_w_rf,
    solve_w_rf_cholesky,
    solve_w_rf_gram,
    streaming_gram,
)
from repro.core.rff import draw_omega, rff_features


@pytest.fixture(scope="module")
def data(rng):
    p, ns, nt = 8, 90, 70
    xs = jnp.asarray(rng.normal(size=(p, ns)), jnp.float32)
    xt = jnp.asarray(rng.normal(size=(p, nt)) + 1.0, jnp.float32)
    return xs, xt


def test_streaming_gram_matches_dense(data):
    """G_H and u from the blocked scan equal the materializing reference."""
    xs, xt = data
    x = jnp.concatenate([xs, xt], axis=1)
    ell = ell_vector(xs.shape[1], xt.shape[1])
    omega = draw_omega(0, 48, x.shape[0])
    g_h, u = streaming_gram(x, ell, omega, block=37)  # non-divisor block
    sig = rff_features(x, omega)
    mu = jnp.mean(sig, axis=1, keepdims=True)
    sc = sig - mu
    g_ref = sc @ sc.T
    np.testing.assert_allclose(np.asarray(g_h), np.asarray(g_ref), atol=3e-5)
    np.testing.assert_allclose(np.asarray(u), np.asarray(sig @ ell), atol=3e-5)


def test_sherman_morrison_solver_matches_cholesky(data):
    """SM-whitened eigh reproduces the Cholesky reference eigenpairs."""
    xs, xt = data
    x = jnp.concatenate([xs, xt], axis=1)
    ell = ell_vector(xs.shape[1], xt.shape[1])
    omega = draw_omega(0, 64, x.shape[0])
    sig = rff_features(x, omega)
    w_ref, v_ref = solve_w_rf_cholesky(sig, ell, 1e-2, 6)
    w_sm, v_sm = solve_w_rf(sig, ell, 1e-2, 6, solver="eigh")
    np.testing.assert_allclose(np.asarray(v_sm), np.asarray(v_ref), rtol=1e-4)
    # both W are B-orthonormal bases of the same eigenspace: compare subspaces
    qa = np.linalg.qr(np.asarray(w_ref))[0]
    qb = np.linalg.qr(np.asarray(w_sm))[0]
    cosines = np.linalg.svd(qa.T @ qb, compute_uv=False)
    assert cosines.min() > 1 - 1e-4


def test_lobpcg_matches_eigh(data):
    """Acceptance: LOBPCG top-m agrees with eigh within 1e-4 rel tolerance."""
    xs, xt = data
    x = jnp.concatenate([xs, xt], axis=1)
    ell = ell_vector(xs.shape[1], xt.shape[1])
    omega = draw_omega(0, 64, x.shape[0])  # 2N = 128
    g_h, u = streaming_gram(x, ell, omega)
    w_e, v_e = solve_w_rf_gram(g_h, u, 1e-2, 8, solver="eigh")
    w_l, v_l = solve_w_rf_gram(g_h, u, 1e-2, 8, solver="lobpcg")
    np.testing.assert_allclose(np.asarray(v_l), np.asarray(v_e), rtol=1e-4)
    qa = np.linalg.qr(np.asarray(w_e))[0]
    qb = np.linalg.qr(np.asarray(w_l))[0]
    cosines = np.linalg.svd(qa.T @ qb, compute_uv=False)
    assert cosines.min() > 1 - 1e-3


@pytest.mark.parametrize("m", [7, 8, 12])  # 5m >= 2N=32 for all of these
def test_lobpcg_small_problem_falls_back(data, m):
    """5m >= 2N degenerates LOBPCG (jax rejects it); must fall back to eigh."""
    xs, xt = data
    x = jnp.concatenate([xs, xt], axis=1)
    ell = ell_vector(xs.shape[1], xt.shape[1])
    omega = draw_omega(0, 16, x.shape[0])  # 2N = 32
    g_h, u = streaming_gram(x, ell, omega)
    w, v = solve_w_rf_gram(g_h, u, 1e-2, m, solver="lobpcg")
    w_e, v_e = solve_w_rf_gram(g_h, u, 1e-2, m, solver="eigh")
    np.testing.assert_allclose(np.asarray(v), np.asarray(v_e), rtol=1e-5)


def test_stream_cholesky_rejected_early(data):
    """cholesky needs the explicit Sigma — stream mode must refuse up front."""
    xs, xt = data
    with pytest.raises(ValueError, match="cholesky"):
        rf_tca_fit(xs, xt, n_features=32, m=4, mode="stream", solver="cholesky")


def test_fit_modes_agree(data):
    """rf_tca_fit stream (xla + pallas) and dense (cholesky) eigenvalues agree."""
    xs, xt = data
    kw = dict(n_features=64, m=8, gamma=1e-2, sigma=2.0, seed=0)
    v_dense = rf_tca_fit(xs, xt, mode="dense", solver="cholesky", **kw).eigvals
    v_stream = rf_tca_fit(xs, xt, mode="stream", **kw).eigvals
    v_pallas = rf_tca_fit(xs, xt, mode="stream", use_pallas=True, **kw).eigvals
    np.testing.assert_allclose(np.asarray(v_stream), np.asarray(v_dense), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(v_pallas), np.asarray(v_dense), rtol=1e-3)


def test_tiled_twin_matches_untiled_and_pallas(data):
    """Tiled XLA twin == untiled scan == tiled Pallas kernel, non-tile N."""
    from repro.kernels import ops as kops

    xs, xt = data
    x = jnp.concatenate([xs, xt], axis=1)
    ell = ell_vector(xs.shape[1], xt.shape[1])
    omega = draw_omega(0, 200, x.shape[0])  # N=200: pads to 256 under tile=128
    g_u, u_u = streaming_gram(x, ell, omega, block=37)
    g_t, u_t = streaming_gram(x, ell, omega, block=37, tile=128)
    g_p, u_p = kops.rff_gram_stream(x, omega, ell, block=64, tile=128)
    scale = float(jnp.abs(g_u).max())
    np.testing.assert_allclose(np.asarray(g_t) / scale, np.asarray(g_u) / scale, atol=2e-6)
    np.testing.assert_allclose(np.asarray(u_t), np.asarray(u_u), atol=2e-6)
    np.testing.assert_allclose(np.asarray(g_p) / scale, np.asarray(g_t) / scale, atol=2e-6)
    np.testing.assert_allclose(np.asarray(u_p), np.asarray(u_t), atol=2e-6)


def test_tiled_kernel_matches_twin_at_n4096():
    """Acceptance: the tiled Pallas kernel agrees with the tiled XLA twin to
    <= 1e-4 relative at N = 4096 (auto tile selection on the kernel path)."""
    from repro.kernels import ops as kops

    p, n, nf = 16, 256, 4096
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (p, n), jnp.float32)
    omega = jax.random.normal(jax.random.fold_in(key, 2), (nf, p), jnp.float32)
    ell = ell_vector(n // 2, n - n // 2)
    assert kops.gram_tile_plan(nf)["tile"] == 512  # auto-tiled past the ceiling
    g_p, u_p = kops.rff_gram_stream(x, omega, ell)  # tile=None -> auto
    g_t, u_t = streaming_gram(x, ell, omega, block=128, tile=512)
    scale = float(jnp.abs(g_t).max())
    assert float(jnp.abs(g_p - g_t).max()) / scale <= 1e-4
    assert float(jnp.abs(u_p - u_t).max()) <= 1e-4 * max(1.0, float(jnp.abs(u_t).max()))


def test_tiled_twin_per_pair_memory_bounded_by_tile():
    """Jaxpr proxy: one (i, j) tile pair of the tiled layout only ever holds
    (tile, tile) accumulators and (tile, block) slabs — an (N, block) slab or
    (N, N) accumulator (the untiled layout) would blow the bound."""
    from repro.core.rf_tca import _tile_pair_stats

    p, n, nf, tile, block = 8, 128, 2048, 128, 64
    key = jax.random.PRNGKey(0)
    om_i = jax.random.normal(key, (tile, p), jnp.float32)
    om_j = jax.random.normal(jax.random.fold_in(key, 1), (tile, p), jnp.float32)
    xb = jax.random.normal(jax.random.fold_in(key, 2), (n // block, block, p), jnp.float32)
    mb = jnp.ones((n // block, block), jnp.float32)
    jaxpr = jax.make_jaxpr(_tile_pair_stats)(om_i, om_j, xb, mb)
    limit = max(3 * tile * tile, xb.size)  # stacked accumulators, input copies

    def walk(jx):
        for eqn in jx.eqns:
            for v in eqn.outvars:
                size = int(np.prod(v.aval.shape)) if v.aval.shape else 1
                assert size <= limit, f"intermediate {v.aval.shape} exceeds tile bound"
        for sub in jax.core.subjaxprs(jx):
            walk(sub)

    walk(jaxpr.jaxpr)
    assert nf * block > limit and nf * nf > limit  # the bound has teeth vs untiled


def test_streaming_never_materializes_sigma(data):
    """The streamed stats pass must not allocate a (2N, n) buffer.

    Checked structurally: every intermediate in the jaxpr of the scan body is
    bounded by max(block * 2N_block_rows, (2N)^2) — a (2N, n) Sigma would
    exceed it.
    """
    from repro.core.rf_tca import _gram_stream_xla

    xs, xt = data
    x = jnp.concatenate([xs, xt], axis=1)
    n = x.shape[1]
    ell = ell_vector(xs.shape[1], xt.shape[1])
    omega = draw_omega(0, 64, x.shape[0])
    two_n, block = 128, 32
    jaxpr = jax.make_jaxpr(lambda a, e, o: _gram_stream_xla(a, e, o, block=block))(
        x, ell, omega
    )
    limit = max(two_n * two_n, two_n * block, x.size)  # stats, slab, input copies

    def walk(jx):
        for eqn in jx.eqns:
            for v in eqn.outvars:
                size = int(np.prod(v.aval.shape)) if v.aval.shape else 1
                assert size <= limit, f"intermediate {v.aval.shape} exceeds streaming bound"
        for sub in jax.core.subjaxprs(jx):
            walk(sub)

    walk(jaxpr.jaxpr)
    assert two_n * n > limit  # the bound would catch a materialized Sigma


# ---- seed-fused fit path (w_rf="fused:<seed>") -----------------------------


def test_fused_fit_state_and_transform(data):
    """w_rf="fused:<seed>": the state carries no omega tensor — only the
    (seed, ensemble, sigma, kernel) spec — and out-of-sample transform
    re-derives draw 0 from the counter stream on demand."""
    from repro.kernels.prng import fused_omega

    xs, xt = data
    st = rf_tca_fit(xs, xt, n_features=48, m=6, gamma=1e-2, w_rf="fused:7")
    assert st.omega is None
    assert st.fused == (7, 1, 1.0, "gauss")
    f = rf_tca_transform(st, xs)
    assert f.shape == (6, xs.shape[1]) and bool(jnp.isfinite(f).all())
    om = fused_omega(7, 48, xs.shape[0])
    exp = st.w_rf.T @ rff_features(xs, om)
    np.testing.assert_allclose(np.asarray(f), np.asarray(exp), rtol=1e-5, atol=1e-6)


def test_fused_fit_pallas_twin_agree(data):
    """The fused fit through the Pallas kernel and through the XLA twin see
    bit-identical (G_H, u), so the deterministic eigensolve agrees exactly."""
    xs, xt = data
    kw = dict(n_features=48, m=6, gamma=1e-2, w_rf="fused:3")
    v_p = rf_tca_fit(xs, xt, use_pallas=True, **kw).eigvals
    v_x = rf_tca_fit(xs, xt, use_pallas=False, **kw).eigvals
    np.testing.assert_array_equal(np.asarray(v_p), np.asarray(v_x))


def test_fused_ensemble_fit_and_transform(data):
    """ensemble=S fit runs end to end; the spec round-trips into the state
    and the ensemble-averaged projector still transforms unseen data."""
    xs, xt = data
    st = rf_tca_fit(xs, xt, n_features=32, m=4, gamma=1e-2, w_rf="fused:1", ensemble=4)
    assert st.fused == (1, 4, 1.0, "gauss")
    assert bool(jnp.isfinite(st.eigvals).all())
    f_t = rf_tca_transform(st, xt)
    assert f_t.shape == (4, xt.shape[1]) and bool(jnp.isfinite(f_t).all())


def test_fused_fit_validation(data):
    """The lever's misuse modes fail fast with actionable messages."""
    xs, xt = data
    kw = dict(n_features=16, m=2)
    with pytest.raises(ValueError, match="ensemble"):
        rf_tca_fit(xs, xt, ensemble=2, **kw)
    with pytest.raises(ValueError, match='mode="stream"'):
        rf_tca_fit(xs, xt, w_rf="fused:0", mode="dense", **kw)
    with pytest.raises(ValueError, match="fused"):
        rf_tca_fit(xs, xt, w_rf="not-a-spec", **kw)
