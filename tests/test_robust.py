"""Robustness layer: aggregation rules, fault injection, wire integrity,
checkpoint/restore, and fedsim crash-restart semantics."""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.netsim import (
    BernoulliScenario,
    CorruptionScenario,
    LinkModel,
    LinkScenario,
    TraceScenario,
)
from repro.comm import wire
from repro.comm.codecs import get_codec
from repro.comm.transport import WireTransport, resolve_codecs
from repro.comm.wire import WireDecodeError
from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig
from repro.federated.aggregation import get_rule as get_rule_via_aggregation
from repro.federated.network import RoundPlan
from repro.fedsim import AsyncConfig, AsyncScheduler
from repro.fleet import Topology
from repro.robust import (
    ByteFaultInjector,
    FaultConfig,
    FiniteMeanRule,
    GeoMedianRule,
    MeanRule,
    NormClipRule,
    TrimmedMeanRule,
    build_fault_plan,
    finite_guard,
    get_rule,
    make_corruptor,
    rule_names,
)
from repro.data import make_domains


@pytest.fixture(scope="module")
def small_setup():
    doms = make_domains(4, 120, shift=0.5, seed=1, dim=8, n_classes=3)
    cfg = ClientConfig(input_dim=8, n_classes=3, n_rff=32, m=8, extractor_widths=(16, 8))
    return doms[:3], doms[3], cfg


def _proto(rounds=3, **kw):
    kw.setdefault("t_c", 2)
    kw.setdefault("warmup_rounds", 1)
    kw.setdefault("batch_size", 32)
    kw.setdefault("seed", 0)
    ids = list(range(3))
    kw.setdefault(
        "scenario", TraceScenario([RoundPlan(ids, ids, ids)] * max(rounds, 1), cycle=True)
    )
    return ProtocolConfig(n_rounds=rounds, **kw)


def _leaf_div(a, b):
    return max(
        float(np.abs(np.asarray(x) - np.asarray(y)).max())
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


def _all_finite(tree):
    return all(np.isfinite(np.asarray(l)).all() for l in jax.tree_util.tree_leaves(tree))


# ---- rules -----------------------------------------------------------------


def test_get_rule_parsing_and_reexport():
    assert isinstance(get_rule("mean"), MeanRule)
    assert get_rule("mean").is_mean and not get_rule("finite_mean").is_mean
    assert isinstance(get_rule("trimmed_mean:0.3"), TrimmedMeanRule)
    assert get_rule("trimmed_mean:0.3").beta == 0.3
    assert get_rule("norm_clip:2.5").clip == 2.5
    assert get_rule("geomedian:4").iters == 4
    rule = TrimmedMeanRule(0.1)
    assert get_rule(rule) is rule  # instances pass through
    with pytest.raises(ValueError, match="unknown aggregation rule"):
        get_rule("krum")
    with pytest.raises(ValueError, match="trim fraction"):
        TrimmedMeanRule(0.5)
    assert set(rule_names()) == {"mean", "finite_mean", "norm_clip", "trimmed_mean",
                                 "geomedian"}
    # federated.aggregation re-exports the seam
    assert get_rule_via_aggregation is get_rule


def test_mean_rule_is_bitwise_the_seed_contractions():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(5,)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(5, 7, 3)).astype(np.float32))
    s, m = jax.jit(MeanRule().weighted_sum)(v, w)
    ref = jax.jit(lambda w, v: jnp.einsum("k,kij->ij", w, v))(w, v)
    ref2 = jax.jit(lambda w, v: jnp.tensordot(w, v, axes=1))(w, v)
    assert np.array_equal(np.asarray(s), np.asarray(ref))
    assert np.array_equal(np.asarray(s), np.asarray(ref2))
    assert float(m) == float(np.sum(np.asarray(w)))


def test_finite_guard_quarantines_rows():
    v = jnp.asarray([[1.0, 2.0], [np.nan, 0.0], [3.0, np.inf], [4.0, 5.0]])
    w = jnp.ones((4,))
    gv, gw = finite_guard(v, w)
    assert np.array_equal(np.asarray(gw), [1.0, 0.0, 0.0, 1.0])
    assert np.isfinite(np.asarray(gv)).all()
    # the mass really drops: a NaN row cannot vote through the guard
    s, m = FiniteMeanRule().weighted_sum(v, w)
    assert np.allclose(np.asarray(s), [5.0, 7.0]) and float(m) == 2.0


def test_trimmed_mean_known_values_and_beta0_degeneracy():
    v = jnp.asarray([[0.0], [1.0], [2.0], [3.0], [1000.0]])
    w = jnp.ones((5,))
    est = TrimmedMeanRule(0.2).estimate(v, w)
    assert float(est[0]) == pytest.approx(2.0)  # tails 0 and 1000 trimmed
    # beta=0 recovers the weighted mean exactly
    rng = np.random.default_rng(1)
    v = jnp.asarray(rng.normal(size=(6, 4)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.5, 2.0, size=(6,)).astype(np.float32))
    est0 = TrimmedMeanRule(0.0).estimate(v, w)
    ref = np.einsum("k,kd->d", np.asarray(w), np.asarray(v)) / np.asarray(w).sum()
    assert np.allclose(np.asarray(est0), ref, atol=1e-5)
    # weight-0 rows occupy no quantile mass
    v = jnp.asarray([[0.0], [1.0], [2.0], [1e9]])
    w = jnp.asarray([1.0, 1.0, 1.0, 0.0])
    est = TrimmedMeanRule(0.25).estimate(v, w)
    assert float(est[0]) == pytest.approx(1.0)


def test_norm_clip_bounds_the_outlier_pull():
    honest = np.tile(np.array([1.0, 0.0], np.float32), (4, 1))
    attack = np.array([[1e6, 1e6]], np.float32)
    v = jnp.asarray(np.concatenate([honest, attack]))
    w = jnp.ones((5,))
    est = NormClipRule().estimate(v, w)  # median-norm radius == 1
    assert float(jnp.linalg.norm(est)) <= 1.0 + 1e-5
    est_fixed = NormClipRule(2.0).estimate(v, w)
    assert np.isfinite(np.asarray(est_fixed)).all()
    assert float(jnp.linalg.norm(est_fixed)) <= 2.0 + 1e-5


def test_geomedian_resists_large_outlier():
    honest = np.random.default_rng(2).normal(size=(6, 8)).astype(np.float32)
    v = jnp.asarray(np.concatenate([honest, np.full((1, 8), 1e8, np.float32)]))
    w = jnp.ones((7,))
    est = np.asarray(GeoMedianRule(16).estimate(v, w))
    lo, hi = honest.min(axis=0), honest.max(axis=0)
    assert (est >= lo - 1.0).all() and (est <= hi + 1.0).all()


# ---- value-level corruptors ------------------------------------------------


def test_corruptors_fire_at_rate_one_and_never_at_zero():
    x = jnp.asarray(np.random.default_rng(3).normal(size=(10,)).astype(np.float32))
    key = jax.random.PRNGKey(0)
    for mode in ("bit_flip", "scale", "sign_flip", "nan", "truncate"):
        out0 = make_corruptor(mode, 0.0, 100.0)(x, key)
        assert np.array_equal(np.asarray(out0), np.asarray(x))  # gate closed
    nan_out = np.asarray(make_corruptor("nan", 1.0, 100.0)(x, key))
    assert np.isnan(nan_out).sum() == 1
    flip_out = np.asarray(make_corruptor("sign_flip", 1.0, 100.0)(x, key))
    assert np.array_equal(flip_out, -np.asarray(x))
    scale_out = np.asarray(make_corruptor("scale", 1.0, 100.0)(x, key))
    assert np.allclose(scale_out, 100.0 * np.asarray(x))
    bit_out = np.asarray(make_corruptor("bit_flip", 1.0, 100.0)(x, key))
    assert (bit_out != np.asarray(x)).sum() == 1  # exactly one element flipped
    trunc_out = np.asarray(make_corruptor("truncate", 1.0, 100.0)(x, key))
    nz = np.nonzero(trunc_out == 0.0)[0]
    assert nz.size >= 1 and np.array_equal(nz, np.arange(10 - nz.size, 10))


def test_fault_config_validation_and_noop():
    assert FaultConfig().is_noop
    assert build_fault_plan(FaultConfig(), k=3) is None  # bitwise-transparent
    assert build_fault_plan(None, k=3) is None
    with pytest.raises(ValueError, match="corruption mode"):
        FaultConfig(corruption="gamma_ray")
    with pytest.raises(ValueError, match="byzantine mode"):
        FaultConfig(byzantine_mode="subtle")
    with pytest.raises(ValueError, match="in \\[0, 1\\]"):
        FaultConfig(corrupt_moments=1.5)
    with pytest.raises(ValueError, match="out of range"):
        build_fault_plan(FaultConfig(byzantine=(7,)), k=3)
    plan = build_fault_plan(FaultConfig(byzantine=(1,), byzantine_mode="sign_flip"), k=3)
    rows = jnp.ones((3, 4))
    out = np.asarray(plan.apply("moments", rows, jax.random.PRNGKey(0)))
    assert np.array_equal(out[1], -np.ones(4)) and np.array_equal(out[0], np.ones(4))


# ---- wire integrity (CRC32) ------------------------------------------------


def test_wire_checksum_rejects_every_single_byte_corruption():
    codec = get_codec("float32")
    vec = np.arange(6, dtype=np.float32)
    frame = wire.serialize(wire.moments_message(vec, sender=1, round=2), codec)
    spec = {"msg": ((6,), np.dtype(np.float32))}
    assert len(frame) == wire.serialized_size("moments", spec, codec)
    decoded, _ = wire.deserialize(frame)
    assert np.array_equal(decoded.arrays["msg"], vec)
    for i in range(len(frame)):
        for bit in (0x01, 0x80):
            bad = bytearray(frame)
            bad[i] ^= bit
            with pytest.raises(WireDecodeError):
                wire.deserialize(bytes(bad))
    for cut in (0, 1, len(frame) // 2, len(frame) - 1):
        with pytest.raises(WireDecodeError):
            wire.deserialize(frame[:cut])
    assert issubclass(WireDecodeError, ValueError)  # legacy handlers still catch


def test_transport_rejects_retransmits_and_gives_up():
    vec = np.arange(4, dtype=np.float32)
    # hopeless channel: every frame corrupted -> retries exhausted -> drop
    t = WireTransport(
        resolve_codecs("float32"),
        fault_injector=ByteFaultInjector(rates={"moments": 1.0}, max_retries=3, seed=0),
    )
    assert t.transfer(wire.moments_message(vec, sender=0, round=1)) is None
    assert t.log.drops_by_kind["moments"] == 1
    assert t.log.rejects_by_kind["moments"] == 4  # 1 try + 3 retries
    assert t.log.messages_by_kind["moments"] == 4  # every attempt cost real bytes
    # half-corrupted channel: rejected frames retransmit and then deliver
    t2 = WireTransport(
        resolve_codecs("float32"),
        fault_injector=ByteFaultInjector(rates={"moments": 0.5}, mode="garbage", seed=1),
    )
    for r in range(40):
        out = t2.transfer(wire.moments_message(vec, sender=0, round=r))
        assert out is not None and np.array_equal(out["msg"], vec)
    assert t2.log.rejects_total > 0 and t2.log.drops_total == 0
    assert t2.log.messages_by_kind["moments"] > 40


def test_serial_wire_trainer_survives_frame_corruption(small_setup):
    sources, target, cfg = small_setup
    faults = FaultConfig(corrupt_moments=0.3, corrupt_w_rf=0.3, corrupt_classifier=0.3)
    tr = FedRFTCATrainer(
        sources, target, cfg,
        _proto(rounds=3, engine="serial", transport="wire", faults=faults),
    )
    tr.train()
    assert tr.comm.rejects_total > 0  # corruption really happened
    assert _all_finite(tr.tgt_params)  # ...and never reached the aggregate
    assert 0.0 <= tr.evaluate() <= 1.0


def test_serial_engine_rejects_robust_rules(small_setup):
    sources, target, cfg = small_setup
    with pytest.raises(ValueError, match="batched engine"):
        FedRFTCATrainer(
            sources, target, cfg, _proto(rounds=2, engine="serial", rule="trimmed_mean")
        )


# ---- batched engine: degeneracy + quarantine + Byzantine -------------------


def test_rule_mean_plus_noop_faults_is_bitwise_degenerate(small_setup):
    sources, target, cfg = small_setup
    tr_ref = FedRFTCATrainer(sources, target, cfg, _proto(rounds=3))
    tr_ref.train()
    tr = FedRFTCATrainer(
        sources, target, cfg, _proto(rounds=3, rule="mean", faults=FaultConfig())
    )
    tr.train()
    assert _leaf_div(tr_ref.tgt_params, tr.tgt_params) == 0.0
    assert _leaf_div(tr_ref._src_stack, tr._src_stack) == 0.0


def test_nan_corruption_poisons_mean_but_not_robust_rules(small_setup):
    sources, target, cfg = small_setup
    faults = FaultConfig(corrupt_moments=0.5, corrupt_w_rf=0.5, corruption="nan")
    tr_mean = FedRFTCATrainer(
        sources, target, cfg, _proto(rounds=3, rule="mean", faults=faults)
    )
    tr_mean.train()
    assert not _all_finite(tr_mean.tgt_params)  # the fragility, demonstrated
    for rule in ("finite_mean", "trimmed_mean", "geomedian", "norm_clip"):
        tr = FedRFTCATrainer(
            sources, target, cfg, _proto(rounds=3, rule=rule, faults=faults)
        )
        tr.train()
        assert _all_finite(tr.tgt_params), rule
        assert _all_finite(tr._src_stack), rule


def test_byzantine_clients_held_by_robust_rules(small_setup):
    sources, target, cfg = small_setup
    faults = FaultConfig(byzantine=(0,), byzantine_mode="scale", byzantine_scale=100.0)
    tr = FedRFTCATrainer(
        sources, target, cfg, _proto(rounds=3, rule="trimmed_mean", faults=faults)
    )
    tr.train()
    assert _all_finite(tr.tgt_params)
    assert 0.0 <= tr.evaluate() <= 1.0


# ---- netsim: bounded retransmits + corruption-as-erasure -------------------


def test_uplink_gives_up_after_retry_budget():
    dead = LinkScenario(
        [LinkModel(drop=1.0)], retry_s=1.0, max_retries=3, retry_jitter=0.0
    )
    rng = np.random.default_rng(0)
    delivered, elapsed = dead.uplink_outcome(rng, 0, 1000)
    assert not delivered and elapsed == pytest.approx(1.0 + 2.0 + 4.0)
    assert dead.uplink_time(np.random.default_rng(0), 0, 1000) == math.inf


def test_corruption_scenario_zero_rates_is_rng_transparent():
    base = BernoulliScenario(p_msg=0.2, p_w=0.2, p_c=0.2)
    wrapped = CorruptionScenario(base=BernoulliScenario(p_msg=0.2, p_w=0.2, p_c=0.2))
    for t in range(5):
        a = base.plan(np.random.default_rng(t), 6, t)
        b = wrapped.plan(np.random.default_rng(t), 6, t)
        assert (a.msg_clients, a.w_clients, a.c_clients) == (
            b.msg_clients, b.w_clients, b.c_clients,
        )


def test_corruption_scenario_certain_corruption_erases_kind():
    sc = CorruptionScenario(
        base=TraceScenario([RoundPlan([0, 1, 2], [0, 1, 2], [0, 1, 2])], cycle=True),
        rates={"w_rf": 1.0},
    )
    plan = sc.plan(np.random.default_rng(0), 3, 1)
    assert plan.msg_clients == [0, 1, 2]
    assert plan.w_clients == [] and plan.c_clients == []  # nesting C subset B


# ---- checkpointing ---------------------------------------------------------


def test_trainer_checkpoint_bitwise_save_restore_continue(small_setup, tmp_path):
    sources, target, cfg = small_setup
    ids = list(range(3))
    plan = RoundPlan(ids, ids, ids)

    tr = FedRFTCATrainer(sources, target, cfg, _proto(rounds=0))
    for t in range(1, 3):
        tr.run_round(t, plan)
    tr.save_state(str(tmp_path / "ck"), step=2)
    for t in range(3, 5):
        tr.run_round(t, plan)

    tr2 = FedRFTCATrainer(sources, target, cfg, _proto(rounds=0))
    tr2.restore_state(str(tmp_path / "ck"))
    for t in range(3, 5):
        tr2.run_round(t, plan)

    assert _leaf_div(tr.tgt_params, tr2.tgt_params) == 0.0
    assert _leaf_div(tr._src_stack, tr2._src_stack) == 0.0
    assert _leaf_div(tr.tgt_opt, tr2.tgt_opt) == 0.0
    host = json.loads((tmp_path / "ck" / "step_00000002.npz.host.json").read_text())
    assert "rng" in host and len(host["iters"]) == 8


# ---- fedsim crash-restart --------------------------------------------------


def _sched(sources, target, cfg, **async_kw):
    tr = FedRFTCATrainer(sources, target, cfg, _proto(rounds=0))
    return tr, AsyncScheduler(tr, AsyncConfig(buffer_size=3, compute_s=1.0, **async_kw))


def test_server_crash_recovers_within_checkpoint_interval(small_setup, tmp_path):
    sources, target, cfg = small_setup
    tr, sched = _sched(
        sources, target, cfg,
        server_crash_times=(7.5,), checkpoint_interval_s=3.0,
        ckpt_dir=str(tmp_path / "ck"),
    )
    sched.run(10)
    assert sched.flushes == 10  # the crashed run still completes its budget
    (rec,) = sched.recoveries
    assert 0.0 <= rec["rollback_s"] <= 3.0  # within one checkpoint interval
    assert rec["restored_flush"] < 10
    assert _all_finite(tr.tgt_params)


def test_server_crash_replay_is_deterministic(small_setup, tmp_path):
    sources, target, cfg = small_setup

    def run(d):
        tr, sched = _sched(
            sources, target, cfg,
            server_crash_times=(5.5,), checkpoint_interval_s=2.0, ckpt_dir=str(d),
        )
        hist = sched.run(8)
        return tr, hist

    tr_a, hist_a = run(tmp_path / "a")
    tr_b, hist_b = run(tmp_path / "b")
    assert hist_a == hist_b
    assert _leaf_div(tr_a.tgt_params, tr_b.tgt_params) == 0.0
    assert _leaf_div(tr_a._src_stack, tr_b._src_stack) == 0.0


def test_crash_without_checkpoint_config_rolls_back_to_start(small_setup, tmp_path):
    sources, target, cfg = small_setup
    # no checkpoint_interval_s: only the t=0 snapshot exists
    tr, sched = _sched(
        sources, target, cfg, server_crash_times=(3.5,), ckpt_dir=str(tmp_path / "ck")
    )
    sched.run(6)
    (rec,) = sched.recoveries
    assert rec["restored_flush"] == 0 and rec["rollback_s"] == pytest.approx(3.5)
    assert sched.flushes == 6


def test_edge_crash_loses_buffer_and_inflight_uplinks(small_setup):
    doms = make_domains(5, 120, shift=0.5, seed=1, dim=8, n_classes=3)
    cfg = ClientConfig(input_dim=8, n_classes=3, n_rff=32, m=8, extractor_widths=(16, 8))
    ids = list(range(4))

    def run():
        proto = ProtocolConfig(
            n_rounds=0, t_c=2, warmup_rounds=1, batch_size=32, seed=0,
            topology=Topology((0, 0, 1, 1)),
            scenario=TraceScenario([RoundPlan(ids, ids, ids)], cycle=True),
        )
        tr = FedRFTCATrainer(doms[:4], doms[4], cfg, proto)
        sched = AsyncScheduler(
            tr,
            AsyncConfig(buffer_size=2, compute_s=1.0, edge_crash_times=((2.0, 0),)),
            links=LinkScenario(links=[LinkModel(latency_s=0.4 * (i + 1)) for i in ids]),
            edge_links=LinkScenario(
                links=[LinkModel(latency_s=0.3), LinkModel(latency_s=0.3)]
            ),
        )
        hist = sched.run(6)
        return tr, sched, hist

    tr, sched, hist = run()
    crash_rows = [h for h in hist if h.get("crash") == "edge"]
    assert len(crash_rows) == 1
    # edge 0 (clients 0, 1) flushed at t=1.8 and its merged uplink was still
    # crossing the backhaul (lands 2.1) when the edge died at t=2.0
    assert crash_rows[0]["lost"] == [0, 1]
    assert sched.flushes == 6  # the lost clients re-dispatched and recovered
    tr2, _, hist2 = run()
    assert hist == hist2
    assert _leaf_div(tr.tgt_params, tr2.tgt_params) == 0.0


def test_async_dead_link_client_gives_up_not_blocks(small_setup):
    sources, target, cfg = small_setup
    tr = FedRFTCATrainer(sources, target, cfg, _proto(rounds=0))
    links = LinkScenario(
        links=[LinkModel(latency_s=0.3), LinkModel(latency_s=0.3), LinkModel(drop=1.0)],
        retry_s=0.5, max_retries=2,
    )
    sched = AsyncScheduler(tr, AsyncConfig(buffer_size=2, compute_s=1.0), links=links)
    hist = sched.run(6)
    assert sched.flushes == 6 and math.isfinite(sched.clock.now)
    assert sched.giveups >= 1
    members = {m for h in hist if "members" in h for m in h["members"]}
    assert 2 not in members  # the dead-link client never lands an update
