"""repro.comm: wire format, codecs, transports, network scenarios.

Coverage required by the subsystem's contracts:
- codec round trips: quantize/dequantize relative-error bounds, seed-replay
  bit-exactness of the reconstructed W_RF, sparsify/densify identity at
  k=full, byte-count exactness vs len(serialized);
- wire: serialize/deserialize round trip across kinds and codecs;
- netsim: nesting invariant, deterministic trace record/replay, JSON round
  trip, straggler deadlines driven by real payload bytes;
- transports threaded through the protocol on both engines.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (
    BernoulliScenario,
    LinkModel,
    LinkScenario,
    TableIIIScenario,
    build_transport,
    classifier_message,
    deserialize,
    get_codec,
    load_trace,
    moments_message,
    record_trace,
    save_trace,
    serialize,
    serialized_size,
    table3_trace,
    w_rf_message,
)
from repro.data import make_domains
from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig
from repro.federated.model import init_params, w_rf_key

ALL_CODECS = ["float32", "float16", "bfloat16", "qint8", "qint4", "topk:0.25", "topk:7"]


@pytest.fixture(scope="module")
def payload(rng):
    return rng.normal(size=(96,)).astype(np.float32)


# ---- codecs ----------------------------------------------------------------


@pytest.mark.parametrize("spec", ALL_CODECS)
def test_byte_count_exactness(spec, payload):
    """len(serialize(...)) == analytic serialized_size for every codec."""
    codec = get_codec(spec)
    msg = moments_message(payload, sender=3, round=11)
    data = serialize(msg, codec, rng=np.random.default_rng(0))
    assert len(data) == msg.nbytes(codec)
    assert len(data) == serialized_size(
        "moments", {"msg": (payload.shape, payload.dtype)}, codec
    )


@pytest.mark.parametrize("spec", ALL_CODECS)
def test_wire_roundtrip_metadata(spec, payload):
    codec = get_codec(spec)
    msg = moments_message(payload, sender=5, round=42, downlink=True)
    out, codec2 = deserialize(serialize(msg, codec, rng=np.random.default_rng(0)))
    assert (out.kind, out.sender, out.round, out.downlink) == ("moments", 5, 42, True)
    # the wire id carries the codec family; topk's k rides in the payload
    assert codec2.name.partition(":")[0] == codec.name.partition(":")[0]
    assert out.arrays["msg"].shape == payload.shape


def test_float32_roundtrip_bitexact(payload):
    out, _ = deserialize(
        serialize(moments_message(payload, sender=0, round=0), get_codec("float32"))
    )
    assert np.array_equal(out.arrays["msg"], payload)


@pytest.mark.parametrize("bits", [8, 4])
def test_quant_relative_error_bound(bits, payload):
    """Stochastic rounding moves each value by at most one quantization step."""
    codec = get_codec(f"qint{bits}")
    out, _ = deserialize(
        serialize(moments_message(payload, sender=0, round=0), codec, rng=np.random.default_rng(1))
    )
    qmax = (1 << (bits - 1)) - 1
    step = np.abs(payload).max() / qmax
    err = np.abs(out.arrays["msg"] - payload).max()
    assert err <= step * (1 + 1e-6), (err, step)


def test_quant_zero_tensor():
    z = np.zeros((16,), np.float32)
    out, _ = deserialize(
        serialize(moments_message(z, sender=0, round=0), get_codec("qint8"),
                  rng=np.random.default_rng(0))
    )
    assert np.array_equal(out.arrays["msg"], z)


def test_topk_identity_at_full(payload):
    """sparsify/densify is the identity when k == size."""
    codec = get_codec("topk:1.0")
    out, _ = deserialize(serialize(moments_message(payload, sender=0, round=0), codec))
    assert np.array_equal(out.arrays["msg"], payload)


def test_topk_keeps_largest(payload):
    codec = get_codec("topk:4")
    out, _ = deserialize(serialize(moments_message(payload, sender=0, round=0), codec))
    got = out.arrays["msg"]
    keep = np.sort(np.argsort(np.abs(payload))[-4:])
    assert np.array_equal(np.flatnonzero(got), keep)
    assert np.array_equal(got[keep], payload[keep])


def test_seed_replay_w_rf_bitexact():
    """The reconstructed W_RF equals init_params' draw bit for bit, from an
    O(1) payload whose size is independent of (N, m)."""
    cfg = ClientConfig(input_dim=8, n_classes=3, n_rff=64, m=8)
    key = jax.random.PRNGKey(123)
    w = np.asarray(init_params(cfg, key)["w_rf"])
    key_data = np.asarray(jax.random.key_data(w_rf_key(cfg, key)))
    codec = get_codec("seed_replay")
    msg = w_rf_message(w, sender=0, round=0, replay=("w_rf_init", key_data))
    data = serialize(msg, codec)
    out, _ = deserialize(data)
    assert np.array_equal(out.arrays["w_rf"], w)
    big = ClientConfig(input_dim=8, n_classes=3, n_rff=512, m=64)
    assert codec.nbytes((2 * big.n_rff, big.m), np.float32) == codec.nbytes(
        w.shape, np.float32
    )  # O(1): key + generator id, not O(N m)


def test_seed_replay_rejects_data_payloads():
    with pytest.raises(ValueError):
        serialize(moments_message(np.ones(4, np.float32), sender=0, round=0),
                  get_codec("seed_replay"))


def test_classifier_multiarray_roundtrip(rng):
    clf = {"w": rng.normal(size=(8, 3)).astype(np.float32),
           "b": rng.normal(size=(3,)).astype(np.float32)}
    out, _ = deserialize(serialize(classifier_message(clf, sender=2, round=9),
                                   get_codec("float32")))
    assert np.array_equal(out.arrays["w"], clf["w"])
    assert np.array_equal(out.arrays["b"], clf["b"])


def test_quant_roundtrip_twin_matches_codec_formula(payload):
    """The jittable roundtrip twin obeys the same one-step error bound and is
    deterministic per key (the batched engine's in-graph channel)."""
    codec = get_codec("qint8")
    x = jnp.asarray(payload)
    a = codec.roundtrip(x, jax.random.PRNGKey(0))
    b = codec.roundtrip(x, jax.random.PRNGKey(0))
    assert jnp.array_equal(a, b)
    step = float(jnp.abs(x).max()) / 127
    assert float(jnp.abs(a - x).max()) <= step * (1 + 1e-6)


# ---- netsim ----------------------------------------------------------------


def test_scenarios_nested_invariant():
    rng = np.random.default_rng(0)
    scenarios = [
        TableIIIScenario("III"),
        BernoulliScenario(0.3, 0.3, 0.3),
        LinkScenario([LinkModel(drop=0.4) for _ in range(6)], deadline_s=1.0),
    ]
    for sc in scenarios:
        for t in range(1, 30):
            p = sc.plan(rng, 6, t)
            assert set(p.c_clients) <= set(p.w_clients) <= set(p.msg_clients)


def test_table3_scenario_matches_plan_round():
    from repro.federated.network import plan_round

    a = TableIIIScenario("II")
    r1, r2 = np.random.default_rng(7), np.random.default_rng(7)
    for t in range(1, 20):
        p, q = a.plan(r1, 5, t), plan_round(r2, 5, "II")
        assert (p.msg_clients, p.w_clients, p.c_clients) == (
            q.msg_clients, q.w_clients, q.c_clients)


def test_trace_record_replay_deterministic(tmp_path):
    trace = record_trace(BernoulliScenario(0.5, 0.2, 0.2), np.random.default_rng(3), 5, 12)
    path = tmp_path / "trace.json"
    save_trace(trace, path)
    loaded = load_trace(path)
    rng = np.random.default_rng(999)  # replay must ignore the rng entirely
    for t in range(1, 13):
        p, q = trace.plan(rng, 5, t), loaded.plan(rng, 5, t)
        assert (p.msg_clients, p.w_clients, p.c_clients) == (
            q.msg_clients, q.w_clients, q.c_clients)
    with pytest.raises(IndexError):
        loaded.plan(rng, 5, 13)
    assert load_trace(path, cycle=True).plan(rng, 5, 13) is not None


def test_table3_trace_settings():
    for setting in ("I", "II", "III"):
        tr = table3_trace(setting, 4, 8, seed=1)
        assert len(tr.plans) == 8


def test_link_scenario_straggler_bytes():
    """A tight deadline drops exactly the payloads too big for the pipe."""
    # 1 KB/s link, 0.5 s deadline -> 400-byte payloads pass, 4000-byte fail
    links = [LinkModel(bandwidth_bps=1000.0)] * 3
    sc = LinkScenario(links, deadline_s=0.5,
                      payload_bytes={"moments": 400, "w_rf": 4000, "classifier": 400})
    p = sc.plan(np.random.default_rng(0), 3, 1)
    assert p.msg_clients == [0, 1, 2]
    assert p.w_clients == []  # stragglers: W_RF can't make the deadline
    assert p.c_clients == []  # nesting: classifier ⊆ w even though it fits


def test_bernoulli_rates_without_sampling():
    sc = BernoulliScenario(0.5, 0.0, 0.0, sample_s_t=False)
    rng = np.random.default_rng(0)
    got = np.mean([len(sc.plan(rng, 10, t).msg_clients) for t in range(1, 400)])
    assert 4.0 < got < 6.0  # ~Binomial(10, 0.5) mean


# ---- transports through the protocol ---------------------------------------


@pytest.fixture(scope="module")
def tiny_setup():
    doms = make_domains(4, 96, shift=0.5, seed=1, dim=8, n_classes=3)
    cfg = ClientConfig(input_dim=8, n_classes=3, n_rff=16, m=4, extractor_widths=(8, 4))
    return doms[:3], doms[3], cfg


def _train(sources, target, cfg, **kw):
    proto = ProtocolConfig(n_rounds=4, t_c=2, warmup_rounds=1, batch_size=24, seed=0, **kw)
    tr = FedRFTCATrainer(sources, target, cfg, proto)
    tr.train()
    return tr


def test_identity_accounting_matches_wire_float32(tiny_setup):
    """Analytic identity-transport bytes == real serialized wire bytes."""
    s, t, cfg = tiny_setup
    a = _train(s, t, cfg, engine="serial")
    b = _train(s, t, cfg, engine="serial", transport="wire")
    assert a.comm.bytes_by_kind == b.comm.bytes_by_kind
    assert a.comm.total == b.comm.total  # float accounting unchanged
    assert a.comm.messages_by_kind == b.comm.messages_by_kind


def test_wire_float32_serial_matches_identity_trajectory(tiny_setup):
    """float32 wire round trips are bit-exact: same final accuracy."""
    s, t, cfg = tiny_setup
    a = _train(s, t, cfg, engine="serial")
    b = _train(s, t, cfg, engine="serial", transport="wire")
    assert a.evaluate() == b.evaluate()


def test_engines_agree_on_byte_accounting(tiny_setup):
    s, t, cfg = tiny_setup
    a = _train(s, t, cfg, engine="batched")
    b = _train(s, t, cfg, engine="serial")
    assert a.comm.bytes_by_kind == b.comm.bytes_by_kind


@pytest.mark.parametrize("engine", ["serial", "batched"])
def test_wire_seed_replay_end_to_end(tiny_setup, engine):
    """seed_replay runs on both engines, pins W_RF bit-exactly to the shared
    init everywhere, and makes W_RF wire bytes shape-independent."""
    s, t, cfg = tiny_setup
    tr = _train(s, t, cfg, engine=engine, transport="wire", codec="seed_replay")
    w0 = np.asarray(tr._w_init)
    assert np.array_equal(np.asarray(tr.tgt_params["w_rf"]), w0)
    for i in range(tr.k):
        assert np.array_equal(np.asarray(tr._src_param(i)["w_rf"]), w0)
    n_w = tr.comm.messages_by_kind["w_rf"]
    if n_w:
        per_msg = tr.comm.bytes_by_kind["w_rf"] / n_w
        dense = get_codec("float32").nbytes((2 * cfg.n_rff, cfg.m), np.float32)
        assert per_msg < 64 < dense  # O(1) key vs O(Nm) floats
    assert tr.comm.w_rf == 0  # no W floats uploaded
    assert 0.0 <= tr.evaluate() <= 1.0


@pytest.mark.parametrize("engine", ["serial", "batched"])
def test_wire_qint8_end_to_end(tiny_setup, engine):
    s, t, cfg = tiny_setup
    tr = _train(s, t, cfg, engine=engine, transport="wire", codec="qint8")
    assert 0.0 <= tr.evaluate() <= 1.0
    if tr.comm.messages_by_kind["moments"]:
        per_msg = tr.comm.bytes_by_kind["moments"] / tr.comm.messages_by_kind["moments"]
        dense = serialized_size(
            "moments", {"msg": ((2 * cfg.n_rff,), np.dtype(np.float32))},
            get_codec("float32"),
        )
        # 4 bytes/elt -> 1 byte/elt + scale; headers identical
        assert per_msg <= dense - 3 * 2 * cfg.n_rff + 4


def test_trace_scenario_through_protocol(tiny_setup):
    """An explicit trace drives the protocol deterministically: same trace,
    same byte log, on both engines."""
    s, t, cfg = tiny_setup
    trace = table3_trace("III", n_clients=3, rounds=4, seed=5)
    a = _train(s, t, cfg, engine="batched", scenario=trace)
    b = _train(s, t, cfg, engine="serial", scenario=trace)
    assert a.comm.bytes_by_kind == b.comm.bytes_by_kind
    assert a.comm.total == b.comm.total


def test_delta_topk_classifier_converges_to_reference(tiny_setup):
    """Delta-coded top-k classifier sync: error does not accumulate (the
    reference rolls forward), and k=full deltas reproduce float32 exactly."""
    s, t, cfg = tiny_setup
    a = _train(s, t, cfg, engine="serial", transport="wire")
    b = _train(s, t, cfg, engine="serial", transport="wire", codec_classifier="topk:1.0")
    aw = np.asarray(a.tgt_params["classifier"]["w"])
    bw = np.asarray(b.tgt_params["classifier"]["w"])
    # k=full delta transfers are lossless, but reconstruct as ref+(v-ref):
    # allow ulp-level drift, nothing structural
    np.testing.assert_allclose(aw, bw, rtol=0, atol=1e-6)


def test_unknown_transport_and_codec_raise():
    with pytest.raises(ValueError):
        build_transport("carrier-pigeon")
    with pytest.raises(ValueError):
        build_transport("wire", "mp3")
    with pytest.raises(ValueError):
        build_transport("wire", "float32", codec_moments="seed_replay")


# ---- shared-backhaul queueing ----------------------------------------------


def test_backhaul_default_is_bitwise_uncontended():
    """backhaul=inf must keep per-payload delivery math bit-for-bit."""
    link = LinkModel(latency_s=0.25, bandwidth_bps=1000.0)
    dt = link.delivery_time(np.random.default_rng(0), 500)
    assert dt == 0.25 + 500 / 1000.0
    # scenario plans: same rng stream, same sets with and without the field
    links = [LinkModel(bandwidth_bps=1000.0, jitter_s=0.1, drop=0.2)] * 3
    pb = {"moments": 400, "w_rf": 4000, "classifier": 400}
    a = LinkScenario(links, deadline_s=0.5, payload_bytes=pb)
    b = LinkScenario(links, deadline_s=0.5, payload_bytes=pb, backhaul_bps=float("inf"))
    for t in range(1, 6):
        pa = a.plan(np.random.default_rng(t), 3, t)
        pb_ = b.plan(np.random.default_rng(t), 3, t)
        assert (pa.msg_clients, pa.w_clients, pa.c_clients) == (
            pb_.msg_clients, pb_.w_clients, pb_.c_clients,
        )


def test_backhaul_contention_drops_concurrent_clients():
    """Payloads that fit each last-mile link miss the deadline once K clients
    share a backhaul: the wire term is the *sum* of in-flight bytes."""
    links = [LinkModel(bandwidth_bps=1e6)] * 4
    pb = {"moments": 400, "w_rf": 400, "classifier": 400}
    fast = LinkScenario(links, deadline_s=0.5, payload_bytes=pb)
    assert fast.plan(np.random.default_rng(0), 4, 1).msg_clients == [0, 1, 2, 3]
    # 4 * 400 B on a 2 kB/s backhaul = 0.8 s > the 0.5 s deadline
    jammed = LinkScenario(links, deadline_s=0.5, payload_bytes=pb, backhaul_bps=2000.0)
    p = jammed.plan(np.random.default_rng(0), 4, 1)
    assert p.msg_clients == [] and p.w_clients == [] and p.c_clients == []


def test_uplink_time_retries_and_contention():
    sc = LinkScenario(
        [LinkModel(latency_s=0.1, bandwidth_bps=1000.0)],
        backhaul_bps=1000.0, retry_s=2.0,
    )
    # no loss, no contention: latency + bytes/bw exactly
    assert sc.uplink_time(np.random.default_rng(0), 0, 500) == 0.1 + 0.5
    # contention: (500 + 1500) / 1000 beats the last-mile 0.5 s
    assert sc.uplink_time(
        np.random.default_rng(0), 0, 500, inflight_bytes=1500
    ) == 0.1 + 2.0
    # losses retransmit under exponential backoff: delivered uplinks are late,
    # exhausted budgets give up (inf), and nothing raises or spins forever
    lossy = LinkScenario([LinkModel(latency_s=0.1, drop=0.7)], retry_s=2.0)
    times = [lossy.uplink_time(np.random.default_rng(s), 0, 100) for s in range(30)]
    delivered = [t for t in times if np.isfinite(t)]
    assert delivered and max(delivered) > 2.0
    # drop=1.0: every attempt fails -> give-up reported as a drop, not an error
    dead = LinkScenario([LinkModel(drop=1.0)], retry_s=1.0, max_retries=3, retry_jitter=0.0)
    ok, elapsed = dead.uplink_outcome(np.random.default_rng(0), 0, 1)
    assert not ok and elapsed == 1.0 + 2.0 + 4.0  # backoff 1, 2, 4 then give up
    assert dead.uplink_time(np.random.default_rng(0), 0, 1) == math.inf
    assert sc.total_uplink_bytes(("moments", "w_rf")) == 0  # no payload table yet


# ---- auto-codec picker ------------------------------------------------------

FAKE_RECORD = {
    "identity": {"acc": 0.80},
    "accuracy_vs_codec": {
        "float32": {"acc": 0.80, "bytes": {"moments": 100, "w_rf": 1000, "classifier": 10}},
        "bfloat16": {"acc": 0.795, "bytes": {"moments": 50, "w_rf": 500, "classifier": 5}},
        "qint4": {"acc": 0.70, "bytes": {"moments": 13, "w_rf": 125, "classifier": 2}},
        "seed_replay": {"acc": 0.79, "bytes": {"moments": 100, "w_rf": 43, "classifier": 10}},
    },
}


def test_pick_codec_cheapest_within_budget():
    from repro.comm import autocodec

    # generous budget: the qint4 run is cheapest and within 10 points
    assert autocodec.pick_codec(0.12, record=FAKE_RECORD) == "qint4"
    # 2-point budget: qint4's 10-point gap disqualifies it; seed_replay wins
    assert autocodec.pick_codec(0.02, record=FAKE_RECORD) == "seed_replay"
    # zero budget: only the gap-free float32 run qualifies
    assert autocodec.pick_codec(0.0, record=FAKE_RECORD) == "float32"
    with pytest.raises(ValueError, match="budget must be >= 0"):
        autocodec.pick_codec(-0.1, record=FAKE_RECORD)
    with pytest.raises(ValueError, match="bad auto-codec spec"):
        autocodec.resolve("auto:cheap", record=FAKE_RECORD)
    assert autocodec.resolve("qint8", record=FAKE_RECORD) == "qint8"  # passthrough


def test_pick_codec_no_fit_raises_and_missing_record(tmp_path):
    from repro.comm import autocodec

    rec = {
        "identity": {"acc": 0.9},
        "accuracy_vs_codec": {"qint4": {"acc": 0.5, "bytes": {"moments": 1}}},
    }
    with pytest.raises(ValueError, match="no measured codec"):
        autocodec.pick_codec(0.01, record=rec)
    with pytest.raises(FileNotFoundError, match="benchmarks.run"):
        autocodec.load_record(tmp_path / "nope.json")


def test_codec_table_schema_stale_raises_value_error():
    """A BENCH_comm.json written by an older bench (missing or reshaped
    fields) must surface as a ValueError naming the rerun command, never a
    bare KeyError from deep inside a trainer constructor."""
    from repro.comm import autocodec

    stale_records = [
        {},  # empty file
        {"accuracy_vs_codec": FAKE_RECORD["accuracy_vs_codec"]},  # no identity
        {"identity": {"accuracy": 0.8}, "accuracy_vs_codec": {}},  # renamed key
        # bytes reshaped from per-kind dict to a flat int
        {"identity": {"acc": 0.8},
         "accuracy_vs_codec": {"qint4": {"acc": 0.7, "bytes": 140}}},
        # row lost its acc
        {"identity": {"acc": 0.8},
         "accuracy_vs_codec": {"qint4": {"bytes": {"moments": 1}}}},
    ]
    for rec in stale_records:
        with pytest.raises(ValueError, match="benchmarks.run"):
            autocodec.codec_table(rec)
    # a schema-valid record that measured nothing is also a hard error
    with pytest.raises(ValueError, match="no codecs"):
        autocodec.codec_table({"identity": {"acc": 0.8}, "accuracy_vs_codec": {}})
    # the happy path still parses
    table = autocodec.codec_table(FAKE_RECORD)
    assert table["seed_replay"]["bytes"] == 153
    assert table["float32"]["gap"] == 0.0


def test_protocol_resolves_auto_codec(tiny_setup, tmp_path, monkeypatch):
    """ProtocolConfig(codec='auto:<budget>') trains with the concrete codec
    the measured curves pick."""
    import json

    from repro.comm import autocodec

    path = tmp_path / "BENCH_comm.json"
    path.write_text(json.dumps(FAKE_RECORD))
    monkeypatch.setattr(autocodec, "DEFAULT_RECORD_PATH", path)
    s, t, cfg = tiny_setup
    tr = _train(s, t, cfg, transport="wire", codec="auto:0.02")
    assert tr.resolved_codec == "seed_replay"
    assert tr._frozen_w  # the pick really flowed into the transport
    tr2 = _train(s, t, cfg, codec="auto:0.12")
    assert tr2.resolved_codec == "qint4"


def test_seed_replay_omega_fused_bit_identity():
    """The "omega_fused" generator replays the fused counter stream: a
    receiver that wants the materialized Omega gets the exact bits the fused
    kernels draw in-kernel, from the 9-byte wire payload."""
    from repro.kernels.prng import fused_omega

    codec = get_codec("seed_replay")
    key = np.asarray([321, 2], np.uint32)  # (seed, ensemble_index)
    om = np.asarray(fused_omega(321, 48, 8, ensemble_index=2))
    msg = w_rf_message(om, sender=0, round=0, replay=("omega_fused", key))
    data = serialize(msg, codec)
    out, _ = deserialize(data)
    assert np.array_equal(out.arrays["w_rf"], om)
    assert codec.nbytes(om.shape, np.float32) == 9  # id + uint32[2] key


def test_seed_replay_decode_memoized():
    """Every round re-announces the same key; the receiver must reconstruct
    only once and hand back the cached read-only array afterwards."""
    from repro.comm.codecs import SeedReplayCodec
    from repro.kernels.prng import fused_omega

    codec = get_codec("seed_replay")
    key = np.asarray([3735928559, 1], np.uint32)  # unique: cold cache entry
    data = codec.encode(None, replay=("omega_fused", key))
    before = SeedReplayCodec.regenerations
    a = codec.decode(data, (32, 8), np.float32)
    assert SeedReplayCodec.regenerations == before + 1  # one real reconstruction
    b = codec.decode(data, (32, 8), np.float32)
    assert SeedReplayCodec.regenerations == before + 1  # repeat decode: cache hit
    assert b is a  # the identical cached object, not a fresh allocation
    assert not a.flags.writeable  # shared cache entry must be immutable
    with pytest.raises(ValueError):
        a[0, 0] = 1.0
    assert np.array_equal(a, np.asarray(fused_omega(3735928559, 32, 8, ensemble_index=1)))
    # a different shape under the same key is a distinct cache entry
    c = codec.decode(data, (16, 8), np.float32)
    assert SeedReplayCodec.regenerations == before + 2
    assert c.shape == (16, 8)
