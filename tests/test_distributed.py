"""Sharded FedRF-TCA data plane: the psum message exchange must reproduce the
host-side math. Runs in a subprocess with forced multi-device CPU (XLA device
count is locked at first jax import, so it can't be set inside this process).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.federated.distributed import (
        build_sharded_round, make_client_mesh, stack_clients, unstack_clients,
    )
    from repro.federated.model import (
        ClientConfig,
        client_message,
        init_params,
        make_omega,
        source_loss,
    )
    from repro.core.mmd import mmd_projected
    from repro.optim import adam, apply_updates

    K = 4
    cfg = ClientConfig(input_dim=6, n_classes=3, n_rff=16, m=4, extractor_widths=(8, 4))
    omega = make_omega(cfg)
    key = jax.random.PRNGKey(0)
    params = [init_params(cfg, jax.random.fold_in(key, i)) for i in range(K)]
    opt = adam(1e-2)
    opts = [opt.init(p) for p in params]
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.normal(size=(K, 6, 8)), jnp.float32)
    ys = jnp.asarray(rng.integers(0, 3, size=(K, 8)))
    x_t = jnp.asarray(rng.normal(size=(6, 8)), jnp.float32)

    mesh = make_client_mesh(K)
    rnd = build_sharded_round(mesh, cfg, omega, opt)
    sp = stack_clients(params)
    so = stack_clients(opts)
    sp2, so2, metrics = rnd(sp, so, xs, ys, x_t)

    # host-side reference of the same synchronous round
    msgs = [client_message(params[i], omega, xs[i], +1.0) for i in range(K)]
    msg_mean = sum(msgs) / K
    ref_params = []
    for i in range(K):
        msg_t = client_message(params[i], omega, x_t, -1.0)
        def loss_fn(p, i=i, msg_t=msg_t):
            l, aux = source_loss(p, omega, xs[i], ys[i], msg_t, cfg, with_mmd=False)
            m_s = client_message(p, omega, xs[i], +1.0)
            all_msgs = [client_message(params[j], omega, xs[j], +1.0) for j in range(K) if j != i]
            mean_msg = (m_s + sum(all_msgs)) / K
            return l + cfg.lambda_mmd * mmd_projected(p["w_rf"], mean_msg, msg_t)
        g = jax.grad(loss_fn)(params[i])
        u, _ = opt.update(g, opts[i], params[i])
        ref_params.append(apply_updates(params[i], u))
    ref_wrf = sum(p["w_rf"] for p in ref_params) / K

    got = unstack_clients(sp2, K)
    err_wrf = float(jnp.abs(got[0]["w_rf"] - ref_wrf).max())
    err_ext = float(jnp.abs(got[1]["extractor"][0]["w"] - ref_params[1]["extractor"][0]["w"]).max())
    print(json.dumps({"err_wrf": err_wrf, "err_ext": err_ext,
                      "l_mmd": float(metrics["l_mmd"])}))
    """
)


@pytest.mark.slow
def test_sharded_round_matches_host_math(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True, env=env, timeout=480
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["err_wrf"] < 1e-5, res
    assert res["err_ext"] < 1e-5, res
    assert res["l_mmd"] >= 0.0
