"""Pallas kernels vs pure-jnp oracles: shape x dtype sweeps (interpret mode)."""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("p,n,nf", [(16, 64, 32), (33, 170, 77), (128, 128, 128), (7, 300, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rff_kernel_sweep(p, n, nf, dtype):
    key = jax.random.PRNGKey(p * n)
    x = jax.random.normal(key, (p, n), dtype)
    om = jax.random.normal(jax.random.fold_in(key, 1), (nf, p), dtype)
    out = ops.rff(x, om, block=64)
    exp = ref.rff_ref(x, om)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("two_n,n", [(64, 128), (96, 210), (128, 64), (32, 500)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_centered_gram_sweep(two_n, n, dtype):
    key = jax.random.PRNGKey(two_n + n)
    sig = jax.random.normal(key, (two_n, n), dtype)
    out = ops.centered_gram(sig, block=32)
    exp = ref.centered_gram_ref(sig)
    scale = float(jnp.abs(exp).max())
    np.testing.assert_allclose(
        np.asarray(out) / scale, np.asarray(exp) / scale,
        atol=5e-2 if dtype == jnp.bfloat16 else 1e-5,
    )


@pytest.mark.parametrize(
    "p,n,nf", [(16, 64, 32), (7, 300, 130), (33, 170, 77), (16, 129, 64), (5, 97, 33)]
)
def test_rff_gram_stream_sweep(p, n, nf):
    """Fused streaming Gram kernel vs dense oracle, incl. non-tile shapes."""
    from repro.core.kernels_math import ell_vector

    key = jax.random.PRNGKey(p + n + nf)
    x = jax.random.normal(key, (p, n), jnp.float32)
    om = jax.random.normal(jax.random.fold_in(key, 1), (nf, p), jnp.float32)
    ell = ell_vector(n // 2, n - n // 2)
    g, u = ops.rff_gram_stream(x, om, ell, block=64)
    ge, ue = ref.rff_gram_stream_ref(x, om, ell)
    scale = float(jnp.abs(ge).max())
    np.testing.assert_allclose(np.asarray(g) / scale, np.asarray(ge) / scale, atol=2e-5)
    np.testing.assert_allclose(np.asarray(u), np.asarray(ue), atol=2e-5)


@pytest.mark.parametrize(
    "p,n,nf,tile", [(16, 64, 32, 128), (7, 300, 130, 128), (16, 129, 300, 256), (5, 97, 33, 128)]
)
def test_rff_gram_stream_tiled_sweep(p, n, nf, tile):
    """(i, j)-tiled kernel vs untiled kernel vs dense oracle, incl. N that is
    not a multiple of the tile (feature-row padding path)."""
    from repro.core.kernels_math import ell_vector

    key = jax.random.PRNGKey(p + n + nf)
    x = jax.random.normal(key, (p, n), jnp.float32)
    om = jax.random.normal(jax.random.fold_in(key, 1), (nf, p), jnp.float32)
    ell = ell_vector(n // 2, n - n // 2)
    g_t, u_t = ops.rff_gram_stream(x, om, ell, block=64, tile=tile)
    g_u, u_u = ops.rff_gram_stream(x, om, ell, block=64, tile=0)
    ge, ue = ref.rff_gram_stream_ref(x, om, ell)
    scale = float(jnp.abs(ge).max())
    np.testing.assert_allclose(np.asarray(g_t) / scale, np.asarray(g_u) / scale, atol=1e-6)
    np.testing.assert_allclose(np.asarray(u_t), np.asarray(u_u), atol=1e-6)
    np.testing.assert_allclose(np.asarray(g_t) / scale, np.asarray(ge) / scale, atol=2e-5)
    np.testing.assert_allclose(np.asarray(u_t), np.asarray(ue), atol=2e-5)


def test_gram_tile_plan_auto_selection():
    """tile=None keeps the untiled fast path up to the VMEM threshold, then
    switches to a tile whose accumulator bytes are independent of N."""
    assert ops.gram_tile_plan(256)["tile"] is None
    assert ops.gram_tile_plan(ops.GRAM_TILE_THRESHOLD)["tile"] is None
    t_mid = ops.gram_tile_plan(1300)
    t_big = ops.gram_tile_plan(8192)
    assert t_mid["tile"] == 256 and t_mid["n_pad"] % 256 == 0
    assert t_big["tile"] == 512
    # per-instance accumulator memory is set by the tile, not N
    assert t_big["acc_bytes"] == 3 * 512 * 512 * 4 + 2 * 512 * 2 * 4
    assert t_big["acc_bytes"] < 3 * 8192 * 8192 * 4
    # explicit overrides: 0 forces untiled, an int forces that tile edge
    assert ops.gram_tile_plan(4096, tile=0)["tile"] is None
    assert ops.gram_tile_plan(300, tile=128)["tile"] == 128
    # lane-misaligned forced tiles must fail here, not at Mosaic lowering
    with pytest.raises(ValueError, match="multiple of 128"):
        ops.gram_tile_plan(4096, tile=200)


def test_tiled_kernel_vmem_accumulators_bounded_by_tile():
    """The pallas_call's scratch accumulators (the VMEM proxy) must be (t, t)
    blocks, not (N_pad, N_pad) — checked on the traced kernel jaxpr."""
    from repro.core.kernels_math import ell_vector

    p, n, nf, tile = 8, 128, 1536, 256
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (p, n), jnp.float32)
    om = jax.random.normal(jax.random.fold_in(key, 1), (nf, p), jnp.float32)
    ell = ell_vector(n // 2, n - n // 2)
    jaxpr = jax.make_jaxpr(
        lambda a, o, e: ops.rff_gram_stream(a, o, e, tile=tile)
    )(x, om, ell)

    def find_pallas(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name == "pallas_call":
                yield eqn
        for sub in jax.core.subjaxprs(jx):
            yield from find_pallas(sub)

    eqns = list(find_pallas(jaxpr.jaxpr))
    assert eqns, "tiled path must lower through pallas_call"
    kernel_jaxpr = eqns[0].params["jaxpr"]
    limit = tile * tile  # largest per-instance buffer the tiled layout allows
    for v in list(kernel_jaxpr.invars) + [
        o for eqn in kernel_jaxpr.eqns for o in eqn.outvars
    ]:
        shape = getattr(getattr(v, "aval", None), "shape", None)
        if shape is None:
            continue
        size = int(np.prod(shape)) if shape else 1
        assert size <= limit, f"kernel buffer {shape} exceeds tile bound"
    assert nf * nf > limit and nf * n > limit  # bound would catch untiled accs


@pytest.mark.parametrize("p,n,nf", [(16, 130, 40), (3, 257, 16)])
def test_rff_padding_non_multiple_of_block(p, n, nf):
    """Default-block (128) wrapper padding paths must match the XLA reference."""
    key = jax.random.PRNGKey(n)
    x = jax.random.normal(key, (p, n), jnp.float32)
    om = jax.random.normal(jax.random.fold_in(key, 1), (nf, p), jnp.float32)
    out = ops.rff(x, om)  # block=128 > all dims: every axis takes the pad path
    exp = ref.rff_ref(x, om)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("two_n,n", [(40, 130), (130, 257)])
def test_centered_gram_padding_non_multiple_of_block(two_n, n):
    """Mean-padding of sample columns (the centering-safe pad) at block=128."""
    key = jax.random.PRNGKey(two_n * n)
    sig = jax.random.normal(key, (two_n, n), jnp.float32)
    out = ops.centered_gram(sig)
    exp = ref.centered_gram_ref(sig)
    scale = float(jnp.abs(exp).max())
    np.testing.assert_allclose(np.asarray(out) / scale, np.asarray(exp) / scale, atol=1e-5)


@pytest.mark.parametrize(
    "b,h,kv,s,d,dv",
    [(1, 2, 1, 128, 32, 32), (2, 4, 2, 128, 16, 16), (1, 4, 4, 256, 32, 16), (2, 8, 2, 64, 64, 64)],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 48])
def test_flash_attention_sweep(b, h, kv, s, d, dv, dtype, window):
    key = jax.random.PRNGKey(b * h * s)
    q = jax.random.normal(key, (b, h, s, d), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, kv, s, d), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, kv, s, dv), dtype)
    out = ops.flash_attention(q, k, v, window=window, block_q=64, block_k=64)
    exp = ref.attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32),
        atol=3e-2 if dtype == jnp.bfloat16 else 2e-5,
    )


def test_flash_non_causal():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 2, 64, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 64, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 64, 16))
    out = ops.flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    exp = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


def test_rff_kernel_feeds_rf_tca():
    """End-to-end: RF-TCA solved through the Pallas path matches XLA path."""
    from repro.core.rf_tca import rf_tca

    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.normal(size=(16, 100)), jnp.float32)
    xt = jnp.asarray(rng.normal(size=(16, 60)) + 1, jnp.float32)
    _, _, s1 = rf_tca(xs, xt, n_features=64, m=8, gamma=1e-2, use_pallas=True)
    _, _, s2 = rf_tca(xs, xt, n_features=64, m=8, gamma=1e-2, use_pallas=False)
    np.testing.assert_allclose(np.asarray(s1.eigvals), np.asarray(s2.eigvals), rtol=1e-2)


# ---- seed-fused RFF kernels (W_RF drawn inside the kernel) -----------------


def _rf_tca_module():
    # repro.core re-exports the rf_tca *function*, which shadows the submodule
    # on attribute access — import the module explicitly.
    return importlib.import_module("repro.core.rf_tca")


def _fused_case(p=7, n=150, key_seed=0):
    from repro.core.kernels_math import ell_vector

    key = jax.random.PRNGKey(key_seed)
    x = jax.random.normal(key, (p, n), jnp.float32)
    ell = ell_vector(n // 2, n - n // 2)
    return x, ell


@pytest.mark.parametrize("ensemble", [1, 3])
@pytest.mark.parametrize("tile", [0, 128])
def test_fused_gram_pallas_matches_twin_bitwise(ensemble, tile):
    """Acceptance: the seed-fused Pallas kernel equals its XLA generator twin
    at 0 ULP in both layouts — same counter draws, same padded geometry, same
    sequential accumulation order, hence the identical float op sequence."""
    rf = _rf_tca_module()
    x, ell = _fused_case(key_seed=tile + ensemble)
    kw = dict(n_features=96, seed=11, ensemble=ensemble, tile=tile)
    g_p, u_p = rf.fused_streaming_gram(x, ell, use_pallas=True, **kw)
    g_x, u_x = rf.fused_streaming_gram(x, ell, use_pallas=False, **kw)
    assert bool(jnp.array_equal(g_p, g_x)), float(jnp.abs(g_p - g_x).max())
    assert bool(jnp.array_equal(u_p, u_x)), float(jnp.abs(u_p - u_x).max())


def test_fused_ensemble1_degenerate_to_materialized():
    """ensemble=1 is bitwise the single-draw program: the fused kernel with
    S=1 equals the materialized kernel fed the generator twin's omega."""
    from repro.core.kernels_math import ell_vector
    from repro.kernels.prng import fused_omega

    p, n, nf, seed = 9, 130, 64, 4
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (p, n), jnp.float32)
    ell = ell_vector(n // 2, n - n // 2)
    g_f, u_f = ops.rff_gram_stream_fused(x, ell, n_features=nf, seed=seed)
    g_m, u_m = ops.rff_gram_stream(x, fused_omega(seed, nf, p), ell)
    assert bool(jnp.array_equal(g_f, g_m)), float(jnp.abs(g_f - g_m).max())
    assert bool(jnp.array_equal(u_f, u_m)), float(jnp.abs(u_f - u_m).max())


def test_fused_ensemble_matches_dense_oracle():
    """ensemble=S averages the per-draw *centered* statistics: the fused pass
    must match the mean over S materialized single-draw oracles."""
    rf = _rf_tca_module()
    x, ell = _fused_case(p=6, n=110, key_seed=5)
    kw = dict(n_features=64, seed=3, ensemble=3)
    g, u = rf.fused_streaming_gram(x, ell, **kw)
    ge, ue = ref.rff_gram_stream_fused_ref(x, ell, **kw)
    scale = float(jnp.abs(ge).max())
    np.testing.assert_allclose(np.asarray(g) / scale, np.asarray(ge) / scale, atol=3e-5)
    np.testing.assert_allclose(np.asarray(u), np.asarray(ue), atol=3e-5)


@pytest.mark.parametrize("p,n,nf", [(16, 64, 32), (7, 130, 96)])
def test_rff_fused_featurize_matches_materialized(p, n, nf):
    """Seed-fused featurize kernel vs rff_ref on the materialized twin omega
    (per-block accumulation vs one matmul: allclose, not bitwise)."""
    from repro.kernels.prng import fused_omega

    key = jax.random.PRNGKey(p * n)
    x = jax.random.normal(key, (p, n), jnp.float32)
    sig = ops.rff_fused(x, n_features=nf, seed=2)
    exp = ref.rff_ref(x, fused_omega(2, nf, p))
    np.testing.assert_allclose(np.asarray(sig), np.asarray(exp), atol=2e-5, rtol=2e-5)


def test_fused_path_weightless_jaxpr():
    """Acceptance: W_RF is absent from the fused path's jaxpr — the pass
    consumes only (x, ell), bakes in no weight-sized constants, and never
    materializes the (2N, n) feature matrix; the only weight state anywhere
    is the static integer seed.  (Per-sample-block transient draws inside the
    scan body are the point of the design and stay within the size bound.)"""
    rf = _rf_tca_module()
    from repro.core.kernels_math import ell_vector

    p, n, nf, block = 8, 1000, 256, 128
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (p, n), jnp.float32)
    ell = ell_vector(n // 2, n - n // 2)
    closed = jax.make_jaxpr(
        lambda a, e: rf.fused_streaming_gram(
            a, e, n_features=nf, seed=5, use_pallas=False, block=block
        )
    )(x, ell)
    # no weight operand: x and ell are the entire input
    assert len(closed.jaxpr.invars) == 2
    # no weight-sized constants baked into the program
    for c in closed.consts:
        assert np.size(c) < nf * p, f"const of shape {np.shape(c)} smells like omega"
    nf_pad, n_pad, p_pad = 256, 1024, 128
    # stats + assembly (2N, 2N) blocks and the blocked input are the biggest
    # legitimate buffers; a materialized Sigma (2N_pad, n_pad) would exceed it
    limit = max(4 * nf_pad * nf_pad, p_pad * n_pad)

    def walk(jx):
        for eqn in jx.eqns:
            for v in eqn.outvars:
                size = int(np.prod(v.aval.shape)) if v.aval.shape else 1
                assert size <= limit, f"intermediate {v.aval.shape} exceeds fused bound"
        for sub in jax.core.subjaxprs(jx):
            walk(sub)

    walk(closed.jaxpr)
    assert 2 * nf_pad * n_pad > limit  # the bound would catch a materialized Sigma

    # the Pallas lowering is equally weightless: same 2-operand surface
    closed_p = jax.make_jaxpr(
        lambda a, e: rf.fused_streaming_gram(
            a, e, n_features=nf, seed=5, use_pallas=True, block=block
        )
    )(x, ell)
    assert len(closed_p.jaxpr.invars) == 2
    for c in closed_p.consts:
        assert np.size(c) < nf * p


@pytest.mark.parametrize("shape", [(512,), (512, 32), (7, 13), (1,), (1024, 5)])
@pytest.mark.parametrize("bits", [8, 4])
def test_fake_quant_kernel_matches_xla_twin(shape, bits):
    """Fused Pallas quantize/dequantize == jitted XLA twin, bitwise (the two
    receive identical uniforms, so stochastic rounding agrees exactly)."""
    key = jax.random.PRNGKey(sum(shape) + bits)
    x = jax.random.normal(key, shape)
    u = jax.random.uniform(jax.random.fold_in(key, 1), shape)
    got = ops.fake_quant(x, u, bits=bits)
    exp = jax.jit(lambda a, b: ref.fake_quant_ref(a, b, bits=bits))(x, u)
    assert jnp.array_equal(got, exp), float(jnp.abs(got - exp).max())


@pytest.mark.parametrize("bits", [8, 4])
def test_fake_quant_roundtrip_error_bound(bits):
    """Stochastic rounding moves each value by < one quantization step."""
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (256, 16)) * 5.0
    u = jax.random.uniform(jax.random.fold_in(key, 1), x.shape)
    out = ops.fake_quant(x, u, bits=bits)
    qmax = (1 << (bits - 1)) - 1
    step = float(jnp.abs(x).max()) / qmax
    assert float(jnp.abs(out - x).max()) <= step * (1 + 1e-6)


def test_fake_quant_zero_and_halfu_deterministic():
    """All-zero inputs survive exactly; u=0.5 gives round-to-nearest."""
    z = jnp.zeros((64,))
    assert jnp.array_equal(ops.fake_quant(z, jnp.full(z.shape, 0.5), bits=8), z)
    x = jnp.asarray([1.0, -1.0, 0.49, -0.49]) * 0.127
    u = jnp.full(x.shape, 0.5)
    out = ops.fake_quant(x, u, bits=8)  # scale = 0.001: nearest code per entry
    np.testing.assert_allclose(np.asarray(out), [0.127, -0.127, 0.062, -0.062], atol=1e-6)
