"""Launch-layer units: input specs, cache pspecs, shape registry, drivers."""
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch.specs import cache_pspecs, input_pspecs, input_specs
from repro.models import LM, ShardRules

RULES = ShardRules(model_size=16, batch_axes=("data",))


def test_input_shapes_registry():
    assert set(INPUT_SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert INPUT_SHAPES["train_4k"].kind == "train"
    assert INPUT_SHAPES["long_500k"].seq_len == 524_288
    assert INPUT_SHAPES["long_500k"].global_batch == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_input_specs_cover_model_inputs(arch, shape_name):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    # token/embedding input present
    assert ("tokens" in specs) != cfg.embeddings_in
    if shape.kind == "train":
        assert "labels" in specs
    if cfg.family == "vlm" and shape.kind != "decode":
        assert "images" in specs
    ps = input_pspecs(cfg, shape, RULES)
    assert set(ps) == set(specs)


def test_long500k_batch_not_sharded_but_cache_seq_is():
    cfg = get_config("command-r-plus-104b")
    import dataclasses

    cfg = dataclasses.replace(cfg, attn_window=4096)
    model = LM(cfg, RULES)
    shape = INPUT_SHAPES["long_500k"]
    cps = cache_pspecs(model, shape, RULES)
    k_spec = cps["layers"]["k"]
    assert k_spec[1] is None  # batch=1 can't shard
    assert k_spec[2] == "data"  # cache sequence context-parallel over data


def test_decode32k_batch_sharded():
    cfg = get_config("internlm2-1.8b")
    model = LM(cfg, RULES)
    cps = cache_pspecs(model, INPUT_SHAPES["decode_32k"], RULES)
    assert cps["layers"]["k"][1] == "data"
    assert cps["layers"]["k"][2] is None


def test_train_driver_reduced_loss_decreases():
    from repro.launch import train as train_mod

    out = train_mod.main(
        ["--arch", "smollm-135m", "--reduced", "--steps", "30", "--batch", "4",
         "--seq", "64", "--clients", "2", "--log-every", "30"]
    )
    assert out["last"] < out["first"] + 0.5  # noisy but sane


def test_mla_cache_is_compressed():
    """MLA decode cache must be (r + rope) per token, not kv*heads*hd."""
    cfg = get_config("deepseek-v2-lite-16b")
    model = LM(cfg, RULES)
    shapes = model.cache_shapes(1, 1000)
    per_tok_mla = shapes["layers"]["c"][-1] + shapes["layers"]["kr"][-1]
    per_tok_gqa = cfg.n_kv_heads * cfg.hd * 2
    assert per_tok_mla == cfg.kv_lora_rank + cfg.rope_head_dim  # 576
    assert per_tok_mla < per_tok_gqa / 7  # the MLA memory win
