"""Event-driven fedsim runtime: clock/queue determinism, availability-trace
replay, sync/async degeneracy against the batched engine, churn + staleness."""
import math

import jax
import numpy as np
import pytest

from repro.comm.netsim import LinkModel, LinkScenario, TraceScenario
from repro.data import make_domains
from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig, aggregation
from repro.federated.engine import unstack_tree
from repro.federated.network import RoundPlan
from repro.fedsim import (
    AsyncConfig,
    AsyncScheduler,
    ClientDeparted,
    ClientJoined,
    EventQueue,
    SyncScheduler,
    VirtualClock,
    always_on_trace,
    duty_cycle_trace,
    load_trace,
    markov_trace,
    save_trace,
)


@pytest.fixture(scope="module")
def small_setup():
    doms = make_domains(4, 120, shift=0.5, seed=1, dim=8, n_classes=3)
    cfg = ClientConfig(input_dim=8, n_classes=3, n_rff=32, m=8, extractor_widths=(16, 8))
    return doms[:3], doms[3], cfg


def _leaf_err(a, b):
    return max(
        float(np.abs(np.asarray(x) - np.asarray(y)).max())
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


def _full_trace(k, rounds):
    ids = list(range(k))
    return TraceScenario([RoundPlan(ids, ids, ids)] * rounds, cycle=True)


# ---- clock + queue ---------------------------------------------------------


def test_event_queue_fifo_at_equal_times():
    q = EventQueue()
    q.push(2.0, "late")
    q.push(1.0, "a")
    q.push(1.0, "b")
    q.push(1.0, "c")
    assert [q.pop() for _ in range(4)] == [(1.0, "a"), (1.0, "b"), (1.0, "c"), (2.0, "late")]
    with pytest.raises(ValueError, match="NaN"):
        q.push(float("nan"), "bad")


def test_virtual_clock_monotone():
    c = VirtualClock()
    c.advance_to(3.5)
    with pytest.raises(ValueError, match="backwards"):
        c.advance_to(3.0)
    assert c.now == 3.5


# ---- availability traces ---------------------------------------------------


def test_availability_semantics():
    tr = duty_cycle_trace(2, 10.0, period=4.0, on_fraction=0.5, stagger=False)
    assert tr.available(0, 0.0) and tr.available(0, 1.9)
    assert not tr.available(0, 2.5) and tr.available(0, 4.5)
    on = always_on_trace(3, 5.0)
    assert on.available_at(4.999) == [0, 1, 2]
    assert on.edges(0) == [(0.0, True)]  # no depart edge at the horizon


def test_markov_trace_churn_fraction_scales():
    calm = markov_trace(8, 2000.0, mean_on=30.0, mean_off=3.0, seed=0)
    churny = markov_trace(8, 2000.0, mean_on=5.0, mean_off=20.0, seed=0)
    up_calm = np.mean([calm.uptime(i) for i in range(8)]) / 2000.0
    up_churny = np.mean([churny.uptime(i) for i in range(8)]) / 2000.0
    assert up_calm > 0.8 > 0.5 > up_churny


def test_trace_json_roundtrip_bit_identical(tmp_path):
    tr = markov_trace(4, 321.5, mean_on=7.3, mean_off=2.1, seed=42)
    path = tmp_path / "churn.json"
    save_trace(tr, path)
    back = load_trace(path)
    assert back.horizon == tr.horizon
    assert back.intervals == tr.intervals  # exact float equality, not approx
    assert back.meta == tr.meta
    for i in range(4):
        assert back.edges(i) == tr.edges(i)


def test_trace_validation():
    with pytest.raises(ValueError, match="bad interval"):
        always_on_trace(1, 5.0).__class__(5.0, [[(3.0, 2.0)]])
    with pytest.raises(ValueError, match="overlapping"):
        always_on_trace(1, 5.0).__class__(5.0, [[(0.0, 3.0), (2.0, 4.0)]])


def test_touching_intervals_coalesce_no_phantom_churn():
    """A client online across an interval boundary must not emit a
    depart/join edge pair there (that would cancel its in-flight work)."""
    tr = duty_cycle_trace(2, 30.0, period=10.0, on_fraction=1.0)
    assert tr.intervals[0] == [(0.0, 30.0)]
    assert tr.edges(0) == [(0.0, True)]
    kls = always_on_trace(1, 20.0).__class__
    t2 = kls(20.0, [[(0.0, 5.0), (5.0, 8.0), (9.0, 20.0)]])
    assert t2.intervals[0] == [(0.0, 8.0), (9.0, 20.0)]
    assert t2.edges(0) == [(0.0, True), (8.0, False), (9.0, True)]


# ---- staleness weights -----------------------------------------------------


def test_staleness_weights_modes():
    s = np.array([0, 1, 3])
    assert np.allclose(aggregation.staleness_weights(s, "constant"), 1.0)
    poly = aggregation.staleness_weights(s, "polynomial")
    assert np.allclose(poly, (1.0 + s) ** -0.5)
    assert poly[0] == 1.0  # staleness 0 is exactly unit weight (degeneracy)
    steep = aggregation.staleness_weights(s, "polynomial:2.0")
    assert np.allclose(steep, (1.0 + s) ** -2.0)
    auto = aggregation.staleness_weights(s, "auto", n_samples=[100, 200, 300])
    assert np.allclose(auto, (1.0 + s) ** -0.5 * np.array([100, 200, 300]) / 200.0)
    with pytest.raises(ValueError, match="unknown staleness"):
        aggregation.staleness_weights(s, "exponential")
    with pytest.raises(ValueError, match="negative"):
        aggregation.staleness_weights([-1], "constant")


# ---- sync scheduler --------------------------------------------------------


def test_sync_scheduler_no_churn_matches_train(small_setup):
    sources, target, cfg = small_setup
    kw = dict(
        n_rounds=5, t_c=2, warmup_rounds=1, batch_size=32, seed=0,
        scenario=_full_trace(3, 5),
    )
    tr_a = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(**kw))
    tr_a.train()
    tr_b = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(**kw))
    hist = SyncScheduler(tr_b).run(5)
    assert _leaf_err(tr_a.tgt_params, tr_b.tgt_params) == 0.0
    assert _leaf_err(tr_a._src_stack, tr_b._src_stack) == 0.0
    assert tr_a.comm.total == tr_b.comm.total
    assert [h["t"] for h in hist] == [1.0, 2.0, 3.0, 4.0, 5.0]
    assert tr_b.model_version == 5 and (tr_b.client_versions == 5).all()


def test_sync_scheduler_drops_offline_clients(small_setup):
    sources, target, cfg = small_setup
    kw = dict(n_rounds=4, warmup_rounds=1, batch_size=32, seed=0, scenario=_full_trace(3, 4))
    tr = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(**kw))
    # client i online only during [i, i+1) of each 3s period: exactly one
    # client is online at each integer barrier time
    avail = duty_cycle_trace(3, 100.0, period=3.0, on_fraction=1 / 3)
    hist = SyncScheduler(tr, availability=avail).run(4)
    assert [h["participants"] for h in hist] == [1, 1, 1, 1]
    for leaf in jax.tree_util.tree_leaves(tr.tgt_params):
        assert np.isfinite(np.asarray(leaf)).all()


def test_sync_scheduler_edge_backhaul_leg(small_setup):
    """With edge_links the barrier waits out an explicit per-edge backhaul
    leg on top of the slowest member; without them (default None) behavior
    is bitwise identical to before the leg existed."""
    from repro.fleet import Topology

    sources, target, cfg = small_setup
    kw = dict(
        n_rounds=3, warmup_rounds=1, batch_size=32, seed=0,
        scenario=_full_trace(3, 3), topology=Topology.of_groups([[0, 1], [2]]),
    )
    links = [LinkModel(latency_s=0.5) for _ in range(3)]

    def run(edge_links=None):
        tr = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(**kw))
        sched = SyncScheduler(
            tr, links=LinkScenario(links=list(links)), edge_links=edge_links,
            compute_s=1.0,
        )
        hist = sched.run(3)
        return tr, [h["t"] for h in hist]

    tr_plain, t_plain = run()
    tr_edge, t_edge = run(LinkScenario(links=[LinkModel(latency_s=2.0),
                                              LinkModel(latency_s=0.25)]))
    # parameters are clock-independent: the leg only stretches virtual time
    assert _leaf_err(tr_plain.tgt_params, tr_edge.tgt_params) == 0.0
    # deterministic latencies: each round now ends at slowest member (1.5s)
    # plus the slow edge's 2s backhaul, instead of 1.5s flat
    assert t_plain == [1.5, 3.0, 4.5]
    assert t_edge == [3.5, 7.0, 10.5]

    tr = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(**kw))
    with pytest.raises(ValueError, match="edge links"):
        SyncScheduler(tr, edge_links=LinkScenario(links=[LinkModel()]))
    tr_flat = FedRFTCATrainer(
        sources, target, cfg,
        ProtocolConfig(**{**kw, "topology": None}),
    )
    with pytest.raises(ValueError, match="topology"):
        SyncScheduler(tr_flat, edge_links=LinkScenario(links=list(links)))


# ---- async scheduler: degeneracy ------------------------------------------


def test_async_degenerate_matches_batched_engine(small_setup):
    """The acceptance gate: uniform latencies, no churn, buffer_size=K must
    reproduce the batched sync engine's per-round parameters to <= 1e-6."""
    sources, target, cfg = small_setup
    k, rounds = 3, 6
    kw = dict(
        n_rounds=rounds, t_c=2, local_steps=2, warmup_rounds=2, batch_size=32,
        seed=0, scenario=_full_trace(k, rounds),
    )
    tr_sync = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(**kw))
    tr_sync.train()
    tr_async = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(**kw))
    links = LinkScenario(links=[LinkModel(latency_s=0.25) for _ in range(k)])
    sched = AsyncScheduler(
        tr_async, AsyncConfig(buffer_size=k, staleness="polynomial"), links=links
    )
    hist = sched.run(rounds)
    assert _leaf_err(tr_sync.tgt_params, tr_async.tgt_params) <= 1e-6
    for i in range(k):
        assert (
            _leaf_err(
                unstack_tree(tr_sync._src_stack, i), unstack_tree(tr_async._src_stack, i)
            )
            <= 1e-6
        )
    # every flush consumed a full fresh buffer, and the comm logs agree
    assert all(h["staleness"] == [0] * k for h in hist)
    assert all(h["weights"] == [1.0] * k for h in hist)
    assert (tr_sync.comm.data_messages, tr_sync.comm.w_rf, tr_sync.comm.classifier) == (
        tr_async.comm.data_messages, tr_async.comm.w_rf, tr_async.comm.classifier,
    )
    assert tr_sync.comm.bytes_by_kind == tr_async.comm.bytes_by_kind
    assert tr_async.model_version == rounds and (tr_async.client_versions == rounds).all()


def test_async_degenerate_matches_ragged_engine(small_setup):
    """Degeneracy must survive ragged per-client batch masks."""
    sources, target, cfg = small_setup
    from repro.data.domains import Domain

    ragged = [sources[0], Domain("s1", sources[1].x[:, :70], sources[1].y[:70]),
              Domain("s2", sources[2].x[:, :20], sources[2].y[:20])]
    kw = dict(
        n_rounds=4, t_c=2, warmup_rounds=1, batch_size=32, message_batch_size=64,
        seed=0, scenario=_full_trace(3, 4),
    )
    tr_sync = FedRFTCATrainer(ragged, target, cfg, ProtocolConfig(**kw))
    tr_sync.train()
    tr_async = FedRFTCATrainer(ragged, target, cfg, ProtocolConfig(**kw))
    AsyncScheduler(tr_async, AsyncConfig(buffer_size=3)).run(4)
    assert _leaf_err(tr_sync.tgt_params, tr_async.tgt_params) <= 1e-6
    assert _leaf_err(tr_sync._src_stack, tr_async._src_stack) <= 1e-6


# ---- async scheduler: genuinely asynchronous behavior ----------------------


def test_async_staleness_appears_with_heterogeneous_latency(small_setup):
    sources, target, cfg = small_setup
    kw = dict(n_rounds=0, t_c=3, warmup_rounds=1, batch_size=32, seed=0)
    tr = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(**kw))
    # client 2 is 5x slower: buffer-of-2 flushes consume its update late
    links = LinkScenario(links=[LinkModel(latency_s=1.0), LinkModel(latency_s=1.0),
                                LinkModel(latency_s=5.0)])
    sched = AsyncScheduler(tr, AsyncConfig(buffer_size=2, staleness="polynomial"), links=links)
    hist = sched.run(8)
    stale = [s for h in hist for s in h["staleness"]]
    assert max(stale) >= 1  # the slow client's updates really are stale
    slow_flushes = [h for h in hist if 2 in h["members"]]
    assert slow_flushes, "slow client's update must eventually be consumed"
    for h in slow_flushes[1:]:
        idx = h["members"].index(2)
        if h["staleness"][idx] > 0:
            assert h["weights"][idx] < 1.0  # polynomial discount really applied
    for leaf in jax.tree_util.tree_leaves(tr.tgt_params):
        assert np.isfinite(np.asarray(leaf)).all()


def test_async_churn_cancels_inflight_and_resumes(small_setup):
    sources, target, cfg = small_setup
    kw = dict(n_rounds=0, t_c=4, warmup_rounds=1, batch_size=32, seed=0)
    tr = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(**kw))
    avail = markov_trace(3, horizon=4000.0, mean_on=12.0, mean_off=6.0, seed=5)
    links = LinkScenario(
        links=[LinkModel(latency_s=0.5, jitter_s=0.2, drop=0.2) for _ in range(3)],
        backhaul_bps=1e4,
    )
    sched = AsyncScheduler(
        tr, AsyncConfig(buffer_size=2, staleness="auto"), availability=avail, links=links
    )
    hist = sched.run(12, eval_every=6)
    assert sched.flushes == 12
    assert sched.clock.now > 0 and math.isfinite(sched.clock.now)
    assert any("acc" in h for h in hist)
    for leaf in jax.tree_util.tree_leaves(tr.tgt_params):
        assert np.isfinite(np.asarray(leaf)).all()
    # the scheduler wires itself with the exact wire byte sizes of THIS
    # trainer's codecs without mutating the caller's scenario object
    assert sched.payload_bytes["moments"] == 2 * cfg.n_rff * 4 + 33  # header + CRC32
    assert links.payload_bytes == {}


def test_async_replay_from_saved_trace_is_identical(small_setup, tmp_path):
    """An availability trace loaded back from JSON must reproduce the run
    bit-for-bit: same flush schedule, same staleness, same parameters."""
    sources, target, cfg = small_setup
    kw = dict(n_rounds=0, t_c=3, warmup_rounds=1, batch_size=32, seed=0)
    avail = markov_trace(3, horizon=3000.0, mean_on=10.0, mean_off=4.0, seed=11)
    path = tmp_path / "trace.json"
    save_trace(avail, path)

    def run(trace):
        tr = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(**kw))
        links = LinkScenario(links=[LinkModel(latency_s=0.3 * (i + 1)) for i in range(3)])
        sched = AsyncScheduler(
            tr, AsyncConfig(buffer_size=2, staleness="polynomial"),
            availability=trace, links=links,
        )
        hist = sched.run(8)
        return tr, hist

    tr_a, hist_a = run(avail)
    tr_b, hist_b = run(load_trace(path))
    assert hist_a == hist_b
    assert _leaf_err(tr_a.tgt_params, tr_b.tgt_params) == 0.0
    assert _leaf_err(tr_a._src_stack, tr_b._src_stack) == 0.0


def test_async_buffer_size_one(small_setup):
    sources, target, cfg = small_setup
    kw = dict(n_rounds=0, warmup_rounds=1, batch_size=32, seed=0)
    tr = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(**kw))
    links = LinkScenario(links=[LinkModel(latency_s=float(i + 1)) for i in range(3)])
    sched = AsyncScheduler(tr, AsyncConfig(buffer_size=1), links=links)
    hist = sched.run(5)
    assert len(hist) == 5
    assert all(len(h["members"]) == 1 for h in hist)


def test_async_event_objects_are_well_typed():
    assert ClientJoined(2).client == 2
    assert ClientDeparted(1) != ClientJoined(1)


def test_async_validation(small_setup):
    sources, target, cfg = small_setup
    kw = dict(n_rounds=0, warmup_rounds=0, batch_size=32, seed=0)
    tr_serial = FedRFTCATrainer(
        sources, target, cfg, ProtocolConfig(engine="serial", **kw)
    )
    with pytest.raises(ValueError, match="batched engine"):
        AsyncScheduler(tr_serial, AsyncConfig(buffer_size=1))
    tr = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(**kw))
    with pytest.raises(ValueError, match="buffer_size"):
        AsyncScheduler(tr, AsyncConfig(buffer_size=7))
    with pytest.raises(ValueError, match="unknown staleness"):
        AsyncScheduler(tr, AsyncConfig(buffer_size=1, staleness="bogus"))
    with pytest.raises(ValueError, match="availability trace covers"):
        AsyncScheduler(tr, AsyncConfig(buffer_size=1), availability=always_on_trace(2, 10.0))
