"""Batched (vmap/scan) round engine vs the serial protocol plane."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import make_domains
from repro.data.domains import Domain
from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig
from repro.federated import network
from repro.federated.engine import stack_trees, unstack_tree
from repro.federated.network import RoundPlan


@pytest.fixture(scope="module")
def small_setup():
    doms = make_domains(4, 120, shift=0.5, seed=1, dim=8, n_classes=3)
    cfg = ClientConfig(input_dim=8, n_classes=3, n_rff=32, m=8, extractor_widths=(16, 8))
    return doms[:3], doms[3], cfg


@pytest.fixture(scope="module")
def ragged_setup():
    """Unequal per-client datasets: 120 / 70 / 20 samples (client 2 is shorter
    than both the training batch and the message batch)."""
    doms = make_domains(4, 120, shift=0.5, seed=1, dim=8, n_classes=3)
    sources = [
        doms[0],
        Domain("s1", doms[1].x[:, :70], doms[1].y[:70]),
        Domain("s2", doms[2].x[:, :20], doms[2].y[:20]),
    ]
    cfg = ClientConfig(input_dim=8, n_classes=3, n_rff=32, m=8, extractor_widths=(16, 8))
    return sources, doms[3], cfg


def _leaf_err(a, b):
    return max(
        float(np.abs(np.asarray(x) - np.asarray(y)).max())
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


def test_stack_unstack_roundtrip():
    trees = [{"a": jnp.arange(3.0) + i, "b": {"c": jnp.ones((2, 2)) * i}} for i in range(4)]
    stacked = stack_trees(trees)
    assert stacked["a"].shape == (4, 3)
    for i in range(4):
        assert _leaf_err(unstack_tree(stacked, i), trees[i]) == 0.0


def test_warmup_matches_serial(small_setup):
    """The scanned+vmapped warm-up reproduces the serial FedAvg loop exactly."""
    sources, target, cfg = small_setup
    kw = dict(n_rounds=0, warmup_rounds=3, batch_size=32, seed=0)
    tr_s = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(engine="serial", **kw))
    tr_b = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(engine="batched", **kw))
    assert _leaf_err(tr_s.tgt_params, tr_b.tgt_params) < 1e-5
    for i in range(len(sources)):
        assert _leaf_err(tr_s.src_params[i], tr_b._src_param(i)) < 1e-5


def test_full_participation_round_matches_serial(small_setup, monkeypatch):
    """With no drops both planes consume identical batches => identical params."""
    sources, target, cfg = small_setup
    k = len(sources)
    monkeypatch.setattr(
        network, "plan_round",
        lambda rng, n, s: RoundPlan(list(range(n)), list(range(n)), list(range(n))),
    )
    kw = dict(n_rounds=4, t_c=2, local_steps=2, warmup_rounds=1, batch_size=32, seed=0)
    tr_s = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(engine="serial", **kw))
    tr_s.train()
    tr_b = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(engine="batched", **kw))
    tr_b.train()
    assert _leaf_err(tr_s.tgt_params, tr_b.tgt_params) < 1e-4
    for i in range(k):
        assert _leaf_err(tr_s.src_params[i], tr_b._src_param(i)) < 1e-4
    assert tr_s.comm.total == tr_b.comm.total
    assert abs(tr_s.evaluate() - tr_b.evaluate()) < 1e-6


def test_drop_settings_and_comm_accounting_match_serial(small_setup):
    """Same plan rng => identical host-side communication logs on both planes."""
    sources, target, cfg = small_setup
    for setting in ("I", "II", "III"):
        kw = dict(
            n_rounds=5, t_c=2, warmup_rounds=1, batch_size=32,
            drop_setting=setting, seed=3,
        )
        tr_s = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(engine="serial", **kw))
        tr_s.train()
        tr_b = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(engine="batched", **kw))
        tr_b.train()
        assert (tr_s.comm.data_messages, tr_s.comm.w_rf, tr_s.comm.classifier) == (
            tr_b.comm.data_messages, tr_b.comm.w_rf, tr_b.comm.classifier,
        )


def test_ragged_full_participation_matches_serial(ragged_setup, monkeypatch):
    """Unequal per-client n_k, full participation: the batched plane pads to
    the max width + masks, and must match the serial plane exactly (the seed
    engine truncated every message batch to the min instead)."""
    sources, target, cfg = ragged_setup
    monkeypatch.setattr(
        network, "plan_round",
        lambda rng, n, s: RoundPlan(list(range(n)), list(range(n)), list(range(n))),
    )
    kw = dict(
        n_rounds=4, t_c=2, local_steps=2, warmup_rounds=2, batch_size=32,
        message_batch_size=64, seed=0,
    )
    tr_s = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(engine="serial", **kw))
    tr_s.train()
    tr_b = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(engine="batched", **kw))
    tr_b.train()
    # per-client sizes are capped at n_k, not truncated to the min
    assert tr_b._batch_sizes == [32, 32, 20]
    assert tr_b._msg_sizes == [64, 64, 20]
    assert tr_b._bmask is not None and tr_b._msg_mask is not None
    assert tr_b._bmask.shape == (3, 32) and tr_b._msg_mask.shape == (3, 64)
    assert _leaf_err(tr_s.tgt_params, tr_b.tgt_params) < 1e-4
    for i in range(len(sources)):
        assert _leaf_err(tr_s.src_params[i], tr_b._src_param(i)) < 1e-4
    assert tr_s.comm.total == tr_b.comm.total
    assert abs(tr_s.evaluate() - tr_b.evaluate()) < 1e-6


def test_ragged_drop_short_client_matches_serial(ragged_setup, monkeypatch):
    """Drop-mask correctness: with the short client dropped from S_t every
    round, its padded message batch must carry zero weight on both planes —
    masked moments + drop masks compose, trajectories still match."""
    sources, target, cfg = ragged_setup
    k = len(sources)
    monkeypatch.setattr(
        network, "plan_round",
        lambda rng, n, s: RoundPlan([0, 1], list(range(n)), list(range(n))),
    )
    kw = dict(
        n_rounds=3, t_c=2, local_steps=1, warmup_rounds=1, batch_size=32,
        message_batch_size=64, seed=0,
    )
    tr_s = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(engine="serial", **kw))
    tr_s.train()
    tr_b = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(engine="batched", **kw))
    tr_b.train()
    assert _leaf_err(tr_s.tgt_params, tr_b.tgt_params) < 1e-4
    for i in range(k):
        assert _leaf_err(tr_s.src_params[i], tr_b._src_param(i)) < 1e-4
    for leaf in jax.tree_util.tree_leaves(tr_b._src_stack):
        assert np.isfinite(np.asarray(leaf)).all()
    assert (tr_s.comm.data_messages, tr_s.comm.w_rf, tr_s.comm.classifier) == (
        tr_b.comm.data_messages, tr_b.comm.w_rf, tr_b.comm.classifier,
    )


def test_per_client_batch_size_sequences(ragged_setup):
    """ProtocolConfig accepts per-client batch-size sequences, capped at n_k."""
    sources, target, cfg = ragged_setup
    proto = ProtocolConfig(
        n_rounds=2, warmup_rounds=1, batch_size=(16, 24, 64),
        message_batch_size=(80, 40, 64), seed=0, engine="batched",
    )
    tr = FedRFTCATrainer(sources, target, cfg, proto)
    assert tr._batch_sizes == [16, 24, 20] and tr._msg_sizes == [80, 40, 20]
    tr.train()
    for leaf in jax.tree_util.tree_leaves(tr.tgt_params):
        assert np.isfinite(np.asarray(leaf)).all()
    with pytest.raises(ValueError, match="entries for"):
        FedRFTCATrainer(
            sources, target, cfg,
            ProtocolConfig(batch_size=(16, 24), engine="batched"),
        )


def test_equal_clients_keep_unmasked_path(small_setup):
    """Full-width clients must not pay the masked path: masks stay None so the
    compiled round is the seed program, bit-for-bit."""
    sources, target, cfg = small_setup
    proto = ProtocolConfig(n_rounds=1, warmup_rounds=0, batch_size=32, engine="batched")
    tr = FedRFTCATrainer(sources, target, cfg, proto)
    assert tr._bmask is None and tr._msg_mask is None


def test_batched_no_message_ablation(small_setup):
    sources, target, cfg = small_setup
    proto = ProtocolConfig(
        n_rounds=3, warmup_rounds=1, batch_size=32, exchange_messages=False,
        seed=0, engine="batched",
    )
    tr = FedRFTCATrainer(sources, target, cfg, proto)
    tr.train()
    assert tr.comm.data_messages == 0


def test_batched_hard_voting_eval(small_setup):
    sources, target, cfg = small_setup
    proto = ProtocolConfig(
        n_rounds=3, warmup_rounds=2, batch_size=32, aggregate_classifier=False,
        seed=0, engine="batched",
    )
    tr = FedRFTCATrainer(sources, target, cfg, proto)
    acc = tr.train(eval_every=3)
    assert 0.0 <= acc[-1] <= 1.0


def test_unknown_engine_rejected(small_setup):
    sources, target, cfg = small_setup
    with pytest.raises(ValueError, match="unknown engine"):
        FedRFTCATrainer(sources, target, cfg, ProtocolConfig(engine="turbo"))


def test_zero_sources_falls_back_to_serial(small_setup):
    """stack_trees([]) is impossible — K=0 must degrade to the serial plane."""
    _, target, cfg = small_setup
    proto = ProtocolConfig(n_rounds=2, warmup_rounds=1, batch_size=32, engine="batched")
    tr = FedRFTCATrainer([], target, cfg, proto)
    tr.train()
    assert tr.comm.rounds == 2 and tr.comm.total == 0
