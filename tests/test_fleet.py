"""Fleet-scale hierarchical federation: topology, two-tier-vs-flat exactness,
chunked/sharded client execution, the segment-reduce kernel, per-edge async
buffers, and server-ingress accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.netsim import LinkModel, LinkScenario, TraceScenario
from repro.data import make_domains
from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig
from repro.federated.aggregation import edge_weighted_sums
from repro.federated.network import RoundPlan
from repro.fedsim import AsyncConfig, AsyncScheduler
from repro.fleet import (
    Topology,
    chunked_vmap,
    client_mesh,
    edge_moment_merge,
    edge_param_merge,
    server_combine,
    sharded_client_map,
    working_set_proxy,
)
from repro.kernels import ops, ref


@pytest.fixture(scope="module")
def fleet_setup():
    """Four source clients (groupable 2x2) + one target."""
    doms = make_domains(5, 120, shift=0.5, seed=1, dim=8, n_classes=3)
    cfg = ClientConfig(input_dim=8, n_classes=3, n_rff=32, m=8, extractor_widths=(16, 8))
    return doms[:4], doms[4], cfg


def _leaf_err(a, b):
    return max(
        float(np.abs(np.asarray(x) - np.asarray(y)).max())
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


def _full_trace(k, rounds):
    ids = list(range(k))
    return TraceScenario([RoundPlan(ids, ids, ids)] * rounds, cycle=True)


# ---- topology ---------------------------------------------------------------


def test_topology_constructors_and_helpers():
    t = Topology.of_groups([[0, 2], [1, 3]])
    assert t.n_clients == 4 and t.n_edges == 2
    assert t.assignment == (0, 1, 0, 1)
    assert t.members(0) == [0, 2] and t.edge_of(3) == 1
    assert t.edges_of([2]) == [0] and t.edges_of([0, 1, 3]) == [0, 1]
    m = t.edge_matrix()
    assert m.shape == (2, 4) and m.sum() == 4.0
    assert (m[0] == [1, 0, 1, 0]).all()
    u = Topology.uniform(10, 3)
    assert u.n_edges == 3
    assert sorted(len(u.members(e)) for e in range(3)) == [3, 3, 4]
    assert Topology.singleton(3).assignment == (0, 1, 2)
    assert Topology.star(3).assignment == (0, 0, 0)


def test_topology_validation():
    with pytest.raises(ValueError, match="contiguous"):
        Topology((0, 2))  # edge 1 is empty
    with pytest.raises(ValueError, match="contiguous"):
        Topology((1, 2))
    with pytest.raises(ValueError, match="at least one"):
        Topology(())
    with pytest.raises(ValueError, match="assigned to edges"):
        Topology.of_groups([[0, 1], [1]])
    with pytest.raises(ValueError, match="empty"):
        Topology.of_groups([[0, 1], []])
    with pytest.raises(ValueError, match="n_edges"):
        Topology.uniform(4, 5)


# ---- segment-reduce kernel vs twin -----------------------------------------


@pytest.mark.parametrize("k,d,e", [(8, 16, 3), (128, 64, 4), (130, 70, 5), (1, 5, 1)])
def test_segment_reduce_kernel_matches_ref(k, d, e):
    rng = np.random.default_rng(k * 7 + d)
    vals = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)
    seg = jnp.asarray(rng.integers(0, e, size=(k,)), jnp.int32)
    w = jnp.asarray(rng.random(size=(k,)), jnp.float32)
    out = ops.segment_reduce(vals, seg, w, n_segments=e, interpret=True)
    want = ref.segment_reduce_ref(vals, seg, w, e)
    assert out.shape == (e, d)
    assert float(jnp.abs(out - want).max()) < 1e-5
    # zero-weight rows contribute exact zeros (the padding invariant)
    out0 = ops.segment_reduce(vals, seg, jnp.zeros((k,)), n_segments=e, interpret=True)
    assert float(jnp.abs(out0).max()) == 0.0


def test_segment_reduce_matches_segment_sum_oracle():
    rng = np.random.default_rng(3)
    vals = jnp.asarray(rng.normal(size=(40, 12)), jnp.float32)
    seg = jnp.asarray(rng.integers(0, 6, size=(40,)), jnp.int32)
    w = jnp.asarray(rng.random(size=(40,)), jnp.float32)
    out = ops.segment_reduce(vals, seg, w, n_segments=6, interpret=True)
    oracle = jax.ops.segment_sum(w[:, None] * vals, seg, num_segments=6)
    assert float(jnp.abs(out - oracle).max()) < 1e-5


# ---- hierarchical merge exactness (unit level) ------------------------------


def test_edge_param_merge_matches_flat_any_topology():
    """Associativity: sum of per-edge partial sums == the flat weighted sum,
    for arbitrary groupings and non-0/1 (staleness) weights."""
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.normal(size=(7, 6, 4)), jnp.float32)
    w = jnp.asarray(rng.random(size=(7,)), jnp.float32)
    flat = jnp.einsum("k,kij->ij", w, vals)
    for topo in (Topology.uniform(7, 3), Topology.singleton(7), Topology.star(7)):
        seg = jnp.asarray(topo.segment_ids)
        sums, mass = edge_param_merge(vals, w, seg, topo.n_edges)
        s, m = server_combine(sums, mass)
        assert float(jnp.abs(s - flat).max()) < 1e-5
        assert abs(float(m) - float(jnp.sum(w))) < 1e-5


def test_edge_moment_merge_pooling_semantics():
    """A singleton participant's pooled row is its message bit-for-bit; a
    multi-member edge's pooled row is the mass-weighted member mean (the
    Sigma-ell message of the pooled population)."""
    rng = np.random.default_rng(1)
    msgs = jnp.asarray(rng.normal(size=(4, 10)), jnp.float32)
    topo = Topology.of_groups([[0, 1], [2, 3]])
    seg = jnp.asarray(topo.segment_ids)
    # one participant per edge, unit weight: bitwise pass-through
    w = jnp.asarray([1.0, 0.0, 0.0, 1.0])
    pooled, mass = edge_moment_merge(msgs, w, seg, 2)
    assert (np.asarray(pooled[0]) == np.asarray(msgs[0])).all()
    assert (np.asarray(pooled[1]) == np.asarray(msgs[3])).all()
    assert np.allclose(np.asarray(mass), [1.0, 1.0])
    # full participation: pooled = member mean, mass = member count
    w = jnp.ones((4,))
    pooled, mass = edge_moment_merge(msgs, w, seg, 2)
    assert np.allclose(np.asarray(pooled[0]), np.asarray((msgs[0] + msgs[1]) / 2), atol=1e-6)
    assert np.allclose(np.asarray(mass), [2.0, 2.0])
    # empty edge: zero mass, finite pooled row
    w = jnp.asarray([1.0, 1.0, 0.0, 0.0])
    pooled, mass = edge_moment_merge(msgs, w, seg, 2)
    assert float(mass[1]) == 0.0 and np.isfinite(np.asarray(pooled)).all()


def test_edge_weighted_sums_jit_traceable():
    f = jax.jit(lambda v, s, w: edge_weighted_sums(v, s, w, 3))
    out = f(jnp.ones((5, 4)), jnp.asarray([0, 1, 2, 0, 1]), jnp.ones((5,)))
    assert np.allclose(np.asarray(out), [[2, 2, 2, 2], [2, 2, 2, 2], [1, 1, 1, 1]])


# ---- two-tier vs flat trainer trajectories ---------------------------------


def test_two_tier_singleton_matches_flat_engine(fleet_setup):
    """The acceptance gate: E=K identity-codec two-tier routes every merge
    through the hierarchy (segment sums, pooled moments, masses) and must
    reproduce the flat batched engine <= 1e-6."""
    sources, target, cfg = fleet_setup
    k, rounds = 4, 4
    kw = dict(
        n_rounds=rounds, t_c=2, local_steps=2, warmup_rounds=1, batch_size=32,
        seed=0, scenario=_full_trace(k, rounds),
    )
    tr_flat = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(**kw))
    tr_flat.train()
    tr_two = FedRFTCATrainer(
        sources, target, cfg, ProtocolConfig(topology=Topology.singleton(k), **kw)
    )
    tr_two.train()
    assert _leaf_err(tr_flat.tgt_params, tr_two.tgt_params) <= 1e-6
    assert _leaf_err(tr_flat._src_stack, tr_two._src_stack) <= 1e-6
    # tier-1 accounting identical; the ingress leg is E=K uplinks + masses
    assert tr_flat.comm.total == tr_two.comm.total


def test_two_tier_grouped_matches_flat_one_delivery_per_edge(fleet_setup):
    """Grouped edges, one moments-participant per edge each round, full W/C
    participation: the pooled moment degenerates to the single member's
    message while the W/classifier merges exercise real grouped partial sums
    — the trajectory must still match the flat engine <= 1e-6."""
    sources, target, cfg = fleet_setup
    k, rounds = 4, 4
    ids = list(range(k))
    plans = [RoundPlan([0, 2], ids, ids), RoundPlan([1, 3], ids, ids)] * (rounds // 2)
    kw = dict(
        n_rounds=rounds, t_c=2, local_steps=2, warmup_rounds=1, batch_size=32,
        seed=0, scenario=TraceScenario(plans, cycle=True),
    )
    tr_flat = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(**kw))
    tr_flat.train()
    topo = Topology.of_groups([[0, 1], [2, 3]])
    tr_two = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(topology=topo, **kw))
    tr_two.train()
    assert _leaf_err(tr_flat.tgt_params, tr_two.tgt_params) <= 1e-6
    assert _leaf_err(tr_flat._src_stack, tr_two._src_stack) <= 1e-6


def test_two_tier_full_participation_trains(fleet_setup):
    """Multi-member pooled moments: a different (union-population) but valid
    estimator — training must stay finite and evaluable, and the server
    ingress must count one merged uplink per edge, not per client."""
    sources, target, cfg = fleet_setup
    k, rounds = 4, 3
    topo = Topology.of_groups([[0, 1], [2, 3]])
    kw = dict(
        n_rounds=rounds, t_c=2, warmup_rounds=1, batch_size=32, seed=0,
        scenario=_full_trace(k, rounds),
    )
    tr = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(topology=topo, **kw))
    tr.train()
    for leaf in jax.tree_util.tree_leaves(tr.tgt_params):
        assert np.isfinite(np.asarray(leaf)).all()
    assert 0.0 <= tr.evaluate() <= 1.0
    # 2 active edges x 3 rounds per kind (classifier on t in {1, 2} ... t%2==0)
    assert tr.edge_transport.log.messages_by_kind["moments"] == 2 * rounds
    assert tr.edge_transport.log.messages_by_kind["w_rf"] == 2 * rounds


def test_server_ingress_two_tier_below_flat(fleet_setup):
    """At K=8 with 2 edges the ingress bytes must already shrink for the
    parameter payloads (the bench gates the K >= 64 full sweep)."""
    doms = make_domains(9, 60, shift=0.5, seed=2, dim=8, n_classes=3)
    sources, target = doms[:8], doms[8]
    cfg = ClientConfig(input_dim=8, n_classes=3, n_rff=32, m=8, extractor_widths=(16, 8))
    rounds = 2
    kw = dict(
        n_rounds=rounds, t_c=2, warmup_rounds=0, batch_size=16, seed=0,
        scenario=_full_trace(8, rounds),
    )
    tr_flat = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(**kw))
    tr_flat.train()
    tr_two = FedRFTCATrainer(
        sources, target, cfg, ProtocolConfig(topology=Topology.uniform(8, 2), **kw)
    )
    tr_two.train()
    assert sum(tr_two.ingress_bytes.values()) < sum(tr_flat.ingress_bytes.values())
    assert tr_two.ingress_bytes["w_rf"] < tr_flat.ingress_bytes["w_rf"]
    assert tr_two.ingress_bytes["moments"] < tr_flat.ingress_bytes["moments"]


def test_two_tier_edge_codec_distorts(fleet_setup):
    """A lossy tier-2 codec must change the trajectory (the edge uplink is
    really distorted) while identity tier-2 stays on the exact path."""
    sources, target, cfg = fleet_setup
    k, rounds = 4, 3
    topo = Topology.of_groups([[0, 1], [2, 3]])
    kw = dict(
        n_rounds=rounds, t_c=2, warmup_rounds=1, batch_size=32, seed=0,
        scenario=_full_trace(k, rounds), transport="wire",
    )
    tr_id = FedRFTCATrainer(
        sources, target, cfg, ProtocolConfig(topology=topo, **kw)
    )
    tr_id.train()
    tr_q = FedRFTCATrainer(
        sources, target, cfg,
        ProtocolConfig(topology=topo, edge_codec="qint8", **kw),
    )
    tr_q.train()
    assert _leaf_err(tr_id.tgt_params, tr_q.tgt_params) > 0.0
    for leaf in jax.tree_util.tree_leaves(tr_q.tgt_params):
        assert np.isfinite(np.asarray(leaf)).all()
    # the tier-2 log prices the edge uplinks at the edge codec: cheaper
    assert (
        tr_q.edge_transport.log.bytes_by_kind["w_rf"]
        < tr_id.edge_transport.log.bytes_by_kind["w_rf"]
    )


def test_fleet_protocol_validation(fleet_setup):
    sources, target, cfg = fleet_setup
    with pytest.raises(ValueError, match="batched engine"):
        FedRFTCATrainer(
            sources, target, cfg,
            ProtocolConfig(engine="serial", topology=Topology.singleton(4)),
        )
    with pytest.raises(ValueError, match="topology covers"):
        FedRFTCATrainer(
            sources, target, cfg, ProtocolConfig(topology=Topology.singleton(3))
        )
    with pytest.raises(ValueError, match="seed_replay"):
        FedRFTCATrainer(
            sources, target, cfg,
            ProtocolConfig(topology=Topology.singleton(4), edge_codec="seed_replay"),
        )


# ---- chunked + sharded client execution ------------------------------------


def test_chunked_vmap_bitwise_and_padding():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(5, 6, 4)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(5, 4, 3)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(4,)), jnp.float32)

    def f(xi, wi, ci):
        z = jnp.tanh(xi @ wi)
        return z.sum(-1) + (xi @ ci).sum(), z

    want = jax.vmap(f, (0, 0, None))(x, w, c)
    for chunk in (2, 3, 5, 9, None):  # 5 % 2 and 5 % 3 != 0: padding path
        got = chunked_vmap(f, (0, 0, None), chunk=chunk)(x, w, c)
        for a, b in zip(jax.tree_util.tree_leaves(want), jax.tree_util.tree_leaves(got)):
            assert (np.asarray(a) == np.asarray(b)).all()
    with pytest.raises(ValueError, match="chunk must be"):
        chunked_vmap(f, (0, 0, None), chunk=0)
    with pytest.raises(ValueError, match="at least one mapped"):
        chunked_vmap(lambda a: a, (None,), chunk=2)(c)


def test_sharded_client_map_mocked_mesh_bitwise():
    """shard_map over a clients mesh (mocked: 1 device) + chunked scan must
    equal the plain vmap bit-for-bit."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(8, 6, 4)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(8, 4, 3)), jnp.float32)

    def f(xi, wi):
        return jnp.tanh(xi @ wi).sum(-1)

    mesh = client_mesh(1)
    want = jax.vmap(f, (0, 0))(x, w)
    got = jax.jit(sharded_client_map(mesh, f, (0, 0), chunk=4))(x, w)
    assert (np.asarray(want) == np.asarray(got)).all()


def test_client_chunk_trainer_matches_unchunked(fleet_setup):
    """The chunked local-step scan through the full trainer: <= 1e-6 of the
    unchunked trajectory (bitwise at the local-step granularity; whole-round
    XLA fusion differs by ulps once the surrounding graph changes)."""
    sources, target, cfg = fleet_setup
    k, rounds = 4, 3
    kw = dict(
        n_rounds=rounds, t_c=2, warmup_rounds=1, batch_size=32, seed=0,
        scenario=_full_trace(k, rounds),
    )
    tr_a = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(**kw))
    tr_a.train()
    tr_b = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(client_chunk=2, **kw))
    tr_b.train()
    assert _leaf_err(tr_a.tgt_params, tr_b.tgt_params) <= 1e-6
    assert _leaf_err(tr_a._src_stack, tr_b._src_stack) <= 1e-6


def test_working_set_proxy_bounded_by_chunk():
    rng = np.random.default_rng(2)
    k, b, p, h = 32, 16, 12, 10
    x = jnp.asarray(rng.normal(size=(k, b, p)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, p, h)), jnp.float32)

    def f(xi, wi):
        return jnp.tanh(xi @ wi).sum(-1)

    full = working_set_proxy(lambda *a: jax.vmap(f, (0, 0))(*a), x, w)
    prev = 0
    for chunk in (2, 4, 8):
        ws = working_set_proxy(chunked_vmap(f, (0, 0), chunk=chunk), x, w)
        assert ws == full * chunk // k  # exactly linear in the chunk
        assert ws > prev
        prev = ws


# ---- async runtime: per-edge buffers + backhaul ----------------------------


def test_async_singleton_topology_matches_flat_buffer_one(fleet_setup):
    """Per-edge buffers degenerate correctly: E=K edges with buffer_size=1
    flush exactly like the flat scheduler with buffer_size=1."""
    sources, target, cfg = fleet_setup
    k = 4
    kw = dict(n_rounds=0, t_c=3, warmup_rounds=1, batch_size=32, seed=0)
    links = LinkScenario(links=[LinkModel(latency_s=float(i + 1)) for i in range(k)])

    def run(topology):
        tr = FedRFTCATrainer(
            sources, target, cfg, ProtocolConfig(topology=topology, **kw)
        )
        sched = AsyncScheduler(
            tr, AsyncConfig(buffer_size=1, staleness="constant"),
            links=LinkScenario(links=list(links.links)),
        )
        hist = sched.run(6)
        return tr, hist

    tr_flat, h_flat = run(None)
    tr_two, h_two = run(Topology.singleton(k))
    assert [h["members"] for h in h_flat] == [h["members"] for h in h_two]
    assert [h["t"] for h in h_flat] == [h["t"] for h in h_two]
    assert _leaf_err(tr_flat.tgt_params, tr_two.tgt_params) <= 1e-6
    assert _leaf_err(tr_flat._src_stack, tr_two._src_stack) <= 1e-6


def test_async_edges_flush_their_own_buffers(fleet_setup):
    """Grouped topology: every flush consumes members of exactly one edge."""
    sources, target, cfg = fleet_setup
    topo = Topology.of_groups([[0, 1], [2, 3]])
    kw = dict(n_rounds=0, t_c=3, warmup_rounds=1, batch_size=32, seed=0)
    tr = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(topology=topo, **kw))
    links = LinkScenario(
        links=[LinkModel(latency_s=0.5 + 0.3 * i, jitter_s=0.1) for i in range(4)]
    )
    sched = AsyncScheduler(tr, AsyncConfig(buffer_size=2), links=links)
    hist = sched.run(6)
    assert len(hist) == 6
    for h in hist:
        edges = {topo.edge_of(c) for c in h["members"]}
        assert len(edges) == 1  # one edge's buffer per flush
    for leaf in jax.tree_util.tree_leaves(tr.tgt_params):
        assert np.isfinite(np.asarray(leaf)).all()


def test_async_edge_links_delay_flushes(fleet_setup):
    """A slow backhaul defers the server flush past the edge-buffer fill time
    and shows up in the flush timestamps."""
    sources, target, cfg = fleet_setup
    topo = Topology.of_groups([[0, 1], [2, 3]])
    kw = dict(n_rounds=0, t_c=3, warmup_rounds=1, batch_size=32, seed=0)

    def run(edge_links):
        tr = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(topology=topo, **kw))
        sched = AsyncScheduler(
            tr, AsyncConfig(buffer_size=2),
            links=LinkScenario(links=[LinkModel(latency_s=1.0) for _ in range(4)]),
            edge_links=edge_links,
        )
        return sched.run(4)

    h_fast = run(None)
    h_slow = run(LinkScenario(links=[LinkModel(latency_s=7.0) for _ in range(2)]))
    # every flush waits out at least one 7 s backhaul crossing (and later
    # flushes compound it, since members redispatch only after the flush)
    assert all(hs["t"] >= hf["t"] + 7.0 for hs, hf in zip(h_slow, h_fast))
    assert h_slow[0]["t"] == h_fast[0]["t"] + 7.0
    assert [h["members"] for h in h_slow] == [h["members"] for h in h_fast]


def test_async_fleet_validation(fleet_setup):
    sources, target, cfg = fleet_setup
    kw = dict(n_rounds=0, warmup_rounds=0, batch_size=32, seed=0)
    topo = Topology.of_groups([[0, 1, 2], [3]])
    tr = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(topology=topo, **kw))
    with pytest.raises(ValueError, match="smallest edge"):
        AsyncScheduler(tr, AsyncConfig(buffer_size=2))  # edge 1 has one member
    tr_flat = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(**kw))
    with pytest.raises(ValueError, match="edge_links need"):
        AsyncScheduler(
            tr_flat, AsyncConfig(buffer_size=1),
            edge_links=LinkScenario(links=[LinkModel()]),
        )
    with pytest.raises(ValueError, match="edge links for"):
        AsyncScheduler(
            tr, AsyncConfig(buffer_size=1),
            edge_links=LinkScenario(links=[LinkModel()]),
        )
    with pytest.raises(ValueError, match="eval_interval"):
        AsyncScheduler(tr_flat, AsyncConfig(buffer_size=1, eval_interval=0.0))


def test_async_eval_interval_ticks(fleet_setup):
    """Time-triggered eval events: dense accuracy-vs-virtual-time rows at the
    configured cadence, interleaved with (not replacing) the flush rows."""
    sources, target, cfg = fleet_setup
    kw = dict(n_rounds=0, t_c=3, warmup_rounds=1, batch_size=32, seed=0)
    tr = FedRFTCATrainer(sources, target, cfg, ProtocolConfig(**kw))
    links = LinkScenario(links=[LinkModel(latency_s=float(i + 1)) for i in range(4)])
    sched = AsyncScheduler(
        tr, AsyncConfig(buffer_size=2, eval_interval=1.5), links=links
    )
    hist = sched.run(5)
    evals = [h for h in hist if "eval" in h]
    flushes = [h for h in hist if "flush" in h]
    assert len(flushes) == 5
    assert len(evals) >= 2
    assert all(0.0 <= h["acc"] <= 1.0 for h in evals)
    times = [h["t"] for h in evals]
    assert times == sorted(times)
    assert all(abs(t - 1.5 * h["eval"]) < 1e-9 for t, h in zip(times, evals))
    # history rows overall are time-ordered
    all_t = [h["t"] for h in hist]
    assert all_t == sorted(all_t)
