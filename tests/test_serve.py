"""repro.serve: model store, batching dispatcher, live admission, load gen.

Coverage required by the subsystem's contracts:
- store: LRU eviction at capacity (hit/miss/eviction counters), version-
  tagged invalidation (bump drops older versions; pinned readers miss);
- dispatcher: bucketed padding masks leave pad columns as exact zeros and
  per-request slices match the direct transform; one jit trace per bucket
  rung (sentinel-gated);
- admission: refit-free (no cached version changes), the admitted client's
  aligner agrees with a from-scratch fit to <= 1e-3, and the moment merge
  tracks the true u statistic; the wire really carries CRC frames;
- memoized fused omega: repeated serving regenerates draw-0 exactly once;
- load generator: deterministic Poisson schedule, open-loop completion;
- telemetry off vs on: served arrays bitwise identical (PR-7 contract).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.transport import WireTransport, resolve_codecs
from repro.core.rf_tca import (
    RFTCAState,
    fused_omega_cache_info,
    rf_tca_fit,
    rf_tca_transform,
)
from repro.obs import (
    DriftMonitor,
    MetricsRegistry,
    RequestTracer,
    Slo,
    SloEngine,
    Tracer,
    count_request_trees,
    sentinel,
    use_registry,
    use_tracer,
)
from repro.serve import (
    AdmissionGateway,
    AlignerServer,
    ModelStore,
    MomentStats,
    Request,
    StoreEntry,
    poisson_arrivals,
    run_open_loop,
    synth_requests,
)

DIM = 8
FIT_KW = dict(n_features=16, m=4, seed=0)


def _domain(seed, n=90, shift=0.7):
    rng = np.random.default_rng(seed)
    xs = rng.standard_normal((DIM, n)).astype(np.float32)
    xt = (rng.standard_normal((DIM, n - 7)) + shift).astype(np.float32)
    return xs, xt


def _server(capacity=4, **kw):
    return AlignerServer(capacity=capacity, min_bucket=4, max_bucket=32, **kw)


def _entry(seed=0):
    xs, xt = _domain(seed)
    return StoreEntry(state=rf_tca_fit(jnp.asarray(xs), jnp.asarray(xt), **FIT_KW))


# ---- model store ------------------------------------------------------------


def test_store_lru_eviction_at_capacity():
    store = ModelStore(capacity=2)
    for i in range(3):
        store.put(("s", f"t{i}"), _entry(i))
    assert len(store) == 2
    assert store.evictions == 1
    # pair 0 was least recently used -> evicted; its latest pointer is gone
    assert store.get(("s", "t0")) is None
    assert store.latest_version(("s", "t0")) is None
    assert store.get(("s", "t1")) is not None and store.get(("s", "t2")) is not None
    assert store.hits == 2 and store.misses == 1
    assert 0.0 <= store.hit_rate <= 1.0
    # a get refreshes recency: t1 survives the next insertion, t2 does not
    store.get(("s", "t1"))
    store.put(("s", "t3"), _entry(3))
    assert store.get(("s", "t1")) is not None
    assert store.get(("s", "t2")) is None


def test_store_version_invalidation():
    store = ModelStore(capacity=4)
    v0 = store.put(("a", "b"), _entry(0))
    assert v0 == 0
    # plain put overwrites the latest version (no invalidation)
    assert store.put(("a", "b"), _entry(1)) == 0
    assert store.invalidations == 0
    # bump stores latest+1 and drops the older version
    v1 = store.put(("a", "b"), _entry(2), bump=True)
    assert v1 == 1 and store.latest_version(("a", "b")) == 1
    assert store.invalidations == 1 and len(store) == 1
    # a reader pinned to the invalidated version misses, never goes stale
    assert store.get(("a", "b"), version=0) is None
    assert store.get(("a", "b"), version=1) is not None
    assert store.get(("a", "b")) is not None  # None -> newest
    # codecs are independent key spaces
    assert store.put(("a", "b"), _entry(3), codec="qint8") == 0
    assert store.latest_version(("a", "b"), "qint8") == 0
    assert store.latest_version(("a", "b")) == 1
    with pytest.raises(ValueError, match="capacity"):
        ModelStore(capacity=0)


# ---- batching dispatcher ----------------------------------------------------


def test_dispatcher_buckets_and_masked_padding():
    srv = _server()
    xs, xt = _domain(4)
    srv.fit_domain(("s", "t"), xs, xt, **FIT_KW)
    entry = srv.store.get(("s", "t"))
    assert srv.dispatcher.bucket_for(1) == 4
    assert srv.dispatcher.bucket_for(5) == 8
    assert srv.dispatcher.bucket_for(999) == 32  # clamped to the ladder top
    # ragged widths across one burst: results must match the direct transform
    rng = np.random.default_rng(7)
    reqs = [
        Request(x=rng.standard_normal((DIM, n)).astype(np.float32), key=("s", "t"))
        for n in (3, 5, 2, 7)
    ]
    done = srv.serve(reqs)
    assert len(done) == 4
    for req, out in done:
        ref = np.asarray(rf_tca_transform(entry.state, jnp.asarray(req.x)))
        assert out.shape == ref.shape
        np.testing.assert_allclose(out, ref, atol=1e-5)
    # a request wider than the top rung cannot be served in one dispatch
    srv.dispatcher.submit(Request(x=np.zeros((DIM, 33), np.float32), key=("s", "t")))
    with pytest.raises(ValueError, match="max_bucket"):
        srv.dispatcher.flush(entry)


def test_dispatcher_one_trace_per_bucket():
    srv = _server()
    xs, xt = _domain(5)
    srv.fit_domain(("s", "t"), xs, xt, **FIT_KW)
    before = sentinel.counts()
    srv.warmup(("s", "t"))  # compiles rungs 4, 8, 16, 32 exactly once each
    rng = np.random.default_rng(8)
    for n in (3, 4, 2, 7, 8, 20, 31, 1):  # re-hits every rung
        srv.serve([Request(x=rng.standard_normal((DIM, n)).astype(np.float32),
                           key=("s", "t"))])
    planes = tuple(f"serve.transform.b{b}" for b in (4, 8, 16, 32))
    sentinel.assert_stable(before, planes, expect=1)


def test_dispatcher_predict_mode():
    srv = _server()
    xs, xt = _domain(6)
    rng = np.random.default_rng(9)
    clf = {"w": rng.standard_normal((4, 3)).astype(np.float32),
           "b": rng.standard_normal(3).astype(np.float32)}
    srv.fit_domain(("s", "t"), xs, xt, classifier=clf, **FIT_KW)
    entry = srv.store.get(("s", "t"))
    x = rng.standard_normal((DIM, 5)).astype(np.float32)
    (req, logits), = srv.serve([Request(x=x, key=("s", "t"), mode="predict")])
    aligned = np.asarray(rf_tca_transform(entry.state, jnp.asarray(x)))
    ref = clf["w"].T @ aligned + clf["b"][:, None]
    np.testing.assert_allclose(logits, ref, atol=1e-5)
    with pytest.raises(ValueError, match="mode"):
        Request(x=x, mode="align")


# ---- live admission ---------------------------------------------------------


def test_admission_refit_free_and_matches_refit():
    srv = _server()
    xs, xt = _domain(10)
    srv.fit_domain(("s", "t"), xs, xt, **FIT_KW)
    v_before = srv.store.latest_version(("s", "t"))
    entry = srv.store.get(("s", "t"))
    rng = np.random.default_rng(11)
    x_new = rng.standard_normal((DIM, 40)).astype(np.float32)
    res = srv.admit(("s", "t"), x_new, role="source", sender=3)
    assert res.delivered and res.version == v_before
    # refit-free: no cached version changed, no refit ran
    assert srv.store.latest_version(("s", "t")) == v_before
    assert srv.refits == 0
    # stats are seeded with the fit moments (90 source cols) + the admission
    assert entry.stats.admitted == 1 and entry.stats.n_source == 90 + 40
    # the wire really carried both legs (CRC-framed bytes, no rejects)
    assert res.bytes_up > 0 and res.bytes_down > res.bytes_up
    # the admitted client's aligner agrees with a from-scratch fit <= 1e-3
    probe = rng.standard_normal((DIM, 13)).astype(np.float32)
    scratch = rf_tca_fit(jnp.asarray(xs), jnp.asarray(xt),
                         w_rf=f"fused:{srv.fused_seed}", **FIT_KW)
    got = np.asarray(rf_tca_transform(res.state, jnp.asarray(probe)))
    want = np.asarray(rf_tca_transform(scratch, jnp.asarray(probe)))
    assert float(np.max(np.abs(got - want))) <= 1e-3
    # and the served state never shipped omega: it is fused, re-derived
    assert res.state.omega is None and res.state.fused is not None


def test_admission_moment_merge_tracks_u():
    """Merging per-client moments incrementally equals the pooled statistic."""
    stats = MomentStats()
    rng = np.random.default_rng(12)
    chunks = [rng.standard_normal((16, n)) for n in (10, 25, 5)]
    for c in chunks:
        stats.merge(np.mean(c, axis=1), c.shape[1], role="source")
    tgt = rng.standard_normal((16, 30))
    stats.merge(-np.mean(tgt, axis=1), 30, role="target")
    pooled = np.mean(np.concatenate(chunks, axis=1), axis=1) - np.mean(tgt, axis=1)
    np.testing.assert_allclose(stats.u, pooled, atol=1e-12)
    assert stats.admitted == 4 and stats.n_source == 40 and stats.n_target == 30
    with pytest.raises(ValueError, match="role"):
        stats.merge(np.zeros(16), 1, role="both")
    with pytest.raises(ValueError, match="n_samples"):
        stats.merge(np.zeros(16), 0)


def test_admission_requires_fused_state_and_rejects_seed_replay():
    store = ModelStore()
    with pytest.raises(ValueError, match="seed_replay"):
        AdmissionGateway(store, transport=WireTransport(
            resolve_codecs("float32", w_rf="seed_replay")))
    # an omega-materialized state cannot be admitted against
    xs, xt = _domain(13)
    state = rf_tca_fit(jnp.asarray(xs), jnp.asarray(xt), **FIT_KW)
    assert state.fused is None
    srv = _server()
    srv.fit_domain(("s", "t"), xs, xt, w_rf=None, **FIT_KW)
    with pytest.raises(ValueError, match="fused"):
        srv.admit(("s", "t"), xs[:, :5])
    with pytest.raises(KeyError, match="fit_domain"):
        srv.get_or_fit(("never", "fitted"))


def test_fused_omega_memoized_across_serving():
    srv = _server()
    xs, xt = _domain(14)
    srv.fit_domain(("s", "t"), xs, xt, **FIT_KW)
    srv.warmup(("s", "t"))
    regen_before = fused_omega_cache_info()["regenerations"]
    rng = np.random.default_rng(15)
    for _ in range(6):
        srv.serve([Request(x=rng.standard_normal((DIM, 5)).astype(np.float32),
                           key=("s", "t"))])
    # the serving hot path hits the memo: zero regenerations after warmup
    assert fused_omega_cache_info()["regenerations"] == regen_before


# ---- load generator ---------------------------------------------------------


def test_loadgen_poisson_deterministic_and_open_loop():
    a1 = poisson_arrivals(100.0, 50, seed=3)
    a2 = poisson_arrivals(100.0, 50, seed=3)
    np.testing.assert_array_equal(a1, a2)
    assert np.all(np.diff(a1) > 0) and a1.shape == (50,)
    with pytest.raises(ValueError, match="rate"):
        poisson_arrivals(0.0, 5, seed=0)

    srv = _server()
    xs, xt = _domain(16)
    srv.fit_domain(("s", "t"), xs, xt, **FIT_KW)
    srv.warmup(("s", "t"))
    reqs = synth_requests([("s", "t")], dim=DIM, n_requests=40, seed=4,
                          cols_lo=2, cols_hi=8)
    res = run_open_loop(srv, reqs, rate=300.0, seed=5)
    summary = res.summary()
    assert summary["completed"] == 40  # open loop: every arrival is served
    assert summary["p99_ms"] >= summary["p50_ms"] > 0
    assert summary["throughput_rps"] > 0 and res.batches >= 1
    assert all(lat > 0 for lat in res.latencies.values())
    # the request mix is a pure function of the seed
    r1 = synth_requests([("s", "t")], dim=DIM, n_requests=5, seed=4)
    r2 = synth_requests([("s", "t")], dim=DIM, n_requests=5, seed=4)
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(a.x, b.x)
        assert a.key == b.key


def test_loadgen_cache_misses_under_many_pairs():
    """More pairs than store capacity: the load run survives in-path refits
    and the store reports a sub-unit hit rate."""
    srv = _server(capacity=2)
    pairs = [("s", f"t{i}") for i in range(3)]
    for i, pair in enumerate(pairs):
        xs, xt = _domain(20 + i)
        srv.fit_domain(pair, xs, xt, **FIT_KW)
    reqs = synth_requests(pairs, dim=DIM, n_requests=30, seed=6, cols_lo=2, cols_hi=6)
    res = run_open_loop(srv, reqs, rate=200.0, seed=7)
    assert res.summary()["completed"] == 30
    assert srv.refits > 0
    assert 0.0 < srv.store.hit_rate < 1.0


# ---- telemetry off vs on: bitwise degeneracy --------------------------------


def test_serve_telemetry_off_on_bitwise_identical():
    def run():
        srv = _server()
        xs, xt = _domain(30)
        srv.fit_domain(("s", "t"), xs, xt, **FIT_KW)
        reqs = synth_requests([("s", "t")], dim=DIM, n_requests=8, seed=8,
                              cols_lo=2, cols_hi=8)
        outs = [out for _, out in srv.serve(reqs)]
        adm = srv.admit(("s", "t"), xs[:, :11], role="source")
        outs.append(np.asarray(adm.state.w_rf))
        return outs

    plain = run()
    with use_registry(MetricsRegistry()), use_tracer(Tracer()):
        instrumented = run()
    for a, b in zip(plain, instrumented):
        np.testing.assert_array_equal(a, b)


# ---- request-level observability --------------------------------------------


def test_serve_observability_off_compiles_no_probe_planes():
    """Zero-overhead-off: without an attached drift monitor the dispatcher
    never touches the probed plane variants, and attaching a request tracer
    + SLO engine with no ambient tracer/registry leaves both the compiled
    planes and the served arrays bitwise untouched."""
    def outputs(srv):
        xs, xt = _domain(40)
        srv.fit_domain(("s", "t"), xs, xt, **FIT_KW)
        reqs = synth_requests([("s", "t")], dim=DIM, n_requests=10, seed=9,
                              cols_lo=2, cols_hi=8)
        return [out for _, out in srv.serve(reqs)]

    before = sentinel.counts()
    plain = outputs(_server(sentinel_prefix="off1"))
    srv2 = _server(sentinel_prefix="off2")
    srv2.attach(request_tracer=RequestTracer(rate=1.0), slo=SloEngine(
        [Slo("serve.latency", target=0.9, bound=1.0, window_fast_s=1.0,
             window_slow_s=4.0)]))
    wired = outputs(srv2)
    after = sentinel.counts()
    for a, b in zip(plain, wired):
        np.testing.assert_array_equal(a, b)
    probe_planes = [k for k, v in after.items()
                    if ".probe" in k and v > before.get(k, 0)]
    assert probe_planes == []  # telemetry off: plain planes only
    assert srv2.reqtrace.sampled_total == 0  # no ambient tracer -> declined


def test_serve_drift_probe_planes_trace_once_and_stay_bitwise():
    srv = _server(sentinel_prefix="dr1")
    xs, xt = _domain(41)
    srv.fit_domain(("s", "t"), xs, xt, **FIT_KW)
    srv.attach(drift=DriftMonitor(window=1, threshold=1e9))
    reqs = synth_requests([("s", "t")], dim=DIM, n_requests=10, seed=10,
                          cols_lo=2, cols_hi=8)
    before = sentinel.counts()
    srv.warmup(("s", "t"))
    done = srv.serve(reqs)
    planes = tuple(f"dr1.transform.b{b}.probe" for b in (4, 8, 16, 32))
    sentinel.assert_stable(before, planes, expect=1)
    # the probed planes' primary outputs are bitwise the direct transform
    entry = srv.store.get(("s", "t"))
    for req, out in done:
        ref = np.asarray(rf_tca_transform(entry.state, jnp.asarray(req.x)))
        np.testing.assert_array_equal(out, ref)
    assert srv.drift.pairs() == [("s", "t")]


def test_serve_auto_refresh_on_drift_alert():
    rng = np.random.default_rng(42)
    clf = {"w": rng.standard_normal((4, 3)).astype(np.float32)}
    srv = _server(sentinel_prefix="dr2")
    xs, xt = _domain(43)
    srv.fit_domain(("s", "t"), xs, xt, classifier=clf, **FIT_KW)
    srv.attach(drift=DriftMonitor(alpha=1.0, window=1, k_consecutive=1,
                                  threshold=0.02))
    srv.admit(("s", "t"), xs[:, :9], role="source")
    assert srv.store.get(("s", "t")).stats.admitted == 1
    v0 = srv.store.latest_version(("s", "t"))
    # a shifted request: the first probed window crosses the threshold,
    # fires, and triggers exactly one moment-space refresh + version bump
    x_shift = (rng.standard_normal((DIM, 20)) + 3.0).astype(np.float32)
    for _ in range(4):  # the same post-drift distribution, re-served
        srv.virtual_now += 0.01
        srv.serve([Request(x=x_shift, key=("s", "t"))])
    assert srv.drift.fires == 1 and srv.moment_refreshes == 1
    assert srv.store.latest_version(("s", "t")) == v0 + 1
    entry = srv.store.get(("s", "t"))
    assert entry.classifier is clf  # carried across the refresh
    assert entry.stats.admitted == 0  # staleness counter reset
    # the reference re-pinned to the live moment: detection re-armed, so the
    # continued (now in-distribution) stream never re-fires
    rec = srv.drift.history[-1]
    assert not rec.fired and rec.mmd < srv.drift.pair_threshold(("s", "t"))


def test_loadgen_service_scale_validation_and_field():
    srv = _server()
    xs, xt = _domain(44)
    srv.fit_domain(("s", "t"), xs, xt, **FIT_KW)
    srv.warmup(("s", "t"))
    reqs = synth_requests([("s", "t")], dim=DIM, n_requests=10, seed=12,
                          cols_lo=2, cols_hi=6)
    res = run_open_loop(srv, reqs, rate=200.0, seed=13, service_scale=2.5)
    assert res.summary()["service_scale"] == 2.5
    assert res.summary()["completed"] == 10
    for bad in (0.0, -1.0, float("inf"), float("nan")):
        with pytest.raises(ValueError, match="service_scale"):
            run_open_loop(srv, reqs, rate=200.0, seed=13, service_scale=bad)


def test_loadgen_emits_request_trees_under_live_tracer():
    srv = _server()
    xs, xt = _domain(45)
    srv.fit_domain(("s", "t"), xs, xt, **FIT_KW)
    srv.attach(request_tracer=RequestTracer(rate=1.0))
    srv.warmup(("s", "t"))
    reqs = synth_requests([("s", "t")], dim=DIM, n_requests=7, seed=14,
                          cols_lo=2, cols_hi=8)
    tracer = Tracer()
    with use_tracer(tracer):
        run_open_loop(srv, reqs, rate=300.0, seed=15)
    assert count_request_trees(tracer.events) == 7
    assert srv.reqtrace.emitted == 7
    # rate 0 disables tracing entirely: no spans, no samples
    srv.attach(request_tracer=RequestTracer(rate=0.0))
    t2 = Tracer()
    with use_tracer(t2):
        run_open_loop(srv, reqs, rate=300.0, seed=16)
    assert count_request_trees(t2.events) == 0
    assert srv.reqtrace.sampled_total == 0
