"""FedRF-TCA protocol: rounds, drop settings, communication accounting, voting."""
import numpy as np
import pytest

from repro.data import make_domains
from repro.federated import (
    ClientConfig,
    FedRFTCATrainer,
    ProtocolConfig,
    hard_vote,
    plan_round,
    sample_participants,
)
from repro.federated.model import make_omega


@pytest.fixture(scope="module")
def small_setup():
    doms = make_domains(4, 120, shift=0.5, seed=1, dim=8, n_classes=3)
    cfg = ClientConfig(input_dim=8, n_classes=3, n_rff=32, m=8, extractor_widths=(16, 8))
    return doms[:3], doms[3], cfg


def test_round_plans_are_nested():
    rng = np.random.default_rng(0)
    for _ in range(50):
        plan = plan_round(rng, 6, "III")
        assert set(plan.c_clients) <= set(plan.w_clients) <= set(plan.msg_clients)


def test_sample_participants_range():
    rng = np.random.default_rng(0)
    sizes = {len(sample_participants(rng, 5)) for _ in range(200)}
    assert sizes <= set(range(6)) and 0 in sizes and 5 in sizes


def test_shared_seed_omega_identical():
    cfg = ClientConfig(input_dim=8, n_classes=3, n_rff=16)
    assert np.allclose(make_omega(cfg), make_omega(cfg))


def test_protocol_runs_and_accounts_comm(small_setup):
    sources, target, cfg = small_setup
    proto = ProtocolConfig(n_rounds=5, t_c=2, warmup_rounds=2, batch_size=32, seed=0)
    tr = FedRFTCATrainer(sources, target, cfg, proto)
    tr.train()
    assert tr.comm.rounds == 5
    # messages are 2N floats each: total must be a multiple of 2N
    assert tr.comm.data_messages % (2 * cfg.n_rff) == 0
    # communication is independent of the sample size: rerun with 4x data
    doms_big = make_domains(4, 480, shift=0.5, seed=1, dim=8, n_classes=3)
    tr2 = FedRFTCATrainer(doms_big[:3], doms_big[3], cfg, proto)
    tr2.train()
    assert tr2.comm.data_messages == tr.comm.data_messages  # O(KN), not O(Kn)


def test_drop_settings_all_run(small_setup):
    sources, target, cfg = small_setup
    for setting in ("I", "II", "III"):
        proto = ProtocolConfig(
            n_rounds=3, t_c=2, warmup_rounds=1, batch_size=32, drop_setting=setting, seed=0
        )
        tr = FedRFTCATrainer(sources, target, cfg, proto)
        acc = tr.train(eval_every=3)
        assert 0.0 <= acc[-1] <= 1.0


def test_no_message_ablation(small_setup):
    sources, target, cfg = small_setup
    proto = ProtocolConfig(
        n_rounds=3, warmup_rounds=1, batch_size=32, exchange_messages=False, seed=0
    )
    tr = FedRFTCATrainer(sources, target, cfg, proto)
    tr.train()
    assert tr.comm.data_messages == 0


def test_hard_vote_majority():
    logits = np.zeros((3, 4, 5))
    logits[0, :, 1] = 9  # client 0 votes class 1
    logits[1, :, 1] = 8  # client 1 votes class 1
    logits[2, :, 2] = 9  # client 2 votes class 2
    assert (hard_vote(logits) == 1).all()


def test_hard_vote_tiebreak_by_logits():
    logits = np.zeros((2, 1, 3))
    logits[0, 0, 0] = 5.0
    logits[1, 0, 1] = 6.0
    assert hard_vote(logits)[0] == 1  # tie 1-1, summed logits favor class 1


def test_one_shot_hard_voting_eval(small_setup):
    sources, target, cfg = small_setup
    proto = ProtocolConfig(
        n_rounds=3, warmup_rounds=2, batch_size=32, aggregate_classifier=False, seed=0
    )
    tr = FedRFTCATrainer(sources, target, cfg, proto)
    acc = tr.train(eval_every=3)
    assert 0.0 <= acc[-1] <= 1.0


def test_adaptation_beats_no_adaptation_on_shifted_domains():
    """End-to-end paper claim at small scale: FedRF-TCA > no-MMD ablation.

    Deterministic fixture chosen by sweep: at (data seed 2, shift 1.6) the
    margin holds with >= +0.13 across protocol seeds; the final accuracy is
    the mean of the last 5 evals (single-round eval noise was the old
    flakiness source), and the assert keeps a 2.5x cushion under the weakest
    sweep margin.
    """
    doms = make_domains(5, 300, shift=1.6, seed=2)
    cfg = ClientConfig(input_dim=16, n_classes=5, n_rff=128, m=16, lambda_mmd=2.0)

    def final_acc(**kw):
        proto = ProtocolConfig(
            n_rounds=150, t_c=25, warmup_rounds=100, lr=5e-3, seed=0, **kw
        )
        tr = FedRFTCATrainer(doms[:4], doms[4], cfg, proto)
        return float(np.mean(tr.train(eval_every=10)[-5:]))

    with_mmd = final_acc()
    without = final_acc(exchange_messages=False)
    assert with_mmd > without + 0.05, (with_mmd, without)
