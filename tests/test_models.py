"""Model-substrate numerics: flash attention, SSD, decode consistency, MoE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import LM, ShardRules
from repro.models.attention import flash_attention
from repro.models.moe import capacity, moe_forward
from repro.models.ssm import ssd_chunked, ssm_ref_sequential

RULES = ShardRules(model_size=1)
KEY = jax.random.PRNGKey(0)


def mk(**kw):
    base = dict(
        arch_id="t", family="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=97, head_dim=16, dtype=jnp.float32, fda_n_rff=16,
        fda_m=4, remat=False,
    )
    base.update(kw)
    return ModelConfig(**base)


def _naive_attn(q, k, v, window=0):
    b, s = q.shape[0], q.shape[1]
    g = q.shape[2] // k.shape[2]
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(q.shape[-1])
    i, j = np.arange(s)[:, None], np.arange(s)[None, :]
    mask = i >= j
    if window:
        mask &= (i - j) < window
    sc = jnp.where(jnp.asarray(mask)[None, None], sc, -1e30)
    return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(sc, -1), vv)


@pytest.mark.parametrize("window", [0, 24])
def test_model_flash_matches_naive(window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 16))
    k = jax.random.normal(ks[1], (2, 64, 2, 16))
    v = jax.random.normal(ks[2], (2, 64, 2, 16))
    out = flash_attention(q, k, v, causal=True, window=window)
    exp = _naive_attn(q, k, v, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_chunked_matches_sequential(chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (2, 64, 3, 8))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, 64, 3)))
    a_log = jax.random.uniform(ks[2], (3,), minval=0.0, maxval=1.0)
    b = jax.random.normal(ks[3], (2, 64, 16))
    c = jax.random.normal(ks[4], (2, 64, 16))
    y, _ = ssd_chunked(x, dt, a_log, b, c, chunk)
    y_ref = ssm_ref_sequential(x, dt, a_log, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-3)


def test_ssd_final_state_matches_recurrence():
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (1, 32, 2, 4))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 32, 2)))
    a_log = jax.random.uniform(ks[2], (2,), minval=0.0, maxval=1.0)
    b = jax.random.normal(ks[3], (1, 32, 8))
    c = jax.random.normal(ks[4], (1, 32, 8))
    _, final = ssd_chunked(x, dt, a_log, b, c, 8)
    # recompute final state step by step
    a = -jnp.exp(a_log)
    st = jnp.zeros((1, 2, 4, 8))
    for t in range(32):
        da = jnp.exp(dt[:, t] * a)
        st = st * da[..., None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, t], b[:, t], x[:, t]
        )
    np.testing.assert_allclose(np.asarray(final), np.asarray(st), atol=1e-3)


def test_moe_capacity_and_aux():
    cfg = mk(family="moe", n_experts=4, top_k=2, d_ff=32)
    assert capacity(cfg, 64) >= 64 * 2 // 4
    from repro.models.blocks import decoder_block_decl
    from repro.models.param import materialize

    decls = decoder_block_decl(cfg, RULES)
    params = materialize(decls, KEY)
    x = jax.random.normal(KEY, (2, 16, 64))
    y, aux = moe_forward(params["moe"], x, cfg)
    assert y.shape == x.shape
    assert float(aux) >= 0.99  # load-balance loss >= 1 at optimum=1


def test_moe_balanced_router_identity():
    """With uniform routing probabilities aux loss ~= 1 (E * sum 1/E * 1/E * E)."""
    cfg = mk(family="moe", n_experts=4, top_k=4, d_ff=32, capacity_factor=4.0)
    from repro.models.blocks import decoder_block_decl
    from repro.models.param import materialize

    decls = decoder_block_decl(cfg, RULES)
    params = materialize(decls, KEY)
    params["moe"]["router"] = jnp.zeros_like(params["moe"]["router"])  # uniform
    x = jax.random.normal(KEY, (2, 32, 64))
    _, aux = moe_forward(params["moe"], x, cfg)
    assert np.isclose(float(aux), 1.0, atol=1e-2)


@pytest.mark.parametrize(
    "cfg_kw",
    [
        dict(),
        dict(family="moe", n_experts=4, top_k=2, n_shared_experts=1, d_ff=64, capacity_factor=8.0),
        dict(family="moe", n_experts=4, top_k=2, kv_lora_rank=32, rope_head_dim=16, d_ff=64,
             capacity_factor=8.0),
        dict(family="ssm", ssm_state=16, ssm_head_dim=16, ssm_chunk=8, d_ff=0),
        dict(family="hybrid", ssm_state=16, ssm_head_dim=16, ssm_chunk=8, attn_every=1, d_ff=0),
    ],
    ids=["dense", "moe", "mla", "ssm", "hybrid"],
)
def test_decode_matches_forward(cfg_kw):
    cfg = mk(**cfg_kw)
    model = LM(cfg, RULES)
    params = model.init(KEY)
    b, s = 2, 16
    toks = jax.random.randint(KEY, (b, s), 0, 97)
    hidden, _ = model.forward(params, {"tokens": toks, "labels": toks})
    full = model.logits(params, hidden)
    cache = model.init_cache(b, s)
    step = jax.jit(model.decode_step)
    errs = []
    for t in range(s):
        logits, cache = step(params, cache, {"tokens": toks[:, t : t + 1]}, jnp.int32(t))
        errs.append(float(jnp.abs(logits - full[:, t]).max()))
    assert max(errs) < 1e-3, max(errs)


def test_prefill_handoff_dense():
    cfg = mk()
    model = LM(cfg, RULES)
    params = model.init(KEY)
    b, s, extra = 2, 16, 4
    toks = jax.random.randint(KEY, (b, s + extra), 0, 97)
    hidden, _ = model.forward(params, {"tokens": toks, "labels": toks})
    full = model.logits(params, hidden)
    logits_p, cache = model.prefill(params, {"tokens": toks[:, :s]})
    assert float(jnp.abs(logits_p - full[:, s - 1]).max()) < 1e-4
    cache = jax.tree_util.tree_map(
        lambda a: jnp.pad(a, [(0, 0), (0, 0), (0, extra)] + [(0, 0)] * (a.ndim - 3)), cache
    )
    for t in range(s, s + extra):
        logits, cache = model.decode_step(
            params, cache, {"tokens": toks[:, t : t + 1]}, jnp.int32(t)
        )
        assert float(jnp.abs(logits - full[:, t]).max()) < 1e-3


def test_sliding_window_ring_buffer_decode():
    """Decode with window w must match a full-cache decode restricted to w."""
    cfg_win = mk(attn_window=8)
    model = LM(cfg_win, RULES)
    params = model.init(KEY)
    b, s = 1, 24
    toks = jax.random.randint(KEY, (b, s), 0, 97)
    hidden, _ = model.forward(params, {"tokens": toks, "labels": toks})  # windowed forward
    full = model.logits(params, hidden)
    cache = model.init_cache(b, 8)  # ring of size window
    errs = []
    for t in range(s):
        logits, cache = model.decode_step(
            params, cache, {"tokens": toks[:, t : t + 1]}, jnp.int32(t)
        )
        errs.append(float(jnp.abs(logits - full[:, t]).max()))
    assert max(errs) < 1e-3, max(errs)


def test_fda_loss_in_model_is_differentiable():
    cfg = mk(fda_lambda=1.0)
    model = LM(cfg, RULES)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (4, 16), 0, 97)
    grads = jax.grad(lambda p: model.loss(p, {"tokens": toks, "labels": toks}, 2)[0])(params)
    g = grads["fda"]["w_rf"]
    assert float(jnp.abs(g).sum()) > 0  # w_rf receives gradient
    assert float(jnp.abs(grads["fda"]["omega"]).sum()) == 0  # omega is frozen
