"""Unified telemetry layer: registry semantics, trace validity + determinism,
jit-retrace sentinels, bitwise off/on degeneracy, and in-graph health probes."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.netsim import LinkModel, LinkScenario, TraceScenario
from repro.data import make_domains
from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig
from repro.federated.network import RoundPlan
from repro.fedsim import AsyncConfig, AsyncScheduler, SyncScheduler, markov_trace
from repro.obs import (
    NULL,
    CrashRecord,
    EvalRecord,
    FlushRecord,
    MetricsRegistry,
    RoundRecord,
    Tracer,
    get_registry,
    quarantine_totals,
    sentinel,
    use_registry,
    use_tracer,
    validate_trace,
    validate_trace_file,
)


@pytest.fixture(scope="module")
def small_setup():
    doms = make_domains(4, 120, shift=0.5, seed=1, dim=8, n_classes=3)
    cfg = ClientConfig(input_dim=8, n_classes=3, n_rff=32, m=8, extractor_widths=(16, 8))
    return doms[:3], doms[3], cfg


def _leaf_err(a, b):
    return max(
        float(np.abs(np.asarray(x) - np.asarray(y)).max())
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


def _trainer(setup, rounds, **proto_kw):
    sources, target, cfg = setup
    k = len(sources)
    ids = list(range(k))
    proto = ProtocolConfig(
        n_rounds=rounds, t_c=2, warmup_rounds=rounds, lr=1e-2, batch_size=32,
        seed=0, scenario=TraceScenario([RoundPlan(ids, ids, ids)] * rounds, cycle=True),
        **proto_kw,
    )
    return FedRFTCATrainer(sources, target, cfg, proto)


# ---- metrics registry ------------------------------------------------------


def test_counter_labels_and_values():
    reg = MetricsRegistry()
    c = reg.counter("comm.bytes")
    c.inc(10, kind="moments")
    c.inc(5, kind="moments")
    c.inc(3, kind="w_rf")
    assert c.value(kind="moments") == 15
    assert c.value(kind="w_rf") == 3
    assert c.value(kind="classifier") == 0
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_and_histogram():
    reg = MetricsRegistry()
    reg.gauge("fed.model_version").set(3)
    h = reg.histogram("net.uplink_s")
    for v in (1.0, 3.0, 2.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["fed.model_version"][""] == 3
    hs = snap["net.uplink_s"][""]
    assert hs["count"] == 3 and hs["min"] == 1.0 and hs["max"] == 3.0
    assert hs["mean"] == 2.0
    with pytest.raises(ValueError):
        h.observe(float("nan"))


def test_kind_mismatch_rejected():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_null_registry_is_inert_default():
    assert get_registry() is NULL
    assert not NULL.collecting
    # every op is a no-op that returns without recording
    NULL.counter("a").inc(5, kind="k")
    NULL.gauge("b").set(1.0)
    NULL.histogram("c").observe(2.0)
    assert NULL.snapshot() == {}


def test_use_registry_scopes_collection():
    reg = MetricsRegistry()
    with use_registry(reg):
        assert get_registry() is reg
        get_registry().counter("scoped").inc()
    assert get_registry() is NULL
    assert reg.counter("scoped").value() == 1


# ---- tracer + trace validation ---------------------------------------------


def test_tracer_roundtrip_and_validation(tmp_path):
    tr = Tracer()
    tr.begin("round", 1.0, args={"round": 1})
    tr.end("round", 2.5)
    tr.complete("compute", 1.0, 0.5, tid=3)
    tr.instant("crash", 2.0)
    assert validate_trace(tr.events) == []
    path = tmp_path / "t.json"
    tr.write(path)
    assert validate_trace_file(path) == []
    doc = json.loads(path.read_text())
    names = [e["name"] for e in doc["traceEvents"] if e["ph"] != "M"]
    assert names == ["round", "round", "compute", "crash"]
    # ts is microseconds in the export
    evs = [e for e in doc["traceEvents"] if e["ph"] == "B"]
    assert evs[0]["ts"] == 1_000_000.0


def test_trace_validation_catches_malformed():
    tr = Tracer()
    tr.begin("round", 1.0)
    assert any("unclosed" in e for e in validate_trace(tr.events))
    tr2 = Tracer()
    tr2.begin("a", 2.0)
    tr2.end("b", 3.0)
    assert validate_trace(tr2.events)
    tr3 = Tracer()
    with pytest.raises(ValueError):
        tr3.complete("x", 0.0, -1.0)
    assert validate_trace([{"name": "x"}])  # missing required keys


def test_wall_span_contextmanager():
    tr = Tracer()
    with tr.span("bench"):
        pass
    assert [e["ph"] for e in tr.events] == ["B", "E"]
    assert validate_trace(tr.events) == []


# ---- sentinel ---------------------------------------------------------------


def test_sentinel_counts_retraces():
    calls = sentinel.count("unit.f")
    f = jax.jit(sentinel.wrap("unit.f", lambda x: x * 2))
    f(jnp.ones(3))
    f(jnp.ones(3))  # cache hit: no retrace
    assert sentinel.count("unit.f") == calls + 1
    f(jnp.ones(5))  # new shape: retrace
    assert sentinel.count("unit.f") == calls + 2


def test_sentinel_assert_stable():
    before = sentinel.counts()
    g = jax.jit(sentinel.wrap("unit.g", lambda x: x + 1))
    g(jnp.ones(2))
    sentinel.assert_stable(before, ("unit.g",), expect=1)
    g(jnp.ones(4))
    with pytest.raises(AssertionError):
        sentinel.assert_stable(before, ("unit.g",), expect=1)


def test_engine_round_plane_traces_once(small_setup):
    before = sentinel.counts()
    tr = _trainer(small_setup, 4)
    SyncScheduler(tr).run(4)
    sentinel.assert_stable(before, ("engine.round",), expect=1)


def test_probe_plane_traces_once_and_flush(small_setup):
    before = sentinel.counts()
    tr = _trainer(small_setup, 3, probe=True)
    sched = AsyncScheduler(tr, AsyncConfig(buffer_size=len(small_setup[0])))
    sched.run(3)
    sentinel.assert_stable(before, ("engine.flush",), expect=1)


# ---- bitwise off/on degeneracy ----------------------------------------------


@pytest.mark.parametrize("engine", ["batched", "serial"])
def test_telemetry_off_is_bitwise(small_setup, engine):
    rounds = 3
    tr_off = _trainer(small_setup, rounds, engine=engine)
    SyncScheduler(tr_off).run(rounds)
    tr_on = _trainer(small_setup, rounds, engine=engine, probe=True)
    with use_registry(MetricsRegistry()), use_tracer(Tracer()):
        SyncScheduler(tr_on).run(rounds)
    assert _leaf_err(tr_off.tgt_params, tr_on.tgt_params) == 0.0
    if engine == "batched":
        assert _leaf_err(tr_off._src_stack, tr_on._src_stack) == 0.0
    else:
        assert _leaf_err(tr_off.src_params, tr_on.src_params) == 0.0


def test_async_telemetry_off_is_bitwise(small_setup):
    sources, _, _ = small_setup
    k = len(sources)

    def run_once(telemetry):
        tr = _trainer(small_setup, 4, probe=telemetry)
        sched = AsyncScheduler(
            tr, AsyncConfig(buffer_size=2),
            links=LinkScenario(links=[LinkModel(latency_s=0.2 * (i + 1)) for i in range(k)]),
        )
        if telemetry:
            with use_registry(MetricsRegistry()), use_tracer(Tracer()):
                sched.run(4)
        else:
            sched.run(4)
        return tr

    a, b = run_once(False), run_once(True)
    assert _leaf_err(a.tgt_params, b.tgt_params) == 0.0
    assert _leaf_err(a._src_stack, b._src_stack) == 0.0


# ---- async trace determinism ------------------------------------------------


def test_async_trace_runs_twice_identical(small_setup):
    sources, _, _ = small_setup
    k = len(sources)

    def run_once():
        tr = _trainer(small_setup, 5, probe=True)
        avail = markov_trace(k, horizon=1e4, mean_on=8.0, mean_off=3.0, seed=7)
        sched = AsyncScheduler(
            tr,
            AsyncConfig(
                buffer_size=2, staleness="polynomial", eval_interval=2.0,
                server_crash_times=(4.0,), checkpoint_interval_s=2.0,
                restart_delay_s=0.5,
            ),
            availability=avail,
            links=LinkScenario(links=[LinkModel(latency_s=0.2 * (i + 1)) for i in range(k)]),
        )
        tracer = Tracer()
        with use_tracer(tracer):
            sched.run(5)
        return tracer, sched

    t1, s1 = run_once()
    t2, s2 = run_once()
    assert t1.events == t2.events  # bit-identical virtual-time story
    assert validate_trace(t1.events) == []
    names = {e["name"] for e in t1.events}
    assert {"compute", "uplink", "flush", "server_crash", "recovery",
            "checkpoint"} <= names
    assert len(s1.recoveries) == 1


# ---- health probes ----------------------------------------------------------


def test_round_probes_shapes_and_mass(small_setup):
    sources, _, _ = small_setup
    k = len(sources)
    tr = _trainer(small_setup, 2, probe=True)
    tr.round(1)
    probes = tr.last_probes
    assert probes is not None
    assert float(probes["moment_mass"]) == pytest.approx(k)
    assert probes["update_norm"].shape == (k,)
    assert np.all(probes["update_norm"] > 0)
    assert float(probes["tgt_update_norm"]) > 0
    # plain mean discounts nobody
    assert np.all(probes["attribution_moments"] == 0.0)
    assert np.all(probes["attribution_w_rf"] == 0.0)


def test_probe_metrics_and_fault_ledger(small_setup):
    rounds = 4
    reg = MetricsRegistry()
    tr = _trainer(small_setup, rounds, probe=True, rule="trimmed_mean")
    with use_registry(reg):
        SyncScheduler(tr).run(rounds)
    snap = reg.snapshot()
    assert snap["probe.update_norm"]["plane=round"]["count"] == rounds
    # trimmed mean always discounts the extremes: the ledger must be populated
    totals = quarantine_totals(reg)
    assert totals and all(v > 0 for v in totals.values())


def test_last_probes_pipeline_drains(small_setup):
    tr = _trainer(small_setup, 3, probe=True)
    sched = SyncScheduler(tr)
    sched.run(3)
    # the run drained the one-step pipeline; reading again is stable
    p1 = tr.last_probes
    p2 = tr.last_probes
    assert p1 is p2 and p1 is not None


# ---- typed history records --------------------------------------------------


def test_record_dict_view():
    row = RoundRecord(t=1.5, round=2, participants=3)
    assert row["t"] == 1.5 and row["participants"] == 3
    assert "acc" not in row  # None-valued fields stay hidden
    row["acc"] = 0.9
    assert row["acc"] == 0.9 and "acc" in row
    assert row.get("missing") is None
    with pytest.raises(KeyError):
        row["nope"] = 1.0
    assert set(dict(row)) == {"t", "round", "participants", "acc"}


def test_scheduler_history_is_typed(small_setup):
    sources, _, _ = small_setup
    k = len(sources)
    tr = _trainer(small_setup, 3)
    sched = AsyncScheduler(
        tr,
        AsyncConfig(buffer_size=k, eval_interval=2.0, server_crash_times=(2.5,),
                    checkpoint_interval_s=1.0),
    )
    hist = sched.run(3, eval_every=1)
    kinds = {type(h) for h in hist}
    assert FlushRecord in kinds and CrashRecord in kinds and EvalRecord in kinds
    flushes = [h for h in hist if isinstance(h, FlushRecord)]
    assert all(h["staleness"] == [0] * len(h["members"]) for h in flushes)
    crash = next(h for h in hist if isinstance(h, CrashRecord))
    assert crash["crash"] == "server" and crash["rollback_s"] >= 0.0


def test_commlog_snapshot_record(small_setup):
    tr = _trainer(small_setup, 2)
    tr.round(1)
    rec = tr.transport.log.snapshot()
    assert rec["bytes_total"] == tr.transport.log.bytes_total > 0
    assert rec["bytes_by_kind"]["moments"] > 0
