"""Unified telemetry layer: registry semantics, trace validity + determinism,
jit-retrace sentinels, bitwise off/on degeneracy, and in-graph health probes."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.netsim import LinkModel, LinkScenario, TraceScenario
from repro.data import make_domains
from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig
from repro.federated.network import RoundPlan
from repro.fedsim import AsyncConfig, AsyncScheduler, SyncScheduler, markov_trace
from repro.obs import (
    NULL,
    CrashRecord,
    DriftMonitor,
    EvalRecord,
    FlushRecord,
    MetricsRegistry,
    RequestTracer,
    RoundRecord,
    Slo,
    SloEngine,
    Tracer,
    count_request_trees,
    emit_probes,
    get_registry,
    quarantine_slo,
    quarantine_totals,
    sentinel,
    use_registry,
    use_tracer,
    validate_trace,
    validate_trace_file,
)
from repro.robust import get_rule


@pytest.fixture(scope="module")
def small_setup():
    doms = make_domains(4, 120, shift=0.5, seed=1, dim=8, n_classes=3)
    cfg = ClientConfig(input_dim=8, n_classes=3, n_rff=32, m=8, extractor_widths=(16, 8))
    return doms[:3], doms[3], cfg


def _leaf_err(a, b):
    return max(
        float(np.abs(np.asarray(x) - np.asarray(y)).max())
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


def _trainer(setup, rounds, **proto_kw):
    sources, target, cfg = setup
    k = len(sources)
    ids = list(range(k))
    proto = ProtocolConfig(
        n_rounds=rounds, t_c=2, warmup_rounds=rounds, lr=1e-2, batch_size=32,
        seed=0, scenario=TraceScenario([RoundPlan(ids, ids, ids)] * rounds, cycle=True),
        **proto_kw,
    )
    return FedRFTCATrainer(sources, target, cfg, proto)


# ---- metrics registry ------------------------------------------------------


def test_counter_labels_and_values():
    reg = MetricsRegistry()
    c = reg.counter("comm.bytes")
    c.inc(10, kind="moments")
    c.inc(5, kind="moments")
    c.inc(3, kind="w_rf")
    assert c.value(kind="moments") == 15
    assert c.value(kind="w_rf") == 3
    assert c.value(kind="classifier") == 0
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_and_histogram():
    reg = MetricsRegistry()
    reg.gauge("fed.model_version").set(3)
    h = reg.histogram("net.uplink_s")
    for v in (1.0, 3.0, 2.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["fed.model_version"][""] == 3
    hs = snap["net.uplink_s"][""]
    assert hs["count"] == 3 and hs["min"] == 1.0 and hs["max"] == 3.0
    assert hs["mean"] == 2.0
    with pytest.raises(ValueError):
        h.observe(float("nan"))


def test_kind_mismatch_rejected():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_null_registry_is_inert_default():
    assert get_registry() is NULL
    assert not NULL.collecting
    # every op is a no-op that returns without recording
    NULL.counter("a").inc(5, kind="k")
    NULL.gauge("b").set(1.0)
    NULL.histogram("c").observe(2.0)
    assert NULL.snapshot() == {}


def test_use_registry_scopes_collection():
    reg = MetricsRegistry()
    with use_registry(reg):
        assert get_registry() is reg
        get_registry().counter("scoped").inc()
    assert get_registry() is NULL
    assert reg.counter("scoped").value() == 1


# ---- tracer + trace validation ---------------------------------------------


def test_tracer_roundtrip_and_validation(tmp_path):
    tr = Tracer()
    tr.begin("round", 1.0, args={"round": 1})
    tr.end("round", 2.5)
    tr.complete("compute", 1.0, 0.5, tid=3)
    tr.instant("crash", 2.0)
    assert validate_trace(tr.events) == []
    path = tmp_path / "t.json"
    tr.write(path)
    assert validate_trace_file(path) == []
    doc = json.loads(path.read_text())
    names = [e["name"] for e in doc["traceEvents"] if e["ph"] != "M"]
    assert names == ["round", "round", "compute", "crash"]
    # ts is microseconds in the export
    evs = [e for e in doc["traceEvents"] if e["ph"] == "B"]
    assert evs[0]["ts"] == 1_000_000.0


def test_trace_validation_catches_malformed():
    tr = Tracer()
    tr.begin("round", 1.0)
    assert any("unclosed" in e for e in validate_trace(tr.events))
    tr2 = Tracer()
    tr2.begin("a", 2.0)
    tr2.end("b", 3.0)
    assert validate_trace(tr2.events)
    tr3 = Tracer()
    with pytest.raises(ValueError):
        tr3.complete("x", 0.0, -1.0)
    assert validate_trace([{"name": "x"}])  # missing required keys


def test_wall_span_contextmanager():
    tr = Tracer()
    with tr.span("bench"):
        pass
    assert [e["ph"] for e in tr.events] == ["B", "E"]
    assert validate_trace(tr.events) == []


# ---- sentinel ---------------------------------------------------------------


def test_sentinel_counts_retraces():
    calls = sentinel.count("unit.f")
    f = jax.jit(sentinel.wrap("unit.f", lambda x: x * 2))
    f(jnp.ones(3))
    f(jnp.ones(3))  # cache hit: no retrace
    assert sentinel.count("unit.f") == calls + 1
    f(jnp.ones(5))  # new shape: retrace
    assert sentinel.count("unit.f") == calls + 2


def test_sentinel_assert_stable():
    before = sentinel.counts()
    g = jax.jit(sentinel.wrap("unit.g", lambda x: x + 1))
    g(jnp.ones(2))
    sentinel.assert_stable(before, ("unit.g",), expect=1)
    g(jnp.ones(4))
    with pytest.raises(AssertionError):
        sentinel.assert_stable(before, ("unit.g",), expect=1)


def test_engine_round_plane_traces_once(small_setup):
    before = sentinel.counts()
    tr = _trainer(small_setup, 4)
    SyncScheduler(tr).run(4)
    sentinel.assert_stable(before, ("engine.round",), expect=1)


def test_probe_plane_traces_once_and_flush(small_setup):
    before = sentinel.counts()
    tr = _trainer(small_setup, 3, probe=True)
    sched = AsyncScheduler(tr, AsyncConfig(buffer_size=len(small_setup[0])))
    sched.run(3)
    sentinel.assert_stable(before, ("engine.flush",), expect=1)


# ---- bitwise off/on degeneracy ----------------------------------------------


@pytest.mark.parametrize("engine", ["batched", "serial"])
def test_telemetry_off_is_bitwise(small_setup, engine):
    rounds = 3
    tr_off = _trainer(small_setup, rounds, engine=engine)
    SyncScheduler(tr_off).run(rounds)
    tr_on = _trainer(small_setup, rounds, engine=engine, probe=True)
    with use_registry(MetricsRegistry()), use_tracer(Tracer()):
        SyncScheduler(tr_on).run(rounds)
    assert _leaf_err(tr_off.tgt_params, tr_on.tgt_params) == 0.0
    if engine == "batched":
        assert _leaf_err(tr_off._src_stack, tr_on._src_stack) == 0.0
    else:
        assert _leaf_err(tr_off.src_params, tr_on.src_params) == 0.0


def test_async_telemetry_off_is_bitwise(small_setup):
    sources, _, _ = small_setup
    k = len(sources)

    def run_once(telemetry):
        tr = _trainer(small_setup, 4, probe=telemetry)
        sched = AsyncScheduler(
            tr, AsyncConfig(buffer_size=2),
            links=LinkScenario(links=[LinkModel(latency_s=0.2 * (i + 1)) for i in range(k)]),
        )
        if telemetry:
            with use_registry(MetricsRegistry()), use_tracer(Tracer()):
                sched.run(4)
        else:
            sched.run(4)
        return tr

    a, b = run_once(False), run_once(True)
    assert _leaf_err(a.tgt_params, b.tgt_params) == 0.0
    assert _leaf_err(a._src_stack, b._src_stack) == 0.0


# ---- async trace determinism ------------------------------------------------


def test_async_trace_runs_twice_identical(small_setup):
    sources, _, _ = small_setup
    k = len(sources)

    def run_once():
        tr = _trainer(small_setup, 5, probe=True)
        avail = markov_trace(k, horizon=1e4, mean_on=8.0, mean_off=3.0, seed=7)
        sched = AsyncScheduler(
            tr,
            AsyncConfig(
                buffer_size=2, staleness="polynomial", eval_interval=2.0,
                server_crash_times=(4.0,), checkpoint_interval_s=2.0,
                restart_delay_s=0.5,
            ),
            availability=avail,
            links=LinkScenario(links=[LinkModel(latency_s=0.2 * (i + 1)) for i in range(k)]),
        )
        tracer = Tracer()
        with use_tracer(tracer):
            sched.run(5)
        return tracer, sched

    t1, s1 = run_once()
    t2, s2 = run_once()
    assert t1.events == t2.events  # bit-identical virtual-time story
    assert validate_trace(t1.events) == []
    names = {e["name"] for e in t1.events}
    assert {"compute", "uplink", "flush", "server_crash", "recovery",
            "checkpoint"} <= names
    assert len(s1.recoveries) == 1


# ---- health probes ----------------------------------------------------------


def test_round_probes_shapes_and_mass(small_setup):
    sources, _, _ = small_setup
    k = len(sources)
    tr = _trainer(small_setup, 2, probe=True)
    tr.round(1)
    probes = tr.last_probes
    assert probes is not None
    assert float(probes["moment_mass"]) == pytest.approx(k)
    assert probes["update_norm"].shape == (k,)
    assert np.all(probes["update_norm"] > 0)
    assert float(probes["tgt_update_norm"]) > 0
    # plain mean discounts nobody
    assert np.all(probes["attribution_moments"] == 0.0)
    assert np.all(probes["attribution_w_rf"] == 0.0)


def test_probe_metrics_and_fault_ledger(small_setup):
    rounds = 4
    reg = MetricsRegistry()
    tr = _trainer(small_setup, rounds, probe=True, rule="trimmed_mean")
    with use_registry(reg):
        SyncScheduler(tr).run(rounds)
    snap = reg.snapshot()
    assert snap["probe.update_norm"]["plane=round"]["count"] == rounds
    # trimmed mean always discounts the extremes: the ledger must be populated
    totals = quarantine_totals(reg)
    assert totals and all(v > 0 for v in totals.values())


def test_last_probes_pipeline_drains(small_setup):
    tr = _trainer(small_setup, 3, probe=True)
    sched = SyncScheduler(tr)
    sched.run(3)
    # the run drained the one-step pipeline; reading again is stable
    p1 = tr.last_probes
    p2 = tr.last_probes
    assert p1 is p2 and p1 is not None


# ---- typed history records --------------------------------------------------


def test_record_dict_view():
    row = RoundRecord(t=1.5, round=2, participants=3)
    assert row["t"] == 1.5 and row["participants"] == 3
    assert "acc" not in row  # None-valued fields stay hidden
    row["acc"] = 0.9
    assert row["acc"] == 0.9 and "acc" in row
    assert row.get("missing") is None
    with pytest.raises(KeyError):
        row["nope"] = 1.0
    assert set(dict(row)) == {"t", "round", "participants", "acc"}


def test_scheduler_history_is_typed(small_setup):
    sources, _, _ = small_setup
    k = len(sources)
    tr = _trainer(small_setup, 3)
    sched = AsyncScheduler(
        tr,
        AsyncConfig(buffer_size=k, eval_interval=2.0, server_crash_times=(2.5,),
                    checkpoint_interval_s=1.0),
    )
    hist = sched.run(3, eval_every=1)
    kinds = {type(h) for h in hist}
    assert FlushRecord in kinds and CrashRecord in kinds and EvalRecord in kinds
    flushes = [h for h in hist if isinstance(h, FlushRecord)]
    assert all(h["staleness"] == [0] * len(h["members"]) for h in flushes)
    crash = next(h for h in hist if isinstance(h, CrashRecord))
    assert crash["crash"] == "server" and crash["rollback_s"] >= 0.0


def test_commlog_snapshot_record(small_setup):
    tr = _trainer(small_setup, 2)
    tr.round(1)
    rec = tr.transport.log.snapshot()
    assert rec["bytes_total"] == tr.transport.log.bytes_total > 0
    assert rec["bytes_by_kind"]["moments"] > 0


# ---- SLO engine: multi-window burn-rate alerting ----------------------------


def test_slo_multi_window_requires_both_and_rearms():
    eng = SloEngine([Slo("lat", target=0.9, bound=1.0,
                         window_fast_s=1.0, window_slow_s=10.0)])
    # a calm prefix fills the slow window with good samples
    for i in range(20):
        assert eng.observe("lat", i * 0.5, 0.1) is None
    # one bad sample: fast burn spikes but the slow window absorbs it
    assert eng.observe("lat", 10.0, 5.0) is None
    # sustained badness tips the slow window too -> exactly one violation
    v1 = eng.observe("lat", 10.2, 5.0)
    v2 = eng.observe("lat", 10.4, 5.0)
    fired = [v for v in (v1, v2) if v is not None]
    assert len(fired) == 1
    v = fired[0]
    assert v.objective == "lat" and v.burn_fast >= 1.0 and v.burn_slow >= 1.0
    assert v.window_fast_s == 1.0 and v.window_slow_s == 10.0
    # edge-triggered: staying inside the episode emits nothing new
    assert eng.observe("lat", 10.6, 5.0) is None
    assert len(eng.history) == 1
    # recovery clears the fast window -> re-armed -> a fresh burst re-fires
    for i in range(30):
        assert eng.observe("lat", 11.0 + i * 0.5, 0.1) is None
    for i in range(6):
        eng.observe("lat", 26.0 + i * 0.1, 5.0)
    assert len(eng.history) == 2
    assert [v.to_dict()["objective"] for v in eng.history] == ["lat", "lat"]


def test_slo_validation_and_min_samples():
    with pytest.raises(ValueError, match="target"):
        Slo("a", target=1.0, bound=1.0)
    with pytest.raises(ValueError, match="window"):
        Slo("a", target=0.9, bound=1.0, window_fast_s=5.0, window_slow_s=5.0)
    with pytest.raises(ValueError, match="burn_threshold"):
        Slo("a", target=0.9, bound=1.0, burn_threshold=0.0)
    eng = SloEngine([Slo("lat", target=0.5, bound=1.0, window_fast_s=1.0,
                         window_slow_s=4.0, min_samples=3)])
    with pytest.raises(ValueError, match="already registered"):
        eng.add(Slo("lat", target=0.5, bound=1.0))
    with pytest.raises(KeyError, match="unknown objective"):
        eng.observe("nope", 0.0, 1.0)
    with pytest.raises(ValueError, match="exactly one"):
        eng.observe("lat", 0.0, 1.0, ok=True)
    # min_samples: two all-bad samples cannot fire, the third can
    assert eng.observe("lat", 0.0, 9.0) is None
    assert eng.observe("lat", 0.1, 9.0) is None
    assert eng.observe("lat", 0.2, 9.0) is not None


def test_slo_window_counters_match_rescan():
    """The O(1) running bad-counters agree with a brute-force window scan."""
    eng = SloEngine([Slo("lat", target=0.9, bound=1.0, window_fast_s=0.7,
                         window_slow_s=3.0, burn_threshold=1e9)])
    stream = eng._streams["lat"]
    rng = np.random.default_rng(0)
    t = 0.0
    for _ in range(200):
        t += float(rng.exponential(0.1))
        eng.observe("lat", t, float(rng.choice([0.1, 5.0])))
        assert stream.bad_fast == sum(b for _, b in stream.fast)
        assert stream.bad_slow == sum(b for _, b in stream.samples)
        assert len(stream.fast) <= len(stream.samples)


def test_slo_quarantine_ledger_end_to_end():
    """PR-7 trim ledger -> probes -> quarantine_totals -> SLO violation
    naming the poisoned member."""
    slo = quarantine_slo(max_rate=0.5, window_fast_s=0.03, window_slow_s=0.12)
    assert slo.kind == "availability" and slo.bound == 0.5
    eng = SloEngine([slo, Slo("up", target=0.9, kind="availability",
                              window_fast_s=1.0, window_slow_s=4.0)])
    # a boundless availability objective only accepts ok= samples
    with pytest.raises(ValueError, match="availability-style"):
        eng.observe("up", 0.0, 1.0)
    assert eng.observe("up", 0.0, ok=True) is None
    assert eng.observe("up", 0.1, ok=False) is not None
    reg = MetricsRegistry()
    # no ledger mass yet: a clean sample, no violation
    assert eng.feed_quarantine(0.0, objective=slo.name, rounds=1, registry=reg) is None
    rule = get_rule("finite_mean")
    vals = np.ones((5, 4), np.float32)
    vals[2, 1] = np.nan  # member 2 delivers a poisoned update
    att = rule.attribution(jnp.asarray(vals), jnp.ones(5, jnp.float32))
    emit_probes({"attribution_moments": att}, plane="round", registry=reg)
    assert quarantine_totals(reg) == {2: 1.0}
    v = eng.feed_quarantine(0.01, objective=slo.name, rounds=1, registry=reg)
    assert v is not None and v.detail == "member=2" and v.kind == "availability"
    with pytest.raises(ValueError, match="rounds"):
        eng.feed_quarantine(0.02, objective=slo.name, rounds=0, registry=reg)


# ---- drift monitor: RF-MMD over streamed moments ----------------------------


def _moments(rng, n, center, noise=0.01, dim=6):
    return [center + noise * rng.standard_normal(dim).astype(np.float32)
            for _ in range(n)]


def test_drift_calibration_then_fire_timeline():
    fired = []
    mon = DriftMonitor(alpha=0.3, window=2, k_consecutive=2,
                       calibration_windows=3, threshold_scale=4.0,
                       burnin_windows=1, on_alert=lambda p, r: fired.append((p, r)))
    rng = np.random.default_rng(1)
    ref = np.zeros(6, np.float32)
    # observations before a reference is pinned are ignored
    assert mon.observe("p", 0.0, ref, 4) is None
    mon.set_reference("p", ref)
    t = 0.0
    for m in _moments(rng, 16, ref):
        t += 0.1
        mon.observe("p", t, m, 4)
    assert mon.fires == 0 and mon.pair_threshold("p") is not None
    for m in _moments(rng, 6, ref + 2.0):
        t += 0.1
        rec = mon.observe("p", t, m, 4)
    assert mon.fires == 1 and len(fired) == 1 and fired[0][0] == "p"
    # the timeline alone reconstructs the story: burn-in + calibration
    # windows flagged, exactly one fire, consecutive resets after it
    tl = mon.timeline()
    assert sum(r["calibrating"] for r in tl) == 1 + 3  # burnin + calibration
    assert [r["fired"] for r in tl].count(True) == 1
    assert tl[-[r["fired"] for r in reversed(tl)].index(True) - 1]["consecutive"] == 0


def test_drift_threshold_ratio_floor_and_validation():
    # a zero-variance calm stream: the std term collapses, the ratio floor rules
    mon = DriftMonitor(window=1, calibration_windows=2, threshold_scale=4.0,
                       threshold_ratio=2.5, burnin_windows=0)
    ref = np.zeros(4, np.float32)
    mon.set_reference("p", ref)
    calm = ref + 0.1  # constant offset -> identical mmd every window
    for t in range(3):
        mon.observe("p", float(t), calm, 2)
    lvl = float(np.dot(calm - ref, calm - ref))
    assert mon.pair_threshold("p") == pytest.approx(2.5 * lvl, rel=1e-5)
    for bad_kw in (dict(alpha=0.0), dict(window=0), dict(k_consecutive=0),
                   dict(threshold_ratio=0.5), dict(burnin_windows=-1),
                   dict(threshold=None, calibration_windows=0)):
        with pytest.raises(ValueError):
            DriftMonitor(**bad_kw)


def test_drift_reference_reset_and_recent_mean():
    mon = DriftMonitor(alpha=1.0, window=1, k_consecutive=1, threshold=0.5)
    mon.set_reference("p", np.zeros(3, np.float32))
    with pytest.raises(ValueError, match="no live moments"):
        mon.recent_mean("p")
    mon.observe("p", 0.0, np.array([1.0, 0, 0], np.float32), 10)
    mon.observe("p", 0.1, np.array([4.0, 0, 0], np.float32), 30)
    pooled, n = mon.recent_mean("p")
    assert n == 40 and pooled[0] == pytest.approx(0.25 * 1.0 + 0.75 * 4.0)
    assert mon.fires == 1  # alpha=1, k=1: the first shifted window fires
    # re-pinning the reference clears the live state entirely
    mon.set_reference("p", np.array([4.0, 0, 0], np.float32))
    with pytest.raises(ValueError, match="no live moments"):
        mon.recent_mean("p")
    rec = mon.observe("p", 0.2, np.array([4.0, 0, 0], np.float32), 5)
    assert rec is not None and not rec.fired and rec.mmd == 0.0


# ---- per-request span trees -------------------------------------------------


def test_request_tracer_sampling_deterministic():
    rt = RequestTracer(rate=0.3, seed=7)
    picks = [rt.sampled(i) for i in range(400)]
    assert picks == [RequestTracer(rate=0.3, seed=7).sampled(i) for i in range(400)]
    frac = sum(picks) / len(picks)
    assert 0.15 < frac < 0.45  # head sampling lands near the configured rate
    assert picks != [RequestTracer(rate=0.3, seed=8).sampled(i) for i in range(400)]
    assert all(RequestTracer(rate=1.0).sampled(i) for i in range(10))
    assert not any(RequestTracer(rate=0.0).sampled(i) for i in range(10))
    with pytest.raises(ValueError, match="rate"):
        RequestTracer(rate=1.5)


def test_request_tracer_tree_shapes():
    tracer = Tracer()
    rt = RequestTracer(rate=1.0, tracer=tracer)
    # a complete tree: root + all three legs contained in it
    assert rt.begin(0, 1.0)
    rt.leg(0, "serve.queue_wait", 1.0, 0.2)
    rt.leg(0, "serve.batch_assembly", 1.2, 0.1)
    rt.leg(0, "serve.padded_dispatch", 1.3, 0.4)
    rt.finish(0, 1.8)
    assert count_request_trees(tracer.events) == 1
    # an incomplete tree (missing a leg) does not count
    assert rt.begin(1, 2.0)
    rt.leg(1, "serve.queue_wait", 2.0, 0.1)
    rt.finish(1, 2.2)
    assert count_request_trees(tracer.events) == 1
    assert rt.emitted == 2 and rt.sampled_total == 2
    # finish without begin is a no-op; no ambient tracer -> begin declines
    rt.finish(99, 3.0)
    assert not RequestTracer(rate=1.0).begin(0, 0.0)
    # every emitted event carries its trace id
    assert all(ev["args"]["trace_id"] in (0, 1) for ev in tracer.events)
    # admission trees ride the wall track in their own (negative) namespace
    rt.emit_admission([("serve.wire_decode", 0.01), ("serve.moment_merge", 0.02),
                       ("serve.w_rf_ship", 0.03)], wall0=0.5)
    adm = [ev for ev in tracer.events if ev["args"]["trace_id"] < 0]
    assert {ev["name"] for ev in adm} == {
        "serve.admission", "serve.wire_decode", "serve.moment_merge",
        "serve.w_rf_ship"}
    assert count_request_trees(tracer.events) == 1  # admissions never miscount
    assert validate_trace(tracer.events) == []


def test_trace_file_request_tree_gate(tmp_path):
    tracer = Tracer()
    rt = RequestTracer(rate=1.0, tracer=tracer)
    rt.begin(3, 0.0)
    rt.leg(3, "serve.queue_wait", 0.0, 0.1)
    rt.leg(3, "serve.batch_assembly", 0.1, 0.1)
    rt.leg(3, "serve.padded_dispatch", 0.2, 0.1)
    rt.finish(3, 0.3)
    path = tmp_path / "trace.json"
    tracer.write(path)
    assert validate_trace_file(path, require_request_trees=1) == []
    errors = validate_trace_file(path, require_request_trees=2)
    assert errors and "request span tree" in errors[0]
