"""§Perf hillclimb variants must be numerically equivalent to the baselines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import LM, ShardRules
from repro.models.attention import flash_attention
from repro.models.layers import cross_entropy


def test_sharded_ce_matches_baseline():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (2, 5, 100))
    labels = jax.random.randint(key, (2, 5), 0, 90)
    a = float(cross_entropy(logits, labels, 90, sharded=False))
    b = float(cross_entropy(logits, labels, 90, sharded=True))
    assert np.isclose(a, b, atol=1e-5), (a, b)


@pytest.mark.parametrize("window", [0, 40])
def test_causal_skip_matches_baseline(window):
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 16))
    k = jax.random.normal(ks[1], (1, 128, 2, 16))
    v = jax.random.normal(ks[2], (1, 128, 2, 16))
    a = flash_attention(q, k, v, causal=True, window=window)
    b = flash_attention(q, k, v, causal=True, window=window, skip_masked=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_model_loss_same_with_perf_flags():
    """End-to-end: flags change the schedule, not the math (single device)."""
    base = dict(
        arch_id="t", family="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=97, head_dim=16, dtype=jnp.float32, fda_n_rff=16,
        fda_m=4, remat=False,
    )
    key = jax.random.PRNGKey(0)
    toks = jax.random.randint(key, (2, 32), 0, 97)
    batch = {"tokens": toks, "labels": toks}
    m1 = LM(ModelConfig(**base), ShardRules(model_size=1))
    m2 = LM(
        ModelConfig(**base, sharded_ce=True, causal_skip=True), ShardRules(model_size=1)
    )
    p = m1.init(key)
    l1 = float(m1.loss(p, batch, 2)[0])
    l2 = float(m2.loss(p, batch, 2)[0])
    assert np.isclose(l1, l2, rtol=1e-5), (l1, l2)
