"""Wire-format benchmark: exact bytes-on-wire per payload per codec, plus
accuracy-vs-loss-rate and accuracy-vs-codec curves through the real protocol.

Claims measured (and recorded in ``BENCH_comm.json``):

- Table I/II made literal: serialized byte sizes of the three FedRF-TCA
  payloads under every codec, at the paper-scale N=512 config — including the
  headline ``W_RF`` reduction from O(N*m) dense floats to the O(1) seed-replay
  key (the same row at 4x N shows the dense payload growing 4x while the
  seed-replay payload does not move);
- Table III generalized: accuracy under increasing Bernoulli message-loss
  rates (``netsim.BernoulliScenario``) on the wire transport;
- accuracy-vs-quantization: identity vs bf16 vs int8 vs int4 vs seed-replay
  codecs end-to-end, batched engine.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from benchmarks.common import da_suite, emit
from repro.comm import BernoulliScenario, get_codec, serialized_size
from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_comm.json"

CODECS = ["float32", "float16", "bfloat16", "qint8", "qint4", "topk:0.25"]


def payload_bytes_table(cfg: ClientConfig) -> dict:
    """Exact wire bytes per payload kind per codec (analytic == serialized)."""
    f32 = np.dtype(np.float32)
    specs = {
        "moments": {"msg": ((2 * cfg.n_rff,), f32)},
        "w_rf": {"w_rf": ((2 * cfg.n_rff, cfg.m), f32)},
        "classifier": {"w": ((cfg.m, cfg.n_classes), f32), "b": ((cfg.n_classes,), f32)},
    }
    table: dict[str, dict[str, int]] = {}
    for name in CODECS:
        codec = get_codec(name)
        table[name] = {k: serialized_size(k, spec, codec) for k, spec in specs.items()}
    table["seed_replay"] = {
        "w_rf": serialized_size("w_rf", specs["w_rf"], get_codec("seed_replay"))
    }
    return table


def _train_acc(sources, target, cfg, smoke: bool = False, **kw) -> tuple[float, dict]:
    rounds = 6 if smoke else 60
    proto = ProtocolConfig(
        n_rounds=rounds, t_c=max(rounds // 4, 1), warmup_rounds=rounds,
        lr=5e-3, batch_size=48, seed=0, **kw
    )
    tr = FedRFTCATrainer(sources, target, cfg, proto)
    accs = tr.train(eval_every=max(rounds // 6, 1))
    return float(np.mean(accs[-3:])), dict(tr.comm.bytes_by_kind)


def run(smoke: bool = False) -> None:
    """Full bench by default; ``smoke=True`` shrinks every training run so CI
    can validate the emitted BENCH_comm.json schema in seconds."""
    paper_cfg = ClientConfig(input_dim=16, n_classes=5, n_rff=512, m=32, lambda_mmd=2.0)
    record: dict = {"smoke": smoke, "bytes_per_payload": payload_bytes_table(paper_cfg)}

    # headline: W_RF bytes at N and 4N — dense scales, seed-replay does not
    for scale, n_rff in (("1x", paper_cfg.n_rff), ("4x", 4 * paper_cfg.n_rff)):
        spec = {"w_rf": ((2 * n_rff, paper_cfg.m), np.dtype(np.float32))}
        dense = serialized_size("w_rf", spec, get_codec("float32"))
        seed = serialized_size("w_rf", spec, get_codec("seed_replay"))
        record[f"w_rf_bytes_{scale}"] = {"float32": dense, "seed_replay": seed}
        emit(f"comm_wire/w_rf_bytes_{scale}", 0.0,
             f"float32={dense},seed_replay={seed},ratio={dense/seed:.0f}x")

    # end-to-end curves on a small-but-trained config (batched engine)
    sources, target = da_suite(n=80 if smoke else 240)
    cfg = ClientConfig(input_dim=16, n_classes=5, n_rff=128, m=16, lambda_mmd=2.0)

    acc_id, bytes_id = _train_acc(sources, target, cfg, smoke)
    record["identity"] = {"acc": acc_id, "bytes": bytes_id}
    emit("comm_wire/identity", 0.0, f"acc={acc_id:.3f},bytes={sum(bytes_id.values())}")

    codecs = ["float32", "seed_replay"] if smoke else [
        "float32", "bfloat16", "qint8", "qint4", "seed_replay"
    ]
    codec_curve = {}
    for name in codecs:
        acc, nbytes = _train_acc(sources, target, cfg, smoke, transport="wire", codec=name)
        codec_curve[name] = {"acc": acc, "bytes": nbytes, "gap": acc_id - acc}
        emit(f"comm_wire/codec_{name}", 0.0,
             f"acc={acc:.3f},gap={acc_id-acc:+.3f},bytes={sum(nbytes.values())}")
    record["accuracy_vs_codec"] = codec_curve

    loss_curve = {}
    for p in (0.0, 0.4) if smoke else (0.0, 0.2, 0.4, 0.6):
        acc, nbytes = _train_acc(
            sources, target, cfg, smoke, transport="wire",
            scenario=BernoulliScenario(p_msg=p, p_w=p, p_c=p),
        )
        loss_curve[f"{p:.1f}"] = {"acc": acc, "bytes": nbytes}
        emit(f"comm_wire/loss_rate_{p:.1f}", 0.0,
             f"acc={acc:.3f},moment_bytes={nbytes['moments']}")
    record["accuracy_vs_loss_rate"] = loss_curve

    JSON_PATH.write_text(json.dumps(record, indent=2) + "\n")
    emit("comm_wire/json", 0.0, f"wrote={JSON_PATH.name}")


if __name__ == "__main__":
    run()
