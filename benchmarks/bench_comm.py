"""Paper Table I + Table II: communication complexity/volume accounting.

Claims checked:
 - FedRF-TCA per-round uplink is O(KN + KNm): independent of sample size n;
 - FedAvg (whole-model) exchanges ~15x more floats per round at this scale;
 - doubling the local dataset size leaves FedRF-TCA traffic unchanged.
"""
from __future__ import annotations


from benchmarks.common import da_suite, emit
from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig
from repro.federated.model import init_params
from repro.utils.tree import tree_size
import jax


def run() -> None:
    cfg = ClientConfig(input_dim=16, n_classes=5, n_rff=512, m=32)
    rounds = 10
    for scale, n in (("1x", 200), ("4x", 800)):
        sources, target = da_suite(n=n)
        proto = ProtocolConfig(n_rounds=rounds, warmup_rounds=0, t_c=5, seed=0)
        tr = FedRFTCATrainer(sources, target, cfg, proto)
        tr.train()
        per_round = tr.comm.total / rounds
        emit(
            f"table2/fedrf_floats_per_round_{scale}_data",
            0.0,
            f"total={per_round:,.0f},messages={tr.comm.data_messages/rounds:,.0f},"
            f"w_rf={tr.comm.w_rf/rounds:,.0f},clf={tr.comm.classifier/rounds:,.0f}",
        )
    # FedAvg baseline: every client ships the whole model every round
    params = init_params(cfg, jax.random.PRNGKey(0))
    model_floats = tree_size(params)
    k = 4
    emit("table2/fedavg_floats_per_round", 0.0, f"total={k * model_floats:,.0f}")
    sources, target = da_suite(n=200)
    proto = ProtocolConfig(n_rounds=rounds, warmup_rounds=0, t_c=5, seed=0)
    tr = FedRFTCATrainer(sources, target, cfg, proto)
    tr.train()
    ratio = (k * model_floats) / (tr.comm.total / rounds)
    emit("table2/fedavg_over_fedrf", 0.0, f"ratio={ratio:.1f}x")
    # Table I complexity: message floats per client = 2N, independent of n
    emit("table1/message_size", 0.0, f"2N={2*cfg.n_rff}(independent_of_n=True)")
    # Paper-scale projection (Table II uses ResNet-50 ~25.6M params/client):
    # FedAvg traffic scales with MODEL size, FedRF-TCA's with N and m only.
    resnet50 = 25_637_000
    fedrf_paper_scale = k * (2 * cfg.n_rff + 2 * cfg.n_rff * cfg.m)  # msgs + W_RF
    emit(
        "table2/paper_scale_projection", 0.0,
        f"fedavg={k*resnet50/1e6:.1f}M,fedrf={fedrf_paper_scale/1e6:.3f}M,"
        f"ratio={k*resnet50/fedrf_paper_scale:.0f}x",
    )


if __name__ == "__main__":
    run()
