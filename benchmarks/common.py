"""Shared benchmark utilities: the synthetic DA suite + CSV emission.

The container is offline, so the paper's Office/Digit-Five datasets are
replaced by the seeded multi-domain generators in repro.data.domains; every
benchmark states which paper table/figure it mirrors.
"""
from __future__ import annotations

import time

from repro.data import make_domains


def da_suite(n_domains=5, n=400, shift=1.2, seed=3):
    """K-1 sources + 1 target with strong-but-identifiable shift."""
    doms = make_domains(n_domains, n, shift=shift, seed=seed)
    return doms[:-1], doms[-1]


def emit(name: str, us_per_call: float, derived: str) -> None:
    """CSV contract required by benchmarks.run: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, (time.time() - t0) * 1e6
