"""Paper Fig. 3 + Tables X-XIII analogue: accuracy/runtime of RF-TCA vs DA
baselines (TCA, R-TCA, JDA, CORAL, DaNN, source-only) on the synthetic suite,
plus the PR-over-PR perf contract for the streaming solver and the batched
round engine.

Claims checked:
 - RF-TCA runs >=5x faster than vanilla TCA at comparable accuracy;
 - accuracy grows with the number of random features N (Fig. 3 blue circles);
 - the streaming fit (scan gram + Sherman-Morrison eigh) is >=3x faster than
   the seed dense path (materialized Sigma + Cholesky + full eigh) at
   (n=4096, N=256, m=32), with O(N^2) instead of O(N n) peak memory;
 - the batched (vmap/scan) round engine beats the serial per-client dispatch.

Emits ``BENCH_rf_tca.json`` (fit wall-times, speedup, peak-memory proxy,
solver agreement, per-round engine wall-times, accuracies) so the perf
trajectory is machine-trackable across PRs.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import da_suite, emit, timed
from repro.baselines import (
    coral_baseline,
    dann_mmd_baseline,
    jda_baseline,
    rf_tca_baseline,
    source_only,
    tca_baseline,
)

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_rf_tca.json"


def fit_perf(n: int = 4096, n_features: int = 256, m: int = 32) -> dict:
    """Streaming vs seed-dense rf_tca_fit at the acceptance shapes.

    Timing is best-of-reps (min, as in ``timeit``): the container shares
    cores, and the minimum is the least-noise estimator of a path's actual
    cost.  All paths are measured interleaved and identically.
    """
    from repro.core.rf_tca import rf_tca_fit

    rng = np.random.default_rng(0)
    p = 16
    xs = jnp.asarray(rng.normal(size=(p, n // 2)), jnp.float32)
    xt = jnp.asarray(rng.normal(size=(p, n - n // 2)) + 1.0, jnp.float32)
    kw = dict(n_features=n_features, m=m, gamma=1e-2)

    dense = lambda: rf_tca_fit(xs, xt, mode="dense", solver="cholesky", **kw).w_rf
    stream = lambda: rf_tca_fit(xs, xt, mode="stream", solver="eigh", **kw).w_rf
    lobpcg = lambda: rf_tca_fit(xs, xt, mode="stream", solver="lobpcg", **kw).w_rf
    stream()  # warm the jitted scan (compile excluded, as for any serving path)
    lobpcg()
    # timeit-style: consecutive reps per path, min of the block — each path is
    # measured at its own steady state on the shared cores
    ts: dict = {dense: [], stream: [], lobpcg: []}
    for fn, reps in ((dense, 11), (stream, 11), (lobpcg, 5)):
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts[fn].append(time.perf_counter() - t0)
    t_dense, t_stream, t_lobpcg = (min(ts[f]) for f in (dense, stream, lobpcg))

    v_dense = np.asarray(rf_tca_fit(xs, xt, mode="dense", solver="cholesky", **kw).eigvals)
    v_stream = np.asarray(rf_tca_fit(xs, xt, mode="stream", solver="eigh", **kw).eigvals)
    v_lob = np.asarray(rf_tca_fit(xs, xt, mode="stream", solver="lobpcg", **kw).eigvals)
    rel_stream = float(np.max(np.abs((v_stream - v_dense) / v_dense)))
    rel_lobpcg = float(np.max(np.abs((v_lob - v_stream) / v_stream)))

    two_n = 2 * n_features
    block = 1024
    out = {
        "shape": {"n": n, "N": n_features, "m": m, "p": p},
        "dense_s": t_dense,
        "stream_s": t_stream,
        "lobpcg_s": t_lobpcg,
        "speedup_stream_vs_dense": t_dense / t_stream,
        "eigvals_rel_err_stream_vs_dense": rel_stream,
        "eigvals_rel_err_lobpcg_vs_eigh": rel_lobpcg,
        # peak-memory proxy: largest fp32 intermediate each path materializes
        # (dense: the (2N, n) Sigma; stream: the (2N, 2N) stats + one slab)
        "memory_proxy_bytes": {
            "dense": 4 * two_n * n,
            "stream": 4 * (two_n * two_n + two_n * block),
        },
    }
    emit("fig3/fit_dense", t_dense * 1e6, f"n={n},N={n_features},m={m}")
    emit(
        "fig3/fit_stream", t_stream * 1e6,
        f"speedup_vs_dense={out['speedup_stream_vs_dense']:.1f}x,rel_err={rel_stream:.1e}",
    )
    emit("fig3/fit_lobpcg", t_lobpcg * 1e6, f"rel_err_vs_eigh={rel_lobpcg:.1e}")
    return out


def large_n_perf(n_features: int = 2048, n: int = 512) -> dict:
    """Tiled streaming-Gram kernel past the untiled VMEM ceiling.

    Times the auto-tiled Pallas path (interpret mode on CPU) against the
    tiled XLA twin at the same shape, records their relative agreement and
    the per-instance accumulator footprint the tiling buys (bounded by the
    tile, not N — the quantity the VMEM-proxy test asserts on).
    """
    from repro.core.kernels_math import ell_vector
    from repro.core.rf_tca import streaming_gram
    from repro.kernels import ops as kops

    plan = kops.gram_tile_plan(n_features)
    rng = np.random.default_rng(0)
    p = 16
    x = jnp.asarray(rng.normal(size=(p, n)), jnp.float32)
    ell = ell_vector(n // 2, n - n // 2)
    omega = jnp.asarray(rng.normal(size=(n_features, p)), jnp.float32)

    pallas = lambda: kops.rff_gram_stream(x, omega, ell)  # auto-tiled
    twin = lambda: streaming_gram(x, ell, omega, block=128, tile=plan["tile"])
    g_p, u_p = jax.block_until_ready(pallas())  # warm both compiles
    g_t, u_t = jax.block_until_ready(twin())
    ts: dict = {"pallas": [], "twin": []}
    for name, fn in (("pallas", pallas), ("twin", twin)):
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts[name].append(time.perf_counter() - t0)
    scale = float(jnp.abs(g_t).max())
    rel = float(jnp.abs(g_p - g_t).max()) / scale
    out = {
        "shape": {"n": n, "N": n_features, "p": p},
        "tile": plan["tile"],
        "tiled_pallas_s": min(ts["pallas"]),
        "tiled_twin_s": min(ts["twin"]),
        "rel_err_pallas_vs_twin": rel,
        "u_abs_err": float(jnp.abs(u_p - u_t).max()),
        # what the tiling buys: per-instance accumulator bytes vs untiled
        "acc_bytes_tiled": plan["acc_bytes"],
        "acc_bytes_untiled": kops.gram_tile_plan(n_features, tile=0)["acc_bytes"],
    }
    emit(
        "fig3/gram_large_N", out["tiled_pallas_s"] * 1e6,
        f"N={n_features},tile={plan['tile']},rel_err={rel:.1e},"
        f"acc_mem={plan['acc_bytes']/2**20:.1f}MiB",
    )
    return out


def _ulp_diff(a, b) -> int:
    """Max ULP distance between two fp32 arrays (0 == bit-for-bit)."""
    order = lambda i: np.where(i >= 0, i, np.int64(-(2**31)) - i)
    ai = order(np.asarray(a, np.float32).reshape(-1).view(np.int32).astype(np.int64))
    bi = order(np.asarray(b, np.float32).reshape(-1).view(np.int32).astype(np.int64))
    return int(np.max(np.abs(ai - bi), initial=0))


def _fused_memory_proxy(n_features: int, p: int = 16, ensemble: int = 1) -> dict:
    """Analytic peak-HBM proxy of one statistics pass at feature count N.

    Both paths hold the O(N^2) output statistics; the materialized path
    additionally keeps the (N_pad, p_pad) frequency matrix resident for the
    whole pass — the allocation the seed-fused kernels delete (the 8-byte
    seed is the weight).  Analytic so the ladder can include N far past what
    interpret-mode CI can run."""
    from repro.kernels import ops as kops

    plan = kops.gram_tile_plan(n_features)
    npad = plan["n_pad"]
    p_pad = p + (-p) % 128
    stats = 4 * (3 * npad * npad + 2 * npad * 2 * ensemble)
    omega_bytes = 4 * npad * p_pad
    return {
        "materialized": stats + omega_bytes,
        "fused": stats,
        "omega_bytes": omega_bytes,
        "tile": plan["tile"],
    }


def fused_perf(
    n_features: int = 192, n: int = 256, ensemble: int = 3,
    proxy_ns: tuple = (512, 1024, 2048, 4096, 8192),
) -> dict:
    """Seed-fused statistics pass: the tentpole evidence rows.

    - fused Pallas vs XLA generator twin at 0 ULP, untiled AND tiled layouts;
    - ensemble=1 bitwise-degenerate to the single-draw (materialized) path;
    - ensemble=S agreement with the mean-of-centered-draws dense oracle;
    - analytic peak-memory proxy ladder (fused strictly below materialized,
      the margin = the deleted omega allocation) up to N far past the sweep;
    - fused vs materialized kernel wall-time at the test shape.
    """
    import importlib

    from repro.core.kernels_math import ell_vector
    from repro.kernels import ops as kops
    from repro.kernels.prng import fused_omega
    from repro.kernels.ref import rff_gram_stream_fused_ref

    rf = importlib.import_module("repro.core.rf_tca")
    rng = np.random.default_rng(0)
    p = 16
    x = jnp.asarray(rng.normal(size=(p, n)), jnp.float32)
    ell = ell_vector(n // 2, n - n // 2)
    seed = 33
    kw = dict(n_features=n_features, seed=seed)

    # fused Pallas vs its XLA generator twin — both layouts, single draw
    g_pu, u_pu = rf.fused_streaming_gram(x, ell, use_pallas=True, **kw)
    g_xu, u_xu = rf.fused_streaming_gram(x, ell, use_pallas=False, **kw)
    ulp_untiled = max(_ulp_diff(g_pu, g_xu), _ulp_diff(u_pu, u_xu))
    g_pt, u_pt = rf.fused_streaming_gram(x, ell, use_pallas=True, tile=128, **kw)
    g_xt, u_xt = rf.fused_streaming_gram(x, ell, use_pallas=False, tile=128, **kw)
    ulp_tiled = max(_ulp_diff(g_pt, g_xt), _ulp_diff(u_pt, u_xt))

    # ensemble=1 degeneracy: the fused kernel must be bitwise the materialized
    # kernel fed the generator-twin omega (garbage-padded draws contribute
    # exact zeros, so the two programs accumulate identical floats)
    omega = fused_omega(seed, n_features, p)
    g_m, u_m = kops.rff_gram_stream(x, omega, ell)
    ens1_diff = max(
        float(jnp.abs(g_pu - g_m).max()), float(jnp.abs(u_pu - u_m).max())
    )

    # ensemble=S vs the dense mean-of-centered-draws oracle
    g_s, u_s = rf.fused_streaming_gram(x, ell, use_pallas=True, ensemble=ensemble, **kw)
    g_o, u_o = rff_gram_stream_fused_ref(x, ell, ensemble=ensemble, **kw)
    scale = float(jnp.abs(g_o).max())
    ens_rel = max(
        float(jnp.abs(g_s - g_o).max()) / scale, float(jnp.abs(u_s - u_o).max())
    )

    fused = lambda: rf.fused_streaming_gram(x, ell, use_pallas=True, **kw)
    mat = lambda: kops.rff_gram_stream(x, omega, ell)
    ts: dict = {"fused": [], "materialized": []}
    for name, fn in (("fused", fused), ("materialized", mat)):
        jax.block_until_ready(fn())
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts[name].append(time.perf_counter() - t0)

    out = {
        "shape": {"n": n, "N": n_features, "p": p, "ensemble": ensemble},
        "ulp_untiled": ulp_untiled,
        "ulp_tiled": ulp_tiled,
        "ensemble1_max_abs_diff": ens1_diff,
        "ensemble_rel_err_vs_oracle": ens_rel,
        "fused_s": min(ts["fused"]),
        "materialized_s": min(ts["materialized"]),
        "memory_proxy_bytes": {
            str(nn): _fused_memory_proxy(nn, p=p) for nn in proxy_ns
        },
    }
    emit("fig3/fused_ulp", 0.0,
         f"untiled={ulp_untiled},tiled={ulp_tiled},ens1_diff={ens1_diff:.1e}")
    emit("fig3/fused_gram", out["fused_s"] * 1e6,
         f"N={n_features},vs_materialized={out['materialized_s']/out['fused_s']:.2f}x")
    top = out["memory_proxy_bytes"][str(proxy_ns[-1])]
    emit("fig3/fused_memory", 0.0,
         f"N={proxy_ns[-1]},fused={top['fused']/2**20:.1f}MiB,"
         f"materialized={top['materialized']/2**20:.1f}MiB")
    return out


def accuracy_resweep(
    sources, target, *, n_sweep: tuple, ensemble_n: int, ensembles: tuple = (1, 4),
    seed: int = 0,
) -> dict:
    """Fig. 3 accuracy-vs-N re-sweep on the seed-fused path, now that large N
    is reachable without materializing (N, p)/(2N, n) tensors.

    Emits the tracked resolution row for the BENCH anomaly where N=500 beat
    N=1000 on the materialized sweep: with more features (and optionally
    ensemble averaging) the curve should recover, or the row records that the
    anomaly persists (solver/feature-budget limited)."""
    accs: dict = {}
    for nn in n_sweep:
        acc, t = timed(
            rf_tca_baseline, sources, target, n_features=nn, gamma=1e-3, m=16,
            w_rf=f"fused:{seed}",
        )
        accs[nn] = acc
        emit(f"fig3/rf_tca_fused_N{nn}", t, f"acc={acc:.3f}")
    ens_accs: dict = {}
    for s in ensembles:
        acc, t = timed(
            rf_tca_baseline, sources, target, n_features=ensemble_n, gamma=1e-3,
            m=16, w_rf=f"fused:{seed}", ensemble=s,
        )
        ens_accs[s] = acc
        emit(f"fig3/rf_tca_fused_N{ensemble_n}_S{s}", t, f"acc={acc:.3f}")

    ns = sorted(accs)
    small = ns[len(ns) // 2 - 1] if len(ns) > 1 else ns[0]
    acc_small = accs[small]
    best_large = max(accs[nn] for nn in ns if nn > small) if ns[-1] > small else acc_small
    status = "resolved" if best_large >= acc_small - 0.005 else "persists"
    anomaly = {
        "small_n": small,
        "acc_small_n": acc_small,
        "best_acc_larger_n": best_large,
        "status": status,
    }
    emit("fig3/claim_N_anomaly", 0.0,
         f"status={status},acc_N{small}={acc_small:.3f},best_larger={best_large:.3f}")
    return {
        "fused": {str(nn): a for nn, a in accs.items()},
        "ensemble_at_N": ensemble_n,
        "ensemble": {str(s): a for s, a in ens_accs.items()},
        "anomaly_small_vs_large_n": anomaly,
    }


def round_engine_perf(rounds: int = 10, n_per_domain: int = 400) -> dict:
    """Per-round wall-time of the serial vs batched protocol data plane."""
    from repro.data import make_domains
    from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig

    doms = make_domains(5, n_per_domain, shift=0.8, seed=0)
    cfg = ClientConfig(input_dim=16, n_classes=5, n_rff=128, m=16)
    res = {}
    for engine in ("serial", "batched"):
        proto = ProtocolConfig(
            n_rounds=rounds, t_c=5, warmup_rounds=0, seed=0, engine=engine
        )
        tr = FedRFTCATrainer(doms[:4], doms[4], cfg, proto)
        tr.round(0)  # compile
        t0 = time.perf_counter()
        tr.train()
        res[engine] = (time.perf_counter() - t0) / rounds
        emit(f"fig3/round_{engine}", res[engine] * 1e6, f"K=4,rounds={rounds}")
    res["speedup_batched_vs_serial"] = res["serial"] / res["batched"]
    emit("fig3/round_speedup", 0.0, f"batched_vs_serial={res['speedup_batched_vs_serial']:.1f}x")
    return res


def ragged_round_perf(rounds: int = 6) -> dict:
    """Ragged-K rounds: unequal per-client datasets through both planes.

    The batched plane pads each client to the max width and masks — this row
    tracks its per-round cost on heterogeneous clients plus the max parameter
    divergence from the serial reference under full participation (should sit
    at fp32 noise; the seed engine's min-truncation made the planes diverge).
    """
    from repro.data import make_domains
    from repro.data.domains import Domain
    from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig
    from repro.federated import network as fed_network
    from repro.federated.network import RoundPlan

    doms = make_domains(5, 400, shift=0.8, seed=0)
    sizes = (400, 250, 120, 40)
    sources = [
        Domain(f"rag{i}", d.x[:, :s], d.y[:s]) for i, (d, s) in enumerate(zip(doms, sizes))
    ]
    cfg = ClientConfig(input_dim=16, n_classes=5, n_rff=128, m=16)
    orig_plan = fed_network.plan_round
    fed_network.plan_round = lambda rng, n, s: RoundPlan(
        list(range(n)), list(range(n)), list(range(n))
    )
    try:
        res: dict = {"client_sizes": list(sizes)}
        trainers = {}
        for engine in ("serial", "batched"):
            proto = ProtocolConfig(
                n_rounds=rounds, t_c=5, warmup_rounds=1, batch_size=64,
                message_batch_size=256, seed=0, engine=engine,
            )
            tr = FedRFTCATrainer(sources, doms[4], cfg, proto)
            tr.round(0)  # compile
            t0 = time.perf_counter()
            tr.train()
            res[f"{engine}_s"] = (time.perf_counter() - t0) / rounds
            trainers[engine] = tr
            emit(f"fig3/ragged_round_{engine}", res[f"{engine}_s"] * 1e6,
                 f"K=4,n_k={sizes}")
        err = max(
            float(np.abs(np.asarray(a) - np.asarray(b)).max())
            for a, b in zip(
                jax.tree_util.tree_leaves(trainers["serial"].tgt_params),
                jax.tree_util.tree_leaves(trainers["batched"].tgt_params),
            )
        )
        res["speedup_batched_vs_serial"] = res["serial_s"] / res["batched_s"]
        res["max_param_divergence"] = err
        emit("fig3/ragged_round_equiv", 0.0,
             f"max_param_div={err:.1e},speedup={res['speedup_batched_vs_serial']:.1f}x")
        return res
    finally:
        fed_network.plan_round = orig_plan


def run(smoke: bool = False) -> None:
    """Full bench by default; ``smoke=True`` runs every row at tiny sizes so
    CI can validate the emitted BENCH_rf_tca.json schema in seconds."""
    record: dict = {"bench": "rf_tca", "smoke": smoke}
    if smoke:
        record["fit"] = fit_perf(n=256, n_features=64, m=8)
        record["large_n"] = large_n_perf(n_features=1280, n=128)
        record["fused"] = fused_perf(n_features=96, n=128, ensemble=2)
        record["round_engine"] = round_engine_perf(rounds=2, n_per_domain=120)
        record["ragged_rounds"] = ragged_round_perf(rounds=2)
    else:
        record["fit"] = fit_perf()
        record["large_n"] = large_n_perf()
        record["fused"] = fused_perf()
        record["round_engine"] = round_engine_perf()
        record["ragged_rounds"] = ragged_round_perf()

    sources, target = da_suite(n=60 if smoke else 400)
    acc_src, t_src = timed(source_only, sources, target, seed=0)
    emit("fig3/source_only", t_src, f"acc={acc_src:.3f}")

    acc_tca, t_tca = timed(tca_baseline, sources, target, gamma=1e-3, m=16)
    emit("fig3/tca", t_tca, f"acc={acc_tca:.3f}")

    acc_rtca, t_rtca = timed(tca_baseline, sources, target, gamma=1e-3, m=16, variant="r")
    emit("fig3/r_tca", t_rtca, f"acc={acc_rtca:.3f}")

    n_sweep = (50, 100) if smoke else (100, 500, 1000)
    accs = {}
    for n in n_sweep:
        acc, t = timed(rf_tca_baseline, sources, target, n_features=n, gamma=1e-3, m=16)
        accs[n] = acc
        emit(f"fig3/rf_tca_N{n}", t, f"acc={acc:.3f},speedup_vs_tca={t_tca/t:.1f}x")

    acc_coral, t = timed(coral_baseline, sources, target)
    emit("fig3/coral", t, f"acc={acc_coral:.3f}")
    acc_jda, t = timed(jda_baseline, sources, target, gamma=1e-3, iters=2)
    emit("fig3/jda", t, f"acc={acc_jda:.3f}")
    acc_dann, t = timed(dann_mmd_baseline, sources, target, steps=30 if smoke else 300)
    emit("fig3/dann", t, f"acc={acc_dann:.3f}")

    # paper claim: more random features never hurts much (monotone-ish)
    emit(
        "fig3/claim_N_trend", 0.0,
        f"acc_N{n_sweep[0]}={accs[n_sweep[0]]:.3f}<=~acc_N{n_sweep[-1]}={accs[n_sweep[-1]]:.3f}",
    )

    record["accuracy"] = {
        "source_only": acc_src,
        "tca": acc_tca,
        "r_tca": acc_rtca,
        **{f"rf_tca_N{n}": a for n, a in accs.items()},
        "coral": acc_coral,
        "jda": acc_jda,
        "dann": acc_dann,
    }
    # seed-fused re-sweep: large N now reachable (no (N, p)/(2N, n) tensors)
    if smoke:
        record["accuracy_resweep"] = accuracy_resweep(
            sources, target, n_sweep=(50, 100), ensemble_n=50, ensembles=(1, 2)
        )
    else:
        record["accuracy_resweep"] = accuracy_resweep(
            sources, target, n_sweep=(100, 500, 1000, 2000, 4000), ensemble_n=500
        )
    JSON_PATH.write_text(json.dumps(record, indent=2) + "\n")
    emit("fig3/json", 0.0, f"wrote={JSON_PATH.name}")


if __name__ == "__main__":
    run()
