"""Paper Fig. 3 + Tables X-XIII analogue: accuracy/runtime of RF-TCA vs DA
baselines (TCA, R-TCA, JDA, CORAL, DaNN, source-only) on the synthetic suite.

Claims checked:
 - RF-TCA runs >=5x faster than vanilla TCA at comparable accuracy;
 - accuracy grows with the number of random features N (Fig. 3 blue circles).
"""
from __future__ import annotations

from benchmarks.common import da_suite, emit, timed
from repro.baselines import (
    coral_baseline,
    dann_mmd_baseline,
    jda_baseline,
    rf_tca_baseline,
    source_only,
    tca_baseline,
)


def run() -> None:
    sources, target = da_suite()
    acc_src, t_src = timed(source_only, sources, target, seed=0)
    emit("fig3/source_only", t_src, f"acc={acc_src:.3f}")

    acc_tca, t_tca = timed(tca_baseline, sources, target, gamma=1e-3, m=16)
    emit("fig3/tca", t_tca, f"acc={acc_tca:.3f}")

    acc_rtca, t_rtca = timed(tca_baseline, sources, target, gamma=1e-3, m=16, variant="r")
    emit("fig3/r_tca", t_rtca, f"acc={acc_rtca:.3f}")

    accs = {}
    for n in (100, 500, 1000):
        acc, t = timed(rf_tca_baseline, sources, target, n_features=n, gamma=1e-3, m=16)
        accs[n] = acc
        emit(f"fig3/rf_tca_N{n}", t, f"acc={acc:.3f},speedup_vs_tca={t_tca/t:.1f}x")

    acc, t = timed(coral_baseline, sources, target)
    emit("fig3/coral", t, f"acc={acc:.3f}")
    acc, t = timed(jda_baseline, sources, target, gamma=1e-3, iters=2)
    emit("fig3/jda", t, f"acc={acc:.3f}")
    acc, t = timed(dann_mmd_baseline, sources, target, steps=300)
    emit("fig3/dann", t, f"acc={acc:.3f}")

    # paper claim: more random features never hurts much (monotone-ish)
    emit(
        "fig3/claim_N_trend", 0.0,
        f"acc_N100={accs[100]:.3f}<=~acc_N1000={accs[1000]:.3f}",
    )


if __name__ == "__main__":
    run()
