"""Paper Fig. 3 + Tables X-XIII analogue: accuracy/runtime of RF-TCA vs DA
baselines (TCA, R-TCA, JDA, CORAL, DaNN, source-only) on the synthetic suite,
plus the PR-over-PR perf contract for the streaming solver and the batched
round engine.

Claims checked:
 - RF-TCA runs >=5x faster than vanilla TCA at comparable accuracy;
 - accuracy grows with the number of random features N (Fig. 3 blue circles);
 - the streaming fit (scan gram + Sherman-Morrison eigh) is >=3x faster than
   the seed dense path (materialized Sigma + Cholesky + full eigh) at
   (n=4096, N=256, m=32), with O(N^2) instead of O(N n) peak memory;
 - the batched (vmap/scan) round engine beats the serial per-client dispatch.

Emits ``BENCH_rf_tca.json`` (fit wall-times, speedup, peak-memory proxy,
solver agreement, per-round engine wall-times, accuracies) so the perf
trajectory is machine-trackable across PRs.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import da_suite, emit, timed
from repro.baselines import (
    coral_baseline,
    dann_mmd_baseline,
    jda_baseline,
    rf_tca_baseline,
    source_only,
    tca_baseline,
)

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_rf_tca.json"


def fit_perf(n: int = 4096, n_features: int = 256, m: int = 32) -> dict:
    """Streaming vs seed-dense rf_tca_fit at the acceptance shapes.

    Timing is best-of-reps (min, as in ``timeit``): the container shares
    cores, and the minimum is the least-noise estimator of a path's actual
    cost.  All paths are measured interleaved and identically.
    """
    from repro.core.rf_tca import rf_tca_fit

    rng = np.random.default_rng(0)
    p = 16
    xs = jnp.asarray(rng.normal(size=(p, n // 2)), jnp.float32)
    xt = jnp.asarray(rng.normal(size=(p, n - n // 2)) + 1.0, jnp.float32)
    kw = dict(n_features=n_features, m=m, gamma=1e-2)

    dense = lambda: rf_tca_fit(xs, xt, mode="dense", solver="cholesky", **kw).w_rf
    stream = lambda: rf_tca_fit(xs, xt, mode="stream", solver="eigh", **kw).w_rf
    lobpcg = lambda: rf_tca_fit(xs, xt, mode="stream", solver="lobpcg", **kw).w_rf
    stream()  # warm the jitted scan (compile excluded, as for any serving path)
    lobpcg()
    # timeit-style: consecutive reps per path, min of the block — each path is
    # measured at its own steady state on the shared cores
    ts: dict = {dense: [], stream: [], lobpcg: []}
    for fn, reps in ((dense, 11), (stream, 11), (lobpcg, 5)):
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts[fn].append(time.perf_counter() - t0)
    t_dense, t_stream, t_lobpcg = (min(ts[f]) for f in (dense, stream, lobpcg))

    v_dense = np.asarray(rf_tca_fit(xs, xt, mode="dense", solver="cholesky", **kw).eigvals)
    v_stream = np.asarray(rf_tca_fit(xs, xt, mode="stream", solver="eigh", **kw).eigvals)
    v_lob = np.asarray(rf_tca_fit(xs, xt, mode="stream", solver="lobpcg", **kw).eigvals)
    rel_stream = float(np.max(np.abs((v_stream - v_dense) / v_dense)))
    rel_lobpcg = float(np.max(np.abs((v_lob - v_stream) / v_stream)))

    two_n = 2 * n_features
    block = 1024
    out = {
        "shape": {"n": n, "N": n_features, "m": m, "p": p},
        "dense_s": t_dense,
        "stream_s": t_stream,
        "lobpcg_s": t_lobpcg,
        "speedup_stream_vs_dense": t_dense / t_stream,
        "eigvals_rel_err_stream_vs_dense": rel_stream,
        "eigvals_rel_err_lobpcg_vs_eigh": rel_lobpcg,
        # peak-memory proxy: largest fp32 intermediate each path materializes
        # (dense: the (2N, n) Sigma; stream: the (2N, 2N) stats + one slab)
        "memory_proxy_bytes": {
            "dense": 4 * two_n * n,
            "stream": 4 * (two_n * two_n + two_n * block),
        },
    }
    emit("fig3/fit_dense", t_dense * 1e6, f"n={n},N={n_features},m={m}")
    emit(
        "fig3/fit_stream", t_stream * 1e6,
        f"speedup_vs_dense={out['speedup_stream_vs_dense']:.1f}x,rel_err={rel_stream:.1e}",
    )
    emit("fig3/fit_lobpcg", t_lobpcg * 1e6, f"rel_err_vs_eigh={rel_lobpcg:.1e}")
    return out


def large_n_perf(n_features: int = 2048, n: int = 512) -> dict:
    """Tiled streaming-Gram kernel past the untiled VMEM ceiling.

    Times the auto-tiled Pallas path (interpret mode on CPU) against the
    tiled XLA twin at the same shape, records their relative agreement and
    the per-instance accumulator footprint the tiling buys (bounded by the
    tile, not N — the quantity the VMEM-proxy test asserts on).
    """
    from repro.core.kernels_math import ell_vector
    from repro.core.rf_tca import streaming_gram
    from repro.kernels import ops as kops

    plan = kops.gram_tile_plan(n_features)
    rng = np.random.default_rng(0)
    p = 16
    x = jnp.asarray(rng.normal(size=(p, n)), jnp.float32)
    ell = ell_vector(n // 2, n - n // 2)
    omega = jnp.asarray(rng.normal(size=(n_features, p)), jnp.float32)

    pallas = lambda: kops.rff_gram_stream(x, omega, ell)  # auto-tiled
    twin = lambda: streaming_gram(x, ell, omega, block=128, tile=plan["tile"])
    g_p, u_p = jax.block_until_ready(pallas())  # warm both compiles
    g_t, u_t = jax.block_until_ready(twin())
    ts: dict = {"pallas": [], "twin": []}
    for name, fn in (("pallas", pallas), ("twin", twin)):
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts[name].append(time.perf_counter() - t0)
    scale = float(jnp.abs(g_t).max())
    rel = float(jnp.abs(g_p - g_t).max()) / scale
    out = {
        "shape": {"n": n, "N": n_features, "p": p},
        "tile": plan["tile"],
        "tiled_pallas_s": min(ts["pallas"]),
        "tiled_twin_s": min(ts["twin"]),
        "rel_err_pallas_vs_twin": rel,
        "u_abs_err": float(jnp.abs(u_p - u_t).max()),
        # what the tiling buys: per-instance accumulator bytes vs untiled
        "acc_bytes_tiled": plan["acc_bytes"],
        "acc_bytes_untiled": kops.gram_tile_plan(n_features, tile=0)["acc_bytes"],
    }
    emit(
        "fig3/gram_large_N", out["tiled_pallas_s"] * 1e6,
        f"N={n_features},tile={plan['tile']},rel_err={rel:.1e},"
        f"acc_mem={plan['acc_bytes']/2**20:.1f}MiB",
    )
    return out


def round_engine_perf(rounds: int = 10, n_per_domain: int = 400) -> dict:
    """Per-round wall-time of the serial vs batched protocol data plane."""
    from repro.data import make_domains
    from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig

    doms = make_domains(5, n_per_domain, shift=0.8, seed=0)
    cfg = ClientConfig(input_dim=16, n_classes=5, n_rff=128, m=16)
    res = {}
    for engine in ("serial", "batched"):
        proto = ProtocolConfig(
            n_rounds=rounds, t_c=5, warmup_rounds=0, seed=0, engine=engine
        )
        tr = FedRFTCATrainer(doms[:4], doms[4], cfg, proto)
        tr.round(0)  # compile
        t0 = time.perf_counter()
        tr.train()
        res[engine] = (time.perf_counter() - t0) / rounds
        emit(f"fig3/round_{engine}", res[engine] * 1e6, f"K=4,rounds={rounds}")
    res["speedup_batched_vs_serial"] = res["serial"] / res["batched"]
    emit("fig3/round_speedup", 0.0, f"batched_vs_serial={res['speedup_batched_vs_serial']:.1f}x")
    return res


def ragged_round_perf(rounds: int = 6) -> dict:
    """Ragged-K rounds: unequal per-client datasets through both planes.

    The batched plane pads each client to the max width and masks — this row
    tracks its per-round cost on heterogeneous clients plus the max parameter
    divergence from the serial reference under full participation (should sit
    at fp32 noise; the seed engine's min-truncation made the planes diverge).
    """
    from repro.data import make_domains
    from repro.data.domains import Domain
    from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig
    from repro.federated import network as fed_network
    from repro.federated.network import RoundPlan

    doms = make_domains(5, 400, shift=0.8, seed=0)
    sizes = (400, 250, 120, 40)
    sources = [
        Domain(f"rag{i}", d.x[:, :s], d.y[:s]) for i, (d, s) in enumerate(zip(doms, sizes))
    ]
    cfg = ClientConfig(input_dim=16, n_classes=5, n_rff=128, m=16)
    orig_plan = fed_network.plan_round
    fed_network.plan_round = lambda rng, n, s: RoundPlan(
        list(range(n)), list(range(n)), list(range(n))
    )
    try:
        res: dict = {"client_sizes": list(sizes)}
        trainers = {}
        for engine in ("serial", "batched"):
            proto = ProtocolConfig(
                n_rounds=rounds, t_c=5, warmup_rounds=1, batch_size=64,
                message_batch_size=256, seed=0, engine=engine,
            )
            tr = FedRFTCATrainer(sources, doms[4], cfg, proto)
            tr.round(0)  # compile
            t0 = time.perf_counter()
            tr.train()
            res[f"{engine}_s"] = (time.perf_counter() - t0) / rounds
            trainers[engine] = tr
            emit(f"fig3/ragged_round_{engine}", res[f"{engine}_s"] * 1e6,
                 f"K=4,n_k={sizes}")
        err = max(
            float(np.abs(np.asarray(a) - np.asarray(b)).max())
            for a, b in zip(
                jax.tree_util.tree_leaves(trainers["serial"].tgt_params),
                jax.tree_util.tree_leaves(trainers["batched"].tgt_params),
            )
        )
        res["speedup_batched_vs_serial"] = res["serial_s"] / res["batched_s"]
        res["max_param_divergence"] = err
        emit("fig3/ragged_round_equiv", 0.0,
             f"max_param_div={err:.1e},speedup={res['speedup_batched_vs_serial']:.1f}x")
        return res
    finally:
        fed_network.plan_round = orig_plan


def run(smoke: bool = False) -> None:
    """Full bench by default; ``smoke=True`` runs every row at tiny sizes so
    CI can validate the emitted BENCH_rf_tca.json schema in seconds."""
    record: dict = {"bench": "rf_tca", "smoke": smoke}
    if smoke:
        record["fit"] = fit_perf(n=256, n_features=64, m=8)
        record["large_n"] = large_n_perf(n_features=1280, n=128)
        record["round_engine"] = round_engine_perf(rounds=2, n_per_domain=120)
        record["ragged_rounds"] = ragged_round_perf(rounds=2)
    else:
        record["fit"] = fit_perf()
        record["large_n"] = large_n_perf()
        record["round_engine"] = round_engine_perf()
        record["ragged_rounds"] = ragged_round_perf()

    sources, target = da_suite(n=60 if smoke else 400)
    acc_src, t_src = timed(source_only, sources, target, seed=0)
    emit("fig3/source_only", t_src, f"acc={acc_src:.3f}")

    acc_tca, t_tca = timed(tca_baseline, sources, target, gamma=1e-3, m=16)
    emit("fig3/tca", t_tca, f"acc={acc_tca:.3f}")

    acc_rtca, t_rtca = timed(tca_baseline, sources, target, gamma=1e-3, m=16, variant="r")
    emit("fig3/r_tca", t_rtca, f"acc={acc_rtca:.3f}")

    n_sweep = (50, 100) if smoke else (100, 500, 1000)
    accs = {}
    for n in n_sweep:
        acc, t = timed(rf_tca_baseline, sources, target, n_features=n, gamma=1e-3, m=16)
        accs[n] = acc
        emit(f"fig3/rf_tca_N{n}", t, f"acc={acc:.3f},speedup_vs_tca={t_tca/t:.1f}x")

    acc_coral, t = timed(coral_baseline, sources, target)
    emit("fig3/coral", t, f"acc={acc_coral:.3f}")
    acc_jda, t = timed(jda_baseline, sources, target, gamma=1e-3, iters=2)
    emit("fig3/jda", t, f"acc={acc_jda:.3f}")
    acc_dann, t = timed(dann_mmd_baseline, sources, target, steps=30 if smoke else 300)
    emit("fig3/dann", t, f"acc={acc_dann:.3f}")

    # paper claim: more random features never hurts much (monotone-ish)
    emit(
        "fig3/claim_N_trend", 0.0,
        f"acc_N{n_sweep[0]}={accs[n_sweep[0]]:.3f}<=~acc_N{n_sweep[-1]}={accs[n_sweep[-1]]:.3f}",
    )

    record["accuracy"] = {
        "source_only": acc_src,
        "tca": acc_tca,
        "r_tca": acc_rtca,
        **{f"rf_tca_N{n}": a for n, a in accs.items()},
        "coral": acc_coral,
        "jda": acc_jda,
        "dann": acc_dann,
    }
    JSON_PATH.write_text(json.dumps(record, indent=2) + "\n")
    emit("fig3/json", 0.0, f"wrote={JSON_PATH.name}")


if __name__ == "__main__":
    run()
