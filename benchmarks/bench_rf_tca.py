"""Paper Fig. 3 + Tables X-XIII analogue: accuracy/runtime of RF-TCA vs DA
baselines (TCA, R-TCA, JDA, CORAL, DaNN, source-only) on the synthetic suite,
plus the PR-over-PR perf contract for the streaming solver and the batched
round engine.

Claims checked:
 - RF-TCA runs >=5x faster than vanilla TCA at comparable accuracy;
 - accuracy grows with the number of random features N (Fig. 3 blue circles);
 - the streaming fit (scan gram + Sherman-Morrison eigh) is >=3x faster than
   the seed dense path (materialized Sigma + Cholesky + full eigh) at
   (n=4096, N=256, m=32), with O(N^2) instead of O(N n) peak memory;
 - the batched (vmap/scan) round engine beats the serial per-client dispatch.

Emits ``BENCH_rf_tca.json`` (fit wall-times, speedup, peak-memory proxy,
solver agreement, per-round engine wall-times, accuracies) so the perf
trajectory is machine-trackable across PRs.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import da_suite, emit, timed
from repro.baselines import (
    coral_baseline,
    dann_mmd_baseline,
    jda_baseline,
    rf_tca_baseline,
    source_only,
    tca_baseline,
)

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_rf_tca.json"


def fit_perf(n: int = 4096, n_features: int = 256, m: int = 32) -> dict:
    """Streaming vs seed-dense rf_tca_fit at the acceptance shapes.

    Timing is best-of-reps (min, as in ``timeit``): the container shares
    cores, and the minimum is the least-noise estimator of a path's actual
    cost.  All paths are measured interleaved and identically.
    """
    from repro.core.rf_tca import rf_tca_fit

    rng = np.random.default_rng(0)
    p = 16
    xs = jnp.asarray(rng.normal(size=(p, n // 2)), jnp.float32)
    xt = jnp.asarray(rng.normal(size=(p, n - n // 2)) + 1.0, jnp.float32)
    kw = dict(n_features=n_features, m=m, gamma=1e-2)

    dense = lambda: rf_tca_fit(xs, xt, mode="dense", solver="cholesky", **kw).w_rf
    stream = lambda: rf_tca_fit(xs, xt, mode="stream", solver="eigh", **kw).w_rf
    lobpcg = lambda: rf_tca_fit(xs, xt, mode="stream", solver="lobpcg", **kw).w_rf
    stream()  # warm the jitted scan (compile excluded, as for any serving path)
    lobpcg()
    # timeit-style: consecutive reps per path, min of the block — each path is
    # measured at its own steady state on the shared cores
    ts: dict = {dense: [], stream: [], lobpcg: []}
    for fn, reps in ((dense, 11), (stream, 11), (lobpcg, 5)):
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts[fn].append(time.perf_counter() - t0)
    t_dense, t_stream, t_lobpcg = (min(ts[f]) for f in (dense, stream, lobpcg))

    v_dense = np.asarray(rf_tca_fit(xs, xt, mode="dense", solver="cholesky", **kw).eigvals)
    v_stream = np.asarray(rf_tca_fit(xs, xt, mode="stream", solver="eigh", **kw).eigvals)
    v_lob = np.asarray(rf_tca_fit(xs, xt, mode="stream", solver="lobpcg", **kw).eigvals)
    rel_stream = float(np.max(np.abs((v_stream - v_dense) / v_dense)))
    rel_lobpcg = float(np.max(np.abs((v_lob - v_stream) / v_stream)))

    two_n = 2 * n_features
    block = 1024
    out = {
        "shape": {"n": n, "N": n_features, "m": m, "p": p},
        "dense_s": t_dense,
        "stream_s": t_stream,
        "lobpcg_s": t_lobpcg,
        "speedup_stream_vs_dense": t_dense / t_stream,
        "eigvals_rel_err_stream_vs_dense": rel_stream,
        "eigvals_rel_err_lobpcg_vs_eigh": rel_lobpcg,
        # peak-memory proxy: largest fp32 intermediate each path materializes
        # (dense: the (2N, n) Sigma; stream: the (2N, 2N) stats + one slab)
        "memory_proxy_bytes": {
            "dense": 4 * two_n * n,
            "stream": 4 * (two_n * two_n + two_n * block),
        },
    }
    emit("fig3/fit_dense", t_dense * 1e6, f"n={n},N={n_features},m={m}")
    emit(
        "fig3/fit_stream", t_stream * 1e6,
        f"speedup_vs_dense={out['speedup_stream_vs_dense']:.1f}x,rel_err={rel_stream:.1e}",
    )
    emit("fig3/fit_lobpcg", t_lobpcg * 1e6, f"rel_err_vs_eigh={rel_lobpcg:.1e}")
    return out


def round_engine_perf(rounds: int = 10) -> dict:
    """Per-round wall-time of the serial vs batched protocol data plane."""
    from repro.data import make_domains
    from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig

    doms = make_domains(5, 400, shift=0.8, seed=0)
    cfg = ClientConfig(input_dim=16, n_classes=5, n_rff=128, m=16)
    res = {}
    for engine in ("serial", "batched"):
        proto = ProtocolConfig(
            n_rounds=rounds, t_c=5, warmup_rounds=0, seed=0, engine=engine
        )
        tr = FedRFTCATrainer(doms[:4], doms[4], cfg, proto)
        tr.round(0)  # compile
        t0 = time.perf_counter()
        tr.train()
        res[engine] = (time.perf_counter() - t0) / rounds
        emit(f"fig3/round_{engine}", res[engine] * 1e6, f"K=4,rounds={rounds}")
    res["speedup_batched_vs_serial"] = res["serial"] / res["batched"]
    emit("fig3/round_speedup", 0.0, f"batched_vs_serial={res['speedup_batched_vs_serial']:.1f}x")
    return res


def run() -> None:
    record: dict = {"bench": "rf_tca"}
    record["fit"] = fit_perf()
    record["round_engine"] = round_engine_perf()

    sources, target = da_suite()
    acc_src, t_src = timed(source_only, sources, target, seed=0)
    emit("fig3/source_only", t_src, f"acc={acc_src:.3f}")

    acc_tca, t_tca = timed(tca_baseline, sources, target, gamma=1e-3, m=16)
    emit("fig3/tca", t_tca, f"acc={acc_tca:.3f}")

    acc_rtca, t_rtca = timed(tca_baseline, sources, target, gamma=1e-3, m=16, variant="r")
    emit("fig3/r_tca", t_rtca, f"acc={acc_rtca:.3f}")

    accs = {}
    for n in (100, 500, 1000):
        acc, t = timed(rf_tca_baseline, sources, target, n_features=n, gamma=1e-3, m=16)
        accs[n] = acc
        emit(f"fig3/rf_tca_N{n}", t, f"acc={acc:.3f},speedup_vs_tca={t_tca/t:.1f}x")

    acc_coral, t = timed(coral_baseline, sources, target)
    emit("fig3/coral", t, f"acc={acc_coral:.3f}")
    acc_jda, t = timed(jda_baseline, sources, target, gamma=1e-3, iters=2)
    emit("fig3/jda", t, f"acc={acc_jda:.3f}")
    acc_dann, t = timed(dann_mmd_baseline, sources, target, steps=300)
    emit("fig3/dann", t, f"acc={acc_dann:.3f}")

    # paper claim: more random features never hurts much (monotone-ish)
    emit(
        "fig3/claim_N_trend", 0.0,
        f"acc_N100={accs[100]:.3f}<=~acc_N1000={accs[1000]:.3f}",
    )

    record["accuracy"] = {
        "source_only": acc_src,
        "tca": acc_tca,
        "r_tca": acc_rtca,
        **{f"rf_tca_N{n}": a for n, a in accs.items()},
        "coral": acc_coral,
        "jda": acc_jda,
        "dann": acc_dann,
    }
    JSON_PATH.write_text(json.dumps(record, indent=2) + "\n")
    emit("fig3/json", 0.0, f"wrote={JSON_PATH.name}")


if __name__ == "__main__":
    run()
