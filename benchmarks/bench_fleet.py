"""Fleet-scale federation benchmark — the ``repro.fleet`` scaling record.

Claims measured (and recorded in ``BENCH_fleet.json``):

- **scaling** — rounds/sec of the batched + chunked (sharded) plane at
  K in {8, 64, 256, 1024} simulated clients (smoke: {8, 64}), with the
  per-device working-set proxy of the local-step stage under the
  ``client_chunk`` scan vs the unchunked vmap — the O(chunk)-not-O(K) claim,
  measured from the jaxpr exactly like the kernel VMEM proxies of PR 3;
- **server ingress, flat vs two-tier** — exact wire bytes entering the
  server per round: K per-client uplinks (flat, analytic — identity
  accounting is analytic by construction) against the measured E merged edge
  uplinks (two-tier), per payload kind.  The CI gate requires two-tier
  strictly below flat from K = 64 up;
- **two-tier exactness** — max parameter divergence of an E=K identity-codec
  two-tier run (every merge through the hierarchy: segment sums, pooled
  moments, masses) from the flat batched engine, gated <= 1e-3 by the smoke
  schema (the unit tests pin <= 1e-6);
- **accuracy vs edge codec** — the tier-2 (edge -> server backhaul) codec
  swept at fixed tier-1 float32: what edge compression costs end-to-end.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import emit
from repro.comm import wire
from repro.comm.netsim import TraceScenario
from repro.data import make_domains
from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig
from repro.federated.model import make_omega, source_loss
from repro.federated.network import RoundPlan
from repro.fleet import Topology, chunked_vmap, working_set_proxy
from repro.optim import adam, apply_updates

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_fleet.json"


def _leaf_div(a, b) -> float:
    return max(
        float(np.abs(np.asarray(x) - np.asarray(y)).max())
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


def _full_trace(k: int, rounds: int) -> TraceScenario:
    ids = list(range(k))
    return TraceScenario([RoundPlan(ids, ids, ids)] * rounds, cycle=True)


def _fleet(k: int, n: int, dim: int, n_classes: int, seed: int = 0):
    doms = make_domains(k + 1, n, dim=dim, n_classes=n_classes, shift=0.6, seed=seed)
    return doms[:k], doms[k]


def _local_step_proxies(cfg, k: int, chunk: int, batch: int) -> tuple[int, int]:
    """Working-set proxy (bytes) of the per-client local-step stage, chunked
    vs unchunked — traced on the same grad+Adam body the engine scans."""
    omega = make_omega(cfg)
    opt = adam(1e-2)
    from repro.federated.model import init_params

    one = init_params(cfg, jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(
        lambda x: np.broadcast_to(np.asarray(x)[None], (k,) + x.shape), one
    )
    opt_state = jax.vmap(opt.init)(jax.tree_util.tree_map(np.asarray, params))
    x = np.zeros((k, cfg.input_dim, batch), np.float32) + 0.1
    y = np.zeros((k, batch), np.int32)
    gates = np.ones((k,), np.float32)
    tmsg = np.zeros((2 * cfg.n_rff,), np.float32)

    def one_client(p, o, xi, yi, gate):
        grads = jax.grad(
            lambda pp: source_loss(pp, omega, xi, yi, tmsg, cfg, mmd_gate=gate)[0]
        )(p)
        upd, o = opt.update(grads, o, p)
        return apply_updates(p, upd), o

    args = (params, opt_state, x, y, gates)
    axes = (0, 0, 0, 0, 0)
    ws_chunk = working_set_proxy(chunked_vmap(one_client, axes, chunk=chunk), *args)
    ws_full = working_set_proxy(chunked_vmap(one_client, axes, chunk=None), *args)
    return ws_chunk, ws_full


def _flat_ingress_per_round(trainer, k: int) -> dict[str, int]:
    """Analytic flat server ingress of one full-participation round (what the
    identity transport would account): one uplink per client per kind."""
    return {
        kind: k * wire.serialized_size(kind, spec, trainer.transport.codecs[kind])
        for kind, spec in trainer._specs.items()
    }


def run(smoke: bool = False) -> None:
    """Full bench by default; ``smoke=True`` shrinks K and the run lengths so
    CI can validate the emitted BENCH_fleet.json schema in seconds."""
    record: dict = {"smoke": smoke}
    cfg_small = ClientConfig(
        input_dim=8, n_classes=3, n_rff=32, m=8, extractor_widths=(16, 8)
    )

    # -- scaling: rounds/sec + working-set proxy vs K ------------------------
    ks = (8, 64) if smoke else (8, 64, 256, 1024)
    n_per = 16 if smoke else 24
    batch = 8
    timed_rounds = 2
    scaling: dict[str, dict] = {}
    ingress: dict[str, dict] = {}
    for k in ks:
        chunk = min(16 if smoke else 128, k)
        n_edges = max(k // 16, 1)
        sources, target = _fleet(k, n_per, cfg_small.input_dim, cfg_small.n_classes)
        proto = ProtocolConfig(
            n_rounds=timed_rounds + 1, t_c=2, warmup_rounds=0, batch_size=batch,
            message_batch_size=batch, client_chunk=chunk,
            topology=Topology.uniform(k, n_edges),
            scenario=_full_trace(k, timed_rounds + 1), seed=0,
        )
        tr = FedRFTCATrainer(sources, target, cfg_small, proto)
        tr.round(1)  # compile
        flat_per_round = _flat_ingress_per_round(tr, k)
        before = dict(tr.ingress_bytes)
        t0 = time.time()
        for t in range(2, timed_rounds + 2):
            tr.round(t)
        dt = (time.time() - t0) / timed_rounds
        two_tier = {
            kind: (tr.ingress_bytes[kind] - before[kind]) // timed_rounds
            for kind in before
        }
        ws_chunk, ws_full = _local_step_proxies(cfg_small, k, chunk, batch)
        scaling[str(k)] = {
            "k": k,
            "chunk": chunk,
            "n_edges": n_edges,
            "round_s": dt,
            "rounds_per_s": 1.0 / max(dt, 1e-9),
            "working_set_bytes_chunked": ws_chunk,
            "working_set_bytes_full": ws_full,
        }
        # classifier only syncs on t % t_c == 0 rounds; compare the kinds
        # every round carries (moments + w_rf) plus the classifier row
        ingress[str(k)] = {
            "flat_per_round": flat_per_round,
            "two_tier_per_round": two_tier,
            "flat_total": sum(flat_per_round[kd] for kd in ("moments", "w_rf")),
            "two_tier_total": sum(two_tier[kd] for kd in ("moments", "w_rf")),
        }
        emit(
            f"fleet/scale_k{k}", dt * 1e6,
            f"rounds_per_s={1.0 / max(dt, 1e-9):.2f},chunk={chunk},"
            f"ws_chunked={ws_chunk},ws_full={ws_full}",
        )
        emit(
            f"fleet/ingress_k{k}", 0.0,
            f"flat={ingress[str(k)]['flat_total']},"
            f"two_tier={ingress[str(k)]['two_tier_total']}",
        )
    record["scaling"] = scaling
    record["ingress"] = ingress
    record["max_k"] = max(ks)

    # -- two-tier exactness: E=K identity codecs vs the flat engine ----------
    k, rounds = 4, 3 if smoke else 6
    sources, target = _fleet(k, 80, cfg_small.input_dim, cfg_small.n_classes, seed=1)
    kw = dict(
        n_rounds=rounds, t_c=2, warmup_rounds=1, batch_size=32, seed=0,
        scenario=_full_trace(k, rounds),
    )
    tr_flat = FedRFTCATrainer(sources, target, cfg_small, ProtocolConfig(**kw))
    tr_flat.train()
    tr_two = FedRFTCATrainer(
        sources, target, cfg_small,
        ProtocolConfig(topology=Topology.singleton(k), **kw),
    )
    tr_two.train()
    div = max(
        _leaf_div(tr_flat.tgt_params, tr_two.tgt_params),
        _leaf_div(tr_flat._src_stack, tr_two._src_stack),
    )
    record["two_tier"] = {
        "max_param_divergence": div,
        "clients": k,
        "n_edges": k,
        "rounds": rounds,
    }
    emit("fleet/two_tier_divergence", 0.0, f"divergence={div:.2e}")

    # -- accuracy vs edge codec (tier-2 compression) -------------------------
    k, rounds = 8, 6 if smoke else 40
    cfg_acc = ClientConfig(input_dim=16, n_classes=5, n_rff=64, m=16, lambda_mmd=2.0)
    sources, target = _fleet(k, 60 if smoke else 200, 16, 5, seed=3)
    curve: dict[str, dict] = {}
    for codec in ("float32", "bfloat16", "qint8"):
        proto = ProtocolConfig(
            n_rounds=rounds, t_c=max(rounds // 3, 1), warmup_rounds=rounds,
            batch_size=32, lr=5e-3, seed=0, transport="wire",
            topology=Topology.uniform(k, 2), edge_codec=codec,
            scenario=_full_trace(k, rounds),
        )
        tr = FedRFTCATrainer(sources, target, cfg_acc, proto)
        tr.train()
        acc = float(tr.evaluate())
        curve[codec] = {
            "acc": acc,
            "edge_uplink_bytes": tr.edge_transport.log.bytes_total,
        }
        emit(f"fleet/edge_codec_{codec}", 0.0, f"acc={acc:.3f}")
    record["edge_codec_curve"] = curve

    JSON_PATH.write_text(json.dumps(record, indent=2) + "\n")
    emit("fleet/json", 0.0, f"wrote={JSON_PATH.name}")


if __name__ == "__main__":
    run()
