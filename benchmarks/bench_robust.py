"""Robustness benchmark — the fault-injection / defense record.

Claims measured (and recorded in ``BENCH_robust.json``):

- **degeneracy** — the AggregationRule refactor is invisible when unused:
  ``rule="mean"`` + a no-op :class:`FaultConfig` reproduces the default
  trainer's parameters bitwise (``max_param_divergence`` gated <= 1e-6 by the
  CI smoke; the unit test pins it at 0.0);
- **accuracy vs corruption rate** — per-payload value corruption (NaN
  injection, 100x scaling) at increasing rates, plain weighted mean against
  every robust rule (finite-guard mean, norm-clip, coordinate trimmed-mean,
  geometric median).  NaN corruption deterministically poisons the mean
  (one corrupted uplink -> NaN parameters -> chance accuracy) while the
  robust rules quarantine it;
- **accuracy vs Byzantine count** — persistent sign-flipping adversaries at
  crafted 10x magnitude; robust rules hold while the mean degrades, up to
  the f < K/2 breakdown point;
- **recovery time vs checkpoint interval** — the fedsim AsyncScheduler with
  a scheduled :class:`ServerCrashed` event: virtual-time rollback (crash
  time minus last checkpoint) stays within one checkpoint interval, and the
  crashed run still completes its flush budget.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from benchmarks.common import da_suite, emit
from repro.comm.netsim import TraceScenario
from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig
from repro.federated.network import RoundPlan
from repro.fedsim import AsyncConfig, AsyncScheduler
from repro.robust import FaultConfig

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_robust.json"

ALL_RULES = ("mean", "finite_mean", "norm_clip", "trimmed_mean", "geomedian")


def _leaf_div(a, b) -> float:
    import jax

    return max(
        float(np.abs(np.asarray(x) - np.asarray(y)).max())
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


def _trainer(sources, target, cfg, rounds, *, rule="mean", faults=None, seed=0):
    ids = list(range(len(sources)))
    proto = ProtocolConfig(
        n_rounds=rounds, t_c=max(rounds // 4, 1), warmup_rounds=rounds, lr=5e-3,
        batch_size=48, seed=seed, rule=rule, faults=faults,
        scenario=TraceScenario([RoundPlan(ids, ids, ids)] * rounds, cycle=True),
    )
    return FedRFTCATrainer(sources, target, cfg, proto)


def run(smoke: bool = False) -> None:
    """Full bench by default; ``smoke=True`` shrinks every sweep so CI can
    validate the emitted BENCH_robust.json schema in seconds."""
    rounds = 8 if smoke else 50
    sources, target = da_suite(n=80 if smoke else 240)
    k = len(sources)
    cfg = ClientConfig(input_dim=16, n_classes=5, n_rff=128, m=16, lambda_mmd=2.0)
    record: dict = {"smoke": smoke, "n_clients": k, "rounds": rounds}

    # -- degeneracy: rule="mean" + no-op faults == the untouched pipeline ----
    tr_ref = _trainer(sources, target, cfg, rounds)
    tr_ref.train()
    tr_deg = _trainer(sources, target, cfg, rounds, rule="mean", faults=FaultConfig())
    tr_deg.train()
    div = max(
        _leaf_div(tr_ref.tgt_params, tr_deg.tgt_params),
        _leaf_div(tr_ref._src_stack, tr_deg._src_stack),
    )
    clean_acc = float(tr_ref.evaluate())
    record["degeneracy"] = {"max_param_divergence": div}
    record["clean_baseline_acc"] = clean_acc
    emit("robust/degeneracy", 0.0, f"divergence={div:.2e},clean_acc={clean_acc:.3f}")

    # -- accuracy vs corruption rate, per mode, mean vs every robust rule ----
    modes = ("nan",) if smoke else ("nan", "scale")
    rates = (0.5,) if smoke else (0.1, 0.25, 0.5)
    rules = ("mean", "trimmed_mean") if smoke else ALL_RULES
    corruption: dict[str, dict] = {}
    for mode in modes:
        by_rate: dict[str, dict] = {}
        for rate in rates:
            faults = FaultConfig(
                corrupt_moments=rate, corrupt_w_rf=rate, corrupt_classifier=rate,
                corruption=mode,
            )
            row: dict[str, float] = {}
            for rule in rules:
                tr = _trainer(sources, target, cfg, rounds, rule=rule, faults=faults)
                tr.train()
                row[rule] = float(tr.evaluate())
            by_rate[f"{rate:.2f}"] = row
            emit(
                f"robust/corrupt_{mode}_{rate:.2f}", 0.0,
                ",".join(f"{r}={row[r]:.3f}" for r in rules),
            )
        corruption[mode] = by_rate
    record["corruption"] = corruption

    # -- accuracy vs Byzantine count (persistent sign-flip adversaries) ------
    byz_counts = (1,) if smoke else tuple(range(1, (k - 1) // 2 + 1))
    byzantine: dict[str, dict] = {}
    for n_byz in byz_counts:
        faults = FaultConfig(
            byzantine=tuple(range(n_byz)), byzantine_mode="sign_flip",
            byzantine_scale=10.0,
        )
        row = {}
        for rule in rules:
            tr = _trainer(sources, target, cfg, rounds, rule=rule, faults=faults)
            tr.train()
            row[rule] = float(tr.evaluate())
        byzantine[str(n_byz)] = row
        emit(
            f"robust/byzantine_{n_byz}", 0.0,
            ",".join(f"{r}={row[r]:.3f}" for r in rules),
        )
    record["byzantine"] = byzantine

    # -- recovery time vs checkpoint interval (fedsim server crash) ----------
    n_flushes = 10 if smoke else 20
    intervals = (3.0,) if smoke else (2.0, 5.0, 10.0)
    buf = max(k // 2, 1)
    # with uniform 1s compute and no links the server completes k/buf flushes
    # per virtual second; crash mid-run so recovery is actually exercised
    crash_t = 0.5 * n_flushes * buf / k
    recovery: dict[str, dict] = {}
    for interval in intervals:
        tr = _trainer(sources, target, cfg, rounds)
        sched = AsyncScheduler(
            tr,
            AsyncConfig(
                buffer_size=buf, compute_s=1.0,
                server_crash_times=(crash_t,),
                checkpoint_interval_s=interval,
            ),
        )
        sched.run(n_flushes)
        rec = sched.recoveries[0]
        recovery[f"{interval:.1f}"] = {
            "checkpoint_interval_s": interval,
            "rollback_s": rec["rollback_s"],
            "restored_flush": rec["restored_flush"],
            "flushes_completed": sched.flushes,
            "recovered": sched.flushes >= n_flushes,
        }
        emit(
            f"robust/recovery_{interval:.1f}", 0.0,
            f"rollback={rec['rollback_s']:.2f}s,flushes={sched.flushes}",
        )
    record["recovery"] = recovery

    JSON_PATH.write_text(json.dumps(record, indent=2) + "\n")
    emit("robust/json", 0.0, f"wrote={JSON_PATH.name}")


if __name__ == "__main__":
    run()
