"""Paper App. D Tables XIV/XV: RF-TCA with Laplace vs Gaussian kernels.

Claim checked: RF-TCA is kernel-agnostic — Cauchy-drawn RFFs (Laplace kernel)
produce comparable adaptation accuracy to the Gaussian default.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import da_suite, emit, timed
from repro.baselines.classifiers import fit_mlp, score
from repro.baselines.da_methods import _concat, _unit
from repro.core.rf_tca import rf_tca


def _run_kernel(sources, target, kernel: str) -> float:
    """Best accuracy over a small sigma grid — the paper's App. D protocol
    (they search sigma in {5..15} per kernel; Cauchy-drawn omegas need a
    larger bandwidth than Gaussian ones for the same data scale)."""
    src = _unit(_concat(sources))
    tgt = _unit(target)
    best = 0.0
    for sigma in (1.0, 3.0, 6.0):
        f_s, f_t, _ = rf_tca(
            jnp.asarray(src.x), jnp.asarray(tgt.x),
            n_features=512, m=16, gamma=1e-3, sigma=sigma, seed=0, kernel=kernel,
        )
        fs, ft = np.asarray(f_s).T, np.asarray(f_t).T
        mu = np.mean(np.concatenate([fs, ft]), 0, keepdims=True)
        sd = np.std(np.concatenate([fs, ft]), 0, keepdims=True) + 1e-8
        pred = fit_mlp((fs - mu) / sd, src.y, int(src.y.max()) + 1, seed=0)
        best = max(best, score(pred, (ft - mu) / sd, tgt.y))
    return best


def run() -> None:
    sources, target = da_suite()
    accs = {}
    for kernel in ("gauss", "laplace"):
        acc, t = timed(_run_kernel, sources, target, kernel)
        accs[kernel] = acc
        emit(f"table14/rf_tca_{kernel}", t, f"acc={acc:.3f}")
    emit(
        "table14/claim_kernel_agnostic", 0.0,
        f"|gauss-laplace|={abs(accs['gauss']-accs['laplace']):.3f}",
    )


if __name__ == "__main__":
    run()
