"""Observability benchmark — the telemetry overhead & fidelity record.

Claims measured (and recorded in ``BENCH_obs.json``):

- **overhead** — fully-on telemetry (live metrics registry + tracer +
  in-graph health probes) against the no-op default, best-of-block
  rounds/sec on the batched sync plane; the recorded ``slowdown`` is gated
  at <= 5% by the CI smoke;
- **degeneracy** — telemetry off vs fully on is *bitwise*: probes add
  auxiliary outputs to the compiled planes but never feed back into the
  parameter computation, and metrics/tracing live entirely host-side.
  Gated at exactly 0.0 for both engines;
- **sentinel** — the compiled planes trace exactly once per run
  (``engine.round`` across a sync run, ``engine.flush`` across an async run
  that crosses a server crash + recovery): telemetry keeps every plane at
  one dispatch;
- **trace export** — an async run with Markov churn, heterogeneous links, a
  scheduled server crash, checkpointing and time-triggered evals exports
  ``trace_obs.json``: the dispatch -> uplink -> flush -> crash -> recovery
  timeline in virtual time, Perfetto-viewable and schema-validated.  A small
  fully-sampled serving segment rides in the same trace, so the export also
  carries complete per-request span trees (queue-wait -> batch-assembly ->
  padded-dispatch), gated by ``validate_trace_file``'s request-tree check.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import da_suite, emit
from repro.comm.netsim import LinkModel, LinkScenario, TraceScenario
from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig
from repro.federated.network import RoundPlan
from repro.fedsim import AsyncConfig, AsyncScheduler, SyncScheduler, markov_trace
from repro.obs import (
    MetricsRegistry,
    RequestTracer,
    Tracer,
    count_request_trees,
    sentinel,
    use_registry,
    use_tracer,
    validate_trace_file,
)
from repro.serve import AlignerServer, run_open_loop, synth_requests

ROOT = Path(__file__).resolve().parent.parent
JSON_PATH = ROOT / "BENCH_obs.json"
TRACE_PATH = ROOT / "trace_obs.json"


def _leaf_div(a, b) -> float:
    import jax

    return max(
        float(np.abs(np.asarray(x) - np.asarray(y)).max())
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


def _params_of(tr):
    """(tgt_params, per-source params) for either engine's state layout."""
    src = tr._src_stack if getattr(tr, "_src_stack", None) is not None else tr.src_params
    return tr.tgt_params, src


def _trainer(
    sources, target, cfg, rounds, *, seed=0, probe=False, engine="batched",
    batch_size=48,
):
    k = len(sources)
    ids = list(range(k))
    proto = ProtocolConfig(
        n_rounds=rounds, t_c=max(rounds // 4, 1), warmup_rounds=rounds, lr=5e-3,
        batch_size=batch_size, seed=seed, engine=engine, probe=probe,
        scenario=TraceScenario([RoundPlan(ids, ids, ids)] * rounds, cycle=True),
    )
    return FedRFTCATrainer(sources, target, cfg, proto)


def _timed_block(sched, block: int) -> float:
    """Rounds/sec of one block.  The timed region blocks on the trainer
    state so the measurement covers *completed* rounds — without it the
    telemetry-off side would only be timing jax's async dispatch enqueue,
    an unfairly fast baseline."""
    import jax

    t0 = time.perf_counter()
    sched.run(block)
    jax.block_until_ready(_params_of(sched.trainer))
    return block / (time.perf_counter() - t0)


def run(smoke: bool = False) -> None:
    """Full bench by default; ``smoke=True`` shrinks every run so CI can
    validate the emitted BENCH_obs.json schema in seconds."""
    rounds = 8 if smoke else 40
    block = 8 if smoke else 15
    sources, target = da_suite(n=80 if smoke else 240)
    k = len(sources)
    cfg = ClientConfig(input_dim=16, n_classes=5, n_rff=128, m=16, lambda_mmd=2.0)
    record: dict = {"smoke": smoke, "n_clients": k, "rounds": rounds}

    # -- overhead: fully-on telemetry vs the no-op default -------------------
    # measured at a realistic per-round workload (the per-round telemetry
    # cost is fixed, so a toy round would overstate the relative overhead);
    # each side compiles its own plane variant (probe=True adds outputs), so
    # warm up with untimed rounds before the timed blocks
    sources_h, target_h = da_suite(n=240)
    cfg_h = ClientConfig(input_dim=16, n_classes=5, n_rff=256, m=32, lambda_mmd=2.0)
    tr_off = _trainer(sources_h, target_h, cfg_h, rounds, batch_size=192)
    s_off = SyncScheduler(tr_off)
    tr_on = _trainer(sources_h, target_h, cfg_h, rounds, probe=True, batch_size=192)
    s_on = SyncScheduler(tr_on)
    s_off.run(2)  # compile + warm both plane variants before timing
    with use_registry(MetricsRegistry()), use_tracer(Tracer()):
        s_on.run(2)
    # time off/on as adjacent pairs and gate on the best paired ratio:
    # machine noise (bursty co-tenants, GC) hits both halves of a pair
    # alike, where best-of-off vs best-of-on would let one lucky
    # telemetry-off block masquerade as telemetry overhead
    rps_off = rps_on = best_ratio = 0.0
    for _ in range(5):
        off = _timed_block(s_off, block)
        with use_registry(MetricsRegistry()), use_tracer(Tracer()):
            on = _timed_block(s_on, block)
        rps_off, rps_on = max(rps_off, off), max(rps_on, on)
        best_ratio = max(best_ratio, on / off)
    slowdown = max(0.0, 1.0 - best_ratio)
    record["overhead"] = {
        "rounds_per_s_off": rps_off,
        "rounds_per_s_on": rps_on,
        "slowdown": slowdown,
    }
    emit(
        "obs/overhead", 0.0,
        f"off={rps_off:.2f}rps,on={rps_on:.2f}rps,slowdown={slowdown:.3f}",
    )

    # -- degeneracy: telemetry off vs fully on is bitwise (both engines) -----
    degeneracy: dict[str, float] = {}
    sentinel_rec: dict[str, int] = {}
    for engine, deg_rounds in (("batched", rounds), ("serial", 4 if smoke else 8)):
        tr_a = _trainer(sources, target, cfg, deg_rounds, engine=engine)
        SyncScheduler(tr_a).run(deg_rounds, eval_every=deg_rounds)
        tr_b = _trainer(
            sources, target, cfg, deg_rounds, engine=engine, probe=True
        )
        before = sentinel.count("engine.round")
        with use_registry(MetricsRegistry()), use_tracer(Tracer()):
            SyncScheduler(tr_b).run(deg_rounds, eval_every=deg_rounds)
        if engine == "batched":
            sentinel_rec["round_traces"] = sentinel.count("engine.round") - before
        (tgt_a, src_a), (tgt_b, src_b) = _params_of(tr_a), _params_of(tr_b)
        div = max(_leaf_div(tgt_a, tgt_b), _leaf_div(src_a, src_b))
        degeneracy[f"{engine}_max_param_divergence"] = div
        emit(f"obs/degeneracy_{engine}", 0.0, f"divergence={div:.2e}")
    record["degeneracy"] = degeneracy

    # -- trace export: churn + crash + checkpoint + eval, one async run ------
    flushes = 10 if smoke else 30
    links = [LinkModel(latency_s=0.1, bandwidth_bps=1e6) for _ in range(k)]
    links[-1] = LinkModel(latency_s=2.0, bandwidth_bps=1e5)
    avail = markov_trace(k, horizon=1e4, mean_on=10.0, mean_off=3.0, seed=11)
    tr = _trainer(sources, target, cfg, flushes, probe=True)
    sched = AsyncScheduler(
        tr,
        AsyncConfig(
            buffer_size=2, staleness="polynomial", eval_interval=2.0,
            server_crash_times=(6.0,), checkpoint_interval_s=3.0,
            restart_delay_s=1.0,
        ),
        availability=avail,
        links=LinkScenario(links=list(links)),
    )
    tracer = Tracer()
    reg = MetricsRegistry()
    before_flush = sentinel.count("engine.flush")
    with use_registry(reg), use_tracer(tracer):
        sched.run(flushes, eval_every=2)
        # serving segment in the same trace: fully-sampled request span
        # trees (rate 1.0 is test/bench-only) alongside the training spans
        srv = AlignerServer(capacity=2, min_bucket=4, max_bucket=16,
                            sentinel_prefix="obs.serve")
        rng = np.random.default_rng(21)
        xs = rng.standard_normal((8, 60)).astype(np.float32)
        xt = (rng.standard_normal((8, 50)) + 0.9).astype(np.float32)
        srv.fit_domain(("src", "tgt"), xs, xt, n_features=16, m=4, seed=0)
        srv.attach(request_tracer=RequestTracer(rate=1.0))
        srv.warmup(("src", "tgt"))
        run_open_loop(
            srv,
            synth_requests([("src", "tgt")], dim=8, n_requests=8, seed=22,
                           cols_lo=4, cols_hi=12),
            rate=500.0, seed=23,
        )
    sentinel_rec["flush_traces"] = sentinel.count("engine.flush") - before_flush
    record["sentinel"] = sentinel_rec
    tracer.write(TRACE_PATH)
    spans: dict[str, int] = {}
    for ev in tracer.events:
        if ev["ph"] in ("B", "X", "i"):
            spans[ev["name"]] = spans.get(ev["name"], 0) + 1
    snap = reg.snapshot()
    record["trace"] = {
        "file": TRACE_PATH.name,
        "n_events": len(tracer.events),
        "spans": spans,
        "request_trees": count_request_trees(tracer.events),
        "validation_errors": validate_trace_file(
            TRACE_PATH, require_request_trees=1
        ),
        "virtual_time": sched.clock.now,
        "server_crashes": len(sched.recoveries),
    }
    record["metrics_sample"] = {
        "fedsim.flushes": snap.get("counters", {}).get("fedsim.flushes", {}),
        "fedsim.server_crashes": snap.get("counters", {}).get(
            "fedsim.server_crashes", {}
        ),
    }
    emit(
        "obs/trace", 0.0,
        f"events={len(tracer.events)},flushes={sched.flushes},"
        f"crashes={len(sched.recoveries)},errors={len(record['trace']['validation_errors'])}",
    )

    JSON_PATH.write_text(json.dumps(record, indent=2) + "\n")
    emit("obs/json", 0.0, f"wrote={JSON_PATH.name}+{TRACE_PATH.name}")


if __name__ == "__main__":
    run()
