"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the JSON records
produced by repro.launch.dryrun and repro.launch.roofline_sweep.

    PYTHONPATH=src python -m benchmarks.report > experiments/tables.md
"""
from __future__ import annotations

import glob
import json
import os


def _fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def load(pattern: str) -> list[dict]:
    out = []
    for p in sorted(glob.glob(pattern)):
        with open(p) as f:
            rec = json.load(f)
        rec["_opt"] = "_opt" in os.path.basename(p)
        out.append(rec)
    return out


def dryrun_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | kind | params | compile s | args/dev | temp/dev | collectives |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        kinds = ",".join(
            k.split("-")[1][:3] if "-" in k else k
            for k, v in r["roofline"]["coll_by_kind"].items() if v > 0
        ) or "-"
        # memory_analysis is PER-DEVICE for the SPMD module
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['kind']} "
            f"| {r['params_total']/1e9:.2f}B | {r['compile_s']} "
            f"| {_fmt_bytes(r['memory']['argument_bytes'])} "
            f"| {_fmt_bytes(r['memory']['temp_bytes'])} | {kinds} |"
        )
    return "\n".join(lines)


def roofline_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS/HLO | note |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        rf = r["roofline"]
        note = "OPTIMIZED" if r.get("_opt") else ""
        if r["shape"] == "long_500k" and "mamba" not in r["arch"] and "zamba" not in r["arch"]:
            note = (note + " window=4096").strip()
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']*1e3:.2f}ms "
            f"| {rf['memory_s']*1e3:.2f}ms | {rf['collective_s']*1e3:.2f}ms "
            f"| **{rf['dominant']}** | {r['useful_flops_ratio']:.2f} | {note} |"
        )
    return "\n".join(lines)


def main() -> None:
    dr = load("experiments/dryrun/*_16x16.json") + load("experiments/dryrun/*_2x16x16.json")
    dr = [r for r in dr]
    print("## §Dry-run (all arch x shape x mesh combos, full depth, scanned)\n")
    print(dryrun_table(dr))
    rl = load("experiments/roofline/*.json")
    print("\n## §Roofline (single-pod, depth-extrapolated unrolled cost analysis)\n")
    print(roofline_table(rl))


if __name__ == "__main__":
    main()
