"""Paper Tables IV/V/VI analogue: federated multi-source DA leaderboard on the
synthetic suite (source-only / FedAvg / TCA / RF-TCA / FedRF-TCA).

Claims checked:
 - FedRF-TCA beats source-only and plain FedAvg under domain shift;
 - FedRF-TCA is competitive with (transductive, centralised) TCA while only
   ever exchanging O(KN) messages.
"""
from __future__ import annotations

from benchmarks.common import da_suite, emit, timed
from repro.baselines import fedavg_baseline, rf_tca_baseline, source_only, tca_baseline
from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig

CFG = ClientConfig(input_dim=16, n_classes=5, n_rff=128, m=16, lambda_mmd=2.0)


def run() -> None:
    rows = {}
    for seed in (3, 11):
        sources, target = da_suite(seed=seed)
        acc, t = timed(source_only, sources, target, seed=0)
        rows.setdefault("source_only", []).append(acc)
        emit(f"table5/source_only_seed{seed}", t, f"acc={acc:.3f}")
        acc, t = timed(fedavg_baseline, sources, target, CFG, rounds=150, lr=5e-3)
        rows.setdefault("fedavg", []).append(acc)
        emit(f"table5/fedavg_seed{seed}", t, f"acc={acc:.3f}")
        acc, t = timed(tca_baseline, sources, target, gamma=1e-3, m=16)
        rows.setdefault("tca", []).append(acc)
        emit(f"table5/tca_seed{seed}", t, f"acc={acc:.3f}")
        acc, t = timed(rf_tca_baseline, sources, target, n_features=512, gamma=1e-3, m=16)
        rows.setdefault("rf_tca", []).append(acc)
        emit(f"table5/rf_tca_seed{seed}", t, f"acc={acc:.3f}")
        proto = ProtocolConfig(n_rounds=150, t_c=25, warmup_rounds=150, lr=5e-3, seed=0)
        tr = FedRFTCATrainer(sources, target, CFG, proto)
        accs, t = timed(tr.train, eval_every=150)
        rows.setdefault("fedrf_tca", []).append(accs[-1])
        emit(f"table5/fedrf_tca_seed{seed}", t, f"acc={accs[-1]:.3f}")
    avg = {k: sum(v) / len(v) for k, v in rows.items()}
    emit(
        "table5/claim_fedrf_beats_no_adaptation", 0.0,
        f"fedrf={avg['fedrf_tca']:.3f}>src={avg['source_only']:.3f},fedavg={avg['fedavg']:.3f}",
    )


if __name__ == "__main__":
    run()
