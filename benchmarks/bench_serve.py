"""Serving benchmark — the adaptation-as-a-service latency/throughput record.

Claims measured (and recorded in ``BENCH_serve.json``):

- **load curve** — p50/p99 latency and achieved throughput of the aligner
  server under an open-loop Poisson arrival process at several offered
  loads (>= 3 levels in the full run), driven through the fedsim virtual
  clock with *measured* wall-clock service times: higher load fills the
  dispatcher's buckets, so throughput climbs until the single server
  saturates and queueing blows up the tail — the classic open-loop story;
- **batching** — the requests-per-dispatch and bucket-width histograms of
  the batching dispatcher across the whole run;
- **cache** — store hit rate with more live domain pairs than store
  capacity: LRU misses re-solve in the request path and the bench survives;
- **admission** — a new client admitted over the real wire (CRC frames,
  codec, retries) gets an aligner whose transforms agree with a
  from-scratch refit to <= 1e-3 while no cached version changes and no
  refit runs (the refit-free gate);
- **sentinel** — each (mode, bucket) compiled plane traces exactly once
  across warmup + every load level: batched serving never silently
  retraces.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.rf_tca import fused_omega_cache_info, rf_tca_fit, rf_tca_transform
from repro.obs import sentinel
from repro.serve import AlignerServer, run_open_loop, synth_requests

ROOT = Path(__file__).resolve().parent.parent
JSON_PATH = ROOT / "BENCH_serve.json"


def _domain_pair(seed: int, dim: int, n: int):
    rng = np.random.default_rng(seed)
    xs = rng.standard_normal((dim, n)).astype(np.float32)
    xt = (rng.standard_normal((dim, n - n // 8)) + 0.9).astype(np.float32)
    return xs, xt


def run(smoke: bool = False) -> dict:
    dim = 8 if smoke else 16
    n = 120 if smoke else 480
    fit_kw = dict(n_features=32 if smoke else 128, m=8 if smoke else 16, seed=0)
    n_pairs, capacity = 4, 3  # one more live pair than capacity: real misses
    rates = [300.0, 1200.0] if smoke else [250.0, 1000.0, 4000.0]
    n_requests = 60 if smoke else 400

    server = AlignerServer(capacity=capacity, min_bucket=8, max_bucket=64)
    pairs = [("src", f"tgt{i}") for i in range(n_pairs)]
    domains = {}
    for i, pair in enumerate(pairs):
        xs, xt = _domain_pair(100 + i, dim, n)
        domains[pair] = (xs, xt)
        server.fit_domain(pair, xs, xt, **fit_kw)

    # -- sentinel gate opens before ANY serving dispatch ---------------------
    before = sentinel.counts()
    server.warmup(pairs[0])  # all pairs share shapes, so all share planes
    # cache statistics should describe the load runs, not the warmup
    server.store.hits = server.store.misses = server.store.evictions = 0

    # -- admission: refit-free, over the real wire ---------------------------
    rng = np.random.default_rng(7)
    x_new = rng.standard_normal((dim, 64)).astype(np.float32)
    pair0 = pairs[0]
    v_before = server.store.latest_version(pair0)
    refits_before = server.refits
    adm = server.admit(pair0, x_new, role="source", sender=42)
    assert adm.delivered, "admission wire legs must deliver (no faults injected)"
    scratch = rf_tca_fit(
        jnp.asarray(domains[pair0][0]), jnp.asarray(domains[pair0][1]),
        w_rf=f"fused:{server.fused_seed}", **fit_kw,
    )
    probe = rng.standard_normal((dim, 25)).astype(np.float32)
    served = np.asarray(rf_tca_transform(adm.state, jnp.asarray(probe)))
    refit = np.asarray(rf_tca_transform(scratch, jnp.asarray(probe)))
    admission = {
        "max_divergence_vs_refit": float(np.max(np.abs(served - refit))),
        "store_version_changed": server.store.latest_version(pair0) != v_before,
        "refit_ran": server.refits != refits_before,
        "bytes_up": adm.bytes_up,
        "bytes_down": adm.bytes_down,
        "moments_merged": server.store.get(pair0).stats.admitted,
    }
    emit("serve_admission_divergence", 0.0, f"{admission['max_divergence_vs_refit']:.2e}")

    # -- open-loop Poisson load sweep ----------------------------------------
    load_curve = {}
    for li, rate in enumerate(rates):
        reqs = synth_requests(
            pairs, dim=dim, n_requests=n_requests, seed=10 + li,
            cols_lo=4, cols_hi=24,
        )
        res = run_open_loop(server, reqs, rate=rate, seed=20 + li)
        s = res.summary()
        load_curve[f"{rate:g}"] = s
        emit(
            f"serve_load_{rate:g}rps", s["p50_ms"] * 1e3,
            f"p99={s['p99_ms']:.2f}ms thru={s['throughput_rps']:.0f}rps "
            f"batch={s['mean_batch']:.1f}",
        )
    top = load_curve[f"{rates[-1]:g}"]
    saturation = {
        "offered_rps": rates[-1],
        "throughput_rps": top["throughput_rps"],
    }

    # -- gates: one trace per bucket rung, memoized fused omega --------------
    after = sentinel.counts()
    traces_per_bucket = {
        plane: after[plane] - before.get(plane, 0)
        for plane in after
        if plane.startswith("serve.") and after[plane] != before.get(plane, 0)
    }
    sentinel.assert_stable(before, tuple(traces_per_bucket), expect=1)

    record = {
        "smoke": smoke,
        "config": {
            "dim": dim, "n": n, **fit_kw, "n_pairs": n_pairs,
            "capacity": capacity, "min_bucket": 8, "max_bucket": 64,
            "n_requests_per_level": n_requests,
        },
        "load_curve": load_curve,
        "saturation": saturation,
        "batch_histogram": server.dispatcher.histogram(),
        "cache": server.store.snapshot(),
        "refits_in_path": server.refits - refits_before,
        "admission": admission,
        "sentinel": {"traces_per_bucket": traces_per_bucket},
        "fused_omega": fused_omega_cache_info(),
        "wire": {
            "bytes_total": int(server.admission.transport.log.bytes_total),
            "rejects_total": int(server.admission.transport.log.rejects_total),
        },
    }
    JSON_PATH.write_text(json.dumps(record, indent=2, sort_keys=True))
    emit("serve_record", 0.0, f"wrote {JSON_PATH.name}")
    return record


if __name__ == "__main__":
    run()
