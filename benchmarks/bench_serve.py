"""Serving benchmark — the adaptation-as-a-service latency/throughput record.

Claims measured (and recorded in ``BENCH_serve.json``):

- **load curve** — p50/p99 latency and achieved throughput of the aligner
  server under an open-loop Poisson arrival process at several offered
  loads (>= 3 levels in the full run), driven through the fedsim virtual
  clock with *measured* wall-clock service times: higher load fills the
  dispatcher's buckets, so throughput climbs until the single server
  saturates and queueing blows up the tail — the classic open-loop story;
- **batching** — the requests-per-dispatch and bucket-width histograms of
  the batching dispatcher across the whole run;
- **cache** — store hit rate with more live domain pairs than store
  capacity: LRU misses re-solve in the request path and the bench survives;
- **admission** — a new client admitted over the real wire (CRC frames,
  codec, retries) gets an aligner whose transforms agree with a
  from-scratch refit to <= 1e-3 while no cached version changes and no
  refit runs (the refit-free gate);
- **observability** — fully-on request telemetry (per-request span trees +
  SLO engine + drift monitor over the probed dispatch planes) against the
  all-off default: paired wall-clock slowdown gated at <= 5%, and the served
  outputs off-vs-on gated *bitwise* at exactly 0.0;
- **SLO** — a latency objective calibrated from the calm run fires
  multi-window burn-rate violations under deliberate overload, and the PR-7
  quarantine ledger surfaces through the same engine as an availability
  objective naming the worst-trimmed member;
- **drift** — a covariate shift injected mid-stream into the request
  distribution is detected by the RF-MMD monitor (detection latency in
  virtual time), triggers the moment-space auto-refresh (exactly one version
  bump per fire), and the refreshed aligner re-centers the drifted target
  where the stale one cannot; the refresh from chunk-pooled streamed
  moments matches a one-shot moment re-solve to <= 1e-3;
- **sentinel** — each (mode, bucket) compiled plane traces exactly once
  across warmup + every load level: batched serving never silently
  retraces.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.rf_tca import (
    fused_omega_cache_info,
    fused_transform_omega,
    rf_tca_fit,
    rf_tca_transform,
)
from repro.core.rff import rff_features
from repro.obs import (
    DriftMonitor,
    MetricsRegistry,
    RequestTracer,
    Slo,
    SloEngine,
    Tracer,
    count_request_trees,
    emit_probes,
    quarantine_slo,
    sentinel,
    use_registry,
    use_tracer,
)
from repro.robust import get_rule
from repro.serve import (
    AlignerServer,
    Request,
    poisson_arrivals,
    run_open_loop,
    synth_requests,
)

ROOT = Path(__file__).resolve().parent.parent
JSON_PATH = ROOT / "BENCH_serve.json"


def _domain_pair(seed: int, dim: int, n: int):
    rng = np.random.default_rng(seed)
    xs = rng.standard_normal((dim, n)).astype(np.float32)
    xt = (rng.standard_normal((dim, n - n // 8)) + 0.9).astype(np.float32)
    return xs, xt


def run(smoke: bool = False, *, service_scale: float = 1.0) -> dict:
    dim = 8 if smoke else 16
    n = 120 if smoke else 480
    fit_kw = dict(n_features=32 if smoke else 128, m=8 if smoke else 16, seed=0)
    n_pairs, capacity = 4, 3  # one more live pair than capacity: real misses
    rates = [300.0, 1200.0] if smoke else [250.0, 1000.0, 4000.0]
    n_requests = 60 if smoke else 400

    server = AlignerServer(capacity=capacity, min_bucket=8, max_bucket=64)
    # always-on request tracer: a no-op without an ambient tracer, but under
    # ``benchmarks.run --profile`` the profile tracer receives the full
    # queue-wait / batch-assembly / padded-dispatch trees of the load sweep
    server.attach(request_tracer=RequestTracer(rate=1.0))
    pairs = [("src", f"tgt{i}") for i in range(n_pairs)]
    domains = {}
    for i, pair in enumerate(pairs):
        xs, xt = _domain_pair(100 + i, dim, n)
        domains[pair] = (xs, xt)
        server.fit_domain(pair, xs, xt, **fit_kw)

    # -- sentinel gate opens before ANY serving dispatch ---------------------
    before = sentinel.counts()
    server.warmup(pairs[0])  # all pairs share shapes, so all share planes
    # cache statistics should describe the load runs, not the warmup
    server.store.hits = server.store.misses = server.store.evictions = 0

    # -- admission: refit-free, over the real wire ---------------------------
    rng = np.random.default_rng(7)
    x_new = rng.standard_normal((dim, 64)).astype(np.float32)
    pair0 = pairs[0]
    v_before = server.store.latest_version(pair0)
    refits_before = server.refits
    adm = server.admit(pair0, x_new, role="source", sender=42)
    assert adm.delivered, "admission wire legs must deliver (no faults injected)"
    scratch = rf_tca_fit(
        jnp.asarray(domains[pair0][0]), jnp.asarray(domains[pair0][1]),
        w_rf=f"fused:{server.fused_seed}", **fit_kw,
    )
    probe = rng.standard_normal((dim, 25)).astype(np.float32)
    served = np.asarray(rf_tca_transform(adm.state, jnp.asarray(probe)))
    refit = np.asarray(rf_tca_transform(scratch, jnp.asarray(probe)))
    admission = {
        "max_divergence_vs_refit": float(np.max(np.abs(served - refit))),
        "store_version_changed": server.store.latest_version(pair0) != v_before,
        "refit_ran": server.refits != refits_before,
        "bytes_up": adm.bytes_up,
        "bytes_down": adm.bytes_down,
        "moments_merged": server.store.get(pair0).stats.admitted,
    }
    emit("serve_admission_divergence", 0.0, f"{admission['max_divergence_vs_refit']:.2e}")

    # -- open-loop Poisson load sweep ----------------------------------------
    load_curve = {}
    for li, rate in enumerate(rates):
        reqs = synth_requests(
            pairs, dim=dim, n_requests=n_requests, seed=10 + li,
            cols_lo=4, cols_hi=24,
        )
        res = run_open_loop(server, reqs, rate=rate, seed=20 + li,
                            service_scale=service_scale)
        s = res.summary()
        load_curve[f"{rate:g}"] = s
        emit(
            f"serve_load_{rate:g}rps", s["p50_ms"] * 1e3,
            f"p99={s['p99_ms']:.2f}ms thru={s['throughput_rps']:.0f}rps "
            f"batch={s['mean_batch']:.1f}",
        )
    top = load_curve[f"{rates[-1]:g}"]
    saturation = {
        "offered_rps": rates[-1],
        "throughput_rps": top["throughput_rps"],
    }

    # -- observability: fully-on overhead + bitwise degeneracy ---------------
    # twin one-pair servers on distinct sentinel prefixes: "off" runs bare,
    # "on" runs the whole stack (head-sampled request tracer, SLO engine,
    # armed drift monitor -> probed dispatch planes) under a live registry
    # and tracer.  Sized at a fixed realistic per-dispatch workload even in
    # smoke — the per-batch telemetry cost is fixed, so a toy dispatch would
    # overstate the relative overhead (same rationale as bench_obs).
    # Requests match the fit target distribution so the armed monitor never
    # fires mid-measurement (a refresh would be real work, not telemetry
    # overhead, and would break the bitwise comparison).
    dim_o, n_o = 64, 480
    fit_kw_o = dict(n_features=512, m=16, seed=0)
    sample_rate = 0.1  # production-style head sampling for the overhead gate
    xs_o, xt_o = _domain_pair(300, dim_o, n_o)
    pair_o = ("src", "obs")
    off_srv = AlignerServer(capacity=2, min_bucket=64, max_bucket=256,
                            sentinel_prefix="serve.off")
    off_srv.fit_domain(pair_o, xs_o, xt_o, **fit_kw_o)
    on_srv = AlignerServer(capacity=2, min_bucket=64, max_bucket=256,
                           sentinel_prefix="serve.on")
    on_srv.fit_domain(pair_o, xs_o, xt_o, **fit_kw_o)
    on_eng = SloEngine([Slo("serve.latency", target=0.9, bound=10.0,
                            window_fast_s=0.05, window_slow_s=0.5)])
    on_srv.attach(
        request_tracer=RequestTracer(rate=sample_rate),
        slo=on_eng,
        drift=DriftMonitor(alpha=0.15, window=4, k_consecutive=2, threshold=0.5),
    )
    off_srv.warmup(pair_o)
    on_srv.warmup(pair_o)  # probe planes (moment hook is set) compile here
    on_srv.rearm_drift()  # warmup dummy batches must not pollute the EWMA
    obs_reqs = synth_requests([pair_o], dim=dim_o, n_requests=40, seed=30,
                              cols_lo=96, cols_hi=224, shift=0.9)
    deg_reqs = synth_requests([pair_o], dim=dim_o, n_requests=16, seed=31,
                              cols_lo=96, cols_hi=224, shift=0.9)

    def _outputs(srv):
        done = srv.serve([Request(x=r.x, key=r.key, mode=r.mode, id=r.id)
                          for r in deg_reqs])
        return {req.id: np.asarray(out) for req, out in done}

    out_off = _outputs(off_srv)
    with use_registry(MetricsRegistry()), use_tracer(Tracer()):
        out_on = _outputs(on_srv)
    obs_degeneracy = max(
        float(np.abs(out_off[i] - out_on[i]).max()) for i in out_off
    )
    # paired wall timing: machine noise hits both halves of a pair alike
    obs_rate = 400.0
    run_open_loop(off_srv, obs_reqs, rate=obs_rate, seed=32,
                  service_scale=service_scale)  # untimed warm pass
    with use_registry(MetricsRegistry()), use_tracer(Tracer()):
        run_open_loop(on_srv, obs_reqs, rate=obs_rate, seed=32,
                      service_scale=service_scale)
    best_ratio = 0.0
    for _ in range(7):
        t0 = time.perf_counter()
        run_open_loop(off_srv, obs_reqs, rate=obs_rate, seed=32,
                      service_scale=service_scale)
        t_off = time.perf_counter() - t0
        with use_registry(MetricsRegistry()), use_tracer(Tracer()):
            t0 = time.perf_counter()
            run_open_loop(on_srv, obs_reqs, rate=obs_rate, seed=32,
                          service_scale=service_scale)
            t_on = time.perf_counter() - t0
        best_ratio = max(best_ratio, t_off / t_on)
    obs_slowdown = max(0.0, 1.0 - best_ratio)
    # span-tree fidelity: one fully-sampled run (rate 1.0 is test-only),
    # then count complete request trees straight off the exported events
    tree_tracer = Tracer()
    on_srv.reqtrace.rate = 1.0
    with use_registry(MetricsRegistry()), use_tracer(tree_tracer):
        run_open_loop(on_srv, obs_reqs, rate=obs_rate, seed=32,
                      service_scale=service_scale)
    on_srv.reqtrace.rate = sample_rate
    obs = {
        "workload": {"dim": dim_o, **fit_kw_o, "cols": [96, 224],
                     "n_requests": 40, "rate_rps": obs_rate},
        "sample_rate": sample_rate,
        "slowdown": obs_slowdown,
        "best_paired_ratio": best_ratio,
        "repeats": 7,
        "degeneracy": obs_degeneracy,
        "request_tracing": {
            "sampled": on_srv.reqtrace.sampled_total,
            "emitted": on_srv.reqtrace.emitted,
            "complete_trees": count_request_trees(tree_tracer.events),
            "events": len(tree_tracer.events),
        },
    }
    emit("serve_obs_overhead", 0.0,
         f"slowdown={obs_slowdown:.3f} degeneracy={obs_degeneracy:.1e} "
         f"trees={obs['request_tracing']['complete_trees']}")

    # -- SLO: calm-calibrated latency objective fires under overload ---------
    # calm run on the bare twin at a rate its ~4ms dispatches absorb without
    # queueing; the objective's bound is 3x that calm p50, then a 40x rate
    # burst drives sustained queueing far past the bound
    calm_res = run_open_loop(
        off_srv,
        synth_requests([pair_o], dim=dim_o, n_requests=40, seed=42,
                       cols_lo=96, cols_hi=224, shift=0.9),
        rate=100.0, seed=43, service_scale=service_scale,
    )
    calm_p50_s = calm_res.summary()["p50_ms"] / 1e3
    bound_s = 3.0 * calm_p50_s
    eng = SloEngine([
        Slo("serve.latency", target=0.9, bound=bound_s,
            window_fast_s=0.05, window_slow_s=0.25, min_samples=3),
        quarantine_slo(max_rate=0.5, window_fast_s=0.03, window_slow_s=0.12),
    ])
    off_srv.attach(slo=eng)
    overload_rps = 4000.0
    over_reqs = synth_requests([pair_o], dim=dim_o, n_requests=48, seed=40,
                               cols_lo=96, cols_hi=224, shift=0.9)
    run_open_loop(off_srv, over_reqs, rate=overload_rps, seed=41,
                  service_scale=service_scale)
    lat_violations = [v for v in eng.history if v.objective == "serve.latency"]
    # quarantine-ledger plumbing: a finite-guard rule repeatedly quarantines
    # one member's NaN update; the ledger surfaces as an availability SLO
    qreg = MetricsRegistry()
    rule = get_rule("finite_mean")
    bad_vals = np.ones((5, 4), np.float32)
    bad_vals[2, 1] = np.nan
    q_rounds = 6
    for r in range(q_rounds):
        att = rule.attribution(jnp.asarray(bad_vals), jnp.ones(5, jnp.float32))
        emit_probes({"attribution_moments": att}, plane="round", registry=qreg)
        eng.feed_quarantine(r * 0.01, objective="robust.quarantine_rate",
                            rounds=r + 1, registry=qreg)
    q_violations = [v for v in eng.history
                    if v.objective == "robust.quarantine_rate"]
    slo_rec = {
        "objectives": [
            {"name": s.name, "target": s.target, "bound": s.bound,
             "kind": s.kind, "window_fast_s": s.window_fast_s,
             "window_slow_s": s.window_slow_s,
             "burn_threshold": s.burn_threshold, "min_samples": s.min_samples}
            for s in eng.objectives()
        ],
        "calm_p50_ms": calm_p50_s * 1e3,
        "bound_ms": bound_s * 1e3,
        "overload_rps": overload_rps,
        "n_violations": len(lat_violations),
        "quarantine": {
            "rounds": q_rounds,
            "n_violations": len(q_violations),
            "worst_member": (q_violations[0].detail if q_violations else None),
        },
        "timeline": [v.to_dict() for v in eng.history],
    }
    emit("serve_slo", 0.0,
         f"violations={len(lat_violations)} bound={bound_s * 1e3:.2f}ms "
         f"quarantine={slo_rec['quarantine']['worst_member']}")

    # -- drift: injected covariate shift -> detection -> auto-refresh --------
    # fixed geometry in both modes: the detection contrast (drift-vs-calm
    # RF-MMD) and the calm noise floor are properties of the feature map and
    # the shift magnitude, not of the run size — this configuration's calm
    # false-fire rate and detection margin are what was validated, so the
    # full run only lengthens the stream (more calm windows, more post-shift
    # windows), never changes the statistic's scale
    dim_d, n_d = 8, 120
    fit_kw_d = dict(n_features=32, m=8, seed=0)
    xs_d, xt_d = _domain_pair(400, dim_d, n_d)
    pair_d = ("src", "drift")
    drift_srv = AlignerServer(capacity=2, min_bucket=8, max_bucket=64,
                              sentinel_prefix="serve.drift")
    drift_srv.fit_domain(pair_d, xs_d, xt_d, **fit_kw_d)
    mon = DriftMonitor(alpha=0.15, window=4, k_consecutive=2,
                       calibration_windows=3, threshold_scale=4.0,
                       burnin_windows=2)
    drift_srv.attach(drift=mon)
    drift_srv.warmup(pair_d)
    drift_srv.rearm_drift()
    stale_state = drift_srv.store.get(pair_d).state  # the no-refresh twin
    calm_n, drift_n = (110, 60) if smoke else (200, 110)
    drift_rate = 800.0
    calm_reqs = synth_requests([pair_d], dim=dim_d, n_requests=calm_n, seed=50,
                               cols_lo=8, cols_hi=24, shift=0.9)
    shift_reqs = synth_requests([pair_d], dim=dim_d, n_requests=drift_n, seed=51,
                                cols_lo=8, cols_hi=24, shift=3.9)
    # the shift lands mid-stream: arrival calm_n of the (recomputable)
    # Poisson schedule is the injection instant, in virtual time
    injection_t = float(
        poisson_arrivals(drift_rate, calm_n + drift_n, seed=52)[calm_n]
    )
    v_before_drift = drift_srv.store.latest_version(pair_d)
    run_open_loop(drift_srv, calm_reqs + shift_reqs, rate=drift_rate, seed=52,
                  service_scale=service_scale)
    fired = [r for r in mon.history if r.fired]
    detection_t = fired[0].t if fired else float("nan")
    bumps = drift_srv.store.latest_version(pair_d) - v_before_drift
    # accuracy: does the refreshed aligner re-center the drifted target?
    probe_rng = np.random.default_rng(53)
    probe_drift = (probe_rng.standard_normal((dim_d, 40)) + 3.9).astype(np.float32)

    def _disc(state) -> float:
        zs = np.asarray(rf_tca_transform(state, jnp.asarray(xs_d)))
        zt = np.asarray(rf_tca_transform(state, jnp.asarray(probe_drift)))
        return float(np.sum((zs.mean(axis=1) - zt.mean(axis=1)) ** 2))

    disc_stale = _disc(stale_state)
    disc_refreshed = _disc(drift_srv.store.get(pair_d).state)
    # refresh equivalence: re-solving from a chunk-pooled streamed moment
    # matches the one-shot moment re-solve (the merged-moments contract);
    # runs on the obs pair so the drift pair's bump count stays untouched
    x_live = (probe_rng.standard_normal((dim_o, 68)) + 3.9).astype(np.float32)
    omega_o = fused_transform_omega(off_srv.store.get(pair_o).state, dim_o)
    mo_once = np.asarray(rff_features(x_live, omega_o).mean(axis=1), np.float32)
    off_srv.refresh_from_moments(pair_o, target_mean=mo_once, n_target=68)
    state_once = off_srv.store.get(pair_o).state
    splits = np.split(x_live, [20, 55], axis=1)  # 20 + 35 + 13 columns
    pooled = sum(
        np.asarray(rff_features(c, omega_o).mean(axis=1), np.float32)
        * (c.shape[1] / x_live.shape[1])
        for c in splits
    )
    off_srv.refresh_from_moments(pair_o, target_mean=pooled, n_target=68)
    state_pooled = off_srv.store.get(pair_o).state
    probe_eq = jnp.asarray(probe_rng.standard_normal((dim_o, 25)).astype(np.float32))
    refresh_div = float(np.max(np.abs(
        np.asarray(rf_tca_transform(state_once, probe_eq))
        - np.asarray(rf_tca_transform(state_pooled, probe_eq))
    )))
    drift_rec = {
        "monitor": {"alpha": 0.15, "window": 4, "k_consecutive": 2,
                    "calibration_windows": 3, "threshold_scale": 4.0,
                    "burnin_windows": 2},
        "workload": {"dim": dim_d, "n_features": fit_kw_d["n_features"],
                     "calm_requests": calm_n, "drift_requests": drift_n,
                     "rate_rps": drift_rate},
        "threshold": mon.pair_threshold(pair_d),
        "injection_t": injection_t,
        "detection_t": detection_t,
        "detection_latency_s": detection_t - injection_t,
        "fires": mon.fires,
        "version_bumps": int(bumps),
        "moment_refreshes": drift_srv.moment_refreshes,
        "accuracy": {
            "stale_disc": disc_stale,
            "refreshed_disc": disc_refreshed,
            "recovered": bool(disc_refreshed < disc_stale),
        },
        "refresh_equivalence": {"max_divergence": refresh_div, "chunks": 3},
        "timeline": mon.timeline(),
    }
    emit("serve_drift", 0.0,
         f"latency={drift_rec['detection_latency_s']:.4f}s fires={mon.fires} "
         f"bumps={bumps} recovered={drift_rec['accuracy']['recovered']} "
         f"refresh_div={refresh_div:.1e}")

    # -- gates: one trace per bucket rung, memoized fused omega --------------
    after = sentinel.counts()
    traces_per_bucket = {
        plane: after[plane] - before.get(plane, 0)
        for plane in after
        if plane.startswith("serve.") and after[plane] != before.get(plane, 0)
    }
    sentinel.assert_stable(before, tuple(traces_per_bucket), expect=1)

    record = {
        "smoke": smoke,
        "config": {
            "dim": dim, "n": n, **fit_kw, "n_pairs": n_pairs,
            "capacity": capacity, "min_bucket": 8, "max_bucket": 64,
            "n_requests_per_level": n_requests,
            "service_scale": float(service_scale),
        },
        "load_curve": load_curve,
        "saturation": saturation,
        "obs": obs,
        "slo": slo_rec,
        "drift": drift_rec,
        "batch_histogram": server.dispatcher.histogram(),
        "cache": server.store.snapshot(),
        "refits_in_path": server.refits - refits_before,
        "admission": admission,
        "sentinel": {"traces_per_bucket": traces_per_bucket},
        "fused_omega": fused_omega_cache_info(),
        "wire": {
            "bytes_total": int(server.admission.transport.log.bytes_total),
            "rejects_total": int(server.admission.transport.log.rejects_total),
        },
    }
    JSON_PATH.write_text(json.dumps(record, indent=2, sort_keys=True))
    emit("serve_record", 0.0, f"wrote {JSON_PATH.name}")
    return record


if __name__ == "__main__":
    run()
