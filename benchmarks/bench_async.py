"""Async federated runtime benchmark — the fedsim robustness record.

Claims measured (and recorded in ``BENCH_async.json``):

- **degeneracy** — an AsyncScheduler run with uniform latencies, no churn and
  ``buffer_size = K`` reproduces the batched sync engine's parameters (the
  recorded ``max_param_divergence`` is gated at <= 1e-3 by the CI smoke; the
  unit test pins it at <= 1e-6);
- **accuracy vs churn rate** — Markov on/off client churn at increasing
  offline fractions: staleness-weighted buffered aggregation
  (:class:`AsyncScheduler`, polynomial discount) against the naive
  drop-the-stragglers baseline (:class:`SyncScheduler`, offline clients
  simply lost from each round's plan), same aggregation budget;
- **accuracy vs buffer size** — FedBuff's knob under fixed churn;
- **virtual time to target accuracy** — sync waits for the slowest link
  every round, async flushes as updates land: wall-clock-to-quality on the
  same heterogeneous links.  The async curve is sampled by *time-triggered
  eval events* (``AsyncConfig.eval_interval``), so its accuracy-vs-virtual-
  time resolution is a fixed cadence rather than whatever the flush schedule
  happens to align with.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from benchmarks.common import da_suite, emit
from repro.comm.netsim import LinkModel, LinkScenario, TraceScenario
from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig
from repro.federated.network import RoundPlan
from repro.fedsim import AsyncConfig, AsyncScheduler, SyncScheduler, markov_trace

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_async.json"


def _leaf_div(a, b) -> float:
    import jax

    return max(
        float(np.abs(np.asarray(x) - np.asarray(y)).max())
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


def _trainer(sources, target, cfg, rounds, *, seed=0):
    k = len(sources)
    ids = list(range(k))
    proto = ProtocolConfig(
        n_rounds=rounds, t_c=max(rounds // 4, 1), warmup_rounds=rounds, lr=5e-3,
        batch_size=48, seed=seed,
        scenario=TraceScenario([RoundPlan(ids, ids, ids)] * rounds, cycle=True),
    )
    return FedRFTCATrainer(sources, target, cfg, proto)


def _acc_of(history, trainer) -> float:
    accs = [h["acc"] for h in history if "acc" in h]
    return float(np.mean(accs[-3:])) if accs else float(trainer.evaluate())


def run(smoke: bool = False) -> None:
    """Full bench by default; ``smoke=True`` shrinks every run so CI can
    validate the emitted BENCH_async.json schema in seconds."""
    rounds = 8 if smoke else 60
    sources, target = da_suite(n=80 if smoke else 240)
    k = len(sources)
    cfg = ClientConfig(input_dim=16, n_classes=5, n_rff=128, m=16, lambda_mmd=2.0)
    record: dict = {"smoke": smoke, "n_clients": k, "rounds": rounds}
    eval_every = max(rounds // 6, 1)

    # -- degeneracy: uniform latency, no churn, buffer=K == the sync engine --
    tr_sync = _trainer(sources, target, cfg, rounds)
    s_sched = SyncScheduler(tr_sync)
    s_sched.run(rounds, eval_every=eval_every)
    tr_async = _trainer(sources, target, cfg, rounds)
    a_sched = AsyncScheduler(
        tr_async,
        AsyncConfig(buffer_size=k, staleness="polynomial"),
        links=LinkScenario(links=[LinkModel(latency_s=0.25) for _ in range(k)]),
    )
    a_hist = a_sched.run(rounds, eval_every=eval_every)
    div = max(
        _leaf_div(tr_sync.tgt_params, tr_async.tgt_params),
        _leaf_div(tr_sync._src_stack, tr_async._src_stack),
    )
    record["degeneracy"] = {
        "max_param_divergence": div,
        "virtual_time_sync": s_sched.clock.now,
        "virtual_time_async": a_sched.clock.now,
        "flushes": a_sched.flushes,
        "staleness_max": int(max(s for h in a_hist for s in h["staleness"])),
    }
    emit("async/degeneracy", 0.0, f"divergence={div:.2e},flushes={a_sched.flushes}")

    # -- accuracy vs churn: buffered-staleness async vs drop-the-stragglers --
    churn_fracs = (0.3,) if smoke else (0.0, 0.2, 0.4, 0.6)
    churn_curve: dict[str, dict] = {}
    for frac in churn_fracs:
        row: dict = {"churn_fraction": frac}
        mean_on = 10.0
        for name, make in (
            ("naive_sync", lambda tr, av: SyncScheduler(tr, availability=av)),
            (
                "async_buffered",
                lambda tr, av: AsyncScheduler(
                    tr,
                    AsyncConfig(buffer_size=max(k // 2, 1), staleness="polynomial"),
                    availability=av,
                ),
            ),
        ):
            avail = (
                None
                if frac == 0.0
                else markov_trace(
                    k, horizon=200.0 * rounds,
                    mean_on=mean_on, mean_off=mean_on * frac / (1.0 - frac),
                    seed=17,
                )
            )
            tr = _trainer(sources, target, cfg, rounds)
            sched = make(tr, avail)
            hist = sched.run(rounds, eval_every=eval_every)
            row[name] = {
                "acc": _acc_of(hist, tr),
                "virtual_time": sched.clock.now,
                "aggregations": len(hist),
            }
        row["async_minus_naive"] = row["async_buffered"]["acc"] - row["naive_sync"]["acc"]
        churn_curve[f"{frac:.1f}"] = row
        emit(
            f"async/churn_{frac:.1f}", 0.0,
            f"naive={row['naive_sync']['acc']:.3f},"
            f"async={row['async_buffered']['acc']:.3f},"
            f"delta={row['async_minus_naive']:+.3f}",
        )
    record["accuracy_vs_churn"] = churn_curve
    wins = [r for r in churn_curve.values() if r["async_minus_naive"] > 0]
    record["async_beats_naive_at"] = [r["churn_fraction"] for r in wins]

    # -- accuracy vs buffer size under fixed churn ---------------------------
    buffer_curve: dict[str, dict] = {}
    frac = 0.3
    avail = markov_trace(
        k, horizon=200.0 * rounds, mean_on=10.0, mean_off=10.0 * frac / (1.0 - frac),
        seed=23,
    )
    sizes = (1, k) if smoke else sorted({1, 2, max(k // 2, 1), k})
    for b in sizes:
        tr = _trainer(sources, target, cfg, rounds)
        sched = AsyncScheduler(
            tr, AsyncConfig(buffer_size=b, staleness="polynomial"), availability=avail
        )
        hist = sched.run(rounds, eval_every=eval_every)
        buffer_curve[str(b)] = {
            "acc": _acc_of(hist, tr),
            "virtual_time": sched.clock.now,
            "staleness_mean": float(
                np.mean([s for h in hist for s in h["staleness"]] or [0.0])
            ),
        }
        emit(f"async/buffer_{b}", 0.0, f"acc={buffer_curve[str(b)]['acc']:.3f}")
    record["accuracy_vs_buffer_size"] = buffer_curve

    # -- virtual time to target accuracy on heterogeneous links --------------
    # one slow straggler: the sync barrier waits for it every round, the
    # buffered server does not
    links = [LinkModel(latency_s=0.1, bandwidth_bps=1e6) for _ in range(k)]
    links[-1] = LinkModel(latency_s=8.0, bandwidth_bps=2e4)
    tr_s = _trainer(sources, target, cfg, rounds)
    ss = SyncScheduler(tr_s, links=LinkScenario(links=list(links)))
    hs = ss.run(rounds, eval_every=1)
    tr_a = _trainer(sources, target, cfg, rounds)
    sa = AsyncScheduler(
        tr_a,
        AsyncConfig(
            buffer_size=max(k // 2, 1), staleness="polynomial", eval_interval=1.0
        ),
        links=LinkScenario(links=list(links)),
    )
    ha = sa.run(rounds, eval_every=1)
    curve_s = [(h["t"], h["acc"]) for h in hs if "acc" in h]
    curve_a = [(h["t"], h["acc"]) for h in ha if "acc" in h]
    target_acc = 0.95 * min(max(a for _, a in curve_s), max(a for _, a in curve_a))
    t_sync = next(t for t, a in curve_s if a >= target_acc)
    t_async = next(t for t, a in curve_a if a >= target_acc)
    record["time_to_target"] = {
        "target_acc": target_acc,
        "virtual_time_sync": t_sync,
        "virtual_time_async": t_async,
        "speedup_async_vs_sync": t_sync / max(t_async, 1e-9),
        # dense time-triggered samples vs flush-aligned ones
        "async_eval_points": len(curve_a),
        "async_eval_ticks": sum(1 for h in ha if "eval" in h),
    }
    emit(
        "async/time_to_target", 0.0,
        f"target={target_acc:.3f},sync={t_sync:.1f}s,async={t_async:.1f}s",
    )

    JSON_PATH.write_text(json.dumps(record, indent=2) + "\n")
    emit("async/json", 0.0, f"wrote={JSON_PATH.name}")


if __name__ == "__main__":
    run()
