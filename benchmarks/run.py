"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (one per measurement), plus a
section header per bench. See EXPERIMENTS.md for the claim-by-claim mapping.

    PYTHONPATH=src python -m benchmarks.run            # all benches
    PYTHONPATH=src python -m benchmarks.run --only fig3,table2

The ``fig3`` bench additionally writes ``BENCH_rf_tca.json`` at the repo root
(fit wall-times dense/stream/lobpcg, speedups, peak-memory proxy, round-engine
per-round times, accuracies) and ``wire`` writes ``BENCH_comm.json``
(bytes-on-wire per payload per codec, accuracy-vs-loss-rate and
accuracy-vs-codec curves) — the machine-readable records tracked across PRs.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    bench_ablation,
    bench_accuracy,
    bench_comm,
    bench_comm_wire,
    bench_gamma,
    bench_hard_voting,
    bench_kernels,
    bench_laplace,
    bench_rf_tca,
    bench_robustness,
    bench_theory,
)

BENCHES = {
    "fig3": ("Fig.3 + Tables X-XIII: RF-TCA vs DA baselines", bench_rf_tca.run),
    "theory": ("Thm.1/2 + Cor.1 validation", bench_theory.run),
    "table2": ("Tables I/II: communication accounting", bench_comm.run),
    "wire": ("Wire format: bytes/payload/codec + loss & codec curves", bench_comm_wire.run),
    "table3": ("Table III + Fig.4: drop/interval robustness", bench_robustness.run),
    "table5": ("Tables IV-VI: federated DA leaderboard", bench_accuracy.run),
    "table8": ("Tables VIII/IX + Fig.5: ablations", bench_ablation.run),
    "appD": ("Appendix D: one-shot hard voting / asynchrony", bench_hard_voting.run),
    "fig6": ("Fig.6/Remark 3: gamma sensitivity", bench_gamma.run),
    "table14": ("App.D Tab.XIV/XV: Laplace vs Gaussian kernels", bench_laplace.run),
    "kernels": ("Pallas kernels vs oracles", bench_kernels.run),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    selected = args.only.split(",") if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    failed = []
    for key in selected:
        title, fn = BENCHES[key]
        print(f"# --- {key}: {title} ---", flush=True)
        t0 = time.time()
        try:
            fn()
        except Exception:  # noqa: BLE001
            failed.append(key)
            traceback.print_exc()
        print(f"# {key} done in {time.time()-t0:.1f}s", flush=True)
    if failed:
        sys.exit(f"benches failed: {failed}")


if __name__ == "__main__":
    main()
