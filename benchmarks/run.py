"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (one per measurement), plus a
section header per bench. See EXPERIMENTS.md for the claim-by-claim mapping.

    PYTHONPATH=src python -m benchmarks.run            # all benches
    PYTHONPATH=src python -m benchmarks.run --only fig3,table2
    PYTHONPATH=src python -m benchmarks.run --smoke    # CI: tiny fig3 + wire
    PYTHONPATH=src python -m benchmarks.run --profile --only async
        # wrap each bench in a wall-clock tracer, write trace_<name>.json

Four benches write machine-readable records at the repo root, tracked across
PRs: ``fig3`` -> ``BENCH_rf_tca.json`` (fit wall-times dense/stream/lobpcg,
speedups, peak-memory proxy, tiled large-N kernel agreement, seed-fused
kernel 0-ULP twin agreement + ensemble degeneracy + fused-vs-materialized
memory ladder + fused accuracy re-sweep with the N-anomaly resolution row,
round-engine per-round times serial/batched/ragged, accuracies), ``wire`` ->
``BENCH_comm.json`` (bytes-on-wire per payload per codec, accuracy-vs-loss-rate
and accuracy-vs-codec curves), ``async`` -> ``BENCH_async.json`` (fedsim
runtime: sync-vs-async degeneracy divergence, accuracy-vs-churn-rate with
staleness-weighted buffering vs drop-the-stragglers, accuracy-vs-buffer-size,
virtual time to target accuracy), ``fleet`` -> ``BENCH_fleet.json``
(rounds/sec + chunk-bounded working-set proxy vs K up to 1024+, server-ingress
bytes flat vs two-tier, two-tier-vs-flat divergence, accuracy vs edge codec),
``robust`` -> ``BENCH_robust.json`` (fault injection: zero-fault bitwise
degeneracy of the AggregationRule refactor, accuracy vs corruption rate and
vs Byzantine count for mean vs each robust rule, crash-recovery rollback vs
checkpoint interval), and ``obs`` -> ``BENCH_obs.json`` + ``trace_obs.json``
(telemetry: fully-on vs off rounds/sec gated at <= 5% slowdown, bitwise
off-vs-on degeneracy for both engines, jit-retrace sentinels at exactly one
trace per plane, and a churn + server-crash async run exported as a
Perfetto-viewable Chrome trace), and ``serve`` -> ``BENCH_serve.json``
(adaptation-as-a-service: p50/p99 latency + throughput vs offered Poisson
load, batch-size histograms, store hit rate under LRU pressure, the
refit-free live-admission gate at <= 1e-3, one jit trace per batch bucket,
request-tracing overhead <= 5% with bitwise off-vs-on degeneracy, SLO
burn-rate violations under overload + the quarantine-ledger objective, and
the drift-injection run: detection latency, auto-refresh version bumps,
chunked-refresh equivalence, and post-refresh accuracy recovery).

``--smoke`` reruns exactly those record-writing benches at tiny sizes and
schema-validates the emitted JSON (required keys present, wall-times positive,
agreement within tolerance) so the perf records cannot silently rot — this is
the CI ``bench-smoke`` job.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time
import traceback
from pathlib import Path

from benchmarks import (
    bench_ablation,
    bench_accuracy,
    bench_async,
    bench_comm,
    bench_comm_wire,
    bench_fleet,
    bench_gamma,
    bench_hard_voting,
    bench_kernels,
    bench_laplace,
    bench_obs,
    bench_rf_tca,
    bench_robust,
    bench_robustness,
    bench_serve,
    bench_theory,
)
from repro.obs import Tracer, use_tracer, validate_trace_file

BENCHES = {
    "fig3": ("Fig.3 + Tables X-XIII: RF-TCA vs DA baselines", bench_rf_tca.run),
    "theory": ("Thm.1/2 + Cor.1 validation", bench_theory.run),
    "table2": ("Tables I/II: communication accounting", bench_comm.run),
    "wire": ("Wire format: bytes/payload/codec + loss & codec curves", bench_comm_wire.run),
    "async": ("Fedsim runtime: churn/staleness/buffer curves + degeneracy", bench_async.run),
    "fleet": ("Fleet scale: K-sweep, two-tier ingress, edge codecs", bench_fleet.run),
    "table3": ("Table III + Fig.4: drop/interval robustness", bench_robustness.run),
    "robust": ("Fault injection: corruption/Byzantine/crash-recovery", bench_robust.run),
    "table5": ("Tables IV-VI: federated DA leaderboard", bench_accuracy.run),
    "table8": ("Tables VIII/IX + Fig.5: ablations", bench_ablation.run),
    "appD": ("Appendix D: one-shot hard voting / asynchrony", bench_hard_voting.run),
    "fig6": ("Fig.6/Remark 3: gamma sensitivity", bench_gamma.run),
    "table14": ("App.D Tab.XIV/XV: Laplace vs Gaussian kernels", bench_laplace.run),
    "kernels": ("Pallas kernels vs oracles", bench_kernels.run),
    "obs": ("Telemetry: overhead gate, degeneracy, sentinels, trace export", bench_obs.run),
    "serve": ("Serving: Poisson load curves, batching, cache, live admission", bench_serve.run),
}


ROOT = Path(__file__).resolve().parent.parent


def _is_pos(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool) and v > 0 and math.isfinite(v)


class _SchemaErrors(list):
    """Collects dotted-path schema violations against a bench record."""

    def __init__(self, record: dict):
        super().__init__()
        self.record = record

    def need(self, path: str, pred=None) -> None:
        cur = self.record
        for part in path.split("."):
            if not isinstance(cur, dict) or part not in cur:
                self.append(f"missing key {path}")
                return
            cur = cur[part]
        if pred is not None and not pred(cur):
            self.append(f"bad value at {path}: {cur!r}")


def validate_rf_tca_record(record: dict) -> list[str]:
    """BENCH_rf_tca.json contract: keys present, wall-times positive, the
    tiled kernel within tolerance of its twin, ragged planes in agreement."""
    e = _SchemaErrors(record)
    acc01 = lambda d: isinstance(d, dict) and d and all(
        isinstance(v, (int, float)) and 0.0 <= v <= 1.0 for v in d.values()
    )
    for k in ("fit.dense_s", "fit.stream_s", "fit.lobpcg_s",
              "fit.speedup_stream_vs_dense", "fit.memory_proxy_bytes.dense",
              "fit.memory_proxy_bytes.stream", "large_n.tiled_pallas_s",
              "large_n.tiled_twin_s", "large_n.tile", "large_n.acc_bytes_tiled",
              "round_engine.serial", "round_engine.batched",
              "round_engine.speedup_batched_vs_serial", "ragged_rounds.serial_s",
              "ragged_rounds.batched_s"):
        e.need(k, _is_pos)
    e.need("large_n.rel_err_pallas_vs_twin", lambda v: 0.0 <= v <= 1e-4)
    e.need("ragged_rounds.max_param_divergence", lambda v: 0.0 <= v <= 1e-3)
    e.need("ragged_rounds.client_sizes", lambda v: isinstance(v, list) and len(set(v)) > 1)
    e.need("accuracy", acc01)
    # seed-fused gates: bit-for-bit vs the XLA generator twin in BOTH
    # layouts, ensemble=1 bitwise-degenerate to the single-draw path, and
    # the fused peak-memory proxy strictly below materialized from N >= 2048
    e.need("fused.ulp_untiled", lambda v: v == 0)
    e.need("fused.ulp_tiled", lambda v: v == 0)
    e.need("fused.ensemble1_max_abs_diff", lambda v: v == 0.0)
    e.need("fused.ensemble_rel_err_vs_oracle", lambda v: 0.0 <= v <= 1e-4)
    e.need("fused.fused_s", _is_pos)
    proxies = (record.get("fused") or {}).get("memory_proxy_bytes") or {}
    if not any(int(k) >= 2048 for k in proxies):
        e.append("fused.memory_proxy_bytes: no ladder entry at N >= 2048")
    for k, row in proxies.items():
        if int(k) >= 2048 and not (
            isinstance(row, dict)
            and _is_pos(row.get("fused"))
            and _is_pos(row.get("materialized"))
            and row["fused"] < row["materialized"]
        ):
            e.append(f"fused.memory_proxy_bytes.{k}: fused not strictly below "
                     f"materialized ({row!r})")
    e.need("accuracy_resweep.fused", acc01)
    e.need("accuracy_resweep.ensemble", acc01)
    e.need(
        "accuracy_resweep.anomaly_small_vs_large_n.status",
        lambda v: v in ("resolved", "persists"),
    )
    return list(e)


def validate_comm_record(record: dict) -> list[str]:
    """BENCH_comm.json contract: exact byte tables and accuracy curves."""
    e = _SchemaErrors(record)
    bytes_table = lambda d: isinstance(d, dict) and d and all(
        isinstance(kinds, dict) and kinds and all(_is_pos(b) for b in kinds.values())
        for kinds in d.values()
    )
    e.need("bytes_per_payload", bytes_table)
    for scale in ("1x", "4x"):
        e.need(f"w_rf_bytes_{scale}.float32", _is_pos)
        e.need(f"w_rf_bytes_{scale}.seed_replay", _is_pos)
    # the headline O(1) claim: seed-replay bytes must not grow with N
    if not self_consistent_seed_replay(record):
        e.append("w_rf seed_replay bytes grew between 1x and 4x N")
    e.need("identity.acc", lambda v: 0.0 <= v <= 1.0)
    e.need("identity.bytes", lambda d: isinstance(d, dict) and all(_is_pos(v) for v in d.values()))
    curve = lambda d: isinstance(d, dict) and d and all(
        isinstance(row, dict) and 0.0 <= row.get("acc", -1.0) <= 1.0 for row in d.values()
    )
    e.need("accuracy_vs_codec", curve)
    e.need("accuracy_vs_loss_rate", curve)
    return list(e)


def validate_async_record(record: dict) -> list[str]:
    """BENCH_async.json contract: degeneracy within tolerance, virtual times
    positive, churn/buffer accuracy curves well-formed."""
    e = _SchemaErrors(record)
    e.need("degeneracy.max_param_divergence", lambda v: 0.0 <= v <= 1e-3)
    for k in ("degeneracy.virtual_time_sync", "degeneracy.virtual_time_async",
              "degeneracy.flushes", "time_to_target.virtual_time_sync",
              "time_to_target.virtual_time_async", "time_to_target.target_acc"):
        e.need(k, _is_pos)
    e.need("degeneracy.staleness_max", lambda v: v == 0)  # full fresh buffers only
    acc_row = lambda r: isinstance(r, dict) and 0.0 <= r.get("acc", -1.0) <= 1.0 and _is_pos(
        r.get("virtual_time")
    )
    e.need("accuracy_vs_churn", lambda d: isinstance(d, dict) and d and all(
        acc_row(r.get("naive_sync")) and acc_row(r.get("async_buffered"))
        for r in d.values()
    ))
    e.need("accuracy_vs_buffer_size", lambda d: isinstance(d, dict) and d and all(
        acc_row(r) for r in d.values()
    ))
    e.need("async_beats_naive_at", lambda v: isinstance(v, list))
    return list(e)


def validate_fleet_record(record: dict) -> list[str]:
    """BENCH_fleet.json contract: the K-sweep sustains its sizes with the
    chunk-bounded working set, two-tier vs flat stays within tolerance, and
    server ingress is strictly below flat from K = 64 up."""
    e = _SchemaErrors(record)
    e.need("max_k", lambda v: v >= (64 if record.get("smoke") else 1024))
    e.need("scaling", lambda d: isinstance(d, dict) and len(d) >= 2)
    e.need("ingress", lambda d: isinstance(d, dict) and d)
    for key, row in (record.get("scaling") or {}).items():
        e.need(f"scaling.{key}.round_s", _is_pos)
        e.need(f"scaling.{key}.rounds_per_s", _is_pos)
        e.need(f"scaling.{key}.working_set_bytes_chunked", _is_pos)
        if row.get("chunk", 0) < row.get("k", 0):
            e.need(
                f"scaling.{key}.working_set_bytes_chunked",
                lambda v, row=row: v < row.get("working_set_bytes_full", 0),
            )
    for key, row in (record.get("ingress") or {}).items():
        if int(key) >= 64:
            e.need(
                f"ingress.{key}.two_tier_total",
                lambda v, row=row: _is_pos(v) and v < row.get("flat_total", 0),
            )
    e.need("two_tier.max_param_divergence", lambda v: 0.0 <= v <= 1e-3)
    e.need("edge_codec_curve", lambda d: isinstance(d, dict) and d and all(
        0.0 <= r.get("acc", -1.0) <= 1.0 and _is_pos(r.get("edge_uplink_bytes"))
        for r in d.values()
    ))
    return list(e)


def validate_robust_record(record: dict) -> list[str]:
    """BENCH_robust.json contract: the rule refactor is bitwise-degenerate
    with zero faults, at least one robust rule beats the plain mean at the
    heaviest corruption rate, and crash recovery rolls back no further than
    one checkpoint interval."""
    e = _SchemaErrors(record)
    e.need("degeneracy.max_param_divergence", lambda v: 0.0 <= v <= 1e-6)
    e.need("clean_baseline_acc", lambda v: 0.0 <= v <= 1.0)
    acc_row = lambda r: isinstance(r, dict) and "mean" in r and all(
        isinstance(v, (int, float)) and 0.0 <= v <= 1.0 for v in r.values()
    )
    e.need("corruption", lambda d: isinstance(d, dict) and d and all(
        isinstance(by_rate, dict) and by_rate and all(acc_row(r) for r in by_rate.values())
        for by_rate in d.values()
    ))
    e.need("byzantine", lambda d: isinstance(d, dict) and d and all(
        acc_row(r) for r in d.values()
    ))
    # the headline claim: a robust rule survives what poisons the mean
    for mode, by_rate in (record.get("corruption") or {}).items():
        if not isinstance(by_rate, dict) or not by_rate:
            continue
        worst = by_rate.get(max(by_rate, key=float))
        if isinstance(worst, dict) and "mean" in worst and len(worst) > 1:
            robust_best = max(v for k, v in worst.items() if k != "mean")
            if not robust_best > worst["mean"]:
                e.append(
                    f"corruption.{mode}: no robust rule beats mean at the "
                    f"heaviest rate ({worst!r})"
                )
    e.need("recovery", lambda d: isinstance(d, dict) and d)
    for key, row in (record.get("recovery") or {}).items():
        if not isinstance(row, dict):
            e.append(f"recovery[{key}]: not a dict")
            continue
        rb, iv = row.get("rollback_s"), row.get("checkpoint_interval_s", -1.0)
        if not (isinstance(rb, (int, float)) and 0.0 <= rb <= iv):
            e.append(f"recovery[{key}]: rollback_s {rb!r} not within interval {iv!r}")
        if row.get("recovered") is not True:
            e.append(f"recovery[{key}]: crashed run did not complete its flushes")
    return list(e)


def validate_obs_record(record: dict) -> list[str]:
    """BENCH_obs.json contract: telemetry fully on costs <= 5% rounds/sec,
    is bitwise-off when disabled (both engines), keeps every compiled plane
    at exactly one trace, and the exported churn + server-crash trace is a
    valid Chrome trace holding the whole virtual-time story."""
    e = _SchemaErrors(record)
    e.need("overhead.rounds_per_s_off", _is_pos)
    e.need("overhead.rounds_per_s_on", _is_pos)
    e.need("overhead.slowdown", lambda v: isinstance(v, (int, float)) and 0.0 <= v <= 0.05)
    e.need("degeneracy.batched_max_param_divergence", lambda v: v == 0.0)
    e.need("degeneracy.serial_max_param_divergence", lambda v: v == 0.0)
    e.need("sentinel.round_traces", lambda v: v == 1)
    e.need("sentinel.flush_traces", lambda v: v == 1)
    e.need("trace.n_events", _is_pos)
    e.need("trace.validation_errors", lambda v: v == [])
    e.need("trace.server_crashes", _is_pos)
    e.need("trace.request_trees", _is_pos)
    for span in ("compute", "uplink", "flush", "server_crash", "recovery",
                 "checkpoint", "eval"):
        e.need(f"trace.spans.{span}", _is_pos)
    # independently re-validate the trace file the record points at — it must
    # also hold at least one *complete* per-request span tree (all three
    # serving legs contained in their root span)
    trace_path = ROOT / str(record.get("trace", {}).get("file", "trace_obs.json"))
    if not trace_path.exists():
        e.append(f"{trace_path.name}: not written")
    else:
        e.extend(
            f"{trace_path.name}: {msg}"
            for msg in validate_trace_file(trace_path, require_request_trees=1)
        )
    return list(e)


def validate_serve_record(record: dict) -> list[str]:
    """BENCH_serve.json contract: positive latencies with p99 >= p50 at every
    offered load (>= 3 levels in the full run), positive saturation
    throughput, a cache hit rate in [0, 1], a nonempty batch histogram, the
    admission-equals-refit gate at <= 1e-3 with no version change and no
    refit, and exactly one jit trace per batch bucket.  The observability
    sections carry their own gates: request tracing fully on stays within
    the 5% overhead budget and bitwise-degenerate when off, the SLO engine
    fires at least one latency violation under overload (timeline entries
    holding both burn windows) plus one quarantine violation naming the
    poisoned member, and the drift run detects the injected shift with a
    positive latency, exactly one version bump per fire, a chunked-vs-oneshot
    refresh within 1e-3, and a recovered post-refresh accuracy."""
    e = _SchemaErrors(record)
    e.need("config.service_scale", _is_pos)
    min_levels = 1 if record.get("smoke") else 3
    curve = record.get("load_curve") or {}
    if not (isinstance(curve, dict) and len(curve) >= min_levels):
        e.append(f"load_curve: want >= {min_levels} offered-load levels, got {len(curve)}")
    for rate, row in curve.items():
        if not isinstance(row, dict):
            e.append(f"load_curve.{rate}: not a dict")
            continue
        for k in ("p50_ms", "p99_ms", "throughput_rps", "completed"):
            if not _is_pos(row.get(k)):
                e.append(f"load_curve.{rate}.{k}: {row.get(k)!r} not positive")
        if not row.get("p99_ms", 0) >= row.get("p50_ms", 0):
            e.append(f"load_curve.{rate}: p99 {row.get('p99_ms')!r} < p50 {row.get('p50_ms')!r}")
    e.need("saturation.throughput_rps", _is_pos)
    e.need("cache.hit_rate", lambda v: isinstance(v, (int, float)) and 0.0 <= v <= 1.0)
    e.need("batch_histogram.dispatches", _is_pos)
    e.need("batch_histogram.requests_per_dispatch", lambda d: isinstance(d, dict) and d)
    e.need("batch_histogram.bucket_widths", lambda d: isinstance(d, dict) and d)
    e.need("admission.max_divergence_vs_refit", lambda v: 0.0 <= v <= 1e-3)
    e.need("admission.store_version_changed", lambda v: v is False)
    e.need("admission.refit_ran", lambda v: v is False)
    e.need("admission.bytes_up", _is_pos)
    e.need("admission.bytes_down", _is_pos)
    e.need("sentinel.traces_per_bucket", lambda d: isinstance(d, dict) and d and all(
        v == 1 for v in d.values()
    ))
    # request-level observability: overhead/degeneracy gates + tree fidelity
    e.need("obs.slowdown", lambda v: isinstance(v, (int, float)) and 0.0 <= v <= 0.05)
    e.need("obs.degeneracy", lambda v: v == 0.0)
    e.need("obs.sample_rate", lambda v: isinstance(v, (int, float)) and 0.0 < v < 1.0)
    e.need("obs.request_tracing.complete_trees", _is_pos)
    e.need("obs.request_tracing.emitted", _is_pos)
    # SLO engine: overload must burn through the latency budget, and the
    # poisoned quarantine ledger must surface the guilty member
    e.need("slo.calm_p50_ms", _is_pos)
    e.need("slo.bound_ms", _is_pos)
    e.need("slo.n_violations", _is_pos)
    e.need("slo.quarantine.n_violations", _is_pos)
    e.need(
        "slo.quarantine.worst_member",
        lambda v: isinstance(v, str) and v.startswith("member=")
        and v.removeprefix("member=").isdigit(),
    )
    timeline = (record.get("slo") or {}).get("timeline")
    if not (isinstance(timeline, list) and timeline and all(
        isinstance(v, dict)
        and all(k in v for k in ("t", "objective", "burn_fast", "burn_slow",
                                 "window_fast_s", "window_slow_s"))
        for v in timeline
    )):
        e.append("slo.timeline: want >= 1 violation records carrying both "
                 f"burn windows, got {timeline!r}")
    # drift: injected shift detected, one bump per fire, refresh equivalent
    e.need("drift.injection_t", _is_pos)
    e.need("drift.detection_latency_s", _is_pos)
    e.need("drift.fires", _is_pos)
    drift = record.get("drift") or {}
    if drift.get("version_bumps") != drift.get("fires"):
        e.append(f"drift: version bumps {drift.get('version_bumps')!r} != "
                 f"fires {drift.get('fires')!r} (want exactly one refresh per fire)")
    e.need("drift.refresh_equivalence.max_divergence", lambda v: 0.0 <= v <= 1e-3)
    e.need("drift.accuracy.recovered", lambda v: v is True)
    e.need("drift.accuracy.stale_disc", _is_pos)
    e.need("drift.accuracy.refreshed_disc", _is_pos)
    return list(e)


def self_consistent_seed_replay(record: dict) -> bool:
    try:
        return (
            record["w_rf_bytes_4x"]["seed_replay"] <= record["w_rf_bytes_1x"]["seed_replay"]
        )
    except (KeyError, TypeError):
        return False


def run_smoke() -> None:
    """CI bench-smoke: tiny fig3 + wire + async + fleet runs, then
    schema-validate every emitted record."""
    for key, fn in (
        ("fig3", bench_rf_tca.run),
        ("wire", bench_comm_wire.run),
        ("async", bench_async.run),
        ("fleet", bench_fleet.run),
        ("robust", bench_robust.run),
        ("obs", bench_obs.run),
        ("serve", bench_serve.run),
    ):
        print(f"# --- smoke {key} ---", flush=True)
        t0 = time.time()
        fn(smoke=True)
        print(f"# smoke {key} done in {time.time()-t0:.1f}s", flush=True)
    errors = []
    for name, validate in (
        ("BENCH_rf_tca.json", validate_rf_tca_record),
        ("BENCH_comm.json", validate_comm_record),
        ("BENCH_async.json", validate_async_record),
        ("BENCH_fleet.json", validate_fleet_record),
        ("BENCH_robust.json", validate_robust_record),
        ("BENCH_obs.json", validate_obs_record),
        ("BENCH_serve.json", validate_serve_record),
    ):
        path = ROOT / name
        if not path.exists():
            errors.append(f"{name}: not written")
            continue
        errors += [f"{name}: {msg}" for msg in validate(json.loads(path.read_text()))]
    if errors:
        sys.exit("bench record schema violations:\n  " + "\n  ".join(errors))
    print(
        "# smoke: BENCH_rf_tca.json + BENCH_comm.json + BENCH_async.json + "
        "BENCH_fleet.json + BENCH_robust.json + BENCH_obs.json + "
        "BENCH_serve.json schemas OK",
        flush=True,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny fig3+wire runs, then schema-validate the emitted JSON records",
    )
    ap.add_argument(
        "--profile", action="store_true",
        help="run each bench under a tracer and write trace_<name>.json "
        "(wall-clock span per bench + any virtual-time spans the fedsim "
        "schedulers emit while it runs); open at ui.perfetto.dev",
    )
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        run_smoke()
        return
    selected = args.only.split(",") if args.only else list(BENCHES)
    failed = []
    for key in selected:
        title, fn = BENCHES[key]
        print(f"# --- {key}: {title} ---", flush=True)
        t0 = time.time()
        try:
            if args.profile:
                tracer = Tracer()
                with use_tracer(tracer), tracer.span(key):
                    fn()
                tracer.write(ROOT / f"trace_{key}.json")
                print(f"# wrote trace_{key}.json ({len(tracer.events)} events)", flush=True)
            else:
                fn()
        except Exception:  # noqa: BLE001
            failed.append(key)
            traceback.print_exc()
        print(f"# {key} done in {time.time()-t0:.1f}s", flush=True)
    if failed:
        sys.exit(f"benches failed: {failed}")


if __name__ == "__main__":
    main()
