"""Paper Appendix D (Tables XVI-XVIII): one-shot hard voting + asynchrony.

Claims checked:
 - never aggregating classifiers (T_C -> inf) and hard-voting the K source
   classifiers at eval still yields competitive accuracy;
 - the protocol tolerates random message passing order (asynchrony).
"""
from __future__ import annotations

from benchmarks.common import da_suite, emit, timed
from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig

CFG = ClientConfig(input_dim=16, n_classes=5, n_rff=128, m=16, lambda_mmd=2.0)


def run() -> None:
    sources, target = da_suite()
    proto = ProtocolConfig(
        n_rounds=120, t_c=25, warmup_rounds=150, lr=5e-3, seed=0,
        aggregate_classifier=False,  # one-shot: classifiers never averaged
    )
    tr = FedRFTCATrainer(sources, target, CFG, proto)
    accs, t = timed(tr.train, eval_every=120)
    emit("appD/one_shot_hard_voting", t, f"acc={accs[-1]:.3f}")

    # asynchrony: setting III drops/reorders both W_RF and classifiers
    proto2 = ProtocolConfig(
        n_rounds=120, t_c=25, warmup_rounds=150, lr=5e-3, seed=0,
        drop_setting="III", aggregate_classifier=False,
    )
    tr2 = FedRFTCATrainer(sources, target, CFG, proto2)
    accs2, t = timed(tr2.train, eval_every=120)
    emit("appD/hard_voting_async", t, f"acc={accs2[-1]:.3f}")
    emit(
        "appD/claim_async_tolerant", 0.0,
        f"drop={abs(accs[-1]-accs2[-1]):.3f}(<0.1 expected)",
    )


if __name__ == "__main__":
    run()
