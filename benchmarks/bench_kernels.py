"""Pallas kernel micro-benchmarks: allclose vs oracle + wall time.

NOTE: this container is CPU-only, so Pallas kernels execute in interpret mode
— wall times here measure the *oracle XLA path* and interpret overhead, not
TPU performance. TPU performance is assessed structurally in §Roofline.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels import ops, ref


def run() -> None:
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 512))
    om = jax.random.normal(jax.random.fold_in(key, 1), (256, 64))
    out, t_pal = timed(lambda: np.asarray(ops.rff(x, om, block=64)))
    exp, t_ref = timed(lambda: np.asarray(ref.rff_ref(x, om)))
    err = float(np.abs(out - exp).max())
    emit("kernels/rff_interpret", t_pal, f"max_err={err:.2e},ref_us={t_ref:.0f}")

    sig = jax.random.normal(key, (256, 512))
    out, t_pal = timed(lambda: np.asarray(ops.centered_gram(sig, block=64)))
    exp, t_ref = timed(lambda: np.asarray(ref.centered_gram_ref(sig)))
    rel = float(np.abs(out - exp).max() / np.abs(exp).max())
    emit("kernels/centered_gram_interpret", t_pal, f"rel_err={rel:.2e},ref_us={t_ref:.0f}")

    q = jax.random.normal(key, (1, 4, 256, 64))
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 256, 64))
    v = jax.random.normal(jax.random.fold_in(key, 3), (1, 2, 256, 64))
    out, t_pal = timed(lambda: np.asarray(ops.flash_attention(q, k, v)))
    exp, t_ref = timed(lambda: np.asarray(ref.attention_ref(q, k, v)))
    err = float(np.abs(out - exp).max())
    emit("kernels/flash_attention_interpret", t_pal, f"max_err={err:.2e},ref_us={t_ref:.0f}")


if __name__ == "__main__":
    run()
