"""Theorem 2 / Corollary 1 / Theorem 1 validation (paper Section III-B).

Claims checked:
 - relative spectral error ||Sigma^T Sigma - K||/||K|| decays ~1/sqrt(N);
 - the Sherman-Morrison-corrected matrices stay close (Cor. 1);
 - RF-TCA top-eigenspace approaches R-TCA's as N grows (Thm. 1).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.core import ell_vector
from repro.core.theory import corollary1_error, kernel_approx_error, theorem1_feature_error


def run() -> None:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 100)), jnp.float32)
    ell = ell_vector(60, 40)
    errs = {}
    for n in (64, 256, 1024, 4096):
        e, t = timed(
            lambda n=n: float(np.mean([kernel_approx_error(x, n, 2.0, s) for s in range(3)]))
        )
        errs[n] = e
        emit(f"thm2/err_N{n}", t, f"rel_spectral_err={e:.4f}")
    rate = errs[64] / errs[4096]
    emit("thm2/decay_64_to_4096", 0.0, f"ratio={rate:.2f}(sqrt(64)=8 ideal)")

    for n in (64, 1024):
        e, t = timed(corollary1_error, x, ell, 1e-2, n, 2.0, 0)
        emit(f"cor1/err_N{n}", t, f"rel_err={e:.4f}")

    for n in (128, 4096):
        e, t = timed(
            lambda n=n: float(
                np.mean([theorem1_feature_error(x, ell, 1e-2, 2, n, 2.0, s) for s in range(3)])
            )
        )
        emit(f"thm1/feature_err_N{n}", t, f"fro_err={e:.4f}")


if __name__ == "__main__":
    run()
