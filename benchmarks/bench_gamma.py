"""Paper Fig. 6 / Remark 3 (App. B): regularisation sensitivity of TCA.

Claim checked: classification accuracy varies with gamma only inside a
critical interval (around l^T K^2 l scale); outside it the spectrum of the
rank-one term is either negligible or dominant and accuracy plateaus.
"""
from __future__ import annotations


from benchmarks.common import da_suite, emit, timed
from repro.baselines import tca_baseline


def run() -> None:
    sources, target = da_suite()
    gammas = [1e-6, 1e-4, 1e-2, 1.0, 1e2]
    accs = {}
    for g in gammas:
        acc, t = timed(tca_baseline, sources, target, gamma=g, m=16)
        accs[g] = acc
        emit(f"fig6/tca_gamma_{g:g}", t, f"acc={acc:.3f}")
    # plateaus at both extremes (Remark 3)
    lo_flat = abs(accs[1e-6] - accs[1e-4])
    emit("fig6/claim_low_gamma_plateau", 0.0, f"delta={lo_flat:.3f}")


if __name__ == "__main__":
    run()
