"""Paper Table III + Fig. 4: robustness to message drops and aggregation interval.

Claims checked:
 - settings (I) A/A/A, (II) A/A/B, (III) A/B/C reach similar target accuracy
   (message drops of W_RF / classifiers do not sink FedRF-TCA);
 - accuracy is stable across classifier-aggregation intervals T_C.
"""
from __future__ import annotations


from benchmarks.common import da_suite, emit, timed
from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig

CFG = ClientConfig(input_dim=16, n_classes=5, n_rff=128, m=16, lambda_mmd=2.0)


def run() -> None:
    sources, target = da_suite()
    accs = {}
    for setting in ("I", "II", "III"):
        proto = ProtocolConfig(
            n_rounds=120, t_c=25, warmup_rounds=150, lr=5e-3, drop_setting=setting, seed=0
        )
        tr = FedRFTCATrainer(sources, target, CFG, proto)
        (acc_list, t) = timed(tr.train, eval_every=120)
        accs[setting] = acc_list[-1]
        emit(f"table3/setting_{setting}", t, f"acc={acc_list[-1]:.3f}")
    spread = max(accs.values()) - min(accs.values())
    emit("table3/claim_drop_robustness", 0.0, f"spread={spread:.3f}(<0.08 expected)")

    tc_accs = {}
    for tc in (10, 50, 200):
        proto = ProtocolConfig(
            n_rounds=120, t_c=tc, warmup_rounds=150, lr=5e-3, seed=0
        )
        tr = FedRFTCATrainer(sources, target, CFG, proto)
        acc_list, t = timed(tr.train, eval_every=120)
        tc_accs[tc] = acc_list[-1]
        emit(f"fig4/t_c_{tc}", t, f"acc={acc_list[-1]:.3f}")
    spread = max(tc_accs.values()) - min(tc_accs.values())
    emit("fig4/claim_tc_stability", 0.0, f"spread={spread:.3f}")


if __name__ == "__main__":
    run()
