"""Paper Tables VIII/IX + Fig. 5 ablations.

Claims checked:
 - FedRF-TCA > plain FedAvg (no alignment) under explicit heterogeneity;
 - dropping the Sigma*ell exchange (no-MMD ablation) loses accuracy;
 - implicit heterogeneity (same distribution split across clients) is much
   easier than explicit heterogeneity for both methods.
"""
from __future__ import annotations

from benchmarks.common import da_suite, emit, timed
from repro.baselines import fedavg_baseline
from repro.data import make_implicit_domains
from repro.federated import ClientConfig, FedRFTCATrainer, ProtocolConfig

CFG = ClientConfig(input_dim=16, n_classes=5, n_rff=128, m=16, lambda_mmd=2.0)


def _fedrf(sources, target, messages=True):
    proto = ProtocolConfig(
        n_rounds=120, t_c=25, warmup_rounds=150, lr=5e-3, seed=0,
        exchange_messages=messages,
    )
    tr = FedRFTCATrainer(sources, target, CFG, proto)
    return tr.train(eval_every=120)[-1]


def run() -> None:
    sources, target = da_suite()
    acc_fedavg, t = timed(fedavg_baseline, sources, target, CFG, rounds=150, lr=5e-3)
    emit("table8/fedavg", t, f"acc={acc_fedavg:.3f}")
    acc_fedrf, t = timed(_fedrf, sources, target, True)
    emit("table8/fedrf_tca", t, f"acc={acc_fedrf:.3f}")
    acc_nomsg, t = timed(_fedrf, sources, target, False)
    emit("fig5/no_sigma_ell", t, f"acc={acc_nomsg:.3f}")
    emit(
        "table8/claim_ordering", 0.0,
        f"fedrf={acc_fedrf:.3f}>no_msg={acc_nomsg:.3f}~fedavg={acc_fedavg:.3f}",
    )

    imp = make_implicit_domains(5, 400, seed=3)
    acc_imp, t = timed(_fedrf, imp[:4], imp[4], True)
    emit("fig5/implicit_heterogeneity", t, f"acc={acc_imp:.3f}")
    emit(
        "fig5/claim_implicit_easier", 0.0,
        f"implicit={acc_imp:.3f}>explicit={acc_fedrf:.3f}",
    )


if __name__ == "__main__":
    run()
