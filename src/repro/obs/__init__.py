"""Unified telemetry layer: metrics, virtual-time tracing, health probes.

One spine for the stack's observability (see each submodule's docstring):

- :mod:`repro.obs.registry` — labeled counters/gauges/histograms with a
  no-op default (telemetry off costs one attribute lookup + empty call).
- :mod:`repro.obs.tracing` — virtual/wall-clock spans exported as Chrome
  trace-event JSON (Perfetto-viewable).
- :mod:`repro.obs.sentinel` — jit retrace counters per compiled plane.
- :mod:`repro.obs.records` — typed history/ledger records with dict views.
- :mod:`repro.obs.probes` — host-side emission of in-graph health probes.
"""
from repro.obs import sentinel
from repro.obs.probes import emit_probes, quarantine_totals
from repro.obs.records import (
    CommRecord,
    CrashRecord,
    EvalRecord,
    FlushRecord,
    Record,
    RoundRecord,
    as_rows,
)
from repro.obs.registry import (
    NULL,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullRegistry,
    get_registry,
    set_registry,
    use_registry,
)
from repro.obs.tracing import (
    PID_VIRTUAL,
    PID_WALL,
    Tracer,
    get_tracer,
    set_tracer,
    use_tracer,
    validate_trace,
    validate_trace_file,
)

# `metrics()` reads better than `get_registry()` at instrumentation sites:
#   metrics().counter("comm.bytes").inc(n, kind=kind)
metrics = get_registry

__all__ = [
    "NULL",
    "PID_VIRTUAL",
    "PID_WALL",
    "CommRecord",
    "Counter",
    "CrashRecord",
    "EvalRecord",
    "FlushRecord",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NullRegistry",
    "Record",
    "RoundRecord",
    "Tracer",
    "as_rows",
    "emit_probes",
    "get_registry",
    "get_tracer",
    "metrics",
    "quarantine_totals",
    "sentinel",
    "set_registry",
    "set_tracer",
    "use_registry",
    "use_tracer",
    "validate_trace",
    "validate_trace_file",
]
