"""Unified telemetry layer: metrics, virtual-time tracing, health probes.

One spine for the stack's observability (see each submodule's docstring):

- :mod:`repro.obs.registry` — labeled counters/gauges/histograms with a
  no-op default (telemetry off costs one attribute lookup + empty call).
- :mod:`repro.obs.tracing` — virtual/wall-clock spans exported as Chrome
  trace-event JSON (Perfetto-viewable).
- :mod:`repro.obs.sentinel` — jit retrace counters per compiled plane.
- :mod:`repro.obs.records` — typed history/ledger records with dict views.
- :mod:`repro.obs.probes` — host-side emission of in-graph health probes.
- :mod:`repro.obs.reqtrace` — head-sampled per-request serving span trees.
- :mod:`repro.obs.slo` — declarative SLOs with multi-window burn-rate alerts.
- :mod:`repro.obs.drift` — RF-MMD domain-drift detection over live moments.
"""
from repro.obs import sentinel
from repro.obs.drift import DriftMonitor, DriftRecord
from repro.obs.probes import emit_probes, quarantine_totals
from repro.obs.records import (
    CommRecord,
    CrashRecord,
    EvalRecord,
    FlushRecord,
    Record,
    RoundRecord,
    as_rows,
)
from repro.obs.registry import (
    NULL,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullRegistry,
    get_registry,
    set_registry,
    use_registry,
)
from repro.obs.reqtrace import RequestTracer
from repro.obs.slo import Slo, SloEngine, SloViolation, quarantine_slo
from repro.obs.tracing import (
    PID_VIRTUAL,
    PID_WALL,
    Tracer,
    count_request_trees,
    get_tracer,
    set_tracer,
    use_tracer,
    validate_trace,
    validate_trace_file,
)

# `metrics()` reads better than `get_registry()` at instrumentation sites:
#   metrics().counter("comm.bytes").inc(n, kind=kind)
metrics = get_registry

__all__ = [
    "NULL",
    "PID_VIRTUAL",
    "PID_WALL",
    "CommRecord",
    "Counter",
    "CrashRecord",
    "DriftMonitor",
    "DriftRecord",
    "EvalRecord",
    "FlushRecord",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NullRegistry",
    "Record",
    "RequestTracer",
    "RoundRecord",
    "Slo",
    "SloEngine",
    "SloViolation",
    "Tracer",
    "as_rows",
    "count_request_trees",
    "emit_probes",
    "get_registry",
    "get_tracer",
    "metrics",
    "quarantine_slo",
    "quarantine_totals",
    "sentinel",
    "set_registry",
    "set_tracer",
    "use_registry",
    "use_tracer",
    "validate_trace",
    "validate_trace_file",
]
