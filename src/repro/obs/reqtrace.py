"""Per-request span trees for the serving plane.

One sampled request becomes one span tree in the exported Chrome trace:
a ``serve.request`` root covering arrival -> completion with nested legs

    serve.queue_wait      arrival -> dispatch start
    serve.batch_assembly  host-side concat + pad + mask of the batch
    serve.padded_dispatch the compiled bucketed dispatch (block_until_ready)

recorded on the *virtual-time* track (the fedsim clock the load generator
runs on) and, for the serve-side processing legs, mirrored on the wall-clock
track — the same two-track convention as :mod:`repro.obs.tracing`.  Each
sampled request gets its own ``tid`` lane and every event carries
``args.trace_id``, so trees stay distinguishable in Perfetto and countable
by :func:`repro.obs.tracing.count_request_trees` (the CI smoke gate).

Admission is traced the same way: one ``serve.admission`` root per admitted
client with the protocol's three legs (``serve.wire_decode`` ->
``serve.moment_merge`` -> ``serve.w_rf_ship``) on the wall track — those
legs are real wire work, not simulated service time.

**Head-based sampling.**  Whether a request is traced is decided once, at
arrival, by a deterministic hash of its id (no RNG state, identical across
replays): ``rate=0`` disables tracing entirely and ``rate=1.0`` — every
request, test/bench-only — would be far too much trace volume in any real
deployment.  Emission goes to the ambient :func:`repro.obs.tracing.
get_tracer`; with no tracer installed every method is a cheap no-op, which
keeps the telemetry-off serving path bitwise identical.
"""
from __future__ import annotations

from repro.obs.tracing import PID_VIRTUAL, PID_WALL, get_tracer

# fixed-point Knuth multiplicative hash: uniform enough for head sampling,
# fully deterministic, and independent of Python's randomized str hash
_KNUTH = 2654435761
_GOLDEN = 0x9E3779B9
_REQUEST_TID_BASE = 10_000  # one lane per sampled request
_ADMISSION_TID_BASE = 50_000  # one lane per traced admission


class RequestTracer:
    """Head-sampled per-request span-tree recorder."""

    def __init__(self, rate: float = 1.0, *, seed: int = 0, tracer=None):
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"sampling rate must be in [0, 1], got {rate}")
        self.rate = float(rate)
        self.seed = int(seed)
        self._tracer = tracer  # None -> the ambient get_tracer()
        self._open: dict[int, dict] = {}
        self.sampled_total = 0
        self.emitted = 0
        self.admissions = 0

    def _t(self):
        return self._tracer if self._tracer is not None else get_tracer()

    # -- sampling ------------------------------------------------------------

    def sampled(self, req_id: int) -> bool:
        """Deterministic head-sampling decision for ``req_id``."""
        if self.rate >= 1.0:
            return True
        if self.rate <= 0.0:
            return False
        h = ((int(req_id) + 1) * _KNUTH + self.seed * _GOLDEN) & 0xFFFFFFFF
        return h < self.rate * 2**32

    # -- request trees -------------------------------------------------------

    def begin(self, req_id: int, arrival: float) -> bool:
        """Open a trace for ``req_id`` if sampled and a tracer is live."""
        if self._t() is None or not self.sampled(req_id):
            return False
        self._open[req_id] = {"arrival": float(arrival), "legs": []}
        self.sampled_total += 1
        return True

    def active(self, req_id: int) -> bool:
        return req_id in self._open

    def leg(self, req_id: int, name: str, t0: float, dur: float, *,
            pid: int = PID_VIRTUAL) -> None:
        """Record one leg of an open request (emitted at :meth:`finish`)."""
        rec = self._open.get(req_id)
        if rec is not None:
            rec["legs"].append((name, float(t0), max(float(dur), 0.0), pid))

    def finish(self, req_id: int, completion: float) -> None:
        """Close the request and emit its whole span tree to the tracer."""
        rec = self._open.pop(req_id, None)
        tracer = self._t()
        if rec is None or tracer is None:
            return
        tid = _REQUEST_TID_BASE + req_id
        args = {"trace_id": req_id}
        tracer.complete(
            "serve.request", rec["arrival"],
            max(float(completion) - rec["arrival"], 0.0),
            tid=tid, pid=PID_VIRTUAL, args=args,
        )
        for name, t0, dur, pid in rec["legs"]:
            tracer.complete(name, t0, dur, tid=tid, pid=pid, args=args)
        self.emitted += 1

    # -- admission trees -----------------------------------------------------

    def emit_admission(self, legs, *, wall0: float) -> None:
        """One wall-clock admission tree: ``legs`` is an ordered list of
        ``(name, duration_s)`` starting at ``wall0`` (tracer-relative)."""
        tracer = self._t()
        if tracer is None or not legs:
            return
        aid = self.admissions
        self.admissions += 1
        tid = _ADMISSION_TID_BASE + aid
        args = {"trace_id": -(aid + 1)}  # negative ids: admission namespace
        total = sum(max(float(d), 0.0) for _, d in legs)
        tracer.complete("serve.admission", wall0, total, tid=tid,
                        pid=PID_WALL, args=args)
        t = float(wall0)
        for name, dur in legs:
            dur = max(float(dur), 0.0)
            tracer.complete(name, t, dur, tid=tid, pid=PID_WALL, args=args)
            t += dur
