"""Metrics registry: labeled counters, gauges and histograms for the stack.

One process-wide registry (default: a no-op :class:`NullRegistry`) collects
host-side telemetry from every subsystem — exact wire bytes and frame
rejects from ``comm.transport``, retry/give-up counts from ``comm.netsim``,
flush/staleness series from ``fedsim.runtime``, ingress bytes from the fleet
tier split, jit retrace counts from ``obs.sentinel``, and the in-graph health
probes (``obs.probes``) collected at dispatch boundaries.

Design constraints, in order:

1. **Near-zero cost when disabled.**  The default registry is
   :data:`NULL` — every ``counter(...)``/``gauge(...)``/``histogram(...)``
   returns a shared no-op instrument whose methods do nothing, so the
   instrumented hot paths pay one attribute lookup and one empty call.
   Telemetry off is the bitwise-degenerate configuration (test-gated): no
   instrument ever touches array values, only host-side scalars.
2. **Labels are first-class.**  ``inc(n, kind="moments", client=3)`` keys the
   series by the sorted label items, so per-client / per-edge / per-payload
   breakdowns need no pre-declared schema.
3. **Deterministic snapshots.** :meth:`MetricsRegistry.snapshot` renders the
   whole registry as plain nested dicts (insertion-ordered, JSON-ready), so
   two identical runs produce identical snapshots.

Usage::

    from repro.obs import metrics, use_registry, MetricsRegistry

    with use_registry(MetricsRegistry()) as reg:
        run_training()
        reg.snapshot()["comm.bytes"]   # {"kind=moments": 131072, ...}

or imperatively via :func:`set_registry` / :func:`get_registry`.
"""
from __future__ import annotations

import contextlib
import math


def _label_key(labels: dict) -> str:
    """Canonical series key: sorted ``k=v`` pairs (empty string when bare)."""
    if not labels:
        return ""
    return ",".join(f"{k}={v}" for k, v in sorted(labels.items()))


class Counter:
    """Monotone accumulator (floats allowed: probe attributions accumulate)."""

    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self.series: dict[str, float] = {}

    def inc(self, value: float = 1, **labels) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name} cannot decrease (got {value})")
        key = _label_key(labels)
        self.series[key] = self.series.get(key, 0) + value

    def value(self, **labels) -> float:
        return self.series.get(_label_key(labels), 0)


class Gauge:
    """Last-write-wins instantaneous value."""

    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self.series: dict[str, float] = {}

    def set(self, value: float, **labels) -> None:
        self.series[_label_key(labels)] = value

    def value(self, **labels) -> float | None:
        return self.series.get(_label_key(labels))


class Histogram:
    """Streaming summary per series: count / sum / min / max.

    A full quantile sketch would be overkill for the repo's needs (the bench
    records report count/mean/extremes); the summary is O(1) per observation
    and deterministic, which the trace/metric determinism tests rely on.
    """

    kind = "histogram"

    def __init__(self, name: str):
        self.name = name
        self.series: dict[str, dict] = {}

    def observe(self, value: float, **labels) -> None:
        value = float(value)
        if math.isnan(value):
            raise ValueError(f"histogram {self.name}: NaN observation")
        key = _label_key(labels)
        s = self.series.get(key)
        if s is None:
            self.series[key] = {"count": 1, "sum": value, "min": value, "max": value}
        else:
            s["count"] += 1
            s["sum"] += value
            s["min"] = min(s["min"], value)
            s["max"] = max(s["max"], value)

    def summary(self, **labels) -> dict | None:
        s = self.series.get(_label_key(labels))
        if s is None:
            return None
        return {**s, "mean": s["sum"] / s["count"]}


class MetricsRegistry:
    """Collecting registry: instruments are created on first use and cached
    by name, so call sites never pre-declare anything."""

    collecting = True

    def __init__(self):
        self._instruments: dict[str, Counter | Gauge | Histogram] = {}

    def _get(self, name: str, cls):
        inst = self._instruments.get(name)
        if inst is None:
            inst = self._instruments[name] = cls(name)
        elif not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} already registered as {inst.kind}, "
                f"asked for {cls.__name__.lower()}"
            )
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self) -> dict:
        """The whole registry as nested plain dicts (JSON-ready)."""
        out: dict = {}
        for name, inst in sorted(self._instruments.items()):
            if isinstance(inst, Histogram):
                out[name] = {
                    k: {**s, "mean": s["sum"] / s["count"]}
                    for k, s in inst.series.items()
                }
            else:
                out[name] = dict(inst.series)
        return out


class _NullInstrument:
    """Shared no-op counter/gauge/histogram — the disabled-telemetry cost."""

    __slots__ = ()

    def inc(self, value: float = 1, **labels) -> None:
        pass

    def set(self, value: float, **labels) -> None:
        pass

    def observe(self, value: float, **labels) -> None:
        pass

    def value(self, **labels):
        return None

    def summary(self, **labels):
        return None


class NullRegistry:
    """The default: every instrument is the shared no-op singleton."""

    collecting = False
    _inst = _NullInstrument()

    def counter(self, name: str) -> _NullInstrument:
        return self._inst

    def gauge(self, name: str) -> _NullInstrument:
        return self._inst

    def histogram(self, name: str) -> _NullInstrument:
        return self._inst

    def snapshot(self) -> dict:
        return {}


NULL = NullRegistry()
_REGISTRY: MetricsRegistry | NullRegistry = NULL


def get_registry() -> MetricsRegistry | NullRegistry:
    """The active registry (the no-op :data:`NULL` unless one was set)."""
    return _REGISTRY


def set_registry(registry: MetricsRegistry | NullRegistry | None) -> None:
    """Install ``registry`` process-wide (None restores the no-op default)."""
    global _REGISTRY
    _REGISTRY = NULL if registry is None else registry


@contextlib.contextmanager
def use_registry(registry: MetricsRegistry | None = None):
    """Scoped collection: installs ``registry`` (a fresh one when None),
    yields it, and restores the previous registry on exit."""
    reg = MetricsRegistry() if registry is None else registry
    prev = _REGISTRY
    set_registry(reg)
    try:
        yield reg
    finally:
        set_registry(prev)
