"""Compilation sentinels: count jit (re)traces per compiled plane.

PR 1/4/5 worked hard to make each hot path exactly ONE compiled dispatch —
the batched round, the async flush, the warm-up scan.  Nothing in the repo
*detected* a silent regression: a shape- or dtype-unstable argument would
make XLA retrace every call and the round loop would quietly become 100x
slower while staying numerically correct.

The sentinel exploits the one reliable, version-independent retrace signal:
the Python body of a jitted function executes exactly once per trace (and
never at execution time).  Wrapping the body with a counter bump therefore
counts cache misses without touching any jax internals::

    self._round = jax.jit(SENTINEL.wrap("engine.round", self._round_fn))

Every bump also lands in the metrics registry (counter ``jit.retraces``
labeled by plane), and :func:`assert_stable` turns "a round loop retraced"
into a hard failure — the test gate this PR adds.

Counts are process-global and monotone; callers that need a per-run delta
snapshot with :func:`counts` before and after (the pattern the tests and
``benchmarks/bench_obs.py`` use).  The bump is trace-time-only, so the
compiled program and its outputs are bit-identical with or without the
sentinel installed.
"""
from __future__ import annotations

import functools

from repro.obs import registry as _registry

_COUNTS: dict[str, int] = {}


def bump(plane: str) -> None:
    """Record one trace of ``plane`` (call from inside a jitted body)."""
    _COUNTS[plane] = _COUNTS.get(plane, 0) + 1
    _registry.get_registry().counter("jit.retraces").inc(plane=plane)


def wrap(plane: str, fn):
    """``fn`` with a trace-time bump — pass the result to ``jax.jit``."""

    @functools.wraps(fn)
    def traced(*args, **kwargs):
        bump(plane)
        return fn(*args, **kwargs)

    return traced


def counts() -> dict[str, int]:
    """Snapshot of traces per plane since process start (or last reset)."""
    return dict(_COUNTS)


def count(plane: str) -> int:
    return _COUNTS.get(plane, 0)


def reset() -> None:
    _COUNTS.clear()


def assert_stable(before: dict[str, int], planes: tuple[str, ...], *,
                  expect: int = 1) -> None:
    """Fail unless each plane traced exactly ``expect`` times since
    ``before`` (a :func:`counts` snapshot).  ``expect=1``: the plane
    compiled once and every subsequent call hit the cache."""
    after = counts()
    bad = {
        p: after.get(p, 0) - before.get(p, 0)
        for p in planes
        if after.get(p, 0) - before.get(p, 0) != expect
    }
    if bad:
        raise AssertionError(
            f"compiled planes retraced: {bad} (expected {expect} trace(s) each) "
            "— a shape/dtype-unstable argument is defeating the jit cache"
        )
