"""Typed telemetry records — one schema for the stack's history rows.

Until this PR three subsystems each grew their own ad-hoc record shape:
``CommLog`` kept bare counter fields, ``SyncScheduler.history`` appended
``{"t", "round", "participants"}`` dicts and ``AsyncScheduler.history``
appended four *different* dict shapes (flush / crash / recovery / eval rows)
distinguishable only by key-probing.  This module consolidates them onto
dataclasses, so every producer states its schema once and every consumer —
tests, benches, the metrics registry, the trace exporter — gets typed fields.

Back-compat is load-bearing: existing tests and benches index rows like
dicts (``row["acc"]``, ``"eval" in h``, ``row.get("crash")``) and even
assign (``row["acc"] = ...``).  :class:`Record` therefore implements the
mutable-mapping surface over its dataclass fields, with ``None``-valued
fields *hidden* from the dict view — ``"acc" in row`` is False until an
evaluation actually populated it, exactly like the old optional dict keys.
``to_dict()`` renders the visible fields as a plain JSON-ready dict.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any


class Record:
    """Mapping facade over dataclass fields (``None`` fields are absent)."""

    def _field_names(self) -> tuple[str, ...]:
        return tuple(f.name for f in dataclasses.fields(self))

    def keys(self):
        return [n for n in self._field_names() if getattr(self, n) is not None]

    def __contains__(self, key: str) -> bool:
        return key in self._field_names() and getattr(self, key) is not None

    def __getitem__(self, key: str):
        if key not in self:
            raise KeyError(key)
        return getattr(self, key)

    def __setitem__(self, key: str, value) -> None:
        if key not in self._field_names():
            raise KeyError(f"{type(self).__name__} has no field {key!r}")
        setattr(self, key, value)

    def get(self, key: str, default=None):
        return getattr(self, key) if key in self else default

    def __iter__(self):
        return iter(self.keys())

    def __len__(self) -> int:
        return len(self.keys())

    def items(self):
        return [(n, getattr(self, n)) for n in self.keys()]

    def to_dict(self) -> dict:
        return dict(self.items())


@dataclass(eq=True)
class RoundRecord(Record):
    """One synchronous round at the barrier (``SyncScheduler.history``)."""

    t: float  # virtual time of the round's barrier
    round: int
    participants: int  # clients that delivered into this round's plan
    acc: float | None = None  # set when the round hit an eval_every boundary


@dataclass(eq=True)
class FlushRecord(Record):
    """One buffered aggregation (``AsyncScheduler.history``)."""

    t: float  # virtual flush time
    flush: int  # 1-based flush counter
    version: int  # server model version AFTER this flush
    members: list  # sorted client ids consumed by the flush
    staleness: list  # per-member version lag at consumption
    weights: list  # per-member staleness weights applied to the merges
    acc: float | None = None


@dataclass(eq=True)
class CrashRecord(Record):
    """A fault-plane episode: server crash/recovery or edge crash."""

    t: float
    crash: str  # "server" | "edge"
    restored_flush: int | None = None  # server: flush count rolled back to
    rollback_s: float | None = None  # server: virtual seconds replayed
    edge: int | None = None  # edge: which aggregator died
    lost: list | None = None  # edge: client ids whose updates were lost


@dataclass(eq=True)
class EvalRecord(Record):
    """A time-triggered evaluation tick (``AsyncConfig.eval_interval``)."""

    t: float
    eval: int  # tick index (1-based)
    acc: float | None = None


@dataclass(eq=True)
class CommRecord(Record):
    """Point-in-time snapshot of a :class:`repro.comm.CommLog`'s counters —
    the typed view of the wire ledger (``CommLog.snapshot()``)."""

    rounds: int
    data_messages: int  # legacy float counts (Table I/II units)
    w_rf: int
    classifier: int
    bytes_by_kind: dict
    messages_by_kind: dict
    rejects_by_kind: dict
    drops_by_kind: dict
    bytes_total: int
    floats_total: int


def as_rows(history: list[Any]) -> list[dict]:
    """Render a history of records (or legacy dicts) as plain dicts."""
    return [h.to_dict() if isinstance(h, Record) else dict(h) for h in history]
