"""Span tracing on virtual or wall-clock time, exported as Chrome trace JSON.

The fedsim runtime's whole point is that *time itself* is simulated — a
churn-under-straggler run is a sequence of dispatch / uplink / flush /
crash / recovery episodes on the :class:`repro.fedsim.clock.VirtualClock`.
This module turns those episodes into Chrome trace-event JSON (the
``{"traceEvents": [...]}`` format) viewable in Perfetto (https://ui.perfetto.dev)
or ``chrome://tracing``, so the timeline becomes *readable* instead of a list
of history rows.

Two time bases share one :class:`Tracer`:

- **virtual time** — the schedulers pass explicit ``ts`` seconds from their
  VirtualClock; these land in the ``pid=2`` ("virtual time") track.
- **wall clock** — :meth:`Tracer.span` (a context manager) stamps
  ``time.perf_counter`` relative to the tracer's birth; these land in
  ``pid=1`` ("wall clock").  ``benchmarks/run.py --profile`` wraps every
  bench in such a span.

Event vocabulary (all milliseconds-displayed, microsecond ``ts`` as the
format requires):

- ``begin``/``end`` — a ``ph: "B"``/``"E"`` span pair on one ``(pid, tid)``
  lane.  Pairs must nest per lane; :func:`validate_trace` enforces balance
  and per-pair monotone timestamps (the CI bench-smoke gate).
- ``complete`` — one ``ph: "X"`` event with an explicit duration (used for
  client compute/uplink episodes whose extent is known at emission).
- ``instant`` — ``ph: "i"`` markers (flush, checkpoint, crash, eval).

Determinism: a tracer fed only virtual-time events from the deterministic
fedsim event loop serializes to byte-identical JSON across runs — the
trace-determinism test pins that.
"""
from __future__ import annotations

import contextlib
import json
import time

PID_WALL = 1
PID_VIRTUAL = 2
_PROCESS_NAMES = {PID_WALL: "wall clock", PID_VIRTUAL: "virtual time"}


class Tracer:
    """Collects trace events; export with :meth:`to_json` / :meth:`write`."""

    def __init__(self):
        self.events: list[dict] = []
        self._wall0 = time.perf_counter()

    # -- low-level emission (explicit timestamps, virtual-time track) --------

    @staticmethod
    def _us(ts_seconds: float) -> float:
        return round(float(ts_seconds) * 1e6, 3)

    def _emit(self, ph: str, name: str, ts: float, *, pid: int, tid: int,
              args: dict | None = None, **extra) -> None:
        ev = {"name": name, "ph": ph, "ts": self._us(ts), "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        ev.update(extra)
        self.events.append(ev)

    def begin(self, name: str, ts: float, *, tid: int = 0, pid: int = PID_VIRTUAL,
              args: dict | None = None) -> None:
        self._emit("B", name, ts, pid=pid, tid=tid, args=args)

    def end(self, name: str, ts: float, *, tid: int = 0, pid: int = PID_VIRTUAL,
            args: dict | None = None) -> None:
        self._emit("E", name, ts, pid=pid, tid=tid, args=args)

    def complete(self, name: str, ts: float, dur: float, *, tid: int = 0,
                 pid: int = PID_VIRTUAL, args: dict | None = None) -> None:
        if dur < 0:
            raise ValueError(f"span {name!r}: negative duration {dur}")
        self._emit("X", name, ts, pid=pid, tid=tid, args=args, dur=self._us(dur))

    def instant(self, name: str, ts: float, *, tid: int = 0, pid: int = PID_VIRTUAL,
                args: dict | None = None) -> None:
        # scope "t": thread-local marker (renders as a tick on the lane)
        self._emit("i", name, ts, pid=pid, tid=tid, args=args, s="t")

    def wall_now(self) -> float:
        """Seconds since this tracer's birth — the wall-clock timestamp base
        explicit emitters (request tracing, admission legs) share with
        :meth:`span`."""
        return time.perf_counter() - self._wall0

    # -- wall-clock spans (context manager; benches / non-sim paths) ---------

    @contextlib.contextmanager
    def span(self, name: str, *, tid: int = 0, args: dict | None = None):
        """Wall-clock ``B``/``E`` pair around a ``with`` block."""
        self.begin(name, time.perf_counter() - self._wall0, tid=tid,
                   pid=PID_WALL, args=args)
        try:
            yield self
        finally:
            self.end(name, time.perf_counter() - self._wall0, tid=tid, pid=PID_WALL)

    # -- export --------------------------------------------------------------

    def trace_events(self) -> list[dict]:
        """All events plus process-name metadata for the two time tracks."""
        pids = {ev["pid"] for ev in self.events}
        meta = [
            {
                "name": "process_name", "ph": "M", "pid": pid, "tid": 0, "ts": 0,
                "args": {"name": _PROCESS_NAMES.get(pid, f"pid {pid}")},
            }
            for pid in sorted(pids)
        ]
        return meta + self.events

    def to_json(self) -> str:
        return json.dumps(
            {"traceEvents": self.trace_events(), "displayTimeUnit": "ms"}
        )

    def write(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())


# -- the process-wide default tracer (None = tracing off) ---------------------

_TRACER: Tracer | None = None


def get_tracer() -> Tracer | None:
    return _TRACER


def set_tracer(tracer: Tracer | None) -> None:
    global _TRACER
    _TRACER = tracer


@contextlib.contextmanager
def use_tracer(tracer: Tracer | None = None):
    """Scoped tracing: installs ``tracer`` (a fresh one when None), yields
    it, restores the previous tracer on exit."""
    t = Tracer() if tracer is None else tracer
    prev = _TRACER
    set_tracer(t)
    try:
        yield t
    finally:
        set_tracer(prev)


# -- schema validation (the CI bench-smoke contract) --------------------------

_REQUIRED_KEYS = ("name", "ph", "ts", "pid", "tid")


def validate_trace(events: list[dict]) -> list[str]:
    """Chrome trace-event schema violations (empty list == valid).

    Checks the contract the CI smoke gates: every event carries
    ``name``/``ph``/``ts``/``pid``/``tid``; ``B``/``E`` pairs balance per
    ``(pid, tid)`` lane with monotone (end >= begin) timestamps and matching
    names; ``X`` events carry a non-negative ``dur``.
    """
    errors: list[str] = []
    stacks: dict[tuple, list[dict]] = {}
    for i, ev in enumerate(events):
        missing = [k for k in _REQUIRED_KEYS if k not in ev]
        if missing:
            errors.append(f"event {i}: missing keys {missing}")
            continue
        ph, lane = ev["ph"], (ev["pid"], ev["tid"])
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] != ev["ts"]:
            errors.append(f"event {i} ({ev['name']!r}): bad ts {ev['ts']!r}")
            continue
        if ph == "B":
            stacks.setdefault(lane, []).append(ev)
        elif ph == "E":
            stack = stacks.get(lane)
            if not stack:
                errors.append(f"event {i}: E {ev['name']!r} with no open B on {lane}")
                continue
            b = stack.pop()
            if b["name"] != ev["name"]:
                errors.append(
                    f"event {i}: E {ev['name']!r} closes B {b['name']!r} on {lane}"
                )
            if ev["ts"] < b["ts"]:
                errors.append(
                    f"event {i}: span {ev['name']!r} ends at {ev['ts']} before "
                    f"its begin {b['ts']} (non-monotone pair)"
                )
        elif ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                errors.append(f"event {i}: X {ev['name']!r} bad dur {ev.get('dur')!r}")
    for lane, stack in stacks.items():
        for b in stack:
            errors.append(f"unclosed B {b['name']!r} on lane {lane}")
    return errors


_REQUEST_ROOT = "serve.request"
_REQUEST_LEGS = ("serve.queue_wait", "serve.batch_assembly", "serve.padded_dispatch")
_TREE_TOL_US = 0.5  # containment slack: ts are microseconds rounded to 3 dp


def count_request_trees(events: list[dict]) -> int:
    """Complete per-request span trees in ``events`` (the smoke gate).

    A tree is one ``(pid, tid, args.trace_id)`` lane holding a
    ``serve.request`` root ``X`` span plus all three serving legs
    (queue-wait, batch-assembly, padded-dispatch) as ``X`` spans contained
    in the root's interval — the shape :class:`repro.obs.reqtrace.
    RequestTracer` emits on the virtual-time track.
    """
    groups: dict[tuple, list[dict]] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        trace_id = (ev.get("args") or {}).get("trace_id")
        if trace_id is None:
            continue
        groups.setdefault((ev.get("pid"), ev.get("tid"), trace_id), []).append(ev)
    trees = 0
    for evs in groups.values():
        roots = [e for e in evs if e.get("name") == _REQUEST_ROOT]
        if not roots:
            continue
        lo = roots[0]["ts"] - _TREE_TOL_US
        hi = roots[0]["ts"] + roots[0].get("dur", 0) + _TREE_TOL_US
        legs = {
            e["name"] for e in evs
            if e.get("name") in _REQUEST_LEGS
            and e["ts"] >= lo and e["ts"] + e.get("dur", 0) <= hi
        }
        if legs.issuperset(_REQUEST_LEGS):
            trees += 1
    return trees


def validate_trace_file(path, *, require_request_trees: int = 0) -> list[str]:
    """Validate an exported trace JSON file (shape + event schema).

    ``require_request_trees > 0`` additionally demands that many complete
    per-request span trees (:func:`count_request_trees`) — the serving
    observability gate on ``trace_obs.json``.
    """
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: {e}"]
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f"{path}: no traceEvents array"]
    real = [ev for ev in events if ev.get("ph") != "M"]
    errors = [f"{path}: {msg}" for msg in validate_trace(real)]
    if require_request_trees > 0:
        trees = count_request_trees(real)
        if trees < require_request_trees:
            errors.append(
                f"{path}: {trees} complete request span tree(s), "
                f"need >= {require_request_trees}"
            )
    return errors
