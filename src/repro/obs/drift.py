"""Domain-drift monitoring in RFF moment space, driving aligner refresh.

The paper's central statistic doubles as the production drift signal: the
RF-approximated MMD between two distributions is the squared distance of
their mean RFF rows (``core.mmd.mmd_rff``), and the serving plane already
computes the live stream's batch moments *inside* the compiled dispatch
(the probed transform planes — no second featurize pass, no raw data
retained anywhere).  This module watches those moments per domain pair:

- **Reference** — the fit-time target moment (``MomentStats.target_mean``),
  re-pinned after every refresh.
- **EWMA** — an exponentially-weighted moving average of the streamed batch
  moment vectors: smooth enough to reject single-batch noise, responsive
  enough to track a covariate shift within a few windows.
- **RF-MMD** — ``||reference - ewma||^2``, evaluated every ``window``
  batches.  The heavy half (the moments) is computed in-graph by the probed
  planes; the distance between two host-resident (2N,) vectors is a plain
  numpy reduction — routing it through a jitted kernel would pay dispatch
  overhead orders of magnitude above the compute, on the serving hot path.
- **Alerting** — the statistic must exceed the threshold for
  ``k_consecutive`` windows before the monitor fires (transient bursts do
  not trigger a re-solve).  The threshold is either given or *calibrated*
  from drift-free evaluations: after ``burnin_windows`` evaluations are
  discarded (the EWMA is still dominated by its first-batch seed there and
  reads far from its steady state), the next ``calibration_windows`` set it
  to ``max(mean + threshold_scale * std, threshold_ratio * mean)`` of the
  calm RF-MMD levels — the ratio floor guards against a lucky-quiet
  calibration run underestimating the calm spread.
- **Refresh input** — alongside the EWMA (the detector), the monitor keeps
  a short weighted window of recent ``(moment, n_cols)`` pairs;
  :meth:`recent_mean` pools them into the post-drift target moment the
  ``AlignerServer`` re-solves from (``refresh_from_moments``) — recency-
  correct where the full merged history would dilute the shift.

Every evaluation appends a typed :class:`DriftRecord` to :attr:`history`,
so the complete detection timeline (calibration, crossings, consecutive
counts, fires) reconstructs from the records alone — the bench contract.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass

import numpy as np

from repro.obs.records import Record
from repro.obs.registry import get_registry

def _sq_mmd(a, b) -> float:
    d = np.asarray(a, np.float32) - np.asarray(b, np.float32)
    return float(np.dot(d, d))


@dataclass(eq=True)
class DriftRecord(Record):
    """One evaluated drift window (JSON-ready via ``to_dict``)."""

    t: float  # caller time (virtual in the serving benches) of the window
    pair: str
    mmd: float  # RF-MMD between reference and live EWMA moments
    threshold: float | None = None  # None while still calibrating
    consecutive: int = 0  # windows above threshold so far (0 after a fire)
    fired: bool = False
    calibrating: bool = False


class _PairState:
    __slots__ = ("ref", "ewma", "recent", "seen", "windows", "consecutive",
                 "threshold", "calibration")

    def __init__(self, maxlen: int, threshold: float | None):
        self.ref: np.ndarray | None = None
        self.ewma: np.ndarray | None = None
        self.recent: deque = deque(maxlen=maxlen)  # (moment, n_cols)
        self.seen = 0  # batches observed since the last reference pin
        self.windows = 0  # evaluations since the last reference pin
        self.consecutive = 0
        self.threshold = threshold
        self.calibration: list[float] = []


class DriftMonitor:
    """Per-domain-pair RF-MMD drift detector over streamed batch moments."""

    def __init__(
        self,
        *,
        alpha: float = 0.3,
        window: int = 4,
        k_consecutive: int = 2,
        threshold: float | None = None,
        calibration_windows: int = 3,
        threshold_scale: float = 6.0,
        threshold_ratio: float = 1.8,
        burnin_windows: int = 1,
        recent_batches: int | None = None,
        on_alert=None,
    ):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        if window < 1 or k_consecutive < 1:
            raise ValueError("window and k_consecutive must be >= 1")
        if threshold is None and calibration_windows < 1:
            raise ValueError("need calibration_windows >= 1 when threshold is None")
        if burnin_windows < 0:
            raise ValueError(f"burnin_windows must be >= 0, got {burnin_windows}")
        self.alpha = alpha
        self.window = window
        self.k_consecutive = k_consecutive
        self.threshold = threshold
        if threshold_ratio < 1.0:
            raise ValueError(f"threshold_ratio must be >= 1, got {threshold_ratio}")
        self.calibration_windows = calibration_windows
        self.threshold_scale = threshold_scale
        self.threshold_ratio = threshold_ratio
        self.burnin_windows = burnin_windows
        self.recent_batches = (
            recent_batches if recent_batches is not None
            else window * max(k_consecutive, 2)
        )
        self.on_alert = on_alert  # callable(pair, DriftRecord) at each fire
        self._pairs: dict = {}
        self.history: list[DriftRecord] = []
        self.fires = 0

    def _state(self, pair) -> _PairState:
        st = self._pairs.get(pair)
        if st is None:
            st = self._pairs[pair] = _PairState(self.recent_batches, self.threshold)
        return st

    def set_reference(self, pair, moment) -> None:
        """Pin the drift-free reference moment (fit time / after refresh).

        Resets the detector's live state: the EWMA re-seeds from the next
        batch, the consecutive counter clears, and the recent window empties
        (its content was just consumed by the refresh)."""
        st = self._state(pair)
        st.ref = np.asarray(moment, np.float32).reshape(-1)
        st.ewma = None
        st.recent.clear()
        st.seen = 0
        st.windows = 0
        st.consecutive = 0

    def pairs(self) -> list:
        return list(self._pairs)

    def observe(self, pair, t: float, moment, n_cols: int) -> DriftRecord | None:
        """Fold one dispatched batch's mean RFF row into the live state;
        evaluates (and possibly fires) every ``window`` batches.  Batches
        observed before :meth:`set_reference` are ignored."""
        st = self._pairs.get(pair)
        if st is None or st.ref is None:
            return None
        m = np.asarray(moment, np.float32).reshape(-1)
        st.ewma = m if st.ewma is None else self.alpha * m + (1 - self.alpha) * st.ewma
        st.recent.append((m, int(n_cols)))
        st.seen += 1
        if st.seen % self.window != 0:
            return None
        return self._evaluate(pair, st, float(t))

    def _evaluate(self, pair, st: _PairState, t: float) -> DriftRecord:
        mmd = _sq_mmd(st.ref, st.ewma)
        reg = get_registry()
        reg.gauge("drift.mmd").set(mmd, pair=str(pair))
        st.windows += 1
        in_burnin = st.windows <= self.burnin_windows
        calibrating = st.threshold is None
        fired = False
        if in_burnin:
            calibrating = True  # recorded as such; never alerts nor calibrates
        elif calibrating:
            st.calibration.append(mmd)
            if len(st.calibration) >= self.calibration_windows:
                lvl = np.asarray(st.calibration, np.float64)
                st.threshold = float(max(
                    lvl.mean() + self.threshold_scale * max(lvl.std(), 1e-12),
                    self.threshold_ratio * lvl.mean(),
                ))
                reg.gauge("drift.threshold").set(st.threshold, pair=str(pair))
        elif mmd > st.threshold:
            st.consecutive += 1
            if st.consecutive >= self.k_consecutive:
                fired = True
                st.consecutive = 0
                self.fires += 1
                reg.counter("drift.fires").inc(pair=str(pair))
        else:
            st.consecutive = 0
        record = DriftRecord(
            t=t, pair=str(pair), mmd=mmd, threshold=st.threshold,
            consecutive=st.consecutive, fired=fired, calibrating=calibrating,
        )
        self.history.append(record)
        if fired and self.on_alert is not None:
            self.on_alert(pair, record)
        return record

    def recent_mean(self, pair) -> tuple[np.ndarray, int]:
        """Column-weighted pooled moment over the recent window — the live
        target-side statistic a moment-space refresh re-solves from."""
        st = self._pairs.get(pair)
        if st is None or not st.recent:
            raise ValueError(f"no live moments observed for pair {pair!r}")
        total = sum(n for _, n in st.recent)
        pooled = sum(m * (n / total) for m, n in st.recent)
        return np.asarray(pooled, np.float32), int(total)

    def pair_threshold(self, pair) -> float | None:
        st = self._pairs.get(pair)
        return None if st is None else st.threshold

    def timeline(self) -> list[dict]:
        """The full detection story as plain dicts (bench/JSON-ready)."""
        return [r.to_dict() for r in self.history]
