"""Host-side collection of in-graph health probes.

The compiled planes (``BatchedRoundEngine._round_fn`` / ``_flush_fn``) can
optionally return a probes dict as an extra output — moment mass, per-client
update norms, and the :meth:`repro.robust.rules.AggregationRule.attribution`
trim/quarantine indicators.  Everything in that dict is computed *inside*
the existing single dispatch; this module is the other half of the contract:
it materializes the device arrays once, at the dispatch boundary, and fans
them into the metrics registry.

Emission schema (all under the active registry):

- ``probe.<name>`` gauge — scalar probes (e.g. ``moment_mass``), labeled
  ``plane=round|flush``.
- ``probe.<name>`` histogram + ``probe.<name>.mean`` gauge — vector probes
  (e.g. per-client ``update_norm``): the histogram observes the max per
  emission (the straggler/outlier signal), the gauge tracks the mean.
- ``robust.trim_quarantine`` counter — attribution probes
  (``attribution_moments`` / ``attribution_w_rf`` / ...), accumulated per
  member with labels ``kind=<payload> member=<i>``.  This is the per-client
  cumulative fault ledger: a client that keeps getting trimmed or
  quarantined grows this counter round over round (the
  reputation-weighted-scheduling precursor from the ROADMAP).

Returns the probes as host numpy arrays so callers (trainer, schedulers,
benches, tests) can also inspect the raw values.
"""
from __future__ import annotations

import numpy as np

from repro.obs.registry import get_registry

ATTRIBUTION_PREFIX = "attribution_"


def emit_probes(probes: dict, *, plane: str, registry=None) -> dict:
    """Materialize ``probes`` (device arrays) and emit them as metrics."""
    try:  # one batched device->host transfer for the whole dict
        import jax

        host = {k: np.asarray(v) for k, v in jax.device_get(probes).items()}
    except ImportError:  # pure-numpy callers
        host = {k: np.asarray(v) for k, v in probes.items()}
    reg = get_registry() if registry is None else registry
    if not reg.collecting:
        return host
    for name, arr in sorted(host.items()):
        if name.startswith(ATTRIBUTION_PREFIX):
            kind = name[len(ATTRIBUTION_PREFIX):]
            ledger = reg.counter("robust.trim_quarantine")
            for i, v in enumerate(arr.reshape(-1).tolist()):
                if v > 0:
                    ledger.inc(float(v), kind=kind, member=i)
        elif arr.ndim == 0:
            reg.gauge(f"probe.{name}").set(float(arr), plane=plane)
        else:
            flat = arr.reshape(-1)
            reg.histogram(f"probe.{name}").observe(float(flat.max()), plane=plane)
            reg.gauge(f"probe.{name}.mean").set(float(flat.mean()), plane=plane)
    return host


def quarantine_totals(registry=None, *, kind: str | None = None) -> dict[int, float]:
    """Per-member cumulative trim/quarantine mass from the fault ledger.

    Sums the ``robust.trim_quarantine`` counter across payload kinds (or one
    ``kind``), keyed by member index — the host-side view of "which client
    was trimmed how often".
    """
    reg = get_registry() if registry is None else registry
    totals: dict[int, float] = {}
    counter = reg.counter("robust.trim_quarantine")
    for key, value in getattr(counter, "series", {}).items():
        labels = dict(part.split("=", 1) for part in key.split(",") if "=" in part)
        if kind is not None and labels.get("kind") != kind:
            continue
        member = int(labels["member"])
        totals[member] = totals.get(member, 0.0) + value
    return totals
