"""Declarative SLOs with multi-window burn-rate alerting.

The serving plane measures latencies and availability; this module turns
them into *objectives* — "99% of requests complete under X seconds",
"no client's trim rate exceeds R" — evaluated continuously over sliding
windows of the observation stream, the way an SRE error-budget policy does:

- An :class:`Slo` declares a good-fraction ``target`` (e.g. ``0.99``) and,
  for threshold-style objectives, a ``bound`` — a sample is *bad* when its
  value exceeds the bound (latency over the limit, trim rate over budget).
  Availability-style objectives feed booleans instead (``ok=False`` is bad).
- The error **budget** is ``1 - target``; the **burn rate** of a window is
  its bad fraction divided by the budget (burn 1.0 = consuming budget
  exactly as fast as the objective allows; burn 10 = ten times too fast).
- **Multi-window** alerting requires the burn to exceed the threshold in a
  *fast* window (catches the spike quickly) AND a *slow* window (rejects
  one-sample blips) simultaneously — the standard fast/slow pair that keeps
  both detection latency and false-positive rate low.

Alerts are edge-triggered: one typed :class:`SloViolation` record lands in
:attr:`SloEngine.history` when an objective *enters* violation, and the
engine re-arms once the fast window recovers.  Every violation also counts
into the metrics registry (``slo.violations`` labeled by objective), and the
record set alone reconstructs the alert timeline — the bench contract.

Timestamps are caller-supplied, so the engine works identically on the
fedsim virtual clock (the serving benches) and on wall time.

The quarantine loop: :meth:`SloEngine.feed_quarantine` lifts the PR-7
``robust.trim_quarantine`` per-member ledger (``probes.quarantine_totals``)
into an availability-style objective — the *worst* member's trim rate is
observed against the bound, so a single client repeatedly trimmed by the
robust aggregation rules raises a violation naming that member.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

from repro.obs.probes import quarantine_totals
from repro.obs.records import Record
from repro.obs.registry import get_registry


@dataclass(eq=True)
class SloViolation(Record):
    """One edge-triggered objective violation (JSON-ready via ``to_dict``)."""

    t: float  # observation time the objective entered violation
    objective: str
    kind: str  # "latency" | "availability" | caller-chosen label
    burn_fast: float  # fast-window burn rate at the crossing
    burn_slow: float  # slow-window burn rate at the crossing
    budget: float  # 1 - target
    window_fast_s: float
    window_slow_s: float
    samples_fast: int
    samples_slow: int
    bound: float | None = None  # threshold objectives: the per-sample cut
    detail: str | None = None  # e.g. "member=3" for the quarantine objective


@dataclass(frozen=True)
class Slo:
    """One declarative objective.

    ``target`` is the good fraction (0 < target < 1); ``bound`` makes the
    objective threshold-style (bad when ``value > bound``), ``bound=None``
    availability-style (bad when ``ok`` is falsy).  ``burn_threshold`` is
    the burn rate BOTH windows must exceed to alert.
    """

    name: str
    target: float
    bound: float | None = None
    kind: str = "latency"
    window_fast_s: float = 5.0
    window_slow_s: float = 60.0
    burn_threshold: float = 1.0
    min_samples: int = 1

    def __post_init__(self):
        if not 0.0 < self.target < 1.0:
            raise ValueError(f"slo {self.name!r}: target must be in (0, 1), got {self.target}")
        if not 0.0 < self.window_fast_s < self.window_slow_s:
            raise ValueError(
                f"slo {self.name!r}: need 0 < fast window < slow window, got "
                f"{self.window_fast_s}, {self.window_slow_s}"
            )
        if self.burn_threshold <= 0:
            raise ValueError(f"slo {self.name!r}: burn_threshold must be > 0")
        if self.min_samples < 1:
            raise ValueError(f"slo {self.name!r}: min_samples must be >= 1")

    @property
    def budget(self) -> float:
        return 1.0 - self.target


def quarantine_slo(
    name: str = "robust.quarantine_rate",
    *,
    max_rate: float,
    target: float = 0.999,
    window_fast_s: float = 5.0,
    window_slow_s: float = 60.0,
    burn_threshold: float = 1.0,
) -> Slo:
    """An availability-style objective over the per-member trim ledger:
    violated when any client's cumulative trim rate exceeds ``max_rate``."""
    return Slo(
        name=name, target=target, bound=max_rate, kind="availability",
        window_fast_s=window_fast_s, window_slow_s=window_slow_s,
        burn_threshold=burn_threshold,
    )


@dataclass
class _Stream:
    """Per-objective sliding sample windows (monotone caller timestamps).

    The fast window is a suffix of the slow one, so both are kept as deques
    with running bad-counts: append + evict-from-the-left keeps every
    observation O(1) amortized — the engine sits on the serving hot path
    (one observe per completed request), where re-scanning the slow window
    per sample would be quadratic in the sustained request rate."""

    samples: deque = field(default_factory=deque)  # slow window: (t, bad)
    fast: deque = field(default_factory=deque)  # fast-window suffix
    bad_slow: int = 0
    bad_fast: int = 0
    alerting: bool = False
    last_detail: str | None = None


class SloEngine:
    """Evaluates a set of :class:`Slo` objectives over observation streams."""

    def __init__(self, objectives: tuple | list = ()):
        self._slos: dict[str, Slo] = {}
        self._streams: dict[str, _Stream] = {}
        self.history: list[SloViolation] = []
        for slo in objectives:
            self.add(slo)

    def add(self, slo: Slo) -> Slo:
        if slo.name in self._slos:
            raise ValueError(f"objective {slo.name!r} already registered")
        self._slos[slo.name] = slo
        self._streams[slo.name] = _Stream()
        return slo

    def has(self, name: str) -> bool:
        return name in self._slos

    def objective(self, name: str) -> Slo:
        return self._slos[name]

    def objectives(self) -> list[Slo]:
        return list(self._slos.values())

    # -- observation + evaluation --------------------------------------------

    def observe(
        self, name: str, t: float, value: float | None = None, *,
        ok: bool | None = None, detail: str | None = None,
    ) -> SloViolation | None:
        """Feed one sample and re-evaluate; returns the violation if this
        observation tipped the objective into alert (else None)."""
        slo = self._slos.get(name)
        if slo is None:
            raise KeyError(f"unknown objective {name!r} (add() it first)")
        if (value is None) == (ok is None):
            raise ValueError("pass exactly one of value= or ok=")
        if value is not None and slo.bound is None:
            raise ValueError(
                f"objective {name!r} is availability-style (no bound); feed ok="
            )
        bad = (float(value) > slo.bound) if value is not None else (not ok)
        stream = self._streams[name]
        sample = (float(t), bad)
        stream.samples.append(sample)
        stream.fast.append(sample)
        stream.bad_slow += bad
        stream.bad_fast += bad
        if detail is not None:
            stream.last_detail = detail
        while stream.samples and stream.samples[0][0] < t - slo.window_slow_s:
            stream.bad_slow -= stream.samples.popleft()[1]
        while stream.fast and stream.fast[0][0] < t - slo.window_fast_s:
            stream.bad_fast -= stream.fast.popleft()[1]
        return self._evaluate(slo, stream, float(t))

    def _evaluate(self, slo: Slo, stream: _Stream, t: float) -> SloViolation | None:
        n_fast, bad_fast = len(stream.fast), stream.bad_fast
        n_slow, bad_slow = len(stream.samples), stream.bad_slow
        burn_fast = (bad_fast / n_fast / slo.budget) if n_fast else 0.0
        burn_slow = (bad_slow / n_slow / slo.budget) if n_slow else 0.0
        reg = get_registry()
        reg.gauge("slo.burn").set(burn_fast, objective=slo.name, window="fast")
        reg.gauge("slo.burn").set(burn_slow, objective=slo.name, window="slow")
        firing = (
            n_fast >= slo.min_samples
            and n_slow >= slo.min_samples
            and burn_fast >= slo.burn_threshold
            and burn_slow >= slo.burn_threshold
        )
        if not firing:
            stream.alerting = False
            return None
        if stream.alerting:
            return None  # already inside this violation episode
        stream.alerting = True
        violation = SloViolation(
            t=t, objective=slo.name, kind=slo.kind,
            burn_fast=burn_fast, burn_slow=burn_slow, budget=slo.budget,
            window_fast_s=slo.window_fast_s, window_slow_s=slo.window_slow_s,
            samples_fast=n_fast, samples_slow=n_slow,
            bound=slo.bound, detail=stream.last_detail,
        )
        self.history.append(violation)
        reg.counter("slo.violations").inc(objective=slo.name)
        return violation

    # -- quarantine-ledger plumbing (PR-7 probes -> alerting) ----------------

    def feed_quarantine(
        self, t: float, *, objective: str, rounds: int,
        totals: dict[int, float] | None = None, registry=None,
        kind: str | None = None,
    ) -> SloViolation | None:
        """Observe the worst per-member trim rate from the fault ledger.

        ``totals`` defaults to :func:`repro.obs.probes.quarantine_totals`
        (the ``robust.trim_quarantine`` counter); ``rounds`` normalizes the
        cumulative mass into a rate.  No members trimmed yet counts as a
        clean (rate 0) sample, so the windows still advance.
        """
        if rounds < 1:
            raise ValueError(f"rounds must be >= 1, got {rounds}")
        if totals is None:
            totals = quarantine_totals(registry, kind=kind)
        if not totals:
            return self.observe(objective, t, value=0.0, detail=None)
        worst = max(totals, key=lambda m: totals[m])
        rate = totals[worst] / rounds
        return self.observe(objective, t, value=rate, detail=f"member={worst}")
