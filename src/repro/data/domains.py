"""Synthetic multi-domain datasets with controllable domain shift.

The container is offline, so the paper's Office-31 / Office-Caltech / Digit-Five
benchmarks are replaced by seeded generators that expose the same experimental
axes the paper ablates:

- K source domains + 1 target domain, shared label space (UFDA, Definition 1);
- *explicit* heterogeneity: each domain is a random affine distortion (rotation,
  anisotropic scale, shift) of shared class-conditional Gaussian mixtures — large
  shift, like distinct datasets (mt vs sv);
- *implicit* heterogeneity: one domain split evenly into K+1 subsets (Fig. 5);
- class structure strong enough that source-only classifiers degrade under shift
  while distribution alignment (TCA / RF-TCA / FedRF-TCA) recovers accuracy.

Data convention matches the paper: columns are samples, ``X in R^{p x n}``.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Domain:
    name: str
    x: np.ndarray  # (p, n)
    y: np.ndarray  # (n,)


def _random_rotation(rng: np.random.Generator, p: int, angle_scale: float) -> np.ndarray:
    """Random orthogonal-ish distortion: expm of a scaled skew-symmetric matrix."""
    a = rng.normal(size=(p, p))
    skew = (a - a.T) / 2
    # Pade-free expm via eigendecomposition of the skew-Hermitian matrix
    w, v = np.linalg.eigh(1j * skew * angle_scale)
    return np.real(v @ np.diag(np.exp(-1j * w)) @ v.conj().T)


def make_domains(
    n_domains: int,
    n_per_domain: int,
    *,
    n_classes: int = 5,
    dim: int = 16,
    shift: float = 0.8,
    class_sep: float = 3.0,
    noise: float = 0.6,
    seed: int = 0,
) -> list[Domain]:
    """Explicit heterogeneity: one latent mixture, per-domain affine distortions.

    ``shift`` controls the distortion magnitude (0 = iid domains).
    """
    rng = np.random.default_rng(seed)
    # shared class prototypes on a scaled simplex-ish arrangement
    protos = rng.normal(size=(n_classes, dim))
    protos *= class_sep / np.linalg.norm(protos, axis=1, keepdims=True)
    domains = []
    for d in range(n_domains):
        # partial shift, like real DA benchmarks: mild rotation (class identity
        # stays recoverable) + translation + anisotropic scale. A full random
        # rotation would make UFDA unidentifiable from marginals alone.
        rot = _random_rotation(rng, dim, angle_scale=0.35 * shift)
        scale = 1.0 + shift * rng.uniform(-0.4, 0.4, size=(dim,))
        offset = 1.2 * shift * rng.normal(size=(dim,))
        y = rng.integers(0, n_classes, size=n_per_domain)
        x = protos[y] + noise * rng.normal(size=(n_per_domain, dim))
        x = (x * scale) @ rot.T + offset
        domains.append(Domain(name=f"dom{d}", x=x.T.astype(np.float32), y=y.astype(np.int32)))
    return domains


def make_implicit_domains(
    n_domains: int, n_per_domain: int, *, seed: int = 0, **kw
) -> list[Domain]:
    """Implicit heterogeneity (Fig. 5): one domain split into similar subsets."""
    base = make_domains(1, n_per_domain * n_domains, seed=seed, **kw)[0]
    rng = np.random.default_rng(seed + 1)
    perm = rng.permutation(base.x.shape[1])
    out = []
    for d in range(n_domains):
        idx = perm[d * n_per_domain : (d + 1) * n_per_domain]
        out.append(Domain(name=f"split{d}", x=base.x[:, idx], y=base.y[idx]))
    return out


def train_test_split(dom: Domain, test_frac: float = 0.3, seed: int = 0) -> tuple[Domain, Domain]:
    rng = np.random.default_rng(seed)
    n = dom.x.shape[1]
    perm = rng.permutation(n)
    k = int(n * (1 - test_frac))
    tr, te = perm[:k], perm[k:]
    return (
        Domain(dom.name + "_tr", dom.x[:, tr], dom.y[tr]),
        Domain(dom.name + "_te", dom.x[:, te], dom.y[te]),
    )


def normalize_unit(x: np.ndarray) -> np.ndarray:
    """Unit-Euclidean-norm columns, as the paper preprocesses DeCAF6 features."""
    return x / (np.linalg.norm(x, axis=0, keepdims=True) + 1e-12)


class BatchStream:
    """Infinite shuffled minibatch stream over columns of x.

    Same draw sequence as the generator it replaced (one permutation per
    epoch, consecutive ``batch_size`` slices while a full batch fits), but
    with *capturable* state: :meth:`state` returns a JSON-serializable dict
    and :meth:`set_state` rewinds the stream exactly — the checkpoint
    machinery's requirement for bitwise save -> restore -> continue.  State
    is compact: the rng state captured *before* each permutation draw plus
    the position in it, so restore re-draws the identical permutation
    instead of serializing index arrays.
    """

    def __init__(self, x: np.ndarray, y: np.ndarray, batch_size: int, seed: int = 0):
        self.x, self.y = x, y
        self.batch_size = int(batch_size)
        self.n = x.shape[1]
        if not 0 < self.batch_size <= self.n:
            # the old generator would silently spin forever on batch > n
            raise ValueError(f"batch_size {batch_size} not in [1, {self.n}]")
        self.rng = np.random.default_rng(seed)
        self._new_epoch()

    def _new_epoch(self) -> None:
        self._perm_state = self.rng.bit_generator.state
        self._perm = self.rng.permutation(self.n)
        self._i = 0

    def __iter__(self):
        return self

    def __next__(self):
        if self._i + self.batch_size > self.n:
            self._new_epoch()
        idx = self._perm[self._i : self._i + self.batch_size]
        self._i += self.batch_size
        return self.x[:, idx], self.y[idx]

    def state(self) -> dict:
        return {"perm_state": self._perm_state, "i": self._i}

    def set_state(self, state: dict) -> None:
        self.rng.bit_generator.state = state["perm_state"]
        self._new_epoch()
        self._i = int(state["i"])


def batches(x: np.ndarray, y: np.ndarray, batch_size: int, seed: int = 0):
    """Infinite shuffled minibatch stream over columns of x (a
    :class:`BatchStream`; kept as the seed-era constructor name)."""
    return BatchStream(x, y, batch_size, seed=seed)
