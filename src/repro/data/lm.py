"""Synthetic LM token pipeline (offline container: no real corpora).

A seeded order-1 Markov token stream with Zipfian marginals — enough structure
that next-token cross-entropy decreases during training (the model can learn the
bigram table), which is what the end-to-end example drivers assert.

The loader is host-shardable: ``TokenStream(..., shard=(host_id, n_hosts))``
yields disjoint deterministic slices so multi-host data parallelism reads
non-overlapping data, matching the production data-plane contract.
"""
from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(
        self,
        vocab_size: int,
        batch_size: int,
        seq_len: int,
        *,
        seed: int = 0,
        shard: tuple[int, int] = (0, 1),
        branching: int = 8,
    ):
        self.vocab_size = vocab_size
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.shard = shard
        rng = np.random.default_rng(seed)
        # sparse Markov table: each token has `branching` likely successors
        self._succ = rng.integers(0, vocab_size, size=(vocab_size, branching))
        # Zipf-ish start distribution
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        self._start_p = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._step = 0
        self._seed = seed

    def __iter__(self):
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        host, n_hosts = self.shard
        rng = np.random.default_rng((self._seed, self._step, host))
        self._step += n_hosts
        b, t = self.batch_size, self.seq_len
        toks = np.empty((b, t + 1), dtype=np.int32)
        toks[:, 0] = rng.choice(self.vocab_size, size=b, p=self._start_p)
        # vectorised Markov walk with 10% uniform-noise transitions
        for i in range(t):
            nxt = self._succ[toks[:, i], rng.integers(0, self._succ.shape[1], size=b)]
            noise = rng.random(b) < 0.1
            nxt = np.where(noise, rng.integers(0, self.vocab_size, size=b), nxt)
            toks[:, i + 1] = nxt
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
