from repro.data.domains import (
    Domain,
    batches,
    make_domains,
    make_implicit_domains,
    normalize_unit,
    train_test_split,
)
from repro.data.lm import TokenStream
