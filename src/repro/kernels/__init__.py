"""Pallas TPU kernels for the framework's compute hot spots.

- rff:             fused RFF feature map (paper Def. 2) — matmul + cos/sin epilogue
- centered_gram:   Sigma H Sigma^T for RF-TCA (Alg. 1) with fused centering
- rff_gram_stream: one-pass fused featurize + Gram/moment accumulation —
                   Sigma never hits HBM, peak memory O(N^2 + N b) regardless
                   of the sample count n (the RF-TCA scaling claim); past
                   N ~ 1k it auto-switches to an (i, j) output-tiled grid
                   whose per-instance VMEM is bounded by the tile, not N
- flash_attention: blockwise online-softmax GQA attention (causal / window)
- segment_reduce:  weighted segment sums for the two-tier fleet plane's
                   grouped moment merges — the (E, K) membership x weights
                   matrix contracted against stacked payloads on the MXU

Each has a jit wrapper in ops.py and a pure-jnp oracle in ref.py. On this
CPU container they run with interpret=True; on TPU they lower via Mosaic.
The streaming RF-TCA fit (core.rf_tca) uses an XLA lax.scan with the same
memory profile on non-TPU backends, where interpret-mode Pallas is slow.
"""
from repro.kernels import ops, ref
