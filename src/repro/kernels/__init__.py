"""Pallas TPU kernels for the framework's compute hot spots.

- rff:             fused RFF feature map (paper Def. 2) — matmul + cos/sin epilogue
- centered_gram:   Sigma H Sigma^T for RF-TCA (Alg. 1) with fused centering
- flash_attention: blockwise online-softmax GQA attention (causal / window)

Each has a jit wrapper in ops.py and a pure-jnp oracle in ref.py. On this
CPU container they run with interpret=True; on TPU they lower via Mosaic.
"""
from repro.kernels import ops, ref
