"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) kernels run in interpret mode; on a real TPU pass
``interpret=False`` (the default flips on TPU backends). Wrappers handle
padding to tile boundaries so callers keep arbitrary shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.centered_gram import centered_gram_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.quantize import fake_quant_pallas
from repro.kernels.rff import rff_pallas
from repro.kernels.rff_gram_stream import rff_gram_stream_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> tuple[jax.Array, int]:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
    return x, size


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def rff(x: jax.Array, omega: jax.Array, *, block: int = 128, interpret: bool | None = None):
    """Sigma (2N, n) from X (p, n) and Omega (N, p)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    n_orig = x.shape[1]
    x, _ = _pad_to(x, 1, block)
    x, _ = _pad_to(x, 0, block)
    omega, p_orig = _pad_to(omega, 1, block)
    omega, n_feat = _pad_to(omega, 0, block)
    out = rff_pallas(
        x, omega, block_n=block, block_m=block, block_p=block,
        scale_n=n_feat, interpret=interpret,
    )
    # rows: [cos(padded N); sin(padded N)] -> slice both halves to N
    cos = out[: omega.shape[0]][:n_feat]
    sin = out[omega.shape[0] :][:n_feat]
    return jnp.concatenate([cos, sin], axis=0)[:, :n_orig]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def centered_gram(sigma: jax.Array, *, block: int = 128, interpret: bool | None = None):
    """Sigma H Sigma^T (fp32) from Sigma (2N, n)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    two_n_orig = sigma.shape[0]
    n_orig = sigma.shape[1]
    # sample padding would corrupt the mean -> pad with the row mean (no-op
    # after centering), then correct the scale of the contraction
    pad = (-n_orig) % block
    if pad:
        mu = jnp.mean(sigma, axis=1, keepdims=True)
        sigma = jnp.concatenate([sigma, jnp.broadcast_to(mu, (sigma.shape[0], pad))], axis=1)
    sigma, _ = _pad_to(sigma, 0, block)
    out = centered_gram_pallas(sigma, block=block, block_k=block, interpret=interpret)
    return out[:two_n_orig, :two_n_orig]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def rff_gram_stream(
    x: jax.Array,
    omega: jax.Array,
    ell: jax.Array,
    *,
    block: int = 128,
    interpret: bool | None = None,
):
    """(G_H (2N, 2N) fp32, u = Sigma ell (2N,) fp32) from X (p, n), Omega (N, p).

    Streams sample blocks through the fused featurize+accumulate kernel so the
    (2N, n) RFF matrix Sigma is never materialized (peak memory O(N^2 + N b)).
    Padded sample columns are masked inside the kernel; padded feature rows
    are sliced off here before assembling the [cos; sin] block structure.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    n = x.shape[1]
    lm = jnp.stack([ell.astype(x.dtype), jnp.ones((n,), x.dtype)])  # (2, n)
    x, _ = _pad_to(x, 1, block)
    lm, _ = _pad_to(lm, 1, block)  # zero-pads ell AND the column mask
    x, _ = _pad_to(x, 0, block)
    omega, _ = _pad_to(omega, 1, block)
    omega, n_feat = _pad_to(omega, 0, block)
    gcc, gcs, gss, mc, ms = rff_gram_stream_pallas(
        x, omega, lm, block_k=block, scale_n=n_feat, interpret=interpret
    )
    gcc, gcs, gss = gcc[:n_feat, :n_feat], gcs[:n_feat, :n_feat], gss[:n_feat, :n_feat]
    g = jnp.concatenate(
        [jnp.concatenate([gcc, gcs], axis=1), jnp.concatenate([gcs.T, gss], axis=1)], axis=0
    )
    u = jnp.concatenate([mc[:n_feat, 0], ms[:n_feat, 0]])
    col_sum = jnp.concatenate([mc[:n_feat, 1], ms[:n_feat, 1]])
    g_h = g - jnp.outer(col_sum, col_sum) / n  # rank-one centering correction
    return 0.5 * (g_h + g_h.T), u


@functools.partial(jax.jit, static_argnames=("bits", "block", "interpret"))
def fake_quant(
    x: jax.Array,
    u: jax.Array,
    *,
    bits: int = 8,
    block: int = 8,
    interpret: bool | None = None,
):
    """Fused stochastic quantize->dequantize of any-shape ``x`` with uniforms
    ``u`` (same shape, in [0,1)) — the wire-codec round trip as one kernel.

    The per-tensor absmax scale is a cheap XLA reduction over the *unpadded*
    values; the elementwise divide/floor/clip/rescale runs in the Pallas
    kernel over a padded (rows, 128) layout (zero padding quantizes to zero
    under u=0 padding, then is sliced away).
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    qmax = (1 << (bits - 1)) - 1
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf))
    scale = jnp.where(absmax > 0, absmax / qmax, 1.0).reshape(1, 1)
    size = xf.size
    cols = 128
    rows = -(-size // cols)
    rows += (-rows) % block
    pad = rows * cols - size
    xp = jnp.pad(xf.ravel(), (0, pad)).reshape(rows, cols)
    up = jnp.pad(u.astype(jnp.float32).ravel(), (0, pad)).reshape(rows, cols)
    out = fake_quant_pallas(xp, up, scale, qmax=qmax, block_r=block, interpret=interpret)
    return out.ravel()[:size].reshape(x.shape).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
):
    """(b,h,s,d) x (b,kv,s,d) x (b,kv,s,dv) -> (b,h,s,dv)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
