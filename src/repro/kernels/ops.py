"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) kernels run in interpret mode; on a real TPU pass
``interpret=False`` (the default flips on TPU backends). Wrappers handle
padding to tile boundaries so callers keep arbitrary shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.centered_gram import centered_gram_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.quantize import fake_quant_pallas
from repro.kernels.rff import rff_fused_pallas, rff_pallas
from repro.kernels.rff_gram_stream import (
    rff_gram_stream_fused_pallas,
    rff_gram_stream_fused_tiled_pallas,
    rff_gram_stream_pallas,
    rff_gram_stream_tiled_pallas,
)
from repro.kernels.segment_reduce import segment_reduce_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# Untiled rff_gram_stream holds 3 (N_pad, N_pad) fp32 accumulators in VMEM;
# past this N the tiled layout takes over.
GRAM_TILE_THRESHOLD = 1024


def gram_tile_plan(n_features: int, *, tile: int | None = None) -> dict:
    """Resolve the (tile, VMEM-accumulator-bytes) plan ``rff_gram_stream``
    will execute for a given feature count.

    ``tile=None`` auto-selects: the untiled fast path (``{"tile": None}``)
    while 3 N_pad^2 fp32 accumulators stay VMEM-friendly (N_pad <=
    ``GRAM_TILE_THRESHOLD``), else a (t, t) output tiling with t chosen to
    bound per-instance accumulator memory at 3 t^2 fp32 while keeping the
    N -> N_pad rounding waste small.  ``tile=0`` forces the untiled path,
    any other int forces that tile edge — it must be a multiple of 128
    (TPU lane alignment of the (t, t) blocks; validated here so the mistake
    cannot pass CPU interpret-mode CI and only surface at Mosaic lowering).
    Returns ``{"tile", "n_pad", "acc_bytes"}`` — ``acc_bytes`` is the exact
    per-instance fp32 accumulator footprint, the quantity the VMEM-proxy
    test bounds.
    """
    if tile is None:
        if n_features <= GRAM_TILE_THRESHOLD:
            t = None
        else:
            # 256 keeps rounding waste <= 12.5% up to 2048; 512 (3 MB of
            # accumulators) amortizes grid overhead for genuinely large N
            t = 256 if n_features <= 2048 else 512
    else:
        if tile % 128:
            raise ValueError(f"tile must be a multiple of 128 (TPU lanes), got {tile}")
        t = tile or None
    if t is None:
        n_pad = n_features + (-n_features) % 128
        acc = 3 * n_pad * n_pad * 4 + 2 * n_pad * 2 * 4
    else:
        n_pad = n_features + (-n_features) % t
        acc = 3 * t * t * 4 + 2 * t * 2 * 4
    return {"tile": t, "n_pad": n_pad, "acc_bytes": acc}


def _pad_to(x: jax.Array, axis: int, mult: int) -> tuple[jax.Array, int]:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
    return x, size


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def rff(x: jax.Array, omega: jax.Array, *, block: int = 128, interpret: bool | None = None):
    """Sigma (2N, n) from X (p, n) and Omega (N, p)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    n_orig = x.shape[1]
    x, _ = _pad_to(x, 1, block)
    x, _ = _pad_to(x, 0, block)
    omega, p_orig = _pad_to(omega, 1, block)
    omega, n_feat = _pad_to(omega, 0, block)
    out = rff_pallas(
        x, omega, block_n=block, block_m=block, block_p=block,
        scale_n=n_feat, interpret=interpret,
    )
    # rows: [cos(padded N); sin(padded N)] -> slice both halves to N
    cos = out[: omega.shape[0]][:n_feat]
    sin = out[omega.shape[0] :][:n_feat]
    return jnp.concatenate([cos, sin], axis=0)[:, :n_orig]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def centered_gram(sigma: jax.Array, *, block: int = 128, interpret: bool | None = None):
    """Sigma H Sigma^T (fp32) from Sigma (2N, n)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    two_n_orig = sigma.shape[0]
    n_orig = sigma.shape[1]
    # sample padding would corrupt the mean -> pad with the row mean (no-op
    # after centering), then correct the scale of the contraction
    pad = (-n_orig) % block
    if pad:
        mu = jnp.mean(sigma, axis=1, keepdims=True)
        sigma = jnp.concatenate([sigma, jnp.broadcast_to(mu, (sigma.shape[0], pad))], axis=1)
    sigma, _ = _pad_to(sigma, 0, block)
    out = centered_gram_pallas(sigma, block=block, block_k=block, interpret=interpret)
    return out[:two_n_orig, :two_n_orig]


@functools.partial(jax.jit, static_argnames=("block", "tile", "interpret"))
def rff_gram_stream(
    x: jax.Array,
    omega: jax.Array,
    ell: jax.Array,
    *,
    block: int = 128,
    tile: int | None = None,
    interpret: bool | None = None,
):
    """(G_H (2N, 2N) fp32, u = Sigma ell (2N,) fp32) from X (p, n), Omega (N, p).

    Streams sample blocks through the fused featurize+accumulate kernel so the
    (2N, n) RFF matrix Sigma is never materialized (peak memory O(N^2 + N b)).
    Padded sample columns are masked inside the kernel; padded feature rows
    are sliced off here before assembling the [cos; sin] block structure.

    ``tile`` picks the accumulator layout (see :func:`gram_tile_plan`): None
    auto-selects the untiled kernel for small N and a (t, t) output tiling —
    per-instance VMEM bounded by the tile, not N — past
    ``GRAM_TILE_THRESHOLD``; 0 forces untiled, an int forces that tile edge.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    n = x.shape[1]
    plan_tile = gram_tile_plan(omega.shape[0], tile=tile)["tile"]
    lm = jnp.stack([ell.astype(x.dtype), jnp.ones((n,), x.dtype)])  # (2, n)
    x, _ = _pad_to(x, 1, block)
    lm, _ = _pad_to(lm, 1, block)  # zero-pads ell AND the column mask
    x, _ = _pad_to(x, 0, block)
    omega, _ = _pad_to(omega, 1, block)
    if plan_tile is None:
        omega, n_feat = _pad_to(omega, 0, block)
        gcc, gcs, gss, mc, ms = rff_gram_stream_pallas(
            x, omega, lm, block_k=block, scale_n=n_feat, interpret=interpret
        )
    else:
        omega, n_feat = _pad_to(omega, 0, plan_tile)
        gcc, gcs, gss, mc, ms = rff_gram_stream_tiled_pallas(
            x, omega, lm, tile=plan_tile, block_k=block, scale_n=n_feat,
            interpret=interpret,
        )
    from repro.core.kernels_math import assemble_streamed_gram

    return assemble_streamed_gram(
        gcc[:n_feat, :n_feat], gcs[:n_feat, :n_feat], gss[:n_feat, :n_feat],
        mc[:n_feat, 0], ms[:n_feat, 0], mc[:n_feat, 1], ms[:n_feat, 1],
        n=n,  # fold_n=None: the kernels fold 1/sqrt(N) into cos/sin already
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_features", "seed", "ensemble_index", "sigma_rf", "rf_kernel",
        "block", "interpret",
    ),
)
def rff_fused(
    x: jax.Array,
    *,
    n_features: int,
    seed: int,
    ensemble_index: int = 0,
    sigma_rf: float = 1.0,
    rf_kernel: str = "gauss",
    block: int = 128,
    interpret: bool | None = None,
):
    """Seed-fused Sigma (2N, n) from X (p, n) — no omega operand; the weight
    blocks are drawn inside the kernel from ``threefry(seed, row, col)``."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    n_orig = x.shape[1]
    x, _ = _pad_to(x, 1, block)
    x, _ = _pad_to(x, 0, block)
    nf_pad = n_features + (-n_features) % block
    out = rff_fused_pallas(
        x, nf_pad=nf_pad, scale_n=n_features, seed=seed,
        ensemble_index=ensemble_index, sigma=sigma_rf, rf_kernel=rf_kernel,
        block_n=block, block_m=block, block_p=block, interpret=interpret,
    )
    cos = out[:nf_pad][:n_features]
    sin = out[nf_pad:][:n_features]
    return jnp.concatenate([cos, sin], axis=0)[:, :n_orig]


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_features", "seed", "ensemble", "sigma_rf", "rf_kernel",
        "block", "tile", "interpret",
    ),
)
def rff_gram_stream_fused(
    x: jax.Array,
    ell: jax.Array,
    *,
    n_features: int,
    seed: int,
    ensemble: int = 1,
    sigma_rf: float = 1.0,
    rf_kernel: str = "gauss",
    block: int = 128,
    tile: int | None = None,
    interpret: bool | None = None,
):
    """Seed-fused (G_H (2N, 2N) fp32, u = Sigma ell (2N,) fp32) from X (p, n).

    Like :func:`rff_gram_stream` but with no omega operand at all: W_RF rows
    are drawn inside the kernel from the counter-based threefry stream, so
    neither the (2N, n) feature matrix nor the (N, p) weight matrix ever
    exists in HBM — peak memory is O(N^2 + N b) stats only, and the only
    W_RF "state" anywhere is the integer seed.  ``ensemble=S`` averages the
    statistics over S independently-keyed draws in the same pass (S=1 traces
    the identical single-draw program).  ``tile`` picks the layout exactly as
    in :func:`rff_gram_stream`.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    n = x.shape[1]
    plan_tile = gram_tile_plan(n_features, tile=tile)["tile"]
    lm = jnp.stack([ell.astype(x.dtype), jnp.ones((n,), x.dtype)])  # (2, n)
    x, _ = _pad_to(x, 1, block)
    lm, _ = _pad_to(lm, 1, block)  # zero-pads ell AND the column mask
    x, _ = _pad_to(x, 0, block)
    if plan_tile is None:
        nf_pad = n_features + (-n_features) % block
        gcc, gcs, gss, mc, ms = rff_gram_stream_fused_pallas(
            x, lm, nf_pad=nf_pad, scale_n=n_features, seed=seed,
            ensemble=ensemble, sigma=sigma_rf, rf_kernel=rf_kernel,
            block_k=block, interpret=interpret,
        )
    else:
        nf_pad = n_features + (-n_features) % plan_tile
        gcc, gcs, gss, mc, ms = rff_gram_stream_fused_tiled_pallas(
            x, lm, nf_pad=nf_pad, scale_n=n_features, tile=plan_tile, seed=seed,
            ensemble=ensemble, sigma=sigma_rf, rf_kernel=rf_kernel,
            block_k=block, interpret=interpret,
        )
    from repro.core.kernels_math import assemble_streamed_gram_ensemble

    nf = n_features
    # the kernel folds 1/sqrt(N S) into the features; mc/ms carry draw e's
    # per-draw moment columns at (2e, 2e+1) for the rank-S centering
    return assemble_streamed_gram_ensemble(
        gcc[:nf, :nf], gcs[:nf, :nf], gss[:nf, :nf], mc[:nf], ms[:nf],
        n=n, ensemble=ensemble,
    )


@functools.partial(jax.jit, static_argnames=("n_segments", "block", "interpret"))
def segment_reduce(
    values: jax.Array,
    seg_ids: jax.Array,
    weights: jax.Array,
    *,
    n_segments: int,
    block: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Weighted segment sums ``out[e] = sum_{k: seg[k]=e} w_k * values[k]``.

    ``values`` (K, D), ``seg_ids`` (K,) ints in [0, n_segments), ``weights``
    (K,) -> (n_segments, D) fp32 — the grouped moment merge of the two-tier
    fleet plane as one MXU matmul of the weighted membership matrix against
    the stacked payloads.  Padding: K to the client block (padded rows carry
    weight 0, so they contribute exact zeros), E and D to the 128 lane edge.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    k, d = values.shape
    onehot = (seg_ids[None, :] == jnp.arange(n_segments)[:, None]).astype(jnp.float32)
    wm = onehot * weights.astype(jnp.float32)[None, :]
    vals = values.astype(jnp.float32)
    wm, _ = _pad_to(wm, 1, block)
    vals, _ = _pad_to(vals, 0, block)
    wm, _ = _pad_to(wm, 0, 8)  # sublane edge of the (E, bk) membership blocks
    vals, _ = _pad_to(vals, 1, block)
    out = segment_reduce_pallas(wm, vals, block_k=block, interpret=interpret)
    return out[:n_segments, :d]


@functools.partial(jax.jit, static_argnames=("bits", "block", "interpret"))
def fake_quant(
    x: jax.Array,
    u: jax.Array,
    *,
    bits: int = 8,
    block: int = 8,
    interpret: bool | None = None,
):
    """Fused stochastic quantize->dequantize of any-shape ``x`` with uniforms
    ``u`` (same shape, in [0,1)) — the wire-codec round trip as one kernel.

    The per-tensor absmax scale is a cheap XLA reduction over the *unpadded*
    values; the elementwise divide/floor/clip/rescale runs in the Pallas
    kernel over a padded (rows, 128) layout (zero padding quantizes to zero
    under u=0 padding, then is sliced away).
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    qmax = (1 << (bits - 1)) - 1
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf))
    scale = jnp.where(absmax > 0, absmax / qmax, 1.0).reshape(1, 1)
    size = xf.size
    cols = 128
    rows = -(-size // cols)
    rows += (-rows) % block
    pad = rows * cols - size
    xp = jnp.pad(xf.ravel(), (0, pad)).reshape(rows, cols)
    up = jnp.pad(u.astype(jnp.float32).ravel(), (0, pad)).reshape(rows, cols)
    out = fake_quant_pallas(xp, up, scale, qmax=qmax, block_r=block, interpret=interpret)
    return out.ravel()[:size].reshape(x.shape).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
):
    """(b,h,s,d) x (b,kv,s,d) x (b,kv,s,dv) -> (b,h,s,dv)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
