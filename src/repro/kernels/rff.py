"""Pallas TPU kernel: fused random-Fourier-feature map (paper Definition 2).

Computes  cos(Omega @ X)/sqrt(N)  and  sin(Omega @ X)/sqrt(N)  in one pass:
the (N, n) matmul is tiled into MXU-aligned VMEM blocks, accumulated in fp32
over the contraction (p) grid axis, and the cos/sin + 1/sqrt(N) epilogue is
fused into the final accumulation step — the (N, n) phase matrix never makes
a round trip to HBM (a GPU-style implementation materialises it twice).

Grid: (N/bn, n/bm, p/bp), contraction innermost. Scratch: fp32 (bn, bm).

The seed-fused variant (:func:`rff_fused_pallas`) has no ``omega`` operand:
each program instance draws its ``(bn, bp)`` weight block from the
counter-based threefry stream of :mod:`repro.kernels.prng` at its absolute
``(row, col)`` offset, so the ``(N, p)`` matrix never exists in HBM — the
8-byte seed is the weight.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.prng import fused_omega_block


def _rff_kernel(omega_ref, x_ref, cos_ref, sin_ref, acc_ref, *, n_features: int, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        omega_ref[...], x_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        z = acc_ref[...]
        inv = 1.0 / jnp.sqrt(jnp.float32(n_features))
        cos_ref[...] = (jnp.cos(z) * inv).astype(cos_ref.dtype)
        sin_ref[...] = (jnp.sin(z) * inv).astype(sin_ref.dtype)


def rff_pallas(
    x: jax.Array,  # (p, n)
    omega: jax.Array,  # (N, p)
    *,
    block_n: int = 128,
    block_m: int = 128,
    block_p: int = 128,
    scale_n: int | None = None,  # true N when omega rows are padded
    interpret: bool = True,
) -> jax.Array:
    """Returns Sigma = [cos(Omega X); sin(Omega X)]/sqrt(N) of shape (2N, n)."""
    n_features, p = omega.shape
    _, n = x.shape
    bn = min(block_n, n_features)
    bm = min(block_m, n)
    bp = min(block_p, p)
    if n_features % bn or n % bm or p % bp:
        raise ValueError(f"shapes ({n_features},{p})x({p},{n}) must tile by ({bn},{bm},{bp})")
    k_steps = p // bp
    grid = (n_features // bn, n // bm, k_steps)

    kernel = functools.partial(_rff_kernel, n_features=scale_n or n_features, k_steps=k_steps)
    cos_out, sin_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bp), lambda i, j, k: (i, k)),
            pl.BlockSpec((bp, bm), lambda i, j, k: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((bn, bm), lambda i, j, k: (i, j)),
            pl.BlockSpec((bn, bm), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_features, n), x.dtype),
            jax.ShapeDtypeStruct((n_features, n), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bn, bm), jnp.float32)],
        interpret=interpret,
    )(omega, x)
    return jnp.concatenate([cos_out, sin_out], axis=0)


def _rff_fused_kernel(
    x_ref, cos_ref, sin_ref, acc_ref,
    *, n_features: int, k_steps: int, block_n: int, block_p: int,
    seed: int, ensemble_index: int, sigma: float, rf_kernel: str,
):
    i = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    om = fused_omega_block(
        seed, block_n, block_p, row0=i * block_n, col0=k * block_p,
        ensemble_index=ensemble_index, sigma=sigma, rf_kernel=rf_kernel,
    )
    acc_ref[...] += jnp.dot(om, x_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _epilogue():
        z = acc_ref[...]
        inv = 1.0 / jnp.sqrt(jnp.float32(n_features))
        cos_ref[...] = (jnp.cos(z) * inv).astype(cos_ref.dtype)
        sin_ref[...] = (jnp.sin(z) * inv).astype(sin_ref.dtype)


def rff_fused_pallas(
    x: jax.Array,  # (p_pad, n), zero-padded feature rows
    *,
    nf_pad: int,  # padded draw height (rows [scale_n, nf_pad) are garbage)
    scale_n: int,  # true N for the 1/sqrt(N) normalization
    seed: int,
    ensemble_index: int = 0,
    sigma: float = 1.0,
    rf_kernel: str = "gauss",
    block_n: int = 128,
    block_m: int = 128,
    block_p: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Seed-fused featurize: Sigma = [cos; sin]/sqrt(N) of shape (2*nf_pad, n)
    with the weight blocks drawn inside the kernel.  Weight columns past the
    true data dim multiply zero-padded x rows, so their (drawn, finite)
    values contribute exact zeros to the phase accumulation."""
    p, n = x.shape
    bn = min(block_n, nf_pad)
    bm = min(block_m, n)
    bp = min(block_p, p)
    if nf_pad % bn or n % bm or p % bp:
        raise ValueError(f"shapes ({nf_pad},{p})x({p},{n}) must tile by ({bn},{bm},{bp})")
    k_steps = p // bp
    grid = (nf_pad // bn, n // bm, k_steps)

    kernel = functools.partial(
        _rff_fused_kernel, n_features=scale_n, k_steps=k_steps,
        block_n=bn, block_p=bp, seed=seed, ensemble_index=ensemble_index,
        sigma=sigma, rf_kernel=rf_kernel,
    )
    cos_out, sin_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bp, bm), lambda i, j, k: (k, j))],
        out_specs=[
            pl.BlockSpec((bn, bm), lambda i, j, k: (i, j)),
            pl.BlockSpec((bn, bm), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nf_pad, n), x.dtype),
            jax.ShapeDtypeStruct((nf_pad, n), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bn, bm), jnp.float32)],
        interpret=interpret,
    )(x)
    return jnp.concatenate([cos_out, sin_out], axis=0)
