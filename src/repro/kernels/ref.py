"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rff_ref(x: jax.Array, omega: jax.Array) -> jax.Array:
    """(p, n), (N, p) -> (2N, n)."""
    z = (omega @ x).astype(jnp.float32)
    n = omega.shape[0]
    out = jnp.concatenate([jnp.cos(z), jnp.sin(z)], axis=0) / jnp.sqrt(n)
    return out.astype(x.dtype)


def centered_gram_ref(sigma: jax.Array) -> jax.Array:
    """(2N, n) -> (2N, 2N) fp32."""
    s = sigma.astype(jnp.float32)
    c = s - jnp.mean(s, axis=1, keepdims=True)
    return c @ c.T


def rff_gram_stream_ref(x: jax.Array, omega: jax.Array, ell: jax.Array):
    """Dense oracle for ops.rff_gram_stream: (G_H (2N,2N), u (2N,)) fp32."""
    sigma = rff_ref(x, omega).astype(jnp.float32)
    g_h = centered_gram_ref(sigma)
    return 0.5 * (g_h + g_h.T), sigma @ ell.astype(jnp.float32)


def rff_gram_stream_fused_ref(
    x: jax.Array,
    ell: jax.Array,
    *,
    n_features: int,
    seed: int,
    ensemble: int = 1,
    sigma: float = 1.0,
    rf_kernel: str = "gauss",
):
    """Dense oracle for ops.rff_gram_stream_fused: the mean over S draws of
    the per-draw *centered* Gram and moment,

        G_H = mean_e [Sigma_e H Sigma_e^T],   u = mean_e [Sigma_e ell],

    with Sigma_e built from the materialized generator twin
    (:func:`repro.kernels.prng.fused_omega`) at ensemble key ``e``.  The
    mean-of-centered (not centered-pooled) form is the semantics the fused
    kernels implement via their per-draw moment columns."""
    from repro.kernels.prng import fused_omega

    g_h = None
    u = None
    for e in range(ensemble):
        omega = fused_omega(
            seed, n_features, x.shape[0],
            ensemble_index=e, sigma=sigma, rf_kernel=rf_kernel,
        )
        g_e, u_e = rff_gram_stream_ref(x, omega, ell)
        g_h = g_e if g_h is None else g_h + g_e
        u = u_e if u is None else u + u_e
    return g_h / ensemble, u / ensemble


def fake_quant_ref(x: jax.Array, u: jax.Array, *, bits: int) -> jax.Array:
    """XLA twin of ops.fake_quant: stochastic-round quantize->dequantize with
    a per-tensor absmax scale.  Bit-identical to the Pallas kernel (and to
    comm.codecs.QuantCodec) given the same uniforms ``u``."""
    qmax = (1 << (bits - 1)) - 1
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf))
    scale = jnp.where(absmax > 0, absmax / qmax, 1.0)
    q = jnp.clip(jnp.floor(xf / scale + u.astype(jnp.float32)), -qmax, qmax)
    return (q * scale).astype(x.dtype)


def segment_reduce_ref(
    values: jax.Array, seg_ids: jax.Array, weights: jax.Array, n_segments: int
) -> jax.Array:
    """XLA twin of ops.segment_reduce: ``out[e] = sum_{k: seg[k]=e} w_k v_k``.

    Implemented as the same weighted-membership matmul the kernel runs (one
    dot over K), so the two paths share a contraction order; ``values`` is
    (K, D), ``seg_ids`` (K,) ints, ``weights`` (K,) -> (n_segments, D) fp32.
    """
    onehot = (seg_ids[None, :] == jnp.arange(n_segments)[:, None]).astype(jnp.float32)
    wm = onehot * weights.astype(jnp.float32)[None, :]
    return wm @ values.astype(jnp.float32)


def attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True, window: int = 0
) -> jax.Array:
    """(b,h,s,d), (b,kv,s,d), (b,kv,s,dv) -> (b,h,s,dv)."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    g = h // kv
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    sc = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    sc = sc / (d ** 0.5)
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= i >= j
    if window:
        mask &= (i - j) < window
    sc = jnp.where(mask[None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)
