"""Pallas TPU kernel: blockwise online-softmax attention (GQA, causal, window).

The framework's backbone hot spot. KV blocks stream HBM->VMEM along the
innermost grid axis while fp32 running (max, sum, acc) live in VMEM scratch;
the q-block output is written once on the last KV step. Causal/sliding-window
masks are computed from grid coordinates with iota — fully-masked blocks still
execute (Pallas grids are static) but contribute exp(-inf)=0.

Grid: (batch, q_heads, s_q/bq, s_k/bk). GQA is expressed in the k/v index_map:
kv_head = q_head // (h // kv), so no KV replication in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, block_q: int, block_k: int, causal: bool, window: int, k_steps: int,
):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)  # (bk, dv)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    s = s * scale  # (bq, bk)

    qi = pl.program_id(2)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = corr * acc_ref[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == k_steps - 1)
    def _write():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (b, h, s, d)
    k: jax.Array,  # (b, kv, s, d)
    v: jax.Array,  # (b, kv, s, dv)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, h, s, d = q.shape
    kv = k.shape[1]
    dv = v.shape[-1]
    g = h // kv
    bq = min(block_q, s)
    bk = min(block_k, s)
    if s % bq or s % bk:
        raise ValueError(f"seq {s} must tile by ({bq},{bk})")
    k_steps = s // bk
    grid = (b, h, s // bq, k_steps)
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=bq, block_k=bk,
        causal=causal, window=window, k_steps=k_steps,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, dv), lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dv), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dv), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
