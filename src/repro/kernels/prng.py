"""Counter-based in-kernel PRNG: element-addressed threefry-2x32 draws.

The seed-fused kernels (``rff.py``, ``rff_gram_stream.py``) generate their
W_RF/Omega rows *inside* the kernel instead of reading a materialized
``(N, p)`` weight tensor from HBM.  That only works if the draw for any
element is a pure function of its *absolute* coordinates — independent of
which tile computes it, in what order, at what padding.  This module is that
function, shared verbatim by the Pallas kernels (interpret mode on CPU,
Mosaic-lowered uint32 ops on TPU) and their XLA generator twins, so
fused-vs-twin agreement is bit-for-bit by construction:

    key     = (seed, ensemble_index)            per random-feature draw
    counter = (row, col)                        per Omega element
    bits    = threefry2x32(key, counter)        2 x uint32
    omega   = box_muller(bits) / sigma          N(0, 1/sigma^2)   (gauss)
            = cauchy(bits) / sigma              Cauchy(0, 1/sigma) (laplace)

Properties the tests pin down:

- **tile-index independence** — a ``(rows, cols)`` block at offset
  ``(r0, c0)`` equals the same slice of the full matrix, whatever other
  blocks are drawn (each element only ever sees its own counter);
- **cross-layout equality** — tiled, untiled, and twin draws agree
  bit-for-bit at overlapping N;
- **ensemble independence** — draw ``e`` is keyed, not offset, so
  ``ensemble=1`` is the single-draw stream (``e=0``) exactly.

This is the classic Random123 threefry-2x32-20 (the same core jax's
``threefry2x32`` implements), written in plain ``jnp`` uint32 ops so the
identical trace runs inside a Pallas kernel body and in an XLA program.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# threefry-2x32 rotation schedule (Random123): even / odd round quads
_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))
_PARITY = np.uint32(0x1BD11BDA)
_TWO_PI = 6.283185307179586


def _rotl(x: jax.Array, r: int) -> jax.Array:
    return (x << np.uint32(r)) | (x >> np.uint32(32 - r))


def threefry2x32(k0, k1, c0, c1) -> tuple[jax.Array, jax.Array]:
    """20-round threefry-2x32 of counter ``(c0, c1)`` under key ``(k0, k1)``.

    All inputs uint32 (scalars or broadcastable arrays); returns two uint32
    arrays of the broadcast shape.  Pure jnp — traceable inside Pallas.
    """
    k0 = jnp.asarray(k0, jnp.uint32)
    k1 = jnp.asarray(k1, jnp.uint32)
    ks = (k0, k1, _PARITY ^ k0 ^ k1)
    x0 = jnp.asarray(c0, jnp.uint32) + ks[0]
    x1 = jnp.asarray(c1, jnp.uint32) + ks[1]
    for d in range(5):
        for r in _ROTATIONS[d % 2]:
            x0 = x0 + x1
            x1 = _rotl(x1, r) ^ x0
        x0 = x0 + ks[(d + 1) % 3]
        x1 = x1 + ks[(d + 2) % 3] + np.uint32(d + 1)
    return x0, x1


def _uniform(bits: jax.Array) -> jax.Array:
    """uint32 -> fp32 uniform on [0, 1) with 24-bit resolution."""
    return (bits >> np.uint32(8)).astype(jnp.float32) * jnp.float32(2.0**-24)


def _normal(b0: jax.Array, b1: jax.Array) -> jax.Array:
    """One N(0, 1) draw per element via Box-Muller on a bit pair.

    ``u1`` enters as ``1 - u`` in (0, 1] so the log is always finite; the
    radius is bounded by sqrt(-2 ln 2^-24) ~ 5.77.
    """
    u1 = _uniform(b0)
    u2 = _uniform(b1)
    r = jnp.sqrt(-2.0 * jnp.log1p(-u1))
    return r * jnp.cos(jnp.float32(_TWO_PI) * u2)


def _cauchy(b0: jax.Array, b1: jax.Array) -> jax.Array:
    """One Cauchy(0, 1) draw per element (inverse CDF on the first word)."""
    u = _uniform(b0)
    return jnp.tan(jnp.float32(np.pi) * (u - 0.5))


_DISTS = {"gauss": _normal, "laplace": _cauchy}


def fused_omega_block(
    seed: int,
    rows: int,
    cols: int,
    *,
    row0=0,
    col0=0,
    ensemble_index: int = 0,
    sigma: float = 1.0,
    rf_kernel: str = "gauss",
) -> jax.Array:
    """A ``(rows, cols)`` block of the seed-defined Omega at offset
    ``(row0, col0)`` — the single generator both the fused Pallas kernels and
    their XLA twins call.

    ``row0`` may be a traced scalar (tiled kernels pass the tile offset);
    everything else is static.  gauss: N(0, 1/sigma^2); laplace:
    Cauchy(0, 1/sigma) — matching :func:`repro.core.rff.draw_omega`'s kernel
    semantics under a different (counter-based) stream.
    """
    if rf_kernel not in _DISTS:
        raise ValueError(f"unknown rf kernel {rf_kernel!r}")
    r = jnp.asarray(row0, jnp.uint32) + jax.lax.broadcasted_iota(
        jnp.uint32, (rows, cols), 0
    )
    c = jnp.asarray(col0, jnp.uint32) + jax.lax.broadcasted_iota(
        jnp.uint32, (rows, cols), 1
    )
    b0, b1 = threefry2x32(
        np.uint32(np.uint64(seed) & 0xFFFFFFFF), np.uint32(ensemble_index), r, c
    )
    draw = _DISTS[rf_kernel](b0, b1)
    if sigma != 1.0:
        draw = draw * jnp.float32(1.0 / sigma)
    return draw


def fused_omega(
    seed: int,
    n_features: int,
    dim: int,
    *,
    ensemble_index: int = 0,
    sigma: float = 1.0,
    rf_kernel: str = "gauss",
) -> jax.Array:
    """The full ``(N, p)`` Omega of the fused stream — the *generator twin*.

    The fused kernels never materialize this; tests and small out-of-sample
    transforms do.  Bit-identical to assembling :func:`fused_omega_block`
    tiles at any tiling.
    """
    return fused_omega_block(
        seed, n_features, dim,
        ensemble_index=ensemble_index, sigma=sigma, rf_kernel=rf_kernel,
    )
