"""Pallas TPU kernel: weighted segment-reduce for grouped moment merges.

The two-tier fleet plane merges K client payloads into E edge partials:

    out[e, :] = sum_k M[e, k] * w[k] * values[k, :]

with M the (E, K) 0/1 edge-membership matrix and w the per-client merge
weights (participation masks x staleness weights).  Expressed as a matmul of
the weighted membership ``WM = M * w`` against the stacked values, the MXU
does the segment reduction directly — no scatter, no sort — and the same
kernel serves every payload kind by flattening trailing dims into D.

Grid: ``(K/bk,)`` with the client-block loop as the only axis; each step
accumulates ``WM[:, k-block] @ values[k-block, :]`` into an (E_pad, D_pad)
fp32 VMEM accumulator (edge counts are small — hundreds — so the full output
fits VMEM comfortably; a (E, D) output tiling along the ``rff_gram_stream``
tiled layout is the known extension if E*D ever outgrows it).

``kernels.ref.segment_reduce_ref`` is the XLA twin (same contraction); the
fleet merge code uses the twin on non-TPU backends where interpret-mode
Pallas is slow, exactly like the streaming-Gram solver does.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _segment_reduce_kernel(wm_ref, v_ref, out_ref, acc, *, k_steps: int):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot(
        wm_ref[...], v_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _write():
        out_ref[...] = acc[...]


def segment_reduce_pallas(
    wm: jax.Array,  # (E_pad, K_pad) fp32 weighted membership M * w
    values: jax.Array,  # (K_pad, D_pad) fp32 stacked client payloads
    *,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """(E_pad, D_pad) fp32 weighted segment sums; see module docstring."""
    e_pad, k_pad = wm.shape
    k_v, d_pad = values.shape
    bk = min(block_k, k_pad)
    if k_v != k_pad or k_pad % bk:
        raise ValueError(f"wm {wm.shape} / values {values.shape} must share K%{bk}==0")
    k_steps = k_pad // bk
    return pl.pallas_call(
        functools.partial(_segment_reduce_kernel, k_steps=k_steps),
        grid=(k_steps,),
        in_specs=[
            pl.BlockSpec((e_pad, bk), lambda k: (0, k)),
            pl.BlockSpec((bk, d_pad), lambda k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((e_pad, d_pad), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((e_pad, d_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((e_pad, d_pad), jnp.float32)],
        interpret=interpret,
    )(wm, values)
