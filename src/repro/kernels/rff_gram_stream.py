"""Pallas TPU kernel: streamed RFF Gram accumulation (RF-TCA Alg. 1 hot path).

Fuses the three stages of the RF-TCA statistics pass — RFF featurization
(paper Def. 2), sample masking, and Gram/moment accumulation — into one
kernel that consumes X (p, n) in sample blocks and emits only O(N^2)-sized
statistics:

    G_cc = C C^T,  G_cs = C S^T,  G_ss = S S^T      (N, N) each
    M_c  = C [ell; mask]^T,  M_s = S [ell; mask]^T  (N, 2) each

with C = cos(Omega X)/sqrt(N), S = sin(Omega X)/sqrt(N) masked to the true
sample columns.  The caller assembles Sigma H Sigma^T and u = Sigma ell from
these; the (2N, n) matrix Sigma itself NEVER exists in HBM, so peak memory is
O(N^2 + N b) for sample-block size b, independent of n — exactly the scaling
the paper claims for RF-TCA.

Two layouts share the kernel math:

- **untiled** (`rff_gram_stream_pallas`): grid (n / bk,) — one axis over
  sample blocks, (N_pad, N_pad) fp32 VMEM accumulators held across the whole
  pass.  3 N^2 fp32 buffers must fit VMEM, so this is the fast path up to
  N_pad ~ 1024 per core.
- **tiled** (`rff_gram_stream_tiled_pallas`): grid (N/t, N/t, n/bk) — a 2-D
  output tiling over (i, j) feature-tile pairs with the sample-block loop
  innermost, so each program instance only holds a (t, t) block of each Gram
  accumulator in VMEM (3 t^2 fp32, independent of N).  Row tile i recomputes
  its cos/sin slab once per (j, k) step — the usual flop-for-memory trade of
  output tiling — which removes the N ceiling entirely.

``kernels.ops.rff_gram_stream`` auto-selects between them from N.

**Seed-fused variants** (`rff_gram_stream_fused_pallas`,
`rff_gram_stream_fused_tiled_pallas`): no ``omega`` operand at all — each
program instance draws its W_RF rows *inside* the kernel from the
counter-based threefry stream of :mod:`repro.kernels.prng`
(``threefry(seed, feature_row, column)`` per element), so the ``(N, p)``
weight tensor never exists in HBM on either side of the federation.  The
per-step math lives in :func:`fused_step_stats` /
:func:`fused_tile_pair_step` / :func:`fused_tile_moment_step`, shared
verbatim by the kernels and their XLA generator twins in ``core/rf_tca.py``
— fused-vs-twin agreement is bit-for-bit by construction.  ``ensemble=S``
averages the Gram/moment statistics over S independently-keyed draws in the
same pass (near-free variance reduction: the draws ride the already-streamed
sample blocks); ``S=1`` traces the identical program as the single-draw path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.prng import fused_omega_block


def _rff_gram_kernel(
    omega_ref,
    x_ref,
    lm_ref,
    gcc_ref,
    gcs_ref,
    gss_ref,
    mc_ref,
    ms_ref,
    acc_cc,
    acc_cs,
    acc_ss,
    acc_mc,
    acc_ms,
    *,
    n_features: int,
    k_steps: int,
):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc_cc[...] = jnp.zeros_like(acc_cc)
        acc_cs[...] = jnp.zeros_like(acc_cs)
        acc_ss[...] = jnp.zeros_like(acc_ss)
        acc_mc[...] = jnp.zeros_like(acc_mc)
        acc_ms[...] = jnp.zeros_like(acc_ms)

    z = jnp.dot(omega_ref[...], x_ref[...], preferred_element_type=jnp.float32)
    inv = 1.0 / jnp.sqrt(jnp.float32(n_features))
    lm = lm_ref[...].astype(jnp.float32)  # (2, bk): row 0 = ell, row 1 = mask
    mask = lm[1:2, :]  # (1, bk); zero on padded sample columns
    c = jnp.cos(z) * inv * mask
    s = jnp.sin(z) * inv * mask

    contract = (((1,), (1,)), ((), ()))
    acc_cc[...] += jax.lax.dot_general(c, c, contract, preferred_element_type=jnp.float32)
    acc_cs[...] += jax.lax.dot_general(c, s, contract, preferred_element_type=jnp.float32)
    acc_ss[...] += jax.lax.dot_general(s, s, contract, preferred_element_type=jnp.float32)
    acc_mc[...] += jax.lax.dot_general(c, lm, contract, preferred_element_type=jnp.float32)
    acc_ms[...] += jax.lax.dot_general(s, lm, contract, preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _write():
        gcc_ref[...] = acc_cc[...]
        gcs_ref[...] = acc_cs[...]
        gss_ref[...] = acc_ss[...]
        mc_ref[...] = acc_mc[...]
        ms_ref[...] = acc_ms[...]


def _rff_gram_tiled_kernel(
    omega_i_ref,
    omega_j_ref,
    x_ref,
    lm_ref,
    gcc_ref,
    gcs_ref,
    gss_ref,
    mc_ref,
    ms_ref,
    acc_cc,
    acc_cs,
    acc_ss,
    acc_mc,
    acc_ms,
    *,
    n_features: int,
    k_steps: int,
):
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_cc[...] = jnp.zeros_like(acc_cc)
        acc_cs[...] = jnp.zeros_like(acc_cs)
        acc_ss[...] = jnp.zeros_like(acc_ss)

    @pl.when((k == 0) & (j == 0))
    def _init_moments():
        acc_mc[...] = jnp.zeros_like(acc_mc)
        acc_ms[...] = jnp.zeros_like(acc_ms)

    inv = 1.0 / jnp.sqrt(jnp.float32(n_features))
    lm = lm_ref[...].astype(jnp.float32)  # (2, bk): row 0 = ell, row 1 = mask
    mask = lm[1:2, :]  # (1, bk); zero on padded sample columns
    z_i = jnp.dot(omega_i_ref[...], x_ref[...], preferred_element_type=jnp.float32)
    z_j = jnp.dot(omega_j_ref[...], x_ref[...], preferred_element_type=jnp.float32)
    c_i = jnp.cos(z_i) * inv * mask
    s_i = jnp.sin(z_i) * inv * mask
    c_j = jnp.cos(z_j) * inv * mask
    s_j = jnp.sin(z_j) * inv * mask

    contract = (((1,), (1,)), ((), ()))
    acc_cc[...] += jax.lax.dot_general(c_i, c_j, contract, preferred_element_type=jnp.float32)
    acc_cs[...] += jax.lax.dot_general(c_i, s_j, contract, preferred_element_type=jnp.float32)
    acc_ss[...] += jax.lax.dot_general(s_i, s_j, contract, preferred_element_type=jnp.float32)

    # the (t, 2) moment blocks only depend on the row tile i: accumulate them
    # once per i, on the j == 0 sweep
    @pl.when(j == 0)
    def _moments():
        acc_mc[...] += jax.lax.dot_general(
            c_i, lm, contract, preferred_element_type=jnp.float32
        )
        acc_ms[...] += jax.lax.dot_general(
            s_i, lm, contract, preferred_element_type=jnp.float32
        )

    @pl.when(k == k_steps - 1)
    def _write():
        gcc_ref[...] = acc_cc[...]
        gcs_ref[...] = acc_cs[...]
        gss_ref[...] = acc_ss[...]

    @pl.when((k == k_steps - 1) & (j == 0))
    def _write_moments():
        mc_ref[...] = acc_mc[...]
        ms_ref[...] = acc_ms[...]


def rff_gram_stream_tiled_pallas(
    x: jax.Array,  # (p, n)
    omega: jax.Array,  # (N, p), N a multiple of ``tile``
    lm: jax.Array,  # (2, n): stacked [ell; column-mask]
    *,
    tile: int = 512,
    block_k: int = 128,
    scale_n: int | None = None,  # true N when omega rows are padded
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Tiled layout of :func:`rff_gram_stream_pallas` (same five outputs).

    Grid (N/t, N/t, n/bk): each (i, j) program instance owns the (t, t)
    output blocks G_cc[i, j], G_cs[i, j], G_ss[i, j] and streams all sample
    blocks through them before moving on — VMEM per instance is 3 t^2 fp32
    accumulators plus two (t, bk) slabs, *independent of N*.
    """
    n_features, p = omega.shape
    _, n = x.shape
    bk = min(block_k, n)
    if n % bk or lm.shape[1] != n:
        raise ValueError(f"n={n} must tile by {bk} and match lm {lm.shape}")
    if n_features % tile:
        raise ValueError(f"N={n_features} must tile by {tile}")
    n_tiles = n_features // tile
    k_steps = n // bk

    kernel = functools.partial(
        _rff_gram_tiled_kernel, n_features=scale_n or n_features, k_steps=k_steps
    )
    return pl.pallas_call(
        kernel,
        grid=(n_tiles, n_tiles, k_steps),
        in_specs=[
            pl.BlockSpec((tile, p), lambda i, j, k: (i, 0)),
            pl.BlockSpec((tile, p), lambda i, j, k: (j, 0)),
            pl.BlockSpec((p, bk), lambda i, j, k: (0, k)),
            pl.BlockSpec((2, bk), lambda i, j, k: (0, k)),
        ],
        out_specs=[
            pl.BlockSpec((tile, tile), lambda i, j, k: (i, j)),
            pl.BlockSpec((tile, tile), lambda i, j, k: (i, j)),
            pl.BlockSpec((tile, tile), lambda i, j, k: (i, j)),
            pl.BlockSpec((tile, 2), lambda i, j, k: (i, 0)),
            pl.BlockSpec((tile, 2), lambda i, j, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_features, n_features), jnp.float32),
            jax.ShapeDtypeStruct((n_features, n_features), jnp.float32),
            jax.ShapeDtypeStruct((n_features, n_features), jnp.float32),
            jax.ShapeDtypeStruct((n_features, 2), jnp.float32),
            jax.ShapeDtypeStruct((n_features, 2), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile, tile), jnp.float32),
            pltpu.VMEM((tile, tile), jnp.float32),
            pltpu.VMEM((tile, tile), jnp.float32),
            pltpu.VMEM((tile, 2), jnp.float32),
            pltpu.VMEM((tile, 2), jnp.float32),
        ],
        interpret=interpret,
    )(omega, omega, x, lm)


def rff_gram_stream_pallas(
    x: jax.Array,  # (p, n)
    omega: jax.Array,  # (N, p)
    lm: jax.Array,  # (2, n): stacked [ell; column-mask]
    *,
    block_k: int = 128,
    scale_n: int | None = None,  # true N when omega rows are padded
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Returns (G_cc, G_cs, G_ss, M_c, M_s); see module docstring for shapes."""
    n_features, p = omega.shape
    _, n = x.shape
    bk = min(block_k, n)
    if n % bk or lm.shape[1] != n:
        raise ValueError(f"n={n} must tile by {bk} and match lm {lm.shape}")
    k_steps = n // bk

    kernel = functools.partial(
        _rff_gram_kernel, n_features=scale_n or n_features, k_steps=k_steps
    )
    nf = n_features
    return pl.pallas_call(
        kernel,
        grid=(k_steps,),
        in_specs=[
            pl.BlockSpec((nf, p), lambda k: (0, 0)),
            pl.BlockSpec((p, bk), lambda k: (0, k)),
            pl.BlockSpec((2, bk), lambda k: (0, k)),
        ],
        out_specs=[
            pl.BlockSpec((nf, nf), lambda k: (0, 0)),
            pl.BlockSpec((nf, nf), lambda k: (0, 0)),
            pl.BlockSpec((nf, nf), lambda k: (0, 0)),
            pl.BlockSpec((nf, 2), lambda k: (0, 0)),
            pl.BlockSpec((nf, 2), lambda k: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nf, nf), jnp.float32),
            jax.ShapeDtypeStruct((nf, nf), jnp.float32),
            jax.ShapeDtypeStruct((nf, nf), jnp.float32),
            jax.ShapeDtypeStruct((nf, 2), jnp.float32),
            jax.ShapeDtypeStruct((nf, 2), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((nf, nf), jnp.float32),
            pltpu.VMEM((nf, nf), jnp.float32),
            pltpu.VMEM((nf, nf), jnp.float32),
            pltpu.VMEM((nf, 2), jnp.float32),
            pltpu.VMEM((nf, 2), jnp.float32),
        ],
        interpret=interpret,
    )(omega, x, lm)


# --------------------------------------------------------------------------
# seed-fused layouts: W_RF rows drawn inside the kernel, no omega operand
# --------------------------------------------------------------------------

_CONTRACT = (((1,), (1,)), ((), ()))


def _fused_feature_scales(lm, *, n_features: int, ensemble: int):
    """(mask, per-feature scale, fp32 lm) for one sample block.

    Features carry 1/sqrt(N S): quadratic contractions (the Gram blocks) then
    accumulate the *mean over draws* directly, while the per-draw moment
    columns come out scaled by 1/sqrt(S) — exactly what the ensemble assembly
    (``assemble_streamed_gram_ensemble``) expects for averaging the centered
    per-draw Grams.  At ``S=1`` no extra op is traced — the single-draw
    program is unchanged.
    """
    inv = 1.0 / jnp.sqrt(jnp.float32(n_features))
    lmf = lm.astype(jnp.float32)  # (2, bk): row 0 = ell, row 1 = mask
    mask = lmf[1:2, :]  # (1, bk); zero on padded sample columns
    if ensemble > 1:
        inv = inv * jax.lax.rsqrt(jnp.float32(ensemble))
    return mask, inv, lmf


def fused_step_stats(
    xblk, lm, *, nf: int, n_features: int, seed: int, ensemble: int,
    sigma: float, rf_kernel: str,
):
    """One sample block's five stat contributions, W_RF rows drawn in-step.

    ``xblk`` (p_pad, bk), ``lm`` (2, bk) -> (dcc (nf, nf), dcs, dss,
    dmc (nf, 2S), dms).  The Gram contributions are pooled over draws (the
    1/sqrt(S) feature scale makes the sum the mean); the moment columns stay
    per draw — centering is quadratic in the column sums, so the assembly
    (:func:`repro.core.kernels_math.assemble_streamed_gram_ensemble`) needs
    draw ``e``'s columns at ``(2e, 2e+1)``.  Shared verbatim by the untiled
    fused kernel and its XLA twin so both trace the identical float ops.
    """
    mask, inv, lm_m = _fused_feature_scales(lm, n_features=n_features, ensemble=ensemble)
    dcc = dcs = dss = None
    dmc_cols = []
    dms_cols = []
    for e in range(ensemble):
        om = fused_omega_block(
            seed, nf, xblk.shape[0], ensemble_index=e, sigma=sigma, rf_kernel=rf_kernel
        )
        z = jnp.dot(om, xblk, preferred_element_type=jnp.float32)
        c = jnp.cos(z) * inv * mask
        s = jnp.sin(z) * inv * mask
        terms = (
            jax.lax.dot_general(c, c, _CONTRACT, preferred_element_type=jnp.float32),
            jax.lax.dot_general(c, s, _CONTRACT, preferred_element_type=jnp.float32),
            jax.lax.dot_general(s, s, _CONTRACT, preferred_element_type=jnp.float32),
        )
        if dcc is None:
            dcc, dcs, dss = terms
        else:
            dcc, dcs, dss = (a + t for a, t in zip((dcc, dcs, dss), terms))
        dmc_cols.append(
            jax.lax.dot_general(c, lm_m, _CONTRACT, preferred_element_type=jnp.float32)
        )
        dms_cols.append(
            jax.lax.dot_general(s, lm_m, _CONTRACT, preferred_element_type=jnp.float32)
        )
    dmc = dmc_cols[0] if ensemble == 1 else jnp.concatenate(dmc_cols, axis=1)
    dms = dms_cols[0] if ensemble == 1 else jnp.concatenate(dms_cols, axis=1)
    return dcc, dcs, dss, dmc, dms


def fused_tile_pair_step(
    xblk, lm, row_i, row_j, *, tile: int, n_features: int, seed: int,
    ensemble: int, sigma: float, rf_kernel: str,
):
    """One (i, j) feature-tile pair's Gram contributions on one sample block.

    ``row_i`` / ``row_j`` are the tiles' absolute row offsets (traced in the
    kernel: ``program_id * tile``).  Returns (dcc, dcs, dss), each (t, t).
    """
    mask, inv, _ = _fused_feature_scales(lm, n_features=n_features, ensemble=ensemble)
    dcc = dcs = dss = None
    for e in range(ensemble):
        om_i = fused_omega_block(
            seed, tile, xblk.shape[0], row0=row_i,
            ensemble_index=e, sigma=sigma, rf_kernel=rf_kernel,
        )
        om_j = fused_omega_block(
            seed, tile, xblk.shape[0], row0=row_j,
            ensemble_index=e, sigma=sigma, rf_kernel=rf_kernel,
        )
        z_i = jnp.dot(om_i, xblk, preferred_element_type=jnp.float32)
        z_j = jnp.dot(om_j, xblk, preferred_element_type=jnp.float32)
        c_i = jnp.cos(z_i) * inv * mask
        s_i = jnp.sin(z_i) * inv * mask
        c_j = jnp.cos(z_j) * inv * mask
        s_j = jnp.sin(z_j) * inv * mask
        terms = (
            jax.lax.dot_general(c_i, c_j, _CONTRACT, preferred_element_type=jnp.float32),
            jax.lax.dot_general(c_i, s_j, _CONTRACT, preferred_element_type=jnp.float32),
            jax.lax.dot_general(s_i, s_j, _CONTRACT, preferred_element_type=jnp.float32),
        )
        if dcc is None:
            dcc, dcs, dss = terms
        else:
            dcc, dcs, dss = (a + t for a, t in zip((dcc, dcs, dss), terms))
    return dcc, dcs, dss


def fused_tile_moment_step(
    xblk, lm, row_i, *, tile: int, n_features: int, seed: int, ensemble: int,
    sigma: float, rf_kernel: str,
):
    """One row tile's (t, 2S) per-draw moment contributions on one sample
    block — draw ``e``'s (ell-moment, column-sum) land in columns
    ``(2e, 2e+1)``, matching :func:`fused_step_stats`."""
    mask, inv, lm_m = _fused_feature_scales(lm, n_features=n_features, ensemble=ensemble)
    dmc_cols = []
    dms_cols = []
    for e in range(ensemble):
        om_i = fused_omega_block(
            seed, tile, xblk.shape[0], row0=row_i,
            ensemble_index=e, sigma=sigma, rf_kernel=rf_kernel,
        )
        z_i = jnp.dot(om_i, xblk, preferred_element_type=jnp.float32)
        c_i = jnp.cos(z_i) * inv * mask
        s_i = jnp.sin(z_i) * inv * mask
        dmc_cols.append(
            jax.lax.dot_general(c_i, lm_m, _CONTRACT, preferred_element_type=jnp.float32)
        )
        dms_cols.append(
            jax.lax.dot_general(s_i, lm_m, _CONTRACT, preferred_element_type=jnp.float32)
        )
    dmc = dmc_cols[0] if ensemble == 1 else jnp.concatenate(dmc_cols, axis=1)
    dms = dms_cols[0] if ensemble == 1 else jnp.concatenate(dms_cols, axis=1)
    return dmc, dms


def _rff_gram_fused_kernel(
    x_ref, lm_ref, gcc_ref, gcs_ref, gss_ref, mc_ref, ms_ref,
    acc_cc, acc_cs, acc_ss, acc_mc, acc_ms,
    *, n_features: int, k_steps: int, seed: int, ensemble: int,
    sigma: float, rf_kernel: str,
):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc_cc[...] = jnp.zeros_like(acc_cc)
        acc_cs[...] = jnp.zeros_like(acc_cs)
        acc_ss[...] = jnp.zeros_like(acc_ss)
        acc_mc[...] = jnp.zeros_like(acc_mc)
        acc_ms[...] = jnp.zeros_like(acc_ms)

    dcc, dcs, dss, dmc, dms = fused_step_stats(
        x_ref[...], lm_ref[...], nf=acc_cc.shape[0], n_features=n_features,
        seed=seed, ensemble=ensemble, sigma=sigma, rf_kernel=rf_kernel,
    )
    acc_cc[...] += dcc
    acc_cs[...] += dcs
    acc_ss[...] += dss
    acc_mc[...] += dmc
    acc_ms[...] += dms

    @pl.when(k == k_steps - 1)
    def _write():
        gcc_ref[...] = acc_cc[...]
        gcs_ref[...] = acc_cs[...]
        gss_ref[...] = acc_ss[...]
        mc_ref[...] = acc_mc[...]
        ms_ref[...] = acc_ms[...]


def _rff_gram_fused_tiled_kernel(
    x_ref, lm_ref, gcc_ref, gcs_ref, gss_ref, mc_ref, ms_ref,
    acc_cc, acc_cs, acc_ss, acc_mc, acc_ms,
    *, n_features: int, k_steps: int, tile: int, seed: int, ensemble: int,
    sigma: float, rf_kernel: str,
):
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_cc[...] = jnp.zeros_like(acc_cc)
        acc_cs[...] = jnp.zeros_like(acc_cs)
        acc_ss[...] = jnp.zeros_like(acc_ss)

    @pl.when((k == 0) & (j == 0))
    def _init_moments():
        acc_mc[...] = jnp.zeros_like(acc_mc)
        acc_ms[...] = jnp.zeros_like(acc_ms)

    x = x_ref[...]
    lm = lm_ref[...]
    dcc, dcs, dss = fused_tile_pair_step(
        x, lm, i * tile, j * tile, tile=tile, n_features=n_features,
        seed=seed, ensemble=ensemble, sigma=sigma, rf_kernel=rf_kernel,
    )
    acc_cc[...] += dcc
    acc_cs[...] += dcs
    acc_ss[...] += dss

    # the (t, 2) moment blocks only depend on the row tile i: accumulate them
    # once per i, on the j == 0 sweep (the row slab is re-drawn — same bits)
    @pl.when(j == 0)
    def _moments():
        dmc, dms = fused_tile_moment_step(
            x, lm, i * tile, tile=tile, n_features=n_features,
            seed=seed, ensemble=ensemble, sigma=sigma, rf_kernel=rf_kernel,
        )
        acc_mc[...] += dmc
        acc_ms[...] += dms

    @pl.when(k == k_steps - 1)
    def _write():
        gcc_ref[...] = acc_cc[...]
        gcs_ref[...] = acc_cs[...]
        gss_ref[...] = acc_ss[...]

    @pl.when((k == k_steps - 1) & (j == 0))
    def _write_moments():
        mc_ref[...] = acc_mc[...]
        ms_ref[...] = acc_ms[...]


def rff_gram_stream_fused_pallas(
    x: jax.Array,  # (p_pad, n), zero-padded feature rows
    lm: jax.Array,  # (2, n): stacked [ell; column-mask]
    *,
    nf_pad: int,  # padded feature-row count (the kernel's draw height)
    scale_n: int,  # true N for the 1/sqrt(N) feature normalization
    seed: int,
    ensemble: int = 1,
    sigma: float = 1.0,
    rf_kernel: str = "gauss",
    block_k: int = 128,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Seed-fused untiled layout: same five outputs, no omega operand.

    Rows ``[scale_n, nf_pad)`` of the outputs are padding garbage (drawn but
    meaningless) — the wrapper slices them off, exactly as the materialized
    kernel's zero-padded omega rows are sliced.
    """
    p, n = x.shape
    bk = min(block_k, n)
    if n % bk or lm.shape[1] != n:
        raise ValueError(f"n={n} must tile by {bk} and match lm {lm.shape}")
    k_steps = n // bk

    kernel = functools.partial(
        _rff_gram_fused_kernel, n_features=scale_n, k_steps=k_steps,
        seed=seed, ensemble=ensemble, sigma=sigma, rf_kernel=rf_kernel,
    )
    nf = nf_pad
    mw = 2 * ensemble  # per-draw moment columns: (2e, 2e+1) for draw e
    return pl.pallas_call(
        kernel,
        grid=(k_steps,),
        in_specs=[
            pl.BlockSpec((p, bk), lambda k: (0, k)),
            pl.BlockSpec((2, bk), lambda k: (0, k)),
        ],
        out_specs=[
            pl.BlockSpec((nf, nf), lambda k: (0, 0)),
            pl.BlockSpec((nf, nf), lambda k: (0, 0)),
            pl.BlockSpec((nf, nf), lambda k: (0, 0)),
            pl.BlockSpec((nf, mw), lambda k: (0, 0)),
            pl.BlockSpec((nf, mw), lambda k: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nf, nf), jnp.float32),
            jax.ShapeDtypeStruct((nf, nf), jnp.float32),
            jax.ShapeDtypeStruct((nf, nf), jnp.float32),
            jax.ShapeDtypeStruct((nf, mw), jnp.float32),
            jax.ShapeDtypeStruct((nf, mw), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((nf, nf), jnp.float32),
            pltpu.VMEM((nf, nf), jnp.float32),
            pltpu.VMEM((nf, nf), jnp.float32),
            pltpu.VMEM((nf, mw), jnp.float32),
            pltpu.VMEM((nf, mw), jnp.float32),
        ],
        interpret=interpret,
    )(x, lm)


def rff_gram_stream_fused_tiled_pallas(
    x: jax.Array,  # (p_pad, n)
    lm: jax.Array,  # (2, n)
    *,
    nf_pad: int,
    scale_n: int,
    tile: int = 512,
    seed: int,
    ensemble: int = 1,
    sigma: float = 1.0,
    rf_kernel: str = "gauss",
    block_k: int = 128,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Seed-fused tiled layout: grid (N/t, N/t, n/bk), W_RF rows drawn per
    tile from ``threefry(seed, tile_row_offset + r, col)`` — VMEM per
    instance is the usual 3 t^2 fp32 accumulators plus the two (t, p) draw
    slabs; nothing N-sized exists anywhere."""
    p, n = x.shape
    bk = min(block_k, n)
    if n % bk or lm.shape[1] != n:
        raise ValueError(f"n={n} must tile by {bk} and match lm {lm.shape}")
    if nf_pad % tile:
        raise ValueError(f"nf_pad={nf_pad} must tile by {tile}")
    n_tiles = nf_pad // tile
    k_steps = n // bk

    kernel = functools.partial(
        _rff_gram_fused_tiled_kernel, n_features=scale_n, k_steps=k_steps,
        tile=tile, seed=seed, ensemble=ensemble, sigma=sigma, rf_kernel=rf_kernel,
    )
    mw = 2 * ensemble  # per-draw moment columns: (2e, 2e+1) for draw e
    return pl.pallas_call(
        kernel,
        grid=(n_tiles, n_tiles, k_steps),
        in_specs=[
            pl.BlockSpec((p, bk), lambda i, j, k: (0, k)),
            pl.BlockSpec((2, bk), lambda i, j, k: (0, k)),
        ],
        out_specs=[
            pl.BlockSpec((tile, tile), lambda i, j, k: (i, j)),
            pl.BlockSpec((tile, tile), lambda i, j, k: (i, j)),
            pl.BlockSpec((tile, tile), lambda i, j, k: (i, j)),
            pl.BlockSpec((tile, mw), lambda i, j, k: (i, 0)),
            pl.BlockSpec((tile, mw), lambda i, j, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nf_pad, nf_pad), jnp.float32),
            jax.ShapeDtypeStruct((nf_pad, nf_pad), jnp.float32),
            jax.ShapeDtypeStruct((nf_pad, nf_pad), jnp.float32),
            jax.ShapeDtypeStruct((nf_pad, mw), jnp.float32),
            jax.ShapeDtypeStruct((nf_pad, mw), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile, tile), jnp.float32),
            pltpu.VMEM((tile, tile), jnp.float32),
            pltpu.VMEM((tile, tile), jnp.float32),
            pltpu.VMEM((tile, mw), jnp.float32),
            pltpu.VMEM((tile, mw), jnp.float32),
        ],
        interpret=interpret,
    )(x, lm)
