"""Pallas TPU kernel: streamed RFF Gram accumulation (RF-TCA Alg. 1 hot path).

Fuses the three stages of the RF-TCA statistics pass — RFF featurization
(paper Def. 2), sample masking, and Gram/moment accumulation — into one
kernel that consumes X (p, n) in sample blocks and emits only O(N^2)-sized
statistics:

    G_cc = C C^T,  G_cs = C S^T,  G_ss = S S^T      (N, N) each
    M_c  = C [ell; mask]^T,  M_s = S [ell; mask]^T  (N, 2) each

with C = cos(Omega X)/sqrt(N), S = sin(Omega X)/sqrt(N) masked to the true
sample columns.  The caller assembles Sigma H Sigma^T and u = Sigma ell from
these; the (2N, n) matrix Sigma itself NEVER exists in HBM, so peak memory is
O(N^2 + N b) for sample-block size b, independent of n — exactly the scaling
the paper claims for RF-TCA.

Two layouts share the kernel math:

- **untiled** (`rff_gram_stream_pallas`): grid (n / bk,) — one axis over
  sample blocks, (N_pad, N_pad) fp32 VMEM accumulators held across the whole
  pass.  3 N^2 fp32 buffers must fit VMEM, so this is the fast path up to
  N_pad ~ 1024 per core.
- **tiled** (`rff_gram_stream_tiled_pallas`): grid (N/t, N/t, n/bk) — a 2-D
  output tiling over (i, j) feature-tile pairs with the sample-block loop
  innermost, so each program instance only holds a (t, t) block of each Gram
  accumulator in VMEM (3 t^2 fp32, independent of N).  Row tile i recomputes
  its cos/sin slab once per (j, k) step — the usual flop-for-memory trade of
  output tiling — which removes the N ceiling entirely.

``kernels.ops.rff_gram_stream`` auto-selects between them from N.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rff_gram_kernel(
    omega_ref,
    x_ref,
    lm_ref,
    gcc_ref,
    gcs_ref,
    gss_ref,
    mc_ref,
    ms_ref,
    acc_cc,
    acc_cs,
    acc_ss,
    acc_mc,
    acc_ms,
    *,
    n_features: int,
    k_steps: int,
):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc_cc[...] = jnp.zeros_like(acc_cc)
        acc_cs[...] = jnp.zeros_like(acc_cs)
        acc_ss[...] = jnp.zeros_like(acc_ss)
        acc_mc[...] = jnp.zeros_like(acc_mc)
        acc_ms[...] = jnp.zeros_like(acc_ms)

    z = jnp.dot(omega_ref[...], x_ref[...], preferred_element_type=jnp.float32)
    inv = 1.0 / jnp.sqrt(jnp.float32(n_features))
    lm = lm_ref[...].astype(jnp.float32)  # (2, bk): row 0 = ell, row 1 = mask
    mask = lm[1:2, :]  # (1, bk); zero on padded sample columns
    c = jnp.cos(z) * inv * mask
    s = jnp.sin(z) * inv * mask

    contract = (((1,), (1,)), ((), ()))
    acc_cc[...] += jax.lax.dot_general(c, c, contract, preferred_element_type=jnp.float32)
    acc_cs[...] += jax.lax.dot_general(c, s, contract, preferred_element_type=jnp.float32)
    acc_ss[...] += jax.lax.dot_general(s, s, contract, preferred_element_type=jnp.float32)
    acc_mc[...] += jax.lax.dot_general(c, lm, contract, preferred_element_type=jnp.float32)
    acc_ms[...] += jax.lax.dot_general(s, lm, contract, preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _write():
        gcc_ref[...] = acc_cc[...]
        gcs_ref[...] = acc_cs[...]
        gss_ref[...] = acc_ss[...]
        mc_ref[...] = acc_mc[...]
        ms_ref[...] = acc_ms[...]


def _rff_gram_tiled_kernel(
    omega_i_ref,
    omega_j_ref,
    x_ref,
    lm_ref,
    gcc_ref,
    gcs_ref,
    gss_ref,
    mc_ref,
    ms_ref,
    acc_cc,
    acc_cs,
    acc_ss,
    acc_mc,
    acc_ms,
    *,
    n_features: int,
    k_steps: int,
):
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_cc[...] = jnp.zeros_like(acc_cc)
        acc_cs[...] = jnp.zeros_like(acc_cs)
        acc_ss[...] = jnp.zeros_like(acc_ss)

    @pl.when((k == 0) & (j == 0))
    def _init_moments():
        acc_mc[...] = jnp.zeros_like(acc_mc)
        acc_ms[...] = jnp.zeros_like(acc_ms)

    inv = 1.0 / jnp.sqrt(jnp.float32(n_features))
    lm = lm_ref[...].astype(jnp.float32)  # (2, bk): row 0 = ell, row 1 = mask
    mask = lm[1:2, :]  # (1, bk); zero on padded sample columns
    z_i = jnp.dot(omega_i_ref[...], x_ref[...], preferred_element_type=jnp.float32)
    z_j = jnp.dot(omega_j_ref[...], x_ref[...], preferred_element_type=jnp.float32)
    c_i = jnp.cos(z_i) * inv * mask
    s_i = jnp.sin(z_i) * inv * mask
    c_j = jnp.cos(z_j) * inv * mask
    s_j = jnp.sin(z_j) * inv * mask

    contract = (((1,), (1,)), ((), ()))
    acc_cc[...] += jax.lax.dot_general(c_i, c_j, contract, preferred_element_type=jnp.float32)
    acc_cs[...] += jax.lax.dot_general(c_i, s_j, contract, preferred_element_type=jnp.float32)
    acc_ss[...] += jax.lax.dot_general(s_i, s_j, contract, preferred_element_type=jnp.float32)

    # the (t, 2) moment blocks only depend on the row tile i: accumulate them
    # once per i, on the j == 0 sweep
    @pl.when(j == 0)
    def _moments():
        acc_mc[...] += jax.lax.dot_general(
            c_i, lm, contract, preferred_element_type=jnp.float32
        )
        acc_ms[...] += jax.lax.dot_general(
            s_i, lm, contract, preferred_element_type=jnp.float32
        )

    @pl.when(k == k_steps - 1)
    def _write():
        gcc_ref[...] = acc_cc[...]
        gcs_ref[...] = acc_cs[...]
        gss_ref[...] = acc_ss[...]

    @pl.when((k == k_steps - 1) & (j == 0))
    def _write_moments():
        mc_ref[...] = acc_mc[...]
        ms_ref[...] = acc_ms[...]


def rff_gram_stream_tiled_pallas(
    x: jax.Array,  # (p, n)
    omega: jax.Array,  # (N, p), N a multiple of ``tile``
    lm: jax.Array,  # (2, n): stacked [ell; column-mask]
    *,
    tile: int = 512,
    block_k: int = 128,
    scale_n: int | None = None,  # true N when omega rows are padded
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Tiled layout of :func:`rff_gram_stream_pallas` (same five outputs).

    Grid (N/t, N/t, n/bk): each (i, j) program instance owns the (t, t)
    output blocks G_cc[i, j], G_cs[i, j], G_ss[i, j] and streams all sample
    blocks through them before moving on — VMEM per instance is 3 t^2 fp32
    accumulators plus two (t, bk) slabs, *independent of N*.
    """
    n_features, p = omega.shape
    _, n = x.shape
    bk = min(block_k, n)
    if n % bk or lm.shape[1] != n:
        raise ValueError(f"n={n} must tile by {bk} and match lm {lm.shape}")
    if n_features % tile:
        raise ValueError(f"N={n_features} must tile by {tile}")
    n_tiles = n_features // tile
    k_steps = n // bk

    kernel = functools.partial(
        _rff_gram_tiled_kernel, n_features=scale_n or n_features, k_steps=k_steps
    )
    return pl.pallas_call(
        kernel,
        grid=(n_tiles, n_tiles, k_steps),
        in_specs=[
            pl.BlockSpec((tile, p), lambda i, j, k: (i, 0)),
            pl.BlockSpec((tile, p), lambda i, j, k: (j, 0)),
            pl.BlockSpec((p, bk), lambda i, j, k: (0, k)),
            pl.BlockSpec((2, bk), lambda i, j, k: (0, k)),
        ],
        out_specs=[
            pl.BlockSpec((tile, tile), lambda i, j, k: (i, j)),
            pl.BlockSpec((tile, tile), lambda i, j, k: (i, j)),
            pl.BlockSpec((tile, tile), lambda i, j, k: (i, j)),
            pl.BlockSpec((tile, 2), lambda i, j, k: (i, 0)),
            pl.BlockSpec((tile, 2), lambda i, j, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_features, n_features), jnp.float32),
            jax.ShapeDtypeStruct((n_features, n_features), jnp.float32),
            jax.ShapeDtypeStruct((n_features, n_features), jnp.float32),
            jax.ShapeDtypeStruct((n_features, 2), jnp.float32),
            jax.ShapeDtypeStruct((n_features, 2), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile, tile), jnp.float32),
            pltpu.VMEM((tile, tile), jnp.float32),
            pltpu.VMEM((tile, tile), jnp.float32),
            pltpu.VMEM((tile, 2), jnp.float32),
            pltpu.VMEM((tile, 2), jnp.float32),
        ],
        interpret=interpret,
    )(omega, omega, x, lm)


def rff_gram_stream_pallas(
    x: jax.Array,  # (p, n)
    omega: jax.Array,  # (N, p)
    lm: jax.Array,  # (2, n): stacked [ell; column-mask]
    *,
    block_k: int = 128,
    scale_n: int | None = None,  # true N when omega rows are padded
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Returns (G_cc, G_cs, G_ss, M_c, M_s); see module docstring for shapes."""
    n_features, p = omega.shape
    _, n = x.shape
    bk = min(block_k, n)
    if n % bk or lm.shape[1] != n:
        raise ValueError(f"n={n} must tile by {bk} and match lm {lm.shape}")
    k_steps = n // bk

    kernel = functools.partial(
        _rff_gram_kernel, n_features=scale_n or n_features, k_steps=k_steps
    )
    nf = n_features
    return pl.pallas_call(
        kernel,
        grid=(k_steps,),
        in_specs=[
            pl.BlockSpec((nf, p), lambda k: (0, 0)),
            pl.BlockSpec((p, bk), lambda k: (0, k)),
            pl.BlockSpec((2, bk), lambda k: (0, k)),
        ],
        out_specs=[
            pl.BlockSpec((nf, nf), lambda k: (0, 0)),
            pl.BlockSpec((nf, nf), lambda k: (0, 0)),
            pl.BlockSpec((nf, nf), lambda k: (0, 0)),
            pl.BlockSpec((nf, 2), lambda k: (0, 0)),
            pl.BlockSpec((nf, 2), lambda k: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nf, nf), jnp.float32),
            jax.ShapeDtypeStruct((nf, nf), jnp.float32),
            jax.ShapeDtypeStruct((nf, nf), jnp.float32),
            jax.ShapeDtypeStruct((nf, 2), jnp.float32),
            jax.ShapeDtypeStruct((nf, 2), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((nf, nf), jnp.float32),
            pltpu.VMEM((nf, nf), jnp.float32),
            pltpu.VMEM((nf, nf), jnp.float32),
            pltpu.VMEM((nf, 2), jnp.float32),
            pltpu.VMEM((nf, 2), jnp.float32),
        ],
        interpret=interpret,
    )(omega, x, lm)
