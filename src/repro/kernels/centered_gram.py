"""Pallas TPU kernel: centered Gram matrix  G = (S - mu 1^T)(S - mu 1^T)^T.

This is the  Sigma H Sigma^T  operator at the heart of RF-TCA (Algorithm 1,
eq. 7): H = I - 11^T/n is idempotent so SH(SH)^T = S H S^T, and centering is
algebraically a rank-one correction we fuse into the block loads — the
centered (2N, n) matrix is never materialised in HBM.

Grid: (2N/bi, 2N/bj, n/bk), contraction over samples innermost, fp32 scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gram_kernel(a_ref, b_ref, mu_i_ref, mu_j_ref, out_ref, acc_ref, *, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ai = a_ref[...].astype(jnp.float32) - mu_i_ref[...].astype(jnp.float32)
    bj = b_ref[...].astype(jnp.float32) - mu_j_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        ai, bj, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _write():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def centered_gram_pallas(
    sigma: jax.Array,  # (2N, n)
    *,
    block: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Returns (2N, 2N) centered Gram of the RFF matrix."""
    two_n, n = sigma.shape
    bi = min(block, two_n)
    bk = min(block_k, n)
    if two_n % bi or n % bk:
        raise ValueError(f"({two_n},{n}) must tile by ({bi},{bk})")
    k_steps = n // bk
    grid = (two_n // bi, two_n // bi, k_steps)
    mu = jnp.mean(sigma, axis=1, keepdims=True).astype(sigma.dtype)  # (2N, 1)

    kernel = functools.partial(_gram_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bi, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bi, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bi, 1), lambda i, j, k: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bi, bi), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((two_n, two_n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bi, bi), jnp.float32)],
        interpret=interpret,
    )(sigma, sigma, mu, mu)
