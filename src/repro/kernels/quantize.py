"""Pallas TPU kernel: fused stochastic-rounding quantize -> dequantize.

The wire codecs (``comm.codecs.QuantCodec``) simulate int8/int4 transmission
of federated payloads.  Inside the batched round engine that round trip is a
*fake-quant* op: ``x -> clip(floor(x/scale + u), -qmax, qmax) * scale`` with
``u ~ U[0,1)`` (unbiased stochastic rounding) and a per-tensor absmax scale.

This kernel fuses the divide / stochastic floor / clip / rescale into one
VMEM pass — the integer code tensor never exists in HBM (an eager
implementation materializes it plus the uniforms twice).  The uniforms are an
*input* so the kernel is bit-identical to its XLA twin
(``kernels.ref.fake_quant_ref``) and to the host codec given the same draws;
on a real TPU the in-kernel ``pltpu.prng_random_bits`` could generate them,
but the interpret-mode CPU lowering of the TPU PRNG primitives does not
exist, and a shared input keeps the twins exactly comparable.

Grid: (rows/block_r,) over a (rows, 128) layout; scale is a (1, 1) block
broadcast to every program instance.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fake_quant_kernel(x_ref, u_ref, scale_ref, out_ref, *, qmax: int):
    scale = scale_ref[0, 0]
    # true divide, not multiply-by-reciprocal: the XLA twin and host codec
    # divide, and a reciprocal flips floor() at quantization-bin boundaries
    q = jnp.clip(jnp.floor(x_ref[...] / scale + u_ref[...]), -qmax, qmax)
    out_ref[...] = (q * scale).astype(out_ref.dtype)


def fake_quant_pallas(
    x: jax.Array,  # (rows, 128) fp32
    u: jax.Array,  # (rows, 128) fp32 uniforms in [0, 1)
    scale: jax.Array,  # (1, 1) fp32 per-tensor scale
    *,
    qmax: int,
    block_r: int = 8,
    interpret: bool = True,
) -> jax.Array:
    rows, cols = x.shape
    if cols != 128 or rows % block_r:
        raise ValueError(f"({rows}, {cols}) must be (k*{block_r}, 128)")
    return pl.pallas_call(
        functools.partial(_fake_quant_kernel, qmax=qmax),
        grid=(rows // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, 128), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 128), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        interpret=interpret,
    )(x, u, scale)
