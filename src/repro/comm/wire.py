"""Wire format for FedRF-TCA federated messages (Table I/II made literal).

The protocol exchanges exactly three payload kinds (paper Alg. 5):

- ``moments``     — the Sigma ell moment vector, 2N floats (eq. 2);
- ``w_rf``        — the (2N, m) aligner W_RF (Alg. 4 FedAvg);
- ``classifier``  — classifier params, (m, C) weight + (C,) bias (every T_C).

A :class:`Message` is a typed envelope around one payload (possibly several
named arrays, e.g. the classifier's w and b); :func:`serialize` produces the
exact on-wire bytes and :func:`deserialize` recovers the arrays through the
payload codec.  :func:`serialized_size` computes the same byte count
analytically — ``len(serialize(msg, codec)) == serialized_size(...)`` is a
tested invariant, which lets the identity transport and the batched engine
do *exact* byte accounting without ever serializing.

Layout (little-endian)::

    magic   4s   b"RFTC"
    version u8
    kind    u8       moments=0 | w_rf=1 | classifier=2
    codec   u8       codecs.Codec.wire_id
    flags   u8       bit0 = downlink
    sender  i16      client id, -1 = server/target
    round   u32
    n_arr   u8
    per array:
      name_len u8, name ascii
      ndim     u8, dims u32 * ndim
      dtype    u8   (logical/decoded dtype)
      plen     u32, payload bytes (codec-specific)
    crc     u32  CRC32 of everything above (integrity trailer, version 2)

Integrity: every frame ends in a CRC32 of the preceding bytes.  A frame that
was bit-flipped, truncated, or replaced in flight fails the check and
:func:`deserialize` raises the typed :class:`WireDecodeError` — transports
reject-and-account (then retransmit) instead of crashing on a raw
``struct.error`` deep inside the parser.
"""
from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass, field

import numpy as np

from repro.comm import codecs as codecs_mod
from repro.comm.codecs import Codec, codec_from_wire_id, dtype_id

MAGIC = b"RFTC"
VERSION = 2  # version 1 + CRC32 integrity trailer

KINDS = ("moments", "w_rf", "classifier")
_KIND_IDS = {k: i for i, k in enumerate(KINDS)}

_HEADER = struct.Struct("<4sBBBBhIB")
_CRC = struct.Struct("<I")


class WireDecodeError(ValueError):
    """A frame that cannot be decoded: bad checksum, truncated or garbage
    bytes, unknown magic/version/codec.  Subclasses ValueError so legacy
    ``except ValueError`` call sites keep working."""


@dataclass
class Message:
    """One federated message: a typed payload envelope.

    ``arrays`` maps payload part names to arrays (moments: {"msg"}, w_rf:
    {"w_rf"}, classifier: {"w", "b"}).  ``replay`` carries the (generator,
    key_data) pair for seed-derived payloads (see codecs.SeedReplayCodec).
    """

    kind: str
    sender: int
    round: int
    arrays: dict[str, np.ndarray]
    downlink: bool = False
    replay: tuple[str, np.ndarray] | None = field(default=None, repr=False)

    def __post_init__(self):
        if self.kind not in _KIND_IDS:
            raise ValueError(f"unknown payload kind {self.kind!r}; have {KINDS}")

    def nbytes(self, codec: Codec) -> int:
        return serialized_size(
            self.kind, {k: (v.shape, v.dtype) for k, v in self.arrays.items()}, codec
        )


def moments_message(msg_vec, *, sender: int, round: int, downlink: bool = False) -> Message:
    return Message("moments", sender, round, {"msg": np.asarray(msg_vec)}, downlink)


def w_rf_message(w, *, sender: int, round: int, downlink: bool = False, replay=None) -> Message:
    return Message("w_rf", sender, round, {"w_rf": np.asarray(w)}, downlink, replay)


def classifier_message(clf, *, sender: int, round: int, downlink: bool = False) -> Message:
    return Message(
        "classifier", sender, round,
        {"w": np.asarray(clf["w"]), "b": np.asarray(clf["b"])}, downlink,
    )


def _array_header(name: str, shape: tuple[int, ...], dtype, plen: int) -> bytes:
    nm = name.encode("ascii")
    return (
        struct.pack("<B", len(nm))
        + nm
        + struct.pack("<B", len(shape))
        + struct.pack(f"<{len(shape)}I", *shape)
        + struct.pack("<BI", dtype_id(dtype), plen)
    )


def serialize(msg: Message, codec: Codec, *, rng=None) -> bytes:
    """Exact on-wire bytes of ``msg`` under ``codec``.

    ``rng`` (np.random.Generator) drives stochastic-rounding codecs; pass a
    generator seeded from (seed, round, sender) for deterministic replay.
    """
    out = [
        _HEADER.pack(
            MAGIC, VERSION, _KIND_IDS[msg.kind], codec.wire_id,
            1 if msg.downlink else 0, msg.sender, msg.round, len(msg.arrays),
        )
    ]
    for name, arr in msg.arrays.items():
        arr = np.asarray(arr)
        payload = codec.encode(arr, rng=rng, replay=msg.replay)
        out.append(_array_header(name, arr.shape, arr.dtype, len(payload)))
        out.append(payload)
    body = b"".join(out)
    return body + _CRC.pack(zlib.crc32(body) & 0xFFFFFFFF)


def deserialize(data: bytes) -> tuple[Message, Codec]:
    """Parse wire bytes -> (Message with decoded arrays, codec used).

    Raises :class:`WireDecodeError` on any malformed frame — checksum
    mismatch, truncation, unknown magic/version/codec, trailing garbage.
    """
    try:
        return _parse(data)
    except WireDecodeError:
        raise
    except (struct.error, ValueError, KeyError, IndexError, UnicodeDecodeError) as e:
        raise WireDecodeError(f"malformed frame ({len(data)} bytes): {e}") from e


def _parse(data: bytes) -> tuple[Message, Codec]:
    if len(data) < _HEADER.size + _CRC.size:
        raise WireDecodeError(f"frame too short: {len(data)} bytes")
    body, (crc,) = data[: -_CRC.size], _CRC.unpack_from(data, len(data) - _CRC.size)
    if zlib.crc32(body) & 0xFFFFFFFF != crc:
        raise WireDecodeError("checksum mismatch")
    magic, version, kind_id, codec_id, flags, sender, rnd, n_arr = _HEADER.unpack_from(
        body, 0
    )
    if magic != MAGIC:
        raise WireDecodeError(f"bad magic {magic!r}")
    if version != VERSION:
        raise WireDecodeError(f"wire version {version} != {VERSION}")
    codec = codec_from_wire_id(codec_id)
    off = _HEADER.size
    arrays: dict[str, np.ndarray] = {}
    for _ in range(n_arr):
        (name_len,) = struct.unpack_from("<B", body, off)
        off += 1
        name = body[off : off + name_len].decode("ascii")
        off += name_len
        (ndim,) = struct.unpack_from("<B", body, off)
        off += 1
        shape = struct.unpack_from(f"<{ndim}I", body, off)
        off += 4 * ndim
        dt_id, plen = struct.unpack_from("<BI", body, off)
        off += 5
        arrays[name] = codec.decode(
            body[off : off + plen], tuple(shape), codecs_mod.DTYPE_CODES[dt_id]
        )
        off += plen
    if off != len(body):
        raise WireDecodeError(f"trailing bytes: parsed {off} of {len(body)}")
    if kind_id >= len(KINDS):
        raise WireDecodeError(f"unknown kind id {kind_id}")
    msg = Message(KINDS[kind_id], sender, rnd, arrays, bool(flags & 1))
    return msg, codec


def serialized_size(
    kind: str, specs: dict[str, tuple[tuple[int, ...], np.dtype]], codec: Codec
) -> int:
    """Analytic ``len(serialize(...))`` from shapes alone (no data needed)."""
    total = _HEADER.size + _CRC.size
    for name, (shape, dtype) in specs.items():
        total += 1 + len(name) + 1 + 4 * len(shape) + 5 + codec.nbytes(shape, dtype)
    return total
