"""Transports: how federated payloads cross the (simulated) wire.

Two implementations behind one interface:

- :class:`IdentityTransport` — arrays pass through untouched (the original
  in-process simulator), but every transfer is *byte-accounted exactly* via
  ``wire.serialized_size`` — the analytic twin of ``len(serialize(...))``.
- :class:`WireTransport` — every transfer is really serialized to bytes under
  the payload's codec and parsed back; the protocol consumes the decoded
  arrays, so lossy codecs (bf16/qint8/qint4/topk) genuinely distort training
  and accuracy-vs-codec curves are measurable (bench_comm_wire).

Both replace the seed's float-counter with :class:`CommLog`, which keeps the
legacy float fields (Table I/II accounting) *and* exact per-payload bytes.

Codec resolution: ``ProtocolConfig(codec=...)`` sets the default for all
three payload kinds; ``codec_moments``/``codec_w_rf``/``codec_classifier``
override per kind.  ``codec="seed_replay"`` means *W_RF by seed replay* —
moments and classifier payloads are data-dependent and cannot be replayed
from a key, so they fall back to float32 — and flips the protocol into
frozen-W mode: W_RF stays pinned at the shared seed-derived init (all clients
bit-identical, gradients stopped), W-aggregation becomes the O(1)-byte key
exchange, and the decoded matrix is bit-exact by construction.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.comm import wire
from repro.comm.codecs import Codec, get_codec
from repro.obs.records import CommRecord
from repro.obs.registry import get_registry

KIND_FIELD = {"moments": "data_messages", "w_rf": "w_rf", "classifier": "classifier"}


@dataclass
class CommLog:
    """Communication record: legacy float counts + exact on-wire bytes.

    ``data_messages``/``w_rf``/``classifier`` count *uploaded floats* exactly
    as the seed's counter did (Table I/II units); ``bytes_by_kind`` counts the
    exact serialized bytes of every message under the active codec, and
    ``messages_by_kind`` the message count.  Seed-replay transfers upload no
    floats (the key is not a float payload) but do cost their O(1) bytes.
    """

    data_messages: int = 0  # Sigma ell floats
    w_rf: int = 0
    classifier: int = 0
    rounds: int = 0
    history: list = field(default_factory=list)
    bytes_by_kind: dict = field(
        default_factory=lambda: {"moments": 0, "w_rf": 0, "classifier": 0}
    )
    messages_by_kind: dict = field(
        default_factory=lambda: {"moments": 0, "w_rf": 0, "classifier": 0}
    )
    rejects_by_kind: dict = field(
        default_factory=lambda: {"moments": 0, "w_rf": 0, "classifier": 0}
    )
    drops_by_kind: dict = field(
        default_factory=lambda: {"moments": 0, "w_rf": 0, "classifier": 0}
    )

    @property
    def total(self) -> int:
        return self.data_messages + self.w_rf + self.classifier

    @property
    def bytes_total(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def rejects_total(self) -> int:
        return sum(self.rejects_by_kind.values())

    @property
    def drops_total(self) -> int:
        return sum(self.drops_by_kind.values())

    def record(self, kind: str, n_floats: int, nbytes: int) -> None:
        setattr(self, KIND_FIELD[kind], getattr(self, KIND_FIELD[kind]) + n_floats)
        self.bytes_by_kind[kind] += nbytes
        self.messages_by_kind[kind] += 1
        reg = get_registry()
        reg.counter("comm.bytes").inc(nbytes, kind=kind)
        reg.counter("comm.messages").inc(kind=kind)
        reg.counter("comm.floats").inc(n_floats, kind=kind)

    def reject(self, kind: str) -> None:
        """One frame failed integrity and was discarded (will retransmit)."""
        self.rejects_by_kind[kind] += 1
        get_registry().counter("comm.rejects").inc(kind=kind)

    def drop(self, kind: str) -> None:
        """One payload was given up on after exhausting its retry budget."""
        self.drops_by_kind[kind] += 1
        get_registry().counter("comm.drops").inc(kind=kind)

    def snapshot(self) -> CommRecord:
        """The ledger as one typed record (see ``repro.obs.records``)."""
        return CommRecord(
            rounds=self.rounds,
            data_messages=self.data_messages,
            w_rf=self.w_rf,
            classifier=self.classifier,
            bytes_by_kind=dict(self.bytes_by_kind),
            messages_by_kind=dict(self.messages_by_kind),
            rejects_by_kind=dict(self.rejects_by_kind),
            drops_by_kind=dict(self.drops_by_kind),
            bytes_total=self.bytes_total,
            floats_total=self.total,
        )


def resolve_codecs(
    default: str = "float32",
    *,
    moments: str | None = None,
    w_rf: str | None = None,
    classifier: str | None = None,
) -> dict[str, Codec]:
    """Per-kind codecs from a default + overrides (see module docstring)."""
    fallback = "float32" if default == "seed_replay" else default
    names = {
        "moments": moments or fallback,
        "w_rf": w_rf or default,
        "classifier": classifier or fallback,
    }
    if names["moments"] == "seed_replay" or names["classifier"] == "seed_replay":
        raise ValueError(
            "seed_replay only applies to seed-derived payloads (w_rf); moments "
            "and classifier contents depend on private data"
        )
    return {k: get_codec(v) for k, v in names.items()}


class Transport:
    """Base: per-kind codecs, a CommLog, deterministic per-message RNG."""

    name = "base"

    def __init__(self, codecs: dict[str, Codec], *, seed: int = 0):
        self.codecs = codecs
        self.log = CommLog()
        self.seed = seed

    @property
    def frozen_w(self) -> bool:
        return self.codecs["w_rf"].name == "seed_replay"

    def _rng(self, msg: wire.Message) -> np.random.Generator:
        """Deterministic stochastic-rounding stream per (seed, round, sender,
        kind, direction) — every payload of a round draws independent bits."""
        return np.random.default_rng(
            (
                self.seed,
                msg.round,
                msg.sender & 0xFFFF,
                wire.KINDS.index(msg.kind),
                1 if msg.downlink else 0,
            )
        )

    def payload_sizes(self, specs: dict[str, dict]) -> dict[str, int]:
        """Exact wire bytes per kind from array specs (for LinkScenario)."""
        return {
            kind: wire.serialized_size(kind, spec, self.codecs[kind])
            for kind, spec in specs.items()
        }

    def _floats_of(self, msg: wire.Message) -> int:
        if self.codecs[msg.kind].name == "seed_replay":
            return 0  # a key is not a float payload
        return int(sum(np.prod(a.shape, dtype=np.int64) for a in msg.arrays.values()))

    def transfer(self, msg: wire.Message) -> dict[str, np.ndarray]:
        raise NotImplementedError

    def account(self, msg: wire.Message) -> None:
        """Record a transfer that happened elsewhere (batched engine's
        in-graph exchange): identical bytes via the analytic size."""
        self.log.record(msg.kind, self._floats_of(msg), msg.nbytes(self.codecs[msg.kind]))

    def account_spec(self, kind: str, specs: dict, *, count: int = 1) -> None:
        """Record ``count`` transfers of a payload known only by shape — the
        batched engine's (and identity transport's) accounting path.  Exact:
        ``wire.serialized_size`` equals ``len(wire.serialize(...))``."""
        codec = self.codecs[kind]
        nbytes = wire.serialized_size(kind, specs, codec)
        floats = (
            0
            if codec.name == "seed_replay"
            else int(sum(np.prod(s, dtype=np.int64) for s, _ in specs.values()))
        )
        for _ in range(count):
            self.log.record(kind, floats, nbytes)

    def channel_fns(self):
        """Jittable per-kind distortion twins for the batched engine, or None
        when every codec is the identity on values (nothing to compile in)."""
        fns = {}
        for kind, codec in self.codecs.items():
            if self.applies_values and codec.lossy:
                fns[kind] = codec.roundtrip
        return fns or None

    applies_values = False  # does transfer() distort the array values?


class IdentityTransport(Transport):
    """Pass-through values + exact analytic byte accounting (the default)."""

    name = "identity"
    applies_values = False

    def transfer(self, msg: wire.Message) -> dict[str, np.ndarray]:
        self.account(msg)
        return msg.arrays

    def transfer_delta(self, msg: wire.Message, *, link: str) -> dict[str, np.ndarray]:
        return self.transfer(msg)


class WireTransport(Transport):
    """Serialize -> bytes -> deserialize on every transfer; counts len(bytes).

    With a ``fault_injector`` (:class:`repro.robust.ByteFaultInjector`)
    installed, each frame may be corrupted in flight: the CRC32 envelope
    check rejects it (typed :class:`~repro.comm.wire.WireDecodeError` —
    never a crash), the reject is accounted, and the frame is retransmitted
    up to the injector's ``max_retries``; on give-up :meth:`transfer`
    returns ``None`` and the payload is accounted as a drop, which the
    serial round treats exactly like a lost message.
    """

    name = "wire"
    applies_values = True

    def __init__(self, codecs: dict[str, Codec], *, seed: int = 0, fault_injector=None):
        super().__init__(codecs, seed=seed)
        self._delta_refs: dict[tuple[str, str], dict[str, np.ndarray]] = {}
        self.fault_injector = fault_injector

    def transfer(self, msg: wire.Message) -> dict[str, np.ndarray] | None:
        codec = self.codecs[msg.kind]
        rng = self._rng(msg)
        attempts = 1 + (self.fault_injector.max_retries if self.fault_injector else 0)
        for _ in range(attempts):
            data = wire.serialize(msg, codec, rng=rng)
            if self.fault_injector is not None:
                data = self.fault_injector.corrupt(msg.kind, data)
            # every attempt crosses the wire: retransmits cost real bytes
            self.log.record(msg.kind, self._floats_of(msg), len(data))
            try:
                decoded, _ = wire.deserialize(data)
            except wire.WireDecodeError:
                self.log.reject(msg.kind)
                continue
            return decoded.arrays
        self.log.drop(msg.kind)
        return None

    def transfer_delta(self, msg: wire.Message, *, link: str) -> dict[str, np.ndarray]:
        """Delta-coded transfer for sparsifying codecs (top-k classifier sync).

        Both endpoints of ``link`` hold the reconstruction of the previous
        transfer as the shared reference (zeros initially — the first
        transfer ships the full value as its own delta).  The payload on the
        wire is ``codec(value - ref)``; the receiver reconstructs
        ``ref + decoded`` and both sides roll the reference forward, so
        sparsification error does not accumulate across syncs.  Codecs that
        are exact on the wire (float32) skip the delta detour — ``ref +
        (value - ref)`` would itself cost an ulp.
        """
        from repro.comm.codecs import TopKCodec

        if not isinstance(self.codecs[msg.kind], TopKCodec):
            return self.transfer(msg)
        ref = self._delta_refs.get((msg.kind, link))
        if ref is None:
            ref = {k: np.zeros_like(np.asarray(v)) for k, v in msg.arrays.items()}
        delta = wire.Message(
            msg.kind, msg.sender, msg.round,
            {k: np.asarray(v) - ref[k] for k, v in msg.arrays.items()},
            msg.downlink, msg.replay,
        )
        decoded = self.transfer(delta)
        if decoded is None:  # gave up under fault injection: reference unrolled
            return None
        recon = {k: ref[k] + decoded[k] for k in decoded}
        self._delta_refs[(msg.kind, link)] = recon
        return recon


def build_transport(
    name: str,
    codec: str = "float32",
    *,
    seed: int = 0,
    codec_moments: str | None = None,
    codec_w_rf: str | None = None,
    codec_classifier: str | None = None,
) -> Transport:
    codecs = resolve_codecs(
        codec, moments=codec_moments, w_rf=codec_w_rf, classifier=codec_classifier
    )
    if name in ("identity", "none"):
        return IdentityTransport(codecs, seed=seed)
    if name == "wire":
        return WireTransport(codecs, seed=seed)
    raise ValueError(f"unknown transport {name!r} (want 'identity' or 'wire')")
