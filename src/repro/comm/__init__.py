"""repro.comm — wire format, codecs, transports, and network simulation.

The communication subsystem behind FedRF-TCA's headline claims:

- ``wire``      typed messages for the three payload kinds + exact byte layout
- ``codecs``    float casts, stochastic int8/int4 quantization, top-k
                sparsification, and the O(1) seed-replay codec for W_RF
- ``transport`` identity (analytic byte accounting) vs wire (real
                serialize/deserialize) transports + the CommLog record
- ``netsim``    Table-III-generalizing, trace-replayable network scenarios,
                shared-backhaul queueing, and the async runtime's per-client
                completion-time queries
- ``autocodec`` one-shot picker: cheapest codec meeting an accuracy budget,
                from the measured BENCH_comm.json curves
                (``ProtocolConfig(codec="auto:<budget>")``)
"""
from repro.comm.autocodec import codec_table, pick_codec, resolve as resolve_auto_codec
from repro.comm.codecs import (
    Codec,
    codec_names,
    get_codec,
    register_replay_generator,
)
from repro.comm.netsim import (
    BernoulliScenario,
    LinkModel,
    LinkScenario,
    Scenario,
    TableIIIScenario,
    TraceScenario,
    load_trace,
    record_trace,
    save_trace,
    table3_trace,
)
from repro.comm.transport import (
    CommLog,
    IdentityTransport,
    Transport,
    WireTransport,
    build_transport,
    resolve_codecs,
)
from repro.comm.wire import (
    Message,
    classifier_message,
    deserialize,
    moments_message,
    serialize,
    serialized_size,
    w_rf_message,
)
