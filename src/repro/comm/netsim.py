"""Trace-driven network simulation for the federated protocol.

Generalizes Table III's three drop settings into arbitrary, replayable
scenarios.  Every scenario emits the same :class:`federated.network.RoundPlan`
(nested participant sets A supseteq B supseteq C for moments / W_RF /
classifier) that both the serial and batched round engines already consume —
the engines never know which scenario produced the plan.

Scenarios:

- :class:`TableIIIScenario` — the paper's settings (I) A/A/A, (II) A/A/B,
  (III) A/B/C, bit-compatible with ``network.plan_round`` (the default).
- :class:`BernoulliScenario` — per-link i.i.d. Bernoulli loss with separate
  probabilities per payload kind; nesting enforced by intersection.
- :class:`LinkScenario` — per-client :class:`LinkModel` (latency, jitter,
  bandwidth, loss) against a round deadline: a client whose simulated
  delivery time exceeds the deadline is a straggler and counts as dropped.
  Uses the *exact* wire byte sizes, so heavier codecs genuinely straggle.
- :class:`TraceScenario` — an explicit list of round plans, replayed
  deterministically; any scenario can be recorded into one
  (:func:`record_trace`) and traces round-trip through JSON
  (:func:`save_trace` / :func:`load_trace`) for shareable experiments.
- :class:`CorruptionScenario` — payload-level corruption over any base
  scenario, in its *defended* (checksummed) form: a corrupted frame is
  rejected and retransmitted, so per-kind corruption rates compose into an
  extra erasure channel (give-up after ``max_retries``).  The undefended
  form — corrupted values reaching the aggregator — lives in
  ``repro.robust.faults`` and runs in-graph in the batched engine.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field

import numpy as np

from repro.federated import network
from repro.federated.network import RoundPlan, sample_participants
from repro.obs.registry import get_registry


class Scenario:
    """Emits one RoundPlan per round: ``plan(rng, n_clients, t)``."""

    def plan(self, rng: np.random.Generator, n_clients: int, t: int) -> RoundPlan:
        raise NotImplementedError


@dataclass
class TableIIIScenario(Scenario):
    """Paper Table III settings as a scenario (delegates to plan_round)."""

    setting: str = "I"

    def plan(self, rng, n_clients, t) -> RoundPlan:
        # resolved through the module so tests can monkeypatch network.plan_round
        return network.plan_round(rng, n_clients, self.setting)


def _nest(a: list[int], b: list[int], c: list[int]) -> RoundPlan:
    """Enforce the protocol invariant C ⊆ B ⊆ A by intersection."""
    b = sorted(set(b) & set(a))
    c = sorted(set(c) & set(b))
    return RoundPlan(sorted(a), b, c)


@dataclass
class BernoulliScenario(Scenario):
    """Independent per-client, per-payload Bernoulli delivery.

    ``p_msg``/``p_w``/``p_c`` are *loss* probabilities for the moments, W_RF
    and classifier payloads.  ``sample_s_t=True`` additionally draws the
    paper's participating set S_t first (Section IV-B) so loss composes with
    client sampling; False exposes the pure-channel ablation.
    """

    p_msg: float = 0.0
    p_w: float = 0.0
    p_c: float = 0.0
    sample_s_t: bool = True

    def plan(self, rng, n_clients, t) -> RoundPlan:
        base = (
            sample_participants(rng, n_clients) if self.sample_s_t else list(range(n_clients))
        )
        a = [i for i in base if rng.random() >= self.p_msg]
        b = [i for i in a if rng.random() >= self.p_w]
        c = [i for i in b if rng.random() >= self.p_c]
        return _nest(a, b, c)


@dataclass
class LinkModel:
    """One client's uplink: Bernoulli loss + latency/jitter/bandwidth."""

    drop: float = 0.0  # Bernoulli loss probability per payload
    latency_s: float = 0.0  # base one-way latency
    jitter_s: float = 0.0  # uniform [0, jitter_s) added per payload
    bandwidth_bps: float = math.inf  # bytes/second on the wire

    def delivery_time(
        self,
        rng,
        nbytes: int,
        *,
        contended_bytes: float | None = None,
        backhaul_bps: float = math.inf,
    ) -> float:
        """Simulated arrival time of an nbytes payload; inf if lost.

        With a finite shared ``backhaul_bps``, ``contended_bytes`` is the sum
        of ALL bytes concurrently on the backhaul (this payload included): the
        wire term becomes ``max(own/bandwidth, contended/backhaul)`` — the
        transfer is pinned by whichever is slower, its own last-mile link or
        its fair share of the serialized backhaul.  The defaults reproduce
        the uncontended per-payload time bit-for-bit.
        """
        if rng.random() < self.drop:
            return math.inf
        jitter = rng.random() * self.jitter_s if self.jitter_s else 0.0
        wire = nbytes / self.bandwidth_bps
        if contended_bytes is not None:
            wire = max(wire, contended_bytes / backhaul_bps)
        return self.latency_s + jitter + wire


@dataclass
class LinkScenario(Scenario):
    """Per-client links against a straggler deadline.

    ``payload_bytes`` maps kind -> exact wire bytes of that payload (from
    ``wire.serialized_size``); the transport wires this up so codec choice
    changes who straggles — e.g. dense float32 W_RF misses a tight deadline
    that the seed-replay key makes trivially.

    A finite ``backhaul_bps`` models a shared uplink (cell tower / institute
    egress): every payload of a round contends with all the others attempting
    the same kind concurrently, so each client's wire time is driven by the
    *sum* of in-flight bytes, not its own payload alone — K clients on a
    shared pipe straggle together even when each last-mile link is fast.
    ``backhaul_bps = inf`` (default) keeps the seed's per-payload behavior
    bit-for-bit, rng stream included.

    The fedsim async runtime does not use round plans; it queries
    :meth:`uplink_outcome` per dispatched client instead (lost payloads
    retransmitted under exponential backoff with jitter, contention from the
    bytes currently in flight), so a client's arrival time — and therefore
    its staleness at consumption — follows from the exact wire bytes of the
    configured codec.  After ``max_retries`` failed attempts the client gives
    up and the uplink is reported as a drop (``delivered=False`` /
    ``uplink_time() == inf``), never an exception and never an unbounded
    spin as ``drop → 1``.
    """

    links: list[LinkModel]
    deadline_s: float = math.inf
    payload_bytes: dict[str, int] = field(default_factory=dict)
    backhaul_bps: float = math.inf  # shared-uplink capacity (queueing)
    retry_s: float = 1.0  # initial retransmit backoff for lost async uplinks
    max_retries: int = 8  # give up (report drop) after this many retransmits
    backoff: float = 2.0  # exponential backoff factor per retransmit
    retry_jitter: float = 0.5  # +- fraction of uniform jitter on each wait

    def plan(self, rng, n_clients, t) -> RoundPlan:
        if len(self.links) < n_clients:
            raise ValueError(f"{len(self.links)} links for {n_clients} clients")
        contended = math.isfinite(self.backhaul_bps)
        sets: dict[str, list[int]] = {"moments": [], "w_rf": [], "classifier": []}
        for i in range(n_clients):
            for kind in sets:
                nbytes = self.payload_bytes.get(kind, 0)
                # all n_clients attempt this kind's payload concurrently; lost
                # ones still occupied airtime, so contention counts them all
                dt = self.links[i].delivery_time(
                    rng,
                    nbytes,
                    contended_bytes=(n_clients * nbytes) if contended else None,
                    backhaul_bps=self.backhaul_bps,
                )
                if dt <= self.deadline_s:
                    sets[kind].append(i)
        return _nest(sets["moments"], sets["w_rf"], sets["classifier"])

    def total_uplink_bytes(self, kinds: tuple[str, ...] = ("moments", "w_rf")) -> int:
        """Exact wire bytes of one client uplink carrying ``kinds``."""
        return sum(self.payload_bytes.get(kind, 0) for kind in kinds)

    def uplink_outcome(
        self,
        rng,
        client: int,
        nbytes: int,
        *,
        inflight_bytes: float = 0.0,
    ) -> tuple[bool, float]:
        """One client uplink attempt sequence -> ``(delivered, elapsed_s)``.

        Bernoulli losses are retransmitted under exponential backoff with
        jitter: attempt ``a`` waits ``retry_s * backoff**a`` (times a uniform
        ``1 ± retry_jitter`` factor) before trying again.  After
        ``max_retries`` retransmits the client gives up: ``(False, elapsed)``
        where ``elapsed`` is the virtual time burned backing off — the
        caller needs it to schedule what happens next (re-dispatch, drop
        accounting).  On success ``elapsed`` includes latency, jitter and the
        (possibly backhaul-contended) wire time.  ``drop=0`` draws no retry
        randomness, keeping fault-free rng streams bit-identical to the seed.
        """
        reg = get_registry()
        link = self.links[client]
        t = 0.0
        if link.drop:
            for attempt in range(self.max_retries + 1):
                if rng.random() >= link.drop:
                    break
                if attempt == self.max_retries:
                    reg.counter("net.giveups").inc(client=client)
                    return False, t  # budget exhausted: no wait after last try
                reg.counter("net.retries").inc(client=client)
                wait = self.retry_s * (self.backoff**attempt)
                if self.retry_jitter:
                    wait *= 1.0 + self.retry_jitter * (2.0 * rng.random() - 1.0)
                t += wait
        jitter = rng.random() * link.jitter_s if link.jitter_s else 0.0
        wire = nbytes / link.bandwidth_bps
        if math.isfinite(self.backhaul_bps):
            wire = max(wire, (nbytes + inflight_bytes) / self.backhaul_bps)
        elapsed = t + link.latency_s + jitter + wire
        reg.histogram("net.uplink_s").observe(elapsed, client=client)
        return True, elapsed

    def uplink_time(
        self,
        rng,
        client: int,
        nbytes: int,
        *,
        inflight_bytes: float = 0.0,
    ) -> float:
        """Virtual seconds until a client's nbytes uplink lands at the server;
        ``inf`` when the retry budget is exhausted (give-up == drop)."""
        delivered, t = self.uplink_outcome(
            rng, client, nbytes, inflight_bytes=inflight_bytes
        )
        return t if delivered else math.inf


@dataclass
class CorruptionScenario(Scenario):
    """Per-kind payload corruption as an erasure channel over ``base``.

    With CRC32 envelope checksums every corrupted frame is rejected and
    retransmitted; a payload only *disappears* when all ``1 + max_retries``
    attempts corrupt, i.e. with probability ``rate ** (1 + max_retries)``.
    This wrapper removes exactly those clients from the base plan's
    per-kind sets — corruption under a working defense degrades to (rare)
    loss, which the protocol already tolerates.  ``rates`` maps payload
    kind (``moments`` / ``w_rf`` / ``classifier``) to the per-frame
    corruption probability.  Zero rates replay the base scenario exactly,
    rng stream included.
    """

    base: Scenario
    rates: dict[str, float] = field(default_factory=dict)
    max_retries: int = 8

    def plan(self, rng, n_clients, t) -> RoundPlan:
        p = self.base.plan(rng, n_clients, t)

        def survive(ids: list[int], kind: str) -> list[int]:
            rate = self.rates.get(kind, 0.0)
            if rate <= 0.0:
                return list(ids)
            giveup = rate ** (1 + self.max_retries)
            return [i for i in ids if rng.random() >= giveup]

        return _nest(
            survive(p.msg_clients, "moments"),
            survive(p.w_clients, "w_rf"),
            survive(p.c_clients, "classifier"),
        )


def amortized_interval_bytes(nbytes: int, interval: int) -> float:
    """Expected per-uplink byte share of an interval payload.

    The classifier syncs every T_C-th aggregation (Table II), so a single
    uplink cannot know whether *its* consuming flush will carry the
    classifier payload.  In expectation each uplink pays ``nbytes / T_C`` of
    it, and that share belongs in :meth:`LinkScenario.uplink_time`'s byte
    argument — otherwise the T_C-interval payload crosses the wire for free
    and never contends for the shared backhaul.  The fedsim schedulers add
    this to every uplink's wire bytes (exact in expectation, smooth in time —
    the alternative, spiking every T_C-th uplink, would need the dispatch to
    predict flush parity, which the buffered server does not know)."""
    if interval <= 0:
        raise ValueError(f"interval must be >= 1, got {interval}")
    return nbytes / interval


@dataclass
class TraceScenario(Scenario):
    """Deterministic replay of an explicit plan list (cycled if ``cycle``)."""

    plans: list[RoundPlan]
    cycle: bool = False

    def plan(self, rng, n_clients, t) -> RoundPlan:
        # round() is called with t starting at 1 (protocol convention)
        idx = t - 1
        if self.cycle:
            idx %= len(self.plans)
        if not 0 <= idx < len(self.plans):
            raise IndexError(f"trace has {len(self.plans)} rounds, asked for t={t}")
        return self.plans[idx]


def record_trace(
    scenario: Scenario, rng: np.random.Generator, n_clients: int, rounds: int
) -> TraceScenario:
    """Materialize any scenario into a replayable trace."""
    return TraceScenario([scenario.plan(rng, n_clients, t) for t in range(1, rounds + 1)])


def save_trace(trace: TraceScenario, path) -> None:
    with open(path, "w") as f:
        json.dump(
            [
                {"msg": p.msg_clients, "w": p.w_clients, "c": p.c_clients}
                for p in trace.plans
            ],
            f,
        )


def load_trace(path, *, cycle: bool = False) -> TraceScenario:
    with open(path) as f:
        raw = json.load(f)
    return TraceScenario(
        [RoundPlan(list(p["msg"]), list(p["w"]), list(p["c"])) for p in raw], cycle
    )


def table3_trace(setting: str, n_clients: int, rounds: int, seed: int = 0) -> TraceScenario:
    """Table III settings (I)/(II)/(III) expressed as deterministic traces."""
    return record_trace(
        TableIIIScenario(setting), np.random.default_rng(seed), n_clients, rounds
    )
