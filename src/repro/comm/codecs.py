"""Payload codec registry for the FedRF-TCA wire format.

A codec turns one array payload into on-wire bytes and back.  Every codec
exposes three faces that are kept consistent (and tested against each other):

- ``encode``/``decode`` — host-side numpy serialization, the byte-exact
  reference path used by ``transport.WireTransport``;
- ``nbytes(shape, dtype)`` — the *analytic* encoded size.  Exact by
  construction (``len(encode(x)) == nbytes(x.shape, x.dtype)`` for every
  codec), which is what lets the identity transport and the batched engine
  account bytes without serializing anything;
- ``roundtrip(x, key)`` — a jittable in-graph twin of decode(encode(x)) so
  the batched round engine can apply the channel distortion inside its one
  compiled dispatch (see ``kernels.ops.fake_quant`` for the Pallas version).

Codecs (Table I/II mapping — message floats per payload in the paper):

==============  =============================================================
``float32``     identity cast; 4 bytes/elt — the paper's float accounting
``float16``     IEEE half cast; 2 bytes/elt
``bfloat16``    bf16 cast; 2 bytes/elt
``qint8``       per-tensor absmax scale + int8 stochastic rounding; 1 byte/elt
``qint4``       same, 4-bit codes packed two per byte; 0.5 byte/elt
``topk``        magnitude top-k sparsification (``topk:0.25`` keeps 25%,
                ``topk:64`` keeps 64 entries); for classifier deltas
``seed_replay`` transmits a PRNG key + generator id instead of the array —
                O(1) bytes for any seed-derived payload such as the shared
                ``W_RF`` (sharpens Table I's O(KNm) W-row to O(K))
==============  =============================================================
"""
from __future__ import annotations

import struct
from typing import Callable

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

# ---------------------------------------------------------------------------
# dtype wire codes (logical/decoded dtype of a payload)
# ---------------------------------------------------------------------------
DTYPE_CODES: dict[int, np.dtype] = {
    0: np.dtype(np.float32),
    1: np.dtype(np.float16),
    2: np.dtype(ml_dtypes.bfloat16),
    3: np.dtype(np.int8),
    4: np.dtype(np.uint8),
    5: np.dtype(np.int32),
    6: np.dtype(np.uint32),
}
DTYPE_IDS = {v: k for k, v in DTYPE_CODES.items()}


def dtype_id(dtype) -> int:
    try:
        return DTYPE_IDS[np.dtype(dtype)]
    except KeyError as e:
        raise ValueError(f"dtype {dtype} has no wire code") from e


# ---------------------------------------------------------------------------
# seed-replay generator registry
# ---------------------------------------------------------------------------
REPLAY_GENERATORS: dict[str, Callable] = {}
_REPLAY_IDS: dict[str, int] = {}


def register_replay_generator(name: str, fn: Callable) -> None:
    """``fn(key_data: uint32[2], shape, dtype) -> np.ndarray``, deterministic."""
    if name not in _REPLAY_IDS:
        _REPLAY_IDS[name] = len(_REPLAY_IDS)
    REPLAY_GENERATORS[name] = fn


def _w_rf_init(key_data: np.ndarray, shape, dtype) -> np.ndarray:
    """Bit-exact replay of ``federated.model.init_params``'s W_RF draw:
    ``normal(key, (2N, m)) / sqrt(2N)`` from the captured subkey."""
    key = jax.random.wrap_key_data(jnp.asarray(key_data, jnp.uint32))
    arr = jax.random.normal(key, shape) / jnp.sqrt(shape[0])
    return np.asarray(arr, dtype=dtype)


register_replay_generator("w_rf_init", _w_rf_init)


def _omega_fused(key_data: np.ndarray, shape, dtype) -> np.ndarray:
    """Replay of the seed-fused counter stream: ``key_data = (seed,
    ensemble_index)`` and the payload is :func:`repro.kernels.prng.fused_omega`
    — the same bits the fused Pallas kernels draw in-kernel, so a receiver
    that *does* want the materialized Omega (plots, dense baselines) gets it
    bit-identically from the 8-byte key.  Receivers on the fused path never
    call this at all: the key itself is the weight."""
    from repro.kernels.prng import fused_omega

    arr = fused_omega(
        int(key_data[0]), shape[0], shape[1], ensemble_index=int(key_data[1])
    )
    return np.asarray(arr, dtype=dtype)


register_replay_generator("omega_fused", _omega_fused)


# ---------------------------------------------------------------------------
# codec base + registry
# ---------------------------------------------------------------------------
class Codec:
    name: str = ""
    wire_id: int = -1
    lossy: bool = False

    def encode(self, arr: np.ndarray, *, rng=None, replay=None) -> bytes:
        raise NotImplementedError

    def decode(self, data: bytes, shape: tuple[int, ...], dtype) -> np.ndarray:
        raise NotImplementedError

    def nbytes(self, shape: tuple[int, ...], dtype) -> int:
        raise NotImplementedError

    def roundtrip(self, x: jax.Array, key: jax.Array | None = None) -> jax.Array:
        """In-graph decode(encode(x)) twin (identity for lossless codecs)."""
        return x


class _CastCodec(Codec):
    """Lossless-layout cast: serialize as ``wire_dtype``, decode by casting back."""

    wire_dtype: np.dtype

    def encode(self, arr, *, rng=None, replay=None) -> bytes:
        return np.ascontiguousarray(arr).astype(self.wire_dtype).tobytes()

    def decode(self, data, shape, dtype):
        flat = np.frombuffer(data, dtype=self.wire_dtype)
        return flat.reshape(shape).astype(dtype)

    def nbytes(self, shape, dtype) -> int:
        return int(np.prod(shape, dtype=np.int64)) * self.wire_dtype.itemsize


class Float32Codec(_CastCodec):
    name, wire_id = "float32", 0
    wire_dtype = np.dtype(np.float32)


class Float16Codec(_CastCodec):
    name, wire_id, lossy = "float16", 1, True
    wire_dtype = np.dtype(np.float16)

    def roundtrip(self, x, key=None):
        return x.astype(jnp.float16).astype(x.dtype)


class BFloat16Codec(_CastCodec):
    name, wire_id, lossy = "bfloat16", 2, True
    wire_dtype = np.dtype(ml_dtypes.bfloat16)

    def roundtrip(self, x, key=None):
        return x.astype(jnp.bfloat16).astype(x.dtype)


# -- stochastic-rounding quantization ---------------------------------------
def quant_scale(absmax, qmax: int):
    """Per-tensor scale; degenerate all-zero tensors quantize through scale 1."""
    return np.where(absmax > 0, absmax / qmax, 1.0).astype(np.float32)


class QuantCodec(Codec):
    """absmax/qmax per-tensor scale + unbiased stochastic rounding
    ``q = clip(floor(x/scale + u), -qmax, qmax)`` with ``u ~ U[0,1)``.

    Wire layout: f32 scale, then int8 codes (qint8) or two 4-bit codes per
    byte, low nibble first (qint4).  The jax ``roundtrip`` twin and the Pallas
    ``kernels.ops.fake_quant`` kernel implement the identical formula, so all
    three agree bitwise when fed the same uniforms.
    """

    lossy = True

    def __init__(self, bits: int):
        assert bits in (4, 8)
        self.bits = bits
        self.qmax = (1 << (bits - 1)) - 1
        self.name = f"qint{bits}"
        self.wire_id = 3 if bits == 8 else 4

    def _codes(self, arr, rng) -> tuple[np.ndarray, np.float32]:
        x = np.ascontiguousarray(arr, dtype=np.float32).ravel()
        scale = quant_scale(np.max(np.abs(x), initial=0.0), self.qmax)
        u = rng.random(x.shape, dtype=np.float32) if rng is not None else 0.5
        q = np.clip(np.floor(x / scale + u), -self.qmax, self.qmax)
        return q.astype(np.int8), scale

    def encode(self, arr, *, rng=None, replay=None) -> bytes:
        q, scale = self._codes(arr, rng)
        if self.bits == 8:
            packed = q.tobytes()
        else:
            v = (q.astype(np.int16) + 8).astype(np.uint8)  # [0, 15]
            if v.size % 2:
                v = np.concatenate([v, np.zeros((1,), np.uint8)])
            packed = ((v[1::2] << 4) | v[0::2]).tobytes()
        return struct.pack("<f", float(scale)) + packed

    def decode(self, data, shape, dtype):
        (scale,) = struct.unpack_from("<f", data, 0)
        size = int(np.prod(shape, dtype=np.int64))
        if self.bits == 8:
            q = np.frombuffer(data, np.int8, count=size, offset=4)
        else:
            b = np.frombuffer(data, np.uint8, offset=4)
            v = np.empty((b.size * 2,), np.uint8)
            v[0::2] = b & 0x0F
            v[1::2] = b >> 4
            q = v[:size].astype(np.int16) - 8
        return (q.astype(np.float32) * scale).reshape(shape).astype(dtype)

    def nbytes(self, shape, dtype) -> int:
        size = int(np.prod(shape, dtype=np.int64))
        return 4 + (size if self.bits == 8 else (size + 1) // 2)

    def roundtrip(self, x, key=None):
        # deferred imports: keep repro.comm importable without the kernel stack
        from repro.kernels import ops, ref

        u = (
            jax.random.uniform(key, x.shape, jnp.float32)
            if key is not None
            else jnp.full(x.shape, 0.5, jnp.float32)
        )
        # fused Pallas quantize/dequantize on TPU; its bitwise-equal XLA twin
        # elsewhere (interpret-mode Pallas inside the compiled round would
        # only slow CPU runs — the twins are tested equal)
        if jax.default_backend() == "tpu":
            return ops.fake_quant(x, u, bits=self.bits)
        return ref.fake_quant_ref(x, u, bits=self.bits)


class TopKCodec(Codec):
    """Magnitude top-k sparsification: u32 k, k u32 flat indices, k f32 values.

    ``k`` is a kept-fraction when the parameter is <= 1 (``topk:0.25``) and an
    absolute count otherwise (``topk:64``).  At k == size the round trip is
    the identity (tested).  Intended for classifier *deltas*, which are
    near-sparse between T_C syncs.
    """

    lossy = True
    wire_id = 5

    def __init__(self, param: float = 0.25):
        self.param = param
        self.name = f"topk:{param:g}"

    def k_of(self, size: int) -> int:
        k = int(round(self.param * size)) if self.param <= 1 else int(self.param)
        return max(1, min(k, size))

    def encode(self, arr, *, rng=None, replay=None) -> bytes:
        x = np.ascontiguousarray(arr, dtype=np.float32).ravel()
        k = self.k_of(x.size)
        idx = np.sort(np.argpartition(np.abs(x), x.size - k)[x.size - k :])
        return (
            struct.pack("<I", k)
            + idx.astype(np.uint32).tobytes()
            + x[idx].astype(np.float32).tobytes()
        )

    def decode(self, data, shape, dtype):
        (k,) = struct.unpack_from("<I", data, 0)
        idx = np.frombuffer(data, np.uint32, count=k, offset=4)
        val = np.frombuffer(data, np.float32, count=k, offset=4 + 4 * k)
        out = np.zeros(int(np.prod(shape, dtype=np.int64)), np.float32)
        out[idx] = val
        return out.reshape(shape).astype(dtype)

    def nbytes(self, shape, dtype) -> int:
        return 4 + 8 * self.k_of(int(np.prod(shape, dtype=np.int64)))

    def roundtrip(self, x, key=None):
        flat = x.astype(jnp.float32).ravel()
        k = self.k_of(flat.size)
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        out = jnp.zeros_like(flat).at[idx].set(flat[idx])
        return out.reshape(x.shape).astype(x.dtype)


class SeedReplayCodec(Codec):
    """O(1) wire: a generator id + PRNG key replaces the array entirely.

    The sender must supply ``replay=(generator_name, key_data)`` where
    ``key_data`` is the uint32 raw key; the receiver re-derives the payload
    bit-exactly through ``REPLAY_GENERATORS[name]``.  This is the paper's own
    shared-seed trick (Alg. 5's seed S for Omega) promoted to a first-class
    codec, applied to the shared ``W_RF``: the (2N, m) matrix costs as much
    on the wire as its 8-byte key.
    """

    wire_id = 6
    name = "seed_replay"

    # decode memoization: a replayed payload is a pure function of
    # (wire bytes, shape, dtype), so a receiver decoding the same key twice
    # (every round re-announces the shared W_RF/Omega) reconstructs *nothing*
    # after the first time — it hands back the cached read-only array.
    # ``regenerations`` counts actual generator invocations (pinned by tests).
    _cache: dict[tuple, np.ndarray] = {}
    _CACHE_MAX = 64
    regenerations: int = 0

    def encode(self, arr, *, rng=None, replay=None) -> bytes:
        if replay is None:
            raise ValueError(
                "seed_replay codec needs replay=(generator, key_data); payload "
                f"of shape {getattr(arr, 'shape', None)} is not seed-derived"
            )
        gen, key_data = replay
        key = np.ascontiguousarray(key_data, dtype=np.uint32)
        if key.size != 2:
            raise ValueError(f"expected a raw (2,) uint32 key, got {key.shape}")
        return struct.pack("<B", _REPLAY_IDS[gen]) + key.tobytes()

    def decode(self, data, shape, dtype):
        cls = SeedReplayCodec
        cache_key = (bytes(data[:9]), tuple(shape), np.dtype(dtype).str)
        hit = cls._cache.get(cache_key)
        if hit is not None:
            return hit
        (gen_id,) = struct.unpack_from("<B", data, 0)
        key = np.frombuffer(data, np.uint32, count=2, offset=1)
        name = {v: k for k, v in _REPLAY_IDS.items()}[gen_id]
        arr = np.array(REPLAY_GENERATORS[name](key, shape, np.dtype(dtype)))
        arr.setflags(write=False)
        cls.regenerations += 1
        if len(cls._cache) >= cls._CACHE_MAX:
            cls._cache.pop(next(iter(cls._cache)))
        cls._cache[cache_key] = arr
        return arr

    def nbytes(self, shape, dtype) -> int:
        return 1 + 8  # generator id + raw uint32[2] key — shape-independent


_FACTORIES: dict[str, Callable[..., Codec]] = {
    "float32": Float32Codec,
    "float16": Float16Codec,
    "bfloat16": BFloat16Codec,
    "qint8": lambda: QuantCodec(8),
    "qint4": lambda: QuantCodec(4),
    "topk": TopKCodec,
    "seed_replay": SeedReplayCodec,
}
_WIRE_IDS = {0: "float32", 1: "float16", 2: "bfloat16", 3: "qint8", 4: "qint4",
             5: "topk", 6: "seed_replay"}


def get_codec(spec: str) -> Codec:
    """``get_codec("qint8")``, ``get_codec("topk:0.1")`` — name[:param]."""
    name, _, param = spec.partition(":")
    if name not in _FACTORIES:
        raise ValueError(f"unknown codec {spec!r}; have {sorted(_FACTORIES)}")
    return _FACTORIES[name](float(param)) if param else _FACTORIES[name]()


def codec_names() -> list[str]:
    return sorted(_FACTORIES)


def codec_from_wire_id(wire_id: int) -> Codec:
    return get_codec(_WIRE_IDS[wire_id])
