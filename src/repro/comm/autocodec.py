"""One-shot codec auto-picker: cheapest codec meeting an accuracy budget.

``BENCH_comm.json`` (written by ``benchmarks/run.py --only wire``) measures
the end-to-end accuracy and exact wire bytes of every codec through the real
protocol.  This module turns that record into a decision procedure:

    >>> pick_codec(0.02)          # cheapest codec losing <= 2% accuracy
    'seed_replay'

and wires it into the protocol as ``ProtocolConfig(codec="auto:<budget>")`` —
the trainer resolves the spec against the measured curves once, at
construction, and then runs with a concrete codec (``trainer.resolved_codec``
records the choice).  The accuracy gap is measured against the identity
transport baseline in the same record; candidates are ranked by total bytes
across the three payload kinds.  A negative-gap codec (one that *helped*,
like seed_replay's implicit W freeze often does) always qualifies.
"""
from __future__ import annotations

import json
from pathlib import Path

# repo root: src/repro/comm/autocodec.py -> three parents up from src/
DEFAULT_RECORD_PATH = Path(__file__).resolve().parents[3] / "BENCH_comm.json"


def load_record(path=None) -> dict:
    p = Path(path) if path is not None else DEFAULT_RECORD_PATH
    if not p.exists():
        raise FileNotFoundError(
            f"no codec benchmark record at {p} — run "
            "`PYTHONPATH=src python -m benchmarks.run --only wire` first"
        )
    return json.loads(p.read_text())


def codec_table(record: dict) -> dict[str, dict]:
    """Per-codec {gap, bytes} from a BENCH_comm.json record (gap = identity
    accuracy minus codec accuracy; bytes = total on-wire bytes of its run).

    A record written by an older bench (missing keys, reshaped rows) raises
    a ``ValueError`` naming the rerun command — never a bare ``KeyError``
    deep in a trainer constructor.
    """
    try:
        base = float(record["identity"]["acc"])
        table = {}
        for name, row in record["accuracy_vs_codec"].items():
            table[name] = {
                "gap": base - float(row["acc"]),
                "bytes": int(sum(row["bytes"].values())),
            }
    except (KeyError, TypeError, AttributeError) as exc:
        raise ValueError(
            "BENCH_comm.json record does not match the current schema "
            f"(missing/reshaped field: {exc!r}) — regenerate it with "
            "`PYTHONPATH=src python -m benchmarks.run --only wire`"
        ) from exc
    if not table:
        raise ValueError(
            "BENCH_comm.json record measured no codecs — regenerate it with "
            "`PYTHONPATH=src python -m benchmarks.run --only wire`"
        )
    return table


def pick_codec(budget: float, *, record: dict | None = None, path=None) -> str:
    """Cheapest codec whose measured accuracy gap is within ``budget``.

    ``budget`` is an absolute accuracy allowance (0.02 = may lose up to two
    accuracy points vs the identity transport).  Raises when no measured
    codec fits — a budget below every measured gap is a configuration error,
    not a silent fallback to the most expensive codec.
    """
    if budget < 0:
        raise ValueError(f"accuracy budget must be >= 0, got {budget}")
    table = codec_table(record if record is not None else load_record(path))
    fits = [(row["bytes"], name) for name, row in table.items() if row["gap"] <= budget]
    if not fits:
        gaps = {name: round(row["gap"], 4) for name, row in table.items()}
        raise ValueError(f"no measured codec meets accuracy budget {budget}: gaps {gaps}")
    return min(fits)[1]


def resolve(spec: str, *, record: dict | None = None, path=None) -> str:
    """``"auto:<budget>"`` -> concrete codec name (identity on other specs)."""
    if not spec.startswith("auto:"):
        return spec
    try:
        budget = float(spec.split(":", 1)[1])
    except ValueError as exc:
        raise ValueError(f"bad auto-codec spec {spec!r}: want 'auto:<float budget>'") from exc
    return pick_codec(budget, record=record, path=path)
