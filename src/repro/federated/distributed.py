"""Sharded FedRF-TCA data plane: the paper's communication pattern as JAX collectives.

The host-side simulator (`protocol.py`) expresses the *asynchronous* protocol.
This module expresses the *synchronous* round (all clients in S_t) as a single
SPMD program with ``shard_map`` over a ``clients`` mesh axis:

- every client shard computes its 2N-float message  Sigma ell   locally;
- the message exchange is ONE ``psum`` over the clients axis  -> an all-reduce
  of 2N floats, byte-for-byte the O(KN) claim of Table I;
- FedAvg of W_RF is ONE ``pmean`` of the (2N, m) aligner        -> O(KNm).

Nothing here scales with the per-client sample count n — compare with a naive
federated MMD which would all-gather (n_i x d) features.

This is also the pattern the backbone integration uses on the production mesh
(clients axis == data axis); see repro.models.fda_head.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.mmd import mmd_projected
from repro.federated.model import ClientConfig, client_message, source_loss
from repro.optim import apply_updates


def make_client_mesh(n_clients: int) -> Mesh:
    devs = jax.devices()[:n_clients]
    if len(devs) < n_clients:
        raise ValueError(
            f"need {n_clients} devices for the sharded data plane, have {len(devs)};"
            " set XLA_FLAGS=--xla_force_host_platform_device_count"
        )
    return jax.make_mesh((n_clients,), ("clients",), devices=devs)


def build_sharded_round(mesh: Mesh, cfg: ClientConfig, omega: jnp.ndarray, opt):
    """Returns a jitted synchronous round over stacked per-client state.

    Stacked state: params/opt with a leading (K,) axis sharded over `clients`;
    batches (K, p, b) and labels (K, b) likewise; target batch replicated.
    """

    def one_round(stacked_params, stacked_opt, xs, ys, x_t):
        def per_client(params, opt_state, x, y, x_tgt):
            # strip the leading length-1 shard axis
            params = jax.tree_util.tree_map(lambda a: a[0], params)
            opt_state = jax.tree_util.tree_map(
                lambda a: a[0] if a.ndim > 0 else a, opt_state
            )
            x, y = x[0], y[0]

            # target message, computed with THIS client's current extractor view
            # of the target batch (synchronous round: target params == broadcast)
            msg_t = client_message(params, omega, x_tgt, -1.0)

            def loss_fn(p):
                loss, aux = source_loss(p, omega, x, y, msg_t, cfg, with_mmd=False)
                msg_s = client_message(p, omega, x, +1.0)
                # >>> THE EXCHANGE: one all-reduce of a 2N-float message <<<
                # Other clients' messages arrive over the wire and are
                # constants to this client (psum's VJP would otherwise sum
                # cotangents across shards): gradient flows through the local
                # term only, matching the host-side protocol semantics.
                msg_sum = msg_s + jax.lax.stop_gradient(
                    jax.lax.psum(msg_s, "clients") - msg_s
                )
                l_mmd = mmd_projected(p["w_rf"], msg_sum / mesh.shape["clients"], msg_t)
                return loss + cfg.lambda_mmd * l_mmd, (aux["l_c"], l_mmd)

            (loss, (l_c, l_mmd)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
            upd, opt_state = opt.update(grads, opt_state, params)
            params = apply_updates(params, upd)
            # >>> FedAvg of the aligner: one pmean of (2N, m) <<<
            params["w_rf"] = jax.lax.pmean(params["w_rf"], "clients")
            metrics = {
                "l_c": jax.lax.pmean(l_c, "clients"),
                "l_mmd": jax.lax.pmean(l_mmd, "clients"),
            }
            params = jax.tree_util.tree_map(lambda a: a[None], params)
            # every opt leaf was stacked with a leading client axis (incl. the
            # scalar step -> (K,)), so unconditionally restore rank
            opt_state = jax.tree_util.tree_map(lambda a: jnp.asarray(a)[None], opt_state)
            return params, opt_state, metrics

        spec_k = P("clients")
        # every stacked opt leaf carries the leading (K,) client axis
        opt_spec = jax.tree_util.tree_map(lambda a: spec_k, stacked_opt)
        param_spec = jax.tree_util.tree_map(lambda _: spec_k, stacked_params)
        return shard_map(
            per_client,
            mesh=mesh,
            in_specs=(param_spec, opt_spec, spec_k, spec_k, P()),
            out_specs=(param_spec, opt_spec, P()),
        )(stacked_params, stacked_opt, xs, ys, x_t)

    return jax.jit(one_round)


def stack_clients(param_list):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *param_list)


def unstack_clients(stacked, k: int):
    return [jax.tree_util.tree_map(lambda a: a[i], stacked) for i in range(k)]
