"""Per-client model for FedRF-TCA (paper Fig. 1):

    feature extractor G (trainable MLP)  ->  RFF compressor (fixed, shared seed)
      ->  linear aligner W_RF (2N x m)   ->  classifier C.

All pieces are pure functions over parameter pytrees so the same code runs in
the host-side protocol simulator and inside jit/shard_map.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.mmd import mmd_projected, mmd_projected_multi
from repro.core.rff import draw_omega, rff_features_rows


@dataclass(frozen=True)
class ClientConfig:
    input_dim: int
    n_classes: int
    extractor_widths: tuple[int, ...] = (64, 32)
    n_rff: int = 256  # N; messages are 2N floats
    m: int = 32  # aligned feature dim
    rff_sigma: float = 1.0
    rff_seed: int = 1234  # the shared seed S of Algorithm 5
    # "materialized": jax.random draw (the seed behavior); "fused": the
    # counter-based stream of repro.kernels.prng — the same bits the
    # seed-fused Pallas kernels draw in-kernel, so a client on this setting
    # shares Omega with the fused Gram/featurize path (and with any receiver
    # replaying the "omega_fused" seed_replay generator) bit-for-bit.
    rff_impl: str = "materialized"
    lambda_mmd: float = 1.0
    # The paper normalises features to unit Euclidean norm (App. D-A) — this
    # also keeps the extractor output inside the RFF kernel's resolvable scale
    # (without it, ||G(x)|| >> sigma puts cos(Omega x) in the oscillatory
    # regime where mean embeddings vanish and MMD gradients are noise).
    normalize_features: bool = True


def make_omega(cfg: ClientConfig) -> jnp.ndarray:
    """Shared-seed Omega: every client derives the identical matrix (Alg. 2/3)."""
    if cfg.rff_impl == "fused":
        from repro.kernels.prng import fused_omega

        return fused_omega(
            cfg.rff_seed, cfg.n_rff, cfg.extractor_widths[-1], sigma=cfg.rff_sigma
        )
    if cfg.rff_impl != "materialized":
        raise ValueError(f"unknown rff_impl {cfg.rff_impl!r}")
    return draw_omega(cfg.rff_seed, cfg.n_rff, cfg.extractor_widths[-1], sigma=cfg.rff_sigma)


def w_rf_key(cfg: ClientConfig, key: jax.Array) -> jax.Array:
    """The exact subkey :func:`init_params` draws W_RF from.  The comm
    subsystem's seed-replay codec ships this key (O(1) bytes) instead of the
    (2N, m) matrix and re-derives W_RF bit-exactly on the receiver."""
    return jax.random.split(key, len(cfg.extractor_widths) + 2)[-2]


def init_params(cfg: ClientConfig, key: jax.Array) -> dict[str, Any]:
    keys = jax.random.split(key, len(cfg.extractor_widths) + 2)
    widths = (cfg.input_dim,) + cfg.extractor_widths
    extractor = []
    for i, (din, dout) in enumerate(zip(widths[:-1], widths[1:])):
        w = jax.random.normal(keys[i], (din, dout)) * jnp.sqrt(2.0 / din)
        extractor.append({"w": w, "b": jnp.zeros((dout,))})
    w_rf = jax.random.normal(keys[-2], (2 * cfg.n_rff, cfg.m)) / jnp.sqrt(2 * cfg.n_rff)
    clf = {
        "w": jax.random.normal(keys[-1], (cfg.m, cfg.n_classes)) / jnp.sqrt(cfg.m),
        "b": jnp.zeros((cfg.n_classes,)),
    }
    return {"extractor": extractor, "w_rf": w_rf, "classifier": clf}


def extract(params, x_cols: jnp.ndarray, normalize: bool = True) -> jnp.ndarray:
    """G(X): (p, n) columns-as-samples -> (n, d_feat) rows-as-samples."""
    h = x_cols.T
    for i, layer in enumerate(params["extractor"]):
        h = h @ layer["w"] + layer["b"]
        if i < len(params["extractor"]) - 1:
            h = jax.nn.gelu(h)
    if normalize:
        h = h / (jnp.linalg.norm(h, axis=-1, keepdims=True) + 1e-6)
    return h


def rff_of(params, omega, x_cols):
    """Sigma rows: (n, 2N)."""
    return rff_features_rows(extract(params, x_cols), omega)


def client_message(params, omega, x_cols, sign: float, mask=None) -> jnp.ndarray:
    """Sigma ell = sign * mean of RFF rows (eq. 2) — the only data-dependent
    message a client ever transmits (2N floats).

    ``mask`` ((n,) 0/1 floats) restricts the mean to the valid sample columns:
    the batched round engine pads ragged per-client message batches to the max
    client length, and the moment must average the client's *true* samples
    only (sum of masked rows / mask count).  ``None`` means every column is a
    real sample (the unpadded path, bit-identical to the seed behavior).
    """
    rows = rff_of(params, omega, x_cols)  # (n, 2N)
    if mask is None:
        return sign * jnp.mean(rows, axis=0)
    m = mask.astype(rows.dtype)
    return sign * (m @ rows) / jnp.sum(m)


def logits_of(params, omega, x_cols) -> jnp.ndarray:
    aligned = rff_of(params, omega, x_cols) @ params["w_rf"]  # (n, m)
    return aligned @ params["classifier"]["w"] + params["classifier"]["b"]


def source_loss(
    params,
    omega,
    x,
    y,
    target_msg,
    cfg: ClientConfig,
    *,
    with_mmd: bool = True,
    mmd_gate=None,
    sample_mask=None,
):
    """Alg. 2: L_S = L_C + lambda L_MMD (or L_C alone when i not in S_t).

    ``with_mmd`` selects the branch at trace time (the serial simulator jits
    two separate step functions).  ``mmd_gate`` instead is a *traced* 0/1
    scalar multiplying the MMD term, so a single vmapped program can express
    per-client membership in S_t — the batched round engine's drop masks.
    ``sample_mask`` ((b,) 0/1 floats) marks the valid columns of a ragged
    batch padded to the stacked batch width: the CE mean and the MMD moment
    both run over the client's true samples only.
    """
    logits = logits_of(params, omega, x)
    one_hot = jax.nn.one_hot(y, cfg.n_classes)
    per_sample = jnp.sum(one_hot * jax.nn.log_softmax(logits), axis=-1)
    if sample_mask is None:
        l_c = -jnp.mean(per_sample)
    else:
        sm = sample_mask.astype(per_sample.dtype)
        l_c = -(sm @ per_sample) / jnp.sum(sm)
    if mmd_gate is None:
        if not with_mmd:
            return l_c, {"l_c": l_c, "l_mmd": jnp.zeros(())}
        mmd_gate = 1.0
    msg_s = client_message(params, omega, x, +1.0, mask=sample_mask)
    l_mmd = mmd_gate * mmd_projected(params["w_rf"], msg_s, target_msg)
    return l_c + cfg.lambda_mmd * l_mmd, {"l_c": l_c, "l_mmd": l_mmd}


def target_loss(params, omega, x, source_msgs, cfg: ClientConfig, *, weights=None):
    """Alg. 3: L_T = mean over received source messages of the pair MMD (11).

    ``weights`` (K,) restricts the mean to the messages that actually arrived
    (batched engine); None means all rows of ``source_msgs`` were received.
    """
    msg_t = client_message(params, omega, x, -1.0)
    l_mmd = mmd_projected_multi(params["w_rf"], source_msgs, msg_t, weights=weights)
    return l_mmd, {"l_mmd": l_mmd}


def accuracy(params, omega, x, y) -> jnp.ndarray:
    return jnp.mean(jnp.argmax(logits_of(params, omega, x), axis=-1) == y)
