"""Batched federated round engine: one compiled dispatch per round.

The serial simulator in ``protocol.py`` dispatches ``K x local_steps`` jitted
calls per round from Python — faithful to the asynchronous protocol, but the
Python/dispatch overhead grows linearly in the client count.  This engine
expresses the same round body (Alg. 5) as a single jitted program:

- per-client parameters/optimizer states are *stacked* along a leading K axis
  (one pytree whose leaves are (K, ...) arrays);
- source local steps run under ``jax.vmap`` across clients and ``lax.scan``
  across local steps;
- the round's drop plan (Table III) enters as 0/1 mask vectors: the MMD term
  is gated per client, dropped messages carry zero weight in the target loss,
  and aggregation assign-backs are ``where``-selected — the program itself is
  identical every round, so XLA compiles it exactly once.

What stays host-side by design: client sampling, drop-set construction and
communication accounting (``network.py`` / ``repro.comm``) — the part the
paper's robustness claims are about and XLA cannot express.  The wire layer
enters this plane as jittable codec distortion twins (``channel``) and the
frozen-W invariant behind the O(1) seed-replay codec (``freeze_w_rf``); byte
accounting stays host-side on the exact analytic sizes.

Ragged clients: per-client sample counts need not match.  The trainer pads
each client's training / message batches to the max client width and passes
0/1 validity masks (``bmask`` (K, b), ``msg_mask`` (K, mb)); every mean and
moment inside the round is computed over true samples only, so the stacked
program reproduces the serial plane's unequal per-client batches instead of
truncating everyone to the min (the seed behavior).

Semantics vs the serial path: identical when every client participates (the
equivalence tests monkeypatch a full-participation plan and check parameter
trajectories match — including with unequal per-client dataset sizes).  Under
random drops the two paths consume client batch streams at different rates
(the serial path skips message batches of dropped clients), so trajectories
are statistically — not bitwise — equal.

Besides the synchronous round, the engine compiles the asynchronous runtime's
data plane (``_flush_fn``): a FedBuff-style buffered aggregation in which only
the clients whose updates sit in the server buffer materialize local steps,
each against the target broadcast of its own dispatch version, and every merge
is staleness-weighted.  With a full fresh buffer and unit weights the flush
reduces term-by-term to the sync round — the degeneracy
``repro.fedsim``'s tests pin down.

Fleet scale (``repro.fleet``): the sync round and the async flush share one
set of merge methods (``_merge_msgs`` / ``_merge_w_rf`` /
``_merge_classifier``).  With ``topology=None`` they are the flat K-client
merges, bit-for-bit.  With a :class:`repro.fleet.Topology` every merge routes
through the two-tier edge -> server split of ``fleet.hierarchy`` (grouped
partial sums + masses, per-tier ``edge_channel`` codec twins on the edge
uplinks), and ``client_chunk`` bounds the local-step working set by running
the per-client vmap ``chunk`` rows at a time (``fleet.sharding.chunked_vmap``
— bitwise equal to the unchunked vmap).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.federated.model import ClientConfig, client_message, source_loss, target_loss
from repro.fleet import hierarchy
from repro.fleet.sharding import chunked_vmap
from repro.obs import sentinel
from repro.optim import apply_updates
from repro.robust.rules import MeanRule

_MASS_EPS = 1e-12


def client_delta_norms(new, old):
    """Per-client L2 norm of a stacked-pytree parameter delta: (K,)."""
    pairs = zip(jax.tree_util.tree_leaves(new), jax.tree_util.tree_leaves(old))
    sq = sum(jnp.sum((a - b) ** 2, axis=tuple(range(1, a.ndim))) for a, b in pairs)
    return jnp.sqrt(sq)


def tree_delta_norm(new, old):
    """Whole-pytree L2 norm of a parameter delta: () scalar."""
    pairs = zip(jax.tree_util.tree_leaves(new), jax.tree_util.tree_leaves(old))
    return jnp.sqrt(sum(jnp.sum((a - b) ** 2) for a, b in pairs))


def stack_trees(trees: list):
    """List of identically-structured pytrees -> one pytree of (K, ...) leaves."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def unstack_tree(tree, i: int):
    """Row i of a stacked pytree (client i's parameters)."""
    return jax.tree_util.tree_map(lambda x: x[i], tree)


def tree_where(pred, new, old):
    """Leafwise jnp.where(pred, new, old) — traced-bool conditional assignment."""
    return jax.tree_util.tree_map(lambda a, b: jnp.where(pred, a, b), new, old)


class BatchedRoundEngine:
    """Compiled data plane for ``FedRFTCATrainer`` (one dispatch per round)."""

    def __init__(
        self,
        cfg: ClientConfig,
        opt,
        omega: jnp.ndarray,
        *,
        exchange_messages: bool = True,
        aggregate_w_rf: bool = True,
        aggregate_classifier: bool = True,
        freeze_w_rf: bool = False,
        channel: dict | None = None,
        topology=None,
        edge_channel: dict | None = None,
        client_chunk: int | None = None,
        rule=None,
        faults=None,
        probe: bool = False,
    ):
        """``freeze_w_rf`` pins W_RF at its (shared, seed-derived) init:
        gradients through it are stopped and W-aggregation is skipped, so all
        clients stay bit-identical — the invariant behind the O(1) seed-replay
        wire codec.  ``channel`` maps payload kinds ("moments"/"w_rf"/
        "classifier") to jittable distortion twins ``fn(x, key) -> x``
        (``comm.Codec.roundtrip``) applied to uplinked values in-graph — the
        batched plane's equivalent of the serial plane's real
        serialize/deserialize round trip (stochastic codecs draw from jax
        keys here vs numpy streams there, so the two planes agree
        statistically, not bitwise).

        Fleet scale: ``topology`` (a :class:`repro.fleet.Topology`) switches
        every merge to the two-tier edge -> server split, with
        ``edge_channel`` the tier-2 codec twins distorting the edge uplinks;
        ``client_chunk`` runs the per-client local-step vmap ``chunk`` rows
        at a time so the working set is O(chunk), not O(K).

        Robustness: ``rule`` (a :class:`repro.robust.AggregationRule`,
        default the seed's exact :class:`~repro.robust.rules.MeanRule`) owns
        every weighted merge — Sigma-ell moments into the target loss, W_RF,
        classifier leaves, and (two-tier) the server-side combine over edge
        partial means — all in-graph, so the round/flush stay one compiled
        dispatch.  ``faults`` (a :class:`repro.robust.FaultPlan`, or None)
        injects value-level payload corruption and Byzantine crafted uplinks
        into the stacked client payloads after the channel — the undefended
        attack surface the robust rules are measured against.  Both default
        to the bit-exact fault-free seed program.

        Observability: ``probe=True`` makes ``_round_fn``/``_flush_fn``
        return a fifth output — a dict of in-graph health probes
        (``moment_mass``, per-client ``update_norm``, ``tgt_update_norm``,
        and the rule's ``attribution_moments`` / ``attribution_w_rf``
        trim/quarantine indicators) — computed inside the same compiled
        program, so both planes stay one dispatch each.  The flag is fixed
        at construction (each variant compiles exactly once); the probe
        outputs never feed back into the parameter computation, so the
        trajectories are bitwise identical either way (test-gated).  The
        three jitted planes are wrapped in :mod:`repro.obs.sentinel`
        retrace counters (planes ``engine.round`` / ``engine.flush`` /
        ``engine.warmup``) — a trace-time-only Python side effect that
        detects silent recompilation without touching the compiled program.
        """
        self.cfg, self.opt, self.omega = cfg, opt, omega
        self.rule = rule if rule is not None else MeanRule()
        self.faults = faults
        self.exchange_messages = exchange_messages
        self.aggregate_w_rf = aggregate_w_rf
        self.aggregate_classifier = aggregate_classifier
        self.freeze_w_rf = freeze_w_rf
        self.channel = channel or {}
        self.topology = topology
        self.edge_channel = edge_channel or {}
        self.client_chunk = client_chunk
        self.probe = probe
        if topology is not None:
            self._seg_ids = jnp.asarray(topology.segment_ids)
            self._n_edges = topology.n_edges
        else:
            self._seg_ids, self._n_edges = None, 0
        self._round = jax.jit(sentinel.wrap("engine.round", self._round_fn))
        self._warmup = jax.jit(sentinel.wrap("engine.warmup", self._warmup_fn))
        self._flush = jax.jit(sentinel.wrap("engine.flush", self._flush_fn))

    # -- building blocks ----------------------------------------------------

    def _maybe_freeze(self, params):
        if not self.freeze_w_rf:
            return params
        return {**params, "w_rf": jax.lax.stop_gradient(params["w_rf"])}

    def _src_local_scan(self, src_p, src_o, xs, ys, mmd_mask, tgt_msg, bmask=None):
        """lax.scan over local steps of a vmapped per-client Adam step.

        xs: (L, K, p, b), ys: (L, K, b), mmd_mask: (K,) 0/1 floats.
        ``bmask`` ((K, b) 0/1 floats or None) marks each client's true batch
        columns when per-client batch sizes are ragged (padded to the max
        client width); the CE/MMD math inside ``source_loss`` then averages
        over true samples only, so each step is identical to the serial
        plane's unpadded per-client step.

        ``tgt_msg`` is either one (2N,) message shared by every client (the
        sync round: the target broadcast of this round) or a (K, 2N) stack of
        per-client messages (the async flush: each client trained against the
        broadcast it was handed at *its* dispatch time, which may be several
        model versions old).
        """
        cfg, omega, opt = self.cfg, self.omega, self.opt

        def one_client(p, o, x, y, gate, sm, tm):
            (_, aux), grads = jax.value_and_grad(
                lambda pp: source_loss(
                    self._maybe_freeze(pp), omega, x, y, tm, cfg,
                    mmd_gate=gate, sample_mask=sm,
                ),
                has_aux=True,
            )(p)
            upd, o = opt.update(grads, o, p)
            return apply_updates(p, upd), o, aux

        tm_ax = 0 if tgt_msg.ndim == 2 else None

        def step(carry, xy):
            ps, os = carry
            x, y = xy
            mask_ax = 0 if bmask is not None else None
            mapped = chunked_vmap(
                one_client,
                (0, 0, 0, 0, 0, mask_ax, tm_ax),
                chunk=self.client_chunk,
            )
            ps, os, _ = mapped(ps, os, x, y, mmd_mask, bmask, tgt_msg)
            return (ps, os), None

        (src_p, src_o), _ = jax.lax.scan(step, (src_p, src_o), (xs, ys))
        return src_p, src_o

    # -- merge code (shared by the sync round and the async flush) ----------
    #
    # ``sel`` is the 0/1 participation mask that gates assign-backs and the
    # "did anything arrive" checks; ``wsel`` the merge weights (== sel in the
    # sync round, buf_mask * staleness weights in the async flush).  With no
    # topology these are the flat K-client merges, bit-for-bit the seed
    # expressions; with one, every merge routes through the two-tier
    # edge -> server split of ``fleet.hierarchy`` (tier-2 ``edge_channel``
    # codec twins applied to the edge uplinks).

    def _uplinked_msgs(self, src_p, x_msg, msg_mask, chan_key):
        """(K, 2N) source Sigma-ell uplinks after the tier-1 channel.  Also
        ``client_chunk``-bounded: the per-client (mb, 2N) RFF slabs are the
        other O(K) activation of a round."""
        omega = self.omega
        k_clients = x_msg.shape[0]
        msgs = chunked_vmap(
            lambda p, x, mk: client_message(p, omega, x, +1.0, mask=mk),
            (0, 0, 0 if msg_mask is not None else None),
            chunk=self.client_chunk,
        )(src_p, x_msg, msg_mask)
        chan_m = self.channel.get("moments")
        if chan_m is not None:
            keys = jax.random.split(jax.random.fold_in(chan_key, 1), k_clients)
            msgs = jax.vmap(chan_m)(msgs, keys)
        if self.faults is not None:
            msgs = self.faults.apply("moments", msgs, jax.random.fold_in(chan_key, 7))
        return msgs

    def _merge_msgs(self, msgs, weights, chan_key, probes=None):
        """What the target trains on.  Flat plane: the rule's moment merge —
        (msgs, weights) unchanged for the mean (the seed's per-pair MMD),
        the single robust pooled moment row otherwise.  Two-tier plane:
        per-edge pooled moments + masses, robustly re-merged over edges when
        the rule is not the mean (an adversarial *edge* is then one outlier
        row, exactly like an adversarial client in the flat plane).

        ``probes`` (a dict, or None) collects in-graph health outputs: the
        delivered moment mass and the rule's per-row (client in the flat
        plane, edge in the two-tier plane) trim/quarantine attribution."""
        if self._seg_ids is None:
            if probes is not None:
                probes["moment_mass"] = jnp.sum(weights)
                probes["attribution_moments"] = self.rule.attribution(msgs, weights)
            return self.rule.merge_moments(msgs, weights)
        pooled, masses = hierarchy.edge_moment_merge(
            msgs,
            weights,
            self._seg_ids,
            self._n_edges,
            self.edge_channel.get("moments"),
            jax.random.fold_in(chan_key, 4),
        )
        if probes is not None:
            probes["moment_mass"] = jnp.sum(masses)
            probes["attribution_moments"] = self.rule.attribution(pooled, masses)
        return self.rule.merge_moments(pooled, masses)

    def _target_scan(self, tgt_p, tgt_o, xt_steps, msgs, weights, any_gate):
        """Alg. 3 local target steps on the merged source moments; a no-op
        (params AND opt state) when nothing arrived, the serial semantics."""
        cfg, opt = self.cfg, self.opt

        def tgt_step(carry, x):
            p, o = carry
            (_, _), grads = jax.value_and_grad(
                lambda pp: target_loss(
                    self._maybe_freeze(pp), self.omega, x, msgs, cfg, weights=weights
                ),
                has_aux=True,
            )(p)
            upd, o = opt.update(grads, o, p)
            return (apply_updates(p, upd), o), None

        (new_tgt_p, new_tgt_o), _ = jax.lax.scan(tgt_step, (tgt_p, tgt_o), xt_steps)
        tgt_p = tree_where(any_gate, new_tgt_p, tgt_p)
        tgt_o = tree_where(any_gate, new_tgt_o, tgt_o)
        return tgt_p, tgt_o

    def _server_merge(self, sums, masses):
        """Tier-2 combine of per-edge (weighted sum, mass) partials.  For the
        mean rule this is the pure reassociation ``(sum sums, sum masses)``
        (bitwise the flat contraction's value up to reassociation — pinned by
        the fleet equivalence tests).  Robust rules instead treat the edge
        partial *means* as K'=E rows: a poisoned edge is one outlier."""
        if self.rule.is_mean:
            return hierarchy.server_combine(sums, masses)
        shaped = masses.reshape((-1,) + (1,) * (sums.ndim - 1))
        rows = sums / jnp.maximum(shaped, _MASS_EPS)
        return self.rule.weighted_sum(rows, masses)

    def _merge_w_rf(self, src_p, tgt_p, sel, wsel, chan_key, probes=None):
        """Weighted W_RF merge over participants + the target (Alg. 4)."""
        k_clients = sel.shape[0]
        chan_w = self.channel.get("w_rf")
        have_w = jnp.sum(sel) > 0
        w_up, w_tgt_up = src_p["w_rf"], tgt_p["w_rf"]
        if chan_w is not None:
            keys = jax.random.split(jax.random.fold_in(chan_key, 2), k_clients + 1)
            w_up = jax.vmap(chan_w)(w_up, keys[:k_clients])
            w_tgt_up = chan_w(w_tgt_up, keys[k_clients])
        if self.faults is not None:
            w_up = self.faults.apply("w_rf", w_up, jax.random.fold_in(chan_key, 8))
        if self._seg_ids is None:
            if probes is not None:
                # post-channel / post-fault uplinks: exactly what the rule saw
                probes["attribution_w_rf"] = self.rule.attribution(w_up, wsel)
            # rule-owned contraction; MeanRule is the seed einsum bit-for-bit
            w_sum, mass = self.rule.weighted_sum(w_up, wsel)
        else:
            sums, masses = hierarchy.edge_param_merge(
                w_up,
                wsel,
                self._seg_ids,
                self._n_edges,
                self.edge_channel.get("w_rf"),
                jax.random.fold_in(chan_key, 5),
            )
            if probes is not None:
                shaped = masses.reshape((-1,) + (1,) * (sums.ndim - 1))
                rows = sums / jnp.maximum(shaped, _MASS_EPS)
                probes["attribution_w_rf"] = self.rule.attribution(rows, masses)
            w_sum, mass = self._server_merge(sums, masses)
        w_avg = (w_sum + w_tgt_up) / (mass + 1.0)
        src_p["w_rf"] = jnp.where(
            (sel > 0)[:, None, None] & have_w, w_avg[None], src_p["w_rf"]
        )
        tgt_p["w_rf"] = jnp.where(have_w, w_avg, tgt_p["w_rf"])
        return src_p, tgt_p

    def _merge_classifier(self, src_p, tgt_p, sel, wsel, do_clf, chan_key, floor):
        """Weighted classifier merge on T_C rounds/flushes (Alg. 4)."""
        k_clients = sel.shape[0]
        chan_c = self.channel.get("classifier")
        have_c = do_clf & (jnp.sum(sel) > 0)
        clf_up = src_p["classifier"]
        if chan_c is not None:
            kbase = jax.random.fold_in(chan_key, 3)
            leaves, treedef = jax.tree_util.tree_flatten(clf_up)
            clf_up = jax.tree_util.tree_unflatten(
                treedef,
                [
                    jax.vmap(chan_c)(
                        leaf, jax.random.split(jax.random.fold_in(kbase, i), k_clients)
                    )
                    for i, leaf in enumerate(leaves)
                ],
            )
        if self.faults is not None:
            # one fault key per merge: the same clients corrupt in every
            # classifier leaf (w and b travel in one message)
            kf = jax.random.fold_in(chan_key, 9)
            clf_up = jax.tree_util.tree_map(
                lambda leaf: self.faults.apply("classifier", leaf, kf), clf_up
            )
        if self._seg_ids is None:

            def leaf_avg(leaf):
                # rule-owned contraction; MeanRule == the seed tensordot/denom
                s, m = self.rule.weighted_sum(leaf, wsel)
                return s / jnp.maximum(m, floor)

            c_avg = jax.tree_util.tree_map(leaf_avg, clf_up)
        else:
            chan_ce = self.edge_channel.get("classifier")
            kbase_e = jax.random.fold_in(chan_key, 6)
            leaves, treedef = jax.tree_util.tree_flatten(clf_up)
            merged = []
            for i, leaf in enumerate(leaves):
                sums, masses = hierarchy.edge_param_merge(
                    leaf,
                    wsel,
                    self._seg_ids,
                    self._n_edges,
                    chan_ce,
                    jax.random.fold_in(kbase_e, i),
                )
                c_sum, mass = self._server_merge(sums, masses)
                merged.append(c_sum / jnp.maximum(mass, floor))
            c_avg = jax.tree_util.tree_unflatten(treedef, merged)
        assign = (sel > 0) & have_c
        src_p["classifier"] = jax.tree_util.tree_map(
            lambda avg, old: jnp.where(
                assign.reshape((-1,) + (1,) * (old.ndim - 1)), avg[None], old
            ),
            c_avg,
            src_p["classifier"],
        )
        tgt_p["classifier"] = tree_where(have_c, c_avg, tgt_p["classifier"])
        return src_p, tgt_p

    # -- round body (Alg. 5) ------------------------------------------------

    def _round_fn(
        self,
        src_p,
        src_o,
        tgt_p,
        tgt_o,
        xs,  # (L, K, p, b) source training batches
        ys,  # (L, K, b)
        x_msg,  # (K, p, mb) source message batches
        xt_steps,  # (L, p, b) target training batches
        xt_msg,  # (p, mb) target message batch
        mmd_mask,  # (K,) 1.0 iff client in plan.msg_clients
        w_mask,  # (K,) 1.0 iff client in plan.w_clients
        c_mask,  # (K,) 1.0 iff client in plan.c_clients
        do_clf,  # () bool: t % T_C == 0 this round
        chan_key,  # per-round PRNG key for stochastic channel distortion
        bmask,  # (K, b) 0/1 valid-column mask of ragged training batches | None
        msg_mask,  # (K, mb) 0/1 valid-column mask of ragged message batches | None
    ):
        omega = self.omega
        chan_m = self.channel.get("moments")
        probes = {} if self.probe else None
        src_p0, tgt_p0 = (src_p, tgt_p) if self.probe else (None, None)

        # target broadcasts its message to the sources in S_t (the one
        # downlink the protocol accounts; distorted by the wire codec)
        tgt_msg = client_message(tgt_p, omega, xt_msg, -1.0)
        if chan_m is not None:
            tgt_msg = chan_m(tgt_msg, jax.random.fold_in(chan_key, 0))

        # local source training (Alg. 2), MMD gated by S_t membership
        gates = mmd_mask if self.exchange_messages else jnp.zeros_like(mmd_mask)
        src_p, src_o = self._src_local_scan(src_p, src_o, xs, ys, gates, tgt_msg, bmask)

        # local target training (Alg. 3) on the messages that arrived —
        # per-client uplinks in the flat plane, per-edge pooled moments (one
        # backhaul uplink per edge) in the two-tier plane
        if self.exchange_messages:
            msgs = self._uplinked_msgs(src_p, x_msg, msg_mask, chan_key)
            merged, tgt_w = self._merge_msgs(msgs, mmd_mask, chan_key, probes)
            any_msg = jnp.sum(mmd_mask) > 0
            tgt_p, tgt_o = self._target_scan(
                tgt_p, tgt_o, xt_steps, merged, tgt_w, any_msg
            )

        # global aggregation (Alg. 4): W_RF over plan.w_clients + the target.
        # Frozen-W mode (seed-replay wire codec) skips it: every client's
        # W_RF is already bit-identical to the shared init.
        if self.aggregate_w_rf and not self.freeze_w_rf:
            src_p, tgt_p = self._merge_w_rf(
                src_p, tgt_p, w_mask, w_mask, chan_key, probes
            )

        # classifier aggregation every T_C rounds over plan.c_clients
        if self.aggregate_classifier:
            src_p, tgt_p = self._merge_classifier(
                src_p, tgt_p, c_mask, c_mask, do_clf, chan_key, 1.0
            )

        if probes is not None:
            probes["update_norm"] = client_delta_norms(src_p, src_p0)
            probes["tgt_update_norm"] = tree_delta_norm(tgt_p, tgt_p0)
            return src_p, src_o, tgt_p, tgt_o, probes
        return src_p, src_o, tgt_p, tgt_o

    def round(self, src_p, src_o, tgt_p, tgt_o, batch, masks, chan_key=None):
        """One communication round. ``batch``/``masks`` are dicts of arrays.

        Ragged client data enters via the optional ``batch`` keys ``bmask``
        ((K, b) training-batch column validity) and ``msg_mask`` ((K, mb)
        message-batch column validity) — both None when every client
        contributes full-width batches.
        """
        if chan_key is None:
            if self.channel:
                # a fixed default key would replay the identical stochastic
                # channel noise every round and bias training
                raise ValueError("channel distortion is set: pass a per-round chan_key")
            chan_key = jax.random.PRNGKey(0)  # traced but unused: no channel
        return self._round(
            src_p,
            src_o,
            tgt_p,
            tgt_o,
            batch["xs"],
            batch["ys"],
            batch["x_msg"],
            batch["xt_steps"],
            batch["xt_msg"],
            masks["mmd"],
            masks["w"],
            masks["c"],
            masks["do_clf"],
            chan_key,
            batch.get("bmask"),
            batch.get("msg_mask"),
        )

    # -- async buffered flush (fedsim.AsyncScheduler's data plane) ----------

    @staticmethod
    def _select_clients(mask, new, old):
        """Leafwise per-client where: row k of ``new`` iff mask[k] > 0."""
        return jax.tree_util.tree_map(
            lambda a, b: jnp.where(mask.reshape((-1,) + (1,) * (a.ndim - 1)) > 0, a, b),
            new,
            old,
        )

    def _flush_fn(
        self,
        src_p,
        src_o,
        tgt_p,
        tgt_o,
        xs,  # (L, K, p, b) dispatch-time source batches (rows outside the buffer are dummies)
        ys,  # (L, K, b)
        x_msg,  # (K, p, mb) dispatch-time source message batches
        xt_steps,  # (L, p, b) target training batches drawn at this flush
        tgt_msgs,  # (K, 2N) the target broadcast each client received at ITS dispatch
        buf_mask,  # (K,) 1.0 iff this client's update is consumed by this flush
        weights,  # (K,) staleness weights of the buffered updates (1.0 at staleness 0)
        do_clf,  # () bool: classifier-merge flush (every T_C-th flush)
        chan_key,  # per-flush PRNG key for stochastic uplink channel distortion
        bmask,  # (K, b) ragged training-batch validity | None
        msg_mask,  # (K, mb) ragged message-batch validity | None
    ):
        """One FedBuff-style buffered aggregation, as a single compiled program.

        The async semantics relative to ``_round_fn``: only the clients whose
        updates sit in the buffer materialize local steps (the others are
        mid-flight or offline — their rows are computed and discarded by the
        ``buf_mask`` select); each buffered client trained against the target
        broadcast of its *own* dispatch version (``tgt_msgs`` row), and every
        merge — Sigma-ell moments into the target steps, W_RF, classifier —
        is weighted by ``buf_mask * weights``, the staleness weighting of
        ``federated.aggregation.staleness_weights``.  With a full buffer, all
        rows at staleness 0 and unit weights, every expression below reduces
        term-by-term to ``_round_fn``'s — that is the sync/async degeneracy
        the fedsim tests pin at <= 1e-6.
        """
        wsel = buf_mask * weights
        probes = {} if self.probe else None
        src_p0, tgt_p0 = (src_p, tgt_p) if self.probe else (None, None)

        # local source training at dispatch inputs; keep only buffered rows
        gates = buf_mask if self.exchange_messages else jnp.zeros_like(buf_mask)
        new_p, new_o = self._src_local_scan(src_p, src_o, xs, ys, gates, tgt_msgs, bmask)
        src_p = self._select_clients(buf_mask, new_p, src_p)
        src_o = self._select_clients(buf_mask, new_o, src_o)

        # target trains on the buffered Sigma-ell moments, staleness-weighted
        # (per-edge pooled in the two-tier plane, like the sync round)
        if self.exchange_messages:
            msgs = self._uplinked_msgs(src_p, x_msg, msg_mask, chan_key)
            merged, tgt_w = self._merge_msgs(msgs, wsel, chan_key, probes)
            any_msg = jnp.sum(buf_mask) > 0
            tgt_p, tgt_o = self._target_scan(
                tgt_p, tgt_o, xt_steps, merged, tgt_w, any_msg
            )

        # staleness-weighted W_RF merge over the buffer + the server copy
        if self.aggregate_w_rf and not self.freeze_w_rf:
            src_p, tgt_p = self._merge_w_rf(
                src_p, tgt_p, buf_mask, wsel, chan_key, probes
            )

        # staleness-weighted classifier merge on T_C-interval flushes
        if self.aggregate_classifier:
            src_p, tgt_p = self._merge_classifier(
                src_p, tgt_p, buf_mask, wsel, do_clf, chan_key, 1e-9
            )

        if probes is not None:
            probes["update_norm"] = client_delta_norms(src_p, src_p0)
            probes["tgt_update_norm"] = tree_delta_norm(tgt_p, tgt_p0)
            return src_p, src_o, tgt_p, tgt_o, probes
        return src_p, src_o, tgt_p, tgt_o

    def flush(self, src_p, src_o, tgt_p, tgt_o, batch, masks, chan_key=None):
        """One buffered aggregation (async plane).  ``batch`` carries the
        dispatch-time draws (``xs``/``ys``/``x_msg``), the flush-time target
        batches (``xt_steps``), the per-client dispatch broadcasts
        (``tgt_msgs`` (K, 2N)), and the ragged masks; ``masks`` carries
        ``buf``/``weights``/``do_clf``."""
        if chan_key is None:
            if self.channel:
                raise ValueError("channel distortion is set: pass a per-flush chan_key")
            chan_key = jax.random.PRNGKey(0)  # traced but unused: no channel
        return self._flush(
            src_p,
            src_o,
            tgt_p,
            tgt_o,
            batch["xs"],
            batch["ys"],
            batch["x_msg"],
            batch["xt_steps"],
            batch["tgt_msgs"],
            masks["buf"],
            masks["weights"],
            masks["do_clf"],
            chan_key,
            batch.get("bmask"),
            batch.get("msg_mask"),
        )

    # -- warm-up (emulated pretraining, FedAvg over sources) -----------------

    def _warmup_fn(self, src_p, src_o, xs, ys, bmask):
        """Scan over R warm-up rounds: local CE steps then whole-model FedAvg.

        xs: (R, L, K, p, b), ys: (R, L, K, b); ``bmask`` ((K, b) or None)
        marks ragged clients' true batch columns.  Replaces R*K*L Python-loop
        dispatches with a single compiled program.
        """
        zeros = jnp.zeros((self.cfg.n_rff * 2,))

        def round_body(carry, inp):
            ps, os = carry
            x_r, y_r = inp
            ps, os = self._src_local_scan(
                ps, os, x_r, y_r, jnp.zeros((x_r.shape[1],)), zeros, bmask
            )
            avg = jax.tree_util.tree_map(lambda t: jnp.mean(t, axis=0, keepdims=True), ps)
            ps = jax.tree_util.tree_map(
                lambda a, t: jnp.broadcast_to(a, t.shape), avg, ps
            )
            return (ps, os), None

        (src_p, src_o), _ = jax.lax.scan(round_body, (src_p, src_o), (xs, ys))
        return src_p, src_o

    def warmup(self, src_p, src_o, xs, ys, bmask=None):
        return self._warmup(src_p, src_o, xs, ys, bmask)
