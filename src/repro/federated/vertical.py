"""Vertical-FL extension of FedRF-TCA (paper §VI: "By leveraging the block
matrix structure inherent in the random feature maps in Definition 2,
FedRF-TCA can be readily extended to vertical FL").

Vertical setting: K parties hold DISJOINT FEATURE BLOCKS of the same samples
(x = [x^(1); ...; x^(K)], party c holds x^(c) in R^{p_c x n}). The RFF phase
matrix decomposes over blocks:

    Omega x = sum_c Omega^(c) x^(c),     Omega = [Omega^(1) | ... | Omega^(K)],

so each party computes its partial phases Z_c = Omega^(c) X^(c) in R^{N x n}
locally (from the shared seed) and only the partial-phase SUM crosses the
network — never raw features, and the nonlinearity cos/sin is applied after
aggregation, which keeps the inversion problem underdetermined exactly as in
Remark 2. On the production mesh the sum is one all-reduce over the party
axis.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.rff import draw_omega


def split_omega(omega: jnp.ndarray, dims: list[int]) -> list[jnp.ndarray]:
    """Column-partition Omega (N, p) into per-party blocks (N, p_c)."""
    if sum(dims) != omega.shape[1]:
        raise ValueError(f"dims {dims} must sum to p={omega.shape[1]}")
    out, start = [], 0
    for d in dims:
        out.append(omega[:, start : start + d])
        start += d
    return out


def partial_phases(omega_block: jnp.ndarray, x_block: jnp.ndarray) -> jnp.ndarray:
    """Party-local computation: Z_c = Omega^(c) X^(c) in R^{N x n}."""
    return omega_block @ x_block


def assemble_rff(partials: list[jnp.ndarray]) -> jnp.ndarray:
    """Server-side: Sigma = [cos(sum Z_c); sin(sum Z_c)]/sqrt(N)."""
    z = sum(partials)
    n_features = z.shape[0]
    return jnp.concatenate([jnp.cos(z), jnp.sin(z)], axis=0) / jnp.sqrt(n_features)


def vertical_rff(
    x_blocks: list[jnp.ndarray], *, seed: int, n_features: int, sigma: float = 1.0
) -> jnp.ndarray:
    """End-to-end vertical RFF: K parties with feature blocks -> Sigma (2N, n).

    Equivalent to the centralized rff_features on the concatenated features
    (tested); communication per party is the (N, n) partial phase matrix —
    independent of p_c and non-invertible w.r.t. x^(c) once summed.
    """
    dims = [xb.shape[0] for xb in x_blocks]
    omega = draw_omega(seed, n_features, sum(dims), sigma=sigma)
    blocks = split_omega(omega, dims)
    partials = [partial_phases(ob, xb) for ob, xb in zip(blocks, x_blocks)]
    return assemble_rff(partials)
