"""Unreliable-network simulation: client sampling and nested message-drop sets.

Paper Section IV-B: at round t the participating set S_t is drawn by first
sampling |S_t| ~ Unif{0, .., K} then sampling that many clients without
replacement. Table III's drop settings use nested random subsets
A ⊇ B ⊇ C: messages Sigma*ell flow for i in A, W_RF for j in B, classifiers
for k in C — settings (I) A/A/A, (II) A/A/B, (III) A/B/C.

This module is the primitive layer; ``repro.comm.netsim`` generalizes it into
pluggable scenarios (Bernoulli channels, latency/bandwidth links with
straggler deadlines, deterministic replayable traces — Table III's settings
become traces via ``comm.table3_trace``), all emitting the same
:class:`RoundPlan` that both round engines consume.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class RoundPlan:
    msg_clients: list[int]  # A: who successfully delivers Sigma ell
    w_clients: list[int]  # B ⊆ A: whose W_RF reaches the server
    c_clients: list[int]  # C ⊆ B: whose classifier reaches the server


def sample_participants(rng: np.random.Generator, n_clients: int) -> list[int]:
    """S_t per Section IV-B: |S_t| ~ Unif{0..K}, then subset w/o replacement."""
    size = int(rng.integers(0, n_clients + 1))
    return sorted(rng.choice(n_clients, size=size, replace=False).tolist())


def _subset(rng: np.random.Generator, ids: list[int]) -> list[int]:
    if not ids:
        return []
    size = int(rng.integers(0, len(ids) + 1))
    return sorted(rng.choice(ids, size=size, replace=False).tolist())


def plan_round(rng: np.random.Generator, n_clients: int, setting: str = "I") -> RoundPlan:
    """Drop setting (I): A/A/A, (II): A/A/B, (III): A/B/C (Table III)."""
    a = sample_participants(rng, n_clients)
    if setting == "I":
        return RoundPlan(a, a, a)
    if setting == "II":
        return RoundPlan(a, a, _subset(rng, a))
    if setting == "III":
        b = _subset(rng, a)
        return RoundPlan(a, b, _subset(rng, b))
    raise ValueError(f"unknown drop setting {setting!r}")


@dataclass
class LossyChannel:
    """Bernoulli message-drop channel for the asynchronous ablations (App. D)."""

    drop_prob: float = 0.0
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def deliver(self, payload):
        """Returns payload or None if the message is lost."""
        if self._rng.random() < self.drop_prob:
            return None
        return payload
