"""Global parameter aggregation (paper Algorithm 4) + one-shot hard voting (App. D).

Staleness-aware buffered aggregation (the fedsim async runtime): the server
merges a *buffer* of client updates, each tagged with how many server model
versions elapsed since its dispatch.  :func:`staleness_weights` turns those
tags into merge weights — ``constant`` (FedBuff's unweighted mean),
``polynomial`` (the standard ``(1+s)^-a`` staleness discount), and ``auto``
(sample-count-proportional importance in the spirit of auto-weighted FDA
aggregation, discounted polynomially by staleness).  The weighted merges
themselves run in-graph — ``BatchedRoundEngine._flush_fn`` applies the
weights to the Sigma-ell moment, W_RF, and classifier merges.

Two-tier (fleet) aggregation: every merge above is a weighted sum over
clients, so it splits associatively across an edge tier.
:func:`edge_weighted_sums` is the grouped-sum primitive both the sync round
and the async flush route through when a ``repro.fleet.Topology`` is
configured — the Pallas segment-reduce kernel on TPU, its XLA twin (the same
membership-matrix contraction) elsewhere.

Robust aggregation: every merge here is a weighted sum, and *which* weighted
sum is now pluggable — :class:`repro.robust.rules.AggregationRule` (norm-clip,
coordinate trimmed-mean, geometric-median, finite-guard quarantine) slots into
the same in-graph merge points via ``ProtocolConfig.rule``.  The rule types
are re-exported here so aggregation stays the one import site for merge
policy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.robust.rules import (  # noqa: F401  (re-export seam)
    AggregationRule,
    FiniteMeanRule,
    GeoMedianRule,
    MeanRule,
    NormClipRule,
    TrimmedMeanRule,
    get_rule,
    rule_names,
)
from repro.utils.tree import tree_mean, tree_weighted_mean

STALENESS_MODES = ("constant", "polynomial", "auto")


def staleness_weights(
    staleness,
    mode: str = "constant",
    *,
    n_samples=None,
    alpha: float = 0.5,
) -> np.ndarray:
    """Merge weights for a buffer of updates with integer ``staleness`` tags.

    ``staleness[k]`` counts server model versions between update k's dispatch
    and its consumption (0 = trained on the current model).  Modes:

    - ``constant``            w_k = 1                      (FedBuff mean)
    - ``polynomial[:alpha]``  w_k = (1 + s_k)^-alpha       (staleness discount)
    - ``auto``                w_k = n_k * (1 + s_k)^-alpha (importance x freshness;
                              n_k from ``n_samples``, uniform when omitted)

    Weights are returned unnormalized (consumers divide by their own mass so
    a weight composes with 0/1 buffer masks); all modes reduce to the uniform
    weight 1.0 at staleness 0 with uniform ``n_samples``, which is what makes
    a no-churn uniform-latency async run degenerate to the sync engine.
    """
    s = np.asarray(staleness, dtype=np.float64)
    if (s < 0).any():
        raise ValueError(f"negative staleness: {s}")
    base = mode.split(":", 1)[0]
    if base not in STALENESS_MODES:
        raise ValueError(f"unknown staleness mode {mode!r} (want {STALENESS_MODES})")
    if ":" in mode:
        alpha = float(mode.split(":", 1)[1])
    if base == "constant":
        w = np.ones_like(s)
    else:
        w = (1.0 + s) ** (-alpha)
        if base == "auto":
            n = np.ones_like(s) if n_samples is None else np.asarray(n_samples, np.float64)
            w = w * (n / n.mean())
    return w.astype(np.float32)


def edge_weighted_sums(
    values: jnp.ndarray,  # (K, D) stacked client payloads
    seg_ids: jnp.ndarray,  # (K,) int edge id per client
    weights: jnp.ndarray,  # (K,) merge weights (masks x staleness)
    n_edges: int,
) -> jnp.ndarray:
    """Grouped weighted sums ``out[e] = sum_{k: seg[k]=e} w_k * values[k]``.

    The associative partial-merge primitive of the two-tier fleet plane
    (jit-traceable; ``n_edges`` static).  On TPU it lowers to the fused
    Pallas segment-reduce kernel (``kernels.ops.segment_reduce``); elsewhere
    it runs the kernel's XLA twin — the identical weighted-membership
    contraction, so both backends share one reduction order.
    """
    if jax.default_backend() == "tpu":
        from repro.kernels import ops

        return ops.segment_reduce(values, seg_ids, weights, n_segments=n_edges)
    from repro.kernels import ref

    return ref.segment_reduce_ref(values, seg_ids, weights, n_edges)


def fedavg_w_rf(source_params: list, target_params, participating: list[int]):
    """Average W_RF over the participating sources + the target (Alg. 4 line 3),
    assign back to everyone in S_t and the target (Alg. 5 line 15)."""
    members = [source_params[i]["w_rf"] for i in participating] + [target_params["w_rf"]]
    return tree_mean(members)


def fedavg_classifier(source_params: list, participating: list[int]):
    """Average classifiers over S_t (Alg. 4 line 5) — only every T_C rounds."""
    if not participating:
        return None
    return tree_mean([source_params[i]["classifier"] for i in participating])


def fedavg_models(param_list: list, weights=None):
    """Plain FedAvg over whole models (the paper's FedAvg baseline, Table II)."""
    if weights is None:
        return tree_mean(param_list)
    return tree_weighted_mean(param_list, weights)


def hard_vote(per_source_logits: np.ndarray) -> np.ndarray:
    """One-shot hard voting over K source classifiers (App. D, settings IV/V).

    per_source_logits: (K, n, classes) -> (n,) majority-vote predictions,
    ties broken by summed logits.
    """
    preds = np.argmax(per_source_logits, axis=-1)  # (K, n)
    k, n = preds.shape
    n_classes = per_source_logits.shape[-1]
    votes = np.zeros((n, n_classes), dtype=np.int64)
    for i in range(k):
        votes[np.arange(n), preds[i]] += 1
    best = votes.max(axis=1, keepdims=True)
    tie = (votes == best).sum(axis=1) > 1
    out = votes.argmax(axis=1)
    if tie.any():
        summed = per_source_logits.sum(axis=0)  # (n, classes)
        masked = np.where(votes == best, summed, -np.inf)
        out = np.where(tie, masked.argmax(axis=1), out)
    return out
