"""Global parameter aggregation (paper Algorithm 4) + one-shot hard voting (App. D)."""
from __future__ import annotations

import numpy as np

from repro.utils.tree import tree_mean, tree_weighted_mean


def fedavg_w_rf(source_params: list, target_params, participating: list[int]):
    """Average W_RF over the participating sources + the target (Alg. 4 line 3),
    assign back to everyone in S_t and the target (Alg. 5 line 15)."""
    members = [source_params[i]["w_rf"] for i in participating] + [target_params["w_rf"]]
    return tree_mean(members)


def fedavg_classifier(source_params: list, participating: list[int]):
    """Average classifiers over S_t (Alg. 4 line 5) — only every T_C rounds."""
    if not participating:
        return None
    return tree_mean([source_params[i]["classifier"] for i in participating])


def fedavg_models(param_list: list, weights=None):
    """Plain FedAvg over whole models (the paper's FedAvg baseline, Table II)."""
    if weights is None:
        return tree_mean(param_list)
    return tree_weighted_mean(param_list, weights)


def hard_vote(per_source_logits: np.ndarray) -> np.ndarray:
    """One-shot hard voting over K source classifiers (App. D, settings IV/V).

    per_source_logits: (K, n, classes) -> (n,) majority-vote predictions,
    ties broken by summed logits.
    """
    preds = np.argmax(per_source_logits, axis=-1)  # (K, n)
    k, n = preds.shape
    n_classes = per_source_logits.shape[-1]
    votes = np.zeros((n, n_classes), dtype=np.int64)
    for i in range(k):
        votes[np.arange(n), preds[i]] += 1
    best = votes.max(axis=1, keepdims=True)
    tie = (votes == best).sum(axis=1) > 1
    out = votes.argmax(axis=1)
    if tie.any():
        summed = per_source_logits.sum(axis=0)  # (n, classes)
        masked = np.where(votes == best, summed, -np.inf)
        out = np.where(tie, masked.argmax(axis=1), out)
    return out
