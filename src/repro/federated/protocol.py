"""FedRF-TCA training protocol (paper Algorithm 5).

Host-side simulator of the full multi-client system: K source clients + 1
target client, per-round client sampling S_t, the three message-drop settings
of Table III, T_C-interval classifier aggregation, communication accounting,
and the one-shot hard-voting variant of Appendix D.

The per-client local updates are jit-compiled pure functions from
``repro.federated.model``; the protocol (who talks to whom, what gets dropped)
is deliberately host-side Python — that is the part XLA cannot express and the
paper's robustness claims are about.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.domains import Domain, batches
from repro.federated import aggregation, network
from repro.federated.model import (
    ClientConfig,
    accuracy,
    client_message,
    init_params,
    logits_of,
    make_omega,
    rff_of,
    source_loss,
    target_loss,
)
from repro.optim import adam, apply_updates


@dataclass
class ProtocolConfig:
    n_rounds: int = 200
    t_c: int = 50  # classifier aggregation interval T_C
    local_steps: int = 1
    batch_size: int = 64
    message_batch_size: int = 256  # messages are cheap (2N floats): use more data
    lr: float = 1e-2
    drop_setting: str = "I"  # Table III: "I" | "II" | "III"
    aggregate_w_rf: bool = True
    aggregate_classifier: bool = True  # False => one-shot hard voting at eval
    exchange_messages: bool = True  # False => ablation "without Sigma ell" (Fig. 5)
    # The paper fine-tunes a *pretrained* extractor (ResNet-50). Offline we
    # emulate pretraining with a FedAvg warm-up phase over the source clients
    # (CE only, whole-model aggregation) before the adaptation phase starts.
    warmup_rounds: int = 100
    seed: int = 0


@dataclass
class CommLog:
    """Uploaded floats, by payload type (Table I / II accounting)."""

    data_messages: int = 0  # Sigma ell vectors
    w_rf: int = 0
    classifier: int = 0
    rounds: int = 0
    history: list = field(default_factory=list)

    @property
    def total(self) -> int:
        return self.data_messages + self.w_rf + self.classifier


class FedRFTCATrainer:
    def __init__(
        self,
        sources: list[Domain],
        target: Domain,
        cfg: ClientConfig,
        proto: ProtocolConfig,
    ):
        self.sources, self.target = sources, target
        self.cfg, self.proto = cfg, proto
        self.k = len(sources)
        self.omega = make_omega(cfg)
        # Paper Fig. 1: every client fine-tunes the SAME pretrained extractor,
        # so all clients share one initialisation (they diverge during training).
        key = jax.random.PRNGKey(proto.seed)
        shared = init_params(cfg, key)
        self.src_params = [jax.tree_util.tree_map(jnp.copy, shared) for _ in range(self.k)]
        self.tgt_params = jax.tree_util.tree_map(jnp.copy, shared)
        self.opt = adam(proto.lr)
        self.src_opt = [self.opt.init(p) for p in self.src_params]
        self.tgt_opt = self.opt.init(self.tgt_params)
        self.rng = np.random.default_rng(proto.seed)
        self.src_iters = [
            batches(d.x, d.y, proto.batch_size, seed=proto.seed + i)
            for i, d in enumerate(sources)
        ]
        self.tgt_iter = batches(target.x, target.y, proto.batch_size, seed=proto.seed + 777)
        self.comm = CommLog()
        self._build_steps()
        self._msg_iters = [
            batches(d.x, d.y, min(proto.message_batch_size, d.x.shape[1]), seed=proto.seed + 500 + i)
            for i, d in enumerate(sources)
        ]
        self._tgt_msg_iter = batches(
            target.x, target.y, min(proto.message_batch_size, target.x.shape[1]), seed=proto.seed + 999
        )
        if proto.warmup_rounds:
            self._warmup(proto.warmup_rounds)

    def _warmup(self, rounds: int) -> None:
        """Emulated pretraining: FedAvg (CE only, whole model) over sources."""
        for _ in range(rounds):
            for i in range(self.k):
                for _ in range(self.proto.local_steps):
                    x, y = next(self.src_iters[i])
                    self.src_params[i], self.src_opt[i], _ = self._src_step_plain(
                        self.src_params[i], self.src_opt[i], jnp.asarray(x), jnp.asarray(y)
                    )
            avg = aggregation.fedavg_models(self.src_params)
            self.src_params = [jax.tree_util.tree_map(jnp.copy, avg) for _ in range(self.k)]
        self.tgt_params = jax.tree_util.tree_map(jnp.copy, avg)

    # ---- jitted local updates ------------------------------------------------
    def _build_steps(self):
        cfg, omega = self.cfg, self.omega

        @jax.jit
        def src_step_mmd(params, opt_state, x, y, tgt_msg):
            (loss, aux), grads = jax.value_and_grad(
                lambda p: source_loss(p, omega, x, y, tgt_msg, cfg, with_mmd=True),
                has_aux=True,
            )(params)
            upd, opt_state = self.opt.update(grads, opt_state, params)
            return apply_updates(params, upd), opt_state, aux

        @jax.jit
        def src_step_plain(params, opt_state, x, y):
            zero = jnp.zeros((2 * cfg.n_rff,))
            (loss, aux), grads = jax.value_and_grad(
                lambda p: source_loss(p, omega, x, y, zero, cfg, with_mmd=False),
                has_aux=True,
            )(params)
            upd, opt_state = self.opt.update(grads, opt_state, params)
            return apply_updates(params, upd), opt_state, aux

        @jax.jit
        def tgt_step(params, opt_state, x, src_msgs):
            (loss, aux), grads = jax.value_and_grad(
                lambda p: target_loss(p, omega, x, src_msgs, cfg), has_aux=True
            )(params)
            upd, opt_state = self.opt.update(grads, opt_state, params)
            return apply_updates(params, upd), opt_state, aux

        @jax.jit
        def msg_of(params, x, sign):
            return client_message(params, omega, x, sign)

        self._src_step_mmd, self._src_step_plain = src_step_mmd, src_step_plain
        self._tgt_step, self._msg_of = tgt_step, msg_of

    # ---- one communication round (Alg. 5 body) -------------------------------
    def round(self, t: int) -> dict[str, Any]:
        proto, cfg = self.proto, self.cfg
        plan = network.plan_round(self.rng, self.k, proto.drop_setting)

        # target broadcasts its message to sources in S_t
        xt, _ = next(self._tgt_msg_iter)
        tgt_msg = self._msg_of(self.tgt_params, jnp.asarray(xt), -1.0)
        if proto.exchange_messages and plan.msg_clients:
            self.comm.data_messages += 2 * cfg.n_rff  # one 2N vector downlink

        # local source training (Alg. 2)
        src_msgs = {}
        for i in range(self.k):
            for _ in range(proto.local_steps):
                x, y = next(self.src_iters[i])
                x, y = jnp.asarray(x), jnp.asarray(y)
                if proto.exchange_messages and i in plan.msg_clients:
                    self.src_params[i], self.src_opt[i], aux = self._src_step_mmd(
                        self.src_params[i], self.src_opt[i], x, y, tgt_msg
                    )
                else:
                    self.src_params[i], self.src_opt[i], aux = self._src_step_plain(
                        self.src_params[i], self.src_opt[i], x, y
                    )
            if proto.exchange_messages and i in plan.msg_clients:
                xm, _ = next(self._msg_iters[i])
                src_msgs[i] = self._msg_of(self.src_params[i], jnp.asarray(xm), +1.0)
                self.comm.data_messages += 2 * cfg.n_rff

        # local target training (Alg. 3)
        if proto.exchange_messages and src_msgs:
            msgs = jnp.stack(list(src_msgs.values()))
            for _ in range(proto.local_steps):
                xt, _ = next(self.tgt_iter)
                self.tgt_params, self.tgt_opt, _ = self._tgt_step(
                    self.tgt_params, self.tgt_opt, jnp.asarray(xt), msgs
                )

        # global aggregation (Alg. 4)
        if proto.aggregate_w_rf and plan.w_clients:
            w_rf = aggregation.fedavg_w_rf(self.src_params, self.tgt_params, plan.w_clients)
            self.comm.w_rf += (len(plan.w_clients) + 1) * w_rf.size  # uplinks
            for i in plan.w_clients:
                self.src_params[i]["w_rf"] = w_rf
            self.tgt_params["w_rf"] = w_rf

        if proto.aggregate_classifier and t % proto.t_c == 0 and plan.c_clients:
            clf = aggregation.fedavg_classifier(self.src_params, plan.c_clients)
            self.comm.classifier += len(plan.c_clients) * sum(
                int(np.prod(v.shape)) for v in jax.tree_util.tree_leaves(clf)
            )
            for i in plan.c_clients:
                self.src_params[i]["classifier"] = clf
            self.tgt_params["classifier"] = clf
        self.comm.rounds += 1
        return {"plan": plan}

    def train(self, eval_every: int = 0) -> list[float]:
        accs = []
        for t in range(1, self.proto.n_rounds + 1):
            self.round(t)
            if eval_every and t % eval_every == 0:
                accs.append(self.evaluate())
        return accs

    # ---- evaluation -----------------------------------------------------------
    def evaluate(self, x=None, y=None) -> float:
        """Aggregated-classifier accuracy on target data (the UFDA objective)."""
        x = self.target.x if x is None else x
        y = self.target.y if y is None else y
        if self.proto.aggregate_classifier:
            return float(accuracy(self.tgt_params, self.omega, jnp.asarray(x), jnp.asarray(y)))
        # one-shot hard voting (App. D): each source classifier votes on the
        # target's aligned features
        aligned_params = dict(self.tgt_params)
        per_src = []
        for i in range(self.k):
            p = {
                "extractor": self.tgt_params["extractor"],
                "w_rf": self.tgt_params["w_rf"],
                "classifier": self.src_params[i]["classifier"],
            }
            per_src.append(np.asarray(logits_of(p, self.omega, jnp.asarray(x))))
        preds = aggregation.hard_vote(np.stack(per_src))
        return float(np.mean(preds == y))
