"""FedRF-TCA training protocol (paper Algorithm 5).

Host-side simulator of the full multi-client system: K source clients + 1
target client, per-round client sampling S_t, the three message-drop settings
of Table III, T_C-interval classifier aggregation, communication accounting,
and the one-shot hard-voting variant of Appendix D.

Two interchangeable data planes execute the round body:

- ``engine="serial"``  — per-client jitted local updates dispatched from a
  Python loop (K x local_steps dispatches per round).  Faithful to the
  asynchronous protocol; the original implementation.
- ``engine="batched"`` (default) — ``federated.engine.BatchedRoundEngine``:
  per-client parameters stacked on a leading K axis, local steps run under
  ``jax.vmap``/``lax.scan``, the round's drop plan enters as 0/1 masks, and
  the whole round (plus the entire warm-up phase) is ONE compiled dispatch.
  Ragged client data is supported natively: per-client batch sizes are capped
  at each client's own n_k, padded to the max width, and masked inside the
  compiled round (see ``ProtocolConfig.batch_size``) — unequal clients are
  never truncated to the min.  Identical math when every client participates
  (equal or unequal n_k); under random drops the two planes consume client
  batch streams at different offsets, so trajectories agree statistically
  rather than bitwise.

The protocol itself (who talks to whom, what gets dropped, what it costs)
stays host-side Python in both planes — that is the part XLA cannot express
and the paper's robustness claims are about.

Communication runs through ``repro.comm``:

- ``ProtocolConfig(transport="identity")`` (default) keeps the in-process
  data path byte-for-byte as before, but the :class:`repro.comm.CommLog`
  now records *exact wire bytes* per payload (analytically — tested equal to
  ``len(serialize(...))``) alongside the legacy float counts.
- ``transport="wire"`` really serializes every message under the configured
  codecs: the serial plane round-trips host-side bytes (the fidelity plane),
  the batched plane applies the codecs' jittable distortion twins in-graph.
- ``codec="seed_replay"`` enables the O(1)-byte W_RF wire: W_RF is pinned at
  the shared seed-derived init (gradients stopped, aggregation skipped, all
  clients bit-identical) and its sync costs a PRNG key instead of 2N*m
  floats.
- ``scenario=`` swaps Table III's drop settings for any ``comm.netsim``
  scenario (Bernoulli channels, latency/bandwidth links with straggler
  deadlines, replayable traces); every scenario emits the same ``RoundPlan``
  both planes already consume.
- ``codec="auto:<budget>"`` resolves to the cheapest codec whose measured
  accuracy gap (BENCH_comm.json curves) fits the budget before the transport
  is built; ``trainer.resolved_codec`` records the pick.

The ``repro.fedsim`` event-driven runtime drives this trainer on a virtual
clock: ``run_round(t, plan)`` is the synchronous scheduler's hook (plan
computed externally, e.g. intersected with a churn trace), while the
asynchronous scheduler bypasses the round loop entirely — it draws per-client
batches at dispatch time (``draw_client_dispatch`` / ``draw_target_steps`` /
``target_message``) and executes buffered flushes through the batched
engine, maintaining the per-client ``client_versions`` staleness tags.

Fleet scale (``repro.fleet``): ``ProtocolConfig(topology=...)`` routes every
merge through two-tier edge -> server aggregation — clients uplink to their
edge (tier-1, the existing codecs), each active edge ships ONE merged uplink
to the server (tier-2, ``edge_codec``), and ``ingress_bytes`` tracks the
server-ingress leg that collapses from K to E messages.
``ProtocolConfig(client_chunk=...)`` bounds the compiled round's per-client
working set (O(chunk) activations) for K in the thousands.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint import ckpt
from repro.comm import autocodec, netsim, transport as comm_transport, wire
from repro.comm.transport import CommLog  # noqa: F401  (seed-era import path)
from repro.data.domains import Domain, batches
from repro.federated import aggregation, network
from repro.federated.engine import BatchedRoundEngine, stack_trees, unstack_tree
from repro.federated.model import (
    ClientConfig,
    accuracy,
    client_message,
    init_params,
    logits_of,
    make_omega,
    source_loss,
    target_loss,
    w_rf_key,
)
from repro.obs import sentinel
from repro.optim import adam, apply_updates
from repro.robust import ByteFaultInjector, build_fault_plan, get_rule
from repro.utils.tree import tree_mean


@dataclass
class ProtocolConfig:
    n_rounds: int = 200
    t_c: int = 50  # classifier aggregation interval T_C
    local_steps: int = 1
    # ``batch_size`` / ``message_batch_size`` accept a scalar (same for every
    # client) or a length-K sequence (per-source-client, the ragged setting).
    # Either way each client's effective size is capped at its own dataset
    # size n_k — unequal clients are padded to the max inside the batched
    # engine (validity masks), never truncated to the min.  The target client
    # uses the scalar (or the max of the sequence) capped at its own n.
    batch_size: int | tuple[int, ...] = 64
    message_batch_size: int | tuple[int, ...] = 256  # messages are cheap (2N floats)
    lr: float = 1e-2
    drop_setting: str = "I"  # Table III: "I" | "II" | "III"
    aggregate_w_rf: bool = True
    aggregate_classifier: bool = True  # False => one-shot hard voting at eval
    exchange_messages: bool = True  # False => ablation "without Sigma ell" (Fig. 5)
    # The paper fine-tunes a *pretrained* extractor (ResNet-50). Offline we
    # emulate pretraining with a FedAvg warm-up phase over the source clients
    # (CE only, whole-model aggregation) before the adaptation phase starts.
    warmup_rounds: int = 100
    engine: str = "batched"  # "batched" (vmap/scan round engine) | "serial"
    # -- communication (repro.comm) -----------------------------------------
    transport: str = "identity"  # "identity" | "wire" (real serialize/parse)
    codec: str = "float32"  # default payload codec; "seed_replay" = O(1) W_RF
    codec_moments: str | None = None  # per-kind overrides of ``codec``
    codec_w_rf: str | None = None
    codec_classifier: str | None = None
    scenario: Any = None  # comm.netsim.Scenario; None -> TableIII(drop_setting)
    # -- fleet scale (repro.fleet) -------------------------------------------
    # ``topology`` (a fleet.Topology) turns on two-tier edge -> server
    # aggregation: every merge routes through per-edge partial sums, the
    # server ingests ONE uplink per active edge per payload kind (at the
    # tier-2 ``edge_codec``, default: same as ``codec``), and the fedsim
    # AsyncScheduler flushes per-edge buffers.  Batched engine only.
    topology: Any = None
    edge_codec: str | None = None
    # ``client_chunk`` bounds the local-step working set: the per-client vmap
    # runs chunk rows at a time (O(chunk) live activations instead of O(K));
    # bitwise-equal to the unchunked program.
    client_chunk: int | None = None
    # -- robustness (repro.robust) -------------------------------------------
    # ``rule``: aggregation rule spec — "mean" | "finite_mean" |
    # "norm_clip[:c]" | "trimmed_mean[:b]" | "geomedian[:iters]" or an
    # AggregationRule instance — owning every weighted merge in the batched
    # engine (in-graph).  "mean" is bit-for-bit the seed pipeline; robust
    # rules need the batched engine.
    rule: Any = "mean"
    # ``faults``: a repro.robust.FaultConfig.  Batched plane: in-graph
    # value-level payload corruption + Byzantine crafted uplinks (what robust
    # rules defend).  Serial wire plane: byte-level frame corruption — the
    # CRC32 envelope checksum rejects each corrupted frame (typed
    # WireDecodeError, never a crash), retransmits, and reports give-up as a
    # drop.  None (or an all-zero config) compiles the exact fault-free
    # program, bit-for-bit.
    faults: Any = None
    # -- observability (repro.obs) -------------------------------------------
    # ``probe``: in-graph health probes — the batched planes additionally
    # return moment mass, per-client update norms and the rule's per-client
    # trim/quarantine attribution, collected host-side after each dispatch
    # (``trainer.last_probes``) and emitted into the active metrics registry.
    # Adds outputs to the compiled planes, never dispatches: round/flush stay
    # one compiled call each, and the parameter trajectory is bitwise
    # identical either way (test-gated).
    probe: bool = False
    seed: int = 0


def _per_client_sizes(
    value: int | tuple[int, ...], k: int, caps: list[int], what: str
) -> list[int]:
    """Resolve a scalar-or-per-client batch-size config field to K concrete
    sizes, each capped at the client's own dataset size."""
    if isinstance(value, int):
        sizes = [value] * k
    else:
        sizes = [int(s) for s in value]
        if len(sizes) != k:
            raise ValueError(f"{what} has {len(sizes)} entries for {k} clients")
    if any(s <= 0 for s in sizes):
        raise ValueError(f"{what} entries must be positive, got {sizes}")
    return [min(s, c) for s, c in zip(sizes, caps)]


def _cycle_pad(x: np.ndarray, y: np.ndarray | None, width: int):
    """Pad a (p, b_k) batch to ``width`` columns by cycling its own samples.

    Padding with zeros would feed all-zero columns through the extractor,
    whose unit-norm layer has a NaN gradient at exactly 0 — and ``0 * NaN``
    poisons the masked loss.  Cycled real samples keep every gradient finite;
    their loss/moment contributions are excluded by the validity mask."""
    idx = np.arange(width) % x.shape[1]
    return x[:, idx], (None if y is None else y[idx])


def _ragged_mask(sizes: list[int], width: int) -> jnp.ndarray | None:
    """(K, width) 0/1 validity mask, or None when every client is full-width
    (the unpadded batched path stays bitwise-identical to the seed)."""
    if not sizes or all(s == width for s in sizes):
        return None
    m = np.zeros((len(sizes), width), np.float32)
    for i, s in enumerate(sizes):
        m[i, :s] = 1.0
    return jnp.asarray(m)


class FedRFTCATrainer:
    def __init__(
        self,
        sources: list[Domain],
        target: Domain,
        cfg: ClientConfig,
        proto: ProtocolConfig,
    ):
        if proto.engine not in ("serial", "batched"):
            raise ValueError(f"unknown engine {proto.engine!r}")
        # nothing to stack/vmap with zero sources — the serial plane handles
        # K=0 (all loops degenerate) while stack_trees([]) cannot
        engine = proto.engine if sources else "serial"
        self.sources, self.target = sources, target
        self.cfg, self.proto = cfg, proto
        self.k = len(sources)
        self.rule = get_rule(proto.rule)
        self._fault_plan = build_fault_plan(proto.faults, self.k)
        if engine != "batched":
            if not self.rule.is_mean:
                raise ValueError(
                    f"rule={self.rule.name!r} runs in-graph and needs the "
                    "batched engine"
                )
            if self._fault_plan is not None and proto.transport != "wire":
                raise ValueError(
                    "serial fault injection corrupts real frames and needs "
                    "transport='wire'; value-level faults need the batched engine"
                )
        self.topology = proto.topology
        if self.topology is not None:
            if engine != "batched":
                raise ValueError("fleet topology needs the batched engine")
            if self.topology.n_clients != self.k:
                raise ValueError(
                    f"topology covers {self.topology.n_clients} clients, "
                    f"trainer has {self.k}"
                )
        self.omega = make_omega(cfg)
        # codec="auto:<budget>" resolves against the measured BENCH_comm.json
        # accuracy-vs-codec curves: cheapest codec whose accuracy gap fits
        codec = proto.codec
        if isinstance(codec, str) and codec.startswith("auto:"):
            codec = autocodec.resolve(codec)
        self.resolved_codec = codec
        self.transport = comm_transport.build_transport(
            proto.transport,
            codec,
            seed=proto.seed,
            codec_moments=proto.codec_moments,
            codec_w_rf=proto.codec_w_rf,
            codec_classifier=proto.codec_classifier,
        )
        if self._fault_plan is not None and engine != "batched":
            # serial wire plane: faults are byte corruption on real frames,
            # defended by the CRC32 checksum + retransmit path
            self.transport.fault_injector = ByteFaultInjector.from_config(proto.faults)
        self.scenario = proto.scenario or netsim.TableIIIScenario(proto.drop_setting)
        self._frozen_w = self.transport.frozen_w
        # exact wire shapes of the three payload kinds (for analytic accounting
        # and for byte-aware scenarios like netsim.LinkScenario)
        f32 = np.dtype(np.float32)
        self._specs = {
            "moments": {"msg": ((2 * cfg.n_rff,), f32)},
            "w_rf": {"w_rf": ((2 * cfg.n_rff, cfg.m), f32)},
            "classifier": {
                "w": ((cfg.m, cfg.n_classes), f32),
                "b": ((cfg.n_classes,), f32),
            },
        }
        # Two-tier wire: the tier-2 (edge -> server) transport carries ONE
        # merged uplink per active edge per payload kind — the partial merge
        # plus the weight mass it reports — under its own ``edge_codec``.
        # ``ingress_bytes`` tracks the server-ingress leg on both planes (the
        # quantity the two-tier split shrinks from K to E messages).
        if self.topology is not None:
            edge_codec = proto.edge_codec or codec
            if edge_codec == "seed_replay" and codec != "seed_replay":
                raise ValueError(
                    "edge_codec='seed_replay' requires the frozen-W protocol "
                    "(codec='seed_replay')"
                )
            self.edge_transport = comm_transport.build_transport(
                proto.transport, edge_codec, seed=proto.seed ^ 0x0ED6E
            )
            self._edge_specs = {
                kind: {**spec, "mass": ((1,), f32)}
                for kind, spec in self._specs.items()
            }
        else:
            self.edge_transport, self._edge_specs = None, None
        self.ingress_bytes = {"moments": 0, "w_rf": 0, "classifier": 0}
        # Paper Fig. 1: every client fine-tunes the SAME pretrained extractor,
        # so all clients share one initialisation (they diverge during training).
        key = jax.random.PRNGKey(proto.seed)
        shared = init_params(cfg, key)
        # the W_RF init subkey IS the seed-replay wire payload
        self._w_key_data = np.asarray(jax.random.key_data(w_rf_key(cfg, key)))
        self._w_init = shared["w_rf"]
        self._chan_base = jax.random.PRNGKey(proto.seed ^ 0x5EED)
        self._tgt_msg_fn = None  # lazily jitted by target_message (async plane)
        src_params = [jax.tree_util.tree_map(jnp.copy, shared) for _ in range(self.k)]
        self.tgt_params = jax.tree_util.tree_map(jnp.copy, shared)
        self.opt = adam(proto.lr)
        self.tgt_opt = self.opt.init(self.tgt_params)
        self.rng = np.random.default_rng(proto.seed)
        # per-client model-version tags: the server model version each client
        # last synced from.  The sync plane bumps them in ``run_round``; the
        # fedsim AsyncScheduler bumps them per buffered flush, and their lag
        # behind ``model_version`` is exactly the staleness that weights the
        # buffered merges.
        self.model_version = 0
        self.client_versions = np.zeros(self.k, dtype=np.int64)
        # latest in-graph health probes (host numpy), set per round/flush
        # when ``proto.probe`` is on (see repro.obs.probes).  Emission is
        # pipelined one step deep: round t's probes are materialized after
        # round t+1 has been dispatched, so the device->host sync never sits
        # between two compiled dispatches (reading ``last_probes`` or
        # finishing a run drains the pipeline).
        self._last_probes: dict | None = None
        self._pending_probes: tuple[str, dict] | None = None
        # Ragged client data: per-client batch sizes capped at each client's
        # own n_k.  The serial plane consumes them directly; the batched plane
        # pads every client to the max width and masks the padding (the seed
        # engine instead truncated all message batches to the min — dropping
        # data exactly for the heterogeneous clients federated DA is about).
        client_ns = [d.x.shape[1] for d in sources]
        self._batch_sizes = _per_client_sizes(
            proto.batch_size, self.k, client_ns, "batch_size"
        )
        self._msg_sizes = _per_client_sizes(
            proto.message_batch_size, self.k, client_ns, "message_batch_size"
        )
        tgt_b = proto.batch_size if isinstance(proto.batch_size, int) else max(proto.batch_size)
        tgt_mb = (
            proto.message_batch_size
            if isinstance(proto.message_batch_size, int)
            else max(proto.message_batch_size)
        )
        self.src_iters = [
            batches(d.x, d.y, self._batch_sizes[i], seed=proto.seed + i)
            for i, d in enumerate(sources)
        ]
        self.tgt_iter = batches(
            target.x, target.y, min(tgt_b, target.x.shape[1]), seed=proto.seed + 777
        )
        self.comm = self.transport.log
        self._msg_iters = [
            batches(d.x, d.y, self._msg_sizes[i], seed=proto.seed + 500 + i)
            for i, d in enumerate(sources)
        ]
        self._tgt_msg_iter = batches(
            target.x, target.y, min(tgt_mb, target.x.shape[1]), seed=proto.seed + 999,
        )
        # pad-to-max widths + 0/1 validity masks for the batched plane (None
        # when all clients are full-width: keeps the unpadded path bitwise)
        self._b_max = max(self._batch_sizes, default=0)
        self._mb_max = max(self._msg_sizes, default=0)
        self._bmask = _ragged_mask(self._batch_sizes, self._b_max)
        self._msg_mask = _ragged_mask(self._msg_sizes, self._mb_max)
        if engine == "batched":
            self._engine = BatchedRoundEngine(
                cfg,
                self.opt,
                self.omega,
                exchange_messages=proto.exchange_messages,
                aggregate_w_rf=proto.aggregate_w_rf,
                aggregate_classifier=proto.aggregate_classifier,
                freeze_w_rf=self._frozen_w,
                channel=self.transport.channel_fns(),
                topology=self.topology,
                edge_channel=(
                    self.edge_transport.channel_fns() if self.edge_transport else None
                ),
                client_chunk=proto.client_chunk,
                rule=self.rule,
                faults=self._fault_plan,
                probe=proto.probe,
            )
            self._src_stack = stack_trees(src_params)
            self._src_opt_stack = jax.vmap(self.opt.init)(self._src_stack)
            self.src_params, self.src_opt = None, None
        else:
            self._engine = None
            self.src_params = src_params
            self.src_opt = [self.opt.init(p) for p in src_params]
            self._build_steps()
        if proto.warmup_rounds:
            self._warmup(proto.warmup_rounds)
        if self._frozen_w:
            self._pin_w_rf()

    def _pin_w_rf(self) -> None:
        """Frozen-W invariant: every client's W_RF is bit-identical to the
        shared seed-derived init (warm-up FedAvg of K identical matrices can
        drift by an ulp for non-power-of-two K — pin it back exactly)."""
        if self._engine is not None:
            self._src_stack["w_rf"] = jnp.broadcast_to(
                self._w_init, self._src_stack["w_rf"].shape
            )
        else:
            for p in self.src_params:
                p["w_rf"] = self._w_init
        self.tgt_params["w_rf"] = self._w_init

    # ---- views over the per-client state (both engines) ----------------------
    def _src_param(self, i: int):
        if self._engine is not None:
            return unstack_tree(self._src_stack, i)
        return self.src_params[i]

    # ---- warm-up (emulated pretraining: FedAvg, CE only, whole model) --------
    def _warmup(self, rounds: int) -> None:
        if rounds <= 0 or self.k == 0:
            return  # nothing to average — leave the shared init untouched
        proto = self.proto
        if self._engine is not None:
            xs, ys = self._draw_source_batches(rounds)
            self._src_stack, self._src_opt_stack = self._engine.warmup(
                self._src_stack, self._src_opt_stack, xs, ys, self._bmask
            )
            # after the final FedAvg broadcast every row is the average; the
            # target starts from that shared pretrained model (paper Fig. 1)
            self.tgt_params = jax.tree_util.tree_map(jnp.copy, unstack_tree(self._src_stack, 0))
            return
        avg = None
        for _ in range(rounds):
            for i in range(self.k):
                for _ in range(proto.local_steps):
                    x, y = next(self.src_iters[i])
                    self.src_params[i], self.src_opt[i], _ = self._src_step_plain(
                        self.src_params[i], self.src_opt[i], jnp.asarray(x), jnp.asarray(y)
                    )
            avg = aggregation.fedavg_models(self.src_params)
            self.src_params = [jax.tree_util.tree_map(jnp.copy, avg) for _ in range(self.k)]
        self.tgt_params = jax.tree_util.tree_map(jnp.copy, avg)

    # ---- host-side batch plumbing --------------------------------------------
    def _draw_source_batches(self, rounds: int):
        """(R, L, K, p, b_max) x / (R, L, K, b_max) y in the serial consumption
        order (each client's stream yields R*L batches, round-major).  Ragged
        clients are zero-padded to the max width; ``self._bmask`` marks the
        true columns."""
        L = self.proto.local_steps
        xs = np.zeros((rounds, L, self.k, self.sources[0].x.shape[0], self._b_max),
                      dtype=np.float32)
        ys = np.zeros((rounds, L, self.k, self._b_max), dtype=np.int32)
        for r in range(rounds):
            for i in range(self.k):
                for s in range(L):
                    x, y = next(self.src_iters[i])
                    xs[r, s, i], ys[r, s, i] = _cycle_pad(x, y, self._b_max)
        return jnp.asarray(xs), jnp.asarray(ys)

    def _round_batch(self):
        """Draw one round's worth of batches for the batched engine (ragged
        clients zero-padded to the max width, masks alongside)."""
        L, p = self.proto.local_steps, self.sources[0].x.shape[0]
        xs = np.zeros((L, self.k, p, self._b_max), np.float32)
        ys = np.zeros((L, self.k, self._b_max), np.int32)
        for i in range(self.k):
            for s in range(L):
                x, y = next(self.src_iters[i])
                xs[s, i], ys[s, i] = _cycle_pad(x, y, self._b_max)
        x_msg = np.zeros((self.k, p, self._mb_max), np.float32)
        for i in range(self.k):
            xm = next(self._msg_iters[i])[0]
            x_msg[i], _ = _cycle_pad(xm, None, self._mb_max)
        xt_steps = np.stack([next(self.tgt_iter)[0] for _ in range(L)])
        xt_msg = next(self._tgt_msg_iter)[0]
        return {
            "xs": jnp.asarray(xs),
            "ys": jnp.asarray(ys),
            "x_msg": jnp.asarray(x_msg),
            "xt_steps": jnp.asarray(xt_steps),
            "xt_msg": jnp.asarray(xt_msg),
            "bmask": self._bmask,
            "msg_mask": self._msg_mask,
        }

    # ---- async-plane plumbing (repro.fedsim.AsyncScheduler) ------------------
    # The async runtime draws each client's batches at its *dispatch* time and
    # the target's at each flush.  Per iterator the draw order is identical to
    # the sync plane's per-round order, which is what lets a no-churn
    # uniform-latency async run consume bit-identical batch streams.

    def draw_client_dispatch(self, i: int):
        """Client i's dispatch draws: (L, p, b_max) / (L, b_max) training
        batches + (p, mb_max) message batch, cycle-padded like the sync plane."""
        L, p = self.proto.local_steps, self.sources[0].x.shape[0]
        xs = np.zeros((L, p, self._b_max), np.float32)
        ys = np.zeros((L, self._b_max), np.int32)
        for s in range(L):
            x, y = next(self.src_iters[i])
            xs[s], ys[s] = _cycle_pad(x, y, self._b_max)
        x_msg, _ = _cycle_pad(next(self._msg_iters[i])[0], None, self._mb_max)
        return xs, ys, x_msg

    def draw_target_steps(self) -> np.ndarray:
        """(L, p, b) target training batches for one flush."""
        return np.stack([next(self.tgt_iter)[0] for _ in range(self.proto.local_steps)])

    def target_message(self, chan_key=None) -> jnp.ndarray:
        """The target's Sigma-ell broadcast at the current parameters — what
        the server hands a client at dispatch.  Applies the wire codec's
        moments distortion twin when one is configured (the downlink leg)."""
        xt_msg = jnp.asarray(next(self._tgt_msg_iter)[0])
        if self._tgt_msg_fn is None:
            omega = self.omega
            self._tgt_msg_fn = jax.jit(
                lambda params, x: client_message(params, omega, x, -1.0)
            )
        msg = self._tgt_msg_fn(self.tgt_params, xt_msg)
        chan = (self._engine.channel if self._engine is not None else {}).get("moments")
        if chan is not None:
            if chan_key is None:
                raise ValueError("channel distortion is set: pass a chan_key")
            msg = chan(msg, chan_key)
        return msg

    def _mask_of(self, ids: list[int]) -> jnp.ndarray:
        m = np.zeros((self.k,), np.float32)
        m[list(ids)] = 1.0
        return jnp.asarray(m)

    # ---- communication accounting (analytic; exact by wire.serialized_size) --
    def account_ingress(self, kind: str, members) -> None:
        """Server-ingress leg of one round/flush's ``kind`` uplinks.

        Flat plane: every participating client's message reaches the server —
        K uplinks at the tier-1 codec.  Two-tier plane: each *active edge*
        (an edge with >= 1 participating member) ships one merged uplink —
        the partial merge plus its mass — at the tier-2 ``edge_codec``; the
        edge transport log records it.  ``ingress_bytes`` is the quantity
        BENCH_fleet.json tracks flat-vs-two-tier."""
        members = list(members)
        if not members:
            return
        if self.topology is None:
            nbytes = wire.serialized_size(
                kind, self._specs[kind], self.transport.codecs[kind]
            )
            total = len(members) * nbytes
            self.ingress_bytes[kind] += total
            obs.metrics().counter("fleet.ingress_bytes").inc(
                total, kind=kind, tier="flat"
            )
        else:
            edges = self.topology.edges_of(members)
            self.edge_transport.account_spec(
                kind, self._edge_specs[kind], count=len(edges)
            )
            nbytes = wire.serialized_size(
                kind, self._edge_specs[kind], self.edge_transport.codecs[kind]
            )
            total = len(edges) * nbytes
            self.ingress_bytes[kind] += total
            obs.metrics().counter("fleet.ingress_bytes").inc(
                total, kind=kind, tier="edge"
            )

    def _account_comm(self, plan: network.RoundPlan, t: int) -> None:
        """Byte + float accounting for the planes whose exchange is in-graph
        (identity transport and the batched engine).  The serial wire plane
        accounts inside ``Transport.transfer`` instead — same message counts,
        same exact byte sizes.  The main log carries the tier-1 (client)
        legs; ``account_ingress`` adds the server-ingress leg, which the
        two-tier plane collapses to one uplink per active edge."""
        proto, tr = self.proto, self.transport
        if proto.exchange_messages and plan.msg_clients:
            # one 2N downlink broadcast + one uplink per delivering client
            tr.account_spec(
                "moments", self._specs["moments"], count=1 + len(plan.msg_clients)
            )
            self.account_ingress("moments", plan.msg_clients)
        if proto.aggregate_w_rf and plan.w_clients:
            tr.account_spec("w_rf", self._specs["w_rf"], count=len(plan.w_clients) + 1)
            self.account_ingress("w_rf", plan.w_clients)
        if proto.aggregate_classifier and t % proto.t_c == 0 and plan.c_clients:
            tr.account_spec(
                "classifier", self._specs["classifier"], count=len(plan.c_clients)
            )
            self.account_ingress("classifier", plan.c_clients)

    # ---- jitted local updates (serial plane) ---------------------------------
    def _build_steps(self):
        cfg, omega = self.cfg, self.omega
        frozen = self._frozen_w

        def maybe_freeze(p):
            return {**p, "w_rf": jax.lax.stop_gradient(p["w_rf"])} if frozen else p

        def src_step_mmd(params, opt_state, x, y, tgt_msg):
            (loss, aux), grads = jax.value_and_grad(
                lambda p: source_loss(
                    maybe_freeze(p), omega, x, y, tgt_msg, cfg, with_mmd=True
                ),
                has_aux=True,
            )(params)
            upd, opt_state = self.opt.update(grads, opt_state, params)
            return apply_updates(params, upd), opt_state, aux

        def src_step_plain(params, opt_state, x, y):
            zero = jnp.zeros((2 * cfg.n_rff,))
            (loss, aux), grads = jax.value_and_grad(
                lambda p: source_loss(
                    maybe_freeze(p), omega, x, y, zero, cfg, with_mmd=False
                ),
                has_aux=True,
            )(params)
            upd, opt_state = self.opt.update(grads, opt_state, params)
            return apply_updates(params, upd), opt_state, aux

        def tgt_step(params, opt_state, x, src_msgs):
            (loss, aux), grads = jax.value_and_grad(
                lambda p: target_loss(maybe_freeze(p), omega, x, src_msgs, cfg),
                has_aux=True,
            )(params)
            upd, opt_state = self.opt.update(grads, opt_state, params)
            return apply_updates(params, upd), opt_state, aux

        def msg_of(params, x, sign):
            return client_message(params, omega, x, sign)

        # NOTE: the serial plane's steps legitimately retrace per distinct
        # client batch shape (ragged clients dispatch at their true widths) —
        # these sentinel planes are informative, never gated like the batched
        # ``engine.*`` planes
        self._src_step_mmd = jax.jit(sentinel.wrap("serial.src_step_mmd", src_step_mmd))
        self._src_step_plain = jax.jit(
            sentinel.wrap("serial.src_step_plain", src_step_plain)
        )
        self._tgt_step = jax.jit(sentinel.wrap("serial.tgt_step", tgt_step))
        self._msg_of = jax.jit(sentinel.wrap("serial.msg_of", msg_of))

    # ---- one communication round (Alg. 5 body) -------------------------------
    def round(self, t: int) -> dict[str, Any]:
        plan = self.scenario.plan(self.rng, self.k, t)
        return self.run_round(t, plan)

    def run_round(self, t: int, plan: network.RoundPlan) -> dict[str, Any]:
        """Execute one round under an externally supplied plan — the scheduler
        hook: ``repro.fedsim.SyncScheduler`` computes the plan itself (scenario
        intersected with the availability trace at the barrier's virtual time)
        and drives the round through here, so with no churn it reproduces
        ``train()`` exactly (same scenario rng stream, same round body)."""
        if self._engine is not None:
            self._round_batched(t, plan)
            self._account_comm(plan, t)
        else:
            self._round_serial(t, plan)
            if not self.transport.applies_values:
                self._account_comm(plan, t)  # wire serial accounts per transfer
        obs.metrics().counter("fed.rounds").inc(engine=self.proto.engine)
        self.comm.rounds += 1
        self.model_version += 1
        obs.metrics().gauge("fed.model_version").set(self.model_version)
        if plan.w_clients:  # clients whose aggregated W_RF was assigned back
            self.client_versions[list(plan.w_clients)] = self.model_version
        return {"plan": plan}

    # ---- checkpoint / restore (repro.checkpoint wired into the trainer) ------
    def _array_state(self):
        """The array half of the trainer state as one checkpointable pytree."""
        tree = {
            "tgt_params": self.tgt_params,
            "tgt_opt": self.tgt_opt,
            "client_versions": self.client_versions,
        }
        if self._engine is not None:
            tree["src"] = {"params": self._src_stack, "opt": self._src_opt_stack}
        else:
            tree["src"] = {"params": self.src_params, "opt": self.src_opt}
        return tree

    def _iterators(self):
        return [*self.src_iters, self.tgt_iter, *self._msg_iters, self._tgt_msg_iter]

    def save_state(self, path: str, *, step: int | None = None, keep: int = 3) -> str:
        """Checkpoint the complete trainer state through ``repro.checkpoint``.

        Arrays (client/target params + optimizer states + version tags) go
        into the atomic npz checkpoint; the host-side randomness — the
        scenario rng and every batch-iterator state — goes into a
        ``<ckpt>.host.json`` sidecar, so a restored trainer replays the
        *exact* trajectory it would have produced (save -> restore ->
        continue is bitwise; test-gated).  With ``step`` the path is treated
        as a checkpoint directory (``step_<n>.npz``, ``keep`` most recent
        retained); returns the written npz path."""
        target = ckpt.save(path, self._array_state(), step=step, keep=keep)
        host = {
            "rng": self.rng.bit_generator.state,
            "iters": [it.state() for it in self._iterators()],
            "model_version": int(self.model_version),
        }
        with open(target + ".host.json", "w") as f:
            json.dump(host, f)
        return target

    def restore_state(self, path: str) -> None:
        """Inverse of :meth:`save_state` (accepts the npz path or a checkpoint
        directory — restores the latest step).  Comm accounting is
        deliberately NOT rolled back: bytes that crossed the wire before a
        crash were really spent, and recovery replays (and re-pays) the
        rounds since the last checkpoint."""
        if os.path.isdir(path):
            found = ckpt.latest(path)
            if found is None:
                raise FileNotFoundError(f"no checkpoints in {path}")
            path = found
        tree = ckpt.restore(path, self._array_state())
        self.tgt_params = tree["tgt_params"]
        self.tgt_opt = tree["tgt_opt"]
        self.client_versions = np.asarray(tree["client_versions"])
        if self._engine is not None:
            self._src_stack = tree["src"]["params"]
            self._src_opt_stack = tree["src"]["opt"]
        else:
            self.src_params = tree["src"]["params"]
            self.src_opt = tree["src"]["opt"]
        with open(path + ".host.json") as f:
            host = json.load(f)
        self.rng.bit_generator.state = host["rng"]
        for it, st in zip(self._iterators(), host["iters"], strict=True):
            it.set_state(st)
        self.model_version = int(host["model_version"])

    def stash_probes(self, plane: str, probes: dict) -> None:
        """Queue a dispatch's device-side probes for host emission, emitting
        whatever was queued before (the one-step pipeline: by the time the
        next dispatch is enqueued, the previous one's outputs are ready, so
        the transfer no longer stalls the device)."""
        self.flush_probes()
        self._pending_probes = (plane, probes)

    def flush_probes(self) -> dict | None:
        """Drain the probe pipeline: materialize + emit any queued probes."""
        if self._pending_probes is not None:
            plane, dev = self._pending_probes
            self._pending_probes = None
            self._last_probes = obs.emit_probes(dev, plane=plane)
        return self._last_probes

    @property
    def last_probes(self) -> dict | None:
        """Most recent round/flush probes as host numpy (drains the queue)."""
        return self.flush_probes()

    def _round_batched(self, t: int, plan: network.RoundPlan) -> None:
        batch = self._round_batch()
        masks = {
            "mmd": self._mask_of(plan.msg_clients) if self.proto.exchange_messages
            else self._mask_of([]),
            "w": self._mask_of(plan.w_clients),
            "c": self._mask_of(plan.c_clients),
            "do_clf": jnp.asarray(t % self.proto.t_c == 0),
        }
        out = self._engine.round(
            self._src_stack,
            self._src_opt_stack,
            self.tgt_params,
            self.tgt_opt,
            batch,
            masks,
            chan_key=jax.random.fold_in(self._chan_base, t),
        )
        (
            self._src_stack,
            self._src_opt_stack,
            self.tgt_params,
            self.tgt_opt,
        ) = out[:4]
        if self._engine.probe:
            self.stash_probes("round", out[4])

    def _round_serial(self, t: int, plan: network.RoundPlan) -> None:
        proto = self.proto
        # wiretx: the transport really serializes/parses every message and the
        # decoded (possibly codec-distorted) arrays flow back into training
        wiretx = self.transport if self.transport.applies_values else None

        # target broadcasts its message to sources in S_t.  Under byte-level
        # fault injection any transfer may give up after its retry budget
        # (None): a lost downlink degrades sources to plain CE steps, a lost
        # uplink is simply a message that never arrived — reject-and-account,
        # never a crash.
        xt, _ = next(self._tgt_msg_iter)
        tgt_msg = self._msg_of(self.tgt_params, jnp.asarray(xt), -1.0)
        downlink_ok = True
        if wiretx and proto.exchange_messages and plan.msg_clients:
            arrs = wiretx.transfer(
                wire.moments_message(tgt_msg, sender=-1, round=t, downlink=True)
            )
            if arrs is None:
                downlink_ok = False
            else:
                tgt_msg = jnp.asarray(arrs["msg"])

        # local source training (Alg. 2)
        src_msgs = {}
        for i in range(self.k):
            for _ in range(proto.local_steps):
                x, y = next(self.src_iters[i])
                x, y = jnp.asarray(x), jnp.asarray(y)
                if proto.exchange_messages and i in plan.msg_clients and downlink_ok:
                    self.src_params[i], self.src_opt[i], aux = self._src_step_mmd(
                        self.src_params[i], self.src_opt[i], x, y, tgt_msg
                    )
                else:
                    self.src_params[i], self.src_opt[i], aux = self._src_step_plain(
                        self.src_params[i], self.src_opt[i], x, y
                    )
            if proto.exchange_messages and i in plan.msg_clients:
                xm, _ = next(self._msg_iters[i])
                msg = self._msg_of(self.src_params[i], jnp.asarray(xm), +1.0)
                if wiretx:
                    arrs = wiretx.transfer(wire.moments_message(msg, sender=i, round=t))
                    if arrs is None:
                        continue  # retry budget exhausted: an undelivered uplink
                    msg = jnp.asarray(arrs["msg"])
                src_msgs[i] = msg

        # local target training (Alg. 3)
        if proto.exchange_messages and src_msgs:
            msgs = jnp.stack(list(src_msgs.values()))
            for _ in range(proto.local_steps):
                xt, _ = next(self.tgt_iter)
                self.tgt_params, self.tgt_opt, _ = self._tgt_step(
                    self.tgt_params, self.tgt_opt, jnp.asarray(xt), msgs
                )

        # global aggregation (Alg. 4)
        if proto.aggregate_w_rf and plan.w_clients:
            if self._frozen_w:
                # seed-replay sync: everyone already holds the identical
                # seed-derived W_RF; the "upload" is the O(1) key, and the
                # decode re-derives the matrix bit-exactly
                if wiretx:
                    # one real key transfer proves the decode; the remaining
                    # members' identical key messages are accounted analytically
                    # (same bytes) instead of re-deriving the matrix K more times
                    decoded = wiretx.transfer(
                        wire.w_rf_message(
                            self._w_init, sender=plan.w_clients[0], round=t,
                            replay=("w_rf_init", self._w_key_data),
                        )
                    )
                    wiretx.account_spec(
                        "w_rf", self._specs["w_rf"], count=len(plan.w_clients)
                    )
                    if decoded is not None:
                        self.tgt_params["w_rf"] = jnp.asarray(decoded["w_rf"])
            elif wiretx:
                ws = []
                for i in plan.w_clients:
                    arrs = wiretx.transfer(
                        wire.w_rf_message(self.src_params[i]["w_rf"], sender=i, round=t)
                    )
                    if arrs is not None:
                        ws.append(arrs["w_rf"])
                arrs = wiretx.transfer(
                    wire.w_rf_message(self.tgt_params["w_rf"], sender=-1, round=t)
                )
                if arrs is not None:
                    ws.append(arrs["w_rf"])
                if ws:
                    w_rf = jnp.asarray(tree_mean(ws))
                    for i in plan.w_clients:
                        self.src_params[i]["w_rf"] = w_rf
                    self.tgt_params["w_rf"] = w_rf
            else:
                w_rf = aggregation.fedavg_w_rf(
                    self.src_params, self.tgt_params, plan.w_clients
                )
                for i in plan.w_clients:
                    self.src_params[i]["w_rf"] = w_rf
                self.tgt_params["w_rf"] = w_rf

        if proto.aggregate_classifier and t % proto.t_c == 0 and plan.c_clients:
            if wiretx:
                clfs = [
                    wiretx.transfer_delta(
                        wire.classifier_message(
                            self.src_params[i]["classifier"], sender=i, round=t
                        ),
                        link=f"clf-up-{i}",
                    )
                    for i in plan.c_clients
                ]
                clfs = [c for c in clfs if c is not None]  # give-ups: lost uplinks
                clf = jax.tree_util.tree_map(jnp.asarray, tree_mean(clfs)) if clfs else None
            else:
                clf = aggregation.fedavg_classifier(self.src_params, plan.c_clients)
            if clf is not None:
                for i in plan.c_clients:
                    self.src_params[i]["classifier"] = clf
                self.tgt_params["classifier"] = clf

    def train(self, eval_every: int = 0) -> list[float]:
        accs = []
        for t in range(1, self.proto.n_rounds + 1):
            self.round(t)
            if eval_every and t % eval_every == 0:
                accs.append(self.evaluate())
        self.flush_probes()
        return accs

    # ---- evaluation -----------------------------------------------------------
    def evaluate(self, x=None, y=None) -> float:
        """Aggregated-classifier accuracy on target data (the UFDA objective)."""
        x = self.target.x if x is None else x
        y = self.target.y if y is None else y
        if self.proto.aggregate_classifier:
            return float(accuracy(self.tgt_params, self.omega, jnp.asarray(x), jnp.asarray(y)))
        # one-shot hard voting (App. D): each source classifier votes on the
        # target's aligned features
        per_src = []
        for i in range(self.k):
            p = {
                "extractor": self.tgt_params["extractor"],
                "w_rf": self.tgt_params["w_rf"],
                "classifier": self._src_param(i)["classifier"],
            }
            per_src.append(np.asarray(logits_of(p, self.omega, jnp.asarray(x))))
        preds = aggregation.hard_vote(np.stack(per_src))
        return float(np.mean(preds == y))
