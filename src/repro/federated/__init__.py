from repro.federated.aggregation import fedavg_classifier, fedavg_models, fedavg_w_rf, hard_vote
from repro.federated.engine import BatchedRoundEngine, stack_trees, unstack_tree
from repro.federated.model import (
    ClientConfig,
    accuracy,
    client_message,
    init_params,
    logits_of,
    make_omega,
    source_loss,
    target_loss,
    w_rf_key,
)
from repro.federated.network import LossyChannel, RoundPlan, plan_round, sample_participants
from repro.federated.protocol import CommLog, FedRFTCATrainer, ProtocolConfig
