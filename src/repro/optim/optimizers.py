"""Pure-JAX optimizers (no optax in the container): SGD(+momentum), Adam, AdamW.

API mirrors optax's GradientTransformation so call-sites stay idiomatic:

    opt = adamw(lr=3e-4, weight_decay=0.1)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: Any


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def _lr_at(lr, step):
    return lr(step) if callable(lr) else jnp.asarray(lr)


def sgd(lr: float | Schedule, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        mom = jax.tree_util.tree_map(jnp.zeros_like, params) if momentum else None
        return SGDState(step=jnp.zeros((), jnp.int32), momentum=mom)

    def update(grads, state, params=None):
        del params
        step = state.step + 1
        lr_t = _lr_at(lr, step)
        if momentum:
            mom = jax.tree_util.tree_map(lambda m, g: momentum * m + g, state.momentum, grads)
            if nesterov:
                upd = jax.tree_util.tree_map(lambda m, g: -lr_t * (momentum * m + g), mom, grads)
            else:
                upd = jax.tree_util.tree_map(lambda m: -lr_t * m, mom)
            return upd, SGDState(step=step, momentum=mom)
        upd = jax.tree_util.tree_map(lambda g: -lr_t * g, grads)
        return upd, SGDState(step=step, momentum=None)

    return Optimizer(init, update)


def adam(
    lr: float | Schedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Adam; with weight_decay > 0 this is AdamW (decoupled decay)."""

    def init(params):
        z = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        z2 = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return AdamState(step=jnp.zeros((), jnp.int32), mu=z, nu=z2)

    def update(grads, state, params=None):
        step = state.step + 1
        lr_t = _lr_at(lr, step)
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
        )
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.nu, grads
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd_leaf(m, v, p):
            u = -lr_t * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u.astype(p.dtype if p is not None else u.dtype)

        if weight_decay:
            if params is None:
                raise ValueError("adamw requires params for decoupled weight decay")
            upd = jax.tree_util.tree_map(upd_leaf, mu, nu, params)
        else:
            upd = jax.tree_util.tree_map(lambda m, v: upd_leaf(m, v, None), mu, nu)
            if params is not None:
                upd = jax.tree_util.tree_map(
                    lambda u, p: u.astype(p.dtype), upd, params
                )
        return upd, AdamState(step=step, mu=mu, nu=nu)

    return Optimizer(init, update)


def adamw(lr: float | Schedule, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def clip_by_global_norm(grads, max_norm: float):
    """Returns (clipped_grads, global_norm)."""
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn


def cosine_schedule(base_lr: float, warmup: int, total: int, min_frac: float = 0.1) -> Schedule:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return sched


def linear_schedule(base_lr: float, total: int, end_frac: float = 0.0) -> Schedule:
    def sched(step):
        prog = jnp.clip(step.astype(jnp.float32) / max(total, 1), 0.0, 1.0)
        return base_lr * (1 - (1 - end_frac) * prog)

    return sched
