from repro.optim.optimizers import (
    AdamState,
    Optimizer,
    SGDState,
    adam,
    adamw,
    apply_updates,
    clip_by_global_norm,
    cosine_schedule,
    linear_schedule,
    sgd,
)
