"""Downstream classifiers used to score aligned features (paper App. D uses
FCNN (2x100), SVM-RBF, and 1-NN; we provide FCNN, logistic regression, 1-NN)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adam, apply_updates


def fit_mlp(
    feats: np.ndarray,
    labels: np.ndarray,
    n_classes: int,
    *,
    hidden: tuple[int, ...] = (100, 100),
    steps: int = 300,
    lr: float = 1e-2,
    seed: int = 0,
):
    """Train the paper's FCNN (two hidden layers, 100 units) on (n, d) features."""
    x = jnp.asarray(feats, jnp.float32)
    y = jnp.asarray(labels)
    widths = (x.shape[1],) + hidden + (n_classes,)
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, len(widths))
    params = [
        {
            "w": jax.random.normal(keys[i], (din, dout)) * jnp.sqrt(2.0 / din),
            "b": jnp.zeros((dout,)),
        }
        for i, (din, dout) in enumerate(zip(widths[:-1], widths[1:]))
    ]

    def apply(p, xx):
        h = xx
        for i, layer in enumerate(p):
            h = h @ layer["w"] + layer["b"]
            if i < len(p) - 1:
                h = jax.nn.relu(h)
        return h

    def loss(p):
        logits = apply(p, x)
        oh = jax.nn.one_hot(y, n_classes)
        return -jnp.mean(jnp.sum(oh * jax.nn.log_softmax(logits), axis=-1))

    opt = adam(lr)
    state = opt.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(loss)(p)
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s

    for _ in range(steps):
        params, state = step(params, state)

    def predict(xx):
        return np.asarray(jnp.argmax(apply(params, jnp.asarray(xx, jnp.float32)), axis=-1))

    return predict


def fit_logreg(feats, labels, n_classes, **kw):
    return fit_mlp(feats, labels, n_classes, hidden=(), **kw)


def knn_1(train_feats: np.ndarray, train_labels: np.ndarray):
    """1-nearest-neighbour in feature space (paper's kNN, k=1)."""
    xt = jnp.asarray(train_feats, jnp.float32)
    yt = np.asarray(train_labels)

    def predict(xx):
        xq = jnp.asarray(xx, jnp.float32)
        d = (
            jnp.sum(xq * xq, 1)[:, None]
            - 2 * xq @ xt.T
            + jnp.sum(xt * xt, 1)[None, :]
        )
        return yt[np.asarray(jnp.argmin(d, axis=1))]

    return predict


def score(predict, feats, labels) -> float:
    return float(np.mean(predict(feats) == np.asarray(labels)))
