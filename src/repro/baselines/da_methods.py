"""Every DA baseline the paper compares against that is reproducible offline:

- source-only (no adaptation)
- vanilla TCA / R-TCA / RF-TCA pipelines (transductive, kernel on raw features)
- JDA-lite (joint marginal+conditional MMD with pseudo-label iterations)
- CORAL (second-order statistics alignment)
- DaNN (1-hidden-layer net with an MMD penalty on the hidden layer)
- plain FedAvg (federated, no adaptation — the paper's Table VIII/IX ablation)

All take columns-as-samples domains and return target accuracy with a shared
classifier family, so numbers are comparable across methods.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.classifiers import fit_mlp, knn_1, score
from repro.core.kernels_math import centering_matrix, ell_vector, gaussian_kernel
from repro.core.rf_tca import rf_tca
from repro.core.tca import r_tca, vanilla_tca
from repro.data.domains import Domain
from repro.federated.aggregation import fedavg_models
from repro.federated.model import (
    ClientConfig,
    accuracy,
    init_params,
    make_omega,
    source_loss,
)
from repro.optim import adam, apply_updates


def _concat(sources: list[Domain]) -> Domain:
    return Domain(
        "+".join(d.name for d in sources),
        np.concatenate([d.x for d in sources], axis=1),
        np.concatenate([d.y for d in sources]),
    )


def _unit(d: Domain) -> Domain:
    """Unit-norm columns — the paper's preprocessing for all kernel methods."""
    from repro.data.domains import normalize_unit

    return Domain(d.name, normalize_unit(d.x), d.y)


def source_only(sources: list[Domain], target: Domain, *, classifier="mlp", seed=0) -> float:
    src = _concat(sources)
    if classifier == "knn":
        pred = knn_1(src.x.T, src.y)
    else:
        pred = fit_mlp(src.x.T, src.y, int(src.y.max()) + 1, seed=seed)
    return score(pred, target.x.T, target.y)


def _transductive_eval(feats_s, y_s, feats_t, y_t, classifier="mlp", seed=0) -> float:
    n_classes = int(max(y_s.max(), y_t.max())) + 1
    # standardise jointly: eigenvector-based features are O(1/sqrt(n)) scaled
    mu = np.mean(np.concatenate([feats_s, feats_t]), axis=0, keepdims=True)
    sd = np.std(np.concatenate([feats_s, feats_t]), axis=0, keepdims=True) + 1e-8
    feats_s, feats_t = (feats_s - mu) / sd, (feats_t - mu) / sd
    if classifier == "knn":
        pred = knn_1(feats_s, y_s)
    else:
        pred = fit_mlp(feats_s, y_s, n_classes, seed=seed)
    return score(pred, feats_t, y_t)


def tca_baseline(
    sources: list[Domain],
    target: Domain,
    *,
    m: int = 32,
    gamma: float = 1e-2,
    sigma: float = 1.0,
    variant: str = "vanilla",
    classifier: str = "mlp",
    seed: int = 0,
) -> float:
    """Vanilla TCA / R-TCA on the pooled kernel (transductive)."""
    src = _unit(_concat(sources))
    target = _unit(target)
    x = jnp.asarray(np.concatenate([src.x, target.x], axis=1))
    n_s = src.x.shape[1]
    ell = ell_vector(n_s, target.x.shape[1])
    k = gaussian_kernel(x, sigma)
    solver = vanilla_tca if variant == "vanilla" else r_tca
    feats = np.asarray(solver(k, ell, gamma, m).features)  # (m, n)
    return _transductive_eval(
        feats[:, :n_s].T, src.y, feats[:, n_s:].T, target.y, classifier, seed
    )


def rf_tca_baseline(
    sources: list[Domain],
    target: Domain,
    *,
    n_features: int = 512,
    m: int = 32,
    gamma: float = 1e-2,
    sigma: float = 1.0,
    classifier: str = "mlp",
    seed: int = 0,
    **rf_tca_kw,
) -> float:
    """RF-TCA (Algorithm 1) pipeline — the paper's single-machine method.

    Extra keyword args pass through to :func:`rf_tca` — e.g.
    ``w_rf="fused:<seed>"`` / ``ensemble=S`` for the seed-fused statistics
    pass, or ``solver`` / ``mode`` overrides for benchmark sweeps."""
    src = _unit(_concat(sources))
    target = _unit(target)
    f_s, f_t, _ = rf_tca(
        jnp.asarray(src.x),
        jnp.asarray(target.x),
        n_features=n_features,
        m=m,
        gamma=gamma,
        sigma=sigma,
        seed=seed,
        **rf_tca_kw,
    )
    return _transductive_eval(
        np.asarray(f_s).T, src.y, np.asarray(f_t).T, target.y, classifier, seed
    )


def coral_baseline(sources: list[Domain], target: Domain, *, classifier="mlp", seed=0) -> float:
    """CORAL: recolor source features to the target second-order statistics."""
    src = _concat(sources)
    xs, xt = src.x.T, target.x.T  # rows-as-samples
    cs = np.cov(xs, rowvar=False) + np.eye(xs.shape[1])
    ct = np.cov(xt, rowvar=False) + np.eye(xt.shape[1])

    def inv_sqrt(c):
        w, v = np.linalg.eigh(c)
        return v @ np.diag(w ** -0.5) @ v.T

    def sqrt(c):
        w, v = np.linalg.eigh(c)
        return v @ np.diag(w ** 0.5) @ v.T

    xs_al = xs @ inv_sqrt(cs) @ sqrt(ct)
    return _transductive_eval(xs_al, src.y, xt, target.y, classifier, seed)


def jda_baseline(
    sources: list[Domain],
    target: Domain,
    *,
    m: int = 32,
    gamma: float = 1e-2,
    sigma: float = 1.0,
    iters: int = 3,
    seed: int = 0,
) -> float:
    """JDA-lite: marginal + class-conditional MMD, pseudo-label refinement.

    Solves  K H K w = lam (gamma I + K M K) w  with
    M = M_0 + sum_c M_c (Long et al. 2013), via Cholesky whitening.
    """
    src = _unit(_concat(sources))
    target = _unit(target)
    n_s, n_t = src.x.shape[1], target.x.shape[1]
    n = n_s + n_t
    n_classes = int(src.y.max()) + 1
    x = jnp.asarray(np.concatenate([src.x, target.x], axis=1))
    k = np.asarray(gaussian_kernel(x, sigma))
    h = np.asarray(centering_matrix(n))
    khk = k @ h @ k
    y_t_pseudo = None
    acc = 0.0
    for it in range(iters):
        m0 = np.zeros((n, n))
        ell = np.asarray(ell_vector(n_s, n_t))
        m0 += np.outer(ell, ell)
        if y_t_pseudo is not None:
            for c in range(n_classes):
                e = np.zeros(n)
                s_idx = np.where(src.y == c)[0]
                t_idx = n_s + np.where(y_t_pseudo == c)[0]
                if len(s_idx) == 0 or len(t_idx) == 0:
                    continue
                e[s_idx] = 1.0 / len(s_idx)
                e[t_idx] = -1.0 / len(t_idx)
                m0 += np.outer(e, e)
        b = gamma * np.eye(n) + k @ m0 @ k
        chol = np.linalg.cholesky(b + 1e-8 * np.eye(n))
        c_mat = np.linalg.solve(chol, np.linalg.solve(chol, khk).T).T
        c_mat = 0.5 * (c_mat + c_mat.T)
        w, v = np.linalg.eigh(c_mat)
        vecs = np.linalg.solve(chol.T, v[:, ::-1][:, :m])
        feats = (vecs.T @ k)  # (m, n)
        pred = knn_1(feats[:, :n_s].T, src.y)
        y_t_pseudo = pred(feats[:, n_s:].T)
        acc = float(np.mean(y_t_pseudo == target.y))
    return acc


def dann_mmd_baseline(
    sources: list[Domain],
    target: Domain,
    *,
    hidden: int = 64,
    lam: float = 1.0,
    steps: int = 400,
    lr: float = 5e-3,
    seed: int = 0,
) -> float:
    """DaNN (Ghifary et al. 2014): 1-hidden-layer net + MMD penalty on hidden."""
    src = _concat(sources)
    n_classes = int(src.y.max()) + 1
    xs = jnp.asarray(src.x.T, jnp.float32)
    ys = jnp.asarray(src.y)
    xt = jnp.asarray(target.x.T, jnp.float32)
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    params = {
        "w1": jax.random.normal(k1, (xs.shape[1], hidden)) * jnp.sqrt(2.0 / xs.shape[1]),
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden, n_classes)) / jnp.sqrt(hidden),
        "b2": jnp.zeros((n_classes,)),
    }

    def hid(p, xx):
        return jnp.tanh(xx @ p["w1"] + p["b1"])

    def loss(p):
        hs, ht = hid(p, xs), hid(p, xt)
        logits = hs @ p["w2"] + p["b2"]
        oh = jax.nn.one_hot(ys, n_classes)
        ce = -jnp.mean(jnp.sum(oh * jax.nn.log_softmax(logits), axis=-1))
        gap = jnp.mean(hs, axis=0) - jnp.mean(ht, axis=0)  # linear-kernel MMD
        return ce + lam * gap @ gap

    opt = adam(lr)
    state = opt.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(loss)(p)
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s

    for _ in range(steps):
        params, state = step(params, state)
    logits_t = hid(params, xt) @ params["w2"] + params["b2"]
    return float(np.mean(np.asarray(jnp.argmax(logits_t, -1)) == target.y))


def fedavg_baseline(
    sources: list[Domain],
    target: Domain,
    cfg: ClientConfig,
    *,
    rounds: int = 200,
    local_steps: int = 1,
    batch_size: int = 64,
    lr: float = 1e-2,
    seed: int = 0,
) -> float:
    """Plain FedAvg: identical client model, no message exchange, no MMD —
    the paper's 'ResNet updated using FedAvg' ablation row (Tables VIII/IX)."""
    from repro.data.domains import batches

    omega = make_omega(cfg)
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, len(sources))
    params = [init_params(cfg, keys[i]) for i in range(len(sources))]
    opt = adam(lr)
    opts = [opt.init(p) for p in params]
    iters = [batches(d.x, d.y, batch_size, seed=seed + i) for i, d in enumerate(sources)]

    @jax.jit
    def local(p, s, x, y):
        zero = jnp.zeros((2 * cfg.n_rff,))
        (_, aux), g = jax.value_and_grad(
            lambda pp: source_loss(pp, omega, x, y, zero, cfg, with_mmd=False), has_aux=True
        )(p)
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s

    for _ in range(rounds):
        for i in range(len(sources)):
            for _ in range(local_steps):
                x, y = next(iters[i])
                params[i], opts[i] = local(params[i], opts[i], jnp.asarray(x), jnp.asarray(y))
        avg = fedavg_models(params)
        params = [avg for _ in sources]
    return float(accuracy(params[0], omega, jnp.asarray(target.x), jnp.asarray(target.y)))
