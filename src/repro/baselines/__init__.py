from repro.baselines.classifiers import fit_logreg, fit_mlp, knn_1, score
from repro.baselines.da_methods import (
    coral_baseline,
    dann_mmd_baseline,
    fedavg_baseline,
    jda_baseline,
    rf_tca_baseline,
    source_only,
    tca_baseline,
)
