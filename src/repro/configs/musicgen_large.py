"""musicgen-large [audio]: 48L decoder-only over EnCodec tokens, d_model=2048,
32H (kv=32, MHA), d_ff=8192, vocab=2048 [arXiv:2306.05284].

The mel/EnCodec frontend is a stub per the brief: input_specs() provides
frame embeddings (seq x d_model); the decoder transformer is real.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    embeddings_in=True,
    source="MusicGen [arXiv:2306.05284]",
)
