"""llama-3.2-vision-90b [vlm]: 100L, d_model=8192, 64H (GQA kv=8),
d_ff=28672, vocab=128256, cross-attention image layers every 4 self layers
(20 cross layers) [hf:meta-llama/Llama-3.2-11B-Vision].

The ViT frontend is a stub per the brief: input_specs() provides patch
embeddings (n_image_tokens x d_image); the cross-attention decoder is real.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=4,
    n_image_tokens=576,
    d_image=1280,
    source="Llama-3.2-Vision [hf:meta-llama/Llama-3.2-11B-Vision]",
)
