"""Architecture registry: ``get_config("<arch-id>")`` for every assigned arch."""
from __future__ import annotations

import importlib

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

_ARCH_MODULES = {
    "mamba2-2.7b": "mamba2_2p7b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "internlm2-1.8b": "internlm2_1p8b",
    "zamba2-7b": "zamba2_7b",
    "smollm-360m": "smollm_360m",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "smollm-135m": "smollm_135m",
    "llama-3.2-vision-90b": "llama_3p2_vision_90b",
    "musicgen-large": "musicgen_large",
    "command-r-plus-104b": "command_r_plus_104b",
}

ARCH_IDS = list(_ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.CONFIG


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]
