"""Model/config schema shared by all assigned architectures + input shapes."""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // n_heads
    rope_theta: float = 10_000.0
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- MLA (DeepSeek) ---
    kv_lora_rank: int = 0  # 0 => standard GQA
    rope_head_dim: int = 64
    # --- SSM (Mamba2/SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_conv_width: int = 4
    ssm_n_groups: int = 1
    # --- hybrid (Zamba2): shared attention block every `attn_every` SSM layers
    attn_every: int = 0
    # --- VLM: cross-attention block every `cross_attn_every` self-attn layers
    cross_attn_every: int = 0
    n_image_tokens: int = 576
    d_image: int = 1280
    # --- audio: backbone consumes precomputed frame embeddings
    embeddings_in: bool = False
    # --- attention variants ---
    attn_window: int = 0  # 0 => full causal; >0 => sliding window
    # --- numerics / FDA head ---
    dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-5
    fda_n_rff: int = 512
    fda_m: int = 64
    fda_lambda: float = 0.1
    fda_seed: int = 1234
    n_clients: int = 0  # 0 => one client per data-parallel shard
    remat: bool = True
    # Unroll layer scans. XLA cost_analysis counts while-loop bodies ONCE, so
    # roofline dry-runs compile with unrolled stacks to get true per-step
    # FLOPs/bytes/collectives; production training keeps scan (small HLO).
    unroll_scan: bool = False
    # --- §Perf hillclimb switches (baseline = False; see EXPERIMENTS.md) ---
    sharded_ce: bool = False  # shard-local CE (kills the vocab all-gather)
    moe_ep: bool = False  # shard_map expert-parallel MoE dispatch
    causal_skip: bool = False  # skip fully-masked causal attention blocks
    seq_parallel: bool = False  # shard the residual stream's seq dim over model
    source: str = ""  # provenance citation

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model<=256, <=4 experts, tiny vocab."""
        base = dict(
            n_layers=2,
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=64 if (self.head_dim or self.d_model // max(self.n_heads, 1)) >= 64 else 32,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            dtype=jnp.float32,
            fda_n_rff=32,
            fda_m=8,
            remat=False,
        )
        if self.n_experts:
            base.update(
                n_experts=4, top_k=min(self.top_k, 2),
                n_shared_experts=min(self.n_shared_experts, 1),
            )
        if self.kv_lora_rank:
            base.update(kv_lora_rank=64, rope_head_dim=32)
        if self.ssm_state:
            base.update(ssm_state=min(self.ssm_state, 32), ssm_head_dim=32, ssm_chunk=16)
        if self.attn_every:
            base.update(attn_every=1, n_layers=2)
        if self.cross_attn_every:
            base.update(cross_attn_every=1, n_layers=2, n_image_tokens=16, d_image=64)
        base.update(overrides)
        return replace(self, **base)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
