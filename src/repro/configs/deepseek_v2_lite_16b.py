"""deepseek-v2-lite-16b [moe]: 27L, d_model=2048, 16H MLA (kv_lora=512),
expert d_ff=1408, vocab=102400, 64 routed experts top-6 + 2 shared.

MLA with decoupled RoPE head (64) and absorbed decode [arXiv:2405.04434].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    kv_lora_rank=512,
    rope_head_dim=64,
    source="DeepSeek-V2 [arXiv:2405.04434]",
)
