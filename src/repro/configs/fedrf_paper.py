"""The paper's own pipeline config (FedRF-TCA, Fig. 1): MLP feature extractor
+ RFF compressor + W_RF aligner + classifier, multi-source federated protocol.

This is the configuration the repro benchmarks (benchmarks/) run; the LM
backbones above integrate the same head via ModelConfig.fda_* fields.
"""
from repro.federated.model import ClientConfig
from repro.federated.protocol import ProtocolConfig

CLIENT = ClientConfig(
    input_dim=16,
    n_classes=5,
    extractor_widths=(64, 32),
    n_rff=512,  # N: messages are 2N = 1024 floats (paper uses N=1000)
    m=32,
    lambda_mmd=2.0,
)

PROTOCOL: ProtocolConfig = ProtocolConfig(
    n_rounds=300,
    t_c=50,
    warmup_rounds=200,
    lr=5e-3,
)
