"""zamba2-7b [hybrid]: 81 Mamba2 layers, d_model=3584, shared GQA attention
block (32H, kv=32) applied every 6 layers, ssm_state=64, vocab=32000
[arXiv:2411.15242].

Deviation noted in DESIGN.md: the shared block is attention-only (Zamba2's
shared block also carries an MLP + per-depth LoRA which we do not replicate).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    attn_every=6,
    source="Zamba2 [arXiv:2411.15242]",
)
