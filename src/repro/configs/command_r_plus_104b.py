"""command-r-plus-104b [dense]: 64L, d_model=12288, 96H (GQA kv=8),
d_ff=33792, vocab=256000, no biases [hf:CohereForAI/c4ai-command-r-v01]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    source="Command-R+ [hf:CohereForAI/c4ai-command-r-v01]",
)
