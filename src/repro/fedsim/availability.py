"""Client churn traces: who is online when, replayable bit-for-bit.

The runtime never consumes a stochastic availability *process* directly — it
consumes an :class:`AvailabilityTrace`: per-client sorted disjoint half-open
``[start, end)`` on-intervals up to a horizon.  Generators materialize the
three churn families into traces, exactly the way ``comm.table3_trace``
materializes Table III's drop settings:

- :func:`always_on_trace` — every client online for the whole horizon (the
  degenerate no-churn case the sync/async equivalence tests pin down);
- :func:`duty_cycle_trace` — periodic duty-cycling with a deterministic
  per-client phase stagger (mobile clients on a charging schedule);
- :func:`markov_trace` — seeded two-state Markov process in continuous time
  (exponential on/off sojourns), the standard churn model.

Traces round-trip through JSON *bit-identically* (:func:`save_trace` /
:func:`load_trace` — Python's json writes ``repr`` floats, which parse back
to the same IEEE-754 doubles), so an experiment's churn is a shareable,
diffable artifact rather than an RNG side effect.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

import numpy as np

Interval = tuple[float, float]


@dataclass
class AvailabilityTrace:
    """Per-client on-intervals over ``[0, horizon)``; the runtime's only view
    of churn.  ``intervals[i]`` is sorted, disjoint, and clipped to the
    horizon; ``meta`` records provenance (generator name + parameters)."""

    horizon: float
    intervals: list[list[Interval]]
    meta: dict = field(default_factory=dict)

    def __post_init__(self):
        for i, ivs in enumerate(self.intervals):
            prev_end = -1.0
            for s, e in ivs:
                if not (0.0 <= s < e <= self.horizon):
                    raise ValueError(f"client {i}: bad interval [{s}, {e})")
                if s < prev_end:
                    raise ValueError(f"client {i}: overlapping/unsorted intervals")
                prev_end = e
            # coalesce touching intervals ([0,10),[10,20) -> [0,20)): a client
            # online across the boundary must NOT emit a depart/join edge pair
            # there — that would fabricate churn (cancelled in-flight work)
            # for a continuously available client
            merged: list[Interval] = []
            for s, e in ivs:
                if merged and merged[-1][1] == s:
                    merged[-1] = (merged[-1][0], e)
                else:
                    merged.append((s, e))
            self.intervals[i] = merged

    @property
    def n_clients(self) -> int:
        return len(self.intervals)

    def available(self, client: int, t: float) -> bool:
        return any(s <= t < e for s, e in self.intervals[client])

    def available_at(self, t: float) -> list[int]:
        return [i for i in range(self.n_clients) if self.available(i, t)]

    def edges(self, client: int) -> list[tuple[float, bool]]:
        """(time, is_join) churn edges for one client, time-sorted."""
        out: list[tuple[float, bool]] = []
        for s, e in self.intervals[client]:
            out.append((s, True))
            if e < self.horizon:
                out.append((e, False))
        return out

    def uptime(self, client: int) -> float:
        return sum(e - s for s, e in self.intervals[client])


def always_on_trace(n_clients: int, horizon: float) -> AvailabilityTrace:
    """No churn: the degenerate trace the sync/async equivalence tests use."""
    return AvailabilityTrace(
        horizon,
        [[(0.0, float(horizon))] for _ in range(n_clients)],
        meta={"kind": "always_on", "n_clients": n_clients},
    )


def duty_cycle_trace(
    n_clients: int,
    horizon: float,
    *,
    period: float,
    on_fraction: float,
    stagger: bool = True,
) -> AvailabilityTrace:
    """Periodic duty-cycling: client i is on for ``on_fraction * period`` of
    every period, phase-shifted by ``i * period / n_clients`` when staggered
    (so the fleet is never simultaneously dark)."""
    if period <= 0.0:
        raise ValueError(f"period must be > 0, got {period}")
    if not 0.0 < on_fraction <= 1.0:
        raise ValueError(f"on_fraction must be in (0, 1], got {on_fraction}")
    on_len = on_fraction * period
    intervals: list[list[Interval]] = []
    for i in range(n_clients):
        phase = (i * period / n_clients) if stagger else 0.0
        ivs: list[Interval] = []
        k = -1  # the phase shift can pull the first window before t=0
        while True:
            s = k * period + phase
            e = s + on_len
            if s >= horizon:
                break
            if e > 0.0:
                ivs.append((max(s, 0.0), min(e, horizon)))
            k += 1
        intervals.append(ivs)
    return AvailabilityTrace(
        horizon,
        intervals,
        meta={
            "kind": "duty_cycle", "n_clients": n_clients,
            "period": period, "on_fraction": on_fraction, "stagger": stagger,
        },
    )


def markov_trace(
    n_clients: int,
    horizon: float,
    *,
    mean_on: float,
    mean_off: float,
    seed: int = 0,
) -> AvailabilityTrace:
    """Seeded two-state Markov churn: alternating Exp(1/mean_on) on-sojourns
    and Exp(1/mean_off) off-sojourns per client; the initial state is drawn
    from the stationary distribution.  ``mean_off / (mean_on + mean_off)`` is
    the churn (offline) fraction — sweep ``mean_off`` for churn-rate curves."""
    if mean_on <= 0 or mean_off < 0:
        raise ValueError("mean_on must be > 0 and mean_off >= 0")
    rng = np.random.default_rng(seed)
    intervals: list[list[Interval]] = []
    for _ in range(n_clients):
        if mean_off == 0.0:
            intervals.append([(0.0, float(horizon))])
            continue
        on = rng.random() < mean_on / (mean_on + mean_off)
        t, ivs = 0.0, []
        while t < horizon:
            dur = float(rng.exponential(mean_on if on else mean_off))
            if on and dur > 0.0:
                ivs.append((t, min(t + dur, float(horizon))))
            t += dur
            on = not on
        intervals.append(ivs)
    return AvailabilityTrace(
        horizon,
        intervals,
        meta={
            "kind": "markov", "n_clients": n_clients,
            "mean_on": mean_on, "mean_off": mean_off, "seed": seed,
        },
    )


def save_trace(trace: AvailabilityTrace, path) -> None:
    with open(path, "w") as f:
        json.dump(
            {
                "horizon": trace.horizon,
                "intervals": [[[s, e] for s, e in ivs] for ivs in trace.intervals],
                "meta": trace.meta,
            },
            f,
        )


def load_trace(path) -> AvailabilityTrace:
    with open(path) as f:
        raw = json.load(f)
    return AvailabilityTrace(
        float(raw["horizon"]),
        [[(float(s), float(e)) for s, e in ivs] for ivs in raw["intervals"]],
        dict(raw.get("meta", {})),
    )
