"""Virtual time + deterministic event heap for the fedsim runtime.

Discrete-event simulation needs exactly two primitives: a clock that only
moves when an event fires (:class:`VirtualClock`) and a priority queue that
pops events in a *reproducible* order (:class:`EventQueue`).  Reproducibility
is the whole point — two events scheduled for the same virtual instant must
pop in the order they were pushed, on every machine, so the heap is keyed by
``(time, seq)`` where ``seq`` is a monotone push counter.  Event payloads are
never compared (dataclass events need no ordering methods).
"""
from __future__ import annotations

import heapq
from typing import Any


class VirtualClock:
    """Simulation time.  Monotone: ``advance_to`` rejects travel backwards."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def advance_to(self, t: float) -> float:
        if t < self.now:
            raise ValueError(f"virtual time cannot go backwards: {t} < {self.now}")
        self.now = float(t)
        return self.now


class EventQueue:
    """Min-heap of ``(time, seq, event)`` — deterministic FIFO tie-breaking."""

    def __init__(self):
        self._heap: list[tuple[float, int, Any]] = []
        self._seq = 0

    def push(self, time: float, event: Any) -> None:
        if time != time:  # NaN would corrupt the heap invariant silently
            raise ValueError("event time is NaN")
        heapq.heappush(self._heap, (float(time), self._seq, event))
        self._seq += 1

    def pop(self) -> tuple[float, Any]:
        time, _, event = heapq.heappop(self._heap)
        return time, event

    def peek_time(self) -> float:
        return self._heap[0][0]

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
