"""Typed events driving the fedsim runtime.

Four event kinds cover the whole asynchronous protocol:

- :class:`ClientJoined` / :class:`ClientDeparted` — churn edges from an
  :mod:`repro.fedsim.availability` trace.  A departure cancels the client's
  in-flight work (its ``epoch`` counter bumps, orphaning any scheduled
  arrival); a (re)join dispatches the client fresh from its *retained* local
  parameters — a returning client carries a stale aligner by construction.
- :class:`ClientUpdateArrived` — the client's uplink (Sigma-ell moments +
  W_RF, classifier piggybacked on T_C flushes) lands at the server at the
  virtual time ``comm.netsim`` computed from its exact wire bytes.  Carries
  the server model version the client was dispatched from, so the consumer
  can compute staleness = version_now - version_at_dispatch.
- :class:`SyncBarrier` — the synchronous scheduler's per-round rendezvous.
- :class:`EdgeUplinkArrived` — two-tier topologies only: an edge whose buffer
  filled merged it and shipped ONE uplink over the backhaul
  (``edge_links``); the server flushes when it lands, not when the edge
  filled.  ``seq`` keys the scheduler's in-flight table holding the merged
  entries.
- :class:`EvalTick` — time-triggered evaluation (``AsyncConfig.
  eval_interval``): accuracy-vs-virtual-time curves get points at a fixed
  cadence instead of only at flush boundaries.

Events hold only host-side bookkeeping (ints/floats); array payloads stay in
the scheduler's pending tables so the heap never compares jax values.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Event:
    """Marker base class (events are ordered by the queue, never by value)."""


@dataclass(frozen=True)
class ClientJoined(Event):
    client: int


@dataclass(frozen=True)
class ClientDeparted(Event):
    client: int


@dataclass(frozen=True)
class ClientUpdateArrived(Event):
    client: int
    version: int  # server model version the client was dispatched from
    epoch: int  # client availability epoch at dispatch (stale if it departed)
    dispatched_at: float  # virtual dispatch time (for latency bookkeeping)


@dataclass(frozen=True)
class SyncBarrier(Event):
    round: int


@dataclass(frozen=True)
class EdgeUplinkArrived(Event):
    edge: int
    seq: int  # key into the scheduler's in-flight edge-uplink table


@dataclass(frozen=True)
class EvalTick(Event):
    index: int
