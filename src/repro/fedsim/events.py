"""Typed events driving the fedsim runtime.

Four event kinds cover the whole asynchronous protocol:

- :class:`ClientJoined` / :class:`ClientDeparted` — churn edges from an
  :mod:`repro.fedsim.availability` trace.  A departure cancels the client's
  in-flight work (its ``epoch`` counter bumps, orphaning any scheduled
  arrival); a (re)join dispatches the client fresh from its *retained* local
  parameters — a returning client carries a stale aligner by construction.
- :class:`ClientUpdateArrived` — the client's uplink (Sigma-ell moments +
  W_RF, classifier piggybacked on T_C flushes) lands at the server at the
  virtual time ``comm.netsim`` computed from its exact wire bytes.  Carries
  the server model version the client was dispatched from, so the consumer
  can compute staleness = version_now - version_at_dispatch.
- :class:`SyncBarrier` — the synchronous scheduler's per-round rendezvous.
- :class:`EdgeUplinkArrived` — two-tier topologies only: an edge whose buffer
  filled merged it and shipped ONE uplink over the backhaul
  (``edge_links``); the server flushes when it lands, not when the edge
  filled.  ``seq`` keys the scheduler's in-flight table holding the merged
  entries.
- :class:`EvalTick` — time-triggered evaluation (``AsyncConfig.
  eval_interval``): accuracy-vs-virtual-time curves get points at a fixed
  cadence instead of only at flush boundaries.

Fault-plane events (the robustness layer):

- :class:`UplinkGaveUp` — ``netsim.uplink_outcome`` exhausted its retry
  budget: the client's update is *lost* (a reported drop, not an infinite
  retransmit loop) and the scheduler re-dispatches it fresh.  Carries the
  same (version, epoch) tags as an arrival so a churned/superseded give-up
  is orphaned identically.
- :class:`ServerCrashed` — the server process dies at a scheduled virtual
  time.  The scheduler restores the last checkpoint
  (``FedRFTCATrainer.restore_state``), rolls its version/flush counters back
  to the checkpoint's, orphans everything in flight, and re-dispatches the
  live cohort after ``restart_delay_s`` — replay from there is
  deterministic.
- :class:`EdgeCrashed` — one edge aggregator dies: its buffered updates and
  any merged uplink it has on the backhaul are lost; the affected clients
  re-dispatch after the restart delay.  No server state is lost, so no
  rollback.

Events hold only host-side bookkeeping (ints/floats); array payloads stay in
the scheduler's pending tables so the heap never compares jax values.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Event:
    """Marker base class (events are ordered by the queue, never by value)."""


@dataclass(frozen=True)
class ClientJoined(Event):
    client: int


@dataclass(frozen=True)
class ClientDeparted(Event):
    client: int


@dataclass(frozen=True)
class ClientUpdateArrived(Event):
    client: int
    version: int  # server model version the client was dispatched from
    epoch: int  # client availability epoch at dispatch (stale if it departed)
    dispatched_at: float  # virtual dispatch time (for latency bookkeeping)


@dataclass(frozen=True)
class SyncBarrier(Event):
    round: int


@dataclass(frozen=True)
class EdgeUplinkArrived(Event):
    edge: int
    seq: int  # key into the scheduler's in-flight edge-uplink table


@dataclass(frozen=True)
class EvalTick(Event):
    index: int


@dataclass(frozen=True)
class RequestArrived(Event):
    """Serving plane (``repro.serve``): one inference/transform request of an
    open-loop arrival process lands at the aligner server.  ``request`` keys
    the load generator's request table (arrays stay host-side, as always).
    ``trace_id`` is the request's distributed-tracing id when head-sampled
    (``-1`` = not traced), so the event stream alone links to span trees."""

    request: int
    trace_id: int = -1


@dataclass(frozen=True)
class RequestCompleted(Event):
    """Serving plane: the batched dispatch holding ``request`` finished at
    this virtual time — per-request latency is completion minus arrival.
    ``trace_id`` mirrors the arrival's sampling decision (``-1`` untraced)."""

    request: int
    trace_id: int = -1


@dataclass(frozen=True)
class UplinkGaveUp(Event):
    client: int
    version: int  # server model version the client was dispatched from
    epoch: int  # availability epoch at dispatch (orphaned on mismatch)
    dispatched_at: float


@dataclass(frozen=True)
class ServerCrashed(Event):
    """Scheduled server failure: restore last checkpoint, replay."""


@dataclass(frozen=True)
class EdgeCrashed(Event):
    """Scheduled edge-aggregator failure: its buffer + backhaul uplink lost."""

    edge: int
