"""repro.fedsim — event-driven asynchronous federated runtime.

Discrete-event simulation over the FedRF-TCA trainer: a virtual clock and a
deterministic event heap (``clock``), typed churn/arrival/barrier events
(``events``), replayable client-availability traces (``availability``), and
two schedulers sharing one API (``runtime``): the barrier-per-round
:class:`SyncScheduler` (degenerates to ``trainer.train()`` with no churn) and
the FedBuff-style :class:`AsyncScheduler` with staleness-aware buffered
aggregation, whose arrival times come from ``comm.netsim``'s exact wire
bytes — codec choice changes staleness changes learning dynamics.
"""
from repro.fedsim.availability import (
    AvailabilityTrace,
    always_on_trace,
    duty_cycle_trace,
    load_trace,
    markov_trace,
    save_trace,
)
from repro.fedsim.clock import EventQueue, VirtualClock
from repro.fedsim.events import (
    ClientDeparted,
    ClientJoined,
    ClientUpdateArrived,
    EdgeCrashed,
    EdgeUplinkArrived,
    EvalTick,
    Event,
    ServerCrashed,
    SyncBarrier,
    UplinkGaveUp,
)
from repro.fedsim.runtime import AsyncConfig, AsyncScheduler, SyncScheduler
