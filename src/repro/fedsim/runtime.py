"""Event-driven federated runtime: virtual-time schedulers over the trainer.

The fixed round loop of ``FedRFTCATrainer.train`` advances in lockstep — the
only "network" it ever sees is which uplinks a round plan drops.  This module
replaces the loop with a discrete-event simulation (``fedsim.clock``) in
which *time itself* comes from the communication subsystem: a client's update
lands when ``comm.netsim`` says its exact wire bytes have crossed its link,
clients churn on an ``fedsim.availability`` trace, and the server either
waits for everyone (:class:`SyncScheduler`) or aggregates a buffer of
whatever arrived (:class:`AsyncScheduler`).

Two schedulers, one API (``run(n, eval_every) -> history``):

- :class:`SyncScheduler` — barrier per round.  The plan comes from the
  trainer's scenario intersected with the availability trace at the barrier's
  virtual time (offline clients are dropped — the "naive drop-the-stragglers"
  baseline), and the round executes through the ``run_round`` hook, so with
  no churn the trajectory is exactly ``trainer.train()``'s.
- :class:`AsyncScheduler` — FedBuff-style buffered aggregation.  Clients are
  dispatched with the target's current Sigma-ell broadcast, train at their
  own pace, and their uplinks land whenever the link model delivers them; the
  server flushes the buffer every ``buffer_size`` arrivals, weighting each
  update's moment / W_RF / classifier contribution by its staleness
  (``federated.aggregation.staleness_weights``: constant | polynomial |
  auto).  With uniform latencies, no churn and ``buffer_size = K`` every
  flush is a full buffer at staleness 0 and the trajectory degenerates to
  the sync engine's (pinned <= 1e-6 by tests and the bench smoke gate).

Because arrival times follow from exact wire bytes, the *codec* choice
changes arrival order and therefore staleness — the comm subsystem feeds
back into the learning dynamics instead of only into byte accounting.  The
T_C-interval classifier payload is amortized into every uplink's wire bytes
(``netsim.amortized_interval_bytes``), so interval syncs count toward wire
time and backhaul contention too.

Fault plane (the robustness layer): uplinks now ride
``netsim.uplink_outcome`` — a retry budget with exponential backoff instead
of an unbounded retransmit loop — so a hopeless link *gives up*
(:class:`UplinkGaveUp`) and the client re-dispatches fresh.  Scheduled
:class:`ServerCrashed` events restore the trainer's last checkpoint
(``checkpoint/ckpt.py`` via ``FedRFTCATrainer.save_state`` /
``restore_state``, written every ``AsyncConfig.checkpoint_interval_s``
virtual seconds) and replay deterministically; :class:`EdgeCrashed` events
lose one edge's buffer and in-flight backhaul uplinks without touching
server state.

Fleet scale: when the trainer carries a ``repro.fleet.Topology``, the
:class:`AsyncScheduler` keeps one buffer *per edge* — an edge flushes when
its own buffer fills, merges it, and (with ``edge_links``) ships one uplink
across the backhaul; the server flush fires when that merged uplink lands
(:class:`EdgeUplinkArrived`).  ``AsyncConfig.eval_interval`` adds
time-triggered :class:`EvalTick` events for dense accuracy-vs-virtual-time
curves independent of the flush schedule.
"""
from __future__ import annotations

import math
import tempfile
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.comm import wire
from repro.comm.netsim import LinkScenario, amortized_interval_bytes
from repro.federated import aggregation
from repro.federated.network import RoundPlan
from repro.fedsim.availability import AvailabilityTrace
from repro.fedsim.clock import EventQueue, VirtualClock
from repro.fedsim.events import (
    ClientDeparted,
    ClientJoined,
    ClientUpdateArrived,
    EdgeCrashed,
    EdgeUplinkArrived,
    EvalTick,
    ServerCrashed,
    SyncBarrier,
    UplinkGaveUp,
)
from repro.obs.records import CrashRecord, EvalRecord, FlushRecord, RoundRecord


def _per_client(value, k: int, what: str) -> np.ndarray:
    arr = np.full((k,), float(value)) if np.ndim(value) == 0 else np.asarray(value, float)
    if arr.shape != (k,):
        raise ValueError(f"{what} must be a scalar or length-{k} sequence")
    if (arr < 0).any():
        raise ValueError(f"{what} must be >= 0")
    return arr


class _SchedulerBase:
    """Shared plumbing: virtual clock, per-client compute times, link wiring.

    Telemetry: when a global :class:`repro.obs.Tracer` is installed
    (``obs.use_tracer()``), both schedulers emit their episodes — sync
    rounds; async compute / uplink / flush / crash / recovery /
    checkpoint — as spans on the *virtual-time* track, keyed to the
    VirtualClock (client ``i`` on lane ``tid=i+1``, the server on
    ``tid=0``, edge backhauls above the client lanes), so an exported
    Chrome trace reconstructs the whole timeline.  Metrics go to the
    active ``obs`` registry; both default to no-ops.
    """

    @property
    def tracer(self):
        # resolved per use so ``obs.use_tracer()`` around run() works even
        # when the scheduler was constructed outside the context
        return obs.get_tracer()

    def __init__(self, trainer, *, availability, links, compute_s, seed):
        self.trainer = trainer
        self.clock = VirtualClock()
        self.queue = EventQueue()
        self.availability = availability
        self.links = links
        self.compute_s = _per_client(compute_s, trainer.k, "compute_s")
        # wire/compute randomness is a separate stream from the trainer's plan
        # rng — the schedulers must not perturb the scenario draws that make a
        # no-churn SyncScheduler reproduce trainer.train() exactly
        self.rng = np.random.default_rng((trainer.proto.seed, seed, 0xF5ED))
        self.history: list[dict[str, Any]] = []
        if availability is not None and availability.n_clients < trainer.k:
            raise ValueError(
                f"availability trace covers {availability.n_clients} clients, "
                f"trainer has {trainer.k}"
            )
        self.payload_bytes: dict[str, int] = {}
        if links is not None:
            if len(links.links) < trainer.k:
                raise ValueError(f"{len(links.links)} links for {trainer.k} clients")
            # the loop-closing default: arrival times follow the exact wire
            # bytes of THIS trainer's configured codecs.  Kept scheduler-local
            # (the caller's scenario object is never mutated, so one
            # LinkScenario can serve trainers with different codecs).
            self.payload_bytes = dict(links.payload_bytes) or trainer.transport.payload_sizes(
                trainer._specs
            )

    def _uplink_kinds(self) -> tuple[str, ...]:
        proto, kinds = self.trainer.proto, []
        if proto.exchange_messages:
            kinds.append("moments")
        if proto.aggregate_w_rf and not self.trainer._frozen_w:
            kinds.append("w_rf")
        return tuple(kinds)

    def _uplink_nbytes(self) -> float:
        """Wire bytes of one client uplink: the per-round payloads plus the
        expected per-flush share of the T_C-interval classifier sync — so
        interval payloads count toward wire time and backhaul contention."""
        proto = self.trainer.proto
        nbytes = float(sum(self.payload_bytes.get(k, 0) for k in self._uplink_kinds()))
        if proto.aggregate_classifier:
            nbytes += amortized_interval_bytes(
                self.payload_bytes.get("classifier", 0), proto.t_c
            )
        return nbytes

    def _edge_uplink_nbytes(self) -> float:
        """Exact wire bytes of one merged edge -> server uplink: the partial
        merges + masses at the tier-2 codec, with the classifier partial's
        T_C-amortized share.  Shared by both schedulers: the async backhaul
        events and the sync barrier's per-edge leg price the same frame."""
        tr = self.trainer
        nbytes = sum(
            wire.serialized_size(k, tr._edge_specs[k], tr.edge_transport.codecs[k])
            for k in self._uplink_kinds()
        )
        if tr.proto.aggregate_classifier:
            nbytes += amortized_interval_bytes(
                wire.serialized_size(
                    "classifier",
                    tr._edge_specs["classifier"],
                    tr.edge_transport.codecs["classifier"],
                ),
                tr.proto.t_c,
            )
        return nbytes


@dataclass
class AsyncConfig:
    """Knobs of the buffered-asynchronous server.

    ``buffer_size`` is per buffer: the server's single buffer in the flat
    plane, each *edge's* buffer when the trainer carries a fleet topology
    (edges flush their own buffers).  ``eval_interval`` adds time-triggered
    :class:`EvalTick` events every that-many virtual seconds, so
    accuracy-vs-virtual-time curves are dense instead of flush-aligned.

    Fault plane: ``server_crash_times`` / ``edge_crash_times`` schedule
    :class:`ServerCrashed` / :class:`EdgeCrashed` events at fixed virtual
    times (edge crashes are ``(time, edge)`` pairs).  A server crash restores
    the last checkpoint — written every ``checkpoint_interval_s`` virtual
    seconds (flush-aligned) into ``ckpt_dir`` (a temp dir when None) — and
    re-dispatches the live cohort after ``restart_delay_s``; replay from the
    checkpoint is deterministic, so two identical runs stay bitwise equal.
    """

    buffer_size: int = 2
    staleness: str = "constant"  # constant | polynomial[:alpha] | auto
    compute_s: Any = 1.0  # per-client local-training seconds (scalar or (K,))
    eval_interval: float | None = None  # virtual seconds between EvalTicks
    seed: int = 0
    # -- fault plane --------------------------------------------------------
    server_crash_times: tuple = ()  # virtual times of ServerCrashed events
    edge_crash_times: tuple = ()  # (time, edge) pairs of EdgeCrashed events
    restart_delay_s: float = 1.0  # crash -> first re-dispatch delay
    checkpoint_interval_s: float | None = None  # virtual s between checkpoints
    ckpt_dir: str | None = None  # checkpoint directory (temp dir when None)


class SyncScheduler(_SchedulerBase):
    """Barrier-per-round scheduler: the existing protocol on a virtual clock.

    Each round: draw the plan from the trainer's scenario (same rng stream as
    ``trainer.train()``), drop clients the availability trace says are offline
    at the barrier — stragglers and churned clients are simply *lost* for the
    round, the paper's Table III worldview — execute via the ``run_round``
    hook, then advance the clock to the barrier: the deadline if a link
    scenario enforces one, else the slowest participant's completion, else
    ``round_s``.

    With ``edge_links`` (two-tier topologies), each active edge adds an
    explicit backhaul leg: the edge forwards its merged round payload to the
    server only after its slowest member completes, so the barrier is
    ``max over edges (slowest member + edge uplink)`` — previously the
    backhaul was silently folded into client links only.
    """

    def __init__(
        self,
        trainer,
        *,
        availability: AvailabilityTrace | None = None,
        links: LinkScenario | None = None,
        edge_links: LinkScenario | None = None,
        round_s: float = 1.0,
        compute_s: Any = 1.0,
        seed: int = 0,
    ):
        super().__init__(
            trainer, availability=availability, links=links, compute_s=compute_s, seed=seed
        )
        if edge_links is not None:
            if trainer.topology is None:
                raise ValueError("edge_links need a fleet topology on the trainer")
            if len(edge_links.links) < trainer.topology.n_edges:
                raise ValueError(
                    f"{len(edge_links.links)} edge links for "
                    f"{trainer.topology.n_edges} edges"
                )
        self.edge_links = edge_links
        self.round_s = float(round_s)

    def _round_duration(self, plan: RoundPlan) -> float:
        if self.links is None and self.edge_links is None:
            return self.round_s
        if self.links is not None and np.isfinite(self.links.deadline_s):
            return float(self.links.deadline_s)  # the barrier waits out the deadline
        nbytes = self._uplink_nbytes() if self.links is not None else 0.0
        # a gave-up uplink (inf) is a straggler LOST to the round, not one
        # the barrier waits forever for
        done: dict[int, float] = {}
        for i in plan.msg_clients:
            t = self.compute_s[i] + (
                self.links.uplink_time(self.rng, i, nbytes)
                if self.links is not None
                else 0.0
            )
            if math.isfinite(t):
                done[i] = t
        if self.edge_links is None:
            return max(done.values(), default=self.round_s)
        # explicit per-edge backhaul leg: each active edge forwards its merged
        # payload once its slowest surviving member lands; an edge whose
        # backhaul gives up (inf) loses the round like a straggler client
        topo = self.trainer.topology
        e_bytes = self._edge_uplink_nbytes()
        times = []
        for e in topo.edges_of(list(done)):
            slowest = max(done[i] for i in done if topo.edge_of(i) == e)
            leg = self.edge_links.uplink_time(self.rng, e, e_bytes)
            if math.isfinite(leg):
                times.append(slowest + leg)
        return max(times, default=self.round_s)

    def run(self, n_rounds: int, eval_every: int = 0) -> list[dict[str, Any]]:
        tr = self.trainer
        for t in range(1, n_rounds + 1):
            plan = tr.scenario.plan(tr.rng, tr.k, t)
            if self.availability is not None:
                online = set(self.availability.available_at(self.clock.now))
                plan = RoundPlan(
                    [i for i in plan.msg_clients if i in online],
                    [i for i in plan.w_clients if i in online],
                    [i for i in plan.c_clients if i in online],
                )
            start = self.clock.now
            tr.run_round(t, plan)
            self.queue.push(self.clock.now + self._round_duration(plan), SyncBarrier(t))
            barrier_t, _ = self.queue.pop()
            self.clock.advance_to(barrier_t)
            row = RoundRecord(
                t=self.clock.now, round=t, participants=len(plan.msg_clients)
            )
            if eval_every and t % eval_every == 0:
                row["acc"] = tr.evaluate()
            self.history.append(row)
            tracer = self.tracer
            if tracer is not None:
                tracer.begin(
                    "round", start, args={"round": t, "participants": row.participants}
                )
                tracer.end("round", self.clock.now)
            reg = obs.metrics()
            reg.counter("fedsim.rounds").inc()
            reg.histogram("fedsim.round_s").observe(self.clock.now - start)
        tr.flush_probes()  # drain the one-step probe pipeline
        return self.history


class AsyncScheduler(_SchedulerBase):
    """FedBuff-style buffered-asynchronous scheduler (see module docstring).

    Lifecycle per client: *dispatch* (draw batches, hand over the target's
    current broadcast, tag with the server model version) -> local compute
    (``compute_s`` virtual seconds) -> uplink (``links.uplink_time`` over the
    exact wire bytes, shared-backhaul contention included) ->
    :class:`ClientUpdateArrived`.  Every ``buffer_size`` arrivals the server
    flushes: one compiled ``engine.flush`` call materializes the buffered
    clients' local steps, trains the target on their staleness-weighted
    moments, and merges W_RF (+ classifier every ``t_c``-th flush), then the
    consumed clients are re-dispatched.  Churn edges from the availability
    trace cancel in-flight work (departure bumps the client's epoch, orphaning
    its arrival event) and re-dispatch on rejoin from the client's *retained*
    — now stale — local parameters.
    """

    def __init__(
        self,
        trainer,
        cfg: AsyncConfig | None = None,
        *,
        availability: AvailabilityTrace | None = None,
        links: LinkScenario | None = None,
        edge_links: LinkScenario | None = None,
    ):
        cfg = cfg or AsyncConfig()
        if trainer._engine is None:
            raise ValueError("AsyncScheduler needs the batched engine (engine='batched')")
        topo = trainer.topology
        if topo is None:
            if not 1 <= cfg.buffer_size <= max(trainer.k, 1):
                raise ValueError(f"buffer_size must be in [1, K={trainer.k}]")
            if edge_links is not None:
                raise ValueError("edge_links need a fleet topology on the trainer")
        else:
            smallest = min(len(topo.members(e)) for e in range(topo.n_edges))
            if not 1 <= cfg.buffer_size <= smallest:
                raise ValueError(
                    f"buffer_size must be in [1, {smallest}] (smallest edge) "
                    f"for this topology"
                )
            if edge_links is not None and len(edge_links.links) < topo.n_edges:
                raise ValueError(
                    f"{len(edge_links.links)} edge links for {topo.n_edges} edges"
                )
        if cfg.eval_interval is not None and cfg.eval_interval <= 0:
            raise ValueError(f"eval_interval must be > 0, got {cfg.eval_interval}")
        if cfg.checkpoint_interval_s is not None and cfg.checkpoint_interval_s <= 0:
            raise ValueError(
                f"checkpoint_interval_s must be > 0, got {cfg.checkpoint_interval_s}"
            )
        if cfg.restart_delay_s < 0:
            raise ValueError(f"restart_delay_s must be >= 0, got {cfg.restart_delay_s}")
        n_edges = topo.n_edges if topo is not None else 1
        for item in cfg.edge_crash_times:
            ct, e = item
            if not 0 <= int(e) < n_edges:
                raise ValueError(f"edge crash {item}: edge id out of range [0, {n_edges})")
            if ct < 0:
                raise ValueError(f"edge crash {item}: time must be >= 0")
        if any(ct < 0 for ct in cfg.server_crash_times):
            raise ValueError(f"server crash times must be >= 0: {cfg.server_crash_times}")
        aggregation.staleness_weights(np.zeros(1), cfg.staleness)  # validate mode early
        super().__init__(
            trainer,
            availability=availability,
            links=links,
            compute_s=cfg.compute_s,
            seed=cfg.seed,
        )
        self.cfg = cfg
        self.version = 0  # server model version (== completed flushes)
        self.flushes = 0
        self.dispatches = 0
        self.live: set[int] = set()
        self.epoch = np.zeros(trainer.k, dtype=np.int64)
        self.pending: dict[int, dict] = {}  # client -> dispatch record (in flight)
        # one buffer per edge (the flat plane is the single pseudo-edge 0);
        # an edge flushes when ITS buffer fills, not the global arrival count
        self.topology = topo
        self._n_edges = topo.n_edges if topo is not None else 1
        self.buffers: dict[int, list[dict]] = {e: [] for e in range(self._n_edges)}
        self.edge_links = edge_links
        self._edge_seq = 0
        # seq -> (edge, merged entries): the edge id is kept so an EdgeCrashed
        # event can cancel that edge's in-flight backhaul uplinks
        self._edge_uplinks: dict[int, tuple[int, list[dict]]] = {}
        self._edge_inflight: list[tuple[float, float]] = []  # backhaul (finish, bytes)
        self._inflight: list[tuple[float, float]] = []  # (finish_time, bytes) uplinks
        self._n_k = np.array([d.x.shape[1] for d in trainer.sources], dtype=np.int64)
        # -- fault plane: give-up accounting + crash/checkpoint state ---------
        self.giveups = 0  # uplinks lost to exhausted retry budgets
        self.recoveries: list[dict[str, Any]] = []  # one row per server recovery
        self._ckpt_dir = cfg.ckpt_dir
        self._ckpt_meta: dict[str, Any] | None = None  # {"t", "flushes"} of last ckpt
        self._next_ckpt: float | None = None

    def _edge_of(self, client: int) -> int:
        return self.topology.edge_of(client) if self.topology is not None else 0

    # -- client lifecycle ---------------------------------------------------

    def _dispatch(self, clients, t: float) -> None:
        """Start one local-training task per client, sharing a single target
        broadcast (one downlink per dispatch instant, like the sync round)."""
        tr = self.trainer
        clients = sorted(c for c in clients if c in self.live)
        if not clients:
            return
        self.dispatches += 1
        chan_key = None
        if tr._engine.channel:
            chan_key = jax.random.fold_in(
                jax.random.fold_in(tr._chan_base, 0x00A5), self.dispatches
            )
        tgt_msg = np.asarray(tr.target_message(chan_key=chan_key))
        if tr.proto.exchange_messages:
            tr.transport.account_spec("moments", tr._specs["moments"], count=1)
        tracer = self.tracer
        reg = obs.metrics()
        reg.counter("fedsim.dispatches").inc()
        for i in clients:
            xs, ys, x_msg = tr.draw_client_dispatch(i)
            self.pending[i] = {
                "client": i,
                "version": self.version,
                "xs": xs,
                "ys": ys,
                "x_msg": x_msg,
                "tgt_msg": tgt_msg,
            }
            delivered, delay = self._completion_delay(i, t)
            reg.counter("fedsim.client_dispatches").inc(client=i)
            if tracer is not None:
                compute = float(self.compute_s[i])
                tracer.complete(
                    "compute", t, compute, tid=i + 1,
                    args={"client": i, "version": self.version},
                )
                tracer.complete(
                    "uplink" if delivered else "uplink_giveup",
                    t + compute, delay - compute, tid=i + 1, args={"client": i},
                )
            ev = (
                ClientUpdateArrived(i, self.version, int(self.epoch[i]), t)
                if delivered
                else UplinkGaveUp(i, self.version, int(self.epoch[i]), t)
            )
            self.queue.push(t + delay, ev)

    def _completion_delay(self, i: int, t: float) -> tuple[bool, float]:
        """(delivered, compute + wire seconds).  ``delivered=False`` means the
        link exhausted its retry budget (``netsim.uplink_outcome`` give-up):
        the update is lost at the returned elapsed time and the scheduler will
        re-dispatch the client instead of retransmitting forever."""
        compute = float(self.compute_s[i])
        if self.links is None:
            return True, compute
        start = t + compute
        self._inflight = [(fin, b) for fin, b in self._inflight if fin > start]
        inflight_bytes = sum(b for _, b in self._inflight)
        nbytes = self._uplink_nbytes()
        delivered, wire = self.links.uplink_outcome(
            self.rng, i, nbytes, inflight_bytes=inflight_bytes
        )
        if delivered:
            self._inflight.append((start + wire, nbytes))
        return delivered, compute + wire

    def _on_arrival(self, t: float, ev: ClientUpdateArrived) -> int | None:
        """Buffer the update at the client's edge; return the edge id when
        its buffer just filled (None otherwise)."""
        if ev.epoch != self.epoch[ev.client] or ev.client not in self.live:
            obs.metrics().counter("fedsim.orphaned_arrivals").inc()
            return None  # churned away mid-flight: the update is lost
        entry = self.pending.pop(ev.client, None)
        if entry is None or entry["version"] != ev.version:
            return None  # superseded dispatch (defensive; churn covers this)
        obs.metrics().counter("fedsim.arrivals").inc()
        if self.trainer.proto.exchange_messages:
            self.trainer.transport.account_spec(
                "moments", self.trainer._specs["moments"], count=1
            )
        edge = self._edge_of(ev.client)
        buf = self.buffers[edge]
        # a rejoin can race an unconsumed buffered update: newest wins
        self.buffers[edge] = buf = [e for e in buf if e["client"] != ev.client]
        buf.append(entry)
        return edge if len(buf) >= self.cfg.buffer_size else None

    # -- the edge backhaul (two-tier topologies) ----------------------------
    # (_edge_uplink_nbytes lives on _SchedulerBase — shared with the sync
    # barrier's per-edge backhaul leg)

    def _edge_uplink_delay(self, edge: int, t: float) -> tuple[bool, float]:
        """(delivered, backhaul crossing seconds) of a merged edge uplink
        starting at ``t``, contended against the other edge uplinks in flight.
        ``delivered=False``: the backhaul gave up — the whole merged buffer is
        lost and its clients re-dispatch."""
        self._edge_inflight = [(fin, b) for fin, b in self._edge_inflight if fin > t]
        inflight = sum(b for _, b in self._edge_inflight)
        nbytes = self._edge_uplink_nbytes()
        delivered, delay = self.edge_links.uplink_outcome(
            self.rng, edge, nbytes, inflight_bytes=inflight
        )
        if delivered:
            self._edge_inflight.append((t + delay, nbytes))
        return delivered, delay

    # -- crash-restart: checkpoints + recovery ------------------------------

    @property
    def ckpt_dir(self) -> str:
        if self._ckpt_dir is None:
            self._ckpt_dir = tempfile.mkdtemp(prefix="fedsim_ckpt_")
        return self._ckpt_dir

    def _checkpoint(self, t: float) -> None:
        """Snapshot the full trainer state (arrays + host rng/iterator state,
        ``FedRFTCATrainer.save_state``) tagged with the flush count."""
        self.trainer.save_state(self.ckpt_dir, step=self.flushes)
        self._ckpt_meta = {"t": t, "flushes": self.flushes}
        obs.metrics().counter("fedsim.checkpoints").inc()
        if self.tracer is not None:
            self.tracer.instant("checkpoint", t, args={"flushes": self.flushes})

    def _maybe_checkpoint(self, t: float) -> None:
        if self._next_ckpt is None or t < self._next_ckpt:
            return
        self._checkpoint(t)
        self._next_ckpt = t + self.cfg.checkpoint_interval_s

    def _redispatch_later(self, clients, t: float) -> None:
        """Queue a fresh dispatch for ``clients`` after the restart delay.
        Reuses :class:`ClientJoined` — same grouping (one shared broadcast per
        instant) and the epoch bump orphans anything still in flight."""
        restart = t + self.cfg.restart_delay_s
        for i in sorted(set(clients)):
            self.queue.push(restart, ClientJoined(i))

    def _recover(self, t: float) -> None:
        """ServerCrashed: restore the last checkpoint and replay from it.

        The trainer's arrays, optimizer state, scenario rng, and batch-stream
        positions all rewind to the checkpoint (bitwise —
        ``restore_state``'s contract), the scheduler's version/flush counters
        roll back with them, and everything in flight is orphaned via an
        epoch bump.  Only virtual time and the comm ledger keep running: a
        crash costs wall-clock and bytes, never determinism.
        """
        if self._ckpt_meta is None:
            raise RuntimeError(
                "ServerCrashed before any checkpoint — run() writes one at "
                "t=0 when crash times are configured"
            )
        tr = self.trainer
        tr.restore_state(self.ckpt_dir)
        rollback = t - self._ckpt_meta["t"]
        self.version = self.flushes = self._ckpt_meta["flushes"]
        self.epoch += 1  # orphan every in-flight arrival/give-up
        self.pending.clear()
        self.buffers = {e: [] for e in range(self._n_edges)}
        self._edge_uplinks.clear()
        self._inflight.clear()
        self._edge_inflight.clear()
        row = CrashRecord(
            t=t, crash="server", restored_flush=self.flushes, rollback_s=rollback
        )
        self.recoveries.append(row)
        self.history.append(row)
        reg = obs.metrics()
        reg.counter("fedsim.server_crashes").inc()
        reg.histogram("fedsim.rollback_s").observe(rollback)
        if self.tracer is not None:
            self.tracer.instant("server_crash", t, args={"rollback_s": rollback})
            self.tracer.begin(
                "recovery", t, args={"restored_flush": self.flushes}
            )
            self.tracer.end("recovery", t + self.cfg.restart_delay_s)
        self._redispatch_later(self.live, t)

    def _crash_edge(self, t: float, edge: int) -> None:
        """EdgeCrashed: the edge's buffered updates and its merged uplinks on
        the backhaul are lost; the clients behind them re-dispatch.  Server
        state is intact, so no rollback."""
        lost = [e["client"] for e in self.buffers[edge]]
        self.buffers[edge] = []
        for seq, (e_id, entries) in list(self._edge_uplinks.items()):
            if e_id == edge:
                lost += [e["client"] for e in entries]
                del self._edge_uplinks[seq]
        self.history.append(CrashRecord(t=t, crash="edge", edge=edge, lost=sorted(lost)))
        obs.metrics().counter("fedsim.edge_crashes").inc(edge=edge)
        if self.tracer is not None:
            self.tracer.instant("edge_crash", t, args={"edge": edge, "lost": len(lost)})
        self._redispatch_later(lost, t)

    # -- the buffered flush -------------------------------------------------

    def _flush(self, t: float, entries: list[dict]) -> dict[str, Any]:
        tr = self.trainer
        members = [e["client"] for e in entries]
        staleness = np.array([self.version - e["version"] for e in entries])
        w_members = aggregation.staleness_weights(
            staleness, self.cfg.staleness, n_samples=self._n_k[members]
        )
        k = tr.k
        buf = np.zeros((k,), np.float32)
        wts = np.zeros((k,), np.float32)
        buf[members] = 1.0
        wts[members] = w_members
        # assemble the stacked batch: buffered rows carry their dispatch-time
        # draws; the rest are finite dummies (computed then discarded by the
        # buffer mask — zeros would hit the unit-norm NaN gradient at 0)
        filler = entries[0]
        L, p = tr.proto.local_steps, tr.sources[0].x.shape[0]
        xs = np.empty((L, k, p, tr._b_max), np.float32)
        ys = np.empty((L, k, tr._b_max), np.int32)
        x_msg = np.empty((k, p, tr._mb_max), np.float32)
        tgt_msgs = np.empty((k, 2 * tr.cfg.n_rff), np.float32)
        by_client = {e["client"]: e for e in entries}
        for i in range(k):
            e = by_client.get(i, filler)
            xs[:, i], ys[:, i], x_msg[i] = e["xs"], e["ys"], e["x_msg"]
            tgt_msgs[i] = e["tgt_msg"]
        batch = {
            "xs": jnp.asarray(xs),
            "ys": jnp.asarray(ys),
            "x_msg": jnp.asarray(x_msg),
            "xt_steps": jnp.asarray(tr.draw_target_steps()),
            "tgt_msgs": jnp.asarray(tgt_msgs),
            "bmask": tr._bmask,
            "msg_mask": tr._msg_mask,
        }
        f = self.flushes + 1
        masks = {
            "buf": jnp.asarray(buf),
            "weights": jnp.asarray(wts),
            "do_clf": jnp.asarray(f % tr.proto.t_c == 0),
        }
        out = tr._engine.flush(
            tr._src_stack,
            tr._src_opt_stack,
            tr.tgt_params,
            tr.tgt_opt,
            batch,
            masks,
            chan_key=jax.random.fold_in(tr._chan_base, f),
        )
        (tr._src_stack, tr._src_opt_stack, tr.tgt_params, tr.tgt_opt) = out[:4]
        if tr._engine.probe:
            tr.stash_probes("flush", out[4])
        # host-side accounting, same message counts as the sync round body;
        # the ingress leg collapses to one merged uplink per active edge in
        # the two-tier plane (here: the one edge whose buffer flushed)
        if tr.proto.exchange_messages and members:
            tr.account_ingress("moments", members)
        if tr.proto.aggregate_w_rf and members:
            tr.transport.account_spec("w_rf", tr._specs["w_rf"], count=len(members) + 1)
            tr.account_ingress("w_rf", members)
        if tr.proto.aggregate_classifier and f % tr.proto.t_c == 0 and members:
            tr.transport.account_spec(
                "classifier", tr._specs["classifier"], count=len(members)
            )
            tr.account_ingress("classifier", members)
        tr.comm.rounds += 1
        self.flushes = f
        self.version += 1
        tr.model_version = self.version
        tr.client_versions[members] = self.version
        row = FlushRecord(
            t=t,
            flush=f,
            version=self.version,
            members=sorted(members),
            staleness=staleness.tolist(),
            weights=w_members.tolist(),
        )
        self.history.append(row)
        reg = obs.metrics()
        reg.counter("fedsim.flushes").inc()
        reg.histogram("fedsim.flush_members").observe(len(members))
        for s in row.staleness:
            reg.histogram("fedsim.staleness").observe(s)
        if self.tracer is not None:
            self.tracer.begin(
                "flush", t,
                args={"flush": f, "members": row.members, "staleness": row.staleness},
            )
            self.tracer.end("flush", t)
        return row

    # -- event loop ---------------------------------------------------------

    def _seed_events(self) -> None:
        tr = self.trainer
        if self.availability is None:
            for i in range(tr.k):
                self.queue.push(0.0, ClientJoined(i))
            return
        for i in range(tr.k):
            for time, is_join in self.availability.edges(i):
                self.queue.push(time, ClientJoined(i) if is_join else ClientDeparted(i))

    def run(self, n_flushes: int, eval_every: int = 0) -> list[dict[str, Any]]:
        """Run until ``n_flushes`` buffered aggregations completed (or the
        event queue drains — e.g. every client churned away for good)."""
        tr = self.trainer
        if tr.k == 0:
            raise ValueError("async runtime needs at least one source client")
        self._seed_events()
        if self.cfg.eval_interval is not None:
            self.queue.push(self.cfg.eval_interval, EvalTick(1))
        for ct in self.cfg.server_crash_times:
            self.queue.push(float(ct), ServerCrashed())
        for ct, e in self.cfg.edge_crash_times:
            self.queue.push(float(ct), EdgeCrashed(int(e)))
        if self.cfg.server_crash_times or self.cfg.checkpoint_interval_s is not None:
            self._checkpoint(0.0)  # a crash before the first interval rolls to t=0
            if self.cfg.checkpoint_interval_s is not None:
                self._next_ckpt = self.cfg.checkpoint_interval_s
        while self.queue and self.flushes < n_flushes:
            # same-instant events pop in push order; joins are grouped so
            # simultaneous (re)joins share one dispatch broadcast
            t = self.queue.peek_time()
            self.clock.advance_to(t)
            batch_events = []
            while self.queue and self.queue.peek_time() == t:
                batch_events.append(self.queue.pop()[1])
            joined: list[int] = []
            for ev in batch_events:
                if isinstance(ev, ServerCrashed):
                    # processed ahead of same-instant churn/give-ups: the
                    # epoch bump orphans them and _recover re-dispatches the
                    # whole live cohort anyway
                    self._recover(t)
                elif isinstance(ev, EdgeCrashed):
                    self._crash_edge(t, ev.edge)
                elif isinstance(ev, ClientDeparted):
                    self.live.discard(ev.client)
                    self.epoch[ev.client] += 1
                    self.pending.pop(ev.client, None)
                elif isinstance(ev, ClientJoined):
                    self.live.add(ev.client)
                    self.epoch[ev.client] += 1
                    joined.append(ev.client)
                elif isinstance(ev, UplinkGaveUp):
                    if ev.epoch != self.epoch[ev.client] or ev.client not in self.live:
                        continue  # churned/crashed away: already orphaned
                    entry = self.pending.get(ev.client)
                    if entry is None or entry["version"] != ev.version:
                        continue
                    del self.pending[ev.client]
                    self.giveups += 1
                    obs.metrics().counter("fedsim.giveups").inc(kind="uplink")
                    joined.append(ev.client)  # lost, not looping: dispatch fresh
            if joined:
                self._dispatch(dict.fromkeys(joined), t)
            for ev in batch_events:
                if isinstance(ev, EvalTick):
                    # model state only changes at flushes, so evaluating at
                    # the tick's own time is exact; keep ticking only while
                    # progress is still possible (else the chain would spin
                    # an otherwise-drained queue forever)
                    acc = tr.evaluate()
                    self.history.append(EvalRecord(t=t, eval=ev.index, acc=acc))
                    if self.tracer is not None:
                        self.tracer.instant("eval", t, args={"acc": float(acc)})
                    if self.queue or self.pending or self._edge_uplinks:
                        self.queue.push(
                            t + self.cfg.eval_interval, EvalTick(ev.index + 1)
                        )
                    continue
                ready: list[dict] | None = None
                if isinstance(ev, ClientUpdateArrived):
                    edge = self._on_arrival(t, ev)
                    if edge is None:
                        continue
                    entries, self.buffers[edge] = self.buffers[edge], []
                    if self.edge_links is None:
                        ready = entries  # edge is colocated: flush immediately
                    else:
                        # the edge merges its buffer and ships ONE uplink;
                        # the server flushes when it crosses the backhaul
                        delivered, delay = self._edge_uplink_delay(edge, t)
                        if self.tracer is not None:
                            self.tracer.complete(
                                "edge_uplink" if delivered else "edge_uplink_giveup",
                                t, delay, tid=tr.k + 1 + edge, args={"edge": edge},
                            )
                        if delivered:
                            self._edge_seq += 1
                            self._edge_uplinks[self._edge_seq] = (edge, entries)
                            self.queue.push(
                                t + delay, EdgeUplinkArrived(edge, self._edge_seq)
                            )
                        else:
                            # backhaul gave up: the merged buffer is lost and
                            # its clients re-dispatch at the give-up instant
                            self.giveups += 1
                            obs.metrics().counter("fedsim.giveups").inc(kind="backhaul")
                            for i in sorted({e["client"] for e in entries}):
                                self.queue.push(t + delay, ClientJoined(i))
                        continue
                elif isinstance(ev, EdgeUplinkArrived):
                    item = self._edge_uplinks.pop(ev.seq, None)
                    if item is None:
                        continue  # orphaned by an edge/server crash
                    ready = item[1]
                if ready is None:
                    continue
                row = self._flush(t, ready)
                self._maybe_checkpoint(t)
                if eval_every and self.flushes % eval_every == 0:
                    row["acc"] = tr.evaluate()
                if self.flushes >= n_flushes:
                    break
                self._dispatch(row["members"], t)
        tr.flush_probes()  # drain the one-step probe pipeline
        return self.history
