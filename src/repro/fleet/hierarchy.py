"""Two-tier (edge -> server) merge code, shared by the sync round and the
async flush.

Every FedRF-TCA aggregate is a weighted sum over clients, so it splits
exactly across an edge tier:

    flat:      agg = sum_k w_k x_k            (+ the target's own term)
    two-tier:  S_e = sum_{k in e} w_k x_k,    m_e = sum_{k in e} w_k
               agg = sum_e S_e               (the server combine)

- **W_RF / classifier** (:func:`edge_param_merge` + :func:`server_combine`):
  associativity makes the two-tier merge equal to the flat one for ANY
  topology and ANY participation/staleness weights — reassociation of the
  fp32 sum is the only difference (<= 1e-6 at test configs, bitwise for the
  degenerate E=K topology).
- **Moments** (:func:`edge_moment_merge`): the edge ships the mass-weighted
  mean ``S_e / m_e`` — by linearity of Sigma-ell this IS the exact moment
  message of the edge's pooled member batch (the associative "Sigma-ell sum"
  of the paper).  The target's per-pair MMD then runs over E edge messages
  weighted by their masses.  Whenever at most one member per edge delivers a
  moment in a round (including E=K), this is *identical* to the flat per-pair
  loss; with several concurrent members an edge contributes the union
  population's message — the same estimator family at edge granularity (the
  fleet tests pin both the identity and the pooled-moment equalities).

Per-tier codecs: the tier-1 (client->edge) distortion twins are applied by
the engine to the per-client uplinks exactly as in the flat plane; the tier-2
(edge->server) twins passed here distort the *edge uplink payloads* — the
normalized partial means, so quantization scales stay sane — before the
server combine.  Identity tier-2 codecs leave the partials untouched (no
normalize/denormalize round trip is inserted, keeping the exactness claims
above intact).

The grouped sums route through ``federated.aggregation.edge_weighted_sums``:
the Pallas segment-reduce MXU kernel on TPU, its XLA twin elsewhere — one
merge code path for both engines.  Each merge appends a ones column to the
payload so the partial sums and the masses come out of a single contraction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.obs.registry import get_registry

_MASS_EPS = 1e-9  # empty-edge guard; a zero mass also zeroes the merge weight


def _sums_and_mass(flat, weights, seg_ids, n_edges):
    """((E, D) partial sums, (E,) masses) from one fused segment reduce."""
    # deferred: aggregation sits inside repro.federated, whose __init__ pulls
    # the engine, which imports this module — resolve the cycle at trace time
    from repro.federated.aggregation import edge_weighted_sums

    # runs at trace time only (the merges live inside the jitted planes), so
    # this counts segment-reduce *instantiations per compile*, not executions
    get_registry().counter("fleet.edge_merges").inc(
        clients=flat.shape[0], edges=n_edges
    )
    aug = jnp.concatenate([flat, jnp.ones((flat.shape[0], 1), flat.dtype)], axis=1)
    out = edge_weighted_sums(aug, seg_ids, weights, n_edges)
    return out[:, :-1], out[:, -1]


def edge_moment_merge(
    msgs: jnp.ndarray,  # (K, 2N) per-client Sigma-ell messages
    weights: jnp.ndarray,  # (K,) participation masks x staleness weights
    seg_ids: jnp.ndarray,  # (K,) int edge assignment
    n_edges: int,
    channel=None,  # tier-2 "moments" distortion twin fn(x, key) | None
    chan_key=None,
):
    """Per-edge pooled moment uplinks: ``(pooled (E, 2N), mass (E,))``.

    ``pooled[e]`` is the weighted mean of edge e's member messages — the
    exact Sigma-ell message of the pooled member samples; ``mass[e]`` is the
    weight it carries into the target's per-pair MMD.  With a singleton
    member of weight 1 the pooled row is that member's message bit-for-bit.
    """
    sums, mass = _sums_and_mass(msgs, weights, seg_ids, n_edges)
    pooled = sums / jnp.maximum(mass, _MASS_EPS)[:, None]
    if channel is not None:
        keys = jax.random.split(chan_key, pooled.shape[0])
        pooled = jax.vmap(channel)(pooled, keys)
    return pooled, mass


def edge_param_merge(
    values: jnp.ndarray,  # (K, ...) stacked client payloads (W_RF / a clf leaf)
    weights: jnp.ndarray,  # (K,)
    seg_ids: jnp.ndarray,  # (K,) int edge assignment
    n_edges: int,
    channel=None,  # tier-2 distortion twin fn(x, key) | None
    chan_key=None,
):
    """Per-edge partial parameter sums: ``(sums (E, ...), mass (E,))``.

    With a tier-2 codec the edge uplink payload is the normalized partial
    mean (codec-friendly scale); the server re-weights it by the mass the
    edge reports alongside.  Without one the raw partial sums flow through
    untouched, so the identity-codec hierarchy is pure reassociation.
    """
    flat = values.reshape(values.shape[0], -1)
    sums_flat, mass = _sums_and_mass(flat, weights, seg_ids, n_edges)
    sums = sums_flat.reshape((n_edges,) + values.shape[1:])
    if channel is not None:
        bcast = mass.reshape((-1,) + (1,) * (sums.ndim - 1))
        means = sums / jnp.maximum(bcast, _MASS_EPS)
        keys = jax.random.split(chan_key, n_edges)
        sums = jax.vmap(channel)(means, keys) * bcast
    return sums, mass


def server_combine(sums: jnp.ndarray, mass: jnp.ndarray):
    """Complete the merge from edge partials: ``(sum_e S_e, sum_e m_e)``."""
    return jnp.sum(sums, axis=0), jnp.sum(mass)
