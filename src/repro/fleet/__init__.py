"""repro.fleet — fleet-scale hierarchical federation.

Scales the round engines from K ~ 4 simulated clients to K in the thousands
along two independent axes:

- **Sharded client execution** (``sharding``): stacked per-client state runs
  under ``shard_map`` over a ``clients`` mesh axis, and within each shard a
  memory-bounded ``client_chunk`` scan (``chunked_vmap``) keeps the local-step
  working set O(chunk) instead of O(K).
- **Two-tier aggregation** (``topology`` + ``hierarchy``): a
  :class:`Topology` assigns clients to edge aggregators; each edge runs the
  masked partial merges (pooled Sigma-ell moments, weighted W_RF/classifier
  partial sums + masses) and ships ONE uplink per payload kind to the server,
  which completes the merge.  Associativity of the weighted sums makes the
  hierarchy exact (see ``hierarchy`` for the precise statement), while
  server-ingress bytes drop from K to E uplinks per kind, with a per-tier
  codec on the edge -> server backhaul.

``ProtocolConfig(topology=..., client_chunk=..., edge_codec=...)`` routes the
batched sync engine and the fedsim ``AsyncScheduler`` (whose edges flush
their own buffers) through this subsystem; ``benchmarks/bench_fleet.py``
records the scaling envelope in ``BENCH_fleet.json``.
"""
from repro.fleet.hierarchy import (
    edge_moment_merge,
    edge_param_merge,
    server_combine,
)
from repro.fleet.sharding import (
    chunked_vmap,
    client_mesh,
    sharded_client_map,
    working_set_proxy,
)
from repro.fleet.topology import Topology
