"""Client-to-edge assignment for two-tier (hierarchical) federation.

A :class:`Topology` partitions the K source clients among E edge aggregators.
Each edge runs the *partial* merges (weighted Σℓ moment sums, weighted W_RF /
classifier sums + their weight masses) over its members and ships ONE uplink
per payload kind to the server, which completes the merge.  Because every
FedRF-TCA aggregate is a weighted sum, the edge→server split is associative:
the server-side combine of edge partials equals the flat K-client merge (see
``repro.fleet.hierarchy`` for the exactness statement and its edge cases).

Topologies are plain host-side data (tuples of ints), JSON-serializable, and
validated eagerly so a bad assignment fails at construction, not inside a
compiled round.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Topology:
    """``assignment[k]`` = the edge aggregator client ``k`` reports to.

    Edge ids must form the contiguous range ``0..E-1`` with every edge
    non-empty — an empty edge would be an aggregator with no clients, which
    is always a configuration bug rather than a degenerate case.
    """

    assignment: tuple[int, ...]

    def __post_init__(self):
        if not self.assignment:
            raise ValueError("topology needs at least one client")
        asg = tuple(int(e) for e in self.assignment)
        object.__setattr__(self, "assignment", asg)
        edges = set(asg)
        if min(edges) < 0 or edges != set(range(len(edges))):
            raise ValueError(
                f"edge ids must be the contiguous range 0..E-1 with no empty "
                f"edges, got {sorted(edges)}"
            )

    @property
    def n_clients(self) -> int:
        return len(self.assignment)

    @property
    def n_edges(self) -> int:
        return max(self.assignment) + 1

    def edge_of(self, client: int) -> int:
        return self.assignment[client]

    def members(self, edge: int) -> list[int]:
        return [k for k, e in enumerate(self.assignment) if e == edge]

    @property
    def segment_ids(self) -> np.ndarray:
        """(K,) int32 edge id per client (the segment-reduce key)."""
        return np.asarray(self.assignment, dtype=np.int32)

    def edge_matrix(self) -> np.ndarray:
        """(E, K) 0/1 float32 membership matrix M: M[e, k] = 1 iff client k
        reports to edge e.  The two-tier merges are ``(M * w) @ values``."""
        m = np.zeros((self.n_edges, self.n_clients), dtype=np.float32)
        m[self.segment_ids, np.arange(self.n_clients)] = 1.0
        return m

    def edges_of(self, clients) -> list[int]:
        """Sorted distinct edges a set of clients reports to (the active
        edge uplinks of a round whose participants are ``clients``)."""
        return sorted({self.assignment[c] for c in clients})

    # -- constructors --------------------------------------------------------

    @staticmethod
    def uniform(n_clients: int, n_edges: int) -> "Topology":
        """Contiguous near-equal blocks: clients ``[k*K/E, (k+1)*K/E)`` per edge."""
        if not 1 <= n_edges <= n_clients:
            raise ValueError(f"need 1 <= n_edges={n_edges} <= n_clients={n_clients}")
        return Topology(tuple(int(k * n_edges // n_clients) for k in range(n_clients)))

    @staticmethod
    def singleton(n_clients: int) -> "Topology":
        """E = K: every client is its own edge — the degeneracy topology the
        two-tier-equals-flat tests pin down."""
        return Topology(tuple(range(n_clients)))

    @staticmethod
    def star(n_clients: int) -> "Topology":
        """E = 1: one edge aggregates the whole fleet (a flat system whose
        single uplink is the pooled merge)."""
        return Topology((0,) * n_clients)

    @staticmethod
    def of_groups(groups) -> "Topology":
        """From explicit member lists: ``of_groups([[0, 2], [1, 3]])``."""
        asg: dict[int, int] = {}
        for e, members in enumerate(groups):
            if not members:
                raise ValueError(f"group {e} is empty (an edge needs members)")
            for k in members:
                if k in asg:
                    raise ValueError(f"client {k} assigned to edges {asg[k]} and {e}")
                asg[int(k)] = e
        if sorted(asg) != list(range(len(asg))):
            raise ValueError(f"clients must be the contiguous range 0..K-1, got {sorted(asg)}")
        return Topology(tuple(asg[k] for k in range(len(asg))))
