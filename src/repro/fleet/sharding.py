"""Sharded + memory-bounded execution over the stacked client axis.

The batched round engine stacks per-client state on a leading K axis and
``vmap``s the local-step body across it — one compiled dispatch, but a
working set proportional to K.  At fleet scale (K in the thousands) that
O(K) working set is the ceiling, so this module provides the two axes the
fleet plane composes:

- :func:`chunked_vmap` — a drop-in ``vmap`` whose leading axis is consumed
  ``client_chunk`` rows at a time through ``lax.map``: only one chunk of
  activations/gradients is ever live, so the per-device working set of the
  local-step stage is O(chunk), not O(K).  ``chunk=None`` (or chunk >= K) is
  exactly ``jax.vmap`` — the unchunked program, bit for bit.  K that does not
  divide by the chunk is padded by repeating row 0 (finite values — zero rows
  would hit the extractor's unit-norm NaN gradient) and sliced back after.
- :func:`client_mesh` / :func:`sharded_client_map` — ``shard_map`` over a
  ``clients`` mesh axis: the stacked arrays are partitioned across devices,
  every shard runs the same (optionally chunked) per-client body on its K/D
  rows, and no collective is needed because the fleet plane's cross-client
  reductions happen in the edge/server merge, not in the local step.  On one
  host a 1-device mesh is the mocked-mesh path the bitwise equivalence tests
  run; the same code lowers to a real multi-device mesh unchanged.

:func:`working_set_proxy` is the measurable twin of the O(chunk) claim: the
largest intermediate the traced program materializes, read from the jaxpr —
the quantity ``BENCH_fleet.json`` records against K.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P


def chunked_vmap(fn, in_axes, *, chunk: int | None):
    """``jax.vmap(fn, in_axes)`` evaluated ``chunk`` rows at a time.

    ``in_axes`` must be a tuple of ``0`` (mapped on the leading axis) or
    ``None`` (broadcast).  Outputs are assumed mapped on axis 0, like the
    engine's per-client bodies.  With ``chunk=None`` (or >= K) this *is*
    ``jax.vmap`` — same program, bitwise.  Otherwise the mapped inputs are
    reshaped to ``(K/chunk, chunk, ...)`` and fed through ``jax.lax.map``,
    so XLA holds one chunk of the body's intermediates at a time.
    """
    vf = jax.vmap(fn, in_axes=in_axes)
    if chunk is not None and chunk <= 0:
        raise ValueError(f"chunk must be a positive int or None, got {chunk}")

    def run(*args):
        if len(args) != len(in_axes):
            raise ValueError(f"{len(args)} args for in_axes of length {len(in_axes)}")
        mapped_leaves = [
            leaf
            for a, ax in zip(args, in_axes)
            if ax == 0
            for leaf in jax.tree_util.tree_leaves(a)
        ]
        if not mapped_leaves:
            raise ValueError("chunked_vmap needs at least one mapped (axis-0) argument")
        k = mapped_leaves[0].shape[0]
        if chunk is None or chunk >= k:
            return vf(*args)
        n_chunks = -(-k // chunk)
        pad = n_chunks * chunk - k

        def pack(x):
            if pad:
                x = jnp.concatenate([x, jnp.repeat(x[:1], pad, axis=0)], axis=0)
            return x.reshape((n_chunks, chunk) + x.shape[1:])

        packed = tuple(
            jax.tree_util.tree_map(pack, a) if ax == 0 else None
            for a, ax in zip(args, in_axes)
        )

        def body(sliced):
            full = tuple(
                s if ax == 0 else a for s, a, ax in zip(sliced, args, in_axes)
            )
            return vf(*full)

        out = jax.lax.map(body, packed)

        def unpack(x):
            x = x.reshape((n_chunks * chunk,) + x.shape[2:])
            return x[:k] if pad else x

        return jax.tree_util.tree_map(unpack, out)

    return run


def client_mesh(n_shards: int) -> Mesh:
    """A 1-D ``clients`` mesh over the first ``n_shards`` devices.  On a
    single-host CPU run ``n_shards=1`` is the mocked mesh; more devices come
    from ``XLA_FLAGS=--xla_force_host_platform_device_count`` or real TPUs."""
    devs = jax.devices()[:n_shards]
    if len(devs) < n_shards:
        raise ValueError(
            f"need {n_shards} devices for the clients mesh, have {len(devs)};"
            " set XLA_FLAGS=--xla_force_host_platform_device_count"
        )
    return jax.make_mesh((n_shards,), ("clients",), devices=devs)


def sharded_client_map(mesh: Mesh, fn, in_axes, *, chunk: int | None = None):
    """``shard_map`` the (chunked) per-client body over the ``clients`` axis.

    Mapped (axis-0) arguments are partitioned on their leading K axis across
    the mesh; broadcast (``None``) arguments are replicated.  Each shard runs
    :func:`chunked_vmap` on its local rows — the local-step stage has no
    cross-client dependency, so there is nothing to ``psum``; the cross-client
    work (edge/server merges) happens outside, on the gathered outputs.  K
    must divide by the mesh size (callers pad the stacked state once, not per
    round).
    """
    inner = chunked_vmap(fn, in_axes, chunk=chunk)
    spec = tuple(P("clients") if ax == 0 else P() for ax in in_axes)

    def run(*args):
        in_specs = tuple(
            jax.tree_util.tree_map(lambda _: s, a)
            if a is not None
            else s
            for a, s in zip(args, spec)
        )
        out = jax.eval_shape(inner, *args)
        out_specs = jax.tree_util.tree_map(lambda _: P("clients"), out)
        return shard_map(
            inner, mesh=mesh, in_specs=in_specs, out_specs=out_specs
        )(*args)

    return run


def working_set_proxy(fn, *args) -> int:
    """Largest transient intermediate (bytes) the traced ``fn(*args)`` makes.

    Traces ``fn`` to a jaxpr and returns the byte size of the biggest array
    any *compute* primitive produces.  Equations that carry a sub-jaxpr
    (``lax.map``/``scan``/cond wrappers) are charged for their body's
    intermediates instead of their own stacked outputs, and pure
    data-movement primitives (reshape/transpose/concat/slice...) are skipped
    — the stacked carry and its repackings are persistent state (the
    (K, ...) parameters, identical under every chunk size), while the
    compute intermediates are the live activation set the ``client_chunk``
    scan exists to bound.  This is the memory-proxy twin of the O(chunk)
    claim, comparable across chunk sizes the way the kernel VMEM proxies of
    PR 3 are comparable across tiles.
    """
    jaxpr = jax.make_jaxpr(fn)(*args)

    def subjaxprs(params):
        for v in params.values():
            if isinstance(v, jax.core.ClosedJaxpr):
                yield v.jaxpr
            elif isinstance(v, jax.core.Jaxpr):
                yield v
            elif isinstance(v, (tuple, list)):
                for item in v:
                    if isinstance(item, jax.core.ClosedJaxpr):
                        yield item.jaxpr
                    elif isinstance(item, jax.core.Jaxpr):
                        yield item

    data_movement = {
        "reshape", "broadcast_in_dim", "transpose", "squeeze", "expand_dims",
        "concatenate", "pad", "copy", "convert_element_type", "slice",
        "dynamic_slice", "gather", "rev",
    }

    def scan_eqns(jx) -> int:
        worst = 0
        for eqn in jx.eqns:
            subs = list(subjaxprs(eqn.params))
            if subs:
                for sub in subs:
                    worst = max(worst, scan_eqns(sub))
                continue  # wrapper outputs are persistent carry, not live set
            if eqn.primitive.name in data_movement:
                continue  # repackings of persistent state, not live compute
            for var in eqn.outvars:
                aval = var.aval
                if hasattr(aval, "shape") and hasattr(aval, "dtype"):
                    size = int(aval.size) * aval.dtype.itemsize
                    worst = max(worst, size)
        return worst

    return scan_eqns(jaxpr.jaxpr)
