"""Model store for the serving plane: fitted aligner/classifier states.

Entries are keyed by ``(domain_pair, codec, version)`` — the domain pair a
state was fitted on, the wire codec its downlinks use, and a monotone version
tag.  Two policies govern the cache:

- **LRU capacity.**  The store holds at most ``capacity`` entries; a ``put``
  past capacity evicts the least-recently-used entry (a ``get`` hit counts as
  use).  Serving a long tail of domain pairs therefore works with bounded
  memory, and the hit rate is the bench's cache headline.
- **Version-tagged invalidation.**  ``put`` with ``bump=True`` (the refresh
  path — e.g. enough admitted moments accumulated to warrant a re-solve)
  stores the state under ``latest_version + 1`` and drops every older version
  of the same ``(domain_pair, codec)``; a reader that pinned an old version
  gets a miss, never a stale aligner.  Plain admission does NOT bump — the
  refit-free contract is that admitting a client changes no cached version.

All counters (hits / misses / evictions / invalidations) are host-side ints
mirrored into the ``obs`` metrics registry (no-op by default, so serving with
telemetry off is bitwise identical).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any

from repro.obs import metrics

StoreKey = tuple  # (domain_pair, codec, version)


@dataclass
class MomentStats:
    """Incrementally-merged Sigma-ell moment statistics of one domain pair.

    The paper's only data-dependent message is the per-client moment (eq. 2):
    source mean with sign +1, target mean with sign -1, and the fit's
    ``u = Sigma ell`` is ``source_mean - target_mean`` — an associative
    weighted mean, so a new client's moments merge in O(2N) with no refit
    (the same associativity the fleet hierarchy exploits).
    """

    source_mean: Any = None  # (2N,) running mean of source RFF rows
    n_source: int = 0
    target_mean: Any = None  # (2N,) running mean of target RFF rows
    n_target: int = 0
    admitted: int = 0  # clients merged since the state was solved

    def merge(self, moment, n_samples: int, *, role: str = "source") -> None:
        """Fold one admitted client's mean moment vector into the stats.

        ``moment`` is the client's signed Sigma-ell message (sign +1 source,
        -1 target, matching ``federated.model.client_message``); the running
        means store the unsigned row means.
        """
        if role not in ("source", "target"):
            raise ValueError(f"role must be 'source' or 'target', got {role!r}")
        if n_samples <= 0:
            raise ValueError(f"n_samples must be > 0, got {n_samples}")
        sign = 1.0 if role == "source" else -1.0
        mean = sign * moment  # undo the wire sign -> plain row mean
        if role == "source":
            tot = self.n_source + n_samples
            self.source_mean = (
                mean if self.source_mean is None
                else (self.n_source * self.source_mean + n_samples * mean) / tot
            )
            self.n_source = tot
        else:
            tot = self.n_target + n_samples
            self.target_mean = (
                mean if self.target_mean is None
                else (self.n_target * self.target_mean + n_samples * mean) / tot
            )
            self.n_target = tot
        self.admitted += 1

    @property
    def u(self):
        """The fit statistic ``u = source_mean - target_mean`` (None until
        both sides have contributed)."""
        if self.source_mean is None or self.target_mean is None:
            return None
        return self.source_mean - self.target_mean


@dataclass
class StoreEntry:
    """One cached model: the fitted aligner state + serving sidecar."""

    state: Any  # core.rf_tca.RFTCAState
    classifier: Any = None  # optional {"w", "b"} head for predict requests
    stats: MomentStats = field(default_factory=MomentStats)
    fit_kw: dict = field(default_factory=dict)  # enough to refit on refresh
    gram: Any = None  # fit-time merged G_H — enables moment-space re-solve


class ModelStore:
    """LRU-of-fitted-states keyed by ``(domain_pair, codec, version)``."""

    def __init__(self, capacity: int = 8):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._entries: OrderedDict[StoreKey, StoreEntry] = OrderedDict()
        self._latest: dict[tuple, int] = {}  # (domain_pair, codec) -> version
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    @staticmethod
    def _pair_key(domain_pair, codec: str) -> tuple:
        return (tuple(domain_pair), str(codec))

    def latest_version(self, domain_pair, codec: str = "float32") -> int | None:
        """Newest stored version of the pair, or None when absent/evicted."""
        v = self._latest.get(self._pair_key(domain_pair, codec))
        if v is not None and (tuple(domain_pair), str(codec), v) not in self._entries:
            return None  # the LRU evicted the newest version out from under us
        return v

    def get(
        self, domain_pair, codec: str = "float32", version: int | None = None
    ) -> StoreEntry | None:
        """Fetch (and LRU-touch) an entry; ``version=None`` means newest."""
        if version is None:
            version = self._latest.get(self._pair_key(domain_pair, codec))
        key = (tuple(domain_pair), str(codec), version)
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            metrics().counter("serve.store.misses").inc()
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        metrics().counter("serve.store.hits").inc()
        return entry

    def put(
        self,
        domain_pair,
        entry: StoreEntry,
        *,
        codec: str = "float32",
        bump: bool = False,
    ) -> int:
        """Insert ``entry``; returns the version it was stored under.

        ``bump=False`` (default) writes version 0 on first insert and
        *overwrites* the current latest version otherwise — the refit-free
        admission path updates an entry's stats in place and never lands
        here.  ``bump=True`` is the invalidation path: the entry is stored
        under ``latest + 1`` and every older version of the pair is dropped.
        """
        pk = self._pair_key(domain_pair, codec)
        current = self._latest.get(pk)
        if current is None:
            version = 0
        elif bump:
            version = current + 1
            dropped = [k for k in self._entries if k[:2] == pk and k[2] < version]
            for k in dropped:
                del self._entries[k]
            self.invalidations += len(dropped)
            metrics().counter("serve.store.invalidations").inc(len(dropped))
        else:
            version = current
        key = (*pk, version)
        self._entries[key] = entry
        self._entries.move_to_end(key)
        self._latest[pk] = version
        while len(self._entries) > self.capacity:
            old_key, _ = self._entries.popitem(last=False)
            self.evictions += 1
            metrics().counter("serve.store.evictions").inc()
            if self._latest.get(old_key[:2]) == old_key[2]:
                del self._latest[old_key[:2]]
        return version

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: StoreKey) -> bool:
        return tuple(key) in self._entries

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def snapshot(self) -> dict:
        """JSON-ready counters for the bench record."""
        return {
            "capacity": self.capacity,
            "entries": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "invalidations": self.invalidations,
            "hit_rate": self.hit_rate,
        }
