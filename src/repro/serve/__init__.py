"""Adaptation-as-a-service: a persistent aligner server over fitted RF-TCA
states.

The training stack fits aligners; this package *serves* them: a model store
(LRU + version-tagged invalidation), a batching dispatcher that coalesces
concurrent requests into bucketed compiled dispatches, a live-admission path
that joins new clients over the real wire with an incremental moment merge
(no refit), and an open-loop Poisson load generator over the fedsim virtual
clock for the latency/throughput bench (``benchmarks/bench_serve.py``).

Request-level observability attaches via ``AlignerServer.attach``: per-request
span trees (``obs.RequestTracer``), latency SLOs with burn-rate alerting
(``obs.SloEngine``), and RF-MMD drift detection over the moments streamed out
of the probed dispatch planes (``obs.DriftMonitor``) — a confirmed drift alert
triggers ``refresh_from_moments``, a statistics-space re-solve with exactly
one version bump.  Everything is off by default and bitwise inert when off.
"""
from repro.serve.admission import (
    AdmissionGateway,
    AdmissionResult,
    admission_message,
    client_moment,
)
from repro.serve.dispatcher import BatchingDispatcher, Request
from repro.serve.loadgen import LoadResult, poisson_arrivals, run_open_loop, synth_requests
from repro.serve.server import AlignerServer
from repro.serve.store import ModelStore, MomentStats, StoreEntry

__all__ = [
    "AdmissionGateway",
    "AdmissionResult",
    "AlignerServer",
    "BatchingDispatcher",
    "LoadResult",
    "ModelStore",
    "MomentStats",
    "Request",
    "StoreEntry",
    "admission_message",
    "client_moment",
    "poisson_arrivals",
    "run_open_loop",
    "synth_requests",
]
