"""Live client admission: join the federation without a global refit.

The deployment story behind FedRF-TCA's O(1) communication: a *new* device
suffering domain shift streams its Sigma-ell moment vector (2N floats, eq. 2)
to the server and gets back a fitted aligner — total traffic a few KB,
independent of the device's sample count, and the server never re-solves
anything.

The path is real wire end to end (``comm/wire.py``): the client's moments and
the server's aligner response are serialized frames with CRC32 trailers
through a :class:`~repro.comm.transport.WireTransport`, so codecs, integrity
rejects and retry budgets all apply.  Server-side, the moment folds into the
store entry's :class:`~repro.serve.store.MomentStats` by *incremental merge*
(the weighted-mean associativity the fleet hierarchy already exploits) — the
cached aligner's version does not change, which is the refit-free contract
the bench gates.

The aligner states are seed-fused (``w_rf="fused:<seed>"``): the response
carries only the solved (2N, m) matrix plus the fused spec the client already
shares, so the *server* never materializes the (N, p) frequency matrix per
admission — the admitted client re-derives draw-0 omega from the shared seed
(memoized, ``core.rf_tca.fused_transform_omega``) exactly like any fused
transform.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.comm import wire
from repro.comm.transport import Transport, WireTransport, resolve_codecs
from repro.core.rf_tca import RFTCAState
from repro.core.rff import rff_features
from repro.obs import get_tracer, metrics
from repro.serve.store import ModelStore


def client_moment(
    x,
    *,
    n_features: int,
    fused_seed: int,
    sigma: float = 1.0,
    kernel: str = "gauss",
    role: str = "source",
) -> np.ndarray:
    """The joining device's only data-dependent message: sign * mean RFF row.

    Drawn against the shared fused seed, so the client's omega is bit-exactly
    the fit's draw-0 matrix (``kernels.prng.fused_omega``) — the device
    materializes its own (N, p) omega locally; the server never does.
    """
    if role not in ("source", "target"):
        raise ValueError(f"role must be 'source' or 'target', got {role!r}")
    from repro.kernels.prng import fused_omega

    omega = fused_omega(fused_seed, n_features, x.shape[0], sigma=sigma, rf_kernel=kernel)
    sign = 1.0 if role == "source" else -1.0
    return sign * np.asarray(jnp.mean(rff_features(jnp.asarray(x), omega), axis=1))


def admission_message(moment, *, sender: int, version: int = 0) -> wire.Message:
    """Frame the moment vector for the uplink (round = the version the client
    saw advertised; the server echoes its actual latest back)."""
    return wire.moments_message(
        np.asarray(moment, np.float32), sender=sender, round=max(version, 0)
    )


@dataclass
class AdmissionResult:
    """Outcome of one admission: the client's aligner (decoded off the wire)
    plus the accounting the bench gates on."""

    delivered: bool
    state: RFTCAState | None  # the admitted client's aligner (fused spec kept)
    version: int | None  # store version served (unchanged by the admission)
    bytes_up: int = 0  # moments frame bytes (retransmits included)
    bytes_down: int = 0  # aligner response bytes


class AdmissionGateway:
    """Server-side admission endpoint over a model store + wire transport."""

    def __init__(self, store: ModelStore, *, transport: Transport | None = None,
                 seed: int = 0):
        if transport is None:
            transport = WireTransport(resolve_codecs("float32"), seed=seed)
        if transport.codecs["w_rf"].name == "seed_replay":
            # seed_replay replays the seed-derived *init*; admission ships the
            # SOLVED aligner, which is data-dependent and cannot be replayed
            raise ValueError(
                "admission responses carry the solved W_RF; the seed_replay "
                "codec would reconstruct the init instead"
            )
        self.store = store
        self.transport = transport
        self.admissions = 0
        self.failures = 0
        # optional obs.RequestTracer: emits one wall-clock admission span
        # tree (wire decode -> moment merge -> W_RF ship) per admit
        self.reqtrace = None

    def _bytes(self) -> int:
        return int(self.transport.log.bytes_total)

    def _rejects(self) -> int:
        return int(self.transport.log.rejects_total)

    def admit(
        self,
        domain_pair,
        moment_msg: wire.Message,
        *,
        n_samples: int,
        role: str = "source",
        codec: str = "float32",
    ) -> AdmissionResult:
        """Admit one client: merge its moments, return the cached aligner.

        Refit-free by construction — the entry's stats update in place and
        the store version is untouched.  ``delivered=False`` means a wire leg
        exhausted its retry budget (fault injection); the moment is NOT
        merged unless its uplink actually decoded.
        """
        entry = self.store.get(domain_pair, codec)
        if entry is None:
            raise KeyError(f"no fitted aligner for domain pair {domain_pair!r}")
        if entry.state.fused is None:
            raise ValueError(
                "admission requires a seed-fused aligner state "
                '(rf_tca_fit(w_rf="fused:<seed>")) so the client can re-derive '
                "omega from the shared seed"
            )
        version = self.store.latest_version(domain_pair, codec) or 0
        reg = metrics()
        rt = self.reqtrace
        tracer = get_tracer() if rt is not None else None
        wall0 = tracer.wall_now() if tracer is not None else 0.0
        legs: list[tuple[str, float]] = []  # (leg name, wall duration s)
        b0, r0 = self._bytes(), self._rejects()
        t0 = time.perf_counter()
        arrays = self.transport.transfer(moment_msg)
        legs.append(("serve.wire_decode", time.perf_counter() - t0))
        bytes_up = self._bytes() - b0
        reg.counter("serve.admission_bytes").inc(bytes_up, leg="up")
        if arrays is None:
            self.failures += 1
            reg.counter("serve.admission_failures").inc(leg="uplink")
            self._trace(rt, tracer, legs, wall0, b0, r0)
            return AdmissionResult(False, None, version, bytes_up, 0)
        t0 = time.perf_counter()
        entry.stats.merge(arrays["msg"], n_samples, role=role)
        legs.append(("serve.moment_merge", time.perf_counter() - t0))
        t0 = time.perf_counter()
        response = wire.w_rf_message(
            np.asarray(entry.state.w_rf, np.float32),
            sender=-1, round=version, downlink=True,
        )
        b1 = self._bytes()
        decoded = self.transport.transfer(response)
        legs.append(("serve.w_rf_ship", time.perf_counter() - t0))
        bytes_down = self._bytes() - b1
        reg.counter("serve.admission_bytes").inc(bytes_down, leg="down")
        if decoded is None:
            self.failures += 1
            reg.counter("serve.admission_failures").inc(leg="downlink")
            self._trace(rt, tracer, legs, wall0, b0, r0)
            return AdmissionResult(False, None, version, bytes_up, bytes_down)
        client_state = RFTCAState(
            omega=None,
            w_rf=jnp.asarray(decoded["w_rf"]),
            eigvals=entry.state.eigvals,
            fused=entry.state.fused,
        )
        self.admissions += 1
        reg.counter("serve.admissions").inc(role=role)
        self._trace(rt, tracer, legs, wall0, b0, r0)
        return AdmissionResult(True, client_state, version, bytes_up, bytes_down)

    def _trace(self, rt, tracer, legs, wall0: float, b0: int, r0: int) -> None:
        """Close out one admission's telemetry: retry counter + span tree."""
        retries = self._rejects() - r0
        if retries:
            metrics().counter("serve.admission_retries").inc(retries)
        if rt is not None and tracer is not None:
            rt.emit_admission(legs, wall0=wall0)
