"""Aligner server: the persistent adaptation-as-a-service facade.

One object owns the three serving-plane pieces and their policies:

- a :class:`~repro.serve.store.ModelStore` of fitted aligner states (LRU
  capacity + version-tagged invalidation),
- a :class:`~repro.serve.dispatcher.BatchingDispatcher` coalescing concurrent
  transform/predict requests into bucketed compiled dispatches,
- an :class:`~repro.serve.admission.AdmissionGateway` admitting new clients
  over the real wire with an incremental moment merge (no refit).

The server retains the fit data per domain pair, which buys two behaviours
the bench measures: an LRU *miss* on a previously-fitted pair re-solves from
the retained data inside the request path (cache-miss cost is real, counted
in ``refits``), and :meth:`refresh` re-solves on demand and bumps the version
(the invalidation path — e.g. after enough admitted moments accumulate).

Seed-fused fits additionally retain their *statistics* (merged Gram G_H,
mean-discrepancy u, and the solve hyperparameters), which unlocks the
moment-space refresh: :meth:`refresh_from_moments` re-solves W_RF from the
retained Gram and an *updated* target moment — no raw-data pass — and the
attached observability stack (:meth:`attach`) closes the loop: the drift
monitor watches live batch moments streamed out of the probed dispatch
planes and, on a confirmed RF-MMD alert, triggers exactly that refresh
(one version bump, staleness counter reset, reference re-pinned).
"""
from __future__ import annotations

from typing import Any

import numpy as np

from repro.comm.transport import Transport
from repro.core.rf_tca import (
    fused_transform_omega,
    rf_tca_fit,
    rf_tca_fit_with_stats,
    rf_tca_resolve,
)
from repro.core.rff import rff_features
from repro.obs import metrics
from repro.serve.admission import AdmissionGateway, AdmissionResult, admission_message, client_moment
from repro.serve.dispatcher import BatchingDispatcher, Request
from repro.serve.store import ModelStore, StoreEntry

# rf_tca_fit kwargs the statistics-returning fit does not take (the fused
# path ignores them anyway: it requires mode="stream" and never blocks)
_NON_STATS_KW = ("mode", "block")


class AlignerServer:
    """Persistent serving endpoint over cached RF-TCA aligners."""

    def __init__(
        self,
        *,
        capacity: int = 8,
        codec: str = "float32",
        transport: Transport | None = None,
        min_bucket: int = 8,
        max_bucket: int = 256,
        fused_seed: int = 1234,
        seed: int = 0,
        sentinel_prefix: str = "serve",
    ):
        self.store = ModelStore(capacity)
        self.dispatcher = BatchingDispatcher(
            min_bucket=min_bucket, max_bucket=max_bucket,
            sentinel_prefix=sentinel_prefix,
        )
        self.codec = codec
        self.fused_seed = fused_seed
        self.admission = AdmissionGateway(self.store, transport=transport, seed=seed)
        # pair key -> (x_s, x_t, fit_kw): enough to re-solve on miss/refresh
        self._domains: dict[tuple, tuple[Any, Any, dict]] = {}
        # pair key -> retained fit statistics (fused path): gram, u, moments,
        # solve hyperparameters — the moment-space refresh re-solves from these
        self._fit_stats: dict[tuple, dict] = {}
        self.refits = 0
        self.moment_refreshes = 0
        # observability wiring (attach()): all None/off by default, and the
        # serving path with them off is bitwise identical to pre-wiring
        self.slo = None
        self.drift = None
        self.reqtrace = None
        self.virtual_now = 0.0  # stamped by the load generator per batch

    @staticmethod
    def _key(domain_pair) -> tuple:
        return tuple(domain_pair)

    def _solve(self, domain_pair) -> StoreEntry:
        key = self._key(domain_pair)
        x_s, x_t, fit_kw = self._domains[key]
        w_rf = fit_kw.get("w_rf")
        if not (isinstance(w_rf, str) and w_rf.startswith("fused:")):
            state = rf_tca_fit(x_s, x_t, **fit_kw)
            return StoreEntry(state=state, fit_kw=dict(fit_kw))
        stats_kw = {k: v for k, v in fit_kw.items() if k not in _NON_STATS_KW}
        state, fstats = rf_tca_fit_with_stats(x_s, x_t, **stats_kw)
        entry = StoreEntry(state=state, fit_kw=dict(fit_kw), gram=fstats["gram"])
        # Seed the moment ledger with the fit-time statistics so admissions
        # merge against the fit moments and refreshes reconstruct u exactly:
        # target_mean is the mean RFF row of the fit target data and
        # source_mean = u + target_mean (float32-exact consistency with the
        # solved statistic, by construction).
        omega = fused_transform_omega(state, int(np.shape(x_t)[0]))
        t_mean = np.asarray(rff_features(x_t, omega).mean(axis=1), np.float32)
        s_mean = np.asarray(fstats["u"], np.float32) + t_mean
        entry.stats.source_mean = s_mean
        entry.stats.n_source = int(np.shape(x_s)[1])
        entry.stats.target_mean = t_mean
        entry.stats.n_target = int(np.shape(x_t)[1])
        self._fit_stats[key] = {
            "gram": fstats["gram"],
            "source_mean": s_mean,
            "target_mean": t_mean,
            "gamma": fstats["gamma"], "m": fstats["m"],
            "solver": fstats["solver"], "seed": fstats["seed"],
            "fused_spec": state.fused,
        }
        return entry

    def fit_domain(self, domain_pair, x_s, x_t, *, classifier=None, **fit_kw) -> int:
        """Fit and cache an aligner for ``domain_pair``; returns its version.

        Defaults to the seed-fused W_RF path (``w_rf="fused:<fused_seed>"``)
        so admissions can ship the solved matrix alone — pass an explicit
        ``w_rf`` to override.
        """
        fit_kw.setdefault("w_rf", f"fused:{self.fused_seed}")
        self._domains[self._key(domain_pair)] = (x_s, x_t, fit_kw)
        entry = self._solve(domain_pair)
        entry.classifier = classifier
        version = self.store.put(domain_pair, entry, codec=self.codec)
        if self.drift is not None and self._key(domain_pair) in self._fit_stats:
            self.drift.set_reference(
                self._key(domain_pair),
                self._fit_stats[self._key(domain_pair)]["target_mean"],
            )
        return version

    def get_or_fit(self, domain_pair) -> StoreEntry:
        """Store lookup; an LRU miss on a known pair re-solves in-path."""
        entry = self.store.get(domain_pair, self.codec)
        if entry is None:
            if self._key(domain_pair) not in self._domains:
                raise KeyError(f"unknown domain pair {domain_pair!r} (fit_domain first)")
            entry = self._solve(domain_pair)
            self.refits += 1
            metrics().counter("serve.refits").inc()
            self.store.put(domain_pair, entry, codec=self.codec)
        return entry

    def serve(self, requests: list[Request]) -> list[tuple[Request, np.ndarray]]:
        """Dispatch a burst of requests; same-key runs batch together."""
        done: list[tuple[Request, np.ndarray]] = []
        i = 0
        while i < len(requests):
            key = requests[i].key
            j = i
            while j < len(requests) and requests[j].key == key:
                self.dispatcher.submit(requests[j])
                j += 1
            entry = self.get_or_fit(key)
            done.extend(self.dispatcher.flush(entry))
            i = j
        return done

    def warmup(self, domain_pair, *, modes: tuple[str, ...] = ("transform",)) -> int:
        """Compile every bucket rung once (dummy batches) so load runs never
        pay a trace in-path; returns the number of planes compiled."""
        entry = self.get_or_fit(domain_pair)
        dim = int(np.shape(self._domains[self._key(domain_pair)][0])[0])
        compiled = 0
        for mode in modes:
            b = self.dispatcher.min_bucket
            while True:
                self.dispatcher.submit(Request(
                    x=np.zeros((dim, b), np.float32), key=self._key(domain_pair), mode=mode,
                ))
                self.dispatcher.flush(entry)
                compiled += 1
                if b >= self.dispatcher.max_bucket:
                    break
                b *= 2
        return compiled

    def admit(self, domain_pair, x_client, *, role: str = "source",
              sender: int = 0) -> AdmissionResult:
        """Admit a new client device holding raw samples ``x_client`` (p, n).

        Convenience wrapper running both halves of the protocol in-process:
        the client-side moment + frame (:func:`~repro.serve.admission.
        client_moment`) and the server-side merge + aligner downlink.  The
        wire in between is real (serialize/CRC/codec/retries).
        """
        entry = self.store.get(domain_pair, self.codec)
        if entry is None:
            entry = self.get_or_fit(domain_pair)
        state = entry.state
        if state.fused is None:
            raise ValueError("admission requires a seed-fused aligner "
                             '(fit_domain default, w_rf="fused:<seed>")')
        f_seed, _, f_sigma, f_kernel = state.fused
        moment = client_moment(
            x_client,
            n_features=state.w_rf.shape[0] // 2,
            fused_seed=f_seed, sigma=f_sigma, kernel=f_kernel, role=role,
        )
        version = self.store.latest_version(domain_pair, self.codec) or 0
        msg = admission_message(moment, sender=sender, version=version)
        return self.admission.admit(
            domain_pair, msg,
            n_samples=int(np.shape(x_client)[1]), role=role, codec=self.codec,
        )

    def refresh(self, domain_pair) -> int:
        """Re-solve from retained data and bump the version (invalidation):
        the explicit refresh path, e.g. once ``entry.stats.admitted`` crosses
        a staleness budget.  Returns the new version."""
        old = self.store.get(domain_pair, self.codec)
        entry = self._solve(domain_pair)
        if old is not None:
            entry.classifier = old.classifier
        self.refits += 1
        metrics().counter("serve.refits").inc()
        return self.store.put(domain_pair, entry, codec=self.codec, bump=True)

    # -- observability wiring (request tracing / SLOs / drift) ---------------

    def attach(self, *, slo=None, drift=None, request_tracer=None) -> None:
        """Wire the observability stack into the serving path.

        - ``request_tracer`` (:class:`repro.obs.RequestTracer`) — per-request
          span trees; also handed to the admission gateway for its wire legs.
        - ``slo`` (:class:`repro.obs.SloEngine`) — the load generator feeds
          completion latencies into it (see ``run_open_loop``).
        - ``drift`` (:class:`repro.obs.DriftMonitor`) — switches transform
          dispatches to the probed planes (batch moments stream out of the
          compiled call), pins each fitted pair's target moment as the drift
          reference, and routes alerts to :meth:`refresh_from_moments`.
        """
        if request_tracer is not None:
            self.reqtrace = request_tracer
            self.admission.reqtrace = request_tracer
        if slo is not None:
            self.slo = slo
        if drift is not None:
            self.drift = drift
            drift.on_alert = self._on_drift_alert
            self.dispatcher.moment_hook = self._on_batch_moment
            for key, fs in self._fit_stats.items():
                drift.set_reference(key, fs["target_mean"])

    def rearm_drift(self) -> None:
        """Re-pin every fitted pair's drift reference, clearing the live
        EWMA/window state — e.g. after :meth:`warmup`, whose dummy batches
        would otherwise pollute threshold calibration."""
        if self.drift is None:
            return
        for key, fs in self._fit_stats.items():
            self.drift.set_reference(key, fs["target_mean"])

    def _on_batch_moment(self, key, moment, n_cols: int) -> None:
        """Dispatcher probe callback: one batch's mean RFF row, stamped with
        the load generator's virtual clock."""
        if self.drift is not None:
            self.drift.observe(self._key(key), self.virtual_now, moment, n_cols)

    def _on_drift_alert(self, pair, record) -> None:
        """Confirmed RF-MMD drift on ``pair`` — refresh from live moments."""
        if self._key(pair) in self._fit_stats:
            self.refresh_from_moments(pair)

    def refresh_from_moments(self, domain_pair, target_mean=None,
                             n_target: int | None = None) -> int:
        """Re-solve W_RF from the retained Gram and an updated target moment.

        The drift-driven refresh: ``u_new = source_mean - target_mean`` where
        ``target_mean`` defaults to the drift monitor's recency-weighted live
        moment (:meth:`repro.obs.DriftMonitor.recent_mean`).  The merged Gram
        G_H is covariate-shift-invariant under the fused feature map, so the
        re-solve is one statistics-space eigensolve — no raw-data pass, no
        wire traffic.  Exactly one version bump; the entry's target-side
        ledger resets to the refreshed moment and ``admitted`` restarts (the
        staleness counter); the drift reference re-pins so detection re-arms.
        Returns the new version.
        """
        key = self._key(domain_pair)
        fs = self._fit_stats.get(key)
        if fs is None:
            raise KeyError(
                f"no retained fit statistics for {domain_pair!r} "
                '(moment-space refresh needs a seed-fused fit_domain)'
            )
        if target_mean is None:
            if self.drift is None:
                raise ValueError(
                    "target_mean=None needs an attached DriftMonitor "
                    "(attach(drift=...)) to pool live moments from"
                )
            target_mean, n_target = self.drift.recent_mean(key)
        target_mean = np.asarray(target_mean, np.float32)
        u_new = fs["source_mean"] - target_mean
        old = self.store.get(domain_pair, self.codec)
        state = rf_tca_resolve(
            fs["gram"], u_new, gamma=fs["gamma"], m=fs["m"],
            solver=fs["solver"], seed=fs["seed"], fused_spec=fs["fused_spec"],
        )
        _, _, fit_kw = self._domains[key]
        entry = StoreEntry(state=state, fit_kw=dict(fit_kw), gram=fs["gram"])
        if old is not None:
            entry.classifier = old.classifier
            # source side carries through (admissions included); target side
            # resets to the refreshed moment; admitted restarts at 0
            entry.stats.source_mean = old.stats.source_mean
            entry.stats.n_source = old.stats.n_source
        else:
            entry.stats.source_mean = fs["source_mean"]
        entry.stats.target_mean = target_mean
        entry.stats.n_target = int(n_target) if n_target else 0
        fs["target_mean"] = target_mean
        self.moment_refreshes += 1
        metrics().counter("serve.moment_refreshes").inc()
        version = self.store.put(domain_pair, entry, codec=self.codec, bump=True)
        if self.drift is not None:
            self.drift.set_reference(key, target_mean)
        return version

    def stats(self) -> dict:
        """JSON-ready serving counters (store + dispatcher + admission)."""
        return {
            "store": self.store.snapshot(),
            "dispatcher": self.dispatcher.histogram(),
            "admissions": self.admission.admissions,
            "admission_failures": self.admission.failures,
            "refits": self.refits,
            "moment_refreshes": self.moment_refreshes,
            "wire": {
                "bytes_total": int(self.admission.transport.log.bytes_total),
                "rejects_total": int(self.admission.transport.log.rejects_total),
            },
        }
