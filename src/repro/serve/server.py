"""Aligner server: the persistent adaptation-as-a-service facade.

One object owns the three serving-plane pieces and their policies:

- a :class:`~repro.serve.store.ModelStore` of fitted aligner states (LRU
  capacity + version-tagged invalidation),
- a :class:`~repro.serve.dispatcher.BatchingDispatcher` coalescing concurrent
  transform/predict requests into bucketed compiled dispatches,
- an :class:`~repro.serve.admission.AdmissionGateway` admitting new clients
  over the real wire with an incremental moment merge (no refit).

The server retains the fit data per domain pair, which buys two behaviours
the bench measures: an LRU *miss* on a previously-fitted pair re-solves from
the retained data inside the request path (cache-miss cost is real, counted
in ``refits``), and :meth:`refresh` re-solves on demand and bumps the version
(the invalidation path — e.g. after enough admitted moments accumulate).
"""
from __future__ import annotations

from typing import Any

import numpy as np

from repro.comm.transport import Transport
from repro.core.rf_tca import rf_tca_fit
from repro.obs import metrics
from repro.serve.admission import AdmissionGateway, AdmissionResult, admission_message, client_moment
from repro.serve.dispatcher import BatchingDispatcher, Request
from repro.serve.store import ModelStore, StoreEntry


class AlignerServer:
    """Persistent serving endpoint over cached RF-TCA aligners."""

    def __init__(
        self,
        *,
        capacity: int = 8,
        codec: str = "float32",
        transport: Transport | None = None,
        min_bucket: int = 8,
        max_bucket: int = 256,
        fused_seed: int = 1234,
        seed: int = 0,
    ):
        self.store = ModelStore(capacity)
        self.dispatcher = BatchingDispatcher(min_bucket=min_bucket, max_bucket=max_bucket)
        self.codec = codec
        self.fused_seed = fused_seed
        self.admission = AdmissionGateway(self.store, transport=transport, seed=seed)
        # pair key -> (x_s, x_t, fit_kw): enough to re-solve on miss/refresh
        self._domains: dict[tuple, tuple[Any, Any, dict]] = {}
        self.refits = 0

    @staticmethod
    def _key(domain_pair) -> tuple:
        return tuple(domain_pair)

    def _solve(self, domain_pair) -> StoreEntry:
        x_s, x_t, fit_kw = self._domains[self._key(domain_pair)]
        state = rf_tca_fit(x_s, x_t, **fit_kw)
        return StoreEntry(state=state, fit_kw=dict(fit_kw))

    def fit_domain(self, domain_pair, x_s, x_t, *, classifier=None, **fit_kw) -> int:
        """Fit and cache an aligner for ``domain_pair``; returns its version.

        Defaults to the seed-fused W_RF path (``w_rf="fused:<fused_seed>"``)
        so admissions can ship the solved matrix alone — pass an explicit
        ``w_rf`` to override.
        """
        fit_kw.setdefault("w_rf", f"fused:{self.fused_seed}")
        self._domains[self._key(domain_pair)] = (x_s, x_t, fit_kw)
        entry = self._solve(domain_pair)
        entry.classifier = classifier
        return self.store.put(domain_pair, entry, codec=self.codec)

    def get_or_fit(self, domain_pair) -> StoreEntry:
        """Store lookup; an LRU miss on a known pair re-solves in-path."""
        entry = self.store.get(domain_pair, self.codec)
        if entry is None:
            if self._key(domain_pair) not in self._domains:
                raise KeyError(f"unknown domain pair {domain_pair!r} (fit_domain first)")
            entry = self._solve(domain_pair)
            self.refits += 1
            metrics().counter("serve.refits").inc()
            self.store.put(domain_pair, entry, codec=self.codec)
        return entry

    def serve(self, requests: list[Request]) -> list[tuple[Request, np.ndarray]]:
        """Dispatch a burst of requests; same-key runs batch together."""
        done: list[tuple[Request, np.ndarray]] = []
        i = 0
        while i < len(requests):
            key = requests[i].key
            j = i
            while j < len(requests) and requests[j].key == key:
                self.dispatcher.submit(requests[j])
                j += 1
            entry = self.get_or_fit(key)
            done.extend(self.dispatcher.flush(entry))
            i = j
        return done

    def warmup(self, domain_pair, *, modes: tuple[str, ...] = ("transform",)) -> int:
        """Compile every bucket rung once (dummy batches) so load runs never
        pay a trace in-path; returns the number of planes compiled."""
        entry = self.get_or_fit(domain_pair)
        dim = int(np.shape(self._domains[self._key(domain_pair)][0])[0])
        compiled = 0
        for mode in modes:
            b = self.dispatcher.min_bucket
            while True:
                self.dispatcher.submit(Request(
                    x=np.zeros((dim, b), np.float32), key=self._key(domain_pair), mode=mode,
                ))
                self.dispatcher.flush(entry)
                compiled += 1
                if b >= self.dispatcher.max_bucket:
                    break
                b *= 2
        return compiled

    def admit(self, domain_pair, x_client, *, role: str = "source",
              sender: int = 0) -> AdmissionResult:
        """Admit a new client device holding raw samples ``x_client`` (p, n).

        Convenience wrapper running both halves of the protocol in-process:
        the client-side moment + frame (:func:`~repro.serve.admission.
        client_moment`) and the server-side merge + aligner downlink.  The
        wire in between is real (serialize/CRC/codec/retries).
        """
        entry = self.store.get(domain_pair, self.codec)
        if entry is None:
            entry = self.get_or_fit(domain_pair)
        state = entry.state
        if state.fused is None:
            raise ValueError("admission requires a seed-fused aligner "
                             '(fit_domain default, w_rf="fused:<seed>")')
        f_seed, _, f_sigma, f_kernel = state.fused
        moment = client_moment(
            x_client,
            n_features=state.w_rf.shape[0] // 2,
            fused_seed=f_seed, sigma=f_sigma, kernel=f_kernel, role=role,
        )
        version = self.store.latest_version(domain_pair, self.codec) or 0
        msg = admission_message(moment, sender=sender, version=version)
        return self.admission.admit(
            domain_pair, msg,
            n_samples=int(np.shape(x_client)[1]), role=role, codec=self.codec,
        )

    def refresh(self, domain_pair) -> int:
        """Re-solve from retained data and bump the version (invalidation):
        the explicit refresh path, e.g. once ``entry.stats.admitted`` crosses
        a staleness budget.  Returns the new version."""
        old = self.store.get(domain_pair, self.codec)
        entry = self._solve(domain_pair)
        if old is not None:
            entry.classifier = old.classifier
        self.refits += 1
        metrics().counter("serve.refits").inc()
        return self.store.put(domain_pair, entry, codec=self.codec, bump=True)

    def stats(self) -> dict:
        """JSON-ready serving counters (store + dispatcher + admission)."""
        return {
            "store": self.store.snapshot(),
            "dispatcher": self.dispatcher.histogram(),
            "admissions": self.admission.admissions,
            "admission_failures": self.admission.failures,
            "refits": self.refits,
            "wire": {
                "bytes_total": int(self.admission.transport.log.bytes_total),
                "rejects_total": int(self.admission.transport.log.rejects_total),
            },
        }
