"""Batching dispatcher: coalesce concurrent requests into one compiled call.

The serving analogue of the batched round engine's one-dispatch-per-round
trick: N concurrent transform/predict requests against the same cached
aligner become ONE jit-compiled dispatch over their concatenated sample
columns, padded to a *bucketed* batch width so the jit cache sees a small
closed set of shapes.

- **Buckets.**  ``bucket_for(n)`` rounds the total column count up to the
  next power-of-two rung of the ladder ``min_bucket .. max_bucket``; a burst
  larger than ``max_bucket`` is split across several dispatches.  Each rung
  owns its own compiled plane, wrapped in a jit-retrace sentinel
  (``serve.<mode>.b<bucket>``) so the compile cache is pinned: a rung traces
  exactly once, and the bench/smoke gate fails if a shape-unstable argument
  ever defeats it.
- **Validity masks.**  Padding reuses the ragged-batch machinery from
  ``federated.protocol``: ``_cycle_pad`` fills the pad columns by cycling
  real samples (never zeros) and ``_ragged_mask`` marks the valid columns;
  the compiled body multiplies its output by the mask, so pad columns leave
  the dispatch as exact zeros and per-request slices are taken host-side.
- **Telemetry.**  Batch sizes (requests and valid columns per dispatch) land
  in the metrics registry and in host-side counters for the bench record.
  None of it touches array values — telemetry off is bitwise identical.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rf_tca import fused_transform_omega
from repro.core.rff import rff_features
from repro.federated.protocol import _cycle_pad, _ragged_mask
from repro.obs import metrics, sentinel


@dataclass
class Request:
    """One serving request: transform (aligned features) or predict (logits)
    for a column batch ``x`` (p, n) against a cached domain pair."""

    x: Any  # (p, n) sample columns
    key: Any = None  # domain pair (routing; the dispatcher is per-entry)
    mode: str = "transform"  # transform | predict
    id: int = -1
    arrival: float = 0.0  # virtual arrival time (load generator bookkeeping)

    def __post_init__(self):
        if self.mode not in ("transform", "predict"):
            raise ValueError(f"mode must be 'transform' or 'predict', got {self.mode!r}")


def _transform_body(w_rf, omega, x, mask):
    out = w_rf.T @ rff_features(x, omega)  # (m, bucket)
    return out * mask[None, :]


def _transform_probe_body(w_rf, omega, x, mask):
    """Transform plane with an in-graph moment probe: alongside the served
    output, emit the batch's mean RFF row over *valid* columns — the drift
    monitor's live statistic, computed where the features already live (the
    PR-7 probe pattern: auxiliary outputs, primary output unchanged)."""
    feats = rff_features(x, omega)  # (2N, bucket)
    out = w_rf.T @ feats  # (m, bucket)
    moment = (feats * mask[None, :]).sum(axis=1) / jnp.maximum(mask.sum(), 1.0)
    return out * mask[None, :], moment


def _predict_body(w_rf, omega, clf_w, clf_b, x, mask):
    aligned = w_rf.T @ rff_features(x, omega)  # (m, bucket)
    logits = clf_w.T @ aligned + clf_b[:, None]  # (C, bucket)
    return logits * mask[None, :]


class BatchingDispatcher:
    """Coalesces queued requests into bucketed compiled dispatches."""

    def __init__(
        self, *, min_bucket: int = 8, max_bucket: int = 256,
        sentinel_prefix: str = "serve",
    ):
        if min_bucket < 1 or max_bucket < min_bucket:
            raise ValueError(
                f"need 1 <= min_bucket <= max_bucket, got {min_bucket}, {max_bucket}"
            )
        self.min_bucket = min_bucket
        self.max_bucket = max_bucket
        self.sentinel_prefix = str(sentinel_prefix)
        # (mode, bucket) -> jitted plane; each plane has its own sentinel so
        # the retrace gate is per bucket rung, not per dispatcher
        self._planes: dict[tuple[str, int], Any] = {}
        self.pending: list[Request] = []
        self.dispatches = 0
        self.batch_requests: dict[int, int] = {}  # requests/dispatch -> count
        self.batch_columns: dict[int, int] = {}  # bucket width -> count
        # drift wiring: when set, transform dispatches run the probed plane
        # and hand (domain_pair, batch moment, n_valid_cols) to this callable
        self.moment_hook = None
        self._leg_log: list[tuple[float, float]] = []  # (assemble_s, dispatch_s)

    def bucket_for(self, n_cols: int) -> int:
        """Smallest power-of-two rung >= n_cols (clamped to the ladder)."""
        b = self.min_bucket
        while b < n_cols and b < self.max_bucket:
            b *= 2
        return b

    def _plane(self, mode: str, bucket: int, *, probe: bool = False):
        key = (mode, bucket, probe)
        plane = self._planes.get(key)
        if plane is None:
            if probe:
                body, suffix = _transform_probe_body, ".probe"
            else:
                body = _transform_body if mode == "transform" else _predict_body
                suffix = ""
            plane = jax.jit(sentinel.wrap(
                f"{self.sentinel_prefix}.{mode}.b{bucket}{suffix}", body
            ))
            self._planes[key] = plane
        return plane

    def submit(self, req: Request) -> None:
        self.pending.append(req)
        reg = metrics()
        reg.counter("serve.requests").inc(mode=req.mode)
        reg.gauge("serve.queue_depth").set(len(self.pending))

    def _take_batch(self) -> list[Request]:
        """Pop a head-of-line run of same-mode requests filling <= max_bucket
        columns (requests larger than max_bucket dispatch alone, truncated
        to the ladder is a caller error — their columns must fit one rung)."""
        batch: list[Request] = []
        cols = 0
        mode = self.pending[0].mode
        while self.pending and self.pending[0].mode == mode:
            n = int(np.shape(self.pending[0].x)[1])
            if n > self.max_bucket:
                raise ValueError(
                    f"request has {n} columns > max_bucket={self.max_bucket}"
                )
            if batch and cols + n > self.max_bucket:
                break
            batch.append(self.pending.pop(0))
            cols += n
        return batch

    def _dispatch(self, entry, batch: list[Request]) -> list[np.ndarray]:
        """One compiled call over the batch's concatenated columns."""
        t0 = time.perf_counter()
        state = entry.state
        x = np.concatenate([np.asarray(r.x, np.float32) for r in batch], axis=1)
        n_cols = x.shape[1]
        bucket = self.bucket_for(n_cols)
        x_pad, _ = _cycle_pad(x, None, bucket)
        mask_rows = _ragged_mask([n_cols], bucket)
        mask = (
            np.ones((bucket,), np.float32)
            if mask_rows is None
            else np.asarray(mask_rows[0])
        )
        omega = state.omega
        if omega is None:
            omega = fused_transform_omega(state, x.shape[0])
        mode = batch[0].mode
        probe = self.moment_hook is not None and mode == "transform"
        t1 = time.perf_counter()
        moment = None
        if mode == "predict":
            if entry.classifier is None:
                raise ValueError("predict request against an entry with no classifier")
            out = self._plane(mode, bucket)(
                state.w_rf, omega, entry.classifier["w"], entry.classifier["b"],
                x_pad, mask,
            )
        elif probe:
            out, moment = self._plane(mode, bucket, probe=True)(
                state.w_rf, omega, x_pad, mask
            )
        else:
            out = self._plane(mode, bucket)(state.w_rf, omega, x_pad, mask)
        out = np.asarray(jax.block_until_ready(out))
        t2 = time.perf_counter()
        self._leg_log.append((t1 - t0, t2 - t1))
        self.dispatches += 1
        self.batch_requests[len(batch)] = self.batch_requests.get(len(batch), 0) + 1
        self.batch_columns[bucket] = self.batch_columns.get(bucket, 0) + 1
        reg = metrics()
        reg.counter("serve.dispatches").inc(mode=mode, bucket=bucket)
        reg.histogram("serve.batch_requests").observe(len(batch))
        reg.histogram("serve.batch_fill").observe(n_cols / bucket)
        reg.histogram("serve.dispatch_s").observe(t2 - t1, bucket=bucket)
        if moment is not None:
            self.moment_hook(batch[0].key, np.asarray(moment), n_cols)
        results, off = [], 0
        for r in batch:
            n = int(np.shape(r.x)[1])
            results.append(out[:, off : off + n])
            off += n
        return results

    def take_legs(self) -> list[tuple[float, float]]:
        """Drain the wall-clock ``(assemble_s, dispatch_s)`` pairs logged
        since the last call — the request tracer's processing-leg split."""
        legs, self._leg_log = self._leg_log, []
        return legs

    def flush(self, entry) -> list[tuple[Request, np.ndarray]]:
        """Drain the pending queue against one store entry; returns
        ``(request, result)`` pairs in submission order.  Each head-of-line
        same-mode run becomes one compiled dispatch."""
        done: list[tuple[Request, np.ndarray]] = []
        while self.pending:
            batch = self._take_batch()
            for req, res in zip(batch, self._dispatch(entry, batch)):
                done.append((req, res))
        return done

    def histogram(self) -> dict:
        """JSON-ready batch statistics for the bench record."""
        return {
            "dispatches": self.dispatches,
            "requests_per_dispatch": {
                str(k): v for k, v in sorted(self.batch_requests.items())
            },
            "bucket_widths": {
                str(k): v for k, v in sorted(self.batch_columns.items())
            },
        }
