"""Open-loop Poisson load generation over the fedsim virtual clock.

Arrivals are an open-loop Poisson process: interarrival gaps are exponential
draws at the offered rate, generated up front and pushed as
:class:`~repro.fedsim.events.RequestArrived` events — the generator never
waits for the server, so queueing delay under overload is *measured*, not
hidden (the closed-loop fallacy).

Service is the real thing: when the (single-server) dispatch loop goes idle
and requests are pending, a head-of-line run against one domain pair becomes
an actual compiled dispatch through :class:`~repro.serve.server.AlignerServer`
— wall-clock service time is measured around ``block_until_ready`` and mapped
into virtual seconds, and a :class:`~repro.fedsim.events.RequestCompleted`
event fires per request at the batch's virtual finish time.  Latency is
completion minus arrival, so the p50/p99-vs-offered-load curve in
``BENCH_serve.json`` reflects genuine queueing + batching dynamics: higher
load -> fuller buckets -> better throughput per dispatch, until saturation.

Determinism: the arrival schedule and request mix are pure functions of the
seed.  Service *times* are wall-clock (hence load-dependent), but the event
sequence under a fixed seed replays the identical arrival order (FIFO heap
ties), and the arrays never depend on timing.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.fedsim.clock import EventQueue, VirtualClock
from repro.fedsim.events import RequestArrived, RequestCompleted
from repro.obs import PID_WALL, get_tracer, metrics
from repro.serve.dispatcher import Request


@dataclass
class LoadResult:
    """One load level's measurements (JSON-ready via :meth:`summary`)."""

    offered_rps: float
    latencies: dict[int, float] = field(default_factory=dict)  # id -> seconds
    horizon: float = 0.0  # virtual time of the last completion
    batches: int = 0
    batch_sizes: list[int] = field(default_factory=list)  # requests per batch
    service_scale: float = 1.0  # wall->virtual calibration used for the run

    def summary(self) -> dict:
        lats = np.array(sorted(self.latencies.values()), dtype=np.float64)
        if lats.size == 0:
            raise RuntimeError("load run completed no requests")
        return {
            "offered_rps": self.offered_rps,
            "completed": int(lats.size),
            "throughput_rps": float(lats.size / self.horizon) if self.horizon > 0 else 0.0,
            "p50_ms": float(np.percentile(lats, 50) * 1e3),
            "p99_ms": float(np.percentile(lats, 99) * 1e3),
            "mean_ms": float(lats.mean() * 1e3),
            "mean_batch": float(np.mean(self.batch_sizes)) if self.batch_sizes else 0.0,
            "max_batch": int(max(self.batch_sizes)) if self.batch_sizes else 0,
            "service_scale": float(self.service_scale),
        }


def poisson_arrivals(rate: float, n: int, *, seed: int) -> np.ndarray:
    """Cumulative arrival times of ``n`` Poisson arrivals at ``rate`` req/s."""
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def synth_requests(
    keys,
    *,
    dim: int,
    n_requests: int,
    seed: int,
    cols_lo: int = 4,
    cols_hi: int = 32,
    mode: str = "transform",
    shift: float = 0.0,
) -> list[Request]:
    """A deterministic request mix: random key, random column count.

    ``shift`` offsets every sample column (covariate shift injection for the
    drift bench: requests drawn at ``shift != 0`` simulate a target
    distribution that moved after the aligner was fitted)."""
    rng = np.random.default_rng(seed + 1)
    reqs = []
    for i in range(n_requests):
        key = keys[int(rng.integers(len(keys)))]
        n_cols = int(rng.integers(cols_lo, cols_hi + 1))
        x = (rng.standard_normal((dim, n_cols)) + shift).astype(np.float32)
        reqs.append(Request(x=x, key=key, mode=mode, id=i))
    return reqs


def run_open_loop(
    server,
    requests: list[Request],
    *,
    rate: float,
    seed: int = 0,
    service_scale: float = 1.0,
    slo_objective: str = "serve.latency",
) -> LoadResult:
    """Drive ``requests`` through ``server`` as an open-loop Poisson stream.

    ``service_scale`` maps measured wall seconds of a dispatch into virtual
    seconds (1.0 = real time; must be a positive finite calibration factor);
    the arrival process always runs in virtual time, so offered load and
    service capacity share one clock.

    Observability attached to the server rides along: requests head-sampled
    by ``server.reqtrace`` get full span trees (queue-wait / batch-assembly /
    padded-dispatch legs in virtual time, processing legs mirrored on the
    wall track), completions feed ``server.slo``'s ``slo_objective`` when
    that objective is registered, and ``server.virtual_now`` is stamped
    before every dispatch so drift observations carry virtual timestamps.
    """
    if not (np.isfinite(service_scale) and service_scale > 0):
        raise ValueError(
            f"service_scale must be a positive finite factor, got {service_scale}"
        )
    arrivals = poisson_arrivals(rate, len(requests), seed=seed)
    reqs = list(requests)
    for i, (req, t) in enumerate(zip(reqs, arrivals)):
        req.id = i
        req.arrival = float(t)

    tracer = get_tracer()
    reqtracer = getattr(server, "reqtrace", None)
    slo = getattr(server, "slo", None)
    feed_slo = slo is not None and slo.has(slo_objective)

    def _tid(i: int) -> int:
        if tracer is None or reqtracer is None:
            return -1
        return i if reqtracer.sampled(i) else -1

    clock = VirtualClock()
    queue = EventQueue()
    for req in reqs:
        queue.push(req.arrival, RequestArrived(req.id, trace_id=_tid(req.id)))

    result = LoadResult(offered_rps=rate, service_scale=float(service_scale))
    pending: list[int] = []
    busy_until = 0.0

    def start_batch(now: float) -> float:
        """Serve one head-of-line same-key run; returns its virtual finish."""
        head_key = reqs[pending[0]].key
        batch_ids = [i for i in pending if reqs[i].key == head_key]
        # respect the dispatcher's ladder: one compiled dispatch per batch
        cols, cut = 0, len(batch_ids)
        for j, i in enumerate(batch_ids):
            cols += int(np.shape(reqs[i].x)[1])
            if j > 0 and cols > server.dispatcher.max_bucket:
                cut = j
                break
        batch_ids = batch_ids[:cut]
        server.virtual_now = now
        w0 = tracer.wall_now() if tracer is not None else 0.0
        t0 = time.perf_counter()
        server.serve([reqs[i] for i in batch_ids])
        dt = (time.perf_counter() - t0) * service_scale
        finish = now + dt
        # wall-clock split of the serve into assembly vs compiled dispatch,
        # from the dispatcher's leg log (one pair per compiled call)
        take = getattr(server.dispatcher, "take_legs", None)
        legs = take() if take is not None else []
        assemble = sum(a for a, _ in legs)
        dispatch = sum(d for _, d in legs)
        frac = assemble / (assemble + dispatch) if assemble + dispatch > 0 else 0.5
        for i in batch_ids:
            pending.remove(i)
            tid = i if (reqtracer is not None and reqtracer.active(i)) else -1
            queue.push(finish, RequestCompleted(i, trace_id=tid))
            if tid >= 0:
                arr = reqs[i].arrival
                reqtracer.leg(i, "serve.queue_wait", arr, now - arr)
                reqtracer.leg(i, "serve.batch_assembly", now, dt * frac)
                reqtracer.leg(i, "serve.padded_dispatch",
                              now + dt * frac, dt * (1 - frac))
                # wall twins of the processing legs (measured, not scaled)
                reqtracer.leg(i, "serve.batch_assembly", w0, assemble,
                              pid=PID_WALL)
                reqtracer.leg(i, "serve.padded_dispatch", w0 + assemble,
                              dispatch, pid=PID_WALL)
        result.batches += 1
        result.batch_sizes.append(len(batch_ids))
        if tracer is not None:
            tracer.complete("serve.batch", now, dt,
                            args={"requests": len(batch_ids), "key": str(head_key)})
        metrics().histogram("serve.service_s").observe(dt)
        return finish

    while len(queue):
        t, ev = queue.pop()
        clock.advance_to(t)
        if isinstance(ev, RequestArrived):
            pending.append(ev.request)
            if ev.trace_id >= 0:
                reqtracer.begin(ev.request, t)
        elif isinstance(ev, RequestCompleted):
            result.latencies[ev.request] = t - reqs[ev.request].arrival
            result.horizon = max(result.horizon, t)
            if feed_slo:
                slo.observe(slo_objective, t, result.latencies[ev.request])
            if reqtracer is not None:
                reqtracer.finish(ev.request, t)
        if pending and clock.now >= busy_until:
            busy_until = start_batch(clock.now)

    return result
