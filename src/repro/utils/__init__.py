from repro.utils.tree import (
    tree_size,
    tree_bytes,
    tree_zeros_like,
    tree_add,
    tree_scale,
    tree_mean,
    tree_weighted_mean,
    tree_allclose,
)
