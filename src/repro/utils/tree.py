"""Small pytree helpers used across the framework (no flax/optax available)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree) -> int:
    """Total number of scalar elements in a pytree."""
    return int(sum(np.prod(x.shape) for x in jax.tree_util.tree_leaves(tree)))


def tree_bytes(tree) -> int:
    """Total bytes across all leaves (uses leaf dtype itemsize)."""
    return int(
        sum(np.prod(x.shape) * jnp.dtype(x.dtype).itemsize for x in jax.tree_util.tree_leaves(tree))
    )


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(a, s):
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_mean(trees):
    """Elementwise mean of a non-empty list of pytrees (FedAvg aggregation)."""
    if not trees:
        raise ValueError("tree_mean of empty list")
    acc = trees[0]
    for t in trees[1:]:
        acc = tree_add(acc, t)
    return tree_scale(acc, 1.0 / len(trees))


def tree_weighted_mean(trees, weights):
    """Weighted mean of pytrees; weights normalised to sum 1 (FedAvg with sizes)."""
    if not trees:
        raise ValueError("tree_weighted_mean of empty list")
    ws = np.asarray(weights, dtype=np.float64)
    ws = ws / ws.sum()
    acc = tree_scale(trees[0], float(ws[0]))
    for t, w in zip(trees[1:], ws[1:]):
        acc = tree_add(acc, tree_scale(t, float(w)))
    return acc


def tree_allclose(a, b, rtol=1e-5, atol=1e-6) -> bool:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    if len(la) != len(lb):
        return False
    return all(np.allclose(x, y, rtol=rtol, atol=atol) for x, y in zip(la, lb))
