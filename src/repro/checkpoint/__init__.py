from repro.checkpoint.ckpt import latest, latest_step, restore, save
