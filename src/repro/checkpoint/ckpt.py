"""Minimal dependable pytree checkpointing: npz payload + json treedef.

Handles arbitrary nested dict/list/tuple/NamedTuple pytrees of jnp/np arrays and
python scalars. Atomic via write-to-temp + rename. Keeps ``keep`` most recent
steps (production habit: bounded disk).
"""
from __future__ import annotations

import json
import os
import re
import tempfile

import jax
import numpy as np


def _flatten_with_paths(tree):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    paths = [
        "/".join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))) for k in kp)
        for kp, _ in leaves_with_paths
    ]
    leaves = [v for _, v in leaves_with_paths]
    return paths, leaves


def save(path: str, tree, step: int | None = None, keep: int = 3) -> str:
    """Save pytree. If ``step`` given, writes ``<path>/step_<step>.npz``."""
    if step is not None:
        os.makedirs(path, exist_ok=True)
        target = os.path.join(path, f"step_{step:08d}.npz")
    else:
        os.makedirs(os.path.dirname(os.path.abspath(path)) or ".", exist_ok=True)
        target = path if path.endswith(".npz") else path + ".npz"
    paths, leaves = _flatten_with_paths(tree)
    treedef = jax.tree_util.tree_structure(tree)
    payload = {f"leaf_{i}": np.asarray(leaf) for i, leaf in enumerate(leaves)}
    payload["__paths__"] = np.array(json.dumps(paths))
    payload["__treedef__"] = np.array(str(treedef))
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(target)), suffix=".tmp")
    os.close(fd)
    np.savez(tmp, **payload)
    os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, target)
    if step is not None and keep:
        _gc(path, keep)
    return target


def restore(path: str, like):
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    if os.path.isdir(path):
        path = latest(path)
        if path is None:
            raise FileNotFoundError("no checkpoints in directory")
    data = np.load(path, allow_pickle=False)
    leaves_like = jax.tree_util.tree_leaves(like)
    treedef = jax.tree_util.tree_structure(like)
    leaves = []
    for i, ref in enumerate(leaves_like):
        arr = data[f"leaf_{i}"]
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise ValueError(f"leaf {i}: shape {arr.shape} != expected {np.shape(ref)}")
        leaves.append(arr.astype(np.asarray(ref).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest(ckpt_dir: str) -> str | None:
    if not os.path.isdir(ckpt_dir):
        return None
    files = sorted(f for f in os.listdir(ckpt_dir) if re.match(r"step_\d+\.npz$", f))
    return os.path.join(ckpt_dir, files[-1]) if files else None


def latest_step(ckpt_dir: str) -> int | None:
    f = latest(ckpt_dir)
    return int(re.search(r"step_(\d+)", f).group(1)) if f else None


def _gc(ckpt_dir: str, keep: int) -> None:
    files = sorted(f for f in os.listdir(ckpt_dir) if re.match(r"step_\d+\.npz$", f))
    for f in files[:-keep]:
        os.remove(os.path.join(ckpt_dir, f))
