"""Abstract input specs + shardings for every (arch x input-shape) pair.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct, no
allocation); ``input_pspecs`` the matching PartitionSpec tree. Batch dims
shard over the data axes when divisible; for ``long_500k`` (global_batch=1)
attention caches shard their *sequence* dim over data instead (context
parallelism for the cache), and SSM states shard their head dim over model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models.layers import ShardRules
from repro.models.model import LM


def _bspec(rules: ShardRules, batch: int):
    n_data = rules_data_size(rules)
    return rules.batch if batch % n_data == 0 else None


def rules_data_size(rules: ShardRules) -> int:
    # data axes sizes are fixed by the production mesh: 16 per axis, pod=2
    sizes = {"data": 16, "pod": 2, "model": rules.model_size}
    n = 1
    for a in rules.batch_axes:
        n *= sizes.get(a, 1)
    return n


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        out = {}
        if cfg.embeddings_in:
            out["embeddings"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.dtype)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        if shape.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.family == "vlm":
            out["images"] = jax.ShapeDtypeStruct((b, cfg.n_image_tokens, cfg.d_image), cfg.dtype)
        return out
    # decode: one new token against a seq_len cache
    if cfg.embeddings_in:
        return {"embeddings": jax.ShapeDtypeStruct((b, 1, cfg.d_model), cfg.dtype)}
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}


def input_pspecs(cfg: ModelConfig, shape: InputShape, rules: ShardRules) -> dict:
    bs = _bspec(rules, shape.global_batch)
    out = {}
    for k in input_specs(cfg, shape):
        if k in ("tokens", "labels"):
            out[k] = P(bs, None)
        elif k == "embeddings":
            out[k] = P(bs, None, None)
        elif k == "images":
            out[k] = P(bs, None, None)
    return out


def cache_pspecs(model: LM, shape: InputShape, rules: ShardRules) -> dict:
    """PartitionSpec tree matching LM.cache_shapes()."""
    cfg = model.cfg
    bs = _bspec(rules, shape.global_batch)
    # when the batch can't shard, shard attention cache sequence over data
    seq_spec = None if bs is not None else rules.batch
    m = rules.model_axis

    def leaf_spec(key: str, shp: tuple) -> P:
        # all leaves are layer-stacked: axis 0 = layers/groups
        if key in ("k", "v", "attn_k", "attn_v"):
            # (L, b, S, kv, hd)
            kv_spec = m if shp[3] % rules.model_size == 0 else None
            return P(None, bs, seq_spec, kv_spec, None)
        if key in ("c", "kr"):  # MLA latent: (L, b, S, r)
            return P(None, bs, seq_spec, None)
        if key in ("img_k", "img_v"):  # (n_cross, b, n_img, kv, hd)
            kv_spec = m if shp[3] % rules.model_size == 0 else None
            return P(None, bs, None, kv_spec, None)
        if key == "ssm":  # (L, b, h, p, n)
            h_spec = m if shp[2] % rules.model_size == 0 else None
            return P(None, bs, h_spec, None, None)
        if key == "conv":  # (L, b, w-1, ch)
            ch_spec = m if shp[3] % rules.model_size == 0 else None
            return P(None, bs, None, ch_spec)
        raise KeyError(key)

    shapes = model.cache_shapes(shape.global_batch, shape.seq_len)

    def walk(tree):
        return {
            k: walk(v) if isinstance(v, dict) else leaf_spec(k, v) for k, v in tree.items()
        }

    return walk(shapes)
