"""Production mesh construction (function, not module constant — importing
this module never touches jax device state)."""
from __future__ import annotations

import numpy as np


def _make_mesh(shape, axes, devices):
    """jax.make_mesh across jax versions: ``axis_types`` and
    ``jax.sharding.AxisType`` only exist on newer jax — fall back to a plain
    mesh (equivalent to all-Auto axes) when they don't."""
    import jax

    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(shape, axes, devices=devices)
    return jax.make_mesh(
        shape, axes, devices=devices, axis_types=(AxisType.Auto,) * len(axes)
    )


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) = 256 chips single pod; (2, 16, 16) = 512 chips across 2 pods."""
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devs)}. "
            "Set XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE "
            "importing jax (dryrun.py does this)."
        )
    return _make_mesh(shape, axes, devs[:n])


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (smoke tests, examples)."""
    import jax

    n = len(jax.devices())
    model = max(1, min(model, n))
    data = n // model
    return _make_mesh((data, model), ("data", "model"), jax.devices()[: data * model])


def data_axis_size(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh.shape if a in ("pod", "data")]))
