"""Training driver: any assigned arch (full or reduced) on the host mesh.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
        --steps 200 --batch 8 --seq 128 --ckpt /tmp/ckpt

Full configs target the production mesh (see dryrun.py); --reduced trains the
smoke-scale variant end-to-end on CPU with loss-decrease checks. The FDA MMD
head is active whenever the data mesh has >1 client (or --clients is given).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt_lib
from repro.configs import get_config
from repro.data import TokenStream
from repro.launch.mesh import make_host_mesh
from repro.models import LM, ShardRules
from repro.optim import adamw, apply_updates, clip_by_global_norm, cosine_schedule


def build_train_step(model: LM, opt, n_clients: int):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch, n_clients), has_aux=True
        )(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {**metrics, "loss": loss, "grad_norm": gnorm}

    return train_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--clients", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=20)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, remat=False) if args.reduced else cfg

    mesh = make_host_mesh()
    rules = ShardRules(model_size=int(mesh.shape["model"]), batch_axes=("data",))
    model = LM(cfg, rules)
    n_clients = args.clients or max(2, int(mesh.shape["data"]))
    if args.batch % n_clients:
        n_clients = 1

    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt = adamw(cosine_schedule(args.lr, warmup=10, total=args.steps), weight_decay=0.01)
    opt_state = opt.init(params)
    start_step = 0
    if args.ckpt:
        latest = ckpt_lib.latest_step(args.ckpt)
        if latest is not None:
            params = ckpt_lib.restore(args.ckpt, params)
            start_step = latest
            print(f"restored step {start_step} from {args.ckpt}")

    stream = TokenStream(cfg.vocab_size, args.batch, args.seq, seed=1)
    step_fn = jax.jit(build_train_step(model, opt, n_clients))

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch_np = next(stream)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        if cfg.embeddings_in:
            emb = jax.random.normal(
                jax.random.fold_in(key, step), (args.batch, args.seq, cfg.d_model)
            ) * 0.02
            batch = {"embeddings": emb, "labels": batch["labels"]}
        if cfg.family == "vlm":
            batch["images"] = jnp.zeros((args.batch, cfg.n_image_tokens, cfg.d_image))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if (step + 1) % args.log_every == 0:
            dt = (time.time() - t0) / args.log_every
            toks = args.batch * args.seq / dt
            print(
                f"step {step+1}: loss={losses[-1]:.4f} ce={float(metrics['ce']):.4f} "
                f"mmd={float(metrics['mmd']):.5f} gnorm={float(metrics['grad_norm']):.2f} "
                f"{toks:,.0f} tok/s"
            )
            t0 = time.time()
        if args.ckpt and (step + 1) % 100 == 0:
            ckpt_lib.save(args.ckpt, params, step=step + 1)
    if args.ckpt:
        ckpt_lib.save(args.ckpt, params, step=args.steps)
    first = float(np.mean(losses[:10])) if len(losses) >= 10 else losses[0]
    last = float(np.mean(losses[-10:]))
    print(f"loss: first10={first:.4f} last10={last:.4f} (improved={last < first})")
    return {"first": first, "last": last, "losses": losses}


if __name__ == "__main__":
    main()
