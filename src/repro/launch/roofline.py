"""Roofline-term derivation from compiled AOT artifacts (no TPU at runtime).

Terms per (arch, shape, mesh), all in seconds per step, per chip:

    compute    = HLO_FLOPs_per_chip / PEAK_FLOPS
    memory     = HLO_bytes_per_chip / HBM_BW
    collective = collective_bytes_per_chip / ICI_BW

``cost_analysis`` of the SPMD-partitioned module is per-device; collective
bytes are parsed from the compiled HLO text by summing the *result* shapes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (methodology: result bytes bound the ICI traffic of the
op up to a ring factor, uniform across configs so comparisons are fair).
"""
from __future__ import annotations

import re
from dataclasses import dataclass

# TPU v5e-class hardware constants (per brief)
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

# one result tensor: dtype[d0,d1,...] — dims may be empty (scalar)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind result bytes summed over the module (per device)."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "fusion" in stripped.split("=")[-1][:60] if "=" in stripped else False:
            continue
        for kind in _COLLECTIVES:
            # match `= <type> kind(` or `= <type> kind-start(` (async pairs)
            if re.search(rf"=\s+[^=]*\s{kind}(-start)?\(", stripped):
                lhs = stripped.split("=", 1)[1]
                head = lhs.split(f" {kind}", 1)[0]
                total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head))
                out[kind] += total
                break
    return out


@dataclass
class Roofline:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_by_kind: dict

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "coll_by_kind": self.coll_by_kind,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def from_compiled(compiled) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    colls = collective_bytes(compiled.as_text())
    return Roofline(
        flops_per_chip=flops,
        hbm_bytes_per_chip=hbm,
        coll_bytes_per_chip=float(sum(colls.values())),
        coll_by_kind=colls,
    )


def model_flops(n_params_active: int, n_tokens: int, kind: str) -> float:
    """MODEL_FLOPS: 6·N·D for training (fwd+bwd), 2·N·D for inference."""
    return (6.0 if kind == "train" else 2.0) * n_params_active * n_tokens
