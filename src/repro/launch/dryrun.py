import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.

DOC = """Multi-pod dry-run: AOT lower + compile every (arch x input-shape x mesh).

For each combination this lowers the real step function (train_step for
train_4k, prefill for prefill_32k, decode_step for decode shapes) against
ShapeDtypeStruct inputs on the production mesh, compiles it, and records
memory_analysis / cost_analysis / the collective schedule for §Dry-run and
§Roofline of EXPERIMENTS.md. No arrays are ever allocated.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --arch mamba2-2.7b --shape train_4k --mesh multi
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.configs.base import InputShape, ModelConfig
from repro.launch import roofline as rl
from repro.launch.mesh import data_axis_size, make_production_mesh
from repro.launch.specs import cache_pspecs, input_pspecs, input_specs
from repro.models import LM, ShardRules
from repro.optim import adamw, apply_updates, clip_by_global_norm


def active_params(model: LM) -> int:
    cfg = model.cfg
    total = model.param_count()
    if not cfg.n_experts:
        return total
    routed = cfg.n_layers * 3 * cfg.d_model * cfg.d_ff * cfg.n_experts
    return int(total - routed + routed * cfg.top_k / cfg.n_experts)


def adjusted_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """long_500k needs sub-quadratic attention: SSM/hybrid run natively; all
    attention archs get a 4096-token sliding window (ring-buffer cache)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm",):
        if cfg.family == "hybrid":
            # hybrid shared-attn also windows its ring cache
            return dataclasses.replace(cfg, attn_window=4096)
        return dataclasses.replace(cfg, attn_window=4096)
    return cfg


def _ns(mesh, tree):
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, p), tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def abstract_opt_state(params_abs, opt):
    """AdamState ShapeDtypeStructs mirroring abstract params (fp32 moments)."""
    f32 = lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32)
    from repro.optim.optimizers import AdamState

    return AdamState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree_util.tree_map(f32, params_abs),
        nu=jax.tree_util.tree_map(f32, params_abs),
    )


def opt_pspecs(param_specs):
    from repro.optim.optimizers import AdamState

    return AdamState(step=P(), mu=param_specs, nu=param_specs)


def lower_combo(arch: str, shape_name: str, multi_pod: bool, unroll: bool = False,
                depth: int | None = None, opt: bool = False):
    """Returns (record dict, compiled) for one (arch, shape, mesh).

    opt=True enables the §Perf hillclimb variants (sharded CE, expert-parallel
    MoE, triangular causal attention); default is the paper-faithful baseline.
    """
    mesh = make_production_mesh(multi_pod=multi_pod)
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    rules = ShardRules(model_size=16, batch_axes=batch_axes, mesh=mesh if opt else None)
    shape = INPUT_SHAPES[shape_name]
    cfg = adjusted_config(get_config(arch), shape)
    if unroll:
        cfg = dataclasses.replace(cfg, unroll_scan=True)
    if depth is not None:
        cfg = dataclasses.replace(cfg, n_layers=depth)
    if opt:
        cfg = dataclasses.replace(
            cfg, sharded_ce=True, moe_ep=True, causal_skip=True, seq_parallel=True
        )
    model = LM(cfg, rules)
    n_chips = int(np.prod(list(mesh.shape.values())))
    n_clients = data_axis_size(mesh)

    param_specs = model.specs()
    params_abs = model.abstract()
    batch_abs = input_specs(cfg, shape)
    batch_ps = input_pspecs(cfg, shape, rules)

    t0 = time.time()
    if shape.kind == "train":
        opt = adamw(3e-4, weight_decay=0.1)

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: model.loss(p, batch, n_clients), has_aux=True
            )(params)
            grads, gnorm = clip_by_global_norm(grads, 1.0)
            updates, opt_state = opt.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            metrics = {**metrics, "loss": loss, "grad_norm": gnorm}
            return params, opt_state, metrics

        opt_abs = abstract_opt_state(params_abs, opt)
        o_specs = opt_pspecs(param_specs)
        metrics_specs = {k: P() for k in ("ce", "aux", "mmd", "loss", "grad_norm")}
        fn = jax.jit(
            train_step,
            in_shardings=(_ns(mesh, param_specs), _ns(mesh, o_specs), _ns(mesh, batch_ps)),
            out_shardings=(_ns(mesh, param_specs), _ns(mesh, o_specs), _ns(mesh, metrics_specs)),
        )
        lowered = fn.lower(params_abs, opt_abs, batch_abs)
    elif shape.kind == "prefill":
        def prefill_step(params, batch):
            return model.prefill(params, batch)

        fn = jax.jit(prefill_step, in_shardings=(_ns(mesh, param_specs), _ns(mesh, batch_ps)))
        lowered = fn.lower(params_abs, batch_abs)
    else:  # decode
        cache_abs = model.abstract_cache(shape.global_batch, shape.seq_len)
        cache_ps = cache_pspecs(model, shape, rules)

        def serve_step(params, cache, batch, pos):
            return model.decode_step(params, cache, batch, pos)

        fn = jax.jit(
            serve_step,
            in_shardings=(
                _ns(mesh, param_specs),
                _ns(mesh, cache_ps),
                _ns(mesh, batch_ps),
                NamedSharding(mesh, P()),
            ),
            out_shardings=(NamedSharding(mesh, P(batch_ps[list(batch_ps)[0]][0], None)),
                           _ns(mesh, cache_ps)),
        )
        lowered = fn.lower(
            params_abs, cache_abs, batch_abs, jax.ShapeDtypeStruct((), jnp.int32)
        )
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    roof = rl.from_compiled(compiled)
    n_active = active_params(model)
    n_tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf = rl.model_flops(n_active, n_tokens, shape.kind)
    flops_global = roof.flops_per_chip * n_chips

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": shape.kind,
        "params_total": model.param_count(),
        "params_active": n_active,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "roofline": roof.as_dict(),
        "model_flops_global": mf,
        "useful_flops_ratio": (mf / flops_global) if flops_global else 0.0,
    }
    return record, compiled


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer scans so cost_analysis counts every layer "
                         "(roofline runs); default keeps scan (fast compile)")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    failures = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch}_{shape}_{'2x16x16' if multi else '16x16'}"
                if args.unroll:
                    tag += "_unroll"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip] {tag}")
                    continue
                try:
                    rec, compiled = lower_combo(arch, shape, multi, unroll=args.unroll)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    r = rec["roofline"]
                    print(
                        f"[ok]   {tag}: compile={rec['compile_s']}s "
                        f"flops/chip={r['flops_per_chip']:.3g} "
                        f"bytes/chip={r['hbm_bytes_per_chip']:.3g} "
                        f"coll/chip={r['coll_bytes_per_chip']:.3g} "
                        f"dominant={r['dominant']} "
                        f"useful={rec['useful_flops_ratio']:.2f}"
                    )
                    del compiled
                except Exception as e:  # noqa: BLE001 — report all failures at end
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e}")
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run combos failed: {[t for t, _ in failures]}")
    print("all requested combos lowered + compiled")


if __name__ == "__main__":
    main()
