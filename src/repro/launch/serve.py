"""Serving driver: prefill a batch of prompts, then decode greedily.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
        --prompt-len 64 --gen 32 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import LM, ShardRules


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = LM(cfg, ShardRules(model_size=1))
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    total = args.prompt_len + args.gen
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.embeddings_in:
        emb = jax.random.normal(key, (args.batch, args.prompt_len, cfg.d_model)) * 0.02
        batch = {"embeddings": emb}
    if cfg.family == "vlm":
        batch["images"] = jnp.zeros((args.batch, cfg.n_image_tokens, cfg.d_image))

    t0 = time.time()
    prefill = jax.jit(model.prefill)
    logits, cache = prefill(params, batch)
    # grow attention caches to hold generated tokens
    def grow(path_key, leaf):
        if path_key in ("k", "v", "attn_k", "attn_v"):
            pad = [(0, 0)] * leaf.ndim
            pad[2] = (0, args.gen)
            return jnp.pad(leaf, pad)
        if path_key in ("c", "kr"):
            pad = [(0, 0)] * leaf.ndim
            pad[2] = (0, args.gen)
            return jnp.pad(leaf, pad)
        return leaf

    def walk(tree):
        return {
            k: walk(v) if isinstance(v, dict) else grow(k, v) for k, v in tree.items()
        }

    cache = walk(cache)
    t_prefill = time.time() - t0

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)[:, None]
    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.gen - 1):
        db = {"tokens": tok}
        if cfg.embeddings_in:
            db = {"embeddings": jax.random.normal(key, (args.batch, 1, cfg.d_model)) * 0.02}
        logits, cache = decode(params, cache, db, jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)[:, None]
        out_tokens.append(np.asarray(tok))
    t_decode = time.time() - t0
    gen = np.concatenate(out_tokens, axis=1)
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"prefill {args.prompt_len} toks x{args.batch}: {t_prefill:.2f}s")
    print(f"decode  {args.gen-1} steps x{args.batch}: {t_decode:.2f}s ({tps:,.1f} tok/s)")
    print("sample:", gen[0][:16])
    assert np.isfinite(gen).all()
    return {"prefill_s": t_prefill, "decode_s": t_decode, "tokens": gen}


if __name__ == "__main__":
    main()
