"""Serving driver: prefill a batch of prompts, then decode greedily.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
        --prompt-len 64 --gen 32 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import LM, ShardRules

# every attention-cache leaf grows along axis 2 (the sequence axis), whether
# it is a plain KV pair, a windowed variant, or an MLA latent/rope column
_CACHE_GROW_KEYS = ("k", "v", "attn_k", "attn_v", "c", "kr")


def grow_cache(tree, extra: int, *, keys: tuple[str, ...] = _CACHE_GROW_KEYS):
    """Pad every cache leaf under a growable key by ``extra`` slots on the
    sequence axis (axis 2), recursing through nested dicts."""
    out = {}
    for k, v in tree.items():
        if isinstance(v, dict):
            out[k] = grow_cache(v, extra, keys=keys)
        elif k in keys:
            pad = [(0, 0)] * v.ndim
            pad[2] = (0, extra)
            out[k] = jnp.pad(v, pad)
        else:
            out[k] = v
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = LM(cfg, ShardRules(model_size=1))
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    total = args.prompt_len + args.gen
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.embeddings_in:
        emb = jax.random.normal(key, (args.batch, args.prompt_len, cfg.d_model)) * 0.02
        batch = {"embeddings": emb}
    if cfg.family == "vlm":
        batch["images"] = jnp.zeros((args.batch, cfg.n_image_tokens, cfg.d_image))

    t0 = time.time()
    prefill = jax.jit(model.prefill)
    logits, cache = prefill(params, batch)
    # grow attention caches to hold generated tokens
    cache = grow_cache(cache, args.gen)
    t_prefill = time.time() - t0

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)[:, None]
    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.gen - 1):
        db = {"tokens": tok}
        if cfg.embeddings_in:
            db = {"embeddings": jax.random.normal(key, (args.batch, 1, cfg.d_model)) * 0.02}
        logits, cache = decode(params, cache, db, jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)[:, None]
        out_tokens.append(np.asarray(tok))
    t_decode = time.time() - t0
    gen = np.concatenate(out_tokens, axis=1)
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"prefill {args.prompt_len} toks x{args.batch}: {t_prefill:.2f}s")
    print(f"decode  {args.gen-1} steps x{args.batch}: {t_decode:.2f}s ({tps:,.1f} tok/s)")
    print("sample:", gen[0][:16])
    assert np.isfinite(gen).all()
    return {"prefill_s": t_prefill, "decode_s": t_decode, "tokens": gen}


if __name__ == "__main__":
    main()
