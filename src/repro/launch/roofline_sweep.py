import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import.

DOC = """Roofline sweep (single-pod): true per-step FLOPs / bytes / collective
bytes for every (arch x input-shape).

XLA's cost_analysis counts while-loop bodies once, so full-depth scanned
modules undercount per-layer work by ~n_layers. Fully unrolling 100-layer
stacks is compile-infeasible on this container, so each combo is compiled
UNROLLED at two reduced depths (pattern-preserving: multiples of the
hybrid/VLM group period) and every cost term is linearly extrapolated in
depth — exact for uniform stacks, <2% pattern error for grouped ones.
memory_analysis (capacity) comes from the full-depth scanned proof pass in
experiments/dryrun/.

Usage: PYTHONPATH=src python -m repro.launch.roofline_sweep --arch all --shape all
"""

import argparse
import json

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch import roofline as rl
from repro.launch.dryrun import lower_combo


def probe_depths(arch: str) -> tuple[int, int]:
    cfg = get_config(arch)
    if cfg.family == "hybrid":
        u = cfg.attn_every
        return u + 1, 2 * (u + 1)  # pattern: k*(u ssm + shared attn) + k extra ssm
    if cfg.family == "vlm":
        u = cfg.cross_attn_every + 1
        return u, 2 * u
    return 2, 4


def sweep_combo(arch: str, shape_name: str, opt: bool = False) -> dict:
    l1, l2 = probe_depths(arch)
    cfg_full = get_config(arch)
    recs = []
    for depth in (l1, l2):
        rec, compiled = lower_combo(arch, shape_name, False, unroll=True, depth=depth, opt=opt)
        recs.append(rec)
        del compiled

    def term(key):
        a = recs[0]["roofline"][key]
        b = recs[1]["roofline"][key]
        slope = (b - a) / (l2 - l1)
        return a + slope * (cfg_full.n_layers - l1)

    coll_kinds = {}
    for kind in recs[0]["roofline"]["coll_by_kind"]:
        a = recs[0]["roofline"]["coll_by_kind"][kind]
        b = recs[1]["roofline"]["coll_by_kind"][kind]
        coll_kinds[kind] = max(0.0, a + (b - a) / (l2 - l1) * (cfg_full.n_layers - l1))

    roof = rl.Roofline(
        flops_per_chip=max(0.0, term("flops_per_chip")),
        hbm_bytes_per_chip=max(0.0, term("hbm_bytes_per_chip")),
        coll_bytes_per_chip=max(0.0, term("coll_bytes_per_chip")),
        coll_by_kind=coll_kinds,
    )
    shape = INPUT_SHAPES[shape_name]
    # params/model-flops at FULL depth (recs carry reduced-depth counts)
    from repro.launch.dryrun import active_params, adjusted_config
    from repro.models import LM

    model = LM(adjusted_config(cfg_full, shape))
    n_active = active_params(model)
    n_tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf = rl.model_flops(n_active, n_tokens, shape.kind)
    flops_global = roof.flops_per_chip * 256
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "16x16",
        "kind": shape.kind,
        "probe_depths": [l1, l2],
        "full_depth": cfg_full.n_layers,
        "params_total": model.param_count(),
        "params_active": n_active,
        "roofline": roof.as_dict(),
        "model_flops_global": mf,
        "useful_flops_ratio": (mf / flops_global) if flops_global else 0.0,
        "probe_records": recs,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--out", default="experiments/roofline")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--opt", action="store_true", help="compile the §Perf-optimized variants")
    args = ap.parse_args()
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            tag = f"{arch}_{shape}" + ("_opt" if args.opt else "")
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path) and not args.force:
                print(f"[skip] {tag}")
                continue
            try:
                rec = sweep_combo(arch, shape, opt=args.opt)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                r = rec["roofline"]
                print(
                    f"[ok]   {tag}: compute={r['compute_s']*1e3:.2f}ms "
                    f"memory={r['memory_s']*1e3:.2f}ms coll={r['collective_s']*1e3:.2f}ms "
                    f"dominant={r['dominant']} useful={rec['useful_flops_ratio']:.2f}"
                )
            except Exception as e:  # noqa: BLE001
                failures.append((tag, repr(e)))
                print(f"[FAIL] {tag}: {e}")
    if failures:
        raise SystemExit(f"{len(failures)} roofline combos failed")
    print("roofline sweep complete")


if __name__ == "__main__":
    main()
