from repro.launch.mesh import data_axis_size, make_host_mesh, make_production_mesh
