"""MMD losses: exact RKHS form, RFF form, and the paper's decomposable eq. (11).

The decomposition is the communication-efficiency enabler: the loss between a
source/target pair only needs the two 2N-vectors  msg_S = Sigma_S l_S  and
msg_T = Sigma_T l_T,  never the raw features.  In the distributed data plane the
sum of per-client messages is a single small all-reduce.
"""
from __future__ import annotations

import jax.numpy as jnp


def mmd_rkhs(k: jnp.ndarray, ell: jnp.ndarray) -> jnp.ndarray:
    """Biased squared MMD in the RKHS of kernel K:  l^T K l."""
    return ell @ (k @ ell)


def mmd_rff(sigma: jnp.ndarray, ell: jnp.ndarray) -> jnp.ndarray:
    """RFF estimate:  ||Sigma l||^2  =  l^T Sigma^T Sigma l."""
    msg = sigma @ ell
    return msg @ msg


def message(sigma: jnp.ndarray, sign: float, n: int | None = None) -> jnp.ndarray:
    """Client message  Sigma l  with l = sign * 1/n (eq. 2).  sigma: (2N, n)."""
    if n is None:
        n = sigma.shape[1]
    return sign * jnp.sum(sigma, axis=1) / n


def mmd_projected(w_rf: jnp.ndarray, msg_s: jnp.ndarray, msg_t: jnp.ndarray) -> jnp.ndarray:
    """Paper eq. (11):  (msg_S + msg_T)^T W W^T (msg_S + msg_T) = ||W^T (msg_S+msg_T)||^2.

    Differentiable in w_rf and (through the messages) in the feature extractors;
    this is the loss backpropagated by Algorithms 2/3.
    """
    v = w_rf.T @ (msg_s + msg_t)
    return v @ v


def mmd_projected_multi(
    w_rf: jnp.ndarray,
    msgs_s: jnp.ndarray,
    msg_t: jnp.ndarray,
    weights: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Mean of per-pair losses over K source messages msgs_s (K, 2N).

    ``weights`` (K,) masks/weights the pairs (mean over weight mass) — this is
    how the batched round engine expresses "messages from clients outside S_t
    were dropped" inside one compiled program.  With no weight mass the loss
    is 0 (no messages arrived, Alg. 3 performs no MMD step).
    """
    v = (msgs_s + msg_t[None, :]) @ w_rf  # (K, m)
    per_pair = jnp.sum(v * v, axis=1)
    if weights is None:
        return jnp.mean(per_pair)
    w = weights.astype(per_pair.dtype)
    return jnp.sum(w * per_pair) / jnp.maximum(jnp.sum(w), 1e-9)
