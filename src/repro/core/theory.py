"""Empirical validation helpers for Theorem 1 / Theorem 2 / Corollary 1."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.kernels_math import centering_matrix, gaussian_kernel, intrinsic_dim
from repro.core.rff import draw_omega, rff_features
from repro.core.tca import r_tca_matrix


def kernel_approx_error(x: jnp.ndarray, n_features: int, sigma: float, seed: int) -> float:
    """Relative spectral error  ||Sigma^T Sigma - K|| / ||K||  (Theorem 2 LHS)."""
    k = gaussian_kernel(x, sigma)
    omega = draw_omega(seed, n_features, x.shape[0], sigma=sigma)
    s = rff_features(x, omega)
    diff = s.T @ s - k
    return float(jnp.linalg.norm(diff, 2) / jnp.linalg.norm(k, 2))


def corollary1_error(
    x: jnp.ndarray, ell: jnp.ndarray, gamma: float, n_features: int, sigma: float, seed: int
) -> float:
    """Relative spectral error between the rank-one-corrected matrices (Cor. 1)."""
    k = gaussian_kernel(x, sigma)
    omega = draw_omega(seed, n_features, x.shape[0], sigma=sigma)
    s = rff_features(x, omega)
    k_hat = s.T @ s

    def corrected(km):
        u = km @ ell
        return km - jnp.outer(u, u) / (gamma + ell @ u)

    err = jnp.linalg.norm(corrected(k) - corrected(k_hat), 2)
    return float(err / jnp.linalg.norm(k, 2))


def theorem1_feature_error(
    x: jnp.ndarray, ell: jnp.ndarray, gamma: float, m: int, n_features: int, sigma: float, seed: int
) -> float:
    """|| H Sigma^T W_RF - H K W_R ||_F with sign-aligned eigenvectors (Thm 1 LHS).

    Both sides are computed as the top-m eigenvectors of A_RF and A_R (eqs. 22-24);
    eigenvector sign ambiguity is resolved by aligning to positive inner product.
    """
    n = x.shape[1]
    h = centering_matrix(n)
    k = gaussian_kernel(x, sigma)
    a_r = r_tca_matrix(k, ell, gamma)

    omega = draw_omega(seed, n_features, x.shape[0], sigma=sigma)
    s = rff_features(x, omega)
    k_hat = s.T @ s
    a_rf = r_tca_matrix(k_hat, ell, gamma)

    def top(a):
        vals, vecs = jnp.linalg.eigh(a)
        return vecs[:, ::-1][:, :m]

    u_r, u_rf = top(a_r), top(a_rf)
    # sign alignment per eigenvector
    signs = jnp.sign(jnp.sum(u_r * u_rf, axis=0))
    signs = jnp.where(signs == 0, 1.0, signs)
    return float(jnp.linalg.norm(h @ (u_rf * signs[None, :] - u_r), "fro"))


def required_features(x: jnp.ndarray, sigma: float, eps: float) -> float:
    """Theorem-1 sufficient N (up to the constant):  dim(K) log(n) / eps^2."""
    k = gaussian_kernel(x, sigma)
    n = x.shape[1]
    return float(intrinsic_dim(k) * jnp.log(n) / eps**2)
