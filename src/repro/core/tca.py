"""Vanilla TCA and R-TCA (paper Section II-B / III-B).

Vanilla TCA solves

    min_W  tr(W^T K ll^T K W) + gamma tr(W^T W)   s.t.  W^T K H K W = I_m,

whose transformed features ``H K W`` span the top-m eigenspace of (Lemma 1)

    A = H ( K^2 - K^2 ll^T K^2 / (gamma + l^T K^2 l) ) H.

R-TCA penalises ``tr(W^T K W)`` instead, giving (eq. 22)

    A_R = (1/gamma) H ( K - K ll^T K / (gamma + l^T K l) ) H.

Both are implemented with the Sherman–Morrison rank-one form — no n x n inverse.
The aligned representations are the top-m eigenvectors (rows = samples), matching
the paper's ``W^T K in R^{m x n}`` convention when transposed.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.kernels_math import centering_matrix


class TCAResult(NamedTuple):
    features: jnp.ndarray  # (m, n) aligned features, columns are samples
    eigvals: jnp.ndarray  # (m,) corresponding eigenvalues, descending


def _top_m_eigh(a: jnp.ndarray, m: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-m eigenpairs of a symmetric matrix, eigenvalues descending."""
    vals, vecs = jnp.linalg.eigh(a)  # ascending
    return vals[::-1][:m], vecs[:, ::-1][:, :m]


def vanilla_tca(k: jnp.ndarray, ell: jnp.ndarray, gamma: float, m: int) -> TCAResult:
    """Lemma-1 symmetric form of vanilla TCA on a precomputed kernel matrix."""
    n = k.shape[0]
    h = centering_matrix(n)
    k2 = k @ k
    u = k2 @ ell  # K^2 l
    denom = gamma + ell @ u
    a = k2 - jnp.outer(u, u) / denom
    a = h @ a @ h
    a = 0.5 * (a + a.T)
    vals, vecs = _top_m_eigh(a, m)
    return TCAResult(features=vecs.T, eigvals=vals)


def r_tca(k: jnp.ndarray, ell: jnp.ndarray, gamma: float, m: int) -> TCAResult:
    """R-TCA (RKHS-norm regularisation), eq. (22)."""
    n = k.shape[0]
    h = centering_matrix(n)
    u = k @ ell
    denom = gamma + ell @ u
    a = k - jnp.outer(u, u) / denom
    a = (h @ a @ h) / gamma
    a = 0.5 * (a + a.T)
    vals, vecs = _top_m_eigh(a, m)
    return TCAResult(features=vecs.T, eigvals=vals)


def r_tca_matrix(k: jnp.ndarray, ell: jnp.ndarray, gamma: float) -> jnp.ndarray:
    """A_R itself (used by the Theorem-1 validation benchmark)."""
    n = k.shape[0]
    h = centering_matrix(n)
    u = k @ ell
    a = (k - jnp.outer(u, u) / (gamma + ell @ u)) / gamma
    a = h @ a @ h
    return 0.5 * (a + a.T)
