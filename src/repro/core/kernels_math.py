"""Exact kernel matrices + spectral utilities (oracles for the RFF approximation)."""
from __future__ import annotations

import jax.numpy as jnp


def pairwise_sq_dists(x: jnp.ndarray, y: jnp.ndarray | None = None) -> jnp.ndarray:
    """Squared Euclidean distances between columns of x (p,n) and y (p,m)."""
    if y is None:
        y = x
    xx = jnp.sum(x * x, axis=0)
    yy = jnp.sum(y * y, axis=0)
    cross = x.T @ y
    d = xx[:, None] + yy[None, :] - 2.0 * cross
    return jnp.maximum(d, 0.0)


def gaussian_kernel(x: jnp.ndarray, sigma: float = 1.0, y: jnp.ndarray | None = None) -> jnp.ndarray:
    """K_ij = exp(-||x_i - x_j||^2 / (2 sigma^2)), columns-as-samples."""
    return jnp.exp(-pairwise_sq_dists(x, y) / (2.0 * sigma**2))


def laplace_kernel(x: jnp.ndarray, sigma: float = 1.0, y: jnp.ndarray | None = None) -> jnp.ndarray:
    """K_ij = exp(-||x_i - x_j||_2 / sigma) (the RFF-Cauchy counterpart)."""
    return jnp.exp(-jnp.sqrt(pairwise_sq_dists(x, y) + 1e-12) / sigma)


def intrinsic_dim(k: jnp.ndarray) -> jnp.ndarray:
    """dim(K) = tr(K) / ||K||_2 — controls the number of RFFs in Theorem 1/2."""
    top = jnp.linalg.eigvalsh(k)[-1]
    return jnp.trace(k) / top


def centering_matrix(n: int) -> jnp.ndarray:
    """H = I_n - 1 1^T / n."""
    return jnp.eye(n) - jnp.ones((n, n)) / n


def median_sigma(x: jnp.ndarray, max_n: int = 512) -> float:
    """Median-heuristic Gaussian bandwidth: sigma = sqrt(median ||xi-xj||^2 / 2)."""
    if x.shape[1] > max_n:
        x = x[:, :: x.shape[1] // max_n + 1]
    d = pairwise_sq_dists(x)
    off = d[jnp.triu_indices(d.shape[0], k=1)]
    return float(jnp.sqrt(jnp.median(off) / 2.0) + 1e-12)


def ell_vector(n_s: int, n_t: int) -> jnp.ndarray:
    """Paper eq. (2): ell_i = 1/n_S for source columns, -1/n_T for target columns."""
    return jnp.concatenate(
        [jnp.full((n_s,), 1.0 / n_s), jnp.full((n_t,), -1.0 / n_t)]
    )
