"""Exact kernel matrices + spectral utilities (oracles for the RFF approximation)."""
from __future__ import annotations

import jax.numpy as jnp


def pairwise_sq_dists(x: jnp.ndarray, y: jnp.ndarray | None = None) -> jnp.ndarray:
    """Squared Euclidean distances between columns of x (p,n) and y (p,m)."""
    if y is None:
        y = x
    xx = jnp.sum(x * x, axis=0)
    yy = jnp.sum(y * y, axis=0)
    cross = x.T @ y
    d = xx[:, None] + yy[None, :] - 2.0 * cross
    return jnp.maximum(d, 0.0)


def gaussian_kernel(
    x: jnp.ndarray, sigma: float = 1.0, y: jnp.ndarray | None = None
) -> jnp.ndarray:
    """K_ij = exp(-||x_i - x_j||^2 / (2 sigma^2)), columns-as-samples."""
    return jnp.exp(-pairwise_sq_dists(x, y) / (2.0 * sigma**2))


def laplace_kernel(x: jnp.ndarray, sigma: float = 1.0, y: jnp.ndarray | None = None) -> jnp.ndarray:
    """K_ij = exp(-||x_i - x_j||_2 / sigma) (the RFF-Cauchy counterpart)."""
    return jnp.exp(-jnp.sqrt(pairwise_sq_dists(x, y) + 1e-12) / sigma)


def intrinsic_dim(k: jnp.ndarray) -> jnp.ndarray:
    """dim(K) = tr(K) / ||K||_2 — controls the number of RFFs in Theorem 1/2."""
    top = jnp.linalg.eigvalsh(k)[-1]
    return jnp.trace(k) / top


def centering_matrix(n: int) -> jnp.ndarray:
    """H = I_n - 1 1^T / n."""
    return jnp.eye(n) - jnp.ones((n, n)) / n


def median_sigma(x: jnp.ndarray, max_n: int = 512) -> float:
    """Median-heuristic Gaussian bandwidth: sigma = sqrt(median ||xi-xj||^2 / 2)."""
    if x.shape[1] > max_n:
        x = x[:, :: x.shape[1] // max_n + 1]
    d = pairwise_sq_dists(x)
    off = d[jnp.triu_indices(d.shape[0], k=1)]
    return float(jnp.sqrt(jnp.median(off) / 2.0) + 1e-12)


def assemble_streamed_gram(
    gcc: jnp.ndarray,
    gcs: jnp.ndarray,
    gss: jnp.ndarray,
    u_c: jnp.ndarray,
    u_s: jnp.ndarray,
    s_c: jnp.ndarray,
    s_s: jnp.ndarray,
    *,
    n: int,
    fold_n: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(G_H, u) from streamed cos/sin Gram blocks — the single home of the
    [cos; sin] block assembly + rank-one centering shared by every streaming
    Gram implementation (untiled scan, tiled twin, and the Pallas wrapper).

    Inputs are the accumulated statistics ``G_cc/G_cs/G_ss`` ((N, N) each),
    the moment halves ``u_c/u_s`` and the feature column sums ``s_c/s_s``
    ((N,) each).  ``fold_n``: the true feature count N when the features were
    accumulated *unscaled* (the 1/sqrt(N) normalization is folded in here,
    quadratic for G, linear for u and the column sum); None when the producer
    already normalized (the Pallas kernels fold it into cos/sin).
    """
    if fold_n is not None:
        inv2 = 1.0 / jnp.float32(fold_n)
        inv = jnp.sqrt(inv2)
        gcc, gcs, gss = inv2 * gcc, inv2 * gcs, inv2 * gss
        u_c, u_s, s_c, s_s = inv * u_c, inv * u_s, inv * s_c, inv * s_s
    g = jnp.concatenate(
        [jnp.concatenate([gcc, gcs], axis=1), jnp.concatenate([gcs.T, gss], axis=1)],
        axis=0,
    )
    u = jnp.concatenate([u_c, u_s])
    col_sum = jnp.concatenate([s_c, s_s])
    g_h = g - jnp.outer(col_sum, col_sum) / n  # rank-one centering (H idempotent)
    return 0.5 * (g_h + g_h.T), u


def assemble_streamed_gram_ensemble(
    gcc: jnp.ndarray,
    gcs: jnp.ndarray,
    gss: jnp.ndarray,
    mc: jnp.ndarray,
    ms: jnp.ndarray,
    *,
    n: int,
    ensemble: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(G_H, u) averaged over S independently-drawn random-feature maps.

    The seed-fused kernels accumulate the raw Gram blocks *pooled* over draws
    (features carry 1/sqrt(N S), so the quadratic contraction is already the
    mean over draws) but keep the moments *per draw*: ``mc``/``ms`` are
    ``(N, 2S)`` with columns ``(2e, 2e+1)`` holding draw ``e``'s ell-moment
    and feature column sum, each scaled by 1/sqrt(S).  Centering is quadratic
    in the column sums, so the mean of the per-draw *centered* Grams needs

        G_H = mean_e [G_e - s_e s_e^T / n] = G_pooled - (1/n) sum_e cs_e cs_e^T

    with ``cs_e`` the stored (1/sqrt(S)-scaled) column sums — a pooled column
    sum would center with the square of the mean instead of the mean of the
    squares.  ``ensemble=1`` delegates to :func:`assemble_streamed_gram`
    unchanged (bitwise-degenerate to the single-draw path).
    """
    if ensemble == 1:
        return assemble_streamed_gram(
            gcc, gcs, gss, mc[:, 0], ms[:, 0], mc[:, 1], ms[:, 1], n=n
        )
    g = jnp.concatenate(
        [jnp.concatenate([gcc, gcs], axis=1), jnp.concatenate([gcs.T, gss], axis=1)],
        axis=0,
    )
    inv_s = 1.0 / jnp.sqrt(jnp.float32(ensemble))
    u = jnp.concatenate([mc[:, 0::2].sum(axis=1), ms[:, 0::2].sum(axis=1)]) * inv_s
    cs = jnp.concatenate([mc[:, 1::2], ms[:, 1::2]], axis=0)  # (2N, S)
    g_h = g - (cs @ cs.T) / n  # rank-S centering: one rank-one term per draw
    return 0.5 * (g_h + g_h.T), u


def ell_vector(n_s: int, n_t: int) -> jnp.ndarray:
    """Paper eq. (2): ell_i = 1/n_S for source columns, -1/n_T for target columns."""
    return jnp.concatenate(
        [jnp.full((n_s,), 1.0 / n_s), jnp.full((n_t,), -1.0 / n_t)]
    )
