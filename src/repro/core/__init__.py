"""Core paper contribution: RFF, TCA variants, RF-TCA, decomposable MMD."""
from repro.core.kernels_math import (
    centering_matrix,
    ell_vector,
    gaussian_kernel,
    intrinsic_dim,
    laplace_kernel,
)
from repro.core.mmd import message, mmd_projected, mmd_projected_multi, mmd_rff, mmd_rkhs
from repro.core.rf_tca import (
    RFTCAState,
    rf_tca,
    rf_tca_fit,
    rf_tca_fit_with_stats,
    rf_tca_resolve,
    rf_tca_transform,
    solve_w_rf,
    solve_w_rf_cholesky,
    solve_w_rf_gram,
    streaming_gram,
)
from repro.core.rff import draw_omega, rff_features, rff_features_rows, rff_message
from repro.core.tca import TCAResult, r_tca, vanilla_tca
