"""Random Fourier features (paper Definition 2).

For data ``X in R^{p x n}`` the RFF matrix is

    Sigma = (1/sqrt(N)) [cos(Omega X); sin(Omega X)]  in  R^{2N x n},

with ``Omega in R^{N x p}``, ``Omega_ij ~ N(0, 1/sigma^2)`` i.i.d.  ``Sigma^T Sigma``
approximates the Gaussian kernel ``K_ij = exp(-||x_i - x_j||^2 / (2 sigma^2))``
(Theorem 2 / [Rahimi-Recht 2008]).

The FedRF-TCA protocol requires every client to draw the *same* Omega from a shared
seed (Alg. 2/3: "predefined random seed S shared by all source and target clients"),
so Omega generation is a pure function of ``(seed, N, p, sigma)``.

Laplace-kernel features (Cauchy-distributed Omega) are also provided — the paper's
Appendix D (Tables XIV/XV) evaluates RF-TCA with the Laplace kernel.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp


def draw_omega(
    seed: int,
    n_features: int,
    dim: int,
    sigma: float = 1.0,
    kernel: Literal["gauss", "laplace"] = "gauss",
) -> jax.Array:
    """Shared-seed frequency matrix Omega in R^{N x p}.

    gauss:   Omega_ij ~ N(0, 1/sigma^2)      -> Sigma^T Sigma ~= Gaussian kernel
    laplace: Omega_ij ~ Cauchy(0, 1/sigma)   -> Sigma^T Sigma ~= Laplace kernel
    """
    key = jax.random.PRNGKey(seed)
    if kernel == "gauss":
        return jax.random.normal(key, (n_features, dim)) / sigma
    elif kernel == "laplace":
        return jax.random.cauchy(key, (n_features, dim)) / sigma
    raise ValueError(f"unknown kernel {kernel!r}")


def rff_features(x: jax.Array, omega: jax.Array, *, use_kernel: bool = False) -> jax.Array:
    """Sigma = [cos(Omega X); sin(Omega X)] / sqrt(N), column-per-sample.

    Args:
      x: data matrix (p, n) — columns are samples (paper convention).
      omega: (N, p) frequency matrix from :func:`draw_omega`.
      use_kernel: route the matmul+cos/sin through the Pallas TPU kernel
        (interpret-mode on CPU); otherwise plain XLA.

    Returns: (2N, n) RFF matrix.
    """
    n_features = omega.shape[0]
    if use_kernel:
        from repro.kernels import ops as kops

        return kops.rff(x, omega)
    z = omega @ x  # (N, n)
    return jnp.concatenate([jnp.cos(z), jnp.sin(z)], axis=0) / jnp.sqrt(n_features)


def rff_features_rows(x_rows: jax.Array, omega: jax.Array) -> jax.Array:
    """Row-major convenience: x_rows (n, p) -> (n, 2N). Used by model heads."""
    return rff_features(x_rows.T, omega).T


@functools.partial(jax.jit, static_argnames=())
def rff_message(x: jax.Array, omega: jax.Array, sign: float = 1.0) -> jax.Array:
    """The paper's compressed client message  Sigma @ ell  in R^{2N}.

    For a source client ell = 1/n_S (sign=+1); for the target ell = -1/n_T
    (sign=-1), per eq. (2).  The message size is independent of n — the heart
    of the O(KN) communication claim (Table I).
    """
    sigma = rff_features(x, omega)
    n = x.shape[1]
    return sign * jnp.sum(sigma, axis=1) / n
