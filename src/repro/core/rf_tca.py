"""RF-TCA (paper Algorithm 1, Section III).

Finds ``W_RF in R^{2N x m}`` as the top-m eigenvectors of

    (Sigma l l^T Sigma^T + gamma I_2N)^{-1} Sigma H Sigma^T,                (7)

a 2N x 2N problem instead of vanilla TCA's n x n one.  We solve the *symmetric
definite generalized* eigenproblem

    G_H w = lambda (gamma I + u u^T) w,     G_H = Sigma H Sigma^T,  u = Sigma l.

Two layers make the fit scale independently of the sample count n:

**Statistics pass** (``mode``): the default ``"stream"`` path consumes X in
sample blocks and accumulates G_H and u directly — via the fused Pallas kernel
``kernels.ops.rff_gram_stream`` on TPU (``use_pallas=True``) or an XLA
``lax.scan`` with the identical O(N^2 + N b) memory profile elsewhere.  The
(2N, n) RFF matrix Sigma never exists.  ``mode="dense"`` is the original
materializing path, kept as the benchmark baseline and small-n reference.

**Solve** (``solver``): B = gamma I + u u^T is an identity-plus-rank-one, so
its inverse square root has the closed Sherman–Morrison-style form

    B^{-1/2} = gamma^{-1/2} (I + c uhat uhat^T),  c = sqrt(gamma/(gamma+|u|^2)) - 1,

which replaces the Cholesky factorization + two triangular solves with two
rank-one updates (O(N^2) instead of O(N^3)).  The whitened operator
C = B^{-1/2} G_H B^{-1/2} is then diagonalized by:

- ``solver="eigh"``   — direct symmetric eigendecomposition.  When running
  outside jit with SciPy available, only the top-m eigenpairs are computed
  (LAPACK ``syevr`` subset — much cheaper than a full ``eigh``).  Best up to
  2N ~ a few thousand; bitwise-deterministic.
- ``solver="lobpcg"`` — matrix-free top-m LOBPCG
  (``jax.experimental.sparse.linalg.lobpcg_standard``) that only applies
  C·v products (O(N^2 m) per iteration).  Pick this when 2N is large enough
  that an O((2N)^3) factorization dominates (2N >~ 4096) or on accelerators
  where the full eigh does not parallelize.  Falls back to ``eigh`` when
  5m >= 2N (the LOBPCG search block would not fit).
- ``solver="cholesky"`` — the original Cholesky-whitening + full ``eigh``
  reference path (seed implementation), kept for benchmarking.

Unlike vanilla TCA (transductive), RF-TCA yields an *out-of-sample* map:
``transform(X_new) = W_RF^T Sigma(X_new)`` — this is what FedRF-TCA exploits.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.kernels_math import (
    assemble_streamed_gram,
    assemble_streamed_gram_ensemble,
    ell_vector,
)
from repro.core.rff import draw_omega, rff_features

try:  # SciPy is optional: only used for the host-side subset-eigh fast path
    from scipy.linalg import eigh as _scipy_eigh
except ImportError:  # pragma: no cover - container always ships SciPy
    _scipy_eigh = None


class RFTCAState(NamedTuple):
    omega: jnp.ndarray | None  # (N, p) frequency matrix; None on the fused path
    w_rf: jnp.ndarray  # (2N, m) aligner
    eigvals: jnp.ndarray  # (m,)
    # seed-fused spec (seed, ensemble, sigma, kernel) when omega is None: the
    # frequency matrix is a pure function of these and is re-drawn on demand
    fused: tuple | None = None


# --------------------------------------------------------------------------
# statistics pass: (G_H, u) from data, streaming or dense
# --------------------------------------------------------------------------


def _gram_stream_body(x: jnp.ndarray, ell: jnp.ndarray, omega: jnp.ndarray, *, block: int):
    """lax.scan streaming accumulation of (G_H, u) — Sigma never materialized.

    Mirrors the Pallas rff_gram_stream kernel's structure and memory profile
    on backends where interpret-mode Pallas would be slow (CPU/GPU): per step
    only an (N, block) cos and sin slab exists, plus (N, N) fp32 accumulators.
    Accumulating the three blocks G_cc / G_cs / G_ss separately instead of the
    concatenated (2N, block) slab saves the G_sc = G_cs^T quarter of the
    contraction FLOPs and a per-step copy.
    """
    p, n = x.shape
    nf = omega.shape[0]
    pad = (-n) % block
    xp = jnp.pad(x, ((0, 0), (0, pad)))
    ep = jnp.pad(ell.astype(jnp.float32), (0, pad))
    nb = (n + pad) // block
    xb = xp.T.reshape(nb, block, p)
    eb = ep.reshape(nb, block)
    if pad:  # static: mask slabs only exist when sample columns are padded
        mb = jnp.pad(jnp.ones((n,), jnp.float32), (0, pad)).reshape(nb, block)
    else:
        mb = jnp.ones((nb, 1), jnp.float32)

    def body(carry, inp):
        cc, cs, ss, u_c, u_s, s_c, s_s = carry
        xblk, elb, mkb = inp
        z = (omega @ xblk.T).astype(jnp.float32)
        # unscaled features; the 1/sqrt(N) normalization is folded into the
        # final statistics (quadratic for G, linear for u and the column sum)
        c = jnp.cos(z)
        s = jnp.sin(z)
        if pad:
            c = c * mkb[None, :]  # zero out padded sample columns
            s = s * mkb[None, :]
        return (
            cc + c @ c.T,
            cs + c @ s.T,
            ss + s @ s.T,
            u_c + c @ elb,
            u_s + s @ elb,
            s_c + jnp.sum(c, axis=1),
            s_s + jnp.sum(s, axis=1),
        ), None

    init = (
        jnp.zeros((nf, nf), jnp.float32),
        jnp.zeros((nf, nf), jnp.float32),
        jnp.zeros((nf, nf), jnp.float32),
        jnp.zeros((nf,), jnp.float32),
        jnp.zeros((nf,), jnp.float32),
        jnp.zeros((nf,), jnp.float32),
        jnp.zeros((nf,), jnp.float32),
    )
    (cc, cs, ss, u_c, u_s, s_c, s_s), _ = jax.lax.scan(body, init, (xb, eb, mb))
    return assemble_streamed_gram(cc, cs, ss, u_c, u_s, s_c, s_s, n=n, fold_n=nf)


_gram_stream_xla = jax.jit(_gram_stream_body, static_argnames=("block",))


def _tile_featurize(om_i, xblk, mkb):
    """Unscaled masked cos/sin slabs of one feature tile on one sample block."""
    z = (om_i @ xblk.T).astype(jnp.float32)
    return jnp.cos(z) * mkb[None, :], jnp.sin(z) * mkb[None, :]


def _tile_pair_stats(om_i, om_j, xb, mb):
    """One (i, j) tile pair of the tiled streaming Gram: scan over sample
    blocks, (tile, tile) accumulators only — module-level so the VMEM-proxy
    test can bound its jaxpr intermediates by the tile size."""
    tile = om_i.shape[0]

    def body(carry, inp):
        cc, cs, ss = carry
        xblk, mkb = inp
        c_i, s_i = _tile_featurize(om_i, xblk, mkb)
        c_j, s_j = _tile_featurize(om_j, xblk, mkb)
        return (cc + c_i @ c_j.T, cs + c_i @ s_j.T, ss + s_i @ s_j.T), None

    init = tuple(jnp.zeros((tile, tile), jnp.float32) for _ in range(3))
    (cc, cs, ss), _ = jax.lax.scan(body, init, (xb, mb))
    return jnp.stack([cc, cs, ss])


def _tile_row_moments(om_i, xb, eb, mb):
    """Row-tile moment accumulators (u and column sums) of the tiled layout."""
    tile = om_i.shape[0]

    def body(carry, inp):
        u_c, u_s, s_c, s_s = carry
        xblk, elb, mkb = inp
        c_i, s_i = _tile_featurize(om_i, xblk, mkb)
        return (
            u_c + c_i @ elb,
            u_s + s_i @ elb,
            s_c + jnp.sum(c_i, axis=1),
            s_s + jnp.sum(s_i, axis=1),
        ), None

    init = tuple(jnp.zeros((tile,), jnp.float32) for _ in range(4))
    out, _ = jax.lax.scan(body, init, (xb, eb, mb))
    return jnp.stack(out)


def _gram_stream_tiled_body(
    x: jnp.ndarray, ell: jnp.ndarray, omega: jnp.ndarray, *, block: int, tile: int
):
    """Tiled-layout XLA twin of ``kernels.rff_gram_stream_tiled_pallas``.

    ``lax.map`` over (i, j) feature-tile pairs with the sample-block
    ``lax.scan`` innermost — exactly the tiled kernel's loop nest, so the live
    intermediates of one pair are two (tile, block) cos/sin slabs and three
    (tile, tile) accumulators, never an (N, block) slab (the untiled twin's
    per-step footprint) let alone the (2N, n) Sigma.  Feature-tile rows
    recompute their slabs once per (j, k) step, the same flop-for-memory trade
    the tiled kernel makes.
    """
    p, n = x.shape
    nf = omega.shape[0]
    pad_n = (-n) % block
    xp = jnp.pad(x, ((0, 0), (0, pad_n)))
    ep = jnp.pad(ell.astype(jnp.float32), (0, pad_n))
    nb = (n + pad_n) // block
    xb = xp.T.reshape(nb, block, p)
    eb = ep.reshape(nb, block)
    mb = jnp.pad(jnp.ones((n,), jnp.float32), (0, pad_n)).reshape(nb, block)
    pad_f = (-nf) % tile
    ni = (nf + pad_f) // tile
    om_t = jnp.pad(omega, ((0, pad_f), (0, 0))).reshape(ni, tile, p)

    def pair_stats(ij):
        return _tile_pair_stats(om_t[ij // ni], om_t[ij % ni], xb, mb)

    def row_moments(i):
        return _tile_row_moments(om_t[i], xb, eb, mb)

    blocks = jax.lax.map(pair_stats, jnp.arange(ni * ni))  # (ni^2, 3, t, t)
    blocks = blocks.reshape(ni, ni, 3, tile, tile).transpose(2, 0, 3, 1, 4)
    blocks = blocks.reshape(3, ni * tile, ni * tile)[:, :nf, :nf]
    mom = jax.lax.map(row_moments, jnp.arange(ni))  # (ni, 4, t)
    mom = mom.transpose(1, 0, 2).reshape(4, ni * tile)[:, :nf]
    return assemble_streamed_gram(
        blocks[0], blocks[1], blocks[2], mom[0], mom[1], mom[2], mom[3], n=n, fold_n=nf
    )


_gram_stream_tiled_xla = jax.jit(_gram_stream_tiled_body, static_argnames=("block", "tile"))


# --------------------------------------------------------------------------
# seed-fused statistics: XLA generator twins of the fused Pallas kernels
# --------------------------------------------------------------------------


def _fused_blocks(x, ell, *, block: int, nf_mult: int, n_features: int):
    """Mirror ``kernels.ops.rff_gram_stream_fused``'s padding exactly:
    (sample-blocked x (nb, p_pad, bk), lm blocks (nb, 2, bk), nf_pad).
    Identical padded shapes are a precondition for bit-for-bit agreement —
    the fused draw covers padded rows/cols too, and only identical block
    geometry makes the twin trace the same float ops as the kernel."""
    p, n = x.shape
    pad_n = (-n) % block
    lm = jnp.stack([ell.astype(x.dtype), jnp.ones((n,), x.dtype)])  # (2, n)
    xp = jnp.pad(x, ((0, (-p) % block), (0, pad_n)))
    lmp = jnp.pad(lm, ((0, 0), (0, pad_n)))
    nb = (n + pad_n) // block
    xb = xp.reshape(xp.shape[0], nb, block).transpose(1, 0, 2)  # (nb, p_pad, bk)
    lmb = lmp.reshape(2, nb, block).transpose(1, 0, 2)  # (nb, 2, bk)
    nf_pad = n_features + (-n_features) % nf_mult
    return xb, lmb, nf_pad


def _gram_stream_fused_body(
    x, ell, *, n_features: int, seed: int, ensemble: int, sigma: float,
    rf_kernel: str, block: int,
):
    """Bit-exact XLA twin of the untiled seed-fused Pallas path.

    Same padded geometry as the ``ops`` wrapper, same per-step math
    (:func:`repro.kernels.rff_gram_stream.fused_step_stats`, shared verbatim),
    same sequential accumulation order over sample blocks — so the twin and
    the interpret-mode kernel execute the identical float op sequence and
    agree to 0 ULP.  No (N, p) weight tensor exists here either: the draw is
    re-generated per sample block from the counter stream.
    """
    from repro.kernels.rff_gram_stream import fused_step_stats

    n = x.shape[1]
    xb, lmb, nf_pad = _fused_blocks(
        x, ell, block=block, nf_mult=block, n_features=n_features
    )
    mw = 2 * ensemble

    def body(carry, inp):
        xblk, lmk = inp
        d = fused_step_stats(
            xblk, lmk, nf=nf_pad, n_features=n_features, seed=seed,
            ensemble=ensemble, sigma=sigma, rf_kernel=rf_kernel,
        )
        return tuple(a + t for a, t in zip(carry, d)), None

    init = (
        jnp.zeros((nf_pad, nf_pad), jnp.float32),
        jnp.zeros((nf_pad, nf_pad), jnp.float32),
        jnp.zeros((nf_pad, nf_pad), jnp.float32),
        jnp.zeros((nf_pad, mw), jnp.float32),
        jnp.zeros((nf_pad, mw), jnp.float32),
    )
    (cc, cs, ss, mc, ms), _ = jax.lax.scan(body, init, (xb, lmb))
    nf = n_features
    return assemble_streamed_gram_ensemble(
        cc[:nf, :nf], cs[:nf, :nf], ss[:nf, :nf], mc[:nf], ms[:nf],
        n=n, ensemble=ensemble,
    )


_gram_stream_fused_xla = jax.jit(
    _gram_stream_fused_body,
    static_argnames=("n_features", "seed", "ensemble", "sigma", "rf_kernel", "block"),
)


def _gram_stream_fused_tiled_body(
    x, ell, *, n_features: int, seed: int, ensemble: int, sigma: float,
    rf_kernel: str, block: int, tile: int,
):
    """Tiled-layout XLA twin of the seed-fused Pallas kernel: ``lax.map`` over
    (i, j) feature-tile pairs with the sample scan innermost, each pair
    re-drawing its two (t, p_pad) weight slabs per step from the counter
    stream — the tiled kernel's loop nest and memory profile, nothing N-sized
    live beyond the output statistics."""
    from repro.kernels.rff_gram_stream import (
        fused_tile_moment_step,
        fused_tile_pair_step,
    )

    n = x.shape[1]
    xb, lmb, nf_pad = _fused_blocks(
        x, ell, block=block, nf_mult=tile, n_features=n_features
    )
    ni = nf_pad // tile
    mw = 2 * ensemble
    kw = dict(
        tile=tile, n_features=n_features, seed=seed, ensemble=ensemble,
        sigma=sigma, rf_kernel=rf_kernel,
    )

    def pair_stats(ij):
        row_i = (ij // ni) * tile
        row_j = (ij % ni) * tile

        def body(carry, inp):
            xblk, lmk = inp
            d = fused_tile_pair_step(xblk, lmk, row_i, row_j, **kw)
            return tuple(a + t for a, t in zip(carry, d)), None

        init = tuple(jnp.zeros((tile, tile), jnp.float32) for _ in range(3))
        out, _ = jax.lax.scan(body, init, (xb, lmb))
        return jnp.stack(out)

    def row_moments(i):
        def body(carry, inp):
            xblk, lmk = inp
            d = fused_tile_moment_step(xblk, lmk, i * tile, **kw)
            return tuple(a + t for a, t in zip(carry, d)), None

        init = tuple(jnp.zeros((tile, mw), jnp.float32) for _ in range(2))
        out, _ = jax.lax.scan(body, init, (xb, lmb))
        return jnp.stack(out)

    blocks = jax.lax.map(pair_stats, jnp.arange(ni * ni))  # (ni^2, 3, t, t)
    blocks = blocks.reshape(ni, ni, 3, tile, tile).transpose(2, 0, 3, 1, 4)
    blocks = blocks.reshape(3, ni * tile, ni * tile)
    mom = jax.lax.map(row_moments, jnp.arange(ni))  # (ni, 2, t, 2S)
    mom = mom.transpose(1, 0, 2, 3).reshape(2, ni * tile, mw)
    nf = n_features
    return assemble_streamed_gram_ensemble(
        blocks[0, :nf, :nf], blocks[1, :nf, :nf], blocks[2, :nf, :nf],
        mom[0, :nf], mom[1, :nf], n=n, ensemble=ensemble,
    )


_gram_stream_fused_tiled_xla = jax.jit(
    _gram_stream_fused_tiled_body,
    static_argnames=(
        "n_features", "seed", "ensemble", "sigma", "rf_kernel", "block", "tile"
    ),
)


def fused_streaming_gram(
    x: jnp.ndarray,
    ell: jnp.ndarray,
    *,
    n_features: int,
    seed: int,
    ensemble: int = 1,
    sigma: float = 1.0,
    rf_kernel: str = "gauss",
    use_pallas: bool = False,
    block: int = 128,
    tile: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Seed-fused (G_H (2N, 2N), u (2N,)) — no omega operand anywhere.

    Dispatches to the fused Pallas kernel (``use_pallas=True``) or its XLA
    generator twin; both draw W_RF inside the pass from
    ``threefry(seed, row, col)`` and agree bit-for-bit.  The layout (untiled
    vs (t, t)-tiled) follows ``kernels.ops.gram_tile_plan`` on both paths so
    Pallas and twin always pick the same geometry.
    """
    from repro.kernels import ops as kops

    if use_pallas:
        return kops.rff_gram_stream_fused(
            x, ell, n_features=n_features, seed=seed, ensemble=ensemble,
            sigma_rf=sigma, rf_kernel=rf_kernel, block=block, tile=tile,
        )
    plan_tile = kops.gram_tile_plan(n_features, tile=tile)["tile"]
    if plan_tile is None:
        return _gram_stream_fused_xla(
            x, ell, n_features=n_features, seed=seed, ensemble=ensemble,
            sigma=sigma, rf_kernel=rf_kernel, block=block,
        )
    return _gram_stream_fused_tiled_xla(
        x, ell, n_features=n_features, seed=seed, ensemble=ensemble,
        sigma=sigma, rf_kernel=rf_kernel, block=block, tile=plan_tile,
    )


def streaming_gram(
    x: jnp.ndarray,
    ell: jnp.ndarray,
    omega: jnp.ndarray,
    *,
    block: int = 1024,
    use_pallas: bool = False,
    tile: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(G_H (2N, 2N), u (2N,)) fp32 from X (p, n) in one blocked pass.

    ``tile`` selects the feature-axis accumulator layout: None auto-selects on
    the Pallas path (``kernels.ops.gram_tile_plan``) and keeps the untiled
    scan on the XLA path; an int forces the (tile, tile)-blocked layout on
    either path (0 forces untiled).
    """
    if use_pallas:
        from repro.kernels import ops as kops

        return kops.rff_gram_stream(
            x, omega, ell, block=min(128, max(8, block)), tile=tile
        )
    if tile:
        return _gram_stream_tiled_xla(
            x, ell, omega, block=min(block, x.shape[1]), tile=tile
        )
    return _gram_stream_xla(x, ell, omega, block=min(block, x.shape[1]))


def _dense_gram(
    sigma: jnp.ndarray, ell: jnp.ndarray, *, use_kernel: bool = False
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Materializing reference: (G_H, u) from an explicit Sigma (2N, n)."""
    if use_kernel:
        from repro.kernels import ops as kops

        g_h = kops.centered_gram(sigma)
    else:
        mu = jnp.mean(sigma, axis=1, keepdims=True)
        s_c = sigma - mu
        g_h = s_c @ s_c.T  # Sigma H Sigma^T  (H idempotent: SH(SH)^T = S H S^T)
    return 0.5 * (g_h + g_h.T), sigma @ ell


# --------------------------------------------------------------------------
# solve: top-m of  G_H w = lambda (gamma I + u u^T) w
# --------------------------------------------------------------------------


def _whiten_half(u: jnp.ndarray, gamma: float) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Closed-form B^{-1/2} for B = gamma I + u u^T (identity plus rank one).

    B has eigenvalue gamma + |u|^2 along uhat and gamma elsewhere, so
    B^{-1/2} = gamma^{-1/2} (I + c uhat uhat^T) with
    c = sqrt(gamma / (gamma + |u|^2)) - 1.  Applying it is two rank-one
    updates, O(N k) for a (2N, k) block — no Cholesky, no triangular solves.
    """
    uu = u @ u
    c = jnp.sqrt(gamma / (gamma + uu)) - 1.0
    uhat = u * jax.lax.rsqrt(uu + 1e-30)
    inv_sqrt_gamma = jax.lax.rsqrt(jnp.asarray(gamma, u.dtype))

    def apply(v: jnp.ndarray) -> jnp.ndarray:
        return (v + c * jnp.outer(uhat, uhat @ v)) * inv_sqrt_gamma

    return apply


@jax.jit
def _whitened_cmat(g_h: jnp.ndarray, u: jnp.ndarray, gamma) -> jnp.ndarray:
    """C = B^{-1/2} G_H B^{-1/2} via two rank-one whitening passes (jitted)."""
    bihalf = _whiten_half(u, gamma)
    cmat = bihalf(bihalf(g_h).T)
    return 0.5 * (cmat + cmat.T)


def _solve_whitened_top_m(g_h, u, gamma, key, *, m: int, iters: int, tol):
    """Traceable top-m of the whitened operator: matrix-free LOBPCG when the
    [X, R, P] search block fits (5m < 2N — jax's lobpcg_standard rejects
    5k >= n), symmetric eigh otherwise.  The single home of that guard."""
    bihalf = _whiten_half(u, gamma)
    if 5 * m < g_h.shape[0]:
        from jax.experimental.sparse.linalg import lobpcg_standard

        def matvec(v):
            return bihalf(g_h @ bihalf(v))

        x0 = jax.random.normal(key, (g_h.shape[0], m), g_h.dtype)
        vals, vecs, _ = lobpcg_standard(matvec, x0, m=iters, tol=tol)
    else:
        vals, vecs = _top_eigh(_whitened_cmat(g_h, u, gamma), m)
    return bihalf(vecs), vals


_lobpcg_solve = functools.partial(
    jax.jit, static_argnames=("m", "iters", "tol")
)(_solve_whitened_top_m)


def _host_top_eigh(cmat, *, m: int):
    """Host-side LAPACK subset eigendecomposition (syevr): top-m pairs only."""
    import numpy as np

    two_n = cmat.shape[0]
    vals, vecs = _scipy_eigh(
        np.asarray(cmat, np.float32), subset_by_index=[two_n - m, two_n - 1]
    )
    return (
        np.ascontiguousarray(vals[::-1]).astype(np.float32),
        np.ascontiguousarray(vecs[:, ::-1]).astype(np.float32),
    )


def _top_eigh(cmat, m: int):
    """Top-m (vals desc, vecs) of a symmetric matrix.

    With SciPy present this routes to the LAPACK subset driver (syevr),
    which only back-transforms the m requested eigenvectors and is several
    times faster than a full ``eigh`` at bench sizes.  On concrete arrays
    SciPy is called directly AFTER the XLA program has finished — running it
    as an in-program callback stalls it badly (XLA's spin-waiting worker
    threads starve the single-threaded LAPACK call).  Under tracing it
    becomes a ``pure_callback``; without SciPy: full jnp eigh.
    """
    two_n = cmat.shape[0]
    if _scipy_eigh is not None:
        if not isinstance(cmat, jax.core.Tracer):
            import numpy as np

            vals, vecs = _host_top_eigh(np.asarray(cmat), m=m)
            return jnp.asarray(vals), jnp.asarray(vecs)
        out_shapes = (
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((two_n, m), jnp.float32),
        )
        return jax.pure_callback(
            functools.partial(_host_top_eigh, m=m), out_shapes, cmat.astype(jnp.float32)
        )
    vals, vecs = jnp.linalg.eigh(cmat)
    return vals[::-1][:m], vecs[:, ::-1][:, :m]


@jax.jit
def _apply_whiten(u, gamma, vecs):
    """w = B^{-1/2} vecs as one dispatch (the final back-transform)."""
    return _whiten_half(u, gamma)(vecs)


def solve_w_rf_gram(
    g_h: jnp.ndarray,
    u: jnp.ndarray,
    gamma: float,
    m: int,
    *,
    solver: str = "eigh",
    lobpcg_iters: int = 100,
    lobpcg_tol: float | None = None,
    seed: int = 0,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-m solution of (7) from the streamed statistics (G_H, u).

    Returns (w_rf (2N, m), eigvals (m,)).  See the module docstring for the
    eigh-vs-lobpcg trade-off.
    """
    if solver == "lobpcg":
        return _lobpcg_solve(
            g_h, u, gamma, jax.random.PRNGKey(seed),
            m=m, iters=lobpcg_iters, tol=lobpcg_tol,
        )
    if solver != "eigh":
        raise ValueError(f"unknown solver {solver!r}")
    cmat = _whitened_cmat(g_h, u, gamma)
    vals, vecs = _top_eigh(cmat, m)
    return _apply_whiten(u, gamma, vecs), vals


def solve_w_rf_cholesky(
    sigma: jnp.ndarray, ell: jnp.ndarray, gamma: float, m: int, *, use_kernel: bool = False
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Original Cholesky-whitening + full-eigh reference (the seed dense path).

    Kept verbatim as the benchmark baseline and a numerical cross-check for
    the Sherman–Morrison solvers.
    """
    two_n = sigma.shape[0]
    g_h, u = _dense_gram(sigma, ell, use_kernel=use_kernel)
    b = gamma * jnp.eye(two_n) + jnp.outer(u, u)
    chol = jnp.linalg.cholesky(b)
    li_g = jax.scipy.linalg.solve_triangular(chol, g_h, lower=True)
    c = jax.scipy.linalg.solve_triangular(chol, li_g.T, lower=True).T
    c = 0.5 * (c + c.T)
    vals, vecs = jnp.linalg.eigh(c)
    vals = vals[::-1][:m]
    vecs = vecs[:, ::-1][:, :m]
    w_rf = jax.scipy.linalg.solve_triangular(chol.T, vecs, lower=False)
    return w_rf, vals


def solve_w_rf(
    sigma: jnp.ndarray,
    ell: jnp.ndarray,
    gamma: float,
    m: int,
    *,
    use_kernel: bool = False,
    solver: str = "eigh",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-m solution of (7) given an explicit RFF matrix Sigma (2N, n).

    Returns (w_rf (2N, m), eigvals (m,)).  ``solver="cholesky"`` reproduces
    the original implementation; "eigh"/"lobpcg" use Sherman–Morrison
    whitening (same eigenpairs, W B-orthonormal in both cases).
    """
    if solver == "cholesky":
        return solve_w_rf_cholesky(sigma, ell, gamma, m, use_kernel=use_kernel)
    g_h, u = _dense_gram(sigma, ell, use_kernel=use_kernel)
    return solve_w_rf_gram(g_h, u, gamma, m, solver=solver)


# --------------------------------------------------------------------------
# public fit / transform
# --------------------------------------------------------------------------


def _draw_omega_traced(key, p: int, sigma, *, n_features: int, kernel: str):
    if kernel == "gauss":
        return jax.random.normal(key, (n_features, p)) / sigma
    if kernel == "laplace":
        return jax.random.cauchy(key, (n_features, p)) / sigma
    raise ValueError(f"unknown kernel {kernel!r}")


@functools.partial(jax.jit, static_argnames=("n_features", "block", "kernel"))
def _fit_stream_stats(
    x_s, x_t, key, gamma, sigma, *, n_features: int, block: int, kernel: str
):
    """Streamed statistics as ONE compiled program: omega draw, blocked Gram
    scan and Sherman–Morrison whitening fuse into (omega, C, u).  The top-m
    eigensolve runs on the host afterwards (see _top_eigh for why it must not
    be an in-program callback)."""
    omega = _draw_omega_traced(key, x_s.shape[0], sigma, n_features=n_features, kernel=kernel)
    x = jnp.concatenate([x_s, x_t], axis=1)
    ell = ell_vector(x_s.shape[1], x_t.shape[1])
    g_h, u = _gram_stream_body(x, ell, omega, block=block)
    return omega, _whitened_cmat(g_h, u, gamma), u


@functools.partial(
    jax.jit, static_argnames=("n_features", "m", "block", "kernel", "lobpcg_iters", "lobpcg_tol")
)
def _fit_stream_lobpcg(
    x_s, x_t, key, gamma, sigma,
    *, n_features: int, m: int, block: int, kernel: str, lobpcg_iters: int, lobpcg_tol,
):
    """Fully-fused streamed fit with the matrix-free LOBPCG solve (no host
    work at all — the right shape for accelerators and large 2N)."""
    omega = _draw_omega_traced(key, x_s.shape[0], sigma, n_features=n_features, kernel=kernel)
    x = jnp.concatenate([x_s, x_t], axis=1)
    ell = ell_vector(x_s.shape[1], x_t.shape[1])
    g_h, u = _gram_stream_body(x, ell, omega, block=block)
    w_rf, vals = _solve_whitened_top_m(
        g_h, u, gamma, jax.random.fold_in(key, 1), m=m, iters=lobpcg_iters, tol=lobpcg_tol
    )
    return omega, w_rf, vals


def _parse_fused_spec(w_rf) -> int | None:
    """``w_rf="fused:<seed>"`` -> seed; None passes through; else error."""
    if w_rf is None:
        return None
    if isinstance(w_rf, str) and w_rf.startswith("fused:"):
        return int(w_rf.split(":", 1)[1])
    raise ValueError(
        f'w_rf must be None or "fused:<seed>", got {w_rf!r}'
    )


def _fit_fused(
    x_s, x_t, *, n_features: int, m: int, gamma: float, sigma: float,
    seed: int, kernel: str, use_pallas: bool, solver: str,
    fused_seed: int, ensemble: int,
) -> tuple[RFTCAState, dict]:
    """Seed-fused statistics pass, returning the fitted state *and* the
    (G_H, u) statistics it solved from — the moment-space refresh input."""
    x = jnp.concatenate([x_s, x_t], axis=1)
    ell = ell_vector(x_s.shape[1], x_t.shape[1])
    g_h, u = fused_streaming_gram(
        x, ell, n_features=n_features, seed=fused_seed, ensemble=ensemble,
        sigma=sigma, rf_kernel=kernel, use_pallas=use_pallas,
    )
    w, vals = solve_w_rf_gram(g_h, u, gamma, m, solver=solver, seed=seed)
    state = RFTCAState(
        omega=None, w_rf=w, eigvals=vals,
        fused=(fused_seed, ensemble, sigma, kernel),
    )
    stats = {
        "gram": g_h, "u": u, "gamma": float(gamma), "m": int(m),
        "solver": str(solver), "seed": int(seed),
    }
    return state, stats


def rf_tca_fit_with_stats(
    x_s: jnp.ndarray,
    x_t: jnp.ndarray,
    *,
    n_features: int,
    m: int,
    gamma: float = 1.0,
    sigma: float = 1.0,
    seed: int = 0,
    kernel: str = "gauss",
    use_pallas: bool = False,
    solver: str = "eigh",
    w_rf: str | None = None,
    ensemble: int = 1,
) -> tuple[RFTCAState, dict]:
    """Seed-fused :func:`rf_tca_fit` that also returns the fit statistics.

    The returned dict carries the merged Gram ``gram`` (G_H), the mean
    discrepancy ``u`` and the solve hyperparameters — everything
    :func:`rf_tca_resolve` needs to re-solve W_RF later from *updated*
    moments (e.g. after target drift) without touching raw data again.
    The state is bitwise identical to ``rf_tca_fit`` with the same
    arguments (the fit delegates to the same fused pass).
    """
    fused_seed = _parse_fused_spec(w_rf)
    if fused_seed is None:
        raise ValueError(
            'rf_tca_fit_with_stats requires the seed-fused path: '
            'pass w_rf="fused:<seed>"'
        )
    if solver not in ("eigh", "lobpcg"):
        raise ValueError(f"unknown solver {solver!r}")
    return _fit_fused(
        x_s, x_t, n_features=n_features, m=m, gamma=gamma, sigma=sigma,
        seed=seed, kernel=kernel, use_pallas=use_pallas, solver=solver,
        fused_seed=fused_seed, ensemble=ensemble,
    )


def rf_tca_resolve(
    gram: jnp.ndarray,
    u: jnp.ndarray,
    *,
    gamma: float,
    m: int,
    solver: str = "eigh",
    seed: int = 0,
    fused_spec: tuple,
) -> RFTCAState:
    """Re-solve W_RF from statistics alone (no data pass).

    ``gram``/``u`` are the (possibly updated) (G_H, u) pair and
    ``fused_spec`` the ``(seed, ensemble, sigma, kernel)`` tuple of the
    original fit — transforms of the returned state draw the same feature
    map.  This is the aligner auto-refresh primitive: a drifted target mean
    changes ``u = mu_S - mu_T`` but not the merged Gram, so a refresh is one
    O(N^2 m) eigensolve instead of a refit over raw data.
    """
    if solver not in ("eigh", "lobpcg"):
        raise ValueError(f"unknown solver {solver!r}")
    w, vals = solve_w_rf_gram(gram, u, gamma, m, solver=solver, seed=seed)
    return RFTCAState(omega=None, w_rf=w, eigvals=vals, fused=tuple(fused_spec))


def rf_tca_fit(
    x_s: jnp.ndarray,
    x_t: jnp.ndarray,
    *,
    n_features: int,
    m: int,
    gamma: float = 1.0,
    sigma: float = 1.0,
    seed: int = 0,
    kernel: str = "gauss",
    use_pallas: bool = False,
    mode: str = "stream",
    solver: str = "eigh",
    block: int = 1024,
    w_rf: str | None = None,
    ensemble: int = 1,
) -> RFTCAState:
    """Algorithm 1: fit W_RF on source (p, n_S) and target (p, n_T) data.

    mode="stream" (default) never materializes the (2N, n) RFF matrix;
    mode="dense" is the original materializing path (solver "cholesky"
    reproduces the seed implementation exactly).

    ``w_rf="fused:<seed>"`` switches the statistics pass to the seed-fused
    generators: the frequency matrix is drawn *inside* the kernel (or its XLA
    twin) from a counter-based stream and never exists as a tensor — the
    returned state has ``omega=None`` and carries the spec instead.
    ``ensemble=S`` then averages the (G_H, u) statistics over S
    independently-keyed draws in the same pass (S=1 is bitwise the
    single-draw path); out-of-sample transforms use draw 0's feature map.
    """
    if mode not in ("stream", "dense"):
        raise ValueError(f"unknown mode {mode!r}")
    if solver not in ("eigh", "lobpcg", "cholesky"):
        raise ValueError(f"unknown solver {solver!r}")
    if mode == "stream" and solver == "cholesky":
        raise ValueError(
            'solver="cholesky" factorizes the explicit-Sigma path and requires '
            'mode="dense"; the streaming solvers are "eigh" and "lobpcg"'
        )
    fused_seed = _parse_fused_spec(w_rf)
    if ensemble != 1 and fused_seed is None:
        raise ValueError('ensemble > 1 requires w_rf="fused:<seed>"')
    if fused_seed is not None:
        if mode != "stream":
            raise ValueError('w_rf="fused:<seed>" requires mode="stream"')
        state, _ = _fit_fused(
            x_s, x_t, n_features=n_features, m=m, gamma=gamma, sigma=sigma,
            seed=seed, kernel=kernel, use_pallas=use_pallas, solver=solver,
            fused_seed=fused_seed, ensemble=ensemble,
        )
        return state
    if mode == "stream" and not use_pallas:
        key = jax.random.PRNGKey(seed)
        blk = min(block, x_s.shape[1] + x_t.shape[1])
        if solver == "lobpcg":
            omega, w_rf, vals = _fit_stream_lobpcg(
                x_s, x_t, key, gamma, sigma,
                n_features=n_features, m=m, block=blk, kernel=kernel,
                lobpcg_iters=100, lobpcg_tol=None,
            )
        else:
            omega, cmat, u = _fit_stream_stats(
                x_s, x_t, key, gamma, sigma,
                n_features=n_features, block=blk, kernel=kernel,
            )
            vals, vecs = _top_eigh(cmat, m)
            w_rf = _apply_whiten(u, gamma, vecs)
        return RFTCAState(omega=omega, w_rf=w_rf, eigvals=vals)
    p = x_s.shape[0]
    omega = draw_omega(seed, n_features, p, sigma=sigma, kernel=kernel)
    x = jnp.concatenate([x_s, x_t], axis=1)
    ell = ell_vector(x_s.shape[1], x_t.shape[1])
    if mode == "stream":
        g_h, u = streaming_gram(x, ell, omega, block=block, use_pallas=use_pallas)
        w_rf, vals = solve_w_rf_gram(g_h, u, gamma, m, solver=solver, seed=seed)
    else:
        sig = rff_features(x, omega, use_kernel=use_pallas)
        w_rf, vals = solve_w_rf(sig, ell, gamma, m, use_kernel=use_pallas, solver=solver)
    return RFTCAState(omega=omega, w_rf=w_rf, eigvals=vals)


# Fused-path transform omega memo: the draw is a pure function of the spec
# (seed, N, p, sigma, kernel), so repeated serving transforms must not redraw
# it per call.  FIFO-capped cache with a ``regenerations`` counter, mirroring
# ``comm.codecs.SeedReplayCodec.decode`` (the wire-side twin of this memo).
_FUSED_OMEGA_CACHE: dict[tuple, jnp.ndarray] = {}
_FUSED_OMEGA_CACHE_MAX = 16
fused_omega_regenerations: int = 0


def fused_transform_omega(state: RFTCAState, dim: int) -> jnp.ndarray:
    """Draw-0 frequency matrix of a seed-fused state, memoized per spec.

    ``dim`` is the data dimension p of the batch about to be featurized.  The
    first call per ``(seed, N, p, sigma, kernel)`` materializes the (N, p)
    matrix from the counter stream and counts one regeneration; subsequent
    transforms (the serving hot path) hit the cache.
    """
    global fused_omega_regenerations
    f_seed, _, f_sigma, f_kernel = state.fused
    n_features = state.w_rf.shape[0] // 2
    key = (int(f_seed), int(n_features), int(dim), float(f_sigma), str(f_kernel))
    hit = _FUSED_OMEGA_CACHE.get(key)
    if hit is not None:
        return hit
    from repro.kernels.prng import fused_omega

    omega = fused_omega(f_seed, n_features, dim, sigma=f_sigma, rf_kernel=f_kernel)
    fused_omega_regenerations += 1
    if len(_FUSED_OMEGA_CACHE) >= _FUSED_OMEGA_CACHE_MAX:
        _FUSED_OMEGA_CACHE.pop(next(iter(_FUSED_OMEGA_CACHE)))
    _FUSED_OMEGA_CACHE[key] = omega
    return omega


def fused_omega_cache_info() -> dict[str, int]:
    """{"size", "max", "regenerations"} — the memo's observable state."""
    return {
        "size": len(_FUSED_OMEGA_CACHE),
        "max": _FUSED_OMEGA_CACHE_MAX,
        "regenerations": fused_omega_regenerations,
    }


def rf_tca_transform(state: RFTCAState, x: jnp.ndarray) -> jnp.ndarray:
    """F = W_RF^T Sigma(X) in R^{m x n} — works on unseen data (out-of-sample).

    On the seed-fused path (``state.omega is None``) the frequency matrix is
    re-drawn from the counter stream on demand (draw 0 when the fit averaged
    an ensemble) and memoized per spec (:func:`fused_transform_omega`) — the
    fit-time statistics never materialized it, and repeated out-of-sample
    transforms materialize it exactly once.
    """
    omega = state.omega
    if omega is None:
        omega = fused_transform_omega(state, x.shape[0])
    return state.w_rf.T @ rff_features(x, omega)


def rf_tca(
    x_s: jnp.ndarray, x_t: jnp.ndarray, **kw
) -> tuple[jnp.ndarray, jnp.ndarray, RFTCAState]:
    """Convenience: fit then return (F_S (m,n_S), F_T (m,n_T), state)."""
    state = rf_tca_fit(x_s, x_t, **kw)
    f_s = rf_tca_transform(state, x_s)
    f_t = rf_tca_transform(state, x_t)
    return f_s, f_t, state
