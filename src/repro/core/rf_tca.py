"""RF-TCA (paper Algorithm 1, Section III).

Finds ``W_RF in R^{2N x m}`` as the top-m eigenvectors of

    (Sigma l l^T Sigma^T + gamma I_2N)^{-1} Sigma H Sigma^T,                (7)

a 2N x 2N problem instead of vanilla TCA's n x n one.  We solve the *symmetric
definite generalized* eigenproblem

    G_H w = lambda (gamma I + u u^T) w,     G_H = Sigma H Sigma^T,  u = Sigma l,

via Cholesky whitening, which is numerically cleaner than the non-symmetric
Sherman–Morrison product and mathematically identical.

Unlike vanilla TCA (transductive), RF-TCA yields an *out-of-sample* map:
``transform(X_new) = W_RF^T Sigma(X_new)`` — this is what FedRF-TCA exploits.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.kernels_math import ell_vector
from repro.core.rff import draw_omega, rff_features


class RFTCAState(NamedTuple):
    omega: jnp.ndarray  # (N, p) shared-seed frequency matrix
    w_rf: jnp.ndarray  # (2N, m) aligner
    eigvals: jnp.ndarray  # (m,)


def solve_w_rf(
    sigma: jnp.ndarray, ell: jnp.ndarray, gamma: float, m: int, *, use_kernel: bool = False
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-m solution of (7) given the RFF matrix Sigma (2N, n).

    Returns (w_rf (2N, m), eigvals (m,)).
    """
    two_n = sigma.shape[0]
    if use_kernel:
        from repro.kernels import ops as kops

        g_h = kops.centered_gram(sigma)
    else:
        mu = jnp.mean(sigma, axis=1, keepdims=True)
        s_c = sigma - mu
        g_h = s_c @ s_c.T  # Sigma H Sigma^T  (H idempotent: SH(SH)^T = S H S^T)
    g_h = 0.5 * (g_h + g_h.T)
    u = sigma @ ell  # (2N,)

    # B = gamma I + u u^T ;  Cholesky of a rank-one update computed directly.
    b = gamma * jnp.eye(two_n) + jnp.outer(u, u)
    l = jnp.linalg.cholesky(b)
    # C = L^{-1} G_H L^{-T}
    li_g = jax.scipy.linalg.solve_triangular(l, g_h, lower=True)
    c = jax.scipy.linalg.solve_triangular(l, li_g.T, lower=True).T
    c = 0.5 * (c + c.T)
    vals, vecs = jnp.linalg.eigh(c)
    vals = vals[::-1][:m]
    vecs = vecs[:, ::-1][:, :m]
    w_rf = jax.scipy.linalg.solve_triangular(l.T, vecs, lower=False)
    return w_rf, vals


def rf_tca_fit(
    x_s: jnp.ndarray,
    x_t: jnp.ndarray,
    *,
    n_features: int,
    m: int,
    gamma: float = 1.0,
    sigma: float = 1.0,
    seed: int = 0,
    kernel: str = "gauss",
    use_pallas: bool = False,
) -> RFTCAState:
    """Algorithm 1: fit W_RF on source (p, n_S) and target (p, n_T) data."""
    p = x_s.shape[0]
    omega = draw_omega(seed, n_features, p, sigma=sigma, kernel=kernel)
    x = jnp.concatenate([x_s, x_t], axis=1)
    sig = rff_features(x, omega, use_kernel=use_pallas)
    ell = ell_vector(x_s.shape[1], x_t.shape[1])
    w_rf, vals = solve_w_rf(sig, ell, gamma, m, use_kernel=use_pallas)
    return RFTCAState(omega=omega, w_rf=w_rf, eigvals=vals)


def rf_tca_transform(state: RFTCAState, x: jnp.ndarray) -> jnp.ndarray:
    """F = W_RF^T Sigma(X) in R^{m x n} — works on unseen data (out-of-sample)."""
    return state.w_rf.T @ rff_features(x, state.omega)


def rf_tca(
    x_s: jnp.ndarray, x_t: jnp.ndarray, **kw
) -> tuple[jnp.ndarray, jnp.ndarray, RFTCAState]:
    """Convenience: fit then return (F_S (m,n_S), F_T (m,n_T), state)."""
    state = rf_tca_fit(x_s, x_t, **kw)
    f_s = rf_tca_transform(state, x_s)
    f_t = rf_tca_transform(state, x_t)
    return f_s, f_t, state
