"""Mamba2 / SSD (state-space duality) blocks [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm (the paper's "minimal SSD"):
quadratic attention-like computation inside chunks of length Q, a linear
recurrence across chunk states — O(S·Q) instead of O(S^2), scan-friendly and
TPU-native (all chunk ops are MXU matmuls).

Decode is the O(1)-per-token recurrent update on the (H, P, N) state — this is
why the SSM archs run ``long_500k`` natively.

Convention: G (ssm groups) = 1, B/C shared across heads within the group.
The depthwise causal conv runs over the packed (x, B, C) channels as in
Mamba2; decode keeps a (W-1)-deep shift register.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import ShardRules
from repro.models.param import ParamDecl


def ssm_decl(cfg: ModelConfig, rules: ShardRules) -> dict:
    d, di, n, h, w = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_n_heads, cfg.ssm_conv_width
    di_spec, h_spec = rules.tp(di), rules.tp(h)
    return {
        "w_z": ParamDecl((d, di), P(None, di_spec), "normal", cfg.dtype),
        "w_x": ParamDecl((d, di), P(None, di_spec), "normal", cfg.dtype),
        "w_b": ParamDecl((d, n), P(None, None), "normal", cfg.dtype),
        "w_c": ParamDecl((d, n), P(None, None), "normal", cfg.dtype),
        "w_dt": ParamDecl((d, h), P(None, h_spec), "normal", cfg.dtype),
        "dt_bias": ParamDecl((h,), P(h_spec), "ssm_dt", jnp.float32),
        "a_log": ParamDecl((h,), P(h_spec), "ssm_a", jnp.float32),
        "d_skip": ParamDecl((h,), P(h_spec), "ones", jnp.float32),
        "conv_x": ParamDecl((w, di), P(None, di_spec), "normal", cfg.dtype, 0.5),
        "conv_b": ParamDecl((w, n), P(None, None), "normal", cfg.dtype, 0.5),
        "conv_c": ParamDecl((w, n), P(None, None), "normal", cfg.dtype, 0.5),
        "norm": ParamDecl((di,), P(di_spec), "ones", cfg.dtype),
        "w_out": ParamDecl((di, d), P(di_spec, None), "normal", cfg.dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x: (b, s, ch); w: (width, ch)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    return out


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """Lower-triangular pairwise cumulative sums: out[..., i, j] = sum_{j<k<=i} a_k."""
    seq = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((seq, seq), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,  # (b, s, h, p)
    dt: jnp.ndarray,  # (b, s, h)  (post-softplus)
    a_log: jnp.ndarray,  # (h,)
    b_in: jnp.ndarray,  # (b, s, n)
    c_in: jnp.ndarray,  # (b, s, n)
    chunk: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD. Returns (y (b,s,h,p), final_state (b,h,p,n))."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    q = min(chunk, s)
    while s % q:
        q //= 2
    nc = s // q
    a = -jnp.exp(a_log.astype(jnp.float32))  # (h,)
    abar = dt.astype(jnp.float32) * a  # (b, s, h)

    xc = x.reshape(bsz, nc, q, h, p)
    bc = b_in.reshape(bsz, nc, q, n).astype(jnp.float32)
    cc = c_in.reshape(bsz, nc, q, n).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, q, h).astype(jnp.float32)
    ac = abar.reshape(bsz, nc, q, h).transpose(0, 3, 1, 2)  # (b, h, nc, q)
    a_cs = jnp.cumsum(ac, axis=-1)  # (b, h, nc, q)

    # 1) intra-chunk (diagonal blocks)
    l_mat = jnp.exp(_segsum(ac))  # (b, h, nc, q, q)
    scores = jnp.einsum("bcln,bcsn->bcls", cc, bc)  # (b, nc, q, q)
    m = jnp.einsum("bcls,bhcls->bhcls", scores, l_mat)
    # dt-weighted input enters the state: weight x by dt
    xdt = xc.astype(jnp.float32) * dtc[..., None]  # (b, nc, q, h, p)
    y_diag = jnp.einsum("bhcls,bcshp->bclhp", m, xdt)

    # 2) per-chunk states
    decay_states = jnp.exp(a_cs[..., -1:] - a_cs)  # (b, h, nc, q)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", bc, decay_states, xdt)

    # 3) inter-chunk recurrence
    chunk_decay = jnp.exp(a_cs[..., -1])  # (b, h, nc)

    def step(carry, inp):
        st, dec = inp  # (b, h, p, n), (b, h)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit the state *entering* the chunk

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        step,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b, nc, h, p, n)

    # 4) inter-chunk outputs
    state_decay = jnp.exp(a_cs)  # (b, h, nc, q)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y.astype(x.dtype), final


def ssm_forward(params, x: jnp.ndarray, cfg: ModelConfig, *, return_state: bool = False):
    """Full Mamba2 block body (pre-norm residual handled by caller).

    x: (b, s, d) -> (b, s, d); with return_state also the decode-ready
    {"ssm": (b,h,p,n), "conv": (b,w-1,ch)} cache.
    """
    h, p, n = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = x @ params["w_z"]
    xs_raw = x @ params["w_x"]
    bb_raw = x @ params["w_b"]
    cb_raw = x @ params["w_c"]
    dt_raw = x @ params["w_dt"]

    xs = jax.nn.silu(_causal_conv(xs_raw, params["conv_x"]))
    bb = jax.nn.silu(_causal_conv(bb_raw, params["conv_b"]))
    cb = jax.nn.silu(_causal_conv(cb_raw, params["conv_c"]))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])

    bsz, s, _ = x.shape
    xh = xs.reshape(bsz, s, h, p)
    y, final_state = ssd_chunked(xh, dt, params["a_log"], bb, cb, cfg.ssm_chunk)
    y = y + xh.astype(jnp.float32) * params["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, h * p).astype(x.dtype)

    # gated RMSNorm (mamba2)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-5)).astype(x.dtype) * params["norm"]
    out = y @ params["w_out"]
    if return_state:
        w = cfg.ssm_conv_width
        packed = jnp.concatenate([xs_raw, bb_raw, cb_raw], axis=-1)  # pre-conv
        tail = packed[:, -(w - 1):, :]
        return out, {"ssm": final_state, "conv": tail.astype(x.dtype)}
    return out


# ---------------------------------------------------------------------------
# decode: O(1) recurrent update
# ---------------------------------------------------------------------------

def ssm_decode(params, x: jnp.ndarray, state: dict, cfg: ModelConfig):
    """Single-token step. x: (b, 1, d); state = {"ssm": (b,h,p,n), "conv": (b,w-1,ch)}."""
    h, p, n = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state
    bsz = x.shape[0]
    xt = x[:, 0, :]
    z = xt @ params["w_z"]
    packed = jnp.concatenate(
        [xt @ params["w_x"], xt @ params["w_b"], xt @ params["w_c"]], axis=-1
    )  # (b, ch)
    conv_w = jnp.concatenate([params["conv_x"], params["conv_b"], params["conv_c"]], axis=1)
    hist = jnp.concatenate([state["conv"], packed[:, None, :]], axis=1)  # (b, w, ch)
    conv_out = jnp.einsum("bwc,wc->bc", hist, conv_w)
    conv_out = jax.nn.silu(conv_out)
    xs, bb, cb = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + n], axis=-1)
    new_conv = hist[:, 1:, :]

    dt = jax.nn.softplus((xt @ params["w_dt"]).astype(jnp.float32) + params["dt_bias"])  # (b, h)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    da = jnp.exp(dt * a)  # (b, h)
    xh = xs.reshape(bsz, h, p).astype(jnp.float32)
    ssm = state["ssm"] * da[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, bb.astype(jnp.float32), xh
    )
    y = jnp.einsum("bn,bhpn->bhp", cb.astype(jnp.float32), ssm)
    y = y + xh * params["d_skip"][None, :, None]
    y = y.reshape(bsz, h * p).astype(x.dtype)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-5)).astype(x.dtype) * params["norm"]
    return (y @ params["w_out"])[:, None, :], {"ssm": ssm, "conv": new_conv}


def ssm_ref_sequential(x, dt, a_log, b_in, c_in):
    """Pure recurrence oracle for tests: O(S) loop, no chunking."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))

    def step(state, inp):
        xt, dtt, bt, ct = inp
        da = jnp.exp(dtt * a)  # (b, h)
        state = state * da[..., None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dtt, bt, xt.astype(jnp.float32)
        )
        y = jnp.einsum("bn,bhpn->bhp", ct, state)
        return state, y

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(
        step,
        init,
        (
            x.transpose(1, 0, 2, 3),
            dt.astype(jnp.float32).transpose(1, 0, 2),
            b_in.astype(jnp.float32).transpose(1, 0, 2),
            c_in.astype(jnp.float32).transpose(1, 0, 2),
        ),
    )
    return ys.transpose(1, 0, 2, 3)
